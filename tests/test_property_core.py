"""Property-based tests (hypothesis) for the compiler's core invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import frontend, pipeline
from repro.core.affine import AExpr, pack_banked, unpack_banked


# ---------------------------------------------------------------------------
# Affine algebra laws
# ---------------------------------------------------------------------------

_vars = st.sampled_from(["i", "j", "k"])
_coeffs = st.integers(min_value=-6, max_value=6)
_consts = st.integers(min_value=-20, max_value=20)


@st.composite
def affine_exprs(draw, depth=2):
    e = AExpr.const_(draw(_consts))
    for _ in range(draw(st.integers(1, 3))):
        e = e + AExpr.var(draw(_vars)) * draw(_coeffs)
    if depth > 0 and draw(st.booleans()):
        c = draw(st.integers(2, 5))
        e = e.floordiv(c) if draw(st.booleans()) else e.mod(c)
        e = e + AExpr.var(draw(_vars)) * draw(_coeffs)
    return e


_envs = st.fixed_dictionaries(
    {"i": st.integers(0, 30), "j": st.integers(0, 30), "k": st.integers(0, 30)})


class TestAffineProperties:
    @given(e=affine_exprs(), env=_envs, c=st.integers(2, 7))
    @settings(max_examples=200, deadline=None)
    def test_divmod_reconstruction(self, e, env, c):
        """(e // c) * c + (e % c) == e  pointwise."""
        lhs = (e.floordiv(c) * c + e.mod(c)).evaluate(env)
        assert lhs == e.evaluate(env)

    @given(e=affine_exprs(), env=_envs, c=st.integers(2, 7))
    @settings(max_examples=200, deadline=None)
    def test_fold_preserves_value(self, e, env, c):
        """Folding rules never change the evaluated result."""
        assert e.mod(c).evaluate(env) == e.evaluate(env) % c
        assert e.floordiv(c).evaluate(env) == e.evaluate(env) // c

    @given(e=affine_exprs(), env=_envs,
           sub=st.integers(0, 10), c=st.integers(2, 5))
    @settings(max_examples=200, deadline=None)
    def test_substitute_consistent(self, e, env, sub, c):
        """substitute(var -> expr) == evaluate with composed env."""
        repl = AExpr.var("j") * c + sub
        e2 = e.substitute({"i": repl})
        env2 = dict(env)
        env2["i"] = repl.evaluate(env)
        assert e2.evaluate(env) == e.evaluate(env2)

    @given(e=affine_exprs(), c=st.integers(2, 5), a=st.integers(0, 4))
    @settings(max_examples=200, deadline=None)
    def test_stripmine_fold_is_constant_bank(self, e, c, a):
        """After i := c*ii + a with a < c, (i % c) is the constant a."""
        if a >= c:
            a = a % c
        i = AExpr.var("i")
        folded = i.mod(c).substitute({"i": AExpr.var("ii") * c + a})
        assert folded.is_const() and folded.const_value() == a


# ---------------------------------------------------------------------------
# Banking layout bijection
# ---------------------------------------------------------------------------

class TestBankingProperties:
    @given(
        dims=st.lists(st.integers(1, 9), min_size=1, max_size=3),
        factor=st.integers(1, 4),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(max_examples=120, deadline=None)
    def test_pack_unpack_bijection(self, dims, factor, seed):
        factors = tuple(min(factor, d) for d in dims)
        rng = np.random.default_rng(seed)
        arr = rng.normal(size=tuple(dims)).astype(np.float32)
        out = unpack_banked(pack_banked(arr, factors), dims, factors)
        np.testing.assert_array_equal(out, arr)

    @given(
        dims=st.lists(st.integers(2, 8), min_size=2, max_size=2),
        factor=st.sampled_from([2]),
    )
    @settings(max_examples=40, deadline=None)
    def test_every_element_lands_in_declared_bank(self, dims, factor):
        """Cyclic banking invariant: element (i,j) lives in bank
        (i%f)*f + (j%f) at intra position (i//f, j//f)."""
        arr = np.arange(dims[0] * dims[1], dtype=np.float32).reshape(dims)
        factors = (factor, factor)
        packed = pack_banked(arr, factors)
        for i in range(dims[0]):
            for j in range(dims[1]):
                bank = (i % factor) * factor + (j % factor)
                assert packed[bank, i // factor, j // factor] == arr[i, j]


# ---------------------------------------------------------------------------
# End-to-end: random small MLPs, every banking config agrees with the oracle
# ---------------------------------------------------------------------------

class TestCompilerAgreesWithOracle:
    @given(
        in_f=st.sampled_from([4, 6, 8]),
        hid=st.sampled_from([4, 8]),
        out_f=st.sampled_from([2, 4]),
        rows=st.sampled_from([1, 2]),
        factor=st.sampled_from([1, 2]),
        mode=st.sampled_from(["layout", "branchy"]),
        seed=st.integers(0, 1000),
    )
    @settings(max_examples=25, deadline=None)
    def test_random_mlp(self, in_f, hid, out_f, rows, factor, mode, seed):
        rng = np.random.default_rng(seed)
        m = frontend.Sequential(
            frontend.Linear(in_f, hid, rng=rng), frontend.ReLU(),
            frontend.Linear(hid, out_f, rng=rng))
        x = rng.normal(size=(rows, in_f)).astype(np.float32)
        d = pipeline.compile_model(m, [(rows, in_f)], factor=factor,
                                   mode=mode, check_hazards=(mode == "layout"))
        hw = d.run({"arg0": x})[0]
        jx = d.run_oracle({"arg0": x})[0]
        np.testing.assert_allclose(hw, jx, rtol=1e-4, atol=1e-5)
