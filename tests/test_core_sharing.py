"""Resource-sharing (binding) pass: mutual-exclusion analysis, latency
neutrality, resource reduction regimes, par safety, and end-to-end
equivalence of shared designs against the jnp oracle."""
import numpy as np
import pytest

from repro.core import estimator, frontend, pipeline, sharing
from repro.core.calyx import (Cell, CIf, CPar, CRepeat, CSeq, Component,
                              GEnable, Group)


def _comp(control, group_cells, extra_cells=()):
    """Minimal component: every named cell is an fp_add unless given."""
    cells = {}
    groups = {}
    for gname, cnames in group_cells.items():
        for c in cnames:
            if c not in cells:
                cells[c] = Cell(c, "fp_add")
        groups[gname] = Group(gname, 3, list(cnames), [])
    for cell in extra_cells:
        cells[cell.name] = cell
    return Component("t", cells, groups, control)


class TestMutualExclusion:
    def test_seq_children_exclusive(self):
        ctl = CSeq([GEnable("a"), GEnable("b")])
        assert sharing.concurrent_pairs(ctl) == set()
        assert sharing.mutually_exclusive(ctl, "a", "b")

    def test_par_arms_concurrent(self):
        ctl = CPar([GEnable("a"), GEnable("b")])
        assert sharing.concurrent_pairs(ctl) == {frozenset({"a", "b"})}
        assert not sharing.mutually_exclusive(ctl, "a", "b")

    def test_if_arms_exclusive(self):
        ctl = CIf(0, GEnable("a"), GEnable("b"))
        assert sharing.concurrent_pairs(ctl) == set()

    def test_repeat_body_exclusive_across_iterations(self):
        ctl = CRepeat(8, CSeq([GEnable("a"), GEnable("b")]), var="i")
        assert sharing.concurrent_pairs(ctl) == set()

    def test_seq_inside_par_arm(self):
        # a,b share an arm (exclusive with each other), both race c
        ctl = CPar([CSeq([GEnable("a"), GEnable("b")]), GEnable("c")])
        pairs = sharing.concurrent_pairs(ctl)
        assert pairs == {frozenset({"a", "c"}), frozenset({"b", "c"})}

    def test_par_under_repeat_stays_concurrent(self):
        ctl = CRepeat(4, CPar([GEnable("a"), GEnable("b")]), var="i")
        assert not sharing.mutually_exclusive(ctl, "a", "b")

    def test_group_not_exclusive_with_itself(self):
        ctl = CSeq([GEnable("a")])
        assert not sharing.mutually_exclusive(ctl, "a", "a")


class TestBinding:
    def test_sequential_groups_share_one_unit(self):
        comp = _comp(CSeq([GEnable("g1"), GEnable("g2")]),
                     {"g1": ["add1"], "g2": ["add2"]})
        out, rep = sharing.share_cells(comp)
        assert rep.cells_before == 2 and rep.cells_after == 1
        (pool,) = [c for c in out.cells.values() if c.kind == "fp_add"]
        assert pool.users == 2
        assert out.groups["g1"].cells == out.groups["g2"].cells == [pool.name]

    def test_par_arms_never_merge(self):
        comp = _comp(CPar([GEnable("g1"), GEnable("g2")]),
                     {"g1": ["add1"], "g2": ["add2"]})
        out, rep = sharing.share_cells(comp)
        assert rep.cells_after == 2
        assert out.groups["g1"].cells != out.groups["g2"].cells
        sharing.verify_sharing(out)  # must not raise

    def test_same_group_uses_stay_distinct(self):
        comp = _comp(CSeq([GEnable("g1")]), {"g1": ["add1", "add2"]})
        out, rep = sharing.share_cells(comp)
        assert rep.cells_after == 2
        assert len(set(out.groups["g1"].cells)) == 2

    def test_const_classes_not_merged(self):
        cells = [Cell("m1", "int_mul", const=12), Cell("m2", "int_mul", const=48)]
        comp = Component(
            "t", {c.name: c for c in cells},
            {"g1": Group("g1", 1, ["m1"], []), "g2": Group("g2", 1, ["m2"], [])},
            CSeq([GEnable("g1"), GEnable("g2")]))
        out, rep = sharing.share_cells(comp)
        assert rep.cells_after == 2          # different constants: no merge
        kinds = {(c.kind, c.const) for c in out.cells.values()}
        assert kinds == {("int_mul", 12), ("int_mul", 48)}

    def test_if_cond_cells_pinned(self):
        cond_cell = Cell("mcond", "int_mul", const=12)
        comp = _comp(
            CSeq([CIf(0, GEnable("g1"), GEnable("g2"), cond_cells=["mcond"])]),
            {"g1": ["add1"], "g2": ["add2"]},
            extra_cells=[cond_cell])
        out, _ = sharing.share_cells(comp)
        assert "mcond" in out.cells          # untouched
        assert out.cells["mcond"].users == 1

    def test_unshareable_kinds_untouched(self):
        reg = Cell("reg_x", "reg32")
        comp = _comp(CSeq([GEnable("g1"), GEnable("g2")]),
                     {"g1": ["add1", "reg_x"], "g2": ["reg_x"]},
                     extra_cells=[reg])
        comp.cells["reg_x"] = reg
        out, _ = sharing.share_cells(comp)
        assert out.cells["reg_x"].users == 1
        assert "reg_x" in out.groups["g2"].cells


class TestModelLevel:
    @pytest.fixture(scope="class")
    def matmul_pair(self):
        m = frontend.Linear(64, 48, bias=False)
        return (pipeline.compile_model(m, [(1, 64)], factor=4, share=True),
                pipeline.compile_model(m, [(1, 64)], factor=4, share=False))

    def test_sharing_preserves_cycles(self, matmul_pair):
        ds, du = matmul_pair
        assert ds.estimate.cycles == du.estimate.cycles
        assert ds.estimate.fsm_states == du.estimate.fsm_states

    def test_sharing_reduces_lut_dsp(self, matmul_pair):
        """Acceptance: factor-4 layout-banked matmul drops >=25% LUT+DSP."""
        ds, du = matmul_pair
        shared = ds.estimate.resources["LUT"] + ds.estimate.resources["DSP"]
        unshared = du.estimate.resources["LUT"] + du.estimate.resources["DSP"]
        assert shared <= 0.75 * unshared, (shared, unshared)

    def test_report_counts(self, matmul_pair):
        ds, _ = matmul_pair
        assert ds.sharing is not None
        assert ds.sharing.cells_after < ds.sharing.cells_before
        assert all(a <= b for b, a in ds.sharing.by_kind.values())
        # every pool's users really exist in the component
        for pool, origs in ds.sharing.pools.items():
            assert pool in ds.component.cells
            assert ds.component.cells[pool].users == len(origs)

    def test_no_sharing_across_par(self, matmul_pair):
        ds, _ = matmul_pair
        sharing.verify_sharing(ds.component)  # must not raise

    def test_emit_text_surfaces_bound_cells(self, matmul_pair):
        ds, _ = matmul_pair
        txt = ds.calyx_text()
        assert "shared_fp_add" in txt
        assert "// shared x" in txt
        assert " uses shared_" in txt

    def test_shared_ffnn_matches_oracle(self):
        d = pipeline.compile_model(frontend.paper_ffnn(), [(1, 64)],
                                   factor=4, share=True)
        x = np.random.default_rng(3).normal(size=(1, 64)).astype(np.float32)
        hw = d.run({"arg0": x})[0]
        oracle = d.run_oracle({"arg0": x})[0]
        np.testing.assert_allclose(hw, oracle, rtol=1e-4, atol=1e-5)

    def test_sharing_keeps_banked_speedup(self):
        m = frontend.paper_ffnn()
        d1 = pipeline.compile_model(m, [(1, 64)], factor=1, share=True)
        d4 = pipeline.compile_model(m, [(1, 64)], factor=4, share=True)
        assert d4.estimate.cycles < 0.25 * d1.estimate.cycles

    def test_mux_overhead_nonzero_for_shared(self, matmul_pair):
        ds, _ = matmul_pair
        over = sharing.mux_overhead(ds.component)
        assert over.lut > 0
