"""Scheduling passes: strip-mining, par unrolling, par/seq restructuring."""
import numpy as np
import pytest

from repro.core import affine, frontend, pipeline, schedule
from repro.core.affine import AExpr, Loop, Par, SetReg, Store, ConstF


def _count(prog_or_stmts, cls):
    stmts = prog_or_stmts.body if hasattr(prog_or_stmts, "body") else prog_or_stmts
    return sum(1 for s in affine.walk_statements(stmts) if isinstance(s, cls))


class TestStripMine:
    def test_par_data_unrolled_with_static_banks(self):
        g = frontend.trace(frontend.paper_ffnn(), [(1, 64)])
        prog = affine.lower_graph(g)
        par = schedule.parallelize(prog, 2)
        assert _count(par, Par) > 0

    def test_factor_not_dividing_uses_gcd(self):
        body = [Store("m", [AExpr.var("i")], ConstF(1.0))]
        loop = Loop("i", 6, body, kind="par_data")
        out = schedule.strip_mine_par(loop, 4)   # gcd(6,4)=2
        assert isinstance(out[0], Loop) and out[0].extent == 3
        inner = out[0].body[0]
        assert isinstance(inner, Par) and len(inner.arms) == 2

    def test_prime_extent_skipped(self):
        body = [Store("m", [AExpr.var("i")], ConstF(1.0))]
        loop = Loop("i", 7, body, kind="par_data")
        out = schedule.strip_mine_par(loop, 2)   # gcd = 1 -> unchanged
        assert out == [loop]

    def test_reduce_split_keeps_semantics(self):
        """Cyclic reduction split: per-arm accumulators + combine."""
        m = frontend.Sequential(frontend.Linear(8, 3, bias=False))
        x = np.random.default_rng(0).normal(size=(2, 8)).astype(np.float32)
        d = pipeline.compile_model(m, [(2, 8)], factor=2)
        np.testing.assert_allclose(d.run({"arg0": x})[0],
                                   d.run_oracle({"arg0": x})[0],
                                   rtol=1e-4, atol=1e-5)


class TestRestructure:
    def test_par_of_equal_loops_hoisted(self):
        """par{ for i {A} | for i {B} } -> for i { par {A|B} }"""
        a = Store("m", [AExpr.var("i") * 2], ConstF(1.0))
        b = Store("m", [AExpr.var("j") * 2 + 1], ConstF(2.0))
        par = Par([[Loop("i", 5, [a])], [Loop("j", 5, [b])]])
        out = schedule.restructure_par(par)
        assert len(out) == 1 and isinstance(out[0], Loop)
        assert out[0].extent == 5
        assert isinstance(out[0].body[0], Par)

    def test_mismatched_extents_left_alone(self):
        a = Store("m", [AExpr.var("i")], ConstF(1.0))
        b = Store("m", [AExpr.var("j")], ConstF(2.0))
        par = Par([[Loop("i", 5, [a])], [Loop("j", 7, [b])]])
        out = schedule.restructure_par(par)
        assert len(out) == 1 and isinstance(out[0], Par)

    def test_restructure_preserves_semantics(self):
        m = frontend.paper_ffnn()
        x = np.random.default_rng(2).normal(size=(1, 64)).astype(np.float32)
        d_on = pipeline.compile_model(m, [(1, 64)], factor=2, restructure=True)
        d_off = pipeline.compile_model(m, [(1, 64)], factor=2,
                                       restructure=False)
        ref = d_on.run_oracle({"arg0": x})[0]
        np.testing.assert_allclose(d_on.run({"arg0": x})[0], ref,
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(d_off.run({"arg0": x})[0], ref,
                                   rtol=1e-4, atol=1e-5)

    def test_restructure_shares_controller_and_is_faster(self):
        """The paper's claim: duplicated per-arm FSMs hurt performance."""
        m = frontend.paper_ffnn()
        d_on = pipeline.compile_model(m, [(1, 64)], factor=2, restructure=True)
        d_off = pipeline.compile_model(m, [(1, 64)], factor=2,
                                       restructure=False)
        assert d_on.estimate.cycles < d_off.estimate.cycles

    def test_reg_renaming_keeps_arms_private(self):
        g = frontend.trace(frontend.paper_ffnn(), [(1, 64)])
        prog = schedule.parallelize(affine.lower_graph(g), 2)
        # hazard checker validates reg privacy; must not raise
        from repro.core import banking
        assert banking.check_par_hazards(prog) == []
