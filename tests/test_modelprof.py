"""Per-operator profiling: sliced-step equivalence, record invariants,
the analytic-vs-HLO cross-check, and the three-level join."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.serve import Engine, ReplayDriver, Request
from repro.models import decode, get_config
from repro.models import params as MP
from repro.models.decode import PROFILED_FAMILIES, profile_ops
from repro.obs import SpanTracer
from repro.obs import modelprof as MPF

# one arch per decomposition: dense, local/global dense, dense+bias,
# ssm, moe, hybrid
EQUIV_ARCHS = ("qwen2-0.5b", "gemma2-27b", "starcoder2-7b",
               "rwkv6-7b", "olmoe-1b-7b", "zamba2-7b")


def _setup(arch, batch=2, cache_len=16, seed=0):
    cfg = get_config(arch).reduced()
    params = MP.init_params(cfg, seed=seed)
    return cfg, params


class TestProfileOps:
    def test_embed_first_head_last(self):
        for arch in EQUIV_ARCHS:
            ops = profile_ops(get_config(arch).reduced())
            assert ops[0] == ("embed", -1)
            assert ops[-1] == ("head", -1)

    def test_per_group_ops_cover_all_groups(self):
        cfg = get_config("qwen2-0.5b").reduced()
        groups = {g for _, g in profile_ops(cfg) if g >= 0}
        assert groups == set(range(cfg.num_groups))

    def test_unprofiled_family_raises(self):
        with pytest.raises(NotImplementedError):
            profile_ops(get_config("llama-3.2-vision-11b").reduced())


class TestSlicedEquivalence:
    """The sliced step must be bit-identical to the fused step — slicing
    is observability, not a numerics change."""

    @pytest.mark.parametrize("arch", EQUIV_ARCHS)
    def test_logits_and_cache_match_fused(self, arch):
        cfg, params = _setup(arch)
        batch, cache_len, steps = 2, 16, 3
        fused = decode.make_serve_step(cfg)
        prof = decode.make_profiled_serve_step(cfg)
        cache_f = decode.init_cache(cfg, params, batch, cache_len)
        cache_p = decode.ProfiledServeStep.init_cache(cfg, params, batch,
                                                      cache_len)
        rng = np.random.default_rng(0)
        tok = jnp.asarray(rng.integers(1, cfg.vocab_size,
                                       size=(batch, 1)), jnp.int32)
        for i in range(steps):
            pos = jnp.asarray(i, jnp.int32)
            lf, cache_f = fused(params, cache_f, tok, pos)
            lp, cache_p, walls = prof(params, cache_p, tok, pos)
            assert len(walls) == len(prof.ops)
            assert all(w >= 0 for w in walls)
            np.testing.assert_array_equal(np.asarray(lf), np.asarray(lp))
            tok = jnp.argmax(lf[:, -1], axis=-1).astype(jnp.int32)[:, None]
        stacked = decode.ProfiledServeStep.stack_cache(cache_p)
        for a, b in zip(jax.tree.leaves(cache_f), jax.tree.leaves(stacked)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestRecords:
    def _records(self, n_steps=2):
        cfg = get_config("qwen2-0.5b").reduced()
        prof = MPF.LayerProfiler()
        ops = profile_ops(cfg)
        for s in range(n_steps):
            prof.on_step(s, ops, [10.0] * len(ops))
        return cfg, prof.records

    def test_roundtrip(self):
        _, records = self._records()
        text = MPF.to_jsonl(records)
        back = MPF.from_jsonl(text)
        assert back == records

    def test_stable_export_is_deterministic(self):
        cfg, _ = self._records()
        ops = profile_ops(cfg)
        streams = []
        for _ in range(2):
            prof = MPF.LayerProfiler()
            for s in range(3):
                # jittered walls/stamps must normalize away
                prof.on_step(s, ops, [float(hash((s, i)) % 97)
                                      for i in range(len(ops))])
            streams.append(MPF.to_jsonl(prof.records, stable=True))
        assert streams[0] == streams[1]
        assert '"n":0' in streams[0]

    def test_record_off_profiler_records_nothing(self):
        cfg = get_config("qwen2-0.5b").reduced()
        prof = MPF.LayerProfiler(record=False)
        ops = profile_ops(cfg)
        prof.on_step(0, ops, [1.0] * len(ops))
        assert prof.records == []

    def test_wrong_kind_rejected(self):
        with pytest.raises(ValueError):
            MPF.LayerRecord.from_json('{"t":0,"k":"step","p":[],"s":0,'
                                      '"o":"attn","g":0,"n":1}')

    def test_validate_passes_complete_stream(self):
        cfg, records = self._records()
        assert MPF.validate(records, cfg=cfg, engine_steps=2) == []

    def test_validate_rejects_malformed(self):
        cfg, records = self._records()
        # bad provenance
        bad = [MPF.LayerRecord(0, "attn", 0, 0, 5, ("engine", "s0", "mlp"))]
        assert any("prov" in p for p in MPF.validate(bad))
        # negative duration
        bad = [MPF.LayerRecord(0, "attn", 0, 0, -5,
                               MPF.layer_prov(0, "attn", 0))]
        assert any("negative" in p for p in MPF.validate(bad))
        # incomplete op set for a step
        assert any("ops" in p
                   for p in MPF.validate(records[:-1], cfg=cfg))
        # wrong step count
        assert any("engine ran" in p
                   for p in MPF.validate(records, engine_steps=5))
        # non-contiguous steps
        shifted = [MPF.LayerRecord(r.ts_us, r.op, r.group, r.step + 1,
                                   r.dur_us,
                                   MPF.layer_prov(r.step + 1, r.op, r.group))
                   for r in records]
        assert any("contiguous" in p for p in MPF.validate(shifted))


class TestAnalyticModel:
    def test_costs_align_with_profile_ops(self):
        for arch in ("qwen2-0.5b", "rwkv6-7b", "olmoe-1b-7b", "zamba2-7b"):
            cfg = get_config(arch).reduced()
            costs = MPF.analytic_op_costs(cfg, batch=2, cache_len=16)
            assert [(c.op, c.group) for c in costs] == list(profile_ops(cfg))
            for c in costs:
                assert c.bytes_rw > 0
                if c.op != "embed":
                    assert c.flops > 0, c

    def test_crosscheck_hlo_qwen(self):
        """The analytic dot-FLOPs must agree with hlo_analysis on the real
        decode-step HLO within the documented tolerances (the committed
        BENCH_model.json gate, run here on the smallest config)."""
        cfg = get_config("qwen2-0.5b").reduced()
        report, problems = MPF.crosscheck_hlo(cfg, batch=2, cache_len=32)
        assert problems == [], (report, problems)
        assert report["flops_rel_err"] <= MPF.FLOPS_RTOL
        assert (1.0 / MPF.BYTES_FACTOR <= report["bytes_ratio"]
                <= MPF.BYTES_FACTOR)

    def test_roofline_class_ridge(self):
        peaks = (100.0, 10.0)          # ridge at 10 FLOPs/byte
        assert MPF.roofline_class(5.0, peaks) == "memory"
        assert MPF.roofline_class(20.0, peaks) == "compute"

    def test_offload_report_ranked_by_share(self):
        cfg = get_config("qwen2-0.5b").reduced()
        prof = MPF.LayerProfiler()
        ops = profile_ops(cfg)
        walls = [100.0 if op == "attn" else 10.0 for op, _ in ops]
        prof.on_step(0, ops, walls)
        costs = MPF.analytic_op_costs(cfg, batch=1, cache_len=4096)
        rows = MPF.offload_report(cfg, prof.records, costs)
        assert rows[0]["op"] == "attn" and rows[0]["rank"] == 1
        assert [r["rank"] for r in rows] == list(range(1, len(rows) + 1))
        shares = [r["share"] for r in rows]
        assert shares == sorted(shares, reverse=True)
        assert all(r["bound"] in ("compute", "memory") for r in rows)


class TestThreeLevelJoin:
    def _drive(self, arch="qwen2-0.5b", requests=3):
        cfg, params = _setup(arch)
        tr = SpanTracer()
        prof = MPF.LayerProfiler()
        eng = Engine(cfg, params, slots=2, max_len=64,
                     spans=tr, layers=prof)
        rng = np.random.default_rng(1)
        arrivals = [(0, Request(r, rng.integers(
            1, cfg.vocab_size, size=4).astype(np.int32), 4))
            for r in range(requests)]
        drv = ReplayDriver(eng, arrivals)
        while drv.active:
            drv.tick()
        return cfg, eng, tr, prof

    def test_join_closes(self):
        cfg, eng, tr, prof = self._drive()
        assert MPF.validate(prof.records, cfg=cfg,
                            engine_steps=eng.steps) == []
        assert MPF.join_mismatches(prof.records, tr.events, cfg=cfg) == []
        rows = MPF.join_steps(prof.records, tr.events)
        assert set(rows) == set(range(eng.steps))
        for row in rows.values():
            assert row.layer_count == len(profile_ops(cfg))
            assert 0 < row.layers_wall_us <= row.step_wall_us

    def test_join_detects_lost_segments(self):
        cfg, eng, tr, prof = self._drive()
        # drop one step's records: the span now has no layer records
        broken = [r for r in prof.records if r.step != 1]
        problems = MPF.join_mismatches(broken, tr.events, cfg=cfg)
        assert problems

    def test_summaries_cover_all_ops(self):
        cfg, eng, tr, prof = self._drive()
        summary = MPF.summarize(prof.records)
        assert set(summary) == set(profile_ops(cfg))
        shares = MPF.op_shares(prof.records)
        assert abs(sum(shares.values()) - 1.0) < 1e-9
