"""Data pipeline, optimizer, compression, checkpointing, fault tolerance."""
import os
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import get_config
from repro.optim import adamw
from repro.optim.compression import (ef_compress, ef_decompress, init_errors)
from repro.runtime.trainer import (StragglerDetector, Trainer, TrainerConfig,
                                   WorkerFailure)


class TestDataPipeline:
    def test_deterministic(self):
        cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=4)
        a = SyntheticLM(cfg).batch(7)["tokens"]
        b = SyntheticLM(cfg).batch(7)["tokens"]
        np.testing.assert_array_equal(a, b)

    def test_steps_differ(self):
        cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=4)
        d = SyntheticLM(cfg)
        assert not np.array_equal(d.batch(0)["tokens"], d.batch(1)["tokens"])

    def test_host_sharding(self):
        cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=8)
        h0 = SyntheticLM(cfg, host_id=0, host_count=2).batch(3)["tokens"]
        h1 = SyntheticLM(cfg, host_id=1, host_count=2).batch(3)["tokens"]
        assert h0.shape == (4, 16)
        assert not np.array_equal(h0, h1)

    def test_planted_structure_learnable(self):
        """Bigram successors appear at the configured rate."""
        cfg = DataConfig(vocab_size=50, seq_len=128, global_batch=8,
                         bigram_frac=0.9)
        d = SyntheticLM(cfg)
        t = d.batch(0)["tokens"]
        hits = (d._succ[t[:, :-1]] == t[:, 1:]).mean()
        assert hits > 0.6

    def test_modality_stub(self):
        mc = get_config("whisper-large-v3").reduced()
        cfg = DataConfig(vocab_size=mc.vocab_size, seq_len=16, global_batch=2)
        b = SyntheticLM(cfg, model_cfg=mc).batch(0)
        assert b["modality"].shape == (2, mc.encoder_seq, mc.d_model)


class TestAdamW:
    def test_converges_on_quadratic(self):
        cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                                total_steps=200)
        params = {"x": jnp.array([5.0, -3.0])}
        state = adamw.init_state(params)
        for _ in range(150):
            grads = jax.tree.map(lambda p: 2 * p, params)  # d/dx x^2
            params, state, _ = adamw.apply_updates(cfg, params, grads, state)
        assert float(jnp.abs(params["x"]).max()) < 0.3

    def test_clipping(self):
        cfg = adamw.AdamWConfig(clip_norm=1.0, warmup_steps=1)
        params = {"x": jnp.zeros(3)}
        state = adamw.init_state(params)
        grads = {"x": jnp.full(3, 1e6)}
        _, _, m = adamw.apply_updates(cfg, params, grads, state)
        assert m["grad_norm"] > 1e5  # reported pre-clip

    def test_schedule_warmup_and_decay(self):
        cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                                min_lr_frac=0.1)
        lrs = [float(adamw.schedule(cfg, jnp.asarray(s)))
               for s in [0, 5, 10, 100]]
        assert lrs[0] == 0.0 and lrs[1] == pytest.approx(0.5)
        assert lrs[2] == pytest.approx(1.0)
        assert lrs[3] == pytest.approx(0.1, rel=0.01)


class TestCompression:
    def test_roundtrip_error_bounded(self):
        rng = np.random.default_rng(0)
        g = {"w": jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)}
        e = init_errors(g)
        q, s, new_e = ef_compress(g, e)
        deq = ef_decompress(q, s)
        err = float(jnp.abs(deq["w"] - g["w"]).max())
        assert err <= float(s["w"]) * 0.5 + 1e-6
        assert q["w"].dtype == jnp.int8

    def test_error_feedback_accumulates(self):
        """EF makes the *average* of repeated compressions unbiased."""
        g = {"w": jnp.full((128,), 0.001, jnp.float32)}  # tiny vs scale
        e = init_errors(g)
        total = jnp.zeros((128,))
        for _ in range(50):
            q, s, e = ef_compress(g, e)
            total = total + ef_decompress(q, s)["w"]
        avg = total / 50
        np.testing.assert_allclose(avg, g["w"], rtol=0.2)


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        m = CheckpointManager(str(tmp_path), async_save=False)
        state = {"a": jnp.arange(6.0).reshape(2, 3),
                 "nested": {"b": jnp.ones(4, jnp.int32)}}
        m.save(5, state)
        restored, step = m.restore(state)
        assert step == 5
        np.testing.assert_array_equal(restored["a"], state["a"])
        np.testing.assert_array_equal(restored["nested"]["b"],
                                      state["nested"]["b"])

    def test_retention(self, tmp_path):
        m = CheckpointManager(str(tmp_path), keep_n=2, async_save=False)
        state = {"a": jnp.zeros(2)}
        for s in (1, 2, 3, 4):
            m.save(s, state)
        assert m.all_steps() == [3, 4]

    def test_incomplete_checkpoint_ignored(self, tmp_path):
        m = CheckpointManager(str(tmp_path), async_save=False)
        m.save(1, {"a": jnp.zeros(2)})
        # simulate a crash mid-save: directory without manifest
        broken = tmp_path / "step_00000002"
        broken.mkdir()
        (broken / "arrays.npz").write_bytes(b"garbage")
        assert m.latest_step() == 1

    def test_async_save(self, tmp_path):
        m = CheckpointManager(str(tmp_path), async_save=True)
        m.save(7, {"a": jnp.ones(8)})
        m.wait()
        assert m.latest_step() == 7


class TestFaultTolerance:
    def _trainer(self, tmp_path, failure_hook=None, steps=12):
        cfg = get_config("qwen2-0.5b").reduced()
        tcfg = TrainerConfig(total_steps=steps, checkpoint_every=4,
                             checkpoint_dir=str(tmp_path), max_restarts=2)
        opt = adamw.AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=steps,
                                weight_decay=0.0)
        return Trainer(cfg, tcfg, opt_cfg=opt, failure_hook=failure_hook,
                       data_cfg=DataConfig(vocab_size=cfg.vocab_size,
                                           seq_len=32, global_batch=4))

    def test_loss_decreases(self, tmp_path):
        tr = self._trainer(tmp_path, steps=12)
        tr.run_with_restarts()
        first = np.mean([h["loss"] for h in tr.history[:3]])
        last = np.mean([h["loss"] for h in tr.history[-3:]])
        assert last < first

    def test_restart_resumes_from_checkpoint(self, tmp_path):
        fired = {"done": False}

        def fail_once(step):
            if step == 6 and not fired["done"]:
                fired["done"] = True
                raise WorkerFailure("injected at step 6")

        tr = self._trainer(tmp_path, failure_hook=fail_once, steps=12)
        tr.run_with_restarts()
        resumes = [h for h in tr.history if "restart" in h]
        assert len(resumes) == 1
        assert resumes[0]["resume_step"] == 4      # last checkpoint before 6
        steps_seen = [h["step"] for h in tr.history if "step" in h]
        assert steps_seen[-1] == 11                # finished the run

    def test_trajectory_identical_after_restart(self, tmp_path):
        """Counter-based data + checkpointed state => same losses."""
        base = self._trainer(tmp_path / "a", steps=8)
        base.run_with_restarts()
        base_losses = {h["step"]: h["loss"] for h in base.history
                       if "step" in h}

        def fail_once(step, fired={"done": False}):
            if step == 5 and not fired["done"]:
                fired["done"] = True
                raise WorkerFailure("boom")

        ft = self._trainer(tmp_path / "b", failure_hook=fail_once, steps=8)
        ft.run_with_restarts()
        ft_losses = {}
        for h in ft.history:
            if "step" in h:
                ft_losses[h["step"]] = h["loss"]   # last write wins (replay)
        for s in (6, 7):
            assert ft_losses[s] == pytest.approx(base_losses[s], rel=1e-4)

    def test_exceeds_max_restarts(self, tmp_path):
        def always_fail(step):
            raise WorkerFailure("dead node")

        tr = self._trainer(tmp_path, failure_hook=always_fail, steps=8)
        with pytest.raises(RuntimeError, match="max_restarts"):
            tr.run_with_restarts()


class TestStragglerDetector:
    def test_flags_slow_host(self):
        d = StragglerDetector(alpha=1.0, threshold=1.5)
        for h in range(8):
            d.record(h, 1.0)
        d.record(3, 9.0)
        assert d.stragglers() == [3]

    def test_no_false_positives(self):
        d = StragglerDetector()
        for h in range(8):
            for _ in range(5):
                d.record(h, 1.0 + 0.01 * h)
        assert d.stragglers() == []


class TestCompressedPsumMultiDevice:
    def test_int8_allreduce_in_hlo(self):
        """Run in a subprocess with 8 host devices: compressed_psum must
        (a) approximate the f32 psum, (b) put an s32 (int8-accum) all-reduce
        in the HLO instead of the f32 one."""
        import subprocess
        import sys
        import textwrap
        code = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.optim.compression import compressed_psum, init_errors
            mesh = jax.make_mesh((8,), ("data",))
            rng = np.random.default_rng(0)
            g = {"w": jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)}
            e = init_errors(g)
            with mesh:
                fn = jax.jit(lambda g, e: compressed_psum(g, e, mesh, "data"))
                out, new_e = fn(g, e)
                text = fn.lower(g, e).compile().as_text()
            np.testing.assert_allclose(np.asarray(out["w"]),
                                       np.asarray(g["w"]), atol=0.05)
            assert "s32" in text and "all-reduce" in text
            print("COMPRESSED_PSUM_OK")
        """)
        r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                           text=True, env={**os.environ,
                                           "PYTHONPATH": "src"},
                           cwd=str(pathlib.Path(__file__).resolve().parents[1]),
                           timeout=300)
        assert "COMPRESSED_PSUM_OK" in r.stdout, r.stderr[-2000:]


class TestElasticRescale:
    def test_restore_onto_different_mesh(self):
        """Save a sharded state on an 8-way mesh, restore onto 4-way and
        2x4 meshes — the checkpoint is mesh-shape-agnostic (elastic)."""
        import subprocess
        import sys
        import textwrap
        code = textwrap.dedent("""
            import os, tempfile
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.checkpoint.manager import CheckpointManager

            d = tempfile.mkdtemp()
            m8 = jax.make_mesh((8,), ("data",))
            state = {"w": jax.device_put(
                jnp.arange(64.0).reshape(8, 8),
                NamedSharding(m8, P("data", None)))}
            ckpt = CheckpointManager(d, async_save=False)
            ckpt.save(3, state)

            # elastic restore: 4-way data mesh, then a 2x4 (data, model) mesh
            m4 = jax.make_mesh((4,), ("data",))
            like4 = jax.ShapeDtypeStruct((8, 8), jnp.float32,
                                         sharding=NamedSharding(m4, P("data", None)))
            r4, step = ckpt.restore({"w": like4})
            assert step == 3
            np.testing.assert_array_equal(np.asarray(r4["w"]),
                                          np.arange(64.0).reshape(8, 8))
            assert r4["w"].sharding.num_devices == 4

            m24 = jax.make_mesh((2, 4), ("data", "model"))
            like24 = jax.ShapeDtypeStruct((8, 8), jnp.float32,
                                          sharding=NamedSharding(m24, P("data", "model")))
            r24, _ = ckpt.restore({"w": like24})
            np.testing.assert_array_equal(np.asarray(r24["w"]),
                                          np.arange(64.0).reshape(8, 8))
            assert r24["w"].sharding.num_devices == 8
            print("ELASTIC_OK")
        """)
        r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                           text=True, env={**os.environ, "PYTHONPATH": "src"},
                           cwd=str(pathlib.Path(__file__).resolve().parents[1]),
                           timeout=300)
        assert "ELASTIC_OK" in r.stdout, r.stderr[-2000:]
