"""Teacher-forced consistency: feeding tokens one-by-one through
serve_step (cache path) must reproduce the training forward's logits at
every position — the strongest correctness check on cache layout, RoPE
offsets, ring buffers, SSM states, and cross-attention caches."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import decode, get_config
from repro.models import params as MP
from repro.models import transformer as TF

# one representative per family mechanic
ARCHS = ["qwen2-0.5b",          # dense GQA + bias + rope
         "gemma2-27b",          # local/global + ring buffer + softcaps
         "olmoe-1b-7b",         # MoE
         "rwkv6-7b",            # attention-free state
         "zamba2-7b",           # mamba states + shared attn
         "whisper-large-v3",    # enc-dec with cross cache
         "llama-3.2-vision-11b"]  # vlm cross-attn


def _setup(name, b=2, s=12, seed=0):
    import dataclasses
    cfg = get_config(name).reduced()
    if cfg.num_experts:
        # generous capacity: the full-sequence forward may drop tokens at
        # tight capacity while per-token decode never does (by design) —
        # equalize for the equivalence check
        cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)
    rng = np.random.default_rng(seed)
    prm = MP.init_params(cfg, seed=seed)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    modality = None
    if cfg.family == "vlm":
        modality = jnp.asarray(
            rng.normal(size=(b, cfg.num_patches, cfg.d_model)), cfg.dtype)
    if cfg.family == "audio":
        modality = jnp.asarray(
            rng.normal(size=(b, cfg.encoder_seq, cfg.d_model)), cfg.dtype)
    return cfg, prm, tokens, modality


@pytest.mark.parametrize("name", ARCHS)
def test_decode_matches_forward(name):
    cfg, prm, tokens, modality = _setup(name)
    b, s = tokens.shape
    full_logits, _ = TF.forward(cfg, prm, tokens, modality=modality)

    cache = decode.init_cache(cfg, prm, b, max_len=s + 4, modality=modality)
    step = jax.jit(lambda p, c, t, pos: decode.serve_step(cfg, p, c, t, pos))
    errs = []
    for i in range(s):
        logits_i, cache = step(prm, cache, tokens[:, i:i + 1],
                               jnp.asarray(i, jnp.int32))
        errs.append(float(jnp.abs(
            logits_i[:, 0] - full_logits[:, i]).max()))
    # positions late in the sequence depend on the whole cache history
    assert max(errs) < 2e-2, f"{name}: max logit divergence {max(errs):.4f}"


def test_gemma_ring_buffer_beyond_window():
    """Decode past the local window: ring buffer must keep exactly the
    last `sliding_window` positions (reduced window = 16)."""
    cfg, prm, tokens, _ = _setup("gemma2-27b", b=1, s=24)
    s = tokens.shape[1]
    assert cfg.sliding_window == 16 < s
    full_logits, _ = TF.forward(cfg, prm, tokens)
    cache = decode.init_cache(cfg, prm, 1, max_len=s)
    step = jax.jit(lambda p, c, t, pos: decode.serve_step(cfg, p, c, t, pos))
    for i in range(s):
        logits_i, cache = step(prm, cache, tokens[:, i:i + 1],
                               jnp.asarray(i, jnp.int32))
    err = float(jnp.abs(logits_i[:, 0] - full_logits[:, -1]).max())
    assert err < 2e-2, f"ring-buffer divergence {err:.4f}"


def test_int8_kv_cache_close_to_bf16():
    """Quantized KV cache must track the exact cache within int8 error."""
    import dataclasses
    cfg, prm, tokens, _ = _setup("qwen2-0.5b", b=2, s=12)
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    b, s = tokens.shape
    full, _ = TF.forward(cfg, prm, tokens)
    cache = decode.init_cache(cfg8, prm, b, max_len=s + 4)
    assert cache["lyr"]["k"].dtype == jnp.int8
    step = jax.jit(lambda p, c, t, pos: decode.serve_step(cfg8, p, c, t, pos))
    errs = []
    for i in range(s):
        li, cache = step(prm, cache, tokens[:, i:i + 1],
                         jnp.asarray(i, jnp.int32))
        errs.append(float(jnp.abs(li[:, 0] - full[:, i]).max()))
    assert max(errs) < 0.35, f"int8 cache divergence {max(errs):.3f}"


def test_int8_cache_halves_bytes():
    import dataclasses
    cfg = get_config("qwen2-7b")
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    def nbytes(c):
        specs = decode.cache_specs(c, 8, 1024)
        return sum(np.prod(s.shape) * s.dtype.itemsize
                   for s in jax.tree.leaves(specs))
    assert nbytes(cfg8) < 0.6 * nbytes(cfg)
