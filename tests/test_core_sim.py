"""Three-way differential harness for the cycle-accurate Calyx simulator.

For every design in the matrix (matmul, conv2d, ffnn, attention) x banking
factors {1,2,4} x share {on,off}:

    simulate() outputs == affine-interpreter outputs == jnp oracle
    SimStats.cycles     == estimator.estimate.cycles   (exactly)

plus focused tests of the simulator's hardware semantics: statically-timed
``if``, port-conflict serialization of unbanked ``par``, the one-access-
per-cycle port checker, and single-owner arbitration of shared units.
"""
import functools

import numpy as np
import pytest

from repro.core import affine, calyx, estimator, frontend, pipeline
from repro.core import dataflow as D
from repro.core import schedule, sim
from repro.core import tensor_ir as T
from repro.core.calyx import Cell, CPar, Component, GEnable, Group

# Single source of truth for the design matrix (dims divisible by every
# banking factor so the layout-mode disjointness proof succeeds at f4);
# the benchmark exercises the same designs the differential suite gates.
from benchmarks.calyx_bench import DESIGNS


@functools.lru_cache(maxsize=None)
def _compiled(design: str, factor: int, share: bool):
    builder, shape = DESIGNS[design]
    return pipeline.compile_model(builder(), [shape], factor=factor,
                                  share=share)


def _input(design: str) -> np.ndarray:
    _, shape = DESIGNS[design]
    return np.random.default_rng(7).normal(size=shape).astype(np.float32)


class TestThreeWayDifferential:
    @pytest.mark.parametrize("share", [True, False])
    @pytest.mark.parametrize("factor", [1, 2, 4])
    @pytest.mark.parametrize("design", sorted(DESIGNS))
    def test_matrix(self, design, factor, share):
        d = _compiled(design, factor, share)
        x = _input(design)
        outs, stats = d.simulate({"arg0": x})
        interp = d.run({"arg0": x})
        oracle = d.run_oracle({"arg0": x})
        # measured cycles equal the closed-form estimate, no tolerance
        assert stats.cycles == d.estimate.cycles
        for s_out, i_out, o_out in zip(outs, interp, oracle):
            # the simulator executes the very groups the interpreter's
            # statements lowered to: bit-for-bit agreement
            np.testing.assert_allclose(s_out, i_out, rtol=0, atol=0)
            np.testing.assert_allclose(s_out, o_out, rtol=1e-4, atol=1e-4)

    def test_branchy_mode_differential(self):
        d = pipeline.compile_model(frontend.paper_ffnn(), [(1, 64)],
                                   factor=2, mode="branchy",
                                   check_hazards=False)
        x = np.random.default_rng(5).normal(size=(1, 64)).astype(np.float32)
        outs, stats = d.simulate({"arg0": x})
        oracle = d.run_oracle({"arg0": x})[0]
        assert stats.cycles == d.estimate.cycles
        np.testing.assert_allclose(outs[0], oracle, rtol=1e-4, atol=1e-4)
        # branchy accesses are never provably disjoint: arms serialized
        assert stats.serialized_arms > 0

    def test_stats_measure_real_work(self):
        d = _compiled("ffnn", 2, True)
        _, stats = d.simulate({"arg0": _input("ffnn")})
        assert stats.group_activations > 0
        assert stats.uops >= stats.group_activations
        assert stats.mem_reads > stats.mem_writes > 0
        # banked par arms broadcast identical-address weight reads
        assert stats.broadcast_reads > 0
        # shared pool cells were granted to their users
        assert stats.fu_grants and all(n > 0 for n in stats.fu_grants.values())

    def test_unshared_design_has_no_pool_grants(self):
        d = _compiled("ffnn", 2, False)
        _, stats = d.simulate({"arg0": _input("ffnn")})
        assert stats.fu_grants == {}


class TestStaticallyTimedIf:
    """The FSM reserves the worst-case `if` arm (estimator docstring):
    the simulator executes only the taken arm yet must measure the same
    count — covered here on a design whose arms have unequal latencies."""

    def test_causal_mask_if_arms_diverge_in_latency(self):
        g = T.Graph(name="mask")
        x = g.add_input("arg0", (4, 4))
        g.outputs = [T.causal_mask(g, x)]
        prog = affine.lower_graph(g)
        comp = calyx.lower_program(prog)
        lats = set()
        for node in _walk(comp.control):
            if isinstance(node, calyx.CIf):
                lats.add((estimator.cycles(comp, node.then),
                          estimator.cycles(comp, node.els)))
        assert any(t != e for t, e in lats), "mask if-arms should differ"
        xv = np.random.default_rng(0).normal(size=(4, 4)).astype(np.float32)
        mems, stats = sim.simulate(comp, prog, {"arg0": xv}, {})
        assert stats.cycles == estimator.cycles(comp)
        oracle = np.where(np.tril(np.ones((4, 4), bool)), xv, -1e30)
        np.testing.assert_allclose(mems[g.outputs[0]], oracle, rtol=1e-6)


class TestPortModel:
    def test_unbanked_par_serializes(self):
        """Parallel arms over one single-ported memory must measure the
        serialized schedule the estimator claims."""
        g = frontend.trace(frontend.Linear(8, 8, bias=False), [(4, 8)])
        prog = schedule.restructure(
            schedule.parallelize(affine.lower_graph(g), 2))
        comp = calyx.lower_program(prog)  # NO banking applied
        x = np.random.default_rng(1).normal(size=(4, 8)).astype(np.float32)
        mems, stats = sim.simulate(comp, prog, {"arg0": x}, g.params)
        assert stats.cycles == estimator.cycles(comp)
        assert stats.serialized_arms > 0
        oracle = x @ g.params[next(iter(g.params))]
        np.testing.assert_allclose(mems[g.outputs[0]], oracle,
                                   rtol=1e-4, atol=1e-5)

    def test_same_cycle_port_clash_raises(self):
        """Two same-cycle different-address reads of one memory violate
        Calyx's one-access-per-cycle constraint; a component whose port
        summary hides the conflict must still be caught at runtime."""
        prog = affine.Program("t", {"m": affine.MemDecl("m", (4,))}, [])
        idx0 = [affine.AExpr.const_(0)]
        idx1 = [affine.AExpr.const_(1)]
        groups = {
            "g1": Group("g1", 2, [], [],
                        [D.UMemRead(0, "m", idx0, 0)]),
            "g2": Group("g2", 2, [], [],
                        [D.UMemRead(0, "m", idx1, 0)]),
        }
        comp = Component("t", {}, groups,
                         CPar([GEnable("g1"), GEnable("g2")]))
        with pytest.raises(sim.SimError, match="one access per cycle"):
            sim.simulate(comp, prog, {}, {})

    def test_identical_address_loads_broadcast(self):
        prog = affine.Program("t", {"m": affine.MemDecl("m", (4,))}, [])
        idx = [affine.AExpr.const_(2)]
        groups = {
            "g1": Group("g1", 2, [], [], [D.UMemRead(0, "m", idx, 0)]),
            "g2": Group("g2", 2, [], [], [D.UMemRead(0, "m", idx, 0)]),
        }
        comp = Component("t", {}, groups,
                         CPar([GEnable("g1"), GEnable("g2")]))
        _, stats = sim.simulate(comp, prog, {}, {})
        assert stats.broadcast_reads == 1


class TestSharedUnitArbitration:
    def test_concurrent_pool_owners_raise(self):
        pool = Cell("shared_fp_add_0", "fp_add", users=2)
        uops = [D.UConst(0, 1.0),
                D.UAlu(1, "add", 0, 0, cell="shared_fp_add_0")]
        groups = {
            "g1": Group("g1", 2, ["shared_fp_add_0"], [], list(uops)),
            "g2": Group("g2", 2, ["shared_fp_add_0"], [], list(uops)),
        }
        comp = Component("t", {"shared_fp_add_0": pool}, groups,
                         CPar([GEnable("g1"), GEnable("g2")]))
        prog = affine.Program("t", {}, [])
        with pytest.raises(sim.SimError, match="single-owner"):
            sim.simulate(comp, prog, {}, {})

    def test_serialized_owners_are_fine(self):
        """Sequential groups may reuse one pool cell — that is the point."""
        pool = Cell("shared_fp_add_0", "fp_add", users=2)
        uops = [D.UConst(0, 1.0),
                D.UAlu(1, "add", 0, 0, cell="shared_fp_add_0")]
        groups = {
            "g1": Group("g1", 2, ["shared_fp_add_0"], [], list(uops)),
            "g2": Group("g2", 2, ["shared_fp_add_0"], [], list(uops)),
        }
        from repro.core.calyx import CSeq
        comp = Component("t", {"shared_fp_add_0": pool}, groups,
                         CSeq([GEnable("g1"), GEnable("g2")]))
        _, stats = sim.simulate(comp, affine.Program("t", {}, []), {}, {})
        assert stats.fu_grants == {"shared_fp_add_0": 2}


class TestEmitTextCondCells:
    def test_if_line_prints_condition_cells(self):
        """Satellite bugfix: emitted text must account for `if` condition
        hardware, not just the groups'."""
        g = T.Graph(name="mask")
        x = g.add_input("arg0", (4, 4))
        g.outputs = [T.causal_mask(g, x)]
        comp = calyx.lower_program(affine.lower_graph(g))
        cond_cells = [c for node in _walk(comp.control)
                      if isinstance(node, calyx.CIf)
                      for c in node.cond_cells]
        assert cond_cells, "mask design should have if-condition cells"
        txt = calyx.emit_text(comp)
        (if_line,) = [ln for ln in txt.splitlines() if "if <cond:" in ln]
        for c in cond_cells:
            assert c in if_line


def _walk(node):
    yield node
    if isinstance(node, (calyx.CSeq, calyx.CPar)):
        for ch in node.children:
            yield from _walk(ch)
    elif isinstance(node, calyx.CRepeat):
        yield from _walk(node.body)
    elif isinstance(node, calyx.CIf):
        yield from _walk(node.then)
        yield from _walk(node.els)
