"""Pallas kernel sweeps: shapes x dtypes vs the pure-jnp oracles
(interpret=True executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.banked_matmul import banked_matmul, derive_block

_TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
        jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def _tol(dt):
    return _TOL[dt]


class TestBankedMatmul:
    @pytest.mark.parametrize("m,k,n", [
        (8, 16, 8), (32, 64, 48), (48, 64, 40), (1, 64, 48),
        (17, 33, 9),                       # ragged -> padding path
        (128, 128, 128),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_shapes_dtypes(self, m, k, n, dtype):
        rng = np.random.default_rng(m * 1000 + k * 10 + n)
        a = jnp.asarray(rng.normal(size=(m, k)), dtype)
        b = jnp.asarray(rng.normal(size=(k, n)), dtype)
        out = ops.matmul(a, b, banks=(2, 2, 2))
        expect = ref.matmul_ref(a, b)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(expect, np.float32),
                                   **_tol(dtype))

    @pytest.mark.parametrize("banks", [(1, 1, 1), (2, 2, 2), (4, 2, 1),
                                       (1, 4, 4)])
    def test_bank_partitions_agree(self, banks):
        rng = np.random.default_rng(7)
        a = jnp.asarray(rng.normal(size=(64, 96)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(96, 64)), jnp.float32)
        out = ops.matmul(a, b, banks=banks)
        np.testing.assert_allclose(out, ref.matmul_ref(a, b), rtol=2e-5,
                                   atol=2e-5)

    def test_derive_block_covers_dims(self):
        bm, bn, bk = derive_block(256, 512, 1024, (2, 4, 8))
        assert bm * 2 >= 256 and bn * 4 >= 512 and bk * 8 >= 1024
        assert bm % 8 == 0 and bn % 128 == 0 and bk % 128 == 0

    def test_f32_accumulation_for_bf16(self):
        """bf16 inputs accumulate in f32: K=512 ones must be exact."""
        a = jnp.ones((8, 512), jnp.bfloat16)
        b = jnp.ones((512, 8), jnp.bfloat16)
        out = ops.matmul(a, b, out_dtype=jnp.float32)
        np.testing.assert_array_equal(np.asarray(out), 512.0)


class TestFlashAttention:
    @pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (8, 1)])
    @pytest.mark.parametrize("causal", [True, False])
    def test_gqa_and_masking(self, hq, hkv, causal):
        rng = np.random.default_rng(hq * 10 + hkv)
        q = jnp.asarray(rng.normal(size=(2, hq, 64, 16)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(2, hkv, 64, 16)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(2, hkv, 64, 16)), jnp.float32)
        out = ops.attention(q, k, v, causal=causal, block_q=16, block_k=16)
        expect = ref.attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(out, expect, rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("s,bq,bk", [(32, 8, 8), (64, 32, 16),
                                         (128, 128, 128)])
    def test_block_shapes(self, dtype, s, bq, bk):
        rng = np.random.default_rng(s)
        q = jnp.asarray(rng.normal(size=(1, 2, s, 8)), dtype)
        k = jnp.asarray(rng.normal(size=(1, 2, s, 8)), dtype)
        v = jnp.asarray(rng.normal(size=(1, 2, s, 8)), dtype)
        out = ops.attention(q, k, v, causal=True, block_q=bq, block_k=bk)
        expect = ref.attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(expect, np.float32),
                                   **_tol(dtype))

    def test_long_context_numerics(self):
        """Online softmax must be stable with large score magnitudes."""
        rng = np.random.default_rng(0)
        q = jnp.asarray(rng.normal(size=(1, 1, 64, 8)) * 8, jnp.float32)
        k = jnp.asarray(rng.normal(size=(1, 1, 64, 8)) * 8, jnp.float32)
        v = jnp.asarray(rng.normal(size=(1, 1, 64, 8)), jnp.float32)
        out = ops.attention(q, k, v, causal=True, block_q=16, block_k=16)
        assert np.isfinite(np.asarray(out)).all()
        np.testing.assert_allclose(out, ref.attention_ref(q, k, v),
                                   rtol=1e-4, atol=1e-4)


class TestDecayScan:
    @pytest.mark.parametrize("mode", ["inclusive", "bonus"])
    @pytest.mark.parametrize("s,chunk", [(32, 8), (64, 16), (64, 64)])
    def test_modes_and_chunks(self, mode, s, chunk):
        rng = np.random.default_rng(s + chunk)
        q = jnp.asarray(rng.normal(size=(1, 2, s, 8)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(1, 2, s, 8)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(1, 2, s, 12)), jnp.float32)
        w = jnp.asarray(-np.abs(rng.normal(size=(1, 2, s, 8))) * 0.3,
                        jnp.float32)
        u = jnp.asarray(rng.normal(size=(2, 8)), jnp.float32)
        out = ops.decay_scan(q, k, v, w, u=u, chunk=chunk, diag_mode=mode)
        expect = ref.ssm_scan_ref(q, k, v, w, u=u, diag_mode=mode)
        np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        rng = np.random.default_rng(5)
        q = jnp.asarray(rng.normal(size=(2, 2, 32, 8)), dtype)
        k = jnp.asarray(rng.normal(size=(2, 2, 32, 8)), dtype)
        v = jnp.asarray(rng.normal(size=(2, 2, 32, 8)), dtype)
        w = jnp.asarray(-np.abs(rng.normal(size=(2, 2, 32, 8))) * 0.2, dtype)
        out = ops.decay_scan(q, k, v, w, chunk=8)
        expect = ref.ssm_scan_ref(q, k, v, w)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(expect, np.float32),
                                   **_tol(dtype))

    def test_chunking_invariance(self):
        """Different chunk sizes must give identical results."""
        rng = np.random.default_rng(9)
        q = jnp.asarray(rng.normal(size=(1, 1, 64, 8)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(1, 1, 64, 8)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(1, 1, 64, 8)), jnp.float32)
        w = jnp.asarray(-np.abs(rng.normal(size=(1, 1, 64, 8))), jnp.float32)
        o1 = ops.decay_scan(q, k, v, w, chunk=8)
        o2 = ops.decay_scan(q, k, v, w, chunk=32)
        np.testing.assert_allclose(o1, o2, rtol=1e-5, atol=1e-5)

    def test_state_carries_across_chunks(self):
        """An impulse at t=0 must influence outputs in later chunks."""
        s, dk = 32, 4
        q = jnp.ones((1, 1, s, dk), jnp.float32)
        k = jnp.zeros((1, 1, s, dk), jnp.float32).at[0, 0, 0].set(1.0)
        v = jnp.zeros((1, 1, s, 4), jnp.float32).at[0, 0, 0].set(1.0)
        w = jnp.full((1, 1, s, dk), -0.1, jnp.float32)
        out = ops.decay_scan(q, k, v, w, chunk=8)
        assert float(out[0, 0, -1, 0]) > 0  # decayed impulse still visible
        np.testing.assert_allclose(out, ref.ssm_scan_ref(q, k, v, w),
                                   rtol=1e-5, atol=1e-5)


class TestBankedConv2d:
    @pytest.mark.parametrize("cin,cout,h,w,kh,kw", [
        (3, 8, 16, 12, 5, 5), (2, 4, 9, 9, 3, 3), (1, 2, 7, 5, 3, 2),
        (3, 8, 80, 60, 5, 5),                 # the paper's CNN first layer
    ])
    @pytest.mark.parametrize("banks", [(1, 1), (2, 2), (4, 2)])
    def test_shapes_and_banks(self, cin, cout, h, w, kh, kw, banks):
        from repro.kernels import ops as kops
        rng = np.random.default_rng(cin * 100 + h)
        x = jnp.asarray(rng.normal(size=(cin, h, w)), jnp.float32)
        wt = jnp.asarray(rng.normal(size=(cout, cin, kh, kw)), jnp.float32)
        out = kops.conv2d(x, wt, banks=banks)
        expect = ref.conv2d_ref(x, wt)
        assert out.shape == expect.shape
        np.testing.assert_allclose(out, expect, rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        from repro.kernels import ops as kops
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(2, 10, 10)), dtype)
        wt = jnp.asarray(rng.normal(size=(4, 2, 3, 3)), dtype)
        out = kops.conv2d(x, wt, banks=(2, 2))
        expect = ref.conv2d_ref(x, wt)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(expect, np.float32),
                                   **_TOL[dtype])
