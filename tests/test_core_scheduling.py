"""Differential harness for the static scheduling layer (opt_level 0/1/2).

For every design in the benchmark matrix x banking factors {1,2,4} x
opt_level {0,1,2} x share {on,off}:

    estimate.cycles == sim-measured cycles == RTL-measured cycles (exactly)
    RTL outputs == Calyx-sim outputs == affine-interpreter outputs (bits)
    all ~= jnp oracle (1e-4)

plus focused tests of the layer itself: chaining is cycle-neutral along
``seq`` and monotone overall, pipelined loops exist and carry their II,
banked designs beat unbanked at opt_level 2 (the point of the layer),
serializing pars warn with ``banking_efficiency < 1``, the bank-affine
strip/conflict machinery, and same-process compile determinism.
"""
import functools
import warnings

import numpy as np
import pytest

from repro.core import estimator, frontend, pipeline, schedule

from benchmarks.calyx_bench import DESIGNS

OPT_LEVELS = (0, 1, 2)


@functools.lru_cache(maxsize=None)
def _compiled(design: str, factor: int, opt: int, share: bool = True):
    builder, shape = DESIGNS[design]
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", estimator.BankingEfficiencyWarning)
        return pipeline.compile_model(builder(), [shape], factor=factor,
                                      share=share, opt_level=opt)


def _input(design: str) -> np.ndarray:
    _, shape = DESIGNS[design]
    return np.random.default_rng(7).normal(size=shape).astype(np.float32)


class TestSchedulingDifferential:
    """est == sim == RTL, bit-exact outputs, at every opt level."""

    @pytest.mark.parametrize("share", [True, False])
    @pytest.mark.parametrize("opt", OPT_LEVELS)
    @pytest.mark.parametrize("factor", [1, 2, 4])
    @pytest.mark.parametrize("design", sorted(DESIGNS))
    def test_matrix(self, design, factor, opt, share):
        d = _compiled(design, factor, opt, share)
        x = _input(design)
        sim_outs, sim_stats = d.simulate({"arg0": x})
        rtl_outs, rtl_stats = d.simulate_rtl({"arg0": x})
        interp = d.run({"arg0": x})
        assert sim_stats.cycles == d.estimate.cycles == rtl_stats.cycles
        for s, r, i in zip(sim_outs, rtl_outs, interp):
            np.testing.assert_allclose(s, r, rtol=0, atol=0)
            np.testing.assert_allclose(s, i, rtol=0, atol=0)
        oracle = d.run_oracle({"arg0": x})
        for s, o in zip(sim_outs, oracle):
            np.testing.assert_allclose(s, o, rtol=1e-4, atol=1e-4)


class TestSchedulingWins:
    @pytest.mark.parametrize("design", sorted(DESIGNS))
    def test_opt_levels_monotone(self, design):
        for factor in (1, 2, 4):
            c = {opt: _compiled(design, factor, opt).estimate.cycles
                 for opt in OPT_LEVELS}
            assert c[2] <= c[1] <= c[0], (design, factor, c)

    @pytest.mark.parametrize("design", sorted(DESIGNS))
    def test_banked_beats_unbanked_at_opt2(self, design):
        """The acceptance headline: with the scheduling layer on, banking
        buys cycles on every benchmark (conv2d banks=4 used to be 3.6x
        *worse* than unbanked; matmul banks=2 used to regress too)."""
        base = _compiled(design, 1, 2).estimate.cycles
        for factor in (2, 4):
            banked = _compiled(design, factor, 2).estimate.cycles
            assert banked < base, (design, factor, banked, base)

    def test_chaining_is_cycle_neutral_along_seq(self):
        """At factor 1 there are no pars: opt 1 only fuses seq runs,
        which must not change a single cycle."""
        for design in DESIGNS:
            c0 = _compiled(design, 1, 0).estimate.cycles
            c1 = _compiled(design, 1, 1).estimate.cycles
            assert c0 == c1, design

    def test_chaining_collapses_fsm_states(self):
        """The chaining motivation: attention at factor 4 burns >1000 FSM
        states unfused; fusion must collapse them (and recover fmax)."""
        d0 = _compiled("attention", 4, 0)
        d1 = _compiled("attention", 4, 1)
        assert d1.estimate.fsm_states < 0.5 * d0.estimate.fsm_states
        assert d1.estimate.fmax_mhz > d0.estimate.fmax_mhz
        assert len(d1.component.groups) < len(d0.component.groups)

    def test_pipelined_loops_annotated(self):
        d = _compiled("matmul", 1, 2)
        pipelined = d.component.meta.get("pipelined")
        assert pipelined, "matmul's MAC reduction should pipeline"
        mac = pipelined[0]
        # accumulator recurrence: adder consumes at 4, latches at 6 -> II=2
        assert mac["ii"] == 2 and mac["body_latency"] == 6
        assert "pipeline ii=2" in d.calyx_text()

    def test_pipelining_skipped_without_benefit(self):
        """opt_level 2 on a design with nothing to pipeline changes
        nothing (if-bodied loops are not single-group after chaining)."""
        from repro.core import affine, calyx, chaining, pipelining
        from repro.core import tensor_ir as T
        g = T.Graph(name="mask")
        x = g.add_input("arg0", (4, 4))
        g.outputs = [T.causal_mask(g, x)]
        comp = chaining.chain_component(
            calyx.lower_program(affine.lower_graph(g)))
        piped = pipelining.pipeline_loops(comp)
        assert piped.meta["pipelined"] == []
        assert estimator.cycles(piped) == estimator.cycles(comp)


class TestBankingEfficiency:
    def test_serializing_par_warns(self):
        """Branchy-mode pars are never provably disjoint: compilation
        must surface the serialization instead of hiding it in cycles."""
        with pytest.warns(estimator.BankingEfficiencyWarning):
            d = pipeline.compile_model(frontend.paper_ffnn(), [(1, 64)],
                                       factor=2, mode="branchy",
                                       check_hazards=False)
        assert d.estimate.banking_efficiency < 1.0

    def test_layout_mode_is_fully_parallel(self):
        for design in DESIGNS:
            for factor in (2, 4):
                d = _compiled(design, factor, 0)
                assert d.estimate.banking_efficiency == 1.0, (design, factor)

    def test_efficiency_field_in_estimate_dict(self):
        d = _compiled("matmul", 2, 0)
        assert d.estimate.as_dict()["banking_efficiency"] == 1.0


class TestBankAffineStripMining:
    def test_strip_count_prefers_divisors_of_factor(self):
        # extent 6, factor 4: gcd says 2, but 3 arms wrap the bank period
        # only when stacked under another strip; standalone the divisor-of-
        # factor preference still picks 2 (provably foldable digits)
        assert schedule.strip_count(6, 4) == 2
        assert schedule.strip_count(8, 4) == 4
        assert schedule.strip_count(6, 2) == 2

    def test_strip_count_fallback_needs_extent_covering_factor(self):
        # extent 3 < factor 4: stripping would stack offsets past the
        # bank period when combined with a sibling strip -> stay at 1
        assert schedule.strip_count(3, 4) == 1
        assert schedule.strip_count(7, 2) == 1
        # extent 9 >= factor 4 with no common divisor: largest divisor
        assert schedule.strip_count(9, 4) == 3

    def test_runtime_banks_prove_distinct(self):
        """The bank-affine conflict proof: matmul's output stores hit
        runtime-selected banks (`i % 2` never folds), yet arms differing
        by a constant unroll offset are provably parallel — this is what
        un-serialized matmul f2 (2366 -> ~1070 cycles at opt 0)."""
        d0 = _compiled("matmul", 2, 0)
        assert d0.estimate.banking_efficiency == 1.0
        assert d0.estimate.cycles < 1966      # beats its unbanked baseline


class TestDeterminism:
    def test_repeated_compiles_emit_identical_text(self):
        """Satellite: the restructure counter is per-invocation, so two
        compiles in one process must produce byte-identical artifacts."""
        def build():
            return pipeline.compile_model(frontend.paper_ffnn(), [(1, 64)],
                                          factor=2, opt_level=2)
        a, b = build(), build()
        assert a.calyx_text() == b.calyx_text()
        assert a.emit_verilog() == b.emit_verilog()
