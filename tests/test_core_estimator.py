"""Estimator regimes: the paper's headline claims as executable assertions."""
import numpy as np
import pytest

from repro.core import calyx, estimator, frontend, pipeline


@pytest.fixture(scope="module")
def ffnn_designs():
    # share=False: the paper's Table 2 numbers predate any binding stage
    # (resource sharing is explicitly future work there), so its regime
    # assertions are against the one-unit-per-statement designs.  The
    # sharing pass has its own regime tests in test_core_sharing.py.
    m = frontend.paper_ffnn()
    return {f: pipeline.compile_model(m, [(1, 64)], factor=f, share=False)
            for f in (1, 2, 4)}


class TestPaperClaims:
    """Fig. 3 / Table 2 of the paper, as regime assertions."""

    def test_f1_cycles_regime(self, ffnn_designs):
        # paper: 22475 cycles; allow +/-20% model error
        assert 18_000 <= ffnn_designs[1].estimate.cycles <= 27_000

    def test_speedup_1_to_2(self, ffnn_designs):
        s = ffnn_designs[1].estimate.cycles / ffnn_designs[2].estimate.cycles
        assert 2.0 <= s <= 2.8, f"paper reports 2.40x, got {s:.2f}"

    def test_speedup_2_to_4(self, ffnn_designs):
        s = ffnn_designs[2].estimate.cycles / ffnn_designs[4].estimate.cycles
        assert 2.6 <= s <= 3.5, f"paper reports 3.05x, got {s:.2f}"

    def test_lut_growth_superlinear(self, ffnn_designs):
        lut = {f: d.estimate.resources["LUT"] for f, d in ffnn_designs.items()}
        assert lut[2] > 2.5 * lut[1]      # paper: 3730 -> 13197
        assert lut[4] > 2.5 * lut[2]      # paper: 13197 -> 49121

    def test_dsp_growth(self, ffnn_designs):
        dsp = {f: d.estimate.resources["DSP"] for f, d in ffnn_designs.items()}
        assert dsp[1] <= 8 and 14 <= dsp[2] <= 26 and 50 <= dsp[4] <= 90

    def test_bram_grows_with_banking(self, ffnn_designs):
        bram = {f: d.estimate.resources["BRAM"] for f, d in ffnn_designs.items()}
        assert bram[4] > bram[1]          # paper: 9 -> 20

    def test_wall_clock_improves(self, ffnn_designs):
        assert (ffnn_designs[4].estimate.wall_us
                < ffnn_designs[2].estimate.wall_us
                < ffnn_designs[1].estimate.wall_us)


class TestPortConflictModel:
    def test_unbanked_parallelism_gives_no_speedup(self):
        """Parallel arms sharing a single-ported memory must serialize —
        the motivation for banking."""
        from repro.core import affine, banking, schedule
        g = frontend.trace(frontend.paper_ffnn(), [(1, 64)])
        prog = affine.lower_graph(g)
        par = schedule.restructure(schedule.parallelize(prog, 2))
        # NO banking applied: same memory, conflicting ports
        comp = calyx.lower_program(par)
        cyc_par_unbanked = estimator.cycles(comp)
        comp_seq = calyx.lower_program(affine.lower_graph(g))
        cyc_seq = estimator.cycles(comp_seq)
        assert cyc_par_unbanked > 0.8 * cyc_seq, (
            f"unbanked par should not speed up: {cyc_par_unbanked} vs {cyc_seq}")

    def test_banked_parallelism_speeds_up(self, ffnn_designs):
        assert (ffnn_designs[2].estimate.cycles
                < 0.55 * ffnn_designs[1].estimate.cycles)


class TestEstimatorStructure:
    def test_cycles_positive_and_deterministic(self, ffnn_designs):
        d = ffnn_designs[1]
        assert estimator.cycles(d.component) == d.estimate.cycles > 0

    def test_fsm_states_grow_with_unrolling(self, ffnn_designs):
        assert (ffnn_designs[4].estimate.fsm_states
                > ffnn_designs[2].estimate.fsm_states
                > ffnn_designs[1].estimate.fsm_states)

    def test_emit_text_round_trips_names(self, ffnn_designs):
        txt = ffnn_designs[2].calyx_text()
        assert "component main" in txt
        assert "par {" in txt and "repeat" in txt

    def test_mha_larger_than_ffnn(self):
        """Paper Table 1: MHA uses ~9x the LUTs of FFNN."""
        mha = pipeline.compile_model(frontend.paper_mha(), [(8, 42)],
                                     factor=1, share=False)
        ffnn = pipeline.compile_model(frontend.paper_ffnn(), [(1, 64)],
                                      factor=1, share=False)
        assert (mha.estimate.resources["LUT"]
                > 3 * ffnn.estimate.resources["LUT"])
        assert (mha.estimate.resources["DSP"]
                > 3 * ffnn.estimate.resources["DSP"])
