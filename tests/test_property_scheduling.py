"""Property-based test: the scheduling layer (chaining + pipelining)
never changes program results and never adds cycles, over randomized
small graphs, banking factors, and sharing.

This is the scheduling twin of ``tests/test_property_sim.py`` /
``tests/test_property_rtl.py``: where those prove binding and the RTL
path are schedule- and value-preserving, this one proves the *optimizing*
passes are value-preserving (bit-for-bit against the unoptimized design
through both simulators) while strictly respecting the differential
contract — measured cycles at every opt level equal that level's own
closed-form estimate, and opt 2 <= opt 1 <= opt 0.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import frontend, pipeline


@st.composite
def random_models(draw):
    """Tiny random MLP-ish module + input shape + banking factor (dims
    are multiples of the factor so the layout disjointness proof holds)."""
    factor = draw(st.sampled_from([1, 2, 4]))
    n_layers = draw(st.integers(1, 3))
    mult = st.integers(1, 2)
    dims = [factor * draw(mult) * 2 for _ in range(n_layers + 1)]
    rng = np.random.default_rng(draw(st.integers(0, 2**16)))
    layers = []
    for a, b in zip(dims, dims[1:]):
        layers.append(frontend.Linear(a, b, bias=draw(st.booleans()),
                                      rng=rng))
        if draw(st.booleans()):
            layers.append(frontend.ReLU())
    rows = factor * draw(mult)
    return frontend.Sequential(*layers), (rows, dims[0]), factor


class TestSchedulingPreservesResults:
    @given(mf=random_models(), share=st.booleans())
    @settings(max_examples=20, deadline=None)
    def test_opt_levels_agree_bitwise_and_never_regress(self, mf, share):
        module, shape, factor = mf
        x = np.random.default_rng(0).normal(size=shape).astype(np.float32)
        cycles = {}
        outs0 = None
        for opt in (0, 1, 2):
            d = pipeline.compile_model(module, [shape], factor=factor,
                                       share=share, opt_level=opt)
            sim_outs, sim_stats = d.simulate({"arg0": x})
            rtl_outs, rtl_stats = d.simulate_rtl({"arg0": x})
            # each level measures its own closed form, at both levels
            assert sim_stats.cycles == d.estimate.cycles == rtl_stats.cycles
            for s, r in zip(sim_outs, rtl_outs):
                np.testing.assert_allclose(s, r, rtol=0, atol=0)
            if outs0 is None:
                outs0 = sim_outs
            else:
                # chaining/pipelining must not change a single bit
                for s, base in zip(sim_outs, outs0):
                    np.testing.assert_allclose(s, base, rtol=0, atol=0)
            cycles[opt] = sim_stats.cycles
        assert cycles[2] <= cycles[1] <= cycles[0]
        oracle = pipeline.compile_model(module, [shape], factor=factor,
                                        share=share).run_oracle({"arg0": x})
        for s, o in zip(outs0, oracle):
            np.testing.assert_allclose(s, o, rtol=1e-4, atol=1e-4)
