"""Every example script must run end-to-end (subprocess smoke)."""
import os
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]
ENV = {**os.environ, "PYTHONPATH": "src"}


def _run(args, timeout=420):
    r = subprocess.run([sys.executable] + args, capture_output=True,
                       text=True, env=ENV, cwd=str(ROOT), timeout=timeout)
    assert r.returncode == 0, f"{args}\n{r.stdout[-1500:]}\n{r.stderr[-1500:]}"
    return r.stdout


class TestExamples:
    def test_quickstart(self):
        out = _run(["examples/quickstart.py"])
        assert "correct=True" in out and "factor=4" in out

    def test_banking_sweep(self):
        out = _run(["examples/banking_sweep.py"])
        assert "paper 2.40x" in out and "branchy" in out

    def test_compile_to_calyx(self):
        out = _run(["examples/compile_to_calyx.py", "--model", "ffnn",
                    "--factor", "2"])
        assert "cycles=" in out and ".futil" in out

    def test_train_lm_with_failure(self, tmp_path):
        layers = tmp_path / "train_layers.jsonl"
        out = _run(["examples/train_lm.py", "--steps", "14",
                    "--inject-failure", "6", "--batch", "4", "--seq", "32",
                    "--profile-layers", str(layers), "--profile-steps", "4",
                    "--stable"])
        assert "restarts=1" in out and out.strip().endswith("OK")
        self._check_layers(layers, arch="qwen2-0.5b", steps=4)

    def test_serve_batched(self, tmp_path):
        prom = tmp_path / "batched.prom"
        spans = tmp_path / "batched.jsonl"
        layers = tmp_path / "batched_layers.jsonl"
        out = _run(["examples/serve_batched.py", "--requests", "2",
                    "--gen", "6", "--prompt-len", "8",
                    "--metrics-out", str(prom),
                    "--spans-out", str(spans),
                    "--profile-layers", str(layers), "--stable"])
        assert out.strip().endswith("OK")
        assert "serve_tokens_generated_total 12" in prom.read_text()
        self._check_spans(spans, requests=2)
        # the layer stream joins against the span stream: prompt+gen steps
        self._check_layers(layers, arch="qwen2-0.5b", steps=14)

    def test_serve_launcher(self, tmp_path):
        metrics = tmp_path / "serve.json"
        spans = tmp_path / "serve.jsonl"
        out = _run(["-m", "repro.launch.serve", "--slots", "2",
                    "--requests", "3", "--gen", "4", "--prompt-len", "4",
                    "--metrics-out", str(metrics),
                    "--spans-out", str(spans), "--stable"])
        assert "3/3 requests" in out
        import json
        doc = json.loads(metrics.read_text())
        m = doc["metrics"]
        assert m["serve_requests_completed_total"]["value"] == 3
        assert m["serve_ttft_us"]["count"] == 3
        self._check_spans(spans, requests=3)

    @staticmethod
    def _check_spans(path, requests):
        sys.path.insert(0, str(ROOT / "src"))
        try:
            from repro.obs import spans as SP
        finally:
            sys.path.pop(0)
        events = SP.from_jsonl(path.read_text())
        assert SP.validate(events) == []
        summaries = SP.summarize(events)
        assert len(summaries) == requests
        assert all(s.reason == SP.FINISHED for s in summaries.values())

    @staticmethod
    def _check_layers(path, arch, steps):
        """The layer artifact parses and passes the modelprof invariants:
        every step carries the complete op set in execution order."""
        sys.path.insert(0, str(ROOT / "src"))
        try:
            from repro.models import get_config
            from repro.obs import modelprof as MPF
        finally:
            sys.path.pop(0)
        cfg = get_config(arch).reduced()
        records = MPF.from_jsonl(path.read_text())
        assert MPF.validate(records, cfg=cfg, engine_steps=steps) == []
