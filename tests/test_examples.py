"""Every example script must run end-to-end (subprocess smoke)."""
import os
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]
ENV = {**os.environ, "PYTHONPATH": "src"}


def _run(args, timeout=420):
    r = subprocess.run([sys.executable] + args, capture_output=True,
                       text=True, env=ENV, cwd=str(ROOT), timeout=timeout)
    assert r.returncode == 0, f"{args}\n{r.stdout[-1500:]}\n{r.stderr[-1500:]}"
    return r.stdout


class TestExamples:
    def test_quickstart(self):
        out = _run(["examples/quickstart.py"])
        assert "correct=True" in out and "factor=4" in out

    def test_banking_sweep(self):
        out = _run(["examples/banking_sweep.py"])
        assert "paper 2.40x" in out and "branchy" in out

    def test_compile_to_calyx(self):
        out = _run(["examples/compile_to_calyx.py", "--model", "ffnn",
                    "--factor", "2"])
        assert "cycles=" in out and ".futil" in out

    def test_train_lm_with_failure(self, tmp_path):
        layers = tmp_path / "train_layers.jsonl"
        out = _run(["examples/train_lm.py", "--steps", "14",
                    "--inject-failure", "6", "--batch", "4", "--seq", "32",
                    "--profile-layers", str(layers), "--profile-steps", "4",
                    "--stable"])
        assert "restarts=1" in out and out.strip().endswith("OK")
        self._check_layers(layers, arch="qwen2-0.5b", steps=4)

    def test_serve_batched(self, tmp_path):
        prom = tmp_path / "batched.prom"
        spans = tmp_path / "batched.jsonl"
        layers = tmp_path / "batched_layers.jsonl"
        out = _run(["examples/serve_batched.py", "--requests", "2",
                    "--gen", "6", "--prompt-len", "8",
                    "--metrics-out", str(prom),
                    "--spans-out", str(spans),
                    "--profile-layers", str(layers), "--stable"])
        assert out.strip().endswith("OK")
        assert "serve_tokens_generated_total 12" in prom.read_text()
        self._check_spans(spans, requests=2)
        # the layer stream joins against the span stream: prompt+gen steps
        self._check_layers(layers, arch="qwen2-0.5b", steps=14)

    def test_serve_batched_fault_plan(self, tmp_path):
        # generate a plan via the CLI, then replay it: victim rows must be
        # dropped with the 'fault' reason while the rest of the batch
        # finishes, and the stable span stream must be byte-deterministic
        plan = tmp_path / "plan.json"
        _run(["-m", "repro.launch.faults", "--seed", "3", "--steps", "40",
              "--rate", "0.12", "--slots", "4",
              "--kinds", "nan_logits,inf_logits,cache_corrupt",
              "--out", str(plan)])
        spans = [tmp_path / "chaos_a.jsonl", tmp_path / "chaos_b.jsonl"]
        metrics = tmp_path / "chaos.json"
        for i, sp in enumerate(spans):
            out = _run(["examples/serve_batched.py", "--requests", "4",
                        "--gen", "12", "--prompt-len", "8",
                        "--fault-plan", str(plan),
                        "--spans-out", str(sp), "--stable"]
                       + (["--metrics-out", str(metrics)] if i == 0 else []))
            assert out.strip().endswith("OK")
            assert "resilience: faults injected=" in out
        assert spans[0].read_text() == spans[1].read_text()
        import json
        m = json.loads(metrics.read_text())["metrics"]
        assert m["serve_faults_injected_total"]["value"] > 0
        assert m["serve_faults_detected_total"]["value"] > 0
        assert m["serve_requests_truncated_fault_total"]["value"] \
            == m["serve_requests_truncated_total"]["value"] > 0
        # every row completes exactly once, finished or dropped-for-fault
        sys.path.insert(0, str(ROOT / "src"))
        try:
            from repro.obs import spans as SP
        finally:
            sys.path.pop(0)
        events = SP.from_jsonl(spans[0].read_text())
        assert SP.validate(events) == []
        summaries = SP.summarize(events)
        assert len(summaries) == 4
        reasons = {s.reason for s in summaries.values()}
        assert reasons <= {SP.FINISHED, SP.TRUNCATED_PREFIX + "fault"}
        assert SP.TRUNCATED_PREFIX + "fault" in reasons

    def test_serve_batched_deadline(self, tmp_path):
        # an immediate deadline truncates every row with the 'deadline'
        # reason and no TTFT sample is ever recorded (sentinel regression)
        metrics = tmp_path / "deadline.json"
        spans = tmp_path / "deadline.jsonl"
        out = _run(["examples/serve_batched.py", "--requests", "2",
                    "--gen", "6", "--prompt-len", "8",
                    "--deadline-ms", "0.001",
                    "--metrics-out", str(metrics),
                    "--spans-out", str(spans), "--stable"])
        assert out.strip().endswith("OK")
        import json
        m = json.loads(metrics.read_text())["metrics"]
        assert m["serve_requests_truncated_deadline_total"]["value"] == 2
        assert m["serve_ttft_us"]["count"] == 0
        sys.path.insert(0, str(ROOT / "src"))
        try:
            from repro.obs import spans as SP
        finally:
            sys.path.pop(0)
        events = SP.from_jsonl(spans.read_text())
        assert SP.validate(events) == []
        assert all(s.reason == SP.TRUNCATED_PREFIX + "deadline"
                   for s in SP.summarize(events).values())

    def test_serve_launcher(self, tmp_path):
        metrics = tmp_path / "serve.json"
        spans = tmp_path / "serve.jsonl"
        out = _run(["-m", "repro.launch.serve", "--slots", "2",
                    "--requests", "3", "--gen", "4", "--prompt-len", "4",
                    "--metrics-out", str(metrics),
                    "--spans-out", str(spans), "--stable"])
        assert "3/3 requests" in out
        import json
        doc = json.loads(metrics.read_text())
        m = doc["metrics"]
        assert m["serve_requests_completed_total"]["value"] == 3
        assert m["serve_ttft_us"]["count"] == 3
        self._check_spans(spans, requests=3)

    @staticmethod
    def _check_spans(path, requests):
        sys.path.insert(0, str(ROOT / "src"))
        try:
            from repro.obs import spans as SP
        finally:
            sys.path.pop(0)
        events = SP.from_jsonl(path.read_text())
        assert SP.validate(events) == []
        summaries = SP.summarize(events)
        assert len(summaries) == requests
        assert all(s.reason == SP.FINISHED for s in summaries.values())

    @staticmethod
    def _check_layers(path, arch, steps):
        """The layer artifact parses and passes the modelprof invariants:
        every step carries the complete op set in execution order."""
        sys.path.insert(0, str(ROOT / "src"))
        try:
            from repro.models import get_config
            from repro.obs import modelprof as MPF
        finally:
            sys.path.pop(0)
        cfg = get_config(arch).reduced()
        records = MPF.from_jsonl(path.read_text())
        assert MPF.validate(records, cfg=cfg, engine_steps=steps) == []
