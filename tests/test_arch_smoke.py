"""Per-architecture smoke tests: reduced config, one forward + one train
step + one decode step on CPU; asserts shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import all_names, get_config
from repro.models import decode, params as P, transformer

ARCHS = all_names()


def _batch(cfg, b=2, s=16, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, size=(b, s)), jnp.int32)}
    if cfg.family == "vlm":
        batch["modality"] = jnp.asarray(
            rng.normal(size=(b, cfg.num_patches, cfg.d_model)), cfg.dtype)
    if cfg.family == "audio":
        batch["modality"] = jnp.asarray(
            rng.normal(size=(b, cfg.encoder_seq, cfg.d_model)), cfg.dtype)
    return batch


@pytest.mark.parametrize("name", ARCHS)
class TestArchSmoke:
    def test_reduced_forward(self, name):
        cfg = get_config(name).reduced()
        prm = P.init_params(cfg, seed=0)
        batch = _batch(cfg)
        logits, aux = transformer.forward(cfg, prm, batch["tokens"],
                                          modality=batch.get("modality"))
        b, s = batch["tokens"].shape
        assert logits.shape == (b, s, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits, np.float32)).all()

    def test_train_step_grads(self, name):
        cfg = get_config(name).reduced()
        prm = P.init_params(cfg, seed=1)
        batch = _batch(cfg, seed=1)
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: transformer.loss_fn(cfg, p, batch), has_aux=True)(prm)
        assert np.isfinite(float(loss))
        gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                             for g in jax.tree.leaves(grads)))
        assert np.isfinite(float(gnorm)) and float(gnorm) > 0

    def test_decode_step(self, name):
        cfg = get_config(name).reduced()
        prm = P.init_params(cfg, seed=2)
        batch = _batch(cfg, b=2, s=8, seed=2)
        cache = decode.init_cache(cfg, prm, batch=2, max_len=32,
                                  modality=batch.get("modality"))
        tok = batch["tokens"][:, :1]
        logits, cache2 = decode.serve_step(cfg, prm, cache, tok,
                                           jnp.asarray(0, jnp.int32))
        assert logits.shape == (2, 1, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        # structures must round-trip (scan-compatible)
        assert (jax.tree.structure(cache) == jax.tree.structure(cache2))

    def test_param_count_positive(self, name):
        cfg = get_config(name)
        n = cfg.param_count()
        na = cfg.active_param_count()
        assert n > 0 and 0 < na <= n
