"""MoE dispatch equivalence + capacity semantics."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import get_config
from repro.models import params as MP
from repro.models.moe import capacity, moe_block


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("olmoe-1b-7b").reduced()
    # generous capacity so no tokens drop -> banked == gather exactly
    cfg = dataclasses.replace(cfg, moe_capacity_factor=4.0)
    prm = MP.init_params(cfg, seed=0)
    layer0 = jax.tree.map(lambda a: a[0], prm["blocks"])["lyr"]["moe"]
    x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 16, cfg.d_model)),
                    jnp.float32)
    return cfg, layer0, x


class TestDispatchEquivalence:
    def test_banked_matches_gather(self, setup):
        cfg, p, x = setup
        yb, _ = moe_block(dataclasses.replace(cfg, moe_dispatch="banked"),
                          p, x)
        yg, _ = moe_block(dataclasses.replace(cfg, moe_dispatch="gather"),
                          p, x)
        np.testing.assert_allclose(np.asarray(yb), np.asarray(yg),
                                   rtol=2e-4, atol=2e-4)

    def test_aux_losses_finite(self, setup):
        cfg, p, x = setup
        _, aux = moe_block(cfg, p, x)
        assert np.isfinite(float(aux["moe_aux"]))
        assert np.isfinite(float(aux["moe_zloss"]))
        assert float(aux["moe_aux"]) >= 1.0 - 1e-3   # >= 1 by Cauchy-Schwarz

    def test_capacity_drops_are_graceful(self, setup):
        cfg, p, x = setup
        tight = dataclasses.replace(cfg, moe_capacity_factor=0.25)
        y, _ = moe_block(tight, p, x)
        assert np.isfinite(np.asarray(y, np.float32)).all()

    def test_capacity_lane_aligned(self, setup):
        cfg, _, _ = setup
        assert capacity(cfg, 1024) % 8 == 0

    def test_grads_flow_through_dispatch(self, setup):
        cfg, p, x = setup

        def loss(pp):
            y, aux = moe_block(cfg, pp, x)
            return jnp.sum(y ** 2) + 0.01 * aux["moe_aux"]

        g = jax.grad(loss)(p)
        gn = float(jnp.sqrt(sum(jnp.sum(a.astype(jnp.float32) ** 2)
                                for a in jax.tree.leaves(g))))
        assert np.isfinite(gn) and gn > 0
        # router must receive gradient (through gate values)
        assert float(jnp.abs(g["router"]).max()) > 0
