"""Stage-boundary verifier: negative corpus, provenance, cache behavior.

Every ``RV0xx`` code in ``diagnostics.CODES`` must fire on a minimal
broken fixture (the negative corpus), with the right severity and a
provenance chain pointing at the offending construct; the full benchmark
matrix must stay clean end to end; ``eliminate_dead`` must strip exactly
the RV002/RV004 findings' subjects without touching the schedule; and
the ``GroupCache`` carry-over (pass-through groups, sharing's verified
rebind) must never suppress a finding a fresh run would report.
"""
import pytest

from repro.core import dataflow as D
from repro.core import diagnostics, pipeline, sharing, verify, verilog
from repro.core.affine import AExpr, Cond, MemDecl, Program
from repro.core.calyx import (Cell, CIf, CPar, CRepeat, CSeq, Component,
                              GEnable, Group)
from repro.core.diagnostics import CODES, ERROR, WARNING
from repro.core.rtl import (DpBlock, DpConst, DpRegWrite, DpSelect,
                            DpUnit, Fsm, FsmState, Netlist, PerfCounter,
                            RegInst, UnitInst, perf_counter_bank)


def _reg_cells(*regs):
    return {f"reg_{r}": Cell(f"reg_{r}", "reg32") for r in regs}


def _group(name, uops, cells=(), latency=2):
    return Group(name, latency, list(cells), [], list(uops))


def _comp(groups, control, cells=None):
    cells = dict(cells or {})
    for g in groups:
        for c in g.cells:
            cells.setdefault(c, Cell(c, "fp_add"))
    return Component("t", cells, {g.name: g for g in groups}, control)


def _ok_uops(reg="acc"):
    """Minimal clean body: define a temp, latch it into a register, and
    consume it (a register nothing reads is an RV012 dead write)."""
    return [D.UConst(0, 1.0), D.URegWrite(reg, 0),
            D.URegRead(1, reg),
            D.UMemWrite("buf", [AExpr.const_(0)], 1, 1)]


def codes_of(rep):
    return {d.code for d in rep}


def find(rep, code):
    hits = [d for d in rep if d.code == code]
    assert hits, f"{code} did not fire; got {[d.code for d in rep]}"
    return hits[0]


def _netlist(blocks, fsms, regs=(), units=()):
    return Netlist("t", mems={}, banks={},
                   regs={r: RegInst(f"reg_{r}", r) for r in regs},
                   index_regs={}, units={u: UnitInst(u, "fp_add", 2)
                                         for u in units},
                   muxes=[], blocks={b.group: b for b in blocks},
                   fsms=list(fsms))


def _fsm(states, fid=0, start=0, binds=None):
    return Fsm(fid, f"fsm{fid}", list(states), start, binds=binds or {})


class TestNegativeCorpusIR:
    """One broken component per IR-level code."""

    def test_rv001_dangling_cell(self):
        g = _group("g", _ok_uops(), cells=["ghost"])
        comp = _comp([g], CSeq([GEnable("g")]),
                     cells=_reg_cells("acc"))
        del comp.cells["ghost"]
        d = find(verify.verify_component(comp), "RV001")
        assert d.severity == ERROR
        assert "group:g" in d.provenance and "cell:ghost" in d.provenance

    def test_rv001_dangling_unit_invocation(self):
        g = _group("g", [D.UConst(0, 1.0),
                         D.UAlu(1, "relu", 0, None, "ghost_unit"),
                         D.URegWrite("acc", 1)])
        comp = _comp([g], CSeq([GEnable("g")]), cells=_reg_cells("acc"))
        d = find(verify.verify_component(comp), "RV001")
        assert "ghost_unit" in d.message

    def test_rv002_unused_cell(self):
        g = _group("g", _ok_uops())
        comp = _comp([g], CSeq([GEnable("g")]),
                     cells={**_reg_cells("acc"),
                            "lonely": Cell("lonely", "fp_mul")})
        d = find(verify.verify_component(comp), "RV002")
        assert d.severity == WARNING
        assert "cell:lonely" in d.provenance

    def test_rv003_undefined_group(self):
        comp = _comp([_group("g", _ok_uops())],
                     CSeq([GEnable("g"), GEnable("phantom")]),
                     cells=_reg_cells("acc"))
        d = find(verify.verify_component(comp), "RV003")
        assert d.severity == ERROR
        assert any(p.startswith("seq[1]") for p in d.provenance)

    def test_rv004_unreachable_group(self):
        comp = _comp([_group("g", _ok_uops()),
                      _group("orphan", _ok_uops("other"))],
                     CSeq([GEnable("g")]),
                     cells=_reg_cells("acc", "other"))
        d = find(verify.verify_component(comp), "RV004")
        assert d.severity == WARNING
        assert "group:orphan" in d.provenance

    def test_rv005_if_missing_condition(self):
        comp = _comp([_group("a", _ok_uops()), _group("b", _ok_uops())],
                     CIf(1, GEnable("a"), GEnable("b")),
                     cells=_reg_cells("acc"))
        assert find(verify.verify_component(comp), "RV005").severity == ERROR

    def test_rv006_negative_extent(self):
        comp = _comp([_group("g", _ok_uops())],
                     CRepeat(-2, GEnable("g"), var="i"),
                     cells=_reg_cells("acc"))
        find(verify.verify_component(comp), "RV006")

    def test_rv006_pipelined_nongroup_body(self):
        comp = _comp([_group("g", _ok_uops())],
                     CRepeat(4, CSeq([GEnable("g")]), var="i", ii=1),
                     cells=_reg_cells("acc"))
        d = find(verify.verify_component(comp), "RV006")
        assert "single group" in d.message

    def test_rv007_empty_group(self):
        comp = _comp([_group("g", [])], CSeq([GEnable("g")]))
        d = find(verify.verify_component(comp), "RV007")
        assert "group:g" in d.provenance

    def test_rv008_undeclared_memory(self):
        prog = Program("t", {"m": MemDecl("m", (8,))}, [])
        g = _group("g", [D.UMemRead(0, "nope", [AExpr.const_(0)], 0),
                         D.URegWrite("acc", 0)])
        comp = _comp([g], CSeq([GEnable("g")]), cells=_reg_cells("acc"))
        d = find(verify.verify_component(comp, prog), "RV008")
        assert "uop[0]:UMemRead" in d.provenance

    def test_rv008_bank_out_of_range(self):
        prog = Program("t", {"m": MemDecl("m", (2, 4), banks=(2,))}, [])
        g = _group("g", [D.UMemRead(0, "m", [AExpr.const_(7),
                                             AExpr.const_(0)], 0),
                         D.URegWrite("acc", 0)])
        comp = _comp([g], CSeq([GEnable("g")]), cells=_reg_cells("acc"))
        comp.meta["bank_factors"] = {"m": (2,)}
        d = find(verify.verify_component(comp, prog), "RV008")
        assert "bank index 7" in d.message

    def test_rv009_unbound_loop_var(self):
        g = _group("g", [D.UMemRead(0, "m", [AExpr.var("i")], 0),
                         D.URegWrite("acc", 0)])
        comp = _comp([g], CSeq([GEnable("g")]), cells=_reg_cells("acc"))
        d = find(verify.verify_component(comp), "RV009")
        assert "var:i" in d.provenance and "group:g" in d.provenance

    def test_rv009_bound_by_enclosing_repeat_is_clean(self):
        g = _group("g", [D.UMemRead(0, "m", [AExpr.var("i")], 0),
                         D.URegWrite("acc", 0)])
        comp = _comp([g], CRepeat(4, GEnable("g"), var="i"),
                     cells={**_reg_cells("acc"),
                            "idx_i": Cell("idx_i", "index")})
        assert "RV009" not in codes_of(verify.verify_component(comp))


class TestNegativeCorpusDataflow:
    def test_rv010_use_before_def(self):
        g = _group("g", [D.UAlu(1, "relu", 0, None, "u0"),
                         D.URegWrite("acc", 1)], cells=["u0"])
        comp = _comp([g], CSeq([GEnable("g")]), cells=None)
        comp.cells.update(_reg_cells("acc"))
        d = find(verify.verify_component(comp), "RV010")
        assert "uop[0]:UAlu" in d.provenance

    def test_rv011_read_before_any_write(self):
        g = _group("g", [D.URegRead(0, "r"), D.URegWrite("out", 0)])
        comp = _comp([g], CSeq([GEnable("g")]),
                     cells=_reg_cells("r", "out"))
        d = find(verify.verify_component(comp), "RV011")
        assert d.severity == ERROR
        assert "group:g" in d.provenance
        assert "uop[0]:URegRead" in d.provenance

    def test_rv011_write_on_other_par_arm_does_not_dominate(self):
        w = _group("w", _ok_uops("r"))
        r = _group("r", [D.URegRead(0, "r"), D.URegWrite("out", 0)])
        comp = _comp([w, r], CPar([GEnable("w"), GEnable("r")]),
                     cells=_reg_cells("r", "out"))
        assert "RV011" in codes_of(verify.verify_component(comp))

    def test_rv011_seq_write_dominates(self):
        w = _group("w", _ok_uops("r"))
        r = _group("r", [D.URegRead(0, "r"), D.URegWrite("out", 0)])
        comp = _comp([w, r], CSeq([GEnable("w"), GEnable("r")]),
                     cells=_reg_cells("r", "out"))
        assert "RV011" not in codes_of(verify.verify_component(comp))

    def test_rv011_if_join_intersects(self):
        # only the then-arm writes: the read after the join is dirty
        w = _group("w", _ok_uops("r"))
        n = _group("n", _ok_uops("other"))
        r = _group("r", [D.URegRead(0, "r"), D.URegWrite("out", 0)])
        cond = Cond.cmp(AExpr.var("i"), "lt", 2)
        comp = _comp(
            [w, n, r],
            CRepeat(4, CSeq([CIf(0, GEnable("w"), GEnable("n"), [], cond),
                             GEnable("r")]), var="i"),
            cells={**_reg_cells("r", "other", "out"),
                   "idx_i": Cell("idx_i", "index")})
        assert "RV011" in codes_of(verify.verify_component(comp))

    def test_rv012_dead_register_write(self):
        g = _group("g", [D.UConst(0, 1.0),
                         D.URegWrite("never_read", 0)])
        comp = _comp([g], CSeq([GEnable("g")]),
                     cells=_reg_cells("never_read"))
        d = find(verify.verify_component(comp), "RV012")
        assert d.severity == WARNING
        assert "uop[1]:URegWrite" in d.provenance

    def test_rv013_write_write_race(self):
        g = _group("g", [D.UConst(0, 1.0), D.UConst(1, 2.0),
                         D.URegWrite("r", 0, off=1),
                         D.URegWrite("r", 1, off=1)])
        comp = _comp([g], CSeq([GEnable("g")]), cells=_reg_cells("r"))
        d = find(verify.verify_component(comp), "RV013")
        assert "cycle offset 1" in d.message

    def test_rv014_temp_redefinition(self):
        g = _group("g", [D.UConst(0, 1.0), D.UConst(0, 2.0),
                         D.URegWrite("r", 0)])
        comp = _comp([g], CSeq([GEnable("g")]), cells=_reg_cells("r"))
        d = find(verify.verify_component(comp), "RV014")
        assert "uop[1]:UConst" in d.provenance


class TestNegativeCorpusHardware:
    def test_rv020_port_conflict(self):
        prog = Program("t", {"m": MemDecl("m", (8,))}, [])
        g = _group("g", [D.UConst(0, 1.0),
                         D.UMemWrite("m", [AExpr.const_(0)], 0, 3),
                         D.UMemWrite("m", [AExpr.const_(1)], 0, 3),
                         D.URegWrite("acc", 0)])
        comp = _comp([g], CSeq([GEnable("g")]), cells=_reg_cells("acc"))
        d = find(verify.verify_component(comp, prog), "RV020")
        assert "cycle offset 3" in d.message

    def test_rv020_broadcast_loads_are_clean(self):
        prog = Program("t", {"m": MemDecl("m", (8,))}, [])
        g = _group("g", [D.UMemRead(0, "m", [AExpr.const_(2)], 1),
                         D.UMemRead(1, "m", [AExpr.const_(2)], 1),
                         D.URegWrite("acc", 0),
                         D.URegWrite("acc2", 1)])
        comp = _comp([g], CSeq([GEnable("g")]),
                     cells=_reg_cells("acc", "acc2"))
        assert "RV020" not in codes_of(verify.verify_component(comp, prog))

    def test_rv021_pool_across_par_arms(self):
        a = _group("a", _ok_uops("ra"), cells=["shared_fp_add_0"])
        b = _group("b", _ok_uops("rb"), cells=["shared_fp_add_0"])
        comp = _comp([a, b], CPar([GEnable("a"), GEnable("b")]),
                     cells={**_reg_cells("ra", "rb"),
                            "shared_fp_add_0":
                                Cell("shared_fp_add_0", "fp_add", users=2)})
        d = find(verify.verify_component(comp), "RV021")
        assert "par[0]+par[1]" in d.provenance

    def test_rv021_pool_within_seq_is_clean(self):
        a = _group("a", _ok_uops("ra"), cells=["shared_fp_add_0"])
        b = _group("b", _ok_uops("rb"), cells=["shared_fp_add_0"])
        comp = _comp([a, b], CSeq([GEnable("a"), GEnable("b")]),
                     cells={**_reg_cells("ra", "rb"),
                            "shared_fp_add_0":
                                Cell("shared_fp_add_0", "fp_add", users=2)})
        assert "RV021" not in codes_of(verify.verify_component(comp))

    def test_rv022_ii_below_recurrence_floor(self):
        # acc written at off 3 but consumed at off 0 -> floor 3; ii=1 lies
        g = _group("g", [D.URegRead(0, "acc"),
                         D.UAlu(1, "add", 0, 0, "u0", off=0),
                         D.URegWrite("acc", 1, off=3)],
                   cells=["u0"], latency=4)
        comp = _comp([g], CRepeat(4, GEnable("g"), var="i", ii=1))
        comp.cells.update(_reg_cells("acc"))
        comp.cells["idx_i"] = Cell("idx_i", "index")
        d = find(verify.verify_component(comp), "RV022")
        assert "floor 3" in d.message

    def test_rv022_modulo_reservation_violation(self):
        prog = Program("t", {"m": MemDecl("m", (8,))}, [])
        g = _group("g", [D.UMemRead(0, "m", [AExpr.var("i")], 0),
                         D.UMemRead(1, "m", [AExpr.var("i")], 2),
                         D.UAlu(2, "add", 0, 1, "u0", off=3),
                         D.URegWrite("out", 2, off=4)],
                   cells=["u0"], latency=5)
        comp = _comp([g], CRepeat(4, GEnable("g"), var="i", ii=2))
        comp.cells.update(_reg_cells("out"))
        comp.cells["idx_i"] = Cell("idx_i", "index")
        d = find(verify.verify_component(comp, prog), "RV022")
        assert "modulo" in d.message

    def test_rv023_loop_carried_memory_dependence(self):
        prog = Program("t", {"m": MemDecl("m", (8,))}, [])
        g = _group("g", [D.UMemRead(0, "m", [AExpr.var("i")], 0),
                         D.UMemWrite("m", [AExpr.var("i")], 0, 1)],
                   latency=2)
        comp = _comp([g], CRepeat(4, GEnable("g"), var="i", ii=1))
        comp.cells["idx_i"] = Cell("idx_i", "index")
        d = find(verify.verify_component(comp, prog), "RV023")
        assert "group:g" in d.provenance


class TestNegativeCorpusNetlist:
    def test_rv030_multi_driven_wire(self):
        b = DpBlock("g", 2, [DpUnit(0, "u0", "relu", 0, None),
                             DpUnit(0, "u0", "relu", 0, None)], [])
        net = _netlist([b], [_fsm([FsmState(0, "group", cycles=2,
                                            group="g", next=1),
                                   FsmState(1, "done")])],
                       units=["u0"])
        rep = verify.verify_netlist(net)
        d = find(rep, "RV030")
        assert "wire:w0" in d.provenance

    def test_rv030_register_driven_twice_same_offset(self):
        b = DpBlock("g", 2, [DpUnit(0, "u0", "relu", 0, None),
                             DpRegWrite("r", 0, off=1),
                             DpRegWrite("r", 0, off=1)], [])
        net = _netlist([b], [_fsm([FsmState(0, "group", cycles=2,
                                            group="g", next=1),
                                   FsmState(1, "done")])],
                       regs=["r"], units=["u0"])
        d = find(verify.verify_netlist(net), "RV030")
        assert "driven twice" in d.message
        # the self-reference in op[0] also surfaces as RV031
        assert "RV031" in codes_of(verify.verify_netlist(net))

    def test_rv031_forward_reference(self):
        b = DpBlock("g", 2, [DpUnit(0, "u0", "relu", 1, None),
                             DpUnit(1, "u0", "relu", 0, None)], [])
        net = _netlist([b], [_fsm([FsmState(0, "group", cycles=2,
                                            group="g", next=1),
                                   FsmState(1, "done")])],
                       units=["u0"])
        d = find(verify.verify_netlist(net), "RV031")
        assert "wire:w1" in d.provenance

    def test_rv032_unreachable_state(self):
        net = _netlist([], [_fsm([FsmState(0, "done"),
                                  FsmState(1, "delay", cycles=1)])])
        d = find(verify.verify_netlist(net), "RV032")
        assert d.severity == WARNING
        assert "state[1]:delay" in d.provenance

    def test_rv033_transition_out_of_range(self):
        net = _netlist([], [_fsm([FsmState(0, "delay", cycles=1, next=9),
                                  FsmState(1, "done")])])
        d = find(verify.verify_netlist(net), "RV033")
        assert "state 9" in d.message

    def test_rv033_loop_backedge_unbound_index(self):
        net = _netlist([], [_fsm([FsmState(0, "delay", cycles=1,
                                           loop=("i", 4, 0), next=1),
                                  FsmState(1, "done")])])
        d = find(verify.verify_netlist(net), "RV033")
        assert "'i'" in d.message

    def test_rv034_unresolvable_loop_var(self):
        cond = Cond.cmp(AExpr.var("k"), "lt", 2)
        b = DpBlock("g", 2, [DpUnit(0, "u0", "relu", 0, None),
                             DpUnit(1, "u0", "relu", 0, None),
                             DpSelect(2, cond, 0, 1)], [])
        net = _netlist([b], [_fsm([FsmState(0, "group", cycles=2,
                                            group="g", next=1),
                                   FsmState(1, "done")])],
                       units=["u0"])
        d = find(verify.verify_netlist(net), "RV034")
        assert "var:k" in d.provenance
        # RV031 must also fire for w0 read in op[0] (self-reference)
        assert find(verify.verify_netlist(net), "RV031")


def _profiled(counters=None):
    """Minimal clean profiled netlist: one group block, one FSM, and a
    counter bank (the canonical one unless a broken bank is injected)."""
    b = DpBlock("g", 2, [DpConst(0, 1.0), DpRegWrite("r", 0, off=1)], [])
    net = _netlist([b], [_fsm([FsmState(0, "group", cycles=2,
                                        group="g", next=1),
                               FsmState(1, "done")])],
                   regs=["r"])
    net.profile = True
    net.counters = (counters if counters is not None
                    else perf_counter_bank(net.blocks))
    return net


class TestNegativeCorpusCounters:
    """RV05x: the profiled netlist's perf-counter bank must match the
    canonical address map hosts derive from the design alone."""

    def test_canonical_bank_is_clean(self):
        rep = verify.verify_netlist(_profiled())
        assert not codes_of(rep) & {"RV050", "RV051", "RV052"}

    def test_unprofiled_netlist_skips_counter_checks(self):
        net = _profiled(counters=[])     # empty bank would be RV052...
        net.profile = False              # ...but the hardware is off
        assert not codes_of(verify.verify_netlist(net)) & \
            {"RV050", "RV051", "RV052"}

    def test_rv050_counter_names_unknown_group(self):
        net = _profiled()
        net.counters[1] = PerfCounter(1, "perf_g_ghost", "group",
                                      group="ghost")
        d = find(verify.verify_netlist(net), "RV050")
        assert d.severity == ERROR
        assert "counter:perf_g_ghost" in d.provenance

    def test_rv051_nondense_indices(self):
        net = _profiled()
        last = net.counters[-1]
        net.counters[-1] = PerfCounter(last.index + 3, last.name,
                                       last.kind)
        d = find(verify.verify_netlist(net), "RV051")
        assert "dense" in d.message

    def test_rv051_unknown_kind(self):
        net = _profiled()
        net.counters.append(PerfCounter(len(net.counters), "perf_bogus",
                                        "bogus"))
        d = find(verify.verify_netlist(net), "RV051")
        assert "counter:perf_bogus" in d.provenance

    def test_rv051_duplicate_names(self):
        net = _profiled()
        net.counters.append(PerfCounter(len(net.counters), "perf_total",
                                        "total"))
        assert any("duplicate" in d.message
                   for d in verify.verify_netlist(net)
                   if d.code == "RV051")

    def test_rv052_missing_stall_counter(self):
        net = _profiled()
        net.counters.pop()               # fsm_overhead is last: still dense
        d = find(verify.verify_netlist(net), "RV052")
        assert "fsm_overhead" in d.message

    def test_rv052_group_without_counter(self):
        bank = perf_counter_bank({})
        net = _profiled(counters=bank)   # dense bank, but no group counter
        d = find(verify.verify_netlist(net), "RV052")
        assert "without a counter" in d.message


class TestNegativeCorpusVerilogLint:
    def test_rv040_delay_control(self):
        d = find_lint("module m;\nassign x = y;\n#5 foo;\nendmodule\n",
                      "RV040")
        assert "module:m" in d.provenance

    def test_rv041_initial_outside_mem_init(self):
        d = find_lint("module m;\ninitial begin\nx = 1;\nend\nendmodule\n",
                      "RV041")
        assert d.severity == ERROR

    def test_rv042_multi_driver(self):
        text = ("module m;\n"
                "assign x = a;\n"
                "assign x = b;\n"
                "endmodule\n")
        d = find_lint(text, "RV042")
        assert "net:x" in d.provenance


def find_lint(text, code):
    findings = verilog.lint_diagnostics(text)
    hits = [d for d in findings if d.code == code]
    assert hits, f"{code} missing from {[d.code for d in findings]}"
    return hits[0]


class TestRegistryCoverage:
    def test_every_code_has_a_negative_fixture(self):
        """The corpus above exercises the full registry — this meta-test
        keeps the two in sync when codes are added."""
        covered = {
            "RV001", "RV002", "RV003", "RV004", "RV005", "RV006",
            "RV007", "RV008", "RV009", "RV010", "RV011", "RV012",
            "RV013", "RV014", "RV020", "RV021", "RV022", "RV023",
            "RV030", "RV031", "RV032", "RV033", "RV034",
            "RV040", "RV041", "RV042",
            "RV050", "RV051", "RV052",
        }
        assert covered == set(CODES)

    def test_error_reports_raise_and_warnings_do_not(self):
        g = _group("g", _ok_uops())
        comp = _comp([g], CSeq([GEnable("g"), GEnable("phantom")]),
                     cells=_reg_cells("acc"))
        rep = verify.verify_component(comp)
        with pytest.raises(diagnostics.VerificationError):
            rep.raise_if_errors()
        warn_only = _comp([_group("g", _ok_uops()),
                           _group("orphan", _ok_uops("o2"))],
                          CSeq([GEnable("g")]),
                          cells=_reg_cells("acc", "o2"))
        verify.verify_component(warn_only).raise_if_errors()  # no raise


class TestPipelineIntegration:
    def test_compiled_design_is_clean_and_stamped(self):
        import repro.core.frontend as frontend
        d = pipeline.compile_model(frontend.Linear(4, 4, bias=False),
                                   [(2, 4)], factor=2, opt_level=2)
        d.to_rtl()
        stages = [r.stage for r in d.verify_reports]
        assert stages[0] == "post-lower"
        assert "post-sharing" in stages and "post-rtl" in stages
        assert all(len(r) == 0 for r in d.verify_reports)
        assert all(r.wall_us > 0 for r in d.verify_reports)

    def test_verify_off_skips_boundaries(self):
        import repro.core.frontend as frontend
        d = pipeline.compile_model(frontend.Linear(4, 4, bias=False),
                                   [(2, 4)], verify=False)
        assert [r.stage for r in d.verify_reports
                if r.stage != "post-rtl"] == []

    def test_broken_artifact_fails_the_boundary(self):
        """An unsound II written onto a compiled design is caught by a
        re-verify — the checks run against the artifact, not the pass's
        claims."""
        import repro.core.frontend as frontend
        d = pipeline.compile_model(frontend.Linear(4, 4, bias=False),
                                   [(2, 4)], factor=2, opt_level=2)
        comp = d.component
        broken = False
        for node in verify._walk_nodes(comp.control):
            if isinstance(node, CRepeat) and node.ii > 1:
                node.ii = 1     # below the floor the pass proved
                broken = True
        if not broken:
            pytest.skip("no pipelined loop with ii > 1 in this design")
        rep = verify.verify_component(comp, d.program, stage="re-verify")
        assert "RV022" in codes_of(rep)


class TestDeadElimination:
    def _design(self):
        g = _group("g", _ok_uops())
        orphan = _group("orphan", _ok_uops("o2"))
        comp = _comp([g, orphan], CSeq([GEnable("g")]),
                     cells={**_reg_cells("acc", "o2"),
                            "stray": Cell("stray", "fp_mul")})
        return comp

    def test_strips_exactly_the_findings_subjects(self):
        comp = self._design()
        out, removed = verify.eliminate_dead(comp)
        assert removed["groups"] == ["orphan"]
        assert set(removed["cells"]) == {"stray", "reg_o2"}
        assert "orphan" not in out.groups and "stray" not in out.cells
        assert out.control is comp.control

    def test_clean_design_returned_unchanged(self):
        comp = self._design()
        out, _ = verify.eliminate_dead(comp)
        again, removed = verify.eliminate_dead(out)
        assert again is out
        assert removed == {"groups": [], "cells": []}

    def test_cycle_neutral(self):
        from repro.core import estimator
        comp = self._design()
        before = estimator.cycles(comp)
        out, _ = verify.eliminate_dead(comp)
        assert estimator.cycles(out) == before


class TestGroupCache:
    def test_hit_skips_recheck_but_revalidates_cells(self):
        g = _group("g", [D.UConst(0, 1.0),
                         D.UAlu(1, "relu", 0, None, "u0"),
                         D.URegWrite("acc", 1),
                         D.URegRead(2, "acc"),
                         D.UMemWrite("buf", [AExpr.const_(0)], 2, 1)],
                   cells=["u0"])
        comp = _comp([g], CSeq([GEnable("g")]), cells=_reg_cells("acc"))
        cache = verify.GroupCache()
        assert len(verify.verify_component(comp, cache=cache)) == 0
        # same group object, cell table loses the ALU: the cached clean
        # verdict must NOT mask the new dangling reference
        smaller = Component("t", _reg_cells("acc"), comp.groups,
                            comp.control)
        rep = verify.verify_component(smaller, cache=cache)
        assert "RV001" in codes_of(rep)

    def test_carry_over_never_suppresses_fresh_findings(self):
        """Boundary N clean, boundary N+1 same control/groups but a cell
        vanished: the carried analyses must still surface the breakage."""
        g = _group("g", [D.UConst(0, 1.0),
                         D.UAlu(1, "relu", 0, None, "u0"),
                         D.URegWrite("acc", 1),
                         D.URegRead(2, "acc"),
                         D.UMemWrite("buf", [AExpr.const_(0)], 2, 1)],
                   cells=["u0"])
        comp = _comp([g], CSeq([GEnable("g")]), cells=_reg_cells("acc"))
        cache = verify.GroupCache()
        assert len(verify.verify_component(comp, cache=cache)) == 0
        popped = dict(comp.cells)
        del popped["u0"]
        comp2 = Component("t", popped, comp.groups, comp.control)
        rep = verify.verify_component(comp2, cache=cache)
        assert "RV001" in codes_of(rep)

    def test_transfer_rebound_carries_verdicts(self):
        import repro.core.frontend as frontend
        d = pipeline.compile_model(frontend.Linear(4, 4, bias=False),
                                   [(2, 4)], factor=2, share=True)
        stages = {r.stage: r for r in d.verify_reports}
        assert len(stages["post-sharing"]) == 0

    def test_transfer_rebound_rejects_nonequivalent_rewrites(self):
        """A 'sharing' rebind that changed an op must not inherit the
        clean verdict — the cache re-checks the group from scratch."""
        g = _group("g", [D.UConst(0, 1.0),
                         D.UAlu(1, "relu", 0, None, "u0"),
                         D.URegWrite("acc", 1)], cells=["u0"])
        comp = _comp([g], CSeq([GEnable("g")]), cells=_reg_cells("acc"))
        cache = verify.GroupCache()
        verify.verify_component(comp, cache=cache)
        hacked = Group("g", g.latency, ["pool0"], [],
                       [D.UConst(0, 1.0),
                        # not a pure rename: operand a changed to 9
                        D.UAlu(1, "relu", 9, None, "pool0"),
                        D.URegWrite("acc", 1)])
        cache.transfer_rebound({"g": g}, {"g": hacked}, {"u0": "pool0"})
        cells = {**_reg_cells("acc"), "pool0": Cell("pool0", "fp_add")}
        comp2 = Component("t", cells, {"g": hacked}, comp.control)
        rep = verify.verify_component(comp2, cache=cache)
        assert "RV010" in codes_of(rep)   # the 9 is read before any def


class TestSharingVerifierAgreement:
    def test_share_cells_output_passes_rv021(self):
        a = _group("a", [D.UConst(0, 1.0),
                         D.UAlu(1, "add", 0, 0, "fa0"),
                         D.URegWrite("ra", 1)], cells=["fa0"])
        b = _group("b", [D.UConst(0, 1.0),
                         D.UAlu(1, "add", 0, 0, "fa1"),
                         D.URegWrite("rb", 1)], cells=["fa1"])
        comp = _comp([a, b], CSeq([GEnable("a"), GEnable("b")]),
                     cells=_reg_cells("ra", "rb"))
        shared, report = sharing.share_cells(comp)
        assert report.removed == 1
        rep = verify.verify_component(shared)
        assert "RV021" not in codes_of(rep)
