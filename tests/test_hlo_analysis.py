"""Validate the trip-count-aware HLO analyzer against known-cost programs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_analysis as H


def _compile_text(fn, *specs):
    return jax.jit(fn).lower(*specs).compile().as_text()


S = jax.ShapeDtypeStruct


class TestFlops:
    def test_single_dot(self):
        text = _compile_text(lambda a, b: a @ b,
                             S((64, 32), jnp.float32),
                             S((32, 16), jnp.float32))
        cost = H.analyze(text)
        assert cost.flops == pytest.approx(2 * 64 * 32 * 16, rel=0.01)

    def test_scan_multiplies_by_trip_count(self):
        def scanned(x, w):
            def body(c, _):
                return c @ w, None
            out, _ = jax.lax.scan(body, x, None, length=8)
            return out

        def unrolled(x, w):
            for _ in range(8):
                x = x @ w
            return x

        specs = (S((128, 128), jnp.float32),) * 2
        f_scan = H.analyze(_compile_text(scanned, *specs)).flops
        f_unroll = H.analyze(_compile_text(unrolled, *specs)).flops
        expect = 2 * 128 ** 3 * 8
        assert f_scan == pytest.approx(expect, rel=0.05)
        assert f_unroll == pytest.approx(expect, rel=0.05)

    def test_nested_scan(self):
        def fn(x, w):
            def outer(c, _):
                def inner(ci, _):
                    return ci @ w, None
                ci, _ = jax.lax.scan(inner, c, None, length=4)
                return ci, None
            out, _ = jax.lax.scan(outer, x, None, length=3)
            return out

        specs = (S((64, 64), jnp.float32),) * 2
        cost = H.analyze(_compile_text(fn, *specs))
        assert cost.flops == pytest.approx(2 * 64 ** 3 * 12, rel=0.05)

    def test_batched_dot(self):
        def fn(a, b):
            return jnp.einsum("bij,bjk->bik", a, b)
        text = _compile_text(fn, S((4, 8, 16), jnp.float32),
                             S((4, 16, 8), jnp.float32))
        cost = H.analyze(text)
        assert cost.flops == pytest.approx(2 * 4 * 8 * 16 * 8, rel=0.01)


class TestTraffic:
    def test_elementwise_traffic_reasonable(self):
        def fn(a, b):
            return a + b * 2.0
        text = _compile_text(fn, S((1024, 1024), jnp.float32),
                             S((1024, 1024), jnp.float32))
        cost = H.analyze(text)
        mb = 1024 * 1024 * 4
        # in + in + out = 3 buffers (fusion collapses the temporary)
        assert 2 * mb <= cost.traffic_bytes <= 5 * mb

    def test_scan_traffic_scales(self):
        def scanned(x):
            def body(c, _):
                return c * 2.0 + 1.0, None
            out, _ = jax.lax.scan(body, x, None, length=16)
            return out
        t1 = H.analyze(_compile_text(scanned, S((512, 512), jnp.float32)))

        def scanned4(x):
            def body(c, _):
                return c * 2.0 + 1.0, None
            out, _ = jax.lax.scan(body, x, None, length=64)
            return out
        t4 = H.analyze(_compile_text(scanned4, S((512, 512), jnp.float32)))
        assert t4.traffic_bytes > 3 * t1.traffic_bytes


class TestCollectives:
    @pytest.fixture(scope="class")
    def mesh8(self):
        if len(jax.devices()) < 8:
            pytest.skip("needs 8 host devices (run under dryrun env)")
        return jax.make_mesh((8,), ("d",))

    def test_psum_bytes(self, mesh8):
        from jax.sharding import NamedSharding, PartitionSpec as P
        from functools import partial

        @partial(jax.jit, out_shardings=NamedSharding(mesh8, P()))
        def fn(x):
            return x.sum(axis=0)

        spec = S((8, 4096), jnp.float32,
                 sharding=NamedSharding(mesh8, P("d", None)))
        text = jax.jit(fn).lower(spec).compile().as_text()
        cost = H.analyze(text)
        assert cost.total_collective_bytes >= 4096 * 4  # one row reduced
