"""Launch layer: shapes, sharding rules, cell skip logic, model flops
(host-mesh scale — the 512-device path is exercised by dryrun itself)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch import shapes as SH
from repro.launch.dryrun import model_flops
from repro.launch.shapes import SHAPES, cell_supported
from repro.models import all_names, get_config
from repro.models import params as MP
from repro.sharding.rules import (ShardingStrategy, param_pspecs,
                                  sanitize_spec)


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


class TestSkips:
    def test_long_context_skips(self):
        runnable = {n: cell_supported(get_config(n), "long_500k")[0]
                    for n in all_names()}
        assert runnable == {
            "gemma2-27b": False, "granite-moe-1b-a400m": False,
            "llama-3.2-vision-11b": False, "olmoe-1b-7b": False,
            "qwen2-0.5b": False, "qwen2-7b": False, "rwkv6-7b": True,
            "starcoder2-7b": False, "whisper-large-v3": False,
            "zamba2-7b": True,
        }

    def test_other_shapes_all_supported(self):
        for n in all_names():
            for s in ("train_4k", "prefill_32k", "decode_32k"):
                assert cell_supported(get_config(n), s)[0]


class TestParamSpecs:
    @pytest.mark.parametrize("arch", all_names())
    def test_pspec_ranks_match_shapes(self, arch):
        cfg = get_config(arch)
        shapes = MP.param_shapes(cfg)
        pspecs = param_pspecs(cfg, ShardingStrategy())
        flat_s = jax.tree.leaves(shapes, is_leaf=MP._is_leaf)
        flat_p = jax.tree.leaves(pspecs,
                                 is_leaf=lambda x: isinstance(x, P))
        assert len(flat_s) == len(flat_p)
        for lf, spec in zip(flat_s, flat_p):
            assert len(spec) <= len(lf[0]), (lf, spec)

    def test_sanitize_drops_uneven(self, mesh):
        big = jax.make_mesh((1,), ("model",)) if False else mesh
        spec = sanitize_spec(P("model", "data"), (51866, 1280), mesh)
        assert spec == P("model", "data")  # 1-device axes always divide

    def test_param_count_magnitudes(self):
        # sanity vs published sizes (within 25%)
        expect = {"qwen2-0.5b": 0.49e9, "qwen2-7b": 7.6e9,
                  "gemma2-27b": 27e9, "olmoe-1b-7b": 6.9e9,
                  "starcoder2-7b": 7.2e9, "rwkv6-7b": 7.6e9}
        for name, n in expect.items():
            got = get_config(name).param_count()
            assert 0.7 * n < got < 1.35 * n, (name, got, n)

    def test_olmoe_active_params_about_1b(self):
        cfg = get_config("olmoe-1b-7b")
        assert 0.9e9 < cfg.active_param_count() < 1.7e9


class TestModelFlops:
    def test_train_flops_6nd_regime(self):
        cfg = get_config("qwen2-7b")
        f = model_flops(cfg, SHAPES["train_4k"])
        n = cfg.param_count()
        tokens = 256 * 4096
        assert f > 6 * 0.8 * n * tokens          # at least ~6ND

    def test_decode_much_smaller_than_prefill(self):
        cfg = get_config("qwen2-7b")
        assert (model_flops(cfg, SHAPES["decode_32k"])
                < 0.01 * model_flops(cfg, SHAPES["prefill_32k"]))

    def test_window_reduces_attn_flops(self):
        g = get_config("gemma2-27b")
        full = model_flops(g, SHAPES["prefill_32k"])
        # a hypothetical all-global gemma would have more attn flops
        import dataclasses
        allglobal = dataclasses.replace(g, local_global=False,
                                        sliding_window=0, num_layers=46)
        assert model_flops(allglobal, SHAPES["prefill_32k"]) > full


class TestInputSpecs:
    @pytest.mark.parametrize("shape", ["train_4k", "prefill_32k",
                                       "decode_32k"])
    def test_specs_build_for_every_arch(self, mesh, shape):
        st = ShardingStrategy()
        for arch in all_names():
            cfg = get_config(arch)
            specs = SH.input_specs(cfg, shape, mesh, st)
            leaves = jax.tree.leaves(specs)
            assert leaves and all(hasattr(l, "shape") for l in leaves)
