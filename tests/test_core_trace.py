"""Observability layer: event traces, profiler, VCD, counter bank.

The contract under test is the ISSUE's four-way differential — the
Calyx-level simulator's stats, the RTL-level simulator's stats, both
event-trace aggregates, the synthesized hardware counter bank, and the
estimator's analytic attribution must agree *exactly* — plus the
supporting surfaces: a committed golden trace that must stay
byte-stable, a negative fixture whose induced port conflict surfaces as
a ``stall:port`` event, VCD well-formedness (checked with the same tiny
checker CI runs), deterministic lint-clean profiled Verilog, and the
zero-cost-when-off guarantee that tracing never perturbs measurement.
"""
import importlib.util
import pathlib

import numpy as np
import pytest

from repro.core import (affine, calyx, estimator, frontend, pipeline,
                        profiler, rtl, rtl_sim, schedule, sim, trace,
                        verilog)

_HERE = pathlib.Path(__file__).resolve().parent
_GOLDEN = _HERE / "data" / "golden_trace_linear2_rtl.jsonl"

_spec = importlib.util.spec_from_file_location(
    "check_vcd", _HERE.parent / "scripts" / "check_vcd.py")
check_vcd = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_vcd)


def _tiny():
    return pipeline.compile_model(frontend.Linear(2, 2, bias=False),
                                  [(2, 2)], factor=1, share=True,
                                  opt_level=0)


def _x(shape, seed=0):
    return np.random.default_rng(seed).normal(size=shape) \
        .astype(np.float32)


class TestGoldenTrace:
    """The committed netlist-level trace of the smallest design is the
    schema's regression anchor: any serialization, provenance-descent,
    or event-ordering change shows up as a byte diff here."""

    def test_rtl_trace_matches_committed_golden_bytes(self):
        tr = trace.Tracer()
        _tiny().simulate_rtl({"arg0": _x((2, 2))}, tracer=tr)
        assert trace.to_jsonl(tr.events) == _GOLDEN.read_text()

    def test_jsonl_round_trip(self):
        events = trace.from_jsonl(_GOLDEN.read_text())
        assert events and trace.to_jsonl(events) == _GOLDEN.read_text()

    def test_tracing_never_perturbs_measurement(self):
        """Zero-cost-when-off also means zero-effect-when-on: the traced
        run must measure exactly what the untraced run measured."""
        d = _tiny()
        x = _x((2, 2))
        _, plain = d.simulate({"arg0": x})
        _, traced = d.simulate({"arg0": x}, tracer=trace.Tracer())
        assert trace.counters_of_stats(plain) == \
            trace.counters_of_stats(traced)
        _, rplain = d.simulate_rtl({"arg0": x})
        _, rtraced = d.simulate_rtl({"arg0": x}, tracer=trace.Tracer())
        assert rplain.cycles == rtraced.cycles


def _conflicting():
    """Unbanked parallelized matmul: both par arms hit the same
    single-ported memory, so the arms serialize — the induced port
    conflict of the negative observability fixture."""
    g = frontend.trace(frontend.Linear(8, 8, bias=False), [(4, 8)])
    prog = schedule.restructure(
        schedule.parallelize(affine.lower_graph(g), 2))
    return calyx.lower_program(prog), prog, g


class TestInducedPortConflict:
    def test_serialized_arms_surface_as_stall_port_events(self):
        comp, prog, g = _conflicting()
        x = _x((4, 8), seed=1)
        tr = trace.Tracer()
        _, stats = sim.simulate(comp, prog, {"arg0": x}, g.params,
                                tracer=tr)
        assert stats.serialized_arms > 0
        stalls = [e for e in tr.events if e.kind == trace.STALL_PORT]
        assert stalls, "induced port conflict produced no stall:port"
        assert all(e.dur > 0 for e in stalls)
        # the events price the very loss the counter reports
        agg = trace.aggregate(tr.events)
        assert agg["stall_port_cycles"] == stats.stall_port_cycles > 0

    def test_rtl_level_agrees_on_the_serialization_loss(self):
        comp, prog, g = _conflicting()
        x = _x((4, 8), seed=1)
        tr_s, tr_r = trace.Tracer(), trace.Tracer()
        _, stats = sim.simulate(comp, prog, {"arg0": x}, g.params,
                                tracer=tr_s)
        net = rtl.lower_component(comp, prog, profile=True)
        _, rstats = rtl_sim.simulate(net, {"arg0": x}, g.params,
                                     tracer=tr_r)
        assert rstats.stall_port_cycles == stats.stall_port_cycles > 0
        assert any(e.kind == trace.STALL_PORT for e in tr_r.events)
        assert trace.join_mismatches(tr_s.events, tr_r.events) == []
        # the synthesized counter bank prices the same loss
        assert rstats.counters["stall_port"] == stats.stall_port_cycles


# the tier-1 slice of the acceptance matrix: the cheap designs fully,
# plus the if-bearing design (attribution exact=False, total-only);
# benchmarks/calyx_bench.py enforces all 48 points
_POINTS = [("matmul", 2, True, 0), ("matmul", 2, True, 2),
           ("ffnn", 4, True, 2), ("ffnn", 1, False, 0),
           ("conv2d", 2, False, 2), ("attention", 2, True, 2)]


class TestFourWayDifferential:
    @pytest.mark.parametrize("design,factor,share,opt", _POINTS)
    def test_profile_agrees_across_all_levels(self, design, factor,
                                              share, opt):
        from benchmarks.calyx_bench import DESIGNS
        builder, shape = DESIGNS[design]
        d = pipeline.compile_model(builder(), [shape], factor=factor,
                                   share=share, opt_level=opt)
        prof = d.profile({"arg0": _x(shape)})
        assert prof.mismatches == []
        assert prof.hw_counters["total"] == prof.cycles \
            == d.estimate.cycles
        # the report renders without touching the mismatch list
        assert str(prof.cycles) in prof.report()


class TestVcdWellFormedness:
    def test_generated_vcd_passes_the_ci_checker(self):
        tr = trace.Tracer()
        d = _tiny()
        d.simulate_rtl({"arg0": _x((2, 2))}, tracer=tr)
        text = profiler.to_vcd(tr.events, name=d.component.name)
        assert check_vcd.check(text) == []

    def test_checker_rejects_malformed_vcd(self):
        tr = trace.Tracer()
        d = _tiny()
        d.simulate_rtl({"arg0": _x((2, 2))}, tracer=tr)
        text = profiler.to_vcd(tr.events, name=d.component.name)
        assert check_vcd.check(text.replace("$timescale 1ns $end\n", ""))
        assert check_vcd.check("$enddefinitions $end\n#0\n")


class TestProfiledVerilog:
    def test_profiled_emission_is_deterministic_and_lint_clean(self):
        d = pipeline.compile_model(frontend.paper_ffnn(), [(1, 64)],
                                   factor=2, opt_level=2)
        a = d.emit_verilog(profile=True)
        b = d.emit_verilog(profile=True)
        assert a == b
        assert verilog.lint(a) == []
        assert "perf_total" in a and "16'hffff" in a

    def test_profile_off_emission_is_byte_identical(self):
        """profile=False is the default and must cost nothing: emitting
        the profiled netlist first must not leak into the plain text."""
        d = pipeline.compile_model(frontend.paper_ffnn(), [(1, 64)],
                                   factor=2, opt_level=2)
        plain = d.emit_verilog()
        d.emit_verilog(profile=True)
        assert d.emit_verilog() == plain
        assert "perf_total" not in plain
