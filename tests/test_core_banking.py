"""Banking pass: layout correctness, branchy equivalence, hazard analysis,
and the paper's c^d blow-up metrics."""
import numpy as np
import pytest

from repro.core import affine, banking, frontend, pipeline, schedule
from repro.core.affine import AExpr, pack_banked, unpack_banked
from repro.core.banking import (BankConflictError, BankingSpec,
                                count_branch_arms, count_divmod_hardware,
                                provably_disjoint)


class TestPackUnpack:
    @pytest.mark.parametrize("shape,factors", [
        ((8,), (2,)), ((8, 6), (2, 3)), ((5,), (2,)), ((7, 5), (4, 2)),
        ((4, 4, 4), (2, 2, 2)),
    ])
    def test_roundtrip(self, shape, factors):
        rng = np.random.default_rng(0)
        arr = rng.normal(size=shape).astype(np.float32)
        packed = unpack_banked(pack_banked(arr, factors), shape, factors)
        np.testing.assert_array_equal(packed, arr)

    def test_cyclic_layout(self):
        arr = np.arange(8.0)
        b = pack_banked(arr, (2,))
        np.testing.assert_array_equal(b[0], [0, 2, 4, 6])
        np.testing.assert_array_equal(b[1], [1, 3, 5, 7])


class TestDisjointness:
    def test_const_difference_is_disjoint(self):
        i = AExpr.var("i")
        assert provably_disjoint([i * 2], [i * 2 + 1])

    def test_same_expr_not_disjoint(self):
        i = AExpr.var("i")
        assert not provably_disjoint([i], [i])

    def test_symbolic_not_disjoint(self):
        assert not provably_disjoint([AExpr.var("i")], [AExpr.var("j")])


class TestLayoutBanking:
    def test_ffnn_factor2_and_4_match_oracle(self):
        m = frontend.paper_ffnn()
        x = np.random.default_rng(0).normal(size=(1, 64)).astype(np.float32)
        ref = None
        for f in (1, 2, 4):
            d = pipeline.compile_model(m, [(1, 64)], factor=f)
            out = d.run({"arg0": x})[0]
            if ref is None:
                ref = d.run_oracle({"arg0": x})[0]
            np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)

    def test_layout_mode_has_no_divmod_or_branches(self):
        d = pipeline.compile_model(frontend.paper_ffnn(), [(1, 64)], factor=4)
        assert count_divmod_hardware(d.program) == 0
        assert count_branch_arms(d.program) == 0
        assert d.hazards == []

    def test_banked_memory_shapes(self):
        d = pipeline.compile_model(frontend.paper_ffnn(), [(1, 64)], factor=2)
        # weight (64,48) -> factors (2,2) -> (4, 32, 24)
        w = [m for m in d.program.mems.values()
             if m.role == "param" and m.banks == (2, 2)
             and m.shape == (4, 32, 24)]
        assert w, "expected the 64x48 weight banked into 4 banks of 32x24"


class TestBranchyBanking:
    def test_branchy_matches_oracle(self):
        m = frontend.paper_ffnn()
        x = np.random.default_rng(1).normal(size=(1, 64)).astype(np.float32)
        d = pipeline.compile_model(m, [(1, 64)], factor=2, mode="branchy",
                                   check_hazards=False)
        np.testing.assert_allclose(d.run({"arg0": x})[0],
                                   d.run_oracle({"arg0": x})[0],
                                   rtol=1e-4, atol=1e-5)

    def test_branchy_blowup_scales_with_banks(self):
        """The paper's c^d growth: branch hardware grows ~4x from f2 to f4."""
        m = frontend.paper_ffnn()
        b2 = pipeline.compile_model(m, [(1, 64)], factor=2, mode="branchy",
                                    check_hazards=False)
        b4 = pipeline.compile_model(m, [(1, 64)], factor=4, mode="branchy",
                                    check_hazards=False)
        n2, n4 = count_branch_arms(b2.program), count_branch_arms(b4.program)
        assert n2 > 0 and n4 > 3 * n2   # c^d with d=2: 4x per factor doubling

    def test_branchy_hazards_not_provable(self):
        d = pipeline.compile_model(frontend.paper_ffnn(), [(1, 64)], factor=2,
                                   mode="branchy", check_hazards=False)
        assert len(d.hazards) > 0   # the static analysis cannot prove safety

    def test_branchy_slower_and_larger_than_layout(self):
        m = frontend.paper_ffnn()
        dl = pipeline.compile_model(m, [(1, 64)], factor=2)
        db = pipeline.compile_model(m, [(1, 64)], factor=2, mode="branchy",
                                    check_hazards=False)
        assert db.estimate.cycles > 2 * dl.estimate.cycles
        assert db.estimate.resources["LUT"] > dl.estimate.resources["LUT"]


class TestHazardDetection:
    def test_write_write_conflict_detected(self):
        """Hand-built Par with two arms writing the same address."""
        i = AExpr.var("i")
        st = affine.Store("m", [i], affine.ConstF(1.0))
        st2 = affine.Store("m", [i], affine.ConstF(2.0))
        prog = affine.Program(
            "p", {"m": affine.MemDecl("m", (4,), "output")},
            [affine.Loop("i", 4, [affine.Par([[st], [st2]])])])
        with pytest.raises(BankConflictError):
            banking.check_par_hazards(prog)

    def test_disjoint_writes_pass(self):
        i = AExpr.var("i")
        st = affine.Store("m", [i * 2], affine.ConstF(1.0))
        st2 = affine.Store("m", [i * 2 + 1], affine.ConstF(2.0))
        prog = affine.Program(
            "p", {"m": affine.MemDecl("m", (8,), "output")},
            [affine.Loop("i", 4, [affine.Par([[st], [st2]])])])
        assert banking.check_par_hazards(prog) == []

    def test_reg_cross_arm_conflict(self):
        s1 = affine.SetReg("r", affine.ConstF(1.0))
        s2 = affine.SetReg("r", affine.ConstF(2.0))
        prog = affine.Program("p", {}, [affine.Par([[s1], [s2]])])
        with pytest.raises(BankConflictError):
            banking.check_par_hazards(prog)
