"""Property-based test: the RTL backend preserves both schedule and
semantics over randomized small graphs — the netlist's measured cycle
count equals the estimator's closed form *and* the Calyx simulator's
measurement, and the netlist computes bit-identical outputs, across
random models, banking factors, and sharing.

This is the RTL twin of ``tests/test_property_sim.py``: where that test
proves the binding pass is cycle-neutral under simulation, this one
proves the Calyx -> netlist -> execution path neither stretches the
static schedule by a single cycle nor perturbs a single output bit.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import frontend, pipeline, verilog


@st.composite
def random_models(draw):
    """Tiny random MLP-ish module + input shape + banking factor.

    Dims are drawn from multiples of the banking factor so that the
    layout-mode disjointness proof succeeds (a banking-pass precondition,
    not an RTL concern); ReLU and bias toggles vary the group mix.
    """
    factor = draw(st.sampled_from([1, 2, 4]))
    n_layers = draw(st.integers(1, 3))
    mult = st.integers(1, 2)
    dims = [factor * draw(mult) * 2 for _ in range(n_layers + 1)]
    rng = np.random.default_rng(draw(st.integers(0, 2**16)))
    layers = []
    for a, b in zip(dims, dims[1:]):
        layers.append(frontend.Linear(a, b, bias=draw(st.booleans()),
                                      rng=rng))
        if draw(st.booleans()):
            layers.append(frontend.ReLU())
    rows = factor * draw(mult)
    return frontend.Sequential(*layers), (rows, dims[0]), factor


class TestRtlMatchesEstimatorAndSim:
    @given(mf=random_models(), share=st.booleans())
    @settings(max_examples=20, deadline=None)
    def test_rtl_cycles_and_outputs_match(self, mf, share):
        module, shape, factor = mf
        d = pipeline.compile_model(module, [shape], factor=factor,
                                   share=share)
        x = np.random.default_rng(0).normal(size=shape).astype(np.float32)
        rtl_outs, rtl_stats = d.simulate_rtl({"arg0": x})
        sim_outs, sim_stats = d.simulate({"arg0": x})
        # the netlist's static controller measures the closed form exactly
        assert rtl_stats.cycles == d.estimate.cycles
        assert rtl_stats.cycles == sim_stats.cycles
        # and routes the very same bits
        for r, s in zip(rtl_outs, sim_outs):
            np.testing.assert_allclose(r, s, rtol=0, atol=0)
        oracle = d.run_oracle({"arg0": x})
        for r, o in zip(rtl_outs, oracle):
            np.testing.assert_allclose(r, o, rtol=1e-4, atol=1e-4)

    @given(mf=random_models())
    @settings(max_examples=5, deadline=None)
    def test_emitted_verilog_is_deterministic_and_clean(self, mf):
        module, shape, factor = mf
        d = pipeline.compile_model(module, [shape], factor=factor)
        text = d.emit_verilog()
        assert text == d.emit_verilog()
        assert verilog.lint(text) == []
