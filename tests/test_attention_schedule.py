"""The static flash schedule (perf iter 3) vs a naive reference, plus
chunked cross-entropy (perf iter 2) vs full-logit CE."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import get_config
from repro.models import params as MP
from repro.models import transformer as TF
from repro.models.attention import chunked_attention


def _naive(q, k, v, causal, window, scale, q_offset=0):
    b, h, sq, dh = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    g = h // hkv
    kr = jnp.repeat(k, g, axis=1)
    vr = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) * scale
    qpos = q_offset + np.arange(sq)
    kpos = np.arange(sk)
    mask = np.ones((sq, sk), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window:
        mask &= (qpos[:, None] - kpos[None, :]) < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      vr.astype(jnp.float32)).astype(q.dtype)


CASES = [
    # (sq, sk, q_chunk, kv_chunk, causal, window, q_offset)
    (64, 64, 16, 16, True, 0, 0),
    (64, 64, 16, 16, False, 0, 0),
    (64, 64, 16, 16, True, 24, 0),      # window smaller than seq
    (64, 64, 32, 16, True, 16, 0),      # window < q_chunk
    (48, 48, 16, 16, True, 0, 0),
    (40, 40, 16, 16, True, 0, 0),       # ragged -> padded kv chunk
    (8, 72, 8, 16, True, 0, 64),        # continuation with q_offset
    (64, 64, 64, 64, True, 0, 0),       # single chunk
    (64, 64, 16, 16, True, 100, 0),     # window > seq (no-op)
    (64, 64, 16, 32, True, 40, 0),
    (96, 96, 32, 32, True, 32, 0),      # window == chunk
    (64, 64, 16, 16, False, 24, 0),     # window without causal
]


class TestStaticFlashSchedule:
    @pytest.mark.parametrize("sq,sk,qc,kc,causal,window,qo", CASES)
    def test_matches_naive(self, sq, sk, qc, kc, causal, window, qo):
        rng = np.random.default_rng(sq * 7 + window)
        q = jnp.asarray(rng.normal(size=(2, 4, sq, 8)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(2, 2, sk, 8)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(2, 2, sk, 8)), jnp.float32)
        out = chunked_attention(q, k, v, causal=causal, window=window,
                                scale=0.3, q_chunk=qc, kv_chunk=kc,
                                q_offset=qo)
        ref = _naive(q, k, v, causal, window, 0.3, qo)
        np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)

    def test_gradients_match_naive(self):
        rng = np.random.default_rng(3)
        q = jnp.asarray(rng.normal(size=(1, 2, 32, 8)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(1, 2, 32, 8)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(1, 2, 32, 8)), jnp.float32)

        def f_chunked(q):
            return jnp.sum(chunked_attention(q, k, v, causal=True, scale=0.3,
                                             q_chunk=8, kv_chunk=8) ** 2)

        def f_naive(q):
            return jnp.sum(_naive(q, k, v, True, 0, 0.3) ** 2)

        g1 = jax.grad(f_chunked)(q)
        g2 = jax.grad(f_naive)(q)
        np.testing.assert_allclose(g1, g2, rtol=1e-4, atol=1e-4)

    def test_dead_chunks_not_lowered(self):
        """Causal scheduling lowers strictly fewer dot FLOPs than full."""
        from repro.launch import hlo_analysis as H
        rng = np.random.default_rng(0)
        q = jnp.asarray(rng.normal(size=(1, 2, 64, 8)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(1, 2, 64, 8)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(1, 2, 64, 8)), jnp.float32)

        def run(causal):
            fn = lambda q, k, v: chunked_attention(
                q, k, v, causal=causal, scale=0.3, q_chunk=16, kv_chunk=16)
            text = jax.jit(fn).lower(q, k, v).compile().as_text()
            return H.analyze(text).flops

        assert run(True) < 0.75 * run(False)


class TestChunkedCE:
    def test_matches_full_ce(self):
        cfg = get_config("qwen2-0.5b").reduced()
        prm = MP.init_params(cfg, seed=0)
        rng = np.random.default_rng(0)
        tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 32)),
                             jnp.int32)
        x, _ = TF.forward_hidden(cfg, prm, tokens)
        chunked = TF.chunked_ce(cfg, prm, x, tokens, chunk=8)
        # full-logit reference
        logits, _ = TF.forward(cfg, prm, tokens)
        lp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), -1)
        nll = -jnp.take_along_axis(lp, tokens[:, 1:][..., None], -1)[..., 0]
        np.testing.assert_allclose(float(chunked), float(nll.mean()),
                                   rtol=1e-5)

    def test_mask_respected(self):
        cfg = get_config("qwen2-0.5b").reduced()
        prm = MP.init_params(cfg, seed=0)
        rng = np.random.default_rng(1)
        tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)),
                             jnp.int32)
        x, _ = TF.forward_hidden(cfg, prm, tokens)
        mask = jnp.ones((2, 16)).at[:, 8:].set(0.0)
        l_masked = TF.chunked_ce(cfg, prm, x, tokens, mask=mask, chunk=8)
        l_full = TF.chunked_ce(cfg, prm, x, tokens, chunk=8)
        assert not np.isclose(float(l_masked), float(l_full))
