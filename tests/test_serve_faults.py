"""Chaos tests for the serving resilience layer.

Covers the four pillars end to end: seeded fault injection
(``repro.launch.faults``), finite-guard detection + slot quarantine +
retry/backoff, deadlines + admission control
(``repro.launch.resilience``), and the determinism contract — same seed +
same FaultPlan means byte-identical ``--stable`` span streams.  Includes
the negative control showing an injected corruption *without* the guard
silently poisons downstream tokens (the guard is load-bearing), and the
TTFT-sentinel regression test (requests that die before their first token
must never reach the TTFT histogram).
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.launch import faults as FLT
from repro.launch import resilience as RES
from repro.launch.serve import Engine, Request, replay
from repro.models import decode, get_config
from repro.models import params as MP
from repro.obs import MetricsRegistry, SpanTracer, spans as SP, traffic

SEED = 0


def _arrivals(cfg, trace, seed=SEED):
    rng = np.random.default_rng(seed + 1)
    return [(t.arrival_step,
             Request(t.rid,
                     rng.integers(1, cfg.vocab_size,
                                  size=t.prompt_len).astype(np.int32),
                     t.gen_len))
            for t in trace]


def _run(arch="qwen2-0.5b", slots=2, requests=6, mean=0.5,
         prompt_lens=(3, 5), gen_lens=(3, 6), max_len=None,
         plan=None, res=None, instrument=True):
    cfg = get_config(arch).reduced()
    params = MP.init_params(cfg, seed=SEED)
    trace = traffic.synth_trace(SEED, requests, mean, prompt_lens, gen_lens)
    if max_len is None:
        max_len = 4 * (traffic.total_tokens(trace)
                       + max(t.prompt_len + t.gen_len for t in trace)) + 64
    reg = MetricsRegistry() if instrument else None
    tr = SpanTracer() if instrument else None
    eng = Engine(cfg, params, slots, max_len, metrics=reg, spans=tr,
                 faults=plan, resilience=res)
    replay(eng, _arrivals(cfg, trace))
    return eng, reg, tr


def _tokens_by_rid(eng):
    return {r.rid: list(r.out) for r in eng.done}


# -- fault plans -------------------------------------------------------------


def test_fault_plan_generate_deterministic_and_roundtrip(tmp_path):
    a = FLT.FaultPlan.generate(7, 200, 0.1, 4)
    b = FLT.FaultPlan.generate(7, 200, 0.1, 4)
    assert a.specs == b.specs
    assert len(a) > 0
    assert sum(a.counts().values()) == len(a)
    for s in a.specs:
        assert 0 <= s.step < 200
        assert s.kind in FLT.KINDS
        if s.kind in FLT.SLOT_KINDS:
            assert 0 <= s.slot < 4
    # step index lookups agree with the flat spec list
    flat = [s for step in range(200) for s in a.at(step)]
    assert flat == list(a.specs)
    p = tmp_path / "plan.json"
    a.save(str(p))
    back = FLT.FaultPlan.load(str(p))
    assert back.specs == a.specs
    assert back.meta["seed"] == 7
    # a different seed draws a different campaign
    assert FLT.FaultPlan.generate(8, 200, 0.1, 4).specs != a.specs


def test_fault_spec_validation():
    with pytest.raises(ValueError):
        FLT.FaultSpec(0, "meteor_strike")
    with pytest.raises(ValueError):
        FLT.FaultSpec(0, FLT.NAN_LOGITS)  # slot kind needs a slot
    with pytest.raises(ValueError):
        FLT.FaultPlan.generate(0, 10, 0.5, 2, kinds=("bogus",))


def test_backoff_deterministic_and_capped():
    cfg = RES.ResilienceConfig(backoff_base=2, backoff_cap=16,
                               backoff_jitter=3, seed=5)
    seq = [RES.backoff_ticks(cfg, rid=9, attempt=a) for a in range(1, 8)]
    assert seq == [RES.backoff_ticks(cfg, 9, a) for a in range(1, 8)]
    for a, d in enumerate(seq, start=1):
        base = min(16, 2 * 2 ** (a - 1))
        assert base <= d <= base + 3
    # jitter distinguishes requests; zero jitter removes it
    nojit = RES.ResilienceConfig(backoff_base=2, backoff_cap=16,
                                 backoff_jitter=0)
    assert RES.backoff_ticks(nojit, 1, 3) == RES.backoff_ticks(nojit, 2, 3) \
        == 8


# -- cache slot surgery ------------------------------------------------------


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "rwkv6-7b"])
def test_cache_slot_reset_and_corrupt(arch):
    import jax

    cfg = get_config(arch).reduced()
    params = MP.init_params(cfg, seed=SEED)
    cache = decode.init_cache(cfg, params, 3, 8)
    # write recognizable values everywhere, then poison slot 1 only
    cache = jax.tree.map(lambda a: jnp.ones_like(a), cache)
    poisoned = decode.corrupt_cache_slot(cfg, cache, 1)
    axes = decode.cache_batch_axes(cfg)
    flat_p, flat_ax = jax.tree.leaves(poisoned), jax.tree.leaves(axes)
    assert len(flat_p) == len(flat_ax)
    for leaf, ax in zip(flat_p, flat_ax):
        assert leaf.shape[ax] == 3
        rows = jnp.moveaxis(leaf, ax, 0)
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            assert bool(jnp.isnan(rows[1]).all())
        assert bool(jnp.isfinite(rows[0].astype(jnp.float32)).all())
        assert bool(jnp.isfinite(rows[2].astype(jnp.float32)).all())
    cleaned = decode.reset_cache_slot(cfg, poisoned, 1)
    for leaf, ax in zip(jax.tree.leaves(cleaned), flat_ax):
        rows = jnp.moveaxis(leaf, ax, 0)
        assert bool((rows[1] == 0).all())
        assert bool((rows[0].astype(jnp.float32) == 1).all())
        assert bool((rows[2].astype(jnp.float32) == 1).all())


# -- chaos determinism (satellite: same seed + plan => byte-identical) -------


def _mixed_plan(steps=96, rate=0.15):
    return FLT.FaultPlan.generate(11, steps, rate, 2,
                                  kinds=(FLT.NAN_LOGITS, FLT.EXCEPTION,
                                         FLT.LATENCY_SPIKE,
                                         FLT.CACHE_CORRUPT),
                                  spike_ticks=3, spike_us=200)


def test_chaos_runs_are_byte_identical():
    res = RES.ResilienceConfig(deadline_ticks=300, seed=SEED)
    eng_a, _, tr_a = _run(plan=_mixed_plan(), res=res)
    eng_b, _, tr_b = _run(plan=_mixed_plan(), res=res)
    a = SP.to_jsonl(tr_a.events, stable=True)
    b = SP.to_jsonl(tr_b.events, stable=True)
    assert a == b and a
    assert SP.validate(tr_a.events, slots=2, engine_steps=eng_a.steps) == []
    # zero lost requests: every offered request terminated with a reason
    assert len(eng_a.done) == 6
    assert all(r.reason == SP.FINISHED
               or r.reason.startswith(SP.TRUNCATED_PREFIX)
               for r in eng_a.done)
    assert eng_a.faults_injected == eng_b.faults_injected > 0
    assert eng_a.faults_detected == eng_b.faults_detected


def test_negative_control_corruption_without_guard():
    """An injected cache corruption with NO resilience silently poisons the
    victim's downstream tokens while leaving the other slot untouched —
    proof the finite-guard is load-bearing, not decorative."""
    plan = FLT.FaultPlan([FLT.FaultSpec(4, FLT.CACHE_CORRUPT, slot=0)])
    clean, _, _ = _run(requests=2, mean=0.0, prompt_lens=(3,),
                       gen_lens=(8,))
    dirty, _, _ = _run(requests=2, mean=0.0, prompt_lens=(3,),
                       gen_lens=(8,), plan=plan)  # faults, no resilience
    ct, dt = _tokens_by_rid(clean), _tokens_by_rid(dirty)
    assert dt[0] != ct[0], "corruption did not reach the victim's tokens"
    assert dt[1] == ct[1], "corruption leaked across batch slots"
    # the engine is failure-blind here: the victim still "finishes"
    assert all(r.reason == SP.FINISHED for r in dirty.done)


def test_guard_detects_quarantines_and_retries():
    """Same corruption with resilience on: detected, quarantined, retried,
    and the victim finishes on attempt 2 with a valid attempt-split span."""
    plan = FLT.FaultPlan([FLT.FaultSpec(4, FLT.CACHE_CORRUPT, slot=0)])
    res = RES.ResilienceConfig(seed=SEED)
    eng, reg, tr = _run(requests=2, mean=0.0, prompt_lens=(3,),
                        gen_lens=(8,), plan=plan, res=res)
    assert eng.faults_detected >= 1
    assert eng.retries >= 1
    assert int(reg.get("serve_retries_total").value) == eng.retries
    assert int(reg.get("serve_faults_detected_total").value) \
        == eng.faults_detected
    assert SP.validate(tr.events, slots=2, engine_steps=eng.steps) == []
    summaries = SP.summarize(tr.events)
    victim = summaries[0]
    assert victim.attempts == 2
    assert victim.reason == SP.FINISHED
    assert [e.kind for e in tr.events if e.kind == SP.REQ_RETRY] \
        == [SP.REQ_RETRY] * eng.retries
    # bystander untouched: single attempt, finished
    assert summaries[1].attempts == 1
    assert summaries[1].reason == SP.FINISHED
    assert all(r.reason == SP.FINISHED for r in eng.done)


def test_retry_exhaustion_reasons():
    # nan on slot 0 at every step: whoever holds slot 0 can never progress
    plan = FLT.FaultPlan([FLT.FaultSpec(s, FLT.NAN_LOGITS, slot=0)
                          for s in range(400)])
    res = RES.ResilienceConfig(max_attempts=2, backoff_base=1,
                               backoff_jitter=0, seed=SEED)
    eng, reg, tr = _run(requests=2, slots=1, mean=0.0, prompt_lens=(3,),
                        gen_lens=(4,), plan=plan, res=res)
    assert SP.validate(tr.events, slots=1, engine_steps=eng.steps) == []
    reasons = sorted(r.reason for r in eng.done)
    assert reasons == [SP.TRUNCATED_PREFIX + RES.REASON_RETRY_EXHAUSTED] * 2
    assert int(reg.get(
        "serve_requests_truncated_quarantine_retry_exhausted_total").value) \
        == 2
    # retries disabled entirely -> the fault itself is the reason
    res1 = RES.ResilienceConfig(max_attempts=1, seed=SEED)
    eng1, reg1, _ = _run(requests=2, slots=1, mean=0.0, prompt_lens=(3,),
                         gen_lens=(4,), plan=plan, res=res1)
    assert all(r.reason == SP.TRUNCATED_PREFIX + RES.REASON_FAULT
               for r in eng1.done)
    assert int(reg1.get("serve_requests_truncated_fault_total").value) == 2


def test_exception_fault_freezes_the_step():
    plan = FLT.FaultPlan([FLT.FaultSpec(2, FLT.EXCEPTION),
                          FLT.FaultSpec(5, FLT.EXCEPTION)])
    res = RES.ResilienceConfig(seed=SEED)
    eng, reg, tr = _run(requests=2, mean=0.0, prompt_lens=(3,),
                        gen_lens=(6,), plan=plan, res=res)
    assert SP.validate(tr.events, slots=2, engine_steps=eng.steps) == []
    # pos is frozen on aborted steps, so it trails the step counter
    assert eng.pos == eng.steps - 2
    fault_steps = [e for e in tr.events
                   if e.kind == SP.STEP and e.detail == "fault:exception"]
    assert [e.step for e in fault_steps] == [2, 5]
    assert all(e.data[2] == 0 for e in fault_steps)  # no tokens that step
    assert all(r.reason == SP.FINISHED for r in eng.done)
    assert int(reg.get("serve_faults_detected_total").value) == 2
    # without resilience the injected exception is fatal (failure-blind)
    with pytest.raises(FLT.InjectedFault):
        _run(requests=2, mean=0.0, prompt_lens=(3,), gen_lens=(6,),
             plan=plan)


def test_latency_spike_advances_deadline_clock():
    # 1 spike of 40 ticks against a 30-tick deadline: structurally, the
    # in-flight requests blow their deadline on the spike step even though
    # barely any real steps ran
    plan = FLT.FaultPlan([FLT.FaultSpec(4, FLT.LATENCY_SPIKE,
                                        spike_ticks=40, spike_us=0)])
    res = RES.ResilienceConfig(deadline_ticks=30, seed=SEED)
    eng, reg, tr = _run(requests=2, mean=0.0, prompt_lens=(3,),
                        gen_lens=(64,), plan=plan, res=res)
    assert SP.validate(tr.events, slots=2, engine_steps=eng.steps) == []
    assert all(r.reason == SP.TRUNCATED_PREFIX + RES.REASON_DEADLINE
               for r in eng.done)
    assert int(reg.get("serve_requests_truncated_deadline_total").value) == 2
    # and without the spike the same workload meets the deadline budget
    eng2, _, _ = _run(requests=2, mean=0.0, prompt_lens=(3,), gen_lens=(8,),
                      res=res)
    assert all(r.reason == SP.FINISHED for r in eng2.done)


# -- deadlines + admission control -------------------------------------------


def test_completion_deadline_enforced():
    res = RES.ResilienceConfig(deadline_ticks=6, seed=SEED)
    eng, reg, tr = _run(requests=4, slots=1, mean=0.0, prompt_lens=(3,),
                        gen_lens=(8,), res=res)
    assert SP.validate(tr.events, slots=1, engine_steps=eng.steps) == []
    assert len(eng.done) == 4
    reasons = {r.rid: r.reason for r in eng.done}
    assert any(v == SP.TRUNCATED_PREFIX + RES.REASON_DEADLINE
               for v in reasons.values())
    assert int(reg.get("serve_requests_truncated_deadline_total").value) \
        == sum(v == SP.TRUNCATED_PREFIX + RES.REASON_DEADLINE
               for v in reasons.values())


def test_ttft_deadline_and_sentinel_regression():
    """Requests killed before emitting a token must (a) carry the deadline
    reason and (b) never reach the TTFT histogram — the first_token_us=-1
    sentinel regression."""
    res = RES.ResilienceConfig(ttft_deadline_ticks=5, seed=SEED)
    eng, reg, tr = _run(requests=4, slots=1, mean=0.0, prompt_lens=(4,),
                        gen_lens=(6,), res=res)
    assert SP.validate(tr.events, slots=1, engine_steps=eng.steps) == []
    no_token = [r for r in eng.done if not r.out]
    with_token = [r for r in eng.done if r.out]
    assert no_token, "expected some requests to miss the TTFT deadline"
    assert all(r.reason == SP.TRUNCATED_PREFIX + RES.REASON_DEADLINE
               for r in no_token)
    ttft = reg.get("serve_ttft_us")
    assert ttft.count == len(with_token)
    assert ttft.quantile(0.0) >= 0  # no -1 sentinel ever observed
    # decode histogram likewise only sees requests with >= 2 tokens
    assert reg.get("serve_decode_token_us").count \
        == sum(len(r.out) >= 2 for r in with_token)


def test_shed_policy_reject_newest_with_client_retry():
    res = RES.ResilienceConfig(queue_cap=1, seed=SEED)
    eng, reg, tr = _run(requests=8, slots=1, mean=0.0, prompt_lens=(3,),
                        gen_lens=(8,), res=res)
    assert SP.validate(tr.events, slots=1, engine_steps=eng.steps) == []
    assert len(eng.done) == 8, "zero-loss: every offered request terminates"
    assert int(reg.get("serve_queue_rejections_total").value) > 0
    shed = [r for r in eng.done
            if r.reason == SP.TRUNCATED_PREFIX + RES.REASON_SHED]
    fin = [r for r in eng.done if r.reason == SP.FINISHED]
    assert shed and fin
    assert int(reg.get("serve_requests_truncated_shed_total").value) \
        == len(shed)


def test_shed_policy_shed_oldest():
    res = RES.ResilienceConfig(queue_cap=1,
                               shed_policy=RES.POLICY_SHED_OLDEST,
                               seed=SEED)
    eng, reg, tr = _run(requests=8, slots=1, mean=0.0, prompt_lens=(3,),
                        gen_lens=(8,), res=res)
    assert SP.validate(tr.events, slots=1, engine_steps=eng.steps) == []
    assert len(eng.done) == 8
    # evictions happen queue-side: no retryable rejections, straight sheds
    assert int(reg.get("serve_queue_rejections_total").value) == 0
    assert any(r.reason == SP.TRUNCATED_PREFIX + RES.REASON_SHED
               for r in eng.done)


def test_shed_policy_token_budget():
    res = RES.ResilienceConfig(shed_policy=RES.POLICY_TOKEN_BUDGET,
                               token_budget=12, seed=SEED)
    eng, reg, tr = _run(requests=8, slots=1, mean=0.0, prompt_lens=(3,),
                        gen_lens=(8,), res=res)
    assert SP.validate(tr.events, slots=1, engine_steps=eng.steps) == []
    assert len(eng.done) == 8
    assert int(reg.get("serve_queue_rejections_total").value) > 0
    assert all(r.reason == SP.FINISHED
               or r.reason.startswith(SP.TRUNCATED_PREFIX)
               for r in eng.done)


def test_resilience_off_engine_unchanged():
    """A resilience-enabled zero-fault run completes the identical token
    streams as the plain engine — the machinery is inert when idle."""
    plain, _, tr_plain = _run()
    armed, _, tr_armed = _run(res=RES.ResilienceConfig(seed=SEED))
    assert _tokens_by_rid(plain) == _tokens_by_rid(armed)
    assert SP.to_jsonl(tr_plain.events, stable=True) \
        == SP.to_jsonl(tr_armed.events, stable=True)


# -- health state machine ----------------------------------------------------


def test_health_degrades_and_recovers():
    plan = FLT.FaultPlan([FLT.FaultSpec(4, FLT.NAN_LOGITS, slot=0)])
    res = RES.ResilienceConfig(recovery_ticks=3, seed=SEED)
    eng, reg, tr = _run(requests=2, mean=0.0, prompt_lens=(3,),
                        gen_lens=(12,), plan=plan, res=res)
    health = [(e.step, e.detail) for e in tr.events if e.kind == SP.HEALTH]
    assert [d for _, d in health] == [RES.DEGRADED, RES.HEALTHY]
    assert health[0][0] == 4
    assert health[1][0] >= health[0][0] + 3
    assert eng.health == RES.HEALTHY
    assert eng.health_ticks[RES.DEGRADED] >= 3
    assert int(reg.get("serve_engine_health").value) == 0


def test_health_drains_and_sheds_new_work():
    plan = FLT.FaultPlan([FLT.FaultSpec(s, FLT.NAN_LOGITS, slot=0)
                          for s in (4, 5)])
    res = RES.ResilienceConfig(drain_faults=2, drain_window=16,
                               backoff_base=1, backoff_jitter=0, seed=SEED)
    eng, reg, tr = _run(requests=6, slots=2, mean=3.0, prompt_lens=(3,),
                        gen_lens=(6,), plan=plan, res=res)
    assert SP.validate(tr.events, slots=2, engine_steps=eng.steps) == []
    assert eng.health == RES.DRAINING
    assert len(eng.done) == 6
    shed = [r for r in eng.done
            if r.reason == SP.TRUNCATED_PREFIX + RES.REASON_SHED]
    assert shed, "late arrivals should be shed while draining"
    assert int(reg.get("serve_engine_health").value) \
        == RES.HEALTH_CODE[RES.DRAINING]


# -- validate: attempt-aware invariants --------------------------------------


def test_validate_attempt_splitting():
    ev = SP.SpanEvent
    ok = [
        ev(0, SP.REQ_ENQUEUE, SP.req_prov(0), 0, 0),
        ev(1, SP.REQ_ADMIT, SP.req_prov(0), 0, 0, 0),
        ev(2, SP.REQ_PREFILL, SP.req_prov(0), 0, 0, 0),
        ev(3, SP.REQ_RETRY, SP.req_prov(0), 2, 0, 0, "quarantine:nonfinite",
           data=(1, 2)),
        ev(4, SP.REQ_ADMIT, SP.req_prov(0), 5, 0, 1),
        ev(5, SP.REQ_PREFILL, SP.req_prov(0), 5, 0, 1),
        ev(6, SP.REQ_FIRST_TOKEN, SP.req_prov(0), 7, 0, 1),
        ev(7, SP.REQ_COMPLETE, SP.req_prov(0), 9, 0, 1, SP.FINISHED,
           data=(3,)),
    ]
    assert SP.validate(ok) == []
    assert SP.summarize(ok)[0].attempts == 2
    # re-enqueue inside a retry attempt is a violation
    bad = ok[:4] + [ev(4, SP.REQ_ENQUEUE, SP.req_prov(0), 5, 0)] + ok[4:]
    assert any("enqueue" in p for p in SP.validate(bad))
    # events after the complete are a violation
    bad = ok + [ev(8, SP.REQ_FIRST_TOKEN, SP.req_prov(0), 10, 0, 1)]
    assert any("after complete" in p for p in SP.validate(bad))
    # phases regressing *within* one attempt are still caught
    bad = [ok[0], ok[1],
           ev(2, SP.REQ_FIRST_TOKEN, SP.req_prov(0), 1, 0, 0),
           ev(3, SP.REQ_PREFILL, SP.req_prov(0), 1, 0, 0),
           ok[7]]
    assert any("out of order" in p for p in SP.validate(bad))
    # health events are part of the schema, not unknown kinds
    stream = ok + [ev(9, SP.HEALTH, ("engine",), 2, detail=RES.DEGRADED,
                      data=(1,))]
    assert SP.validate(stream) == []


def test_validate_occupancy_intervals_with_retry():
    ev = SP.SpanEvent
    # rid0 occupies slot 0 for steps 0-1, is quarantined on step 1, then
    # re-admitted on step 3; the step occupancy snapshots must match
    stream = [
        ev(0, SP.REQ_ENQUEUE, SP.req_prov(0), 0, 0),
        ev(1, SP.REQ_ADMIT, SP.req_prov(0), 0, 0, 0),
        ev(2, SP.STEP, SP.step_prov(0), 0, data=(1, 0, 0, 1)),
        ev(3, SP.REQ_RETRY, SP.req_prov(0), 1, 0, 0, "quarantine:nonfinite",
           data=(1, 1)),
        ev(4, SP.STEP, SP.step_prov(1), 1, data=(1, 0, 0, 0)),
        ev(5, SP.STEP, SP.step_prov(2), 2, data=(0, 0, 0, 0)),
        ev(6, SP.REQ_ADMIT, SP.req_prov(0), 3, 0, 0),
        ev(7, SP.STEP, SP.step_prov(3), 3, data=(1, 0, 0, 1)),
        ev(8, SP.REQ_COMPLETE, SP.req_prov(0), 4, 0, 0, SP.FINISHED,
           data=(1,)),
        ev(9, SP.STEP, SP.step_prov(4), 4, data=(1, 0, 1, 0)),
    ]
    assert SP.validate(stream, slots=1, engine_steps=5) == []
    # claiming occupancy on the gap step is flagged
    wrong = list(stream)
    wrong[5] = ev(5, SP.STEP, SP.step_prov(2), 2, data=(1, 0, 0, 0))
    assert any("in flight" in p for p in SP.validate(wrong, slots=1,
                                                     engine_steps=5))
