"""Tests for the serving metrics registry (``repro.obs.metrics``).

The histogram quantile contract is checked against numpy:

* value-aligned buckets + integer ``q * count`` -> exact match with
  ``numpy.quantile(..., method="inverted_cdf")``;
* arbitrary data on coarse buckets -> within one bucket width of the
  linear-interpolation numpy quantile.
"""
import json
import math

import numpy as np
import pytest

from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               DEFAULT_TIME_BUCKETS_US)


# -- counters / gauges -------------------------------------------------------


def test_counter_semantics():
    c = Counter("reqs")
    assert c.value == 0
    c.inc()
    c.inc(4)
    assert c.value == 5
    with pytest.raises(ValueError):
        c.inc(-1)
    assert c.value == 5


def test_gauge_semantics():
    g = Gauge("depth")
    g.set(7)
    g.inc(2)
    g.dec(3)
    assert g.value == 6
    g.set(-1)           # gauges may go negative
    assert g.value == -1


def test_registry_get_or_create_and_kind_conflict():
    reg = MetricsRegistry()
    c1 = reg.counter("serve_requests_total")
    c2 = reg.counter("serve_requests_total")
    assert c1 is c2
    with pytest.raises(TypeError):
        reg.gauge("serve_requests_total")
    with pytest.raises(TypeError):
        reg.histogram("serve_requests_total")
    with pytest.raises(KeyError):
        reg.get("never_registered")
    assert reg.names() == ["serve_requests_total"]


# -- histogram quantiles -----------------------------------------------------


def test_quantile_exact_on_value_aligned_buckets():
    # observations 1..100, buckets at every integer bound: every distinct
    # value sits exactly on a bucket upper bound, and q*count is an
    # integer for q in {.5, .9, .99} -> the interpolated estimate must
    # equal numpy's inverted_cdf quantile exactly
    data = np.arange(1, 101, dtype=np.float64)
    h = Histogram("t", buckets=list(range(1, 101)))
    for v in data:
        h.observe(v)
    for q in (0.5, 0.9, 0.99):
        exact = float(np.quantile(data, q, method="inverted_cdf"))
        assert h.quantile(q) == pytest.approx(exact, abs=1e-9), q


def test_quantile_within_bucket_width_on_coarse_buckets():
    rng = np.random.default_rng(0)
    data = rng.uniform(50, 9_000, size=500)
    bounds = [100, 200, 500, 1_000, 2_000, 5_000, 10_000]
    h = Histogram("t", buckets=bounds)
    for v in data:
        h.observe(v)
    edges = [float(min(data))] + [float(b) for b in bounds]
    for q in (0.1, 0.5, 0.9, 0.99):
        est = h.quantile(q)
        exact = float(np.quantile(data, q))
        # width of the bucket the estimate landed in
        i = int(np.searchsorted(bounds, est))
        lo = edges[i]
        hi = bounds[i] if i < len(bounds) else float(max(data))
        assert abs(est - exact) < (hi - lo), (q, est, exact)


def test_quantile_edges():
    h = Histogram("t")
    assert math.isnan(h.quantile(0.5))          # empty
    h.observe(150)
    assert h.quantile(0.0) == 150               # single value: clamped
    assert h.quantile(0.5) == 150
    assert h.quantile(1.0) == 150
    with pytest.raises(ValueError):
        h.quantile(1.5)


def test_histogram_observe_le_semantics_and_overflow():
    h = Histogram("t", buckets=[10, 20])
    for v in (10, 20, 21, 5):
        h.observe(v)
    # le semantics: 10 falls in the first bucket, 20 in the second,
    # 21 overflows
    assert h.bucket_counts == [2, 1, 1]
    assert h.count == 4
    assert h.sum == 56
    assert (h.min, h.max) == (5, 21)


def test_histogram_rejects_bad_buckets():
    with pytest.raises(ValueError):
        Histogram("t", buckets=[])
    with pytest.raises(ValueError):
        Histogram("t", buckets=[10, 10])
    with pytest.raises(ValueError):
        Histogram("t", buckets=[20, 10])


def test_default_buckets_are_strictly_increasing():
    assert list(DEFAULT_TIME_BUCKETS_US) == \
        sorted(set(DEFAULT_TIME_BUCKETS_US))


# -- exporters ---------------------------------------------------------------


def test_prometheus_golden():
    reg = MetricsRegistry()
    reg.counter("serve_requests_total", "requests accepted").inc(3)
    reg.gauge("serve_queue_depth").set(2)
    h = reg.histogram("serve_step_latency_us", buckets=[100, 1000])
    h.observe(50)
    h.observe(150)
    h.observe(5000)
    assert reg.to_prometheus() == (
        "# TYPE serve_queue_depth gauge\n"
        "serve_queue_depth 2\n"
        "# HELP serve_requests_total requests accepted\n"
        "# TYPE serve_requests_total counter\n"
        "serve_requests_total 3\n"
        "# TYPE serve_step_latency_us histogram\n"
        'serve_step_latency_us_bucket{le="100"} 1\n'
        'serve_step_latency_us_bucket{le="1000"} 2\n'
        'serve_step_latency_us_bucket{le="+Inf"} 3\n'
        "serve_step_latency_us_sum 5200\n"
        "serve_step_latency_us_count 3\n")


def test_json_export_round_trips_and_is_deterministic():
    reg = MetricsRegistry()
    reg.counter("b").inc(2)
    reg.gauge("a").set(1.5)
    h = reg.histogram("c", buckets=[10])
    h.observe(4)
    doc = json.loads(reg.dump_json())
    assert doc["schema"] == 1
    assert doc["metrics"]["b"] == {"kind": "counter", "value": 2}
    assert doc["metrics"]["a"] == {"kind": "gauge", "value": 1.5}
    assert doc["metrics"]["c"]["count"] == 1
    assert doc["metrics"]["c"]["p50"] == 4
    assert reg.dump_json() == reg.dump_json()
    # empty registry exports cleanly
    assert MetricsRegistry().to_prometheus() == ""
