"""Property tests (hypothesis) for model-side numerical kernels."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import ref as KREF
from repro.models.ssm_ops import chunked_decay_scan, decay_scan_step


class TestChunkedDecayScan:
    @given(
        s=st.sampled_from([16, 32, 48]),
        chunk=st.sampled_from([4, 8, 16]),
        dk=st.sampled_from([4, 8]),
        scalar=st.booleans(),
        mode=st.sampled_from(["inclusive", "bonus"]),
        seed=st.integers(0, 1000),
    )
    @settings(max_examples=30, deadline=None)
    def test_matches_stepwise_reference(self, s, chunk, dk, scalar, mode,
                                        seed):
        if s % chunk:
            chunk = s
        rng = np.random.default_rng(seed)
        b, h, dv = 1, 2, 4
        q = jnp.asarray(rng.normal(size=(b, h, s, dk)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(b, h, s, dk)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(b, h, s, dv)), jnp.float32)
        w_full = jnp.asarray(-np.abs(rng.normal(size=(b, h, s, dk))) * 0.4,
                             jnp.float32)
        u = jnp.asarray(rng.normal(size=(h, dk)), jnp.float32) \
            if mode == "bonus" else None
        if scalar and mode == "inclusive":
            w = w_full[..., 0]                      # (b,h,s) scalar decay
            w_ref = jnp.broadcast_to(w[..., None], (b, h, s, dk))
        else:
            w = w_full
            w_ref = w_full
        out = chunked_decay_scan(q, k, v, w, u=u, chunk=chunk,
                                 diag_mode=mode)
        expect = KREF.ssm_scan_ref(q, k, v, w_ref, u=u, diag_mode=mode)
        np.testing.assert_allclose(out, expect, rtol=2e-4, atol=2e-4)

    @given(seed=st.integers(0, 500), steps=st.integers(2, 12))
    @settings(max_examples=25, deadline=None)
    def test_decode_step_composes(self, seed, steps):
        """Repeated decay_scan_step == chunked scan over the sequence."""
        rng = np.random.default_rng(seed)
        b, h, dk, dv = 1, 2, 4, 4
        q = jnp.asarray(rng.normal(size=(b, h, steps, dk)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(b, h, steps, dk)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(b, h, steps, dv)), jnp.float32)
        w = jnp.asarray(-np.abs(rng.normal(size=(b, h, steps, dk))) * 0.3,
                        jnp.float32)
        full = KREF.ssm_scan_ref(q, k, v, w)
        hstate = jnp.zeros((b, h, dk, dv), jnp.float32)
        for t in range(steps):
            o, hstate = decay_scan_step(hstate, q[:, :, t], k[:, :, t],
                                        v[:, :, t], w[:, :, t])
            np.testing.assert_allclose(o, full[:, :, t], rtol=2e-4,
                                       atol=2e-4)

    def test_state_handoff_equals_monolithic(self):
        """Scanning two halves with return_state/h0 == one full scan."""
        rng = np.random.default_rng(0)
        b, h, s, dk, dv = 1, 2, 32, 4, 4
        q = jnp.asarray(rng.normal(size=(b, h, s, dk)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(b, h, s, dk)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(b, h, s, dv)), jnp.float32)
        w = jnp.asarray(-np.abs(rng.normal(size=(b, h, s, dk))) * 0.2,
                        jnp.float32)
        full = chunked_decay_scan(q, k, v, w, chunk=8)
        o1, hmid = chunked_decay_scan(q[:, :, :16], k[:, :, :16],
                                      v[:, :, :16], w[:, :, :16], chunk=8,
                                      return_state=True)
        o2 = chunked_decay_scan(q[:, :, 16:], k[:, :, 16:], v[:, :, 16:],
                                w[:, :, 16:], chunk=8, h0=hmid)
        np.testing.assert_allclose(jnp.concatenate([o1, o2], axis=2), full,
                                   rtol=1e-5, atol=1e-5)
