"""Span-lifecycle tests for the instrumented serving engine.

Drives ``repro.launch.serve.Engine`` in-process over a seeded synthetic
trace and checks the invariants ``repro.obs.spans.validate`` promises:
every admitted request completes (or is truncated with a reason), phase
timestamps are monotone, the step-event count equals the engine's step
count, and two same-seed runs serialize byte-identically in the span
exporter's stable mode.
"""
import numpy as np
import pytest

from repro.launch.serve import Engine, Request, replay
from repro.models import decode, get_config
from repro.models import params as MP
from repro.obs import MetricsRegistry, SpanTracer, spans as SP, traffic

SEED = 0


def _arrivals(cfg, trace, seed=SEED):
    rng = np.random.default_rng(seed + 1)
    return [(t.arrival_step,
             Request(t.rid,
                     rng.integers(1, cfg.vocab_size,
                                  size=t.prompt_len).astype(np.int32),
                     t.gen_len))
            for t in trace]


def _run(arch="qwen2-0.5b", slots=2, requests=6, mean=0.5,
         prompt_lens=(3, 5), gen_lens=(3, 6), max_len=None):
    cfg = get_config(arch).reduced()
    params = MP.init_params(cfg, seed=SEED)
    trace = traffic.synth_trace(SEED, requests, mean, prompt_lens, gen_lens)
    if max_len is None:
        max_len = traffic.total_tokens(trace) \
            + max(t.prompt_len + t.gen_len for t in trace) + 8
    reg = MetricsRegistry()
    tr = SpanTracer()
    eng = Engine(cfg, params, slots, max_len, metrics=reg, spans=tr)
    replay(eng, _arrivals(cfg, trace))
    return eng, reg, tr


@pytest.fixture(scope="module")
def qwen_run():
    return _run()


def test_lifecycle_invariants_hold(qwen_run):
    eng, reg, tr = qwen_run
    assert SP.validate(tr.events, slots=2, engine_steps=eng.steps) == []


def test_every_request_completes(qwen_run):
    eng, reg, tr = qwen_run
    summaries = SP.summarize(tr.events)
    assert sorted(summaries) == list(range(6))
    assert all(s.reason == SP.FINISHED for s in summaries.values())
    assert int(reg.get("serve_requests_completed_total").value) == 6
    assert int(reg.get("serve_requests_truncated_total").value) == 0
    # phase chain complete and monotone for every finished request
    for s in summaries.values():
        chain = [s.enqueue_us, s.admit_us, s.prefill_us,
                 s.first_token_us, s.complete_us]
        assert all(v >= 0 for v in chain), s
        assert chain == sorted(chain), s
        assert s.ttft_us >= 0


def test_step_events_match_engine_steps(qwen_run):
    eng, reg, tr = qwen_run
    step_events = [e for e in tr.events if e.kind == SP.STEP]
    assert len(step_events) == eng.steps
    assert int(reg.get("serve_engine_steps_total").value) == eng.steps
    assert [e.step for e in step_events] == list(range(eng.steps))


def test_token_accounting_matches_metrics(qwen_run):
    eng, reg, tr = qwen_run
    gen_from_engine = sum(len(r.out) for r in eng.done)
    gen_from_steps = sum(e.data[2] for e in tr.events if e.kind == SP.STEP)
    gen_from_spans = sum(s.tokens for s in SP.summarize(tr.events).values())
    assert gen_from_engine == gen_from_steps == gen_from_spans \
        == int(reg.get("serve_tokens_generated_total").value)
    pre_from_steps = sum(e.data[3] for e in tr.events if e.kind == SP.STEP)
    assert pre_from_steps \
        == int(reg.get("serve_tokens_prefill_total").value) \
        == sum(r.fed for r in eng.done)
    util = SP.slot_utilization(tr.events, 2)
    assert 0.0 < util <= 1.0


def test_latency_histograms_populated(qwen_run):
    eng, reg, tr = qwen_run
    ttft = reg.get("serve_ttft_us")
    assert ttft.count == 6
    assert ttft.quantile(0.5) >= 0
    step_h = reg.get("serve_step_latency_us")
    assert step_h.count == eng.steps
    # every request generated >= 2 tokens, so decode latency is defined
    assert reg.get("serve_decode_token_us").count == 6


def test_truncation_reason_and_counter():
    # max_len too small for the workload: the engine must truncate with a
    # reason rather than lose requests, and the spans must stay valid
    eng, reg, tr = _run(requests=4, mean=0.0, prompt_lens=(4,),
                        gen_lens=(32,), max_len=12)
    assert SP.validate(tr.events, slots=2, engine_steps=eng.steps) == []
    assert len(eng.done) == 4
    truncated = [r for r in eng.done
                 if r.reason == SP.TRUNCATED_PREFIX + "max_len"]
    assert truncated, "expected at least one truncated request"
    assert int(reg.get("serve_requests_truncated_total").value) \
        == len(truncated)
    summaries = SP.summarize(tr.events)
    assert all(s.reason == SP.FINISHED
               or s.reason.startswith(SP.TRUNCATED_PREFIX)
               for s in summaries.values())


def test_per_reason_truncation_counters():
    # the aggregate truncation counter decomposes exactly into the
    # per-reason companions; max_len truncations land on their own counter
    eng, reg, tr = _run(requests=4, mean=0.0, prompt_lens=(4,),
                        gen_lens=(32,), max_len=12)
    total = int(reg.get("serve_requests_truncated_total").value)
    assert total > 0
    by_reason = {reason: int(reg.get(
        f"serve_requests_truncated_{reason}_total").value)
        for reason in ("max_len", "deadline", "shed", "fault",
                       "quarantine_retry_exhausted")}
    assert by_reason["max_len"] == total
    assert sum(by_reason.values()) == total


def test_ttft_sentinel_never_reaches_histogram():
    # requests truncated before emitting any token must not contribute a
    # TTFT sample (the first_token_us = -1 sentinel regression): max_len=6
    # kills the second wave mid-prefill with zero generated tokens
    eng, reg, tr = _run(requests=4, mean=0.0, prompt_lens=(4,),
                        gen_lens=(32,), max_len=6)
    tokenless = [r for r in eng.done if not r.out]
    assert tokenless, "expected requests truncated before their first token"
    ttft = reg.get("serve_ttft_us")
    assert ttft.count == sum(bool(r.out) for r in eng.done)
    assert ttft.quantile(0.0) >= 0
    assert SP.validate(tr.events, slots=2, engine_steps=eng.steps) == []


def test_same_seed_runs_serialize_identically():
    _, _, tr_a = _run(requests=4)
    _, _, tr_b = _run(requests=4)
    a = SP.to_jsonl(tr_a.events, stable=True)
    b = SP.to_jsonl(tr_b.events, stable=True)
    assert a == b
    assert a  # non-empty
    # round-trip through the parser preserves the structural fields
    evs = SP.from_jsonl(a)
    assert len(evs) == len(tr_a.events)
    assert [e.kind for e in evs] == [e.kind for e in tr_a.events]
    assert [e.rid for e in evs] == [e.rid for e in tr_a.events]


def test_non_transformer_family_spans():
    eng, reg, tr = _run(arch="rwkv6-7b", requests=3, mean=0.0,
                        prompt_lens=(3,), gen_lens=(4,))
    assert SP.validate(tr.events, slots=2, engine_steps=eng.steps) == []
    assert int(reg.get("serve_requests_completed_total").value) == 3


def test_uninstrumented_engine_emits_nothing():
    cfg = get_config("qwen2-0.5b").reduced()
    params = MP.init_params(cfg, seed=SEED)
    trace = traffic.synth_trace(SEED, 2, 0.0, (3,), (3,))
    eng = Engine(cfg, params, 2, 32)
    replay(eng, _arrivals(cfg, trace))
    assert eng.spans is None and eng._m is None
    assert len(eng.done) == 2


def test_validate_flags_broken_streams():
    ev = SP.SpanEvent
    # enqueue with no complete
    bad = [ev(0, SP.REQ_ENQUEUE, SP.req_prov(0), 0, 0)]
    assert any("complete" in p for p in SP.validate(bad))
    # non-monotone phase timestamps
    bad = [ev(10, SP.REQ_ENQUEUE, SP.req_prov(1), 0, 1),
           ev(5, SP.REQ_COMPLETE, SP.req_prov(1), 1, 1, 0, SP.FINISHED,
              data=(1,))]
    assert any("monotone" in p for p in SP.validate(bad))
    # bad completion reason
    bad = [ev(0, SP.REQ_ENQUEUE, SP.req_prov(2), 0, 2),
           ev(1, SP.REQ_COMPLETE, SP.req_prov(2), 1, 2, 0, "exploded",
              data=(0,))]
    assert any("reason" in p for p in SP.validate(bad))
    # step events not contiguous
    bad = [ev(0, SP.STEP, SP.step_prov(1), 1, data=(0, 0, 0, 0))]
    assert any("contiguous" in p for p in SP.validate(bad))


def test_step_stats_sanity():
    cfg = get_config("qwen2-0.5b").reduced()
    params = MP.init_params(cfg, seed=SEED)
    cache = decode.init_cache(cfg, params, 2, 16)
    st = decode.step_stats(cfg, cache)
    assert st["cache_bytes"] > 0
    assert st["cache_max_len"] == 16
    assert st["approx_flops_per_token"] > 0
