"""Property-based test: the observability differential holds on random
graphs, not just the curated benchmark matrix.

For tiny randomized MLPs across banking factors and both ends of the
scheduling ablation, ``CompiledDesign.profile`` must report zero
mismatches — Calyx-sim stats == RTL-sim stats == both trace aggregates
== the synthesized hardware counter bank == the estimator's analytic
attribution (exact, since these graphs are if-free).
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings

from repro.core import pipeline
from test_property_sim import random_models


class TestCounterEqualityOnRandomGraphs:
    @given(mf=random_models())
    @settings(max_examples=10, deadline=None)
    def test_all_levels_agree(self, mf):
        module, shape, factor = mf
        x = np.random.default_rng(0).normal(size=shape) \
            .astype(np.float32)
        for opt in (0, 2):
            d = pipeline.compile_model(module, [shape], factor=factor,
                                       opt_level=opt)
            prof = d.profile({"arg0": x})
            assert prof.mismatches == []
            assert prof.attribution.exact   # no ifs in these graphs
            assert prof.hw_counters["total"] == prof.cycles
