"""Four-way differential harness for the RTL backend.

For every design in the matrix (matmul, conv2d, ffnn, attention) x banking
factors {1,2,4} x share {on,off}:

    simulate_rtl() outputs == simulate() outputs == run() outputs   (bit)
    all                    ~= jnp oracle                        (float tol)
    RtlStats.cycles        == SimStats.cycles == estimate.cycles (exactly)
    emit_verilog() passes the no-behavioral-constructs lint

plus focused tests of the netlist lowering (FSM structure, per-controller
index registers, operand-mux grants), the RTL simulator's hardware
discipline (port clashes, shared-unit ownership), the Verilog emitter's
determinism and lint contract, and the input-validation satellite.
"""
import functools

import numpy as np
import pytest

from repro.core import affine, calyx, estimator, frontend, pipeline
from repro.core import rtl, rtl_sim, schedule, sim, verilog
from repro.core import tensor_ir as T

# Single source of truth for the matrix — shared with the Calyx-sim suite.
from benchmarks.calyx_bench import DESIGNS


@functools.lru_cache(maxsize=None)
def _compiled(design: str, factor: int, share: bool):
    builder, shape = DESIGNS[design]
    return pipeline.compile_model(builder(), [shape], factor=factor,
                                  share=share)


def _input(design: str) -> np.ndarray:
    _, shape = DESIGNS[design]
    return np.random.default_rng(7).normal(size=shape).astype(np.float32)


class TestFourWayDifferential:
    @pytest.mark.parametrize("share", [True, False])
    @pytest.mark.parametrize("factor", [1, 2, 4])
    @pytest.mark.parametrize("design", sorted(DESIGNS))
    def test_matrix(self, design, factor, share):
        d = _compiled(design, factor, share)
        x = _input(design)
        rtl_outs, rtl_stats = d.simulate_rtl({"arg0": x})
        sim_outs, sim_stats = d.simulate({"arg0": x})
        interp = d.run({"arg0": x})
        oracle = d.run_oracle({"arg0": x})
        # RTL cycles equal both the Calyx measurement and the closed form
        assert rtl_stats.cycles == sim_stats.cycles == d.estimate.cycles
        for r, s, i, o in zip(rtl_outs, sim_outs, interp, oracle):
            np.testing.assert_allclose(r, s, rtol=0, atol=0)
            np.testing.assert_allclose(r, i, rtol=0, atol=0)
            np.testing.assert_allclose(r, o, rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("share", [True, False])
    @pytest.mark.parametrize("factor", [1, 2, 4])
    @pytest.mark.parametrize("design", sorted(DESIGNS))
    def test_matrix_verilog_lints_clean(self, design, factor, share):
        d = _compiled(design, factor, share)
        text = d.emit_verilog()
        assert verilog.lint(text) == []

    def test_branchy_mode(self):
        d = pipeline.compile_model(frontend.paper_ffnn(), [(1, 64)],
                                   factor=2, mode="branchy",
                                   check_hazards=False)
        x = np.random.default_rng(5).normal(size=(1, 64)).astype(np.float32)
        rtl_outs, rtl_stats = d.simulate_rtl({"arg0": x})
        sim_outs, sim_stats = d.simulate({"arg0": x})
        assert rtl_stats.cycles == sim_stats.cycles == d.estimate.cycles
        np.testing.assert_allclose(rtl_outs[0], sim_outs[0], rtol=0, atol=0)
        # runtime bank selection must survive emission + lint
        assert verilog.lint(d.emit_verilog()) == []

    def test_unbanked_par_serializes_in_one_child_controller(self):
        g = frontend.trace(frontend.Linear(8, 8, bias=False), [(4, 8)])
        prog = schedule.restructure(
            schedule.parallelize(affine.lower_graph(g), 2))
        comp = calyx.lower_program(prog)  # NO banking applied
        net = rtl.lower_component(comp, prog)
        x = np.random.default_rng(1).normal(size=(4, 8)).astype(np.float32)
        mems, stats = rtl_sim.simulate(net, {"arg0": x}, g.params)
        assert stats.cycles == estimator.cycles(comp)
        smem, _ = sim.simulate(comp, prog, {"arg0": x}, g.params)
        for name, arr in smem.items():
            np.testing.assert_array_equal(mems[name], arr)

    def test_statically_timed_if_pads_to_worst_arm(self):
        g = T.Graph(name="mask")
        x = g.add_input("arg0", (4, 4))
        g.outputs = [T.causal_mask(g, x)]
        prog = affine.lower_graph(g)
        comp = calyx.lower_program(prog)
        net = rtl.lower_component(comp, prog)
        # the cheap else-arm must carry a pad state so both paths take
        # exactly the worst-case arm latency
        pads = [st for f in net.fsms for st in f.states
                if st.kind == "delay" and st.label == "pad"]
        assert pads and all(p.cycles > 0 for p in pads)
        xv = np.random.default_rng(0).normal(size=(4, 4)).astype(np.float32)
        mems, stats = rtl_sim.simulate(net, {"arg0": xv}, {})
        assert stats.cycles == estimator.cycles(comp)
        oracle = np.where(np.tril(np.ones((4, 4), bool)), xv, -1e30)
        np.testing.assert_allclose(mems[g.outputs[0]], oracle, rtol=1e-6)


class TestNetlistStructure:
    def test_par_components_become_child_fsms(self):
        d = _compiled("matmul", 2, True)
        net = d.to_rtl()
        assert net.fsms[0].parent is None
        children = [f for f in net.fsms if f.parent is not None]
        assert children, "banked par design must fork child controllers"
        par_states = [st for f in net.fsms for st in f.states
                      if st.kind == "par"]
        assert par_states
        forked = {cid for st in par_states for cid in st.children}
        assert forked == {f.fid for f in children}
        # the schedule is static: join cycles come from the estimator model
        assert all(st.join_cycles >= 1 for st in par_states)

    def test_index_registers_are_per_controller(self):
        d = _compiled("matmul", 2, True)
        net = d.to_rtl()
        # concurrent arms reuse source loop vars; each owning controller
        # must get a physically distinct counter
        by_var = {}
        for (fid, var), reg in net.index_regs.items():
            by_var.setdefault(var, []).append(reg.name)
        for var, names in by_var.items():
            assert len(set(names)) == len(names), \
                f"index register names for {var} collide: {names}"

    def test_shared_units_carry_operand_muxes_and_grants(self):
        d = _compiled("ffnn", 2, True)
        net = d.to_rtl()
        pooled = [u for u in net.units.values() if u.users > 1]
        assert pooled, "shared design must have pool cells"
        muxed = {m.unit for m in net.muxes}
        assert muxed == {u.name for u in pooled}
        granted = {op.unit
                   for blk in net.blocks.values() for op in blk.ops
                   if isinstance(op, rtl.DpUnit) and op.grant >= 0}
        assert granted == muxed
        # unshared design: no muxes at all
        net_u = _compiled("ffnn", 2, False).to_rtl()
        assert net_u.muxes == []

    def test_netlist_stats_track_real_structure(self):
        d = _compiled("ffnn", 2, True)
        net = d.to_rtl()
        s = net.stats()
        assert s["fsms"] == len(net.fsms)
        assert s["banks"] == len(net.banks) > 0
        assert s["fsm_states"] > 0 and s["dp_ops"] > 0

    def test_lowering_rejects_summary_only_components(self):
        d = _compiled("matmul", 1, True)
        import copy
        comp = copy.deepcopy(d.component)
        for g in comp.groups.values():
            g.uops = []
        with pytest.raises(ValueError, match="micro-ops"):
            rtl.lower_component(comp, d.program)


class TestRtlHardwareDiscipline:
    def test_same_cycle_port_clash_raises(self):
        from repro.core.calyx import CPar, Component, GEnable, Group
        from repro.core import dataflow as D
        prog = affine.Program("t", {"m": affine.MemDecl("m", (4,))}, [])
        groups = {
            "g1": Group("g1", 2, [], [],
                        [D.UMemRead(0, "m", [affine.AExpr.const_(0)], 0)]),
            "g2": Group("g2", 2, [], [],
                        [D.UMemRead(0, "m", [affine.AExpr.const_(1)], 0)]),
        }
        comp = Component("t", {}, groups,
                         CPar([GEnable("g1"), GEnable("g2")]))
        net = rtl.lower_component(comp, prog)
        with pytest.raises(rtl_sim.RtlSimError, match="one access per cycle"):
            rtl_sim.simulate(net, {}, {})

    def test_identical_address_loads_broadcast(self):
        from repro.core.calyx import CPar, Component, GEnable, Group
        from repro.core import dataflow as D
        prog = affine.Program("t", {"m": affine.MemDecl("m", (4,))}, [])
        idx = [affine.AExpr.const_(2)]
        groups = {
            "g1": Group("g1", 2, [], [], [D.UMemRead(0, "m", idx, 0)]),
            "g2": Group("g2", 2, [], [], [D.UMemRead(0, "m", idx, 0)]),
        }
        comp = Component("t", {}, groups,
                         CPar([GEnable("g1"), GEnable("g2")]))
        _, stats = rtl_sim.simulate(rtl.lower_component(comp, prog), {}, {})
        assert stats.broadcast_reads == 1

    def test_concurrent_shared_unit_owners_raise(self):
        from repro.core.calyx import CPar, Cell, Component, GEnable, Group
        from repro.core import dataflow as D
        pool = Cell("shared_fp_add_0", "fp_add", users=2)
        uops = [D.UConst(0, 1.0),
                D.UAlu(1, "add", 0, 0, cell="shared_fp_add_0")]
        groups = {
            "g1": Group("g1", 2, ["shared_fp_add_0"], [], list(uops)),
            "g2": Group("g2", 2, ["shared_fp_add_0"], [], list(uops)),
        }
        comp = Component("t", {"shared_fp_add_0": pool}, groups,
                         CPar([GEnable("g1"), GEnable("g2")]))
        net = rtl.lower_component(comp, affine.Program("t", {}, []))
        with pytest.raises(rtl_sim.RtlSimError, match="operand muxes"):
            rtl_sim.simulate(net, {}, {})


class TestVerilogEmission:
    def test_emission_is_deterministic(self):
        d = _compiled("matmul", 2, True)
        a = d.emit_verilog()
        # a freshly lowered netlist must print byte-identically
        b = verilog.emit(rtl.lower_component(d.component, d.program))
        assert a == b

    def test_no_behavioral_constructs(self):
        text = _compiled("matmul", 2, True).emit_verilog()
        lines = text.splitlines()
        # no #delay anywhere
        assert not any(verilog._DELAY_RE.search(ln) for ln in lines)
        # initial blocks only inside the memory-bank primitive
        module = ""
        for ln in lines:
            m = verilog._MODULE_RE.match(ln)
            if m:
                module = m.group(1)
            if "initial" in ln.split("//")[0]:
                assert module == verilog.MEM_INIT_MODULE
        # and the structural lint agrees
        assert verilog.lint(text) == []

    def test_lint_catches_violations(self):
        bad = "\n".join([
            "module t (input logic clk, output logic q);",
            "  assign q = 1'b0;",
            "  assign q = 1'b1;",
            "  initial begin",
            "    q = #5 1'b0;",
            "  end",
            "endmodule",
        ])
        errs = verilog.lint(bad)
        assert any("multi-driver" in e for e in errs)
        assert any("delay" in e for e in errs)
        assert any("initial" in e for e in errs)

    def test_golden_structure(self):
        """The emitted module exposes the go/done handshake, the host bus,
        one FSM process per controller, and one port mux per bank."""
        d = _compiled("matmul", 2, True)
        net = d.to_rtl()
        text = d.emit_verilog()
        assert f"module {net.name} (" in text
        for port in ("input  logic go", "output logic done",
                     "input  logic host_we", "output logic [63:0] host_rdata"):
            assert port in text
        for f in net.fsms:
            assert f"fsm{f.fid}_state" in text
        for bank in net.banks.values():
            assert f"u_{bank.name} " in text
        # latency parameters mirror float_lib through rtl.unit_latency
        from repro.core import float_lib as F
        if "repro_fp_mul" in text:
            assert f"#(.LATENCY({F.FLOAT_COSTS['fp_mul'].cycles}))" in text


class TestInputValidation:
    """Satellite: bad inputs fail fast with a clear error, at every
    execution level, instead of a deep KeyError in the evaluators."""

    @pytest.mark.parametrize("method", ["run", "simulate", "simulate_rtl"])
    def test_missing_input(self, method):
        d = _compiled("matmul", 1, True)
        with pytest.raises(ValueError, match=r"missing \['arg0'\]"):
            getattr(d, method)({})

    @pytest.mark.parametrize("method", ["run", "simulate", "simulate_rtl"])
    def test_unexpected_input(self, method):
        d = _compiled("matmul", 1, True)
        x = _input("matmul")
        with pytest.raises(ValueError, match=r"unexpected \['bogus'\]"):
            getattr(d, method)({"arg0": x, "bogus": x})

    @pytest.mark.parametrize("method", ["run", "simulate", "simulate_rtl"])
    def test_wrong_shape(self, method):
        d = _compiled("matmul", 1, True)
        x = _input("matmul")
        with pytest.raises(ValueError, match="shape"):
            getattr(d, method)({"arg0": x.reshape(8, 4)})
