"""Affine-expression algebra + interpreter-vs-oracle for every op kind."""
import numpy as np
import pytest

from repro.core import affine, frontend, jax_backend, pipeline
from repro.core import tensor_ir as T
from repro.core.affine import AExpr


class TestAExprAlgebra:
    def test_linear_ops(self):
        i, j = AExpr.var("i"), AExpr.var("j")
        e = i * 3 + j * 2 + 5
        assert e.evaluate({"i": 2, "j": 7}) == 3 * 2 + 2 * 7 + 5

    def test_mod_folds_when_coeffs_divisible(self):
        ii = AExpr.var("ii")
        e = (ii * 4 + 3).mod(4)
        assert e.is_const() and e.const_value() == 3

    def test_div_folds_when_coeffs_divisible(self):
        ii = AExpr.var("ii")
        e = (ii * 4 + 3).floordiv(4)
        assert e.key() == AExpr.var("ii").key()

    def test_mod_survives_otherwise(self):
        i = AExpr.var("i")
        e = (i * 3).mod(2)
        assert not e.is_const() and e.has_divmod()
        assert e.evaluate({"i": 3}) == (3 * 3) % 2

    def test_substitute_refolds(self):
        i, a = AExpr.var("i"), AExpr.var("ii")
        e = i.mod(2)            # symbolic
        folded = e.substitute({"i": a * 2 + 1})
        assert folded.is_const() and folded.const_value() == 1

    def test_structural_equality_and_cancellation(self):
        i = AExpr.var("i")
        e1 = i.mod(3) * 4 + 1
        e2 = i.mod(3) * 4
        diff = e1 - e2
        assert diff.is_const() and diff.const_value() == 1

    def test_mod_one_is_zero(self):
        assert AExpr.var("x").mod(1).const_value() == 0

    def test_divmod_identity_holds(self):
        # x == (x // c) * c + (x % c) for sampled values
        x = AExpr.var("x")
        e = x.floordiv(5) * 5 + x.mod(5)
        for v in range(0, 23):
            assert e.evaluate({"x": v}) == v


def _roundtrip(module, shape, rtol=1e-4, atol=1e-5, factor=1, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=shape).astype(np.float32)
    d = pipeline.compile_model(module, [shape], factor=factor)
    hw = d.run({"arg0": x})
    jx = d.run_oracle({"arg0": x})
    for h, j in zip(hw, jx):
        np.testing.assert_allclose(h, j, rtol=rtol, atol=atol)
    return d


class TestOpLowerings:
    def test_matmul(self):
        class M(frontend.Module):
            def __init__(self):
                self.lin = frontend.Linear(6, 5, bias=False)

            def forward(self, x):
                return self.lin(x)

        _roundtrip(M(), (3, 6))

    def test_linear_bias_relu(self):
        m = frontend.Sequential(frontend.Linear(6, 4), frontend.ReLU())
        _roundtrip(m, (2, 6))

    def test_conv_pool_flatten(self):
        m = frontend.Sequential(frontend.Conv2d(2, 3, 3, 3),
                                frontend.MaxPool2d(2, 2),
                                frontend.Flatten())
        _roundtrip(m, (2, 7, 7))

    def test_softmax(self):
        m = frontend.Softmax()
        _roundtrip(m, (3, 5), rtol=1e-3)

    def test_causal_mask_and_transpose(self):
        class M(frontend.Module):
            def forward(self, x):
                g = x.graph
                t = x.t()
                s = x @ t
                return frontend.Value(g, T.causal_mask(g, s.name))

        _roundtrip(M(), (4, 3))

    def test_mha_matches_oracle(self):
        _roundtrip(frontend.paper_mha(), (4, 42), rtol=1e-3, atol=1e-4)


class TestUsefulFlops:
    def test_ffnn_flops(self):
        g = frontend.trace(frontend.paper_ffnn(), [(1, 64)])
        # 2*(1*64*48) + 2*(1*48*4) matmul + elementwise
        assert g.flops() >= 2 * 64 * 48 + 2 * 48 * 4
        prog = affine.lower_graph(g)
        assert prog.meta["useful_flops"] == g.flops()
