"""Property-based test: ``sharing.share_cells`` is cycle-neutral as
*measured by the cycle-accurate simulator* — not merely asserted by the
estimator — over randomized small graphs, banking factors, and schedules.

The binding pass promises to never change the schedule; the estimator's
closed form enforces that statically, but only the simulator proves the
bound design still *executes* in the same number of cycles and computes
the same values through the shared pools.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import frontend, pipeline


@st.composite
def random_models(draw):
    """Tiny random MLP-ish module + input shape + banking factor.

    Dims are drawn from multiples of the banking factor so that the
    layout-mode disjointness proof succeeds (a banking-pass precondition,
    not a simulator concern); ReLU and bias toggles vary the group mix.
    """
    factor = draw(st.sampled_from([1, 2, 4]))
    n_layers = draw(st.integers(1, 3))
    mult = st.integers(1, 2)
    dims = [factor * draw(mult) * 2 for _ in range(n_layers + 1)]
    rng = np.random.default_rng(draw(st.integers(0, 2**16)))
    layers = []
    for a, b in zip(dims, dims[1:]):
        layers.append(frontend.Linear(a, b, bias=draw(st.booleans()),
                                      rng=rng))
        if draw(st.booleans()):
            layers.append(frontend.ReLU())
    rows = factor * draw(mult)
    return frontend.Sequential(*layers), (rows, dims[0]), factor


class TestSharingCycleNeutralUnderSimulation:
    @given(mf=random_models())
    @settings(max_examples=25, deadline=None)
    def test_shared_and_unshared_simulate_identically(self, mf):
        module, shape, factor = mf
        shared = pipeline.compile_model(module, [shape], factor=factor,
                                        share=True)
        unshared = pipeline.compile_model(module, [shape], factor=factor,
                                          share=False)
        x = np.random.default_rng(0).normal(size=shape).astype(np.float32)
        outs_s, stats_s = shared.simulate({"arg0": x})
        outs_u, stats_u = unshared.simulate({"arg0": x})
        # cycle-neutrality, measured: binding changed nothing the FSM sees
        assert stats_s.cycles == stats_u.cycles
        # and the measurement agrees with both closed-form estimates
        assert stats_s.cycles == shared.estimate.cycles
        assert stats_u.cycles == unshared.estimate.cycles
        # routing through pools computes the very same values
        for a, b in zip(outs_s, outs_u):
            np.testing.assert_allclose(a, b, rtol=0, atol=0)
        oracle = shared.run_oracle({"arg0": x})
        for a, o in zip(outs_s, oracle):
            np.testing.assert_allclose(a, o, rtol=1e-4, atol=1e-4)
