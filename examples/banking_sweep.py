"""Reproduce the paper's Fig. 3 / Table 2 (FFNN partition sweep) and the
layout-vs-branchy ablation.

Compiles with ``share=False``: the paper has no binding stage, so its
Table 2 LUT/DSP columns correspond to unshared designs (cycles are
identical either way).  See benchmarks/banking_ablation.py for the
shared-vs-unshared resource comparison.

    PYTHONPATH=src python examples/banking_sweep.py
"""
from repro.core import frontend, pipeline
from repro.core.banking import count_branch_arms, count_divmod_hardware

PAPER_CYCLES = {1: 22475, 2: 9378, 4: 3078}


def main():
    model = frontend.paper_ffnn()
    print(f"{'factor':>6} {'mode':>8} {'cycles':>8} {'paper':>8} "
          f"{'LUT':>7} {'DSP':>4} {'branches':>8} {'divmod':>6}")
    layout = {}
    for factor in (1, 2, 4):
        for mode in ("layout", "branchy"):
            if factor == 1 and mode == "branchy":
                continue
            d = pipeline.compile_model(model, [(1, 64)], factor=factor,
                                       mode=mode, check_hazards=False,
                                       share=False)
            if mode == "layout":
                layout[factor] = d
            print(f"{factor:>6} {mode:>8} {d.estimate.cycles:>8} "
                  f"{PAPER_CYCLES[factor] if mode == 'layout' else '-':>8} "
                  f"{d.estimate.resources['LUT']:>7} "
                  f"{d.estimate.resources['DSP']:>4} "
                  f"{count_branch_arms(d.program):>8} "
                  f"{count_divmod_hardware(d.program):>6}")
    c1, c2, c4 = (layout[f].estimate.cycles for f in (1, 2, 4))
    print(f"\nspeedup 1->2: {c1 / c2:.2f}x (paper 2.40x)")
    print(f"speedup 2->4: {c2 / c4:.2f}x (paper 3.05x)")


if __name__ == "__main__":
    main()
