"""Emit the Calyx-like IR for any of the paper's models to a .futil-style
text file — the debuggability surface the paper highlights.

Compile flow: trace -> affine -> parallelize/restructure -> bank ->
Calyx lowering -> resource sharing (binding) -> estimate.  Sharing is on
by default; ``--no-share`` reproduces the paper's one-unit-per-statement
designs (its Table 2 resource numbers).  Shared pool cells show up in the
emitted text as ``shared_<kind>_<n> = ...; // shared xK`` and each group
lists the pool cells it drives (``group st_12<5> uses shared_fp_add_0``).

    PYTHONPATH=src python examples/compile_to_calyx.py --model ffnn \
        --factor 2 --out /tmp/ffnn_f2.futil
    PYTHONPATH=src python examples/compile_to_calyx.py --model ffnn \
        --factor 4 --no-share        # the paper's unshared resource story
    PYTHONPATH=src python examples/compile_to_calyx.py --model ffnn \
        --factor 2 --simulate        # execute the component cycle-accurately
    PYTHONPATH=src python examples/compile_to_calyx.py --model ffnn \
        --factor 2 --emit-verilog /tmp/ffnn_f2.sv --simulate-rtl
    PYTHONPATH=src python examples/compile_to_calyx.py --model ffnn \
        --factor 2 --opt-level 2 --simulate   # chaining + loop pipelining

``--opt-level`` selects the static scheduling layer: 0 = the paper's
schedule (one group per statement), 1 = operation chaining / group fusion
(seq runs and port-compatible par arms merge into multi-op groups), 2 =
level 1 plus loop pipelining — innermost single-group repeats get an
initiation interval II = max(loop-carried register recurrence,
iterative-unit reservation, memory-port modulo reservation), computed
from the group's stamped micro-op offsets; e.g. a MAC reduction whose
accumulator is consumed by the adder at cycle 4 and latched at cycle 6
pipelines at II = 2.  Pipelined loops print as ``repeat N pipeline
ii=K`` in the emitted text, and the estimate/simulators all price the
same overlapped schedule.

``--simulate`` runs the cycle-accurate simulator (``repro.core.sim``) on a
random input: it executes the lowered component's micro-ops, measures the
cycle count (which must equal the estimate), and reports the max abs error
against the jnp oracle.

``--emit-verilog PATH`` lowers the component to the structural RTL netlist
and writes it as SystemVerilog; ``--simulate-rtl`` executes
that netlist cycle-by-cycle (``repro.core.rtl_sim``) and checks the
measured cycles against the estimate — the last two stages of the
four-way differential harness.

``--verify`` (the default) runs the stage-boundary static verifier
(``repro.core.verify``) at every boundary the compile crosses and prints
the per-stage diagnostic table — codes, severities, provenance chains;
``--no-verify`` skips it (the paper's original unchecked flow).

Observability (``repro.core.trace`` / ``repro.core.profiler``):

    PYTHONPATH=src python examples/compile_to_calyx.py --model ffnn \
        --factor 4 --opt-level 2 --profile       # attribution report
    PYTHONPATH=src python examples/compile_to_calyx.py --model ffnn \
        --factor 2 --trace /tmp/ffnn.jsonl --vcd /tmp/ffnn.vcd

``--profile`` runs both simulators with tracing plus the synthesized
counter bank and the analytic attribution, cross-checks all levels for
exact equality, and prints the flame table / occupancy / stall
breakdown.  ``--trace PATH`` writes the netlist-level event trace as
JSONL; ``--vcd PATH`` writes a GTKWave/Surfer-openable waveform of the
group enables, controller states, and bank-port grants.
"""
import argparse

import numpy as np

from repro.core import diagnostics, frontend, pipeline, profiler, trace

MODELS = {
    "ffnn": (frontend.paper_ffnn, (1, 64)),
    "cnn": (frontend.paper_cnn, (3, 80, 60)),
    "mha": (frontend.paper_mha, (8, 42)),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", choices=list(MODELS), default="ffnn")
    ap.add_argument("--factor", type=int, default=2, choices=(1, 2, 4))
    ap.add_argument("--mode", choices=("layout", "branchy"), default="layout")
    ap.add_argument("--opt-level", type=int, default=0, choices=(0, 1, 2),
                    help="static scheduling layer: 0=paper schedule, "
                         "1=chaining/group fusion, 2=+loop pipelining (II)")
    ap.add_argument("--no-share", action="store_true",
                    help="skip the binding pass (paper's unshared designs)")
    ap.add_argument("--simulate", action="store_true",
                    help="cycle-accurately execute the lowered component "
                         "and check measured cycles against the estimate")
    ap.add_argument("--emit-verilog", metavar="PATH", default=None,
                    help="lower to the RTL netlist and write "
                         "SystemVerilog to PATH")
    ap.add_argument("--simulate-rtl", action="store_true",
                    help="execute the RTL netlist cycle-by-cycle and check "
                         "measured cycles against the estimate")
    ap.add_argument("--verify", dest="verify", action="store_true",
                    default=True,
                    help="run the stage-boundary static verifier and print "
                         "the diagnostic table (default)")
    ap.add_argument("--no-verify", dest="verify", action="store_false",
                    help="skip stage-boundary verification")
    ap.add_argument("--profile", action="store_true",
                    help="trace both simulators, cross-check the counter "
                         "levels, and print the attribution report")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="write the netlist-level event trace as JSONL")
    ap.add_argument("--vcd", metavar="PATH", default=None,
                    help="write a VCD waveform of the netlist-level trace")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    builder, shape = MODELS[args.model]
    d = pipeline.compile_model(builder(), [shape], factor=args.factor,
                               mode=args.mode,
                               check_hazards=args.mode == "layout",
                               share=not args.no_share,
                               opt_level=args.opt_level,
                               verify=args.verify)
    text = d.calyx_text()
    out = args.out or f"/tmp/{args.model}_f{args.factor}_{args.mode}.futil"
    with open(out, "w") as f:
        f.write(text)
    e = d.estimate
    print(f"model={args.model} factor={args.factor} mode={args.mode} "
          f"share={not args.no_share} opt_level={args.opt_level}")
    print(f"  cycles={e.cycles}  fmax={e.fmax_mhz}MHz  wall={e.wall_us}us")
    print(f"  resources={e.resources}  fsm_states={e.fsm_states}  "
          f"banking_efficiency={e.banking_efficiency}")
    print(f"  cells={len(d.component.cells)}  groups={len(d.component.groups)}")
    pipelined = d.component.meta.get("pipelined") or []
    if pipelined:
        loops = " ".join(f"{p['var']}[x{p['extent']} ii={p['ii']} "
                         f"body={p['body_latency']}]" for p in pipelined)
        print(f"  pipelined loops: {loops}")
    if d.sharing is not None:
        print(f"  {d.sharing.summary()}")
    print(f"  wrote {len(text.splitlines())} lines -> {out}")
    if args.simulate:
        x = np.random.default_rng(0).normal(size=shape).astype(np.float32)
        outs, stats = d.simulate({"arg0": x})
        oracle = d.run_oracle({"arg0": x})
        err = max(float(np.max(np.abs(s - o)))
                  for s, o in zip(outs, oracle))
        verdict = ("matches estimate" if stats.cycles == e.cycles
                   else f"MISMATCH vs estimate {e.cycles}")
        print(f"  simulated cycles={stats.cycles} ({verdict}); "
              f"max|out - oracle|={err:.2e}")
        print(f"  sim: groups={stats.group_activations} uops={stats.uops} "
              f"reads={stats.mem_reads} writes={stats.mem_writes} "
              f"broadcast={stats.broadcast_reads} "
              f"serialized_arms={stats.serialized_arms} "
              f"shared_fu_grants={sum(stats.fu_grants.values())}")
    if args.emit_verilog or args.simulate_rtl:
        net = d.to_rtl()
        ns = net.stats()
        print(f"  netlist: fsms={ns['fsms']} states={ns['fsm_states']} "
              f"units={ns['units']} banks={ns['banks']} mux2={ns['mux2']}")
    if args.emit_verilog:
        text = d.emit_verilog(args.emit_verilog)
        print(f"  wrote {len(text.splitlines())} lines of SystemVerilog "
              f"-> {args.emit_verilog}")
    if args.simulate_rtl:
        x = np.random.default_rng(0).normal(size=shape).astype(np.float32)
        outs, rstats = d.simulate_rtl({"arg0": x})
        oracle = d.run_oracle({"arg0": x})
        err = max(float(np.max(np.abs(s - o)))
                  for s, o in zip(outs, oracle))
        verdict = ("matches estimate" if rstats.cycles == e.cycles
                   else f"MISMATCH vs estimate {e.cycles}")
        print(f"  rtl cycles={rstats.cycles} ({verdict}); "
              f"max|out - oracle|={err:.2e}")
        print(f"  rtl: transitions={rstats.fsm_transitions} "
              f"groups={rstats.group_fires} reads={rstats.mem_reads} "
              f"writes={rstats.mem_writes} par_forks={rstats.par_forks}")
    if args.profile:
        x = np.random.default_rng(0).normal(size=shape).astype(np.float32)
        prof = d.profile({"arg0": x})
        print()
        print(prof.report())
        if prof.mismatches:
            raise SystemExit(1)
    if args.trace or args.vcd:
        x = np.random.default_rng(0).normal(size=shape).astype(np.float32)
        tracer = trace.Tracer()
        d.simulate_rtl({"arg0": x}, tracer=tracer)
        if args.trace:
            with open(args.trace, "w") as f:
                f.write(trace.to_jsonl(tracer.events))
            print(f"  wrote {len(tracer.events)} events -> {args.trace}")
        if args.vcd:
            text = profiler.to_vcd(tracer.events, name=d.component.name)
            with open(args.vcd, "w") as f:
                f.write(text)
            print(f"  wrote {len(text.splitlines())} VCD lines "
                  f"-> {args.vcd}")
    if args.verify:
        print()
        print(diagnostics.render_table(d.verify_reports))


if __name__ == "__main__":
    main()
