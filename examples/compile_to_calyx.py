"""Emit the Calyx-like IR for any of the paper's models to a .futil-style
text file — the debuggability surface the paper highlights.

Compile flow: trace -> affine -> parallelize/restructure -> bank ->
Calyx lowering -> resource sharing (binding) -> estimate.  Sharing is on
by default; ``--no-share`` reproduces the paper's one-unit-per-statement
designs (its Table 2 resource numbers).  Shared pool cells show up in the
emitted text as ``shared_<kind>_<n> = ...; // shared xK`` and each group
lists the pool cells it drives (``group st_12<5> uses shared_fp_add_0``).

    PYTHONPATH=src python examples/compile_to_calyx.py --model ffnn \
        --factor 2 --out /tmp/ffnn_f2.futil
    PYTHONPATH=src python examples/compile_to_calyx.py --model ffnn \
        --factor 4 --no-share        # the paper's unshared resource story
"""
import argparse

from repro.core import frontend, pipeline

MODELS = {
    "ffnn": (frontend.paper_ffnn, (1, 64)),
    "cnn": (frontend.paper_cnn, (3, 80, 60)),
    "mha": (frontend.paper_mha, (8, 42)),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", choices=list(MODELS), default="ffnn")
    ap.add_argument("--factor", type=int, default=2, choices=(1, 2, 4))
    ap.add_argument("--mode", choices=("layout", "branchy"), default="layout")
    ap.add_argument("--no-share", action="store_true",
                    help="skip the binding pass (paper's unshared designs)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    builder, shape = MODELS[args.model]
    d = pipeline.compile_model(builder(), [shape], factor=args.factor,
                               mode=args.mode,
                               check_hazards=args.mode == "layout",
                               share=not args.no_share)
    text = d.calyx_text()
    out = args.out or f"/tmp/{args.model}_f{args.factor}_{args.mode}.futil"
    with open(out, "w") as f:
        f.write(text)
    e = d.estimate
    print(f"model={args.model} factor={args.factor} mode={args.mode} "
          f"share={not args.no_share}")
    print(f"  cycles={e.cycles}  fmax={e.fmax_mhz}MHz  wall={e.wall_us}us")
    print(f"  resources={e.resources}  fsm_states={e.fsm_states}")
    print(f"  cells={len(d.component.cells)}  groups={len(d.component.groups)}")
    if d.sharing is not None:
        print(f"  {d.sharing.summary()}")
    print(f"  wrote {len(text.splitlines())} lines -> {out}")


if __name__ == "__main__":
    main()
