"""End-to-end training driver with fault tolerance.

    PYTHONPATH=src python examples/train_lm.py --arch qwen2-0.5b --steps 60 \
        [--inject-failure 25]

Trains the reduced config on the synthetic bigram corpus with the full
runtime: AdamW + schedule, periodic checkpoints, restart-on-failure, and
straggler detection.  Loss must drop well below ln(vocab) as the model
learns the planted bigrams.
"""
import argparse
import tempfile

import numpy as np

from repro.data.pipeline import DataConfig
from repro.models import get_config
from repro.optim import adamw
from repro.runtime.trainer import Trainer, TrainerConfig, WorkerFailure


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--inject-failure", type=int, default=-1,
                    help="simulate a node failure at this step")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    ckpt = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_train_")
    tcfg = TrainerConfig(total_steps=args.steps, checkpoint_every=10,
                         checkpoint_dir=ckpt)
    opt = adamw.AdamWConfig(lr=args.lr, warmup_steps=max(2, args.steps // 10),
                            total_steps=args.steps, weight_decay=0.01)
    fired = {"done": False}

    def maybe_fail(step):
        if step == args.inject_failure and not fired["done"]:
            fired["done"] = True
            print(f"!! injecting WorkerFailure at step {step}")
            raise WorkerFailure("simulated preemption")

    tr = Trainer(cfg, tcfg, opt_cfg=opt,
                 data_cfg=DataConfig(vocab_size=cfg.vocab_size,
                                     seq_len=args.seq,
                                     global_batch=args.batch),
                 failure_hook=maybe_fail if args.inject_failure >= 0 else None)
    tr.run_with_restarts()

    losses = [h["loss"] for h in tr.history if "loss" in h]
    restarts = [h for h in tr.history if "restart" in h]
    print(f"\narch={cfg.name} steps={args.steps} "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"(ln V = {np.log(cfg.vocab_size):.2f}) restarts={len(restarts)}")
    if tr.detector.stragglers():
        print("stragglers:", tr.detector.stragglers())
    assert losses[-1] < losses[0], "loss did not decrease"
    print("OK")


if __name__ == "__main__":
    main()
