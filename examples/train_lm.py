"""End-to-end training driver with fault tolerance.

    PYTHONPATH=src python examples/train_lm.py --arch qwen2-0.5b --steps 60 \
        [--inject-failure 25]

Trains the reduced config on the synthetic bigram corpus with the full
runtime: AdamW + schedule, periodic checkpoints, restart-on-failure, and
straggler detection.  Loss must drop well below ln(vocab) as the model
learns the planted bigrams.

``--profile-layers PATH`` additionally runs a short greedy decode of the
*trained* parameters through the sliced per-operator step and writes the
layer-record JSONL (``repro.obs.modelprof`` schema) — the same artifact
the serving drivers emit, so a training run can hand its checkpoint's
operator profile straight to the offload analysis.
"""
import argparse
import tempfile

import numpy as np

from repro.data.pipeline import DataConfig
from repro.models import get_config
from repro.optim import adamw
from repro.runtime.trainer import Trainer, TrainerConfig, WorkerFailure


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--inject-failure", type=int, default=-1,
                    help="simulate a node failure at this step")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--profile-layers", default="",
                    help="after training, profile a short greedy decode "
                         "of the trained params and write the per-operator "
                         "layer records here as JSONL")
    ap.add_argument("--profile-steps", type=int, default=8,
                    help="decode steps for --profile-layers")
    ap.add_argument("--stable", action="store_true",
                    help="normalize wall-clock fields in the layer export")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    ckpt = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_train_")
    tcfg = TrainerConfig(total_steps=args.steps, checkpoint_every=10,
                         checkpoint_dir=ckpt)
    opt = adamw.AdamWConfig(lr=args.lr, warmup_steps=max(2, args.steps // 10),
                            total_steps=args.steps, weight_decay=0.01)
    fired = {"done": False}

    def maybe_fail(step):
        if step == args.inject_failure and not fired["done"]:
            fired["done"] = True
            print(f"!! injecting WorkerFailure at step {step}")
            raise WorkerFailure("simulated preemption")

    tr = Trainer(cfg, tcfg, opt_cfg=opt,
                 data_cfg=DataConfig(vocab_size=cfg.vocab_size,
                                     seq_len=args.seq,
                                     global_batch=args.batch),
                 failure_hook=maybe_fail if args.inject_failure >= 0 else None)
    state = tr.run_with_restarts()

    losses = [h["loss"] for h in tr.history if "loss" in h]
    restarts = [h for h in tr.history if "restart" in h]
    print(f"\narch={cfg.name} steps={args.steps} "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"(ln V = {np.log(cfg.vocab_size):.2f}) restarts={len(restarts)}")
    if tr.detector.stragglers():
        print("stragglers:", tr.detector.stragglers())
    assert losses[-1] < losses[0], "loss did not decrease"

    if args.profile_layers:
        import jax.numpy as jnp
        from repro.models import decode
        from repro.obs import modelprof as MPF
        if cfg.family not in decode.PROFILED_FAMILIES:
            ap.error(f"--profile-layers supports families "
                     f"{decode.PROFILED_FAMILIES}, not {cfg.family}")
        n, batch = args.profile_steps, 2
        pstep = decode.make_profiled_serve_step(cfg)
        cache = decode.ProfiledServeStep.init_cache(
            cfg, state["params"], batch, n + 1)
        layers = MPF.LayerProfiler()
        tok = jnp.ones((batch, 1), jnp.int32)
        for i in range(n):
            logits, cache, walls = pstep(state["params"], cache, tok,
                                         jnp.asarray(i, jnp.int32))
            layers.on_step(i, pstep.ops, walls)
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(
                jnp.int32)[:, None]
        problems = MPF.validate(layers.records, cfg=cfg, engine_steps=n)
        assert not problems, problems
        with open(args.profile_layers, "w") as f:
            f.write(MPF.to_jsonl(layers.records, stable=args.stable))
        print(f"{len(layers.records)} layer records -> "
              f"{args.profile_layers}{' (stable)' if args.stable else ''}")
    print("OK")


if __name__ == "__main__":
    main()
