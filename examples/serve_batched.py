"""End-to-end serving driver: batched prefill + decode with a KV cache.

    PYTHONPATH=src python examples/serve_batched.py --arch qwen2-0.5b \
        --requests 4 --prompt-len 16 --gen 24 \
        --metrics-out /tmp/batched.prom --spans-out /tmp/batched.jsonl

Serves the reduced config of any assigned architecture on CPU: a batch of
requests is prefilled token-by-token into the cache, then decoded greedily.
(The production path lowers the identical serve_step at decode_32k /
long_500k shapes in the multi-pod dry-run.)

All reported wall-clock numbers are taken after ``jax.block_until_ready``
on the step outputs — jax dispatch is asynchronous, so stamping before the
sync would time the *enqueue*, not the compute.  With ``--metrics-out`` /
``--spans-out`` the driver additionally syncs per step and emits the same
metric names and span schema as the continuous-batching engine
(``repro.launch.serve``); the uninstrumented run keeps the original
sync-at-phase-end behavior and pays nothing.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import faults as FLT, resilience as RES
from repro.launch.serve import serve_metrics
from repro.models import decode, get_config
from repro.models import params as MP
from repro.obs import MetricsRegistry, SpanTracer, modelprof as MPF, \
    spans as SP


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics-out", default="",
                    help="write the metrics registry here on exit "
                         "(.json -> JSON, anything else -> Prometheus text)")
    ap.add_argument("--spans-out", default="",
                    help="write the span event stream here as JSONL")
    ap.add_argument("--profile-layers", default="",
                    help="run the sliced per-operator decode step and "
                         "write one layer record per (op, step) here as "
                         "JSONL (repro.obs.modelprof schema)")
    ap.add_argument("--stable", action="store_true",
                    help="normalize wall-clock fields in the span and "
                         "layer exports")
    ap.add_argument("--fault-plan", default="",
                    help="replay a FaultPlan JSON (repro.launch.faults): "
                         "nan/inf logits, latency spikes, and cache "
                         "corruption apply per step with an always-on "
                         "finite guard; victim rows are dropped with the "
                         "'fault' reason instead of poisoning the report. "
                         "'exception' specs are engine-level and ignored "
                         "by this fixed-batch driver")
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="wall-clock completion deadline for the batch; "
                         "rows still in flight when it expires are "
                         "truncated with the 'deadline' reason")
    args = ap.parse_args()

    plan = None
    if args.fault_plan:
        if args.profile_layers:
            ap.error("--fault-plan and --profile-layers are mutually "
                     "exclusive (fault replay targets the standard path)")
        plan = FLT.FaultPlan.load(args.fault_plan)
    resilient = plan is not None or args.deadline_ms > 0

    cfg = get_config(args.arch).reduced()
    rng = np.random.default_rng(args.seed)
    params = MP.init_params(cfg, seed=args.seed)
    max_len = args.prompt_len + args.gen

    modality = None
    if cfg.family == "vlm":
        modality = jnp.asarray(rng.normal(
            size=(args.requests, cfg.num_patches, cfg.d_model)), cfg.dtype)
    if cfg.family == "audio":
        modality = jnp.asarray(rng.normal(
            size=(args.requests, cfg.encoder_seq, cfg.d_model)), cfg.dtype)

    layers = None
    if args.profile_layers:
        if cfg.family not in decode.PROFILED_FAMILIES:
            ap.error(f"--profile-layers supports families "
                     f"{decode.PROFILED_FAMILIES}, not {cfg.family}")
        layers = MPF.LayerProfiler()

    if layers is not None:
        pstep = decode.make_profiled_serve_step(cfg)
        cache = decode.ProfiledServeStep.init_cache(cfg, params,
                                                    args.requests, max_len)
    else:
        cache = decode.init_cache(cfg, params, args.requests, max_len,
                                  modality=modality)
        step = decode.make_serve_step(cfg)

    metrics = MetricsRegistry() if args.metrics_out else None
    spans_tr = SpanTracer() if args.spans_out else None
    observing = metrics is not None or spans_tr is not None
    m = serve_metrics(metrics, cfg, args.requests,
                      decode.ProfiledServeStep.stack_cache(cache)
                      if layers is not None else cache) \
        if metrics is not None else None
    now_us = spans_tr.now_us if spans_tr is not None \
        else lambda t0=time.perf_counter(): int((time.perf_counter() - t0)
                                                * 1e6)

    if layers is not None:
        def step(params, cache, toks, pos):
            """Sliced step: record one layer wall per operator, stamped on
            the span tracer's clock when one is attached (one-clock rule)."""
            logits, cache, walls = pstep(params, cache, toks, pos)
            layers.on_step(int(pos), pstep.ops, walls,
                           ts_us=now_us() if spans_tr is not None else None)
            return logits, cache

    prompts = rng.integers(1, cfg.vocab_size,
                           size=(args.requests, args.prompt_len)).astype(
                               np.int32)
    print(f"arch={cfg.name} (reduced) requests={args.requests} "
          f"prompt={args.prompt_len} gen={args.gen}")

    # every request is enqueued and admitted up front (fixed batch, one
    # slot per request) — the spans still carry the full phase chain so
    # the batched and continuous drivers export comparable streams
    enqueue_us = now_us() if observing else 0
    if spans_tr is not None:
        for r in range(args.requests):
            spans_tr.emit(SP.REQ_ENQUEUE, ts_us=enqueue_us,
                          prov=SP.req_prov(r), step=0, rid=r)
        for r in range(args.requests):
            spans_tr.emit(SP.REQ_ADMIT, ts_us=enqueue_us,
                          prov=SP.req_prov(r), step=0, rid=r, slot=r)
            spans_tr.emit(SP.REQ_PREFILL, ts_us=enqueue_us,
                          prov=SP.req_prov(r), step=0, rid=r, slot=r)
    if m is not None:
        m["enq"].inc(args.requests)
        m["adm"].inc(args.requests)
        m["occ"].set(args.requests)

    def observe_step(idx, t_step, tokens_out, prefill_fed, occ):
        """Per-step sync + event/metric emission (instrumented runs only)."""
        wall = int((time.perf_counter() - t_step) * 1e6)
        if spans_tr is not None:
            spans_tr.emit(SP.STEP, prov=SP.step_prov(idx), step=idx,
                          dur_us=wall,
                          data=(occ, 0, tokens_out, prefill_fed))
        if m is not None:
            m["steps"].inc()
            m["gen"].inc(tokens_out)
            m["pre"].inc(prefill_fed)
            m["step_h"].observe(wall)

    # fixed-batch resilience state: rows are dropped (never retried — there
    # is no queue to retry into) and the rest of the batch keeps serving
    alive = np.ones(args.requests, bool)
    toks_emitted = np.zeros(args.requests, np.int64)
    counts = {"inj": 0, "det": 0}
    expired = False
    sync_each = observing or resilient

    def apply_faults(idx, logits, cache):
        """Replay this step's fault specs.  Latency sleeps land inside the
        step wall; 'exception' specs are engine-level and skipped here."""
        for f in plan.at(idx):
            if f.kind in (FLT.NAN_LOGITS, FLT.INF_LOGITS) \
                    and 0 <= f.slot < args.requests:
                poison = float("nan") if f.kind == FLT.NAN_LOGITS \
                    else float("inf")
                logits = logits.at[f.slot, -1].set(poison)
            elif f.kind == FLT.CACHE_CORRUPT \
                    and 0 <= f.slot < args.requests:
                cache = decode.corrupt_cache_slot(cfg, cache, f.slot)
            elif f.kind == FLT.LATENCY_SPIKE:
                time.sleep(f.spike_us / 1e6)
            else:
                continue
            counts["inj"] += 1
            if m is not None:
                m["finj"].inc()
        return logits, cache

    def finish_rows(rows, idx, detail):
        """Terminate rows with a truncation reason (span + counters)."""
        us = now_us() if observing else 0
        for r in rows:
            alive[r] = False
            if spans_tr is not None:
                spans_tr.emit(SP.REQ_COMPLETE, ts_us=us,
                              prov=SP.req_prov(r), step=idx, rid=r, slot=r,
                              detail=detail, data=(int(toks_emitted[r]),))
        if m is not None and rows:
            m["trunc"].inc(len(rows))
            m["trunc_" + detail[len(SP.TRUNCATED_PREFIX):]].inc(len(rows))
            m["occ"].set(int(alive.sum()))

    def screen(idx, logits):
        """Finite guard: drop rows whose sampled logits went non-finite."""
        fin = np.isfinite(np.asarray(logits[:, -1], np.float32)).all(axis=1)
        bad = [r for r in range(args.requests) if alive[r] and not fin[r]]
        if bad:
            counts["det"] += len(bad)
            if m is not None:
                m["fdet"].inc(len(bad))
            finish_rows(bad, idx, SP.TRUNCATED_PREFIX + RES.REASON_FAULT)

    def past_deadline():
        return args.deadline_ms > 0 \
            and (time.perf_counter() - t_serve0) * 1e3 > args.deadline_ms

    # prefill (token-by-token through the decode path)
    t0 = t_serve0 = time.perf_counter()
    logits = None
    steps_run = 0
    for i in range(args.prompt_len):
        t_step = time.perf_counter() if observing else 0.0
        logits, cache = step(params, cache, jnp.asarray(prompts[:, i:i + 1]),
                             jnp.asarray(i, jnp.int32))
        if sync_each:
            jax.block_until_ready(logits)
        if plan is not None:
            logits, cache = apply_faults(i, logits, cache)
        occ_now = int(alive.sum())  # rows dying this step still occupy it
        if plan is not None:
            screen(i, logits)
        if i == args.prompt_len - 1:
            # the last prefill step's logits produce the first tokens
            toks_emitted[alive] += 1
        steps_run += 1
        if observing:
            observe_step(i, t_step,
                         int(alive.sum()) if i == args.prompt_len - 1 else 0,
                         args.requests, occ_now)
        if past_deadline():
            finish_rows([r for r in range(args.requests) if alive[r]], i,
                        SP.TRUNCATED_PREFIX + RES.REASON_DEADLINE)
            expired = True
            break
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    # greedy decode
    outs = []
    t_decode = 0.0
    first_us = enqueue_us
    if not expired:
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        if observing:
            jax.block_until_ready(tok)
            first_us = now_us()
            if spans_tr is not None:
                for r in range(args.requests):
                    if alive[r]:
                        spans_tr.emit(SP.REQ_FIRST_TOKEN, ts_us=first_us,
                                      prov=SP.req_prov(r),
                                      step=args.prompt_len - 1, rid=r,
                                      slot=r)
            if m is not None:
                for r in range(args.requests):
                    if alive[r]:
                        m["ttft"].observe(first_us - enqueue_us)
        t0 = time.perf_counter()
        for i in range(args.gen):
            outs.append(np.asarray(tok))
            t_step = time.perf_counter() if observing else 0.0
            logits, cache = step(params, cache, tok,
                                 jnp.asarray(args.prompt_len + i, jnp.int32))
            if sync_each:
                jax.block_until_ready(logits)
            if plan is not None:
                logits, cache = apply_faults(args.prompt_len + i, logits,
                                             cache)
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[
                :, None]
            occ_now = int(alive.sum())
            if plan is not None:
                screen(args.prompt_len + i, logits)
            if i < args.gen - 1:
                # the final iteration's freshly computed token is discarded
                toks_emitted[alive] += 1
            steps_run += 1
            if observing:
                jax.block_until_ready(tok)
                observe_step(args.prompt_len + i, t_step,
                             int(alive.sum()) if i < args.gen - 1 else 0,
                             0, occ_now)
            if past_deadline():
                finish_rows([r for r in range(args.requests) if alive[r]],
                            args.prompt_len + i,
                            SP.TRUNCATED_PREFIX + RES.REASON_DEADLINE)
                expired = True
                break
            if not alive.any():
                break
        jax.block_until_ready(tok)
        t_decode = time.perf_counter() - t0

    if observing:
        done_us = now_us()
        last_step = max(steps_run - 1, 0)
        if spans_tr is not None:
            for r in range(args.requests):
                if alive[r]:
                    spans_tr.emit(SP.REQ_COMPLETE, ts_us=done_us,
                                  prov=SP.req_prov(r), step=last_step,
                                  rid=r, slot=r, detail=SP.FINISHED,
                                  data=(int(toks_emitted[r]),))
        if m is not None:
            m["fin"].inc(int(alive.sum()))
            m["occ"].set(0)
            if not expired:
                for r in range(args.requests):
                    if alive[r] and toks_emitted[r] >= 2:
                        m["dtok"].observe((done_us - first_us)
                                          / (int(toks_emitted[r]) - 1))

    tps = int(toks_emitted.sum()) / t_decode if t_decode > 0 else 0.0
    print(f"prefill: {t_prefill:.2f}s   decode: {t_decode:.2f}s "
          f"({tps:.1f} tok/s aggregate)")
    if outs:
        gen = np.concatenate(outs, axis=1)
        for r in range(min(args.requests, 2)):
            print(f"req{r}: prompt={prompts[r, :8].tolist()}... "
                  f"generated={gen[r, :12].tolist()}...")
    if resilient:
        print(f"resilience: faults injected={counts['inj']} "
              f"detected={counts['det']} "
              f"dropped={int((~alive).sum())} "
              f"survivors={int(alive.sum())}")
    if metrics is not None:
        with open(args.metrics_out, "w") as f:
            f.write(metrics.dump_json()
                    if args.metrics_out.endswith(".json")
                    else metrics.to_prometheus())
        print(f"metrics -> {args.metrics_out}")
    if spans_tr is not None:
        problems = SP.validate(spans_tr.events, slots=args.requests,
                               engine_steps=steps_run)
        assert not problems, problems
        with open(args.spans_out, "w") as f:
            f.write(SP.to_jsonl(spans_tr.events, stable=args.stable))
        print(f"{len(spans_tr.events)} span events -> {args.spans_out}"
              f"{' (stable)' if args.stable else ''}")
    if layers is not None:
        problems = MPF.validate(layers.records, cfg=cfg,
                                engine_steps=steps_run)
        if spans_tr is not None:
            problems += MPF.join_mismatches(layers.records, spans_tr.events,
                                            cfg=cfg)
        assert not problems, problems
        with open(args.profile_layers, "w") as f:
            f.write(MPF.to_jsonl(layers.records, stable=args.stable))
        print(f"{len(layers.records)} layer records -> "
              f"{args.profile_layers}{' (stable)' if args.stable else ''}")
    finite = np.isfinite(np.asarray(logits, np.float32))
    assert finite[alive].all() if resilient else finite.all()
    print("OK")


if __name__ == "__main__":
    main()
