"""End-to-end serving driver: batched prefill + decode with a KV cache.

    PYTHONPATH=src python examples/serve_batched.py --arch qwen2-0.5b \
        --requests 4 --prompt-len 16 --gen 24

Serves the reduced config of any assigned architecture on CPU: a batch of
requests is prefilled token-by-token into the cache, then decoded greedily.
(The production path lowers the identical serve_step at decode_32k /
long_500k shapes in the multi-pod dry-run.)
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode, get_config
from repro.models import params as MP


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    rng = np.random.default_rng(args.seed)
    params = MP.init_params(cfg, seed=args.seed)
    max_len = args.prompt_len + args.gen

    modality = None
    if cfg.family == "vlm":
        modality = jnp.asarray(rng.normal(
            size=(args.requests, cfg.num_patches, cfg.d_model)), cfg.dtype)
    if cfg.family == "audio":
        modality = jnp.asarray(rng.normal(
            size=(args.requests, cfg.encoder_seq, cfg.d_model)), cfg.dtype)

    cache = decode.init_cache(cfg, params, args.requests, max_len,
                              modality=modality)
    step = jax.jit(lambda p, c, t, pos: decode.serve_step(cfg, p, c, t, pos))

    prompts = rng.integers(1, cfg.vocab_size,
                           size=(args.requests, args.prompt_len)).astype(
                               np.int32)
    print(f"arch={cfg.name} (reduced) requests={args.requests} "
          f"prompt={args.prompt_len} gen={args.gen}")

    # prefill (token-by-token through the decode path)
    t0 = time.time()
    logits = None
    for i in range(args.prompt_len):
        logits, cache = step(params, cache, jnp.asarray(prompts[:, i:i + 1]),
                             jnp.asarray(i, jnp.int32))
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    # greedy decode
    outs = []
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    t0 = time.time()
    for i in range(args.gen):
        outs.append(np.asarray(tok))
        logits, cache = step(params, cache, tok,
                             jnp.asarray(args.prompt_len + i, jnp.int32))
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen = np.concatenate(outs, axis=1)
    tps = args.requests * args.gen / t_decode
    print(f"prefill: {t_prefill:.2f}s   decode: {t_decode:.2f}s "
          f"({tps:.1f} tok/s aggregate)")
    for r in range(min(args.requests, 2)):
        print(f"req{r}: prompt={prompts[r, :8].tolist()}... "
              f"generated={gen[r, :12].tolist()}...")
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    print("OK")


if __name__ == "__main__":
    main()
