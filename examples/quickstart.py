"""Quickstart: compile a PyTorch-style model down to Calyx and run it.

    PYTHONPATH=src python examples/quickstart.py

Walks the full pipeline on the paper's FFNN: trace -> affine -> parallelize
-> bank -> Calyx -> resource sharing -> estimate, validates the hardware
schedule against the jnp oracle, and prints the banking sweep the paper's
Fig. 3 reports.  Resources shown are for the *shared* (bound) designs —
cycles match the paper's unshared numbers exactly (binding is
latency-neutral), but LUT/DSP land well below its Table 1/2; pass
``share=False`` to ``compile_model`` for the paper's regime.
"""
import numpy as np

from repro.core import frontend, pipeline

def main():
    model = frontend.paper_ffnn()
    x = np.random.default_rng(0).normal(size=(1, 64)).astype(np.float32)

    print("=== FFNN through the PyTorch->Calyx pipeline ===")
    base = None
    for factor in (1, 2, 4):
        d = pipeline.compile_model(model, [(1, 64)], factor=factor)
        hw = d.run({"arg0": x})[0]
        oracle = d.run_oracle({"arg0": x})[0]
        ok = np.allclose(hw, oracle, rtol=1e-4, atol=1e-5)
        base = base or d.estimate.cycles
        print(f"factor={factor}: cycles={d.estimate.cycles:6d} "
              f"(speedup {base / d.estimate.cycles:4.2f}x) "
              f"fmax={d.estimate.fmax_mhz}MHz "
              f"resources={d.estimate.resources} correct={ok}")

    print("\n=== Calyx IR (factor=2, excerpt) ===")
    d = pipeline.compile_model(model, [(1, 64)], factor=2)
    text = d.calyx_text()
    print("\n".join(text.splitlines()[:25]))
    print(f"... ({len(text.splitlines())} lines total)")


if __name__ == "__main__":
    main()
