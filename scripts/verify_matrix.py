"""Run the stage-boundary verifier over the full benchmark matrix.

Compiles every point of the 4-design x factor {1,2,4} x share {on,off}
x opt_level {0,2} matrix (the same one ``benchmarks/calyx_bench.py``
measures) with verification on, lowers each to the RTL netlist, and
requires every boundary report to come back empty — zero errors *and*
zero warnings.  No simulation runs, so the sweep is fast enough for a
per-push CI job; it is the static half of the differential harness.

    PYTHONPATH=src python scripts/verify_matrix.py
    PYTHONPATH=src python scripts/verify_matrix.py --designs matmul,ffnn

Exit status is nonzero if any point fails to compile or any finding
fires anywhere in the matrix.
"""
from __future__ import annotations

import argparse
import os
import sys
import time
import warnings

from repro.core import diagnostics, estimator, pipeline

sys.path.insert(0, os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))
from benchmarks.calyx_bench import DESIGNS, FACTORS, OPT_LEVELS  # noqa: E402


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--designs", default=None,
                    help="comma-separated subset (default: all four)")
    args = ap.parse_args()
    selected = ([n.strip() for n in args.designs.split(",") if n.strip()]
                if args.designs else list(DESIGNS))

    bad = []
    points = 0
    stages = 0
    t0 = time.perf_counter()
    for name in selected:
        builder, shape = DESIGNS[name]
        for factor in FACTORS:
            for share in (True, False):
                for opt in OPT_LEVELS:
                    points += 1
                    label = (f"{name} f{factor} "
                             f"{'shared' if share else 'unshared'} o{opt}")
                    try:
                        with warnings.catch_warnings():
                            warnings.simplefilter(
                                "ignore",
                                estimator.BankingEfficiencyWarning)
                            d = pipeline.compile_model(
                                builder(), [shape], factor=factor,
                                share=share, opt_level=opt)
                            d.to_rtl()
                    except diagnostics.VerificationError as exc:
                        bad.append((label, exc.report))
                        print(f"  {label}: VERIFY FAILED at "
                              f"{exc.report.stage}")
                        continue
                    except Exception as exc:
                        bad.append((label, None))
                        print(f"  {label}: compile failed — "
                              f"{type(exc).__name__}: {exc}")
                        continue
                    stages += len(d.verify_reports)
                    findings = [x for r in d.verify_reports for x in r]
                    if findings:
                        bad.append((label, None))
                        print(f"  {label}: {len(findings)} finding(s)")
                        print(diagnostics.render_table(d.verify_reports))
                    else:
                        print(f"  {label}: clean "
                              f"({len(d.verify_reports)} stages)")
    wall = time.perf_counter() - t0
    if bad:
        print(f"\nFAIL: {len(bad)}/{points} matrix point(s) dirty")
        for label, report in bad:
            if report is not None:
                print(diagnostics.render_table([report]))
        return 1
    print(f"\nOK: {points} points x verify, {stages} stage reports, "
          f"all clean ({wall:.1f}s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
