"""Tiny VCD well-formedness checker — the CI gate for profiler waveforms.

Validates the structural rules any VCD consumer (GTKWave, Surfer)
relies on, without needing either installed:

* header order: declarations, then ``$enddefinitions``, then value
  changes only;
* ``$scope``/``$upscope`` balance and a ``$timescale``;
* every ``$var`` has a kind, a positive width, a unique identifier, and
  a reference name;
* every value change uses a declared identifier, scalar changes are
  ``0/1/x/z``, vector changes are ``b<binary>`` and fit the declared
  width;
* timestamps are non-negative, strictly increasing, and start at 0;
* every declared signal has an initial value at time 0 (``$dumpvars``).

    PYTHONPATH=src python scripts/check_vcd.py out.vcd [more.vcd ...]

Exit status 0 iff every file passes; failures print one line per issue.
"""
from __future__ import annotations

import re
import sys
from typing import Dict, List

_VAR_RE = re.compile(r"^\$var\s+(\w+)\s+(\d+)\s+(\S+)\s+(\S+)\s+\$end$")
_TIME_RE = re.compile(r"^#(\d+)$")
_SCALAR_RE = re.compile(r"^([01xzXZ])(\S+)$")
_VECTOR_RE = re.compile(r"^b([01xzXZ]+)\s+(\S+)$")


def check(text: str) -> List[str]:
    errors: List[str] = []
    widths: Dict[str, int] = {}
    in_defs = True
    scope_depth = 0
    saw_timescale = False
    saw_enddefs = False
    last_time = -1
    at_time0 = False
    initialized: set = set()
    in_dumpvars = False
    for ln, raw in enumerate(text.splitlines(), 1):
        line = raw.strip()
        if not line:
            continue
        if in_defs:
            if line.startswith("$timescale"):
                saw_timescale = True
            elif line.startswith("$scope"):
                scope_depth += 1
            elif line.startswith("$upscope"):
                scope_depth -= 1
                if scope_depth < 0:
                    errors.append(f"line {ln}: $upscope without $scope")
            elif line.startswith("$var"):
                m = _VAR_RE.match(line)
                if not m:
                    errors.append(f"line {ln}: malformed $var: {line}")
                    continue
                _kind, width, ident, _name = m.groups()
                if int(width) < 1:
                    errors.append(f"line {ln}: non-positive width: {line}")
                if ident in widths:
                    errors.append(f"line {ln}: duplicate identifier "
                                  f"{ident!r}")
                widths[ident] = int(width)
            elif line.startswith("$enddefinitions"):
                saw_enddefs = True
                in_defs = False
                if scope_depth != 0:
                    errors.append(f"line {ln}: unbalanced $scope nesting "
                                  f"({scope_depth} open)")
            continue
        # value-change section
        m = _TIME_RE.match(line)
        if m:
            t = int(m.group(1))
            if t <= last_time:
                errors.append(f"line {ln}: timestamp #{t} not increasing "
                              f"(previous #{last_time})")
            if last_time == -1 and t != 0:
                errors.append(f"line {ln}: first timestamp is #{t}, "
                              f"expected #0")
            at_time0 = (last_time == -1 and t == 0)
            last_time = t
            continue
        if line == "$dumpvars":
            in_dumpvars = True
            continue
        if line == "$end" and in_dumpvars:
            in_dumpvars = False
            continue
        if line.startswith("$comment"):
            continue
        sm = _SCALAR_RE.match(line)
        vm = _VECTOR_RE.match(line)
        if sm:
            ident = sm.group(2)
            if ident not in widths:
                errors.append(f"line {ln}: change for undeclared id "
                              f"{ident!r}")
            elif widths[ident] != 1:
                errors.append(f"line {ln}: scalar change for {ident!r} "
                              f"of width {widths[ident]}")
        elif vm:
            bits, ident = vm.groups()
            if ident not in widths:
                errors.append(f"line {ln}: change for undeclared id "
                              f"{ident!r}")
            elif len(bits) > widths[ident]:
                errors.append(f"line {ln}: {len(bits)}-bit value for "
                              f"{ident!r} of width {widths[ident]}")
        else:
            errors.append(f"line {ln}: unparseable value change: {line}")
            continue
        if at_time0 or in_dumpvars:
            initialized.add((sm or vm).group(2))
    if not saw_timescale:
        errors.append("missing $timescale")
    if not saw_enddefs:
        errors.append("missing $enddefinitions")
    if not widths:
        errors.append("no $var declarations")
    missing = sorted(set(widths) - initialized)
    if missing:
        errors.append(f"signals without an initial value at #0: "
                      f"{missing[:8]}")
    return errors


def main(paths: List[str]) -> int:
    if not paths:
        print("usage: check_vcd.py FILE.vcd [...]", file=sys.stderr)
        return 2
    status = 0
    for path in paths:
        with open(path) as f:
            errors = check(f.read())
        if errors:
            status = 1
            print(f"{path}: FAIL ({len(errors)} issue(s))")
            for e in errors[:20]:
                print(f"  {e}")
        else:
            print(f"{path}: OK")
    return status


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
