"""Regenerate EXPERIMENTS.md from artifacts (run after dry-run sweeps)."""
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
from benchmarks.make_report import (load, dryrun_table, roofline_table,
                                    compare_table)

ROOT = pathlib.Path(__file__).resolve().parents[1]

base = load("dryrun_baseline")
opt = load("dryrun_opt")

CELLS = [("qwen2-0.5b", "train_4k"), ("olmoe-1b-7b", "train_4k"),
         ("gemma2-27b", "train_4k")]


def summary():
    lines = []
    for a, s in CELLS:
        b = base[(a, s, "pod16x16")]["roofline"]
        o = opt[(a, s, "pod16x16")]["roofline"]
        bt = (base[(a, s, "pod16x16")].get("memory_analysis") or {}).get(
            "temp_size_in_bytes", 0) / 1e9
        ot = (opt[(a, s, "pod16x16")].get("memory_analysis") or {}).get(
            "temp_size_in_bytes", 0) / 1e9
        lines.append(
            f"* **{a} × {s}** — step-time bound {b['step_time_s']:.2f}s → "
            f"{o['step_time_s']:.2f}s ({b['step_time_s']/o['step_time_s']:.2f}×), "
            f"roofline fraction {b['roofline_frac']:.4f} → "
            f"{o['roofline_frac']:.4f} "
            f"({o['roofline_frac']/max(b['roofline_frac'],1e-12):.2f}×), "
            f"peak temp {bt:.1f} → {ot:.1f} GB/device "
            f"({'fits' if ot <= 16 else 'exceeds'} v5e HBM).")
    return "\n".join(lines)


tables = "\n\n".join([
    "### Dry-run (single pod 16x16, baseline)\n\n" + dryrun_table(base, "pod16x16"),
    "### Dry-run (multi-pod 2x16x16, baseline)\n\n" + dryrun_table(base, "pod2x16x16"),
    "### Roofline (single pod, baseline)\n\n" + roofline_table(base),
])
opt_tables = "\n\n".join([
    "### Dry-run + roofline (single pod, OPTIMIZED — all §Perf iterations on, microbatch=4 for train cells)\n\n"
    + roofline_table(opt),
    "### Optimized vs baseline (hillclimbed cells)\n\n"
    + compare_table(base, opt, CELLS),
])

doc = (ROOT / "EXPERIMENTS.md").read_text()
# splice the baseline tables block between the markers
start = doc.index("### Dry-run (single pod")
end = doc.index("Baseline observations")
doc = doc[:start] + tables + "\n\n" + doc[end:]
doc = doc.replace("OPTIMIZED_TABLES_PLACEHOLDER", opt_tables)
doc = doc.replace("SUMMARY_PLACEHOLDER", summary())
(ROOT / "EXPERIMENTS.md").write_text(doc)
print("EXPERIMENTS.md regenerated:", len(doc.splitlines()), "lines")
