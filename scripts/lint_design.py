"""Compile one design and run the stage-boundary verifier — lint mode.

Compiles the requested model through the full flow (lower -> chaining ->
pipelining -> sharing -> RTL netlist), running ``repro.core.verify`` at
every stage boundary, and prints the diagnostic table.  Exit status is
nonzero iff any error-severity finding fired — warnings print but pass —
so the script doubles as a pre-commit / CI lint gate for a design:

    PYTHONPATH=src python scripts/lint_design.py --model ffnn --factor 2
    PYTHONPATH=src python scripts/lint_design.py --model attention \
        --factor 4 --opt-level 2 --no-share
    PYTHONPATH=src python scripts/lint_design.py --model ffnn --factor 4 \
        --profile        # + traced profiling run with counter cross-check

``--profile`` additionally verifies the profiled netlist (the RV05x
counter-bank checks), runs both simulators with tracing on a fixed
random input, and fails if any level of the observability differential
(stats, trace aggregates, hardware counter bank, analytic attribution)
disagrees.

Models: the four benchmark microdesigns (matmul, conv2d, ffnn,
attention) plus the paper's cnn and mha.  A compile whose boundary check
raises ``VerificationError`` still prints the offending stage's table
before exiting 1 — the table, not the traceback, is the product.
"""
from __future__ import annotations

import argparse
import sys

from repro.core import diagnostics, frontend, pipeline

MODELS = {
    "matmul": (lambda: frontend.Linear(8, 8, bias=False), (4, 8)),
    "conv2d": (lambda: frontend.Conv2d(2, 2, 3, 3), (2, 6, 6)),
    "ffnn": (frontend.paper_ffnn, (1, 64)),
    "attention": (lambda: frontend.MultiheadAttention(8, 2), (4, 8)),
    "cnn": (frontend.paper_cnn, (3, 80, 60)),
    "mha": (frontend.paper_mha, (8, 42)),
}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", choices=list(MODELS), default="ffnn")
    ap.add_argument("--factor", type=int, default=2, choices=(1, 2, 4))
    ap.add_argument("--opt-level", type=int, default=2, choices=(0, 1, 2))
    ap.add_argument("--no-share", action="store_true")
    ap.add_argument("--mode", choices=("layout", "branchy"),
                    default="layout")
    ap.add_argument("--profile", action="store_true",
                    help="also verify the profiled netlist (RV05x) and "
                         "run the traced counter cross-check")
    args = ap.parse_args()

    builder, shape = MODELS[args.model]
    print(f"lint {args.model} factor={args.factor} "
          f"opt_level={args.opt_level} share={not args.no_share} "
          f"mode={args.mode}")
    try:
        d = pipeline.compile_model(builder(), [shape], factor=args.factor,
                                   mode=args.mode,
                                   check_hazards=args.mode == "layout",
                                   share=not args.no_share,
                                   opt_level=args.opt_level)
        d.to_rtl()
        if args.profile:
            d.to_rtl(profile=True)   # RV05x counter-bank checks
        reports = d.verify_reports
    except diagnostics.VerificationError as exc:
        print(diagnostics.render_table([exc.report]))
        print(f"\nFAIL: {len(exc.report.errors())} error(s) at "
              f"{exc.report.stage}")
        return 1
    print(diagnostics.render_table(reports))
    if args.profile:
        import numpy as np
        x = np.random.default_rng(0).normal(size=shape).astype(np.float32)
        prof = d.profile({"arg0": x})
        if prof.mismatches:
            for m in prof.mismatches:
                print(f"  counter mismatch: {m}")
            print(f"\nFAIL: {len(prof.mismatches)} observability "
                  f"mismatch(es)")
            return 1
        print(f"profile: {prof.cycles} cycles, counters agree across "
              f"sim / rtl_sim / traces / hardware bank")
    errors = sum(len(r.errors()) for r in reports)
    warnings = sum(len(r.warnings()) for r in reports)
    if errors:
        print(f"\nFAIL: {errors} error(s), {warnings} warning(s)")
        return 1
    verdict = "clean" if not warnings else f"{warnings} warning(s)"
    print(f"\nOK: {len(reports)} stage(s) checked, {verdict}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
