"""Perf-regression gate over BENCH_calyx.json.

Compares a freshly generated benchmark file against the committed
baseline and fails (exit 1) if any matching point's cycle count exceeds
the baseline by more than the tolerance (default 2%).  Points are
matched on (design, banks, share, opt_level); a schema-2 baseline (which
predates the scheduling layer) is read as opt_level 0.  Points present
only on one side are reported but never fail the gate — new designs and
a trimmed CI matrix are both expected.

Schema-4 files also carry per-point ``compile_us``/``verify_us`` stamps
(the stage-boundary verifier's share of compile time); the gate fails if
the aggregate verifier overhead — sum(verify_us) / sum(compile_us) over
the new file — exceeds ``--verify-overhead`` (default 15%; the
five-boundary suite measures ~13-14% across the full matrix, see the
"Static verification" section of the README).  Older files without the
stamps skip that check.

Schema-5 files additionally stamp the tracing-off simulator wall clock
per point (``sim_wall_us``); the gate fails if the new file's aggregate
tracing-off sim time grew beyond ``--sim-wall-overhead`` relative to the
baseline's — tracking the disabled trace hook's <2% overhead contract
across PRs (the hook is a single ``tracer is not None`` branch by
construction; the CI budget is looser than 2% because shared-runner
wall clocks are noisy, but a hook creeping into the hot path shows up
here as a step change).  The check is skipped when either file predates
the stamp — schema-4 baselines are read forward-compatibly.

    PYTHONPATH=src python scripts/check_perf_regression.py \
        --baseline BENCH_calyx.json --new /tmp/bench_new.json

The gate also covers ``BENCH_serve.json`` (the serving load harness,
``benchmarks/serve_bench.py``) via ``--serve-baseline``/``--serve-new``:
points are matched on (arch, profile) and fail when the new p99 TTFT
grows — or tokens/sec shrinks — beyond ``--serve-tolerance`` (default
3.0, i.e. 4x; latency quantiles of second-long CPU replays on shared
runners are far noisier than cycle counts, so this catches order-of-
magnitude breakage, not percent drift).  Independently of the baseline,
every new serve point's ``trace_overhead`` (tracing-off vs tracing-on
per-tick wall, measured in lockstep by the bench) must stay under
``--serve-trace-overhead`` (default 5%), every point must be
``deterministic`` and every request must have completed.  Either gate
(calyx, serve) may be run alone by passing only its file pair.

    PYTHONPATH=src python scripts/check_perf_regression.py \
        --serve-baseline BENCH_serve.json --serve-new /tmp/serve_new.json

``BENCH_model.json`` (the per-operator decode profiles,
``benchmarks/model_profile_bench.py``) is gated via
``--model-baseline``/``--model-new``: points are matched on arch.  Three
checks per new point, mirroring the bench's own contracts:

* ``record_overhead`` (recording vs record-off sliced engines, measured
  in lockstep by the bench) must stay under ``--model-overhead``
  (default 5%) — exact, like the serve trace-overhead gate;
* the analytic-vs-HLO cross-check must hold exactly as committed:
  ``flops_rel_err`` within ``--model-flops-rtol`` and ``bytes_ratio``
  inside the ``--model-bytes-factor`` band (defaults match
  ``repro.obs.modelprof``'s calibrated constants — this is a determinism
  check on the cost model, not a wall clock, so there is no noise
  allowance);
* per-operator mean walls against the baseline at
  ``--model-tolerance`` (default 3.0 = 4x — cross-machine microsecond
  walls of sub-millisecond segments; catches an operator suddenly
  dominating, not percent drift).  The stream must also be
  ``deterministic`` and the join coverage p50 positive.

    PYTHONPATH=src python scripts/check_perf_regression.py \
        --model-baseline BENCH_model.json --model-new /tmp/model_new.json

``BENCH_resilience.json`` (the chaos/goodput harness,
``benchmarks/resilience_bench.py``) is gated via
``--resilience-baseline``/``--resilience-new``: ``zero_fault`` points
must keep ``resilience_overhead`` under ``--resilience-overhead``
(default 5%) and stay token-equivalent to the plain engine; every fault
campaign must be ``deterministic``, lose zero requests, and hold
``goodput`` at or above ``--resilience-goodput`` (default 0.90).
Against the baseline, a campaign's goodput may not drop by more than
0.05 absolute — goodput is a seeded count ratio, not a wall clock, so
the band only absorbs intentional campaign retuning, not noise.

    PYTHONPATH=src python scripts/check_perf_regression.py \
        --resilience-baseline BENCH_resilience.json \
        --resilience-new /tmp/resilience_new.json
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, Tuple

Key = Tuple[str, int, bool, int]


def load(path: str) -> Tuple[int, Dict[Key, int],
                             Tuple[float, float], Dict[Key, float]]:
    with open(path) as f:
        data = json.load(f)
    schema = data.get("schema", 0)
    rows: Dict[Key, int] = {}
    sim_wall: Dict[Key, float] = {}
    compile_us = verify_us = 0.0
    for rec in data.get("records", []):
        if "error" in rec or "cycles" not in rec:
            continue
        key = (rec["design"], int(rec["banks"]), bool(rec["share"]),
               int(rec.get("opt_level", 0)))
        rows[key] = int(rec["cycles"])
        compile_us += float(rec.get("compile_us", 0.0))
        verify_us += float(rec.get("verify_us", 0.0))
        if "sim_wall_us" in rec:            # schema 5+
            sim_wall[key] = float(rec["sim_wall_us"])
    return schema, rows, (compile_us, verify_us), sim_wall


def load_serve(path: str) -> Dict[Tuple[str, str], dict]:
    with open(path) as f:
        data = json.load(f)
    return {(rec["arch"], rec["profile"]): rec
            for rec in data.get("records", [])}


def check_serve(args) -> Tuple[list, list]:
    """Returns (regressions, contract_failures) over the serve files."""
    base = load_serve(args.serve_baseline) if args.serve_baseline else {}
    new = load_serve(args.serve_new)
    regressions = []
    contract = []
    for key, rec in sorted(new.items()):
        ovh = float(rec.get("trace_overhead", 0.0))
        tag = "ok" if ovh < args.serve_trace_overhead else "FAIL"
        print(f"  serve {key}: trace_overhead={ovh:+.1%} "
              f"(limit {args.serve_trace_overhead:.0%}) {tag}")
        if ovh >= args.serve_trace_overhead:
            contract.append(f"{key}: trace overhead {ovh:+.1%}")
        if not rec.get("deterministic", False):
            contract.append(f"{key}: span stream not deterministic")
        if rec.get("completed") != rec.get("requests"):
            contract.append(
                f"{key}: {rec.get('completed')}/{rec.get('requests')} "
                f"requests completed")
        if key not in base:
            if base:
                print(f"  serve {key}: new point (no baseline)")
            continue
        ref = base[key]
        tol = args.serve_tolerance
        for metric, worse_is_bigger in (("ttft_us", True),
                                        ("tokens_per_sec", False)):
            new_v = rec["ttft_us"]["p99"] if worse_is_bigger \
                else float(rec[metric])
            ref_v = ref["ttft_us"]["p99"] if worse_is_bigger \
                else float(ref[metric])
            if ref_v <= 0:
                continue
            delta = (new_v - ref_v) / ref_v
            bad = (new_v > ref_v * (1.0 + tol)) if worse_is_bigger \
                else (new_v < ref_v / (1.0 + tol))
            name = "ttft_p99" if worse_is_bigger else metric
            print(f"  serve {key}: {name} {ref_v:.0f} -> {new_v:.0f} "
                  f"({delta:+.1%}) {'REGRESSION' if bad else 'ok'}")
            if bad:
                regressions.append(f"{key}: {name} {delta:+.1%} beyond "
                                   f"{tol:.0%} tolerance")
    return regressions, contract


def load_model(path: str) -> Dict[str, dict]:
    with open(path) as f:
        data = json.load(f)
    return {rec["arch"]: rec for rec in data.get("records", [])}


def check_model(args) -> Tuple[list, list]:
    """Returns (regressions, contract_failures) over the model files."""
    base = load_model(args.model_baseline) if args.model_baseline else {}
    new = load_model(args.model_new)
    regressions = []
    contract = []
    for arch, rec in sorted(new.items()):
        ovh = float(rec.get("record_overhead", 0.0))
        tag = "ok" if ovh < args.model_overhead else "FAIL"
        print(f"  model {arch}: record_overhead={ovh:+.1%} "
              f"(limit {args.model_overhead:.0%}) {tag}")
        if ovh >= args.model_overhead:
            contract.append(f"{arch}: record overhead {ovh:+.1%}")
        if not rec.get("deterministic", False):
            contract.append(f"{arch}: layer stream not deterministic")
        cc = rec.get("crosscheck", {})
        rel = float(cc.get("flops_rel_err", 0.0))
        ratio = float(cc.get("bytes_ratio", 1.0))
        ok_cc = (rel <= args.model_flops_rtol
                 and 1.0 / args.model_bytes_factor <= ratio
                 <= args.model_bytes_factor)
        print(f"  model {arch}: flops_rel_err={rel:.4f} "
              f"bytes_ratio={ratio:.2f} {'ok' if ok_cc else 'FAIL'}")
        if not ok_cc:
            contract.append(f"{arch}: analytic/HLO cross-check broken "
                            f"(rel_err={rel:.4f}, ratio={ratio:.2f})")
        cov = rec.get("coverage", {}).get("p50", 0.0)
        if cov <= 0:
            contract.append(f"{arch}: join coverage p50 {cov}")
        if arch not in base:
            if base:
                print(f"  model {arch}: new point (no baseline)")
            continue
        ref_walls = {r["op"]: float(r["wall_us_mean"])
                     for r in base[arch].get("offload", [])}
        for row in rec.get("offload", []):
            op, new_v = row["op"], float(row["wall_us_mean"])
            ref_v = ref_walls.get(op)
            if ref_v is None or ref_v <= 0:
                continue
            delta = (new_v - ref_v) / ref_v
            bad = new_v > ref_v * (1.0 + args.model_tolerance)
            print(f"  model {arch}.{op}: wall {ref_v:.1f} -> {new_v:.1f}us "
                  f"({delta:+.1%}) {'REGRESSION' if bad else 'ok'}")
            if bad:
                regressions.append(
                    f"{arch}.{op}: wall {delta:+.1%} beyond "
                    f"{args.model_tolerance:.0%} tolerance")
    return regressions, contract


def load_resilience(path: str) -> Dict[tuple, dict]:
    with open(path) as f:
        data = json.load(f)
    out = {}
    for rec in data.get("records", []):
        key = (rec["arch"], rec["profile"], rec["campaign"],
               rec.get("policy", ""), rec.get("fault_rate", 0.0))
        out[key] = rec
    return out


def check_resilience(args) -> Tuple[list, list]:
    """Returns (regressions, contract_failures) over the chaos files."""
    base = load_resilience(args.resilience_baseline) \
        if args.resilience_baseline else {}
    new = load_resilience(args.resilience_new)
    regressions = []
    contract = []
    for key, rec in sorted(new.items()):
        name = "/".join(str(k) for k in key if k != "")
        if rec["campaign"] == "zero_fault":
            ovh = float(rec.get("resilience_overhead", 0.0))
            tag = "ok" if ovh < args.resilience_overhead else "FAIL"
            print(f"  resilience {name}: overhead={ovh:+.1%} "
                  f"(limit {args.resilience_overhead:.0%}) {tag}")
            if ovh >= args.resilience_overhead:
                contract.append(f"{name}: armed zero-fault overhead "
                                f"{ovh:+.1%}")
            if not rec.get("equivalent", False):
                contract.append(f"{name}: armed engine diverged from "
                                f"the plain engine")
            continue
        goodput = float(rec.get("goodput", 0.0))
        lost = int(rec.get("lost", 0))
        det = bool(rec.get("deterministic", False))
        ok = (goodput >= args.resilience_goodput and lost == 0 and det)
        print(f"  resilience {name}: goodput={goodput:.2f} "
              f"(floor {args.resilience_goodput:.2f}) lost={lost} "
              f"det={det} {'ok' if ok else 'FAIL'}")
        if goodput < args.resilience_goodput:
            contract.append(f"{name}: goodput {goodput:.2f} below "
                            f"{args.resilience_goodput:.2f}")
        if lost:
            contract.append(f"{name}: {lost} request(s) lost")
        if not det:
            contract.append(f"{name}: chaos replay not deterministic")
        ref = base.get(key)
        if ref is None:
            if base:
                print(f"  resilience {name}: new point (no baseline)")
            continue
        drop = float(ref.get("goodput", 0.0)) - goodput
        if drop > 0.05:
            regressions.append(f"{name}: goodput dropped "
                               f"{drop:.2f} vs baseline")
    return regressions, contract


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline",
                    help="committed BENCH_calyx.json")
    ap.add_argument("--new",
                    help="freshly generated benchmark JSON")
    ap.add_argument("--tolerance", type=float, default=0.02,
                    help="allowed relative cycle growth (default 2%%)")
    ap.add_argument("--verify-overhead", type=float, default=0.15,
                    help="max verifier share of compile time over the new "
                         "file's matrix (default 15%%; schema 4+ only)")
    ap.add_argument("--sim-wall-overhead", type=float, default=None,
                    help="max relative growth of the aggregate tracing-off "
                         "sim wall clock vs the baseline (schema 5+ on "
                         "both sides; skipped when unset or unstamped)")
    ap.add_argument("--serve-baseline",
                    help="committed BENCH_serve.json")
    ap.add_argument("--serve-new",
                    help="freshly generated serve benchmark JSON")
    ap.add_argument("--serve-tolerance", type=float, default=3.0,
                    help="allowed relative p99-TTFT growth / tokens-per-sec "
                         "shrink vs the serve baseline (default 3.0 = 4x; "
                         "serve walls are cross-machine noisy)")
    ap.add_argument("--serve-trace-overhead", type=float, default=0.05,
                    help="max per-point tracing overhead in the new serve "
                         "file (default 5%%)")
    ap.add_argument("--model-baseline",
                    help="committed BENCH_model.json")
    ap.add_argument("--model-new",
                    help="freshly generated model profile JSON")
    ap.add_argument("--model-overhead", type=float, default=0.05,
                    help="max per-point layer-record overhead in the new "
                         "model file (default 5%%)")
    ap.add_argument("--model-tolerance", type=float, default=3.0,
                    help="allowed relative per-operator wall growth vs the "
                         "model baseline (default 3.0 = 4x; microsecond "
                         "segment walls are cross-machine noisy)")
    ap.add_argument("--model-flops-rtol", type=float, default=0.02,
                    help="max analytic-vs-HLO flops relative error "
                         "(matches repro.obs.modelprof.FLOPS_RTOL)")
    ap.add_argument("--model-bytes-factor", type=float, default=5.0,
                    help="analytic-vs-HLO bytes ratio band (matches "
                         "repro.obs.modelprof.BYTES_FACTOR)")
    ap.add_argument("--resilience-baseline",
                    help="committed BENCH_resilience.json")
    ap.add_argument("--resilience-new",
                    help="freshly generated resilience benchmark JSON")
    ap.add_argument("--resilience-goodput", type=float, default=0.90,
                    help="minimum goodput every fault campaign in the new "
                         "resilience file must hold (default 0.90)")
    ap.add_argument("--resilience-overhead", type=float, default=0.05,
                    help="max armed-but-idle per-tick overhead in the new "
                         "resilience file's zero_fault points (default 5%%)")
    args = ap.parse_args()
    if bool(args.baseline) != bool(args.new):
        ap.error("--baseline and --new must be given together")
    if args.serve_baseline and not args.serve_new:
        ap.error("--serve-baseline requires --serve-new")
    if args.model_baseline and not args.model_new:
        ap.error("--model-baseline requires --model-new")
    if args.resilience_baseline and not args.resilience_new:
        ap.error("--resilience-baseline requires --resilience-new")
    if not args.new and not args.serve_new and not args.model_new \
            and not args.resilience_new:
        ap.error("give --baseline/--new, --serve-new, --model-new and/or "
                 "--resilience-new")

    regressions = []
    improved = 0
    new = {}
    overhead_fail = None
    sim_wall_fail = None
    if args.new:
        _, base, _, base_sim_wall = load(args.baseline)
        _, new, (compile_us, verify_us), new_sim_wall = load(args.new)
        for key, cycles in sorted(new.items()):
            if key not in base:
                print(f"  new point (no baseline): {key} -> {cycles} "
                      f"cycles")
                continue
            ref = base[key]
            delta = (cycles - ref) / ref if ref else 0.0
            tag = "ok"
            if cycles > ref * (1.0 + args.tolerance):
                regressions.append((key, ref, cycles, delta))
                tag = "REGRESSION"
            elif cycles < ref:
                improved += 1
                tag = "improved"
            print(f"  {key}: {ref} -> {cycles} cycles ({delta:+.1%}) "
                  f"{tag}")
        missing = sorted(set(base) - set(new))
        if missing:
            print(f"  ({len(missing)} baseline points not regenerated — "
                  f"trimmed matrix)")
        if compile_us > 0 and verify_us > 0:
            ratio = verify_us / compile_us
            tag = "ok" if ratio < args.verify_overhead else "FAIL"
            print(f"  verifier overhead: {verify_us / 1e3:.1f}ms of "
                  f"{compile_us / 1e3:.1f}ms compile = {ratio:.1%} "
                  f"(limit {args.verify_overhead:.0%}) {tag}")
            if ratio >= args.verify_overhead:
                overhead_fail = ratio
        shared = sorted(set(base_sim_wall) & set(new_sim_wall))
        if args.sim_wall_overhead is not None and shared:
            base_sum = sum(base_sim_wall[k] for k in shared)
            new_sum = sum(new_sim_wall[k] for k in shared)
            if base_sum > 0:
                growth = (new_sum - base_sum) / base_sum
                tag = "ok" if growth < args.sim_wall_overhead else "FAIL"
                print(f"  sim wall clock (tracing off, {len(shared)} "
                      f"shared points): {base_sum / 1e3:.1f}ms -> "
                      f"{new_sum / 1e3:.1f}ms ({growth:+.1%}, limit "
                      f"+{args.sim_wall_overhead:.0%}) {tag}")
                if growth >= args.sim_wall_overhead:
                    sim_wall_fail = growth
        elif args.sim_wall_overhead is not None:
            print("  sim wall clock check skipped (no shared schema-5 "
                  "points)")
    serve_regressions, serve_contract = ([], [])
    if args.serve_new:
        serve_regressions, serve_contract = check_serve(args)
    model_regressions, model_contract = ([], [])
    if args.model_new:
        model_regressions, model_contract = check_model(args)
    res_regressions, res_contract = ([], [])
    if args.resilience_new:
        res_regressions, res_contract = check_resilience(args)
    if regressions:
        print(f"\nFAIL: {len(regressions)} point(s) regressed beyond "
              f"{args.tolerance:.0%}:")
        for key, ref, cycles, delta in regressions:
            print(f"  {key}: {ref} -> {cycles} ({delta:+.1%})")
        return 1
    if overhead_fail is not None:
        print(f"\nFAIL: stage-boundary verifier costs {overhead_fail:.1%} "
              f"of compile time (limit {args.verify_overhead:.0%})")
        return 1
    if sim_wall_fail is not None:
        print(f"\nFAIL: tracing-off sim wall clock grew "
              f"{sim_wall_fail:+.1%} over the baseline (limit "
              f"+{args.sim_wall_overhead:.0%})")
        return 1
    if serve_regressions or serve_contract:
        for msg in serve_regressions + serve_contract:
            print(f"\nFAIL: serve {msg}")
        return 1
    if model_regressions or model_contract:
        for msg in model_regressions + model_contract:
            print(f"\nFAIL: model {msg}")
        return 1
    if res_regressions or res_contract:
        for msg in res_regressions + res_contract:
            print(f"\nFAIL: resilience {msg}")
        return 1
    print(f"\nOK: no regressions (calyx: {improved} improved, "
          f"{len(new)} points checked)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
