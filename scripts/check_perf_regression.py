"""Perf-regression gate over BENCH_calyx.json.

Compares a freshly generated benchmark file against the committed
baseline and fails (exit 1) if any matching point's cycle count exceeds
the baseline by more than the tolerance (default 2%).  Points are
matched on (design, banks, share, opt_level); a schema-2 baseline (which
predates the scheduling layer) is read as opt_level 0.  Points present
only on one side are reported but never fail the gate — new designs and
a trimmed CI matrix are both expected.

    PYTHONPATH=src python scripts/check_perf_regression.py \
        --baseline BENCH_calyx.json --new /tmp/bench_new.json
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, Tuple

Key = Tuple[str, int, bool, int]


def load(path: str) -> Tuple[int, Dict[Key, int]]:
    with open(path) as f:
        data = json.load(f)
    schema = data.get("schema", 0)
    rows: Dict[Key, int] = {}
    for rec in data.get("records", []):
        if "error" in rec or "cycles" not in rec:
            continue
        key = (rec["design"], int(rec["banks"]), bool(rec["share"]),
               int(rec.get("opt_level", 0)))
        rows[key] = int(rec["cycles"])
    return schema, rows


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True,
                    help="committed BENCH_calyx.json")
    ap.add_argument("--new", required=True,
                    help="freshly generated benchmark JSON")
    ap.add_argument("--tolerance", type=float, default=0.02,
                    help="allowed relative cycle growth (default 2%%)")
    args = ap.parse_args()

    _, base = load(args.baseline)
    _, new = load(args.new)
    regressions = []
    improved = 0
    for key, cycles in sorted(new.items()):
        if key not in base:
            print(f"  new point (no baseline): {key} -> {cycles} cycles")
            continue
        ref = base[key]
        delta = (cycles - ref) / ref if ref else 0.0
        tag = "ok"
        if cycles > ref * (1.0 + args.tolerance):
            regressions.append((key, ref, cycles, delta))
            tag = "REGRESSION"
        elif cycles < ref:
            improved += 1
            tag = "improved"
        print(f"  {key}: {ref} -> {cycles} cycles ({delta:+.1%}) {tag}")
    missing = sorted(set(base) - set(new))
    if missing:
        print(f"  ({len(missing)} baseline points not regenerated — "
              f"trimmed matrix)")
    if regressions:
        print(f"\nFAIL: {len(regressions)} point(s) regressed beyond "
              f"{args.tolerance:.0%}:")
        for key, ref, cycles, delta in regressions:
            print(f"  {key}: {ref} -> {cycles} ({delta:+.1%})")
        return 1
    print(f"\nOK: no cycle regressions beyond {args.tolerance:.0%} "
          f"({improved} improved, {len(new)} points checked)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
