"""Deterministic synthetic LM data pipeline, host-sharded.

Every (step, host) batch is derived from a counter-based RNG, so the
pipeline is stateless and restart-safe: after a failure, resuming at step k
reproduces exactly the batches a non-failed run would have seen — a
prerequisite for the checkpoint/restart tests to assert bitwise-identical
training trajectories.

The token stream is structured (zipf-distributed unigrams + planted bigram
dependencies) so that a model can actually reduce loss on it.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from ..models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.3
    bigram_frac: float = 0.5      # fraction of positions forced to planted
                                  # bigram successors (learnable structure)


class SyntheticLM:
    """Host-sharded iterator of {'tokens': (B_local, S) int32} batches."""

    def __init__(self, cfg: DataConfig, host_id: int = 0,
                 host_count: int = 1, model_cfg: Optional[ModelConfig] = None):
        assert cfg.global_batch % host_count == 0, (cfg.global_batch,
                                                    host_count)
        self.cfg = cfg
        self.host_id = host_id
        self.host_count = host_count
        self.local_batch = cfg.global_batch // host_count
        self.model_cfg = model_cfg
        # planted bigram table: token t -> deterministic successor
        rng = np.random.default_rng(cfg.seed)
        self._succ = rng.integers(0, cfg.vocab_size, size=cfg.vocab_size,
                                  dtype=np.int32)
        # zipf-ish unigram distribution over the vocab
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_a)
        self._p = p / p.sum()

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        """Deterministic batch for (step, host)."""
        c = self.cfg
        rng = np.random.default_rng(
            (c.seed * 1_000_003 + step) * 65_537 + self.host_id)
        toks = rng.choice(c.vocab_size, p=self._p,
                          size=(self.local_batch, c.seq_len)).astype(np.int32)
        # plant bigrams sequentially so chains survive:
        # with prob bigram_frac, position i = succ(position i-1)
        mask = rng.random((self.local_batch, c.seq_len - 1)) < c.bigram_frac
        for i in range(1, c.seq_len):
            toks[:, i] = np.where(mask[:, i - 1],
                                  self._succ[toks[:, i - 1]], toks[:, i])
        out = {"tokens": toks}
        mc = self.model_cfg
        if mc is not None and mc.family == "vlm":
            out["modality"] = rng.normal(size=(
                self.local_batch, mc.num_patches, mc.d_model)).astype(
                    np.float32).astype(mc.dtype)
        if mc is not None and mc.family == "audio":
            out["modality"] = rng.normal(size=(
                self.local_batch, mc.encoder_seq, mc.d_model)).astype(
                    np.float32).astype(mc.dtype)
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1
