"""Seeded synthetic heavy-traffic traces for the serving load harness.

Arrivals are Poisson in *engine-step* time — inter-arrival gaps are drawn
from an exponential distribution and accumulated, then floored to the step
grid — so a trace is a deterministic function of its seed (wall-clock
arrival times would not be).  Prompt and generation lengths are sampled
independently per request from the given mixes, modelling the mixed
short-chat / long-generation traffic the continuous-batching scheduler
(ROADMAP item 5) must eventually handle.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class TraceRequest:
    """One synthetic request: joins the engine queue once the engine has
    executed ``arrival_step`` steps."""
    rid: int
    arrival_step: int
    prompt_len: int
    gen_len: int


def synth_trace(seed: int, requests: int, mean_interarrival: float,
                prompt_lens: Sequence[int], gen_lens: Sequence[int]
                ) -> List[TraceRequest]:
    """Draw a seeded trace of ``requests`` requests.

    ``mean_interarrival`` is the mean gap between arrivals in engine
    steps; 0 makes every request arrive at step 0 (closed-loop burst).
    """
    if requests <= 0:
        return []
    rng = np.random.default_rng(seed)
    if mean_interarrival > 0:
        gaps = rng.exponential(mean_interarrival, size=requests)
        arrivals = np.floor(np.cumsum(gaps) - gaps[0]).astype(int)
    else:
        arrivals = np.zeros(requests, dtype=int)
    plens = rng.choice(np.asarray(prompt_lens, dtype=int), size=requests)
    glens = rng.choice(np.asarray(gen_lens, dtype=int), size=requests)
    return [TraceRequest(i, int(arrivals[i]), int(plens[i]), int(glens[i]))
            for i in range(requests)]


def total_tokens(trace: Sequence[TraceRequest]) -> int:
    """Prompt + generation tokens over the whole trace — an upper bound on
    the engine steps (and cache positions) a serial replay needs."""
    return sum(r.prompt_len + r.gen_len for r in trace)
