"""Serving-side observability: metrics registry + request-span tracing.

The runtime mirror of the hardware path's ``core.trace``/``core.profiler``
stack (PR 7): one canonical schema per surface, zero-cost-when-off hooks
(every instrumentation site in the engine is guarded by
``if metrics is not None`` / ``if spans is not None``), and exporters whose
output is deterministic under a fixed seed (``stable=True`` normalizes the
wall-clock fields, everything else is already byte-stable).

Modules
-------
``metrics``   process-local counters/gauges/fixed-bucket histograms with
              Prometheus-text and JSON exporters
``spans``     per-request span events (enqueue -> admit -> prefill ->
              decode -> complete) + per-engine-step events, JSONL
``traffic``   seeded synthetic heavy-traffic traces (Poisson arrivals,
              mixed prompt/gen lengths) for the load harness
"""
from .metrics import Counter, Gauge, Histogram, MetricsRegistry  # noqa: F401
from .spans import SpanEvent, SpanTracer  # noqa: F401
from .traffic import TraceRequest, synth_trace  # noqa: F401
