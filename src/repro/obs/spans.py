"""Request-span tracing for the serving runtime.

One span per request — the phase chain
``enqueue -> admit -> prefill -> decode(first_token) -> complete`` — plus
one event per engine step carrying slot occupancy, queue depth, and tokens
emitted.  The schema follows the ``kind``/provenance conventions of the
hardware path's ``core.trace`` (namespaced ``kind`` strings, a ``prov``
tuple naming the event's position in the runtime "control tree", explicit
JSON key order so serialization is byte-stable), so a future compiled-kernel
serve step can nest a hardware profile inside a request span by extending
the same stream.

Event kinds
-----------

==================  =========================================================
kind                meaning
==================  =========================================================
``req:enqueue``     request submitted to the engine queue
``req:admit``       request claimed a slot (``slot`` set from here on)
``req:prefill``     first prompt token fed — prefill phase begins
``req:first_token`` first generated token emitted (TTFT stamp)
``req:complete``    slot released; ``detail`` = ``finished`` or
                    ``truncated:<reason>``; ``data`` = (tokens_generated,)
``req:retry``       slot quarantined and the request requeued for another
                    attempt; ``detail`` = ``quarantine:<cause>``;
                    ``data`` = (attempt_just_failed, backoff_ticks).
                    Splits the request span into attempts — phases after
                    a retry restart from ``admit``
``step``            one engine step; ``data`` = (slots_occupied,
                    queue_depth, tokens_emitted, prompt_tokens_fed);
                    ``dur_us`` = step wall time, stamped only after
                    ``jax.block_until_ready`` on the step outputs; a
                    step lost to an injected exception carries
                    ``detail`` = ``fault:exception``
``engine:health``   engine health transition; ``detail`` = the new state
                    (``healthy``/``degraded``/``draining``), ``data`` =
                    (state_code,)
==================  =========================================================

Provenance: request events carry ``("req<rid>",)``; step events carry
``("engine", "s<step>")`` — the serving analogue of ``core.trace``'s
control-tree paths.

Determinism
-----------

Under a fixed seed the event *structure* (kinds, order, rids, slots,
counts) is fully deterministic; only the wall-clock fields ``ts_us`` and
``dur_us`` vary run-to-run.  ``to_jsonl(events, stable=True)`` — the
exporters' ``--stable`` mode — normalizes exactly those two fields
(``ts_us`` becomes the event's ordinal in the stream, ``dur_us`` becomes
0), making the serialized stream byte-identical across runs; the
determinism tests and the CI artifact diff rely on this.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

# -- event kinds -------------------------------------------------------------
REQ_ENQUEUE = "req:enqueue"
REQ_ADMIT = "req:admit"
REQ_PREFILL = "req:prefill"
REQ_FIRST_TOKEN = "req:first_token"
REQ_COMPLETE = "req:complete"
REQ_RETRY = "req:retry"
STEP = "step"
HEALTH = "engine:health"

REQ_KINDS = (REQ_ENQUEUE, REQ_ADMIT, REQ_PREFILL, REQ_FIRST_TOKEN,
             REQ_COMPLETE)
# the phase order every request must respect within one attempt (missing
# phases are allowed for truncated requests, but present ones must appear
# in this order); a REQ_RETRY marker ends an attempt and the next one
# restarts from REQ_ADMIT
PHASE_ORDER = {k: i for i, k in enumerate(REQ_KINDS)}

FINISHED = "finished"
TRUNCATED_PREFIX = "truncated:"
QUARANTINE_PREFIX = "quarantine:"


def req_prov(rid: int) -> Tuple[str, ...]:
    return (f"req{rid}",)


def step_prov(step: int) -> Tuple[str, ...]:
    return ("engine", f"s{step}")


@dataclasses.dataclass(frozen=True)
class SpanEvent:
    """One serving event.  Only ``ts_us``/``dur_us`` are wall-clock; every
    other field is deterministic under a fixed seed."""
    ts_us: int                      # microseconds since tracer epoch
    kind: str
    prov: Tuple[str, ...] = ()
    step: int = -1                  # engine step index (-1 = pre-engine)
    rid: int = -1
    slot: int = -1
    detail: str = ""
    dur_us: int = 0
    data: Tuple[int, ...] = ()

    def to_json(self, stable_ts: Optional[int] = None) -> str:
        # explicit key order -> byte-stable serialization (cf. core.trace)
        ts = self.ts_us if stable_ts is None else stable_ts
        dur = self.dur_us if stable_ts is None else 0
        return json.dumps({"t": ts, "k": self.kind, "p": list(self.prov),
                           "s": self.step, "r": self.rid, "l": self.slot,
                           "d": self.detail, "n": dur,
                           "a": list(self.data)}, separators=(",", ":"))

    @staticmethod
    def from_json(line: str) -> "SpanEvent":
        o = json.loads(line)
        return SpanEvent(o["t"], o["k"], tuple(o["p"]), o["s"], o["r"],
                         o["l"], o["d"], o["n"],
                         tuple(int(v) for v in o["a"]))


class SpanTracer:
    """Event sink.  The engine accepts ``spans=None`` (the default) and
    guards every emission site with ``if spans is not None`` — the same
    zero-cost-when-off contract as ``core.trace.Tracer``."""

    __slots__ = ("events", "_clock", "_t0")

    def __init__(self, clock=time.perf_counter) -> None:
        self.events: List[SpanEvent] = []
        self._clock = clock
        self._t0 = clock()

    def now_us(self) -> int:
        return int((self._clock() - self._t0) * 1e6)

    def emit(self, kind: str, *, ts_us: Optional[int] = None,
             prov: Tuple[str, ...] = (), step: int = -1, rid: int = -1,
             slot: int = -1, detail: str = "", dur_us: int = 0,
             data: Tuple[int, ...] = ()) -> None:
        if ts_us is None:
            ts_us = self.now_us()
        self.events.append(SpanEvent(ts_us, kind, prov, step, rid, slot,
                                     detail, dur_us, data))


# -- serialization -----------------------------------------------------------


def to_jsonl(events: Iterable[SpanEvent], stable: bool = False) -> str:
    """One event per line, in emission order.  ``stable=True`` normalizes
    the wall-clock fields (``ts_us`` -> event ordinal, ``dur_us`` -> 0) so
    two same-seed runs serialize byte-identically."""
    if stable:
        return "".join(ev.to_json(stable_ts=i) + "\n"
                       for i, ev in enumerate(events))
    return "".join(ev.to_json() + "\n" for ev in events)


def from_jsonl(text: str) -> List[SpanEvent]:
    return [SpanEvent.from_json(line)
            for line in text.splitlines() if line.strip()]


# -- span assembly -----------------------------------------------------------


@dataclasses.dataclass
class RequestSummary:
    """The per-request span, assembled from the event stream."""
    rid: int
    enqueue_us: int = -1
    admit_us: int = -1
    prefill_us: int = -1
    first_token_us: int = -1
    complete_us: int = -1
    reason: str = ""
    tokens: int = 0
    slot: int = -1
    attempts: int = 1

    @property
    def ttft_us(self) -> int:
        """Enqueue-to-first-token (queueing + prefill included)."""
        if self.first_token_us < 0 or self.enqueue_us < 0:
            return -1
        return self.first_token_us - self.enqueue_us

    @property
    def decode_us_per_token(self) -> float:
        """Steady-state decode latency: first-token-to-complete over the
        tokens emitted after the first (undefined below 2 tokens)."""
        if self.tokens < 2 or self.first_token_us < 0:
            return float("nan")
        return (self.complete_us - self.first_token_us) / (self.tokens - 1)


_PHASE_FIELD = {REQ_ENQUEUE: "enqueue_us", REQ_ADMIT: "admit_us",
                REQ_PREFILL: "prefill_us", REQ_FIRST_TOKEN: "first_token_us",
                REQ_COMPLETE: "complete_us"}


def summarize(events: Sequence[SpanEvent]) -> Dict[int, RequestSummary]:
    """Assemble one :class:`RequestSummary` per request id."""
    spans: Dict[int, RequestSummary] = {}
    for ev in events:
        if ev.kind not in _PHASE_FIELD:
            continue
        s = spans.setdefault(ev.rid, RequestSummary(ev.rid))
        setattr(s, _PHASE_FIELD[ev.kind], ev.ts_us)
        if ev.slot >= 0:
            s.slot = ev.slot
        if ev.kind == REQ_COMPLETE:
            s.reason = ev.detail
            s.tokens = ev.data[0] if ev.data else 0
    for ev in events:
        if ev.kind == REQ_RETRY and ev.rid in spans:
            spans[ev.rid].attempts += 1
    return spans


# -- invariants --------------------------------------------------------------


def validate(events: Sequence[SpanEvent], slots: int = 0,
             engine_steps: int = -1) -> List[str]:
    """Span lifecycle invariants; returns violation strings (empty = ok).

    * every enqueued request completes (``finished``) or is truncated with
      a reason — exactly one complete, as the request's final event;
    * exactly one enqueue per request, as the request's first event (a
      retry re-admits, it never re-enqueues);
    * ``req:retry`` markers split the span into attempts; within each
      attempt present phases appear in ``PHASE_ORDER``, and timestamps are
      monotone non-decreasing across the whole request stream;
    * step events are contiguous (0..n-1) and, when ``engine_steps`` is
      given, count exactly ``engine_steps``;
    * slot occupancy never exceeds ``slots`` (when given) and the
      occupancy recorded on each step event matches the reconstructed
      in-flight count — a request occupies a slot over each
      [admit_step, release_step] interval, where release is the step of
      the attempt's ``req:retry`` or the final ``req:complete``.
    """
    out: List[str] = []
    per_req: Dict[int, List[SpanEvent]] = {}
    step_events: List[SpanEvent] = []
    for ev in events:
        if ev.kind == STEP:
            step_events.append(ev)
        elif ev.kind in PHASE_ORDER or ev.kind == REQ_RETRY:
            per_req.setdefault(ev.rid, []).append(ev)
        elif ev.kind != HEALTH:
            out.append(f"unknown event kind {ev.kind!r}")
    for rid, evs in sorted(per_req.items()):
        kinds = [e.kind for e in evs]
        n_enq = kinds.count(REQ_ENQUEUE)
        if n_enq == 0:
            out.append(f"req{rid}: no enqueue event")
        elif n_enq > 1:
            out.append(f"req{rid}: {n_enq} enqueue events (want exactly 1)")
        elif kinds[0] != REQ_ENQUEUE:
            out.append(f"req{rid}: enqueue is not the first event")
        if kinds.count(REQ_COMPLETE) != 1:
            out.append(f"req{rid}: {kinds.count(REQ_COMPLETE)} complete "
                       f"events (want exactly 1)")
        else:
            comp = evs[kinds.index(REQ_COMPLETE)]
            if comp.detail != FINISHED and \
                    not comp.detail.startswith(TRUNCATED_PREFIX):
                out.append(f"req{rid}: complete reason {comp.detail!r} is "
                           f"neither finished nor truncated:*")
            if kinds[-1] != REQ_COMPLETE:
                out.append(f"req{rid}: events after complete: "
                           f"{kinds[kinds.index(REQ_COMPLETE) + 1:]}")
        # split the span into attempts at retry markers; each attempt's
        # phases must independently respect PHASE_ORDER
        attempts: List[List[SpanEvent]] = [[]]
        for e in evs:
            attempts[-1].append(e)
            if e.kind == REQ_RETRY:
                attempts.append([])
        if not attempts[-1]:
            attempts.pop()
        for i, att in enumerate(attempts):
            order = [PHASE_ORDER[e.kind] for e in att
                     if e.kind in PHASE_ORDER]
            if order != sorted(order):
                out.append(f"req{rid} attempt {i + 1}: phases out of "
                           f"order: {[e.kind for e in att]}")
        ts = [e.ts_us for e in evs]
        if ts != sorted(ts):
            out.append(f"req{rid}: phase timestamps not monotone: {ts}")
    steps_seen = [e.step for e in step_events]
    if steps_seen != list(range(len(steps_seen))):
        out.append(f"step events not contiguous from 0: {steps_seen[:10]}")
    if engine_steps >= 0 and len(step_events) != engine_steps:
        out.append(f"{len(step_events)} step events but engine ran "
                   f"{engine_steps} steps")
    # reconstruct occupancy from the request lifecycle and check each step:
    # each admit opens a slot interval, closed (inclusive) by the step of
    # the attempt's retry marker or the final complete
    intervals: List[Tuple[int, int]] = []
    for rid, evs in per_req.items():
        opened = -1
        for e in evs:
            if e.kind == REQ_ADMIT:
                opened = e.step
            elif e.kind in (REQ_RETRY, REQ_COMPLETE) and opened >= 0:
                intervals.append((opened, e.step))
                opened = -1
        if opened >= 0:                     # admitted, never released
            intervals.append((opened, 1 << 62))
    for ev in step_events:
        occ = ev.data[0] if ev.data else 0
        if slots and occ > slots:
            out.append(f"step {ev.step}: occupancy {occ} > {slots} slots")
        expect = sum(1 for lo, hi in intervals if lo <= ev.step <= hi)
        if ev.data and occ != expect:
            out.append(f"step {ev.step}: occupancy {occ} but "
                       f"{expect} requests in flight")
    return out


def slot_utilization(events: Sequence[SpanEvent], slots: int) -> float:
    """Mean fraction of slots occupied over all engine steps."""
    occ = [ev.data[0] for ev in events if ev.kind == STEP and ev.data]
    if not occ or slots <= 0:
        return 0.0
    return sum(occ) / (len(occ) * slots)
