"""Per-layer / per-operator profiling for the jax model zoo.

The middle tier of the observability stack: serving spans (``obs.spans``)
record one opaque wall time per engine step; the hardware path records
cycle-exact counters per Calyx group (``core.profiler``).  This module
opens the black box between them — one record per *model operator* per
engine step, produced by the sliced decode step
(``models.decode.ProfiledServeStep``: embed / per-group attn · mlp ·
time_mix · channel_mix · scan · moe / head, each independently jitted and
wall-stamped after ``jax.block_until_ready``).

Three joined views per config:

* **measured** — :class:`LayerRecord` streams from :class:`LayerProfiler`,
  byte-stable JSONL with the span exporter's conventions;
* **analytic** — :func:`analytic_op_costs`: a dot-FLOPs/bytes/arithmetic-
  intensity cost model per operator derived from ``ModelConfig``,
  cross-checked against ``launch.hlo_analysis.analyze`` on the real
  decode-step HLO (:func:`crosscheck_hlo`);
* **joined** — layer records link to engine-step span events by step
  provenance (record prov ``("engine", "s<step>", "<op>[.g<G>]")`` extends
  span prov ``("engine", "s<step>")``); :func:`join_steps` /
  :func:`join_mismatches` close the request-span -> engine-step ->
  layer-op chain.

Record schema (JSON keys, fixed order -> byte-stable serialization)
-------------------------------------------------------------------

==  =======================================================================
t   ``ts_us`` — wall-clock stamp (tracer epoch); ordinal in stable mode
k   ``kind`` — always ``"layer"``
p   provenance tuple ``["engine", "s<step>", "<op>[.g<group>]"]``
s   engine step index
o   operator name (``embed``/``attn``/``mlp``/``moe``/``time_mix``/
    ``channel_mix``/``scan``/``attn_local``/... /``head``)
g   scan-group index (-1 for embed/head)
n   ``dur_us`` — segment wall microseconds, stamped post-``block_until_
    ready``; 0 in stable mode
==  =======================================================================

Contracts (gated by ``benchmarks/model_profile_bench.py`` +
``scripts/check_perf_regression.py --model-*``)
----------------------------------------------

* **record overhead < 5%, measured in lockstep**: two *profiled-mode*
  engines (both running the sliced step, so segment sync cost is identical)
  — one with ``LayerProfiler(record=False)``, one recording — driven
  through the identical schedule tick-for-tick.  This isolates the cost of
  *recording* (stamping + appending), exactly as PR 8's span contract
  isolated the tracing hooks from the engine's inherent per-step sync.
  The sliced-vs-fused execution delta is real but *inherent to profiling*
  (lost XLA fusion + one dispatch/sync per segment) and is reported
  separately as the informational ``slice_overhead``.
* **join closes**: every engine-step span maps to exactly one complete,
  in-order set of per-layer records (``profile_ops(cfg)``), and the summed
  segment walls cover at least ``JOIN_COVERAGE_MIN`` of the step wall
  without exceeding it (segments nest inside the step window; the residual
  is host-side driver work: token marshalling, argmax transfer, span
  emission).
* **analytic-vs-HLO cross-check**: summed analytic dot-FLOPs agree with
  the HLO analysis within ``FLOPS_RTOL`` (both count exactly the ``dot``
  ops); analytic bytes agree with HLO fusion-boundary traffic within a
  factor ``BYTES_FACTOR`` (the analytic model counts weights + state +
  activation I/O — a roofline denominator, not an XLA fusion simulator).
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

KIND = "layer"

# sum(segment walls)/step_wall must land in [JOIN_COVERAGE_MIN, 1]: the
# segments are timed inside the engine-step window, so they can never sum
# past it.  The residual is host-side driver work that is O(1) per step
# regardless of model size — token marshalling, the eager per-step argmax
# dispatch + host transfer, span/metric emission.  On the reduced CPU
# smoke configs that residual is ~0.7-0.8 of a ~1ms step (measured
# coverage band 0.19-0.35 typical for qwen2-0.5b reduced, with isolated
# slow steps — admission bursts, allocator/GC pauses — dipping to ~0.10),
# so the gate floor is deliberately low: it exists to catch a *broken*
# join (records from another run, misattributed steps, lost segments —
# those drive coverage to ~0), not to assert the reduced configs are
# compute-dominated.  The bench reports ``coverage_p50`` per config so
# drift stays visible.
JOIN_COVERAGE_MIN = 0.05

# analytic-vs-HLO tolerances.  Calibration (reduced configs, batch=2,
# cache_len=32): flops rel err 0.0 (qwen2-0.5b), 8e-4 (rwkv6-7b), 0.0
# (olmoe-1b-7b) — the analytic model counts exactly the dot ops
# hlo_analysis counts, the rwkv residual is XLA constant-folding one tiny
# lora contraction.  Bytes land at 0.25-0.32x of the HLO figure on these
# activation-dominated tiny configs (0.29-0.32 at cache_len=32, 0.25 at
# the bench's ~124-slot cache: hlo_analysis re-counts activations at
# every fusion boundary and charges dynamic-update-slice at 2x the full
# cache slice; the analytic model counts weights + state + activation
# I/O once — a roofline denominator, not a fusion simulator), so the
# bytes gate is a factor band wide enough to hold that ratio from both
# sides.  It catches order-of-magnitude model breakage, not fusion
# accounting drift.
FLOPS_RTOL = 0.02
BYTES_FACTOR = 5.0


def layer_prov(step: int, op: str, group: int) -> Tuple[str, ...]:
    """Extends ``spans.step_prov(step)`` by one level — the op label."""
    label = op if group < 0 else f"{op}.g{group}"
    return ("engine", f"s{step}", label)


@dataclasses.dataclass(frozen=True)
class LayerRecord:
    """One operator execution inside one engine step.  Only ``ts_us`` and
    ``dur_us`` are wall-clock; everything else is deterministic under a
    fixed seed."""
    ts_us: int
    op: str
    group: int
    step: int
    dur_us: int
    prov: Tuple[str, ...] = ()

    def to_json(self, stable_ts: Optional[int] = None) -> str:
        ts = self.ts_us if stable_ts is None else stable_ts
        dur = self.dur_us if stable_ts is None else 0
        return json.dumps({"t": ts, "k": KIND, "p": list(self.prov),
                           "s": self.step, "o": self.op, "g": self.group,
                           "n": dur}, separators=(",", ":"))

    @staticmethod
    def from_json(line: str) -> "LayerRecord":
        o = json.loads(line)
        if o.get("k") != KIND:
            raise ValueError(f"not a layer record: kind={o.get('k')!r}")
        return LayerRecord(o["t"], o["o"], o["g"], o["s"], o["n"],
                           tuple(o["p"]))


class LayerProfiler:
    """Record sink for the profiled engine.

    The engine accepts ``layers=None`` (default) and pays nothing; passing
    a profiler switches the engine to the sliced step.  ``record=False``
    keeps the sliced execution but drops every record — the lockstep
    baseline that isolates recording cost from slicing cost in the
    overhead contract (see module docstring).
    """

    __slots__ = ("records", "record", "_clock", "_t0")

    def __init__(self, record: bool = True, clock=time.perf_counter):
        self.records: List[LayerRecord] = []
        self.record = record
        self._clock = clock
        self._t0 = clock()

    def now_us(self) -> int:
        return int((self._clock() - self._t0) * 1e6)

    def on_step(self, step: int, ops: Sequence[Tuple[str, int]],
                walls_us: Sequence[float],
                ts_us: Optional[int] = None) -> None:
        """Append one record per ``(op, group)`` with its measured wall.
        ``ts_us``: the engine's post-step stamp (its clock when a span
        tracer is attached — the one-clock rule)."""
        if not self.record:
            return
        if ts_us is None:
            ts_us = self.now_us()
        for (op, g), w in zip(ops, walls_us):
            self.records.append(
                LayerRecord(ts_us, op, g, step, int(w),
                            layer_prov(step, op, g)))


# -- serialization -----------------------------------------------------------


def to_jsonl(records: Iterable[LayerRecord], stable: bool = False) -> str:
    """One record per line in emission order; ``stable=True`` normalizes
    the wall-clock fields (``ts_us`` -> ordinal, ``dur_us`` -> 0) exactly
    like the span exporter, so same-seed runs serialize byte-identically."""
    if stable:
        return "".join(r.to_json(stable_ts=i) + "\n"
                       for i, r in enumerate(records))
    return "".join(r.to_json() + "\n" for r in records)


def from_jsonl(text: str) -> List[LayerRecord]:
    return [LayerRecord.from_json(line)
            for line in text.splitlines() if line.strip()]


# -- invariants --------------------------------------------------------------


def validate(records: Sequence[LayerRecord], cfg=None,
             engine_steps: int = -1) -> List[str]:
    """Layer-record invariants; returns violation strings (empty = ok).

    * provenance matches the record's (step, op, group);
    * durations are non-negative, groups are >= -1;
    * steps are contiguous from 0 and, when ``engine_steps`` is given,
      count exactly that many;
    * with ``cfg``: every step carries exactly ``profile_ops(cfg)`` — the
      complete op set, in execution order (the completeness half of the
      three-level join).
    """
    out: List[str] = []
    per_step: Dict[int, List[LayerRecord]] = {}
    for i, r in enumerate(records):
        if r.prov != layer_prov(r.step, r.op, r.group):
            out.append(f"record {i}: prov {r.prov} != "
                       f"{layer_prov(r.step, r.op, r.group)}")
        if r.dur_us < 0:
            out.append(f"record {i}: negative dur_us {r.dur_us}")
        if r.group < -1:
            out.append(f"record {i}: group {r.group} < -1")
        per_step.setdefault(r.step, []).append(r)
    steps = sorted(per_step)
    if steps != list(range(len(steps))):
        out.append(f"steps not contiguous from 0: {steps[:10]}")
    if engine_steps >= 0 and len(steps) != engine_steps:
        out.append(f"{len(steps)} profiled steps but engine ran "
                   f"{engine_steps}")
    if cfg is not None:
        from repro.models.decode import profile_ops
        want = list(profile_ops(cfg))
        for s in steps:
            got = [(r.op, r.group) for r in per_step[s]]
            if got != want:
                out.append(f"step {s}: ops {got} != expected {want}")
    return out


# -- aggregation -------------------------------------------------------------


@dataclasses.dataclass
class OpSummary:
    op: str
    group: int
    calls: int = 0
    wall_us: int = 0

    @property
    def mean_us(self) -> float:
        return self.wall_us / self.calls if self.calls else 0.0


def summarize(records: Sequence[LayerRecord]
              ) -> Dict[Tuple[str, int], OpSummary]:
    """Aggregate wall time per (op, group) across all steps."""
    out: Dict[Tuple[str, int], OpSummary] = {}
    for r in records:
        s = out.setdefault((r.op, r.group), OpSummary(r.op, r.group))
        s.calls += 1
        s.wall_us += r.dur_us
    return out


def op_shares(records: Sequence[LayerRecord]) -> Dict[str, float]:
    """Fraction of total profiled wall per operator *kind* (groups
    summed) — the flame-table column and the offload ranking key."""
    by_op: Dict[str, int] = {}
    for r in records:
        by_op[r.op] = by_op.get(r.op, 0) + r.dur_us
    total = sum(by_op.values())
    if not total:
        return {op: 0.0 for op in by_op}
    return {op: w / total for op, w in by_op.items()}


# -- the three-level join ----------------------------------------------------


@dataclasses.dataclass
class JoinRow:
    """One engine step's span event joined to its layer records."""
    step: int
    step_wall_us: int          # span event dur_us
    layers_wall_us: int        # sum of segment walls
    layer_count: int

    @property
    def coverage(self) -> float:
        """Fraction of the step wall attributed to model operators; the
        remainder is host-side driver residual."""
        if self.step_wall_us <= 0:
            return 0.0
        return self.layers_wall_us / self.step_wall_us


def join_steps(records: Sequence[LayerRecord],
               events: Sequence[Any]) -> Dict[int, JoinRow]:
    """Join layer records to engine-step span events by step provenance.
    ``events`` is the span stream (``obs.spans.SpanEvent``); only ``step``
    events participate."""
    from . import spans as SP
    walls: Dict[int, int] = {}
    counts: Dict[int, int] = {}
    for r in records:
        walls[r.step] = walls.get(r.step, 0) + r.dur_us
        counts[r.step] = counts.get(r.step, 0) + 1
    out: Dict[int, JoinRow] = {}
    for ev in events:
        if ev.kind != SP.STEP:
            continue
        if ev.prov != SP.step_prov(ev.step):
            continue
        out[ev.step] = JoinRow(ev.step, ev.dur_us,
                               walls.get(ev.step, 0),
                               counts.get(ev.step, 0))
    return out


def join_mismatches(records: Sequence[LayerRecord], events: Sequence[Any],
                    cfg=None, coverage_min: float = JOIN_COVERAGE_MIN
                    ) -> List[str]:
    """Violations of the three-level join (empty = the join closes):
    every step span has a complete record set (when ``cfg`` given), and
    summed segment walls land in ``[coverage_min, 1] * step_wall``."""
    out = list(validate(records, cfg=cfg))
    rows = join_steps(records, events)
    profiled_steps = {r.step for r in records}
    if profiled_steps - set(rows):
        out.append(f"layer records for steps without a step span: "
                   f"{sorted(profiled_steps - set(rows))[:10]}")
    for step, row in sorted(rows.items()):
        if row.layer_count == 0:
            out.append(f"step {step}: span event has no layer records")
            continue
        if row.step_wall_us > 0 and row.layers_wall_us > row.step_wall_us:
            out.append(f"step {step}: layer walls {row.layers_wall_us}us "
                       f"exceed step wall {row.step_wall_us}us "
                       f"(segments must nest inside the step)")
        if row.step_wall_us > 0 and row.coverage < coverage_min:
            out.append(f"step {step}: coverage {row.coverage:.2f} < "
                       f"{coverage_min} (layers {row.layers_wall_us}us of "
                       f"step {row.step_wall_us}us)")
    return out


# -- analytic cost model -----------------------------------------------------


@dataclasses.dataclass(frozen=True)
class OpCost:
    """Analytic per-call cost of one operator: dot-FLOPs (matching
    ``hlo_analysis``'s dot-only convention) and HBM bytes (weights +
    state/cache + activation I/O — the roofline denominator)."""
    op: str
    group: int
    flops: float
    bytes_rw: float

    @property
    def intensity(self) -> float:
        """Arithmetic intensity in FLOPs/byte."""
        return self.flops / self.bytes_rw if self.bytes_rw else 0.0


def _dtype_bytes(name: str) -> int:
    return {"float32": 4, "bfloat16": 2, "float16": 2}.get(name, 4)


def analytic_op_costs(cfg, batch: int, cache_len: int) -> List[OpCost]:
    """Per-operator cost list aligned 1:1 with
    ``models.decode.profile_ops(cfg)`` for a single decode step.

    FLOPs count exactly the matmul-like (``dot``) terms — projections,
    attention scores/PV over the full static cache span (the decode path
    computes masked attention over all ``cache_len`` positions), MLP and
    expert einsums, RWKV mixing matrices and the decay-scan output dot —
    because that is what ``hlo_analysis.analyze`` counts.  Elementwise
    work (softmax, norms, gates, rotary, state outer products) and
    gathers are 0 dot-FLOPs by that convention.
    """
    from repro.models.decode import profile_ops
    from repro.models.params import gated_mlp

    B, S = batch, cache_len
    d, f, V = cfg.d_model, cfg.d_ff, cfg.vocab_size
    h, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    es = _dtype_bytes(cfg.dtype)
    n_mat = 3 if gated_mlp(cfg) else 2

    def attn_cost(op: str, g: int) -> OpCost:
        flops = 2 * B * (d * h * dh + 2 * d * hkv * dh   # q, k, v proj
                         + 2 * h * dh * S                # scores + PV
                         + h * dh * d)                   # out proj
        w = (d * h * dh + 2 * d * hkv * dh + h * dh * d) * es
        if cfg.qkv_bias:
            w += (h + 2 * hkv) * dh * es
        kv = 2 * B * hkv * S * dh * es      # cache read
        kv += 2 * 2 * B * hkv * dh * es     # update slice (r+w convention)
        return OpCost(op, g, flops, w + kv + 2 * B * d * es)

    def mlp_cost(op: str, g: int) -> OpCost:
        flops = n_mat * 2 * B * d * f
        byts = n_mat * d * f * es + 2 * B * d * es + 2 * B * f * es
        return OpCost(op, g, flops, byts)

    def moe_cost(g: int) -> OpCost:
        from repro.models.moe import capacity
        E, cap = cfg.num_experts, capacity(cfg, B)
        flops = 2 * B * d * E + n_mat * 2 * E * cap * d * f
        byts = (d * E * 4 + n_mat * E * d * f * es
                + 2 * E * cap * d * es + 2 * B * d * es)
        return OpCost("moe", g, flops, byts)

    def time_mix_cost(g: int) -> OpCost:
        lora_w, lora_mix = 64, 32
        flops = 2 * B * (d * 5 * lora_mix          # ddlerp mix_A
                         + 5 * d * lora_mix        # ddlerp mix_B
                         + 4 * d * h * dh          # wr/wk/wv/wg
                         + d * lora_w + lora_w * d  # decay lora
                         + h * dh * dh             # decay-scan output dot
                         + h * dh * d)             # wo
        w = (d * 5 * lora_mix + 5 * lora_mix * d + 4 * d * h * dh
             + d * lora_w + lora_w * d + h * dh * d + 5 * d) * es
        state = 2 * B * h * dh * dh * 4            # wkv state r/w (f32)
        state += 2 * B * d * es                    # shift state r/w
        return OpCost("time_mix", g, flops, w + state + 2 * B * d * es)

    def channel_mix_cost(g: int) -> OpCost:
        flops = 2 * B * (d * f + f * d + d * d)
        byts = ((2 * d * f + d * d) * es + 2 * B * d * es  # shift r/w
                + 2 * B * d * es)
        return OpCost("channel_mix", g, flops, byts)

    def scan_cost(g: int) -> OpCost:
        n_mamba = cfg.hybrid_attn_every - 1
        d_inner = 2 * d
        nh = d_inner // cfg.ssm_head_dim
        st = cfg.ssm_state
        ch = d_inner + 2 * st
        proj = 2 * d_inner + 2 * st + nh
        per = 2 * B * (d * proj                    # in_proj
                       + ch * cfg.ssm_conv_width   # conv window dot
                       + nh * st * cfg.ssm_head_dim  # decay-scan output
                       + d_inner * d)              # out_proj
        w = (d * proj + cfg.ssm_conv_width * ch + d_inner * d) * es
        state = 2 * B * nh * st * cfg.ssm_head_dim * 4  # h state r/w
        state += 2 * B * (cfg.ssm_conv_width - 1) * ch * es  # conv window
        per_bytes = w + state + 2 * B * d * es
        return OpCost("scan", g, n_mamba * per, n_mamba * per_bytes)

    costs: List[OpCost] = []
    for op, g in profile_ops(cfg):
        if op == "embed":
            costs.append(OpCost(op, g, 0.0, 2 * B * d * es + 4 * B))
        elif op == "head":
            costs.append(OpCost(op, g, 2 * B * d * V,
                                d * V * es + B * V * 4 + B * d * es))
        elif op in ("attn", "attn_local", "attn_global"):
            costs.append(attn_cost(op, g))
        elif op in ("mlp", "mlp_local", "mlp_global"):
            costs.append(mlp_cost(op, g))
        elif op == "moe":
            costs.append(moe_cost(g))
        elif op == "time_mix":
            costs.append(time_mix_cost(g))
        elif op == "channel_mix":
            costs.append(channel_mix_cost(g))
        elif op == "scan":
            costs.append(scan_cost(g))
        else:
            raise ValueError(op)
    return costs


def analytic_totals(cfg, batch: int, cache_len: int) -> Tuple[float, float]:
    """(total dot-FLOPs, total bytes) of one decode step."""
    costs = analytic_op_costs(cfg, batch, cache_len)
    return (sum(c.flops for c in costs), sum(c.bytes_rw for c in costs))


# -- analytic-vs-HLO cross-check ---------------------------------------------


def decode_step_hlo(cfg, batch: int, cache_len: int) -> str:
    """Compiled HLO text of the fused decode step at (batch, cache_len)."""
    import functools
    import jax
    import jax.numpy as jnp
    from repro.models import decode, params as MP
    params = MP.init_params(cfg, seed=0)
    cache = decode.init_cache(cfg, params, batch, cache_len)
    toks = jnp.zeros((batch, 1), jnp.int32)
    fn = jax.jit(functools.partial(decode.serve_step, cfg))
    return fn.lower(params, cache, toks,
                    jnp.asarray(0, jnp.int32)).compile().as_text()


def crosscheck_hlo(cfg, batch: int, cache_len: int,
                   hlo_text: Optional[str] = None,
                   flops_rtol: float = FLOPS_RTOL,
                   bytes_factor: float = BYTES_FACTOR
                   ) -> Tuple[Dict[str, float], List[str]]:
    """Compare the analytic model against ``hlo_analysis.analyze`` on the
    real decode-step HLO.  Returns (report dict, violations)."""
    from repro.launch import hlo_analysis
    if hlo_text is None:
        hlo_text = decode_step_hlo(cfg, batch, cache_len)
    hlo = hlo_analysis.analyze(hlo_text)
    a_flops, a_bytes = analytic_totals(cfg, batch, cache_len)
    rel = abs(a_flops - hlo.flops) / max(hlo.flops, 1.0)
    ratio = (a_bytes / hlo.traffic_bytes if hlo.traffic_bytes
             else float("inf"))
    report = {"analytic_flops": a_flops, "hlo_flops": hlo.flops,
              "flops_rel_err": rel, "analytic_bytes": a_bytes,
              "hlo_bytes": hlo.traffic_bytes, "bytes_ratio": ratio}
    problems: List[str] = []
    if rel > flops_rtol:
        problems.append(
            f"{cfg.name}: analytic flops {a_flops:.3e} vs HLO "
            f"{hlo.flops:.3e} (rel err {rel:.3f} > {flops_rtol})")
    if not (1.0 / bytes_factor <= ratio <= bytes_factor):
        problems.append(
            f"{cfg.name}: analytic bytes {a_bytes:.3e} vs HLO "
            f"{hlo.traffic_bytes:.3e} (ratio {ratio:.2f} outside "
            f"[1/{bytes_factor}, {bytes_factor}])")
    return report, problems


# -- roofline classification + offload candidates ----------------------------


def device_peaks() -> Tuple[float, float]:
    """(peak FLOPs/s, HBM bytes/s) of the modeled accelerator."""
    from repro.launch import hlo_stats
    return float(hlo_stats.PEAK_FLOPS_BF16), float(hlo_stats.HBM_BW)


def roofline_class(intensity: float,
                   peaks: Optional[Tuple[float, float]] = None) -> str:
    """``compute``- vs ``memory``-bound against the device ridge point."""
    peak_flops, bw = peaks or device_peaks()
    ridge = peak_flops / bw
    return "compute" if intensity >= ridge else "memory"


def offload_report(cfg, records: Sequence[LayerRecord],
                   costs: Sequence[OpCost],
                   peaks: Optional[Tuple[float, float]] = None
                   ) -> List[Dict[str, Any]]:
    """Ranked Calyx-lowering candidates: one row per operator *kind*,
    ordered by measured share of decode-step time, annotated with the
    analytic per-step FLOPs/bytes/intensity and roofline class.

    ``costs`` should be the analytic costs at the *deployment* shape (the
    full config / production cache length), while ``records`` carry the
    measured reduced-config walls — the measured ranking tells us where
    the step time goes, the analytic columns tell us what an accelerator
    would have to beat at scale.
    """
    shares = op_shares(records)
    summary = summarize(records)
    by_op: Dict[str, Dict[str, float]] = {}
    for c in costs:
        row = by_op.setdefault(c.op, {"flops": 0.0, "bytes": 0.0})
        row["flops"] += c.flops
        row["bytes"] += c.bytes_rw
    rows: List[Dict[str, Any]] = []
    for op, share in shares.items():
        cost = by_op.get(op, {"flops": 0.0, "bytes": 0.0})
        intensity = (cost["flops"] / cost["bytes"]
                     if cost["bytes"] else 0.0)
        wall = sum(s.wall_us for (o, _), s in summary.items() if o == op)
        calls = sum(s.calls for (o, _), s in summary.items() if o == op)
        rows.append({
            "op": op,
            "share": round(share, 4),
            "wall_us_mean": round(wall / calls, 1) if calls else 0.0,
            "flops_per_step": cost["flops"],
            "bytes_per_step": cost["bytes"],
            "intensity": round(intensity, 3),
            "bound": roofline_class(intensity, peaks),
        })
    rows.sort(key=lambda r: -r["share"])
    for rank, r in enumerate(rows, 1):
        r["rank"] = rank
    return rows
