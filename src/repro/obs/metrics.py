"""Process-local metrics registry: counters, gauges, fixed-bucket histograms.

Dependency-free (stdlib only).  Instruments are created through a
:class:`MetricsRegistry` (get-or-create by name, type conflicts raise) and
exported either as Prometheus text exposition format
(:meth:`MetricsRegistry.to_prometheus`) or as a JSON document
(:meth:`MetricsRegistry.to_json`); both iterate names in sorted order so
the output is deterministic.

Histogram quantiles
-------------------

:class:`Histogram` stores only fixed-bucket counts (plus sum/count/min/max)
and estimates quantiles by linear interpolation inside the selected bucket,
Prometheus ``histogram_quantile`` style: the target rank is ``q * count``,
the first bucket whose cumulative count reaches the rank is selected, and
the result interpolates between the bucket's lower and upper bound by the
rank's position among the bucket's samples.  Two exactness properties are
unit-tested against numpy (``tests/test_obs_metrics.py``):

* **value-aligned buckets are exact** — when every distinct observation
  equals a bucket upper bound and ``q * count`` is an integer (p50/p90/p99
  over 100 samples), the estimate equals
  ``numpy.quantile(data, q, method="inverted_cdf")`` exactly;
* **coarse buckets are off by less than one bucket width** — for arbitrary
  data the estimate is within the selected bucket, so it differs from the
  exact (linear-interpolation) numpy quantile by strictly less than that
  bucket's width.

The first bucket's lower bound is clamped to the observed minimum and the
overflow bucket's upper bound to the observed maximum, so estimates never
leave the observed value range.
"""
from __future__ import annotations

import bisect
import json
import math
from typing import Dict, List, Sequence, Tuple, Union

Number = Union[int, float]

# log-spaced microsecond buckets, 100us .. 10s — the default for the
# serving latency histograms (TTFT, per-step, per-token)
DEFAULT_TIME_BUCKETS_US: Tuple[float, ...] = (
    100, 200, 500,
    1_000, 2_000, 5_000,
    10_000, 20_000, 50_000,
    100_000, 200_000, 500_000,
    1_000_000, 2_000_000, 5_000_000, 10_000_000,
)


def _fmt(v: Number) -> str:
    """Exposition-format number: integral values print without a dot."""
    if isinstance(v, bool):
        return str(int(v))
    if isinstance(v, int):
        return str(v)
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    if float(v).is_integer():
        return str(int(v))
    return repr(float(v))


class Counter:
    """Monotonically increasing value."""

    kind = "counter"
    __slots__ = ("name", "help", "value")

    def __init__(self, name: str, help: str = "") -> None:
        self.name = name
        self.help = help
        self.value: Number = 0

    def inc(self, v: Number = 1) -> None:
        if v < 0:
            raise ValueError(f"counter {self.name}: negative increment {v}")
        self.value += v

    def as_json(self) -> Dict[str, object]:
        return {"kind": self.kind, "value": self.value}


class Gauge:
    """Last-set value."""

    kind = "gauge"
    __slots__ = ("name", "help", "value")

    def __init__(self, name: str, help: str = "") -> None:
        self.name = name
        self.help = help
        self.value: Number = 0

    def set(self, v: Number) -> None:
        self.value = v

    def inc(self, v: Number = 1) -> None:
        self.value += v

    def dec(self, v: Number = 1) -> None:
        self.value -= v

    def as_json(self) -> Dict[str, object]:
        return {"kind": self.kind, "value": self.value}


class Histogram:
    """Fixed-bucket histogram with interpolated quantiles (see module
    docstring for the exactness contract)."""

    kind = "histogram"
    __slots__ = ("name", "help", "bounds", "bucket_counts", "count", "sum",
                 "min", "max")

    def __init__(self, name: str, help: str = "",
                 buckets: Sequence[Number] = DEFAULT_TIME_BUCKETS_US) -> None:
        if not buckets:
            raise ValueError(f"histogram {name}: empty bucket list")
        bounds = [float(b) for b in buckets]
        if bounds != sorted(bounds) or len(set(bounds)) != len(bounds):
            raise ValueError(f"histogram {name}: buckets must be strictly "
                             f"increasing, got {buckets}")
        self.name = name
        self.help = help
        self.bounds: List[float] = bounds       # finite upper bounds
        self.bucket_counts: List[int] = [0] * (len(bounds) + 1)  # +overflow
        self.count = 0
        self.sum: float = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: Number) -> None:
        v = float(v)
        # first bucket with bound >= v (Prometheus `le` semantics)
        self.bucket_counts[bisect.bisect_left(self.bounds, v)] += 1
        self.count += 1
        self.sum += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)

    def quantile(self, q: float) -> float:
        """Interpolated q-quantile (0 <= q <= 1); nan when empty."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile {q} outside [0, 1]")
        if self.count == 0:
            return math.nan
        rank = q * self.count
        cum = 0
        for i, n in enumerate(self.bucket_counts):
            if n == 0:
                continue
            if cum + n >= rank:
                lo = self.min if i == 0 else self.bounds[i - 1]
                hi = self.max if i == len(self.bounds) else self.bounds[i]
                lo = max(lo, self.min)
                hi = min(hi, self.max)
                if hi <= lo:
                    return lo
                frac = (rank - cum) / n
                return lo + (hi - lo) * frac
            cum += n
        return self.max

    def mean(self) -> float:
        return self.sum / self.count if self.count else math.nan

    def as_json(self) -> Dict[str, object]:
        return {
            "kind": self.kind,
            "count": self.count,
            "sum": self.sum,
            "min": self.min if self.count else None,
            "max": self.max if self.count else None,
            "buckets": {_fmt(b): c
                        for b, c in zip(self.bounds, self.bucket_counts)},
            "overflow": self.bucket_counts[-1],
            "p50": self.quantile(0.5) if self.count else None,
            "p90": self.quantile(0.9) if self.count else None,
            "p99": self.quantile(0.99) if self.count else None,
        }


Instrument = Union[Counter, Gauge, Histogram]


class MetricsRegistry:
    """Get-or-create instrument registry with deterministic exporters."""

    __slots__ = ("_instruments",)

    def __init__(self) -> None:
        self._instruments: Dict[str, Instrument] = {}

    def _get(self, cls, name: str, help: str, **kw) -> Instrument:
        inst = self._instruments.get(name)
        if inst is None:
            inst = cls(name, help, **kw)
            self._instruments[name] = inst
        elif not isinstance(inst, cls):
            raise TypeError(f"metric {name} already registered as "
                            f"{inst.kind}, requested {cls.kind}")
        return inst

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[Number] = DEFAULT_TIME_BUCKETS_US
                  ) -> Histogram:
        return self._get(Histogram, name, help, buckets=buckets)

    def get(self, name: str) -> Instrument:
        return self._instruments[name]

    def names(self) -> List[str]:
        return sorted(self._instruments)

    # -- exporters -----------------------------------------------------------

    def to_prometheus(self) -> str:
        """Prometheus text exposition format, names in sorted order."""
        lines: List[str] = []
        for name in self.names():
            inst = self._instruments[name]
            if inst.help:
                lines.append(f"# HELP {name} {inst.help}")
            lines.append(f"# TYPE {name} {inst.kind}")
            if isinstance(inst, Histogram):
                cum = 0
                for b, n in zip(inst.bounds, inst.bucket_counts):
                    cum += n
                    lines.append(f'{name}_bucket{{le="{_fmt(b)}"}} {cum}')
                lines.append(f'{name}_bucket{{le="+Inf"}} {inst.count}')
                lines.append(f"{name}_sum {_fmt(inst.sum)}")
                lines.append(f"{name}_count {inst.count}")
            else:
                lines.append(f"{name} {_fmt(inst.value)}")
        return "\n".join(lines) + ("\n" if lines else "")

    def to_json(self) -> Dict[str, object]:
        return {"schema": 1,
                "metrics": {name: self._instruments[name].as_json()
                            for name in self.names()}}

    def dump_json(self) -> str:
        return json.dumps(self.to_json(), indent=2, sort_keys=True) + "\n"
