"""Logical-axis sharding rules and parameter PartitionSpecs.

Model code annotates activations with logical axes (common.constrain);
parameters get PartitionSpecs from path-based rules here.  The default
strategy is FSDP(+pod) x TP: tensor-parallel over ``model``, parameters and
optimizer state additionally sharded over the data axes (ZeRO-3), which is
what lets a 27B fp32 optimizer state fit 512 x 16 GB chips.

Expert ("bank") dimensions shard over ``model`` — the paper's
layout-embedded banking at mesh scale: the device index IS the bank index.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.config import ModelConfig
from ..models import params as MP


@dataclasses.dataclass(frozen=True)
class ShardingStrategy:
    name: str = "fsdp_tp"
    tp: str = "model"
    fsdp: Tuple[str, ...] = ("data",)         # ZeRO-3 param axes
    batch: Tuple[str, ...] = ("data",)
    shard_params_fsdp: bool = True

    def with_pod(self) -> "ShardingStrategy":
        return dataclasses.replace(self, fsdp=("pod",) + self.fsdp,
                                   batch=("pod",) + self.batch)


def logical_rules(strategy: ShardingStrategy, *,
                  shard_heads: bool = False) -> Dict[str, Any]:
    """Activation logical-axis -> mesh axes."""
    return {
        "batch": strategy.batch,
        "seq": None,
        "embed": None,
        "heads": strategy.tp if shard_heads else None,
        "kv_heads": strategy.tp if shard_heads else None,
        "mlp": strategy.tp,
        "vocab": strategy.tp,
        "experts": strategy.tp,      # banks over the model axis
        "capacity": strategy.batch,
    }


# path-suffix -> spec builder; evaluated against the unstacked leaf
_COL = {"wq", "wk", "wv", "wi", "wg", "in_proj", "lm_head", "router",
        "wr", "mix_A", "wlora_A"}
_ROW = {"wo", "out_proj", "wlora_B"}
_TP_VEC = {"bq", "bk", "bv", "conv_b", "A_log", "D", "dt_bias"}


def _base_spec(path: Tuple[str, ...], shape: tuple,
               st: ShardingStrategy) -> P:
    name = path[-1]
    parent = path[-2] if len(path) >= 2 else ""
    fsdp = tuple(st.fsdp) if st.shard_params_fsdp else None
    fa = fsdp if fsdp else None

    if name == "embed":
        return P(st.tp, fa)
    if parent == "moe":
        if name in ("w1", "wg"):
            return P(st.tp, fa, None)
        if name == "w2":
            return P(st.tp, None, fa)
        if name == "router":
            return P(fa, None)
    if parent == "cm" and name == "wv":      # rwkv channel-mix down proj
        return P(st.tp, fa)
    if parent == "cm" and name == "wk":
        return P(fa, st.tp)
    if name in _COL and len(shape) == 2:
        return P(fa, st.tp)
    if name in _ROW and len(shape) == 2:
        return P(st.tp, fa)
    if name == "conv_w":
        return P(None, st.tp)
    if name in _TP_VEC and len(shape) == 1:
        return P(st.tp)
    if name == "u":
        return P(st.tp, None)
    if name == "mix_B":
        return P(None, None, fa)
    # norms, gates, mu, gn_*: replicate
    return P(*([None] * len(shape)))


def _n_stack_dims(path: Tuple[str, ...]) -> int:
    n = 0
    if "blocks" in path or "encoder" in path:
        n += 1
    if any(k in path for k in ("self", "mamba")):
        n += 1
    return n


def sanitize_spec(spec: P, shape: tuple, mesh: Optional[Mesh]) -> P:
    """Drop spec axes whose shard count does not divide the dimension
    (input shardings must tile evenly; e.g. whisper vocab 51866 over 16)."""
    if mesh is None:
        return spec
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def nshards(ax):
        if ax is None:
            return 1
        axes = ax if isinstance(ax, (tuple, list)) else (ax,)
        out = 1
        for a in axes:
            out *= sizes[a]
        return out

    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = [ax if (ax is None or dim % nshards(ax) == 0) else None
           for dim, ax in zip(shape, entries)]
    return P(*out)


def param_pspecs(cfg: ModelConfig, strategy: ShardingStrategy,
                 mesh: Optional[Mesh] = None) -> Any:
    """PartitionSpec pytree matching param_shapes(cfg).  With ``mesh``,
    specs are sanitized to evenly-dividing axes."""
    shapes = MP.param_shapes(cfg)

    def walk(tree, path):
        if MP._is_leaf(tree):
            n_lead = _n_stack_dims(path)
            inner_shape = tree[0][n_lead:]
            base = _base_spec(path, inner_shape, strategy)
            spec = P(*([None] * n_lead + list(base)))
            return sanitize_spec(spec, tree[0], mesh)
        return {k: walk(v, path + (k,)) for k, v in tree.items()}

    return walk(shapes, ())


def param_shardings(cfg: ModelConfig, mesh: Mesh,
                    strategy: ShardingStrategy) -> Any:
    return jax.tree.map(lambda spec: NamedSharding(mesh, spec),
                        param_pspecs(cfg, strategy, mesh=mesh),
                        is_leaf=lambda x: isinstance(x, P))


def validate_divisibility(cfg: ModelConfig, mesh: Mesh,
                          strategy: ShardingStrategy) -> Dict[str, int]:
    """Report leaves whose sharded dims don't divide (GSPMD pads these —
    legal but wasteful; surfaced for the roofline notes)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def axis_size(ax) -> int:
        if ax is None:
            return 1
        if isinstance(ax, (tuple, list)):
            out = 1
            for a in ax:
                out *= sizes[a]
            return out
        return sizes[ax]

    uneven = {}
    shapes = jax.tree.leaves(MP.param_shapes(cfg), is_leaf=MP._is_leaf)
    specs = jax.tree.leaves(param_pspecs(cfg, strategy),
                            is_leaf=lambda x: isinstance(x, P))
    for lf, spec in zip(shapes, specs):
        for dim, ax in zip(lf[0], tuple(spec)):
            n = axis_size(ax)
            if n > 1 and dim % n:
                uneven[f"{lf[0]}@{ax}"] = dim
    return uneven
