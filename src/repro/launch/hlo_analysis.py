"""Trip-count-aware HLO cost analysis.

XLA's ``compiled.cost_analysis()`` visits every while-loop body exactly once
— for scan-over-layers models that undercounts FLOPs/bytes/collectives by
the layer count.  This module parses the post-SPMD HLO text instead:

  * computations are parsed into symbol tables (every %name's shape);
  * ``while`` ops multiply their body's cost by the trip count recovered
    from the loop condition's comparison constant;
  * FLOPs come from ``dot`` ops (2 x prod(result) x contracted size, exact
    via the printed contracting dims);
  * HBM traffic counts each op's operands+result at fusion boundaries
    (fusion-internal computations are excluded, mirroring XLA's model);
  * collective bytes take max(operands, result) per op — a ring-transfer
    proxy — split by kind.

Validated against unrolled-vs-scanned reference programs in
tests/test_hlo_analysis.py.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                     "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OPND_RE = re.compile(r"%([\w.\-]+)")
_CALLED_RE = re.compile(r"(?:to_apply|calls|body|condition|true_computation|false_computation|branch_computations=\{)=?%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")

# ops whose "result" isn't real HBM traffic
_NO_TRAFFIC = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "while", "conditional", "call", "after-all",
               "iota", "copy-start", "copy-done"}


def _shape_bytes_list(text: str) -> List[int]:
    return [_prod(dims) * _DTYPE_BYTES.get(dt, 4)
            for dt, dims in _SHAPE_RE.findall(text)]


def _prod(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


@dataclasses.dataclass
class OpInfo:
    name: str
    opcode: str
    result_bytes: int
    result_type_text: str
    operands: List[str]
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[OpInfo] = dataclasses.field(default_factory=list)
    symbols: Dict[str, int] = dataclasses.field(default_factory=dict)

    # filled by the analysis
    flops: float = 0.0
    traffic: float = 0.0
    collectives: Dict[str, float] = dataclasses.field(default_factory=dict)
    whiles: List[Tuple[str, str]] = dataclasses.field(default_factory=list)


def _split_def_rhs(rhs: str):
    """rhs of an op definition -> (result_type_text, opcode, args_text)."""
    if rhs.startswith("("):
        i = rhs.find(")")
        type_text, rest = rhs[: i + 1], rhs[i + 1:].strip()
    else:
        m = re.match(r"^[a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?", rhs)
        if m:
            type_text, rest = m.group(0), rhs[m.end():].strip()
        else:
            type_text, rest = "", rhs
    m = re.match(r"([a-z][a-z0-9\-]*)", rest)
    opcode = m.group(1) if m else ""
    args_text = ""
    j = rest.find("(")
    if j >= 0:
        depth = 0
        for k in range(j, len(rest)):
            if rest[k] == "(":
                depth += 1
            elif rest[k] == ")":
                depth -= 1
                if depth == 0:
                    args_text = rest[j:k + 1]
                    break
    return type_text, opcode, args_text


def parse_module(text: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry = ""
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if not s or s.startswith("//"):
            continue
        # computation header: "%name (args) -> type {"  or "ENTRY %name ..."
        if s.endswith("{") and ("(" in s) and "=" not in s.split("(")[0]:
            header = s[:-1].strip()
            is_entry = header.startswith("ENTRY")
            header = header.replace("ENTRY", "").strip()
            name = header.split("(")[0].strip().lstrip("%").strip()
            cur = Computation(name)
            comps[name] = cur
            if is_entry:
                entry = name
            continue
        if s == "}" or s == "})":
            cur = None
            continue
        if cur is None:
            continue
        m = _DEF_RE.match(s)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        type_text, opcode, args_text = _split_def_rhs(rhs)
        result_bytes = sum(_shape_bytes_list(type_text))
        operands = _OPND_RE.findall(args_text)
        cur.symbols[name] = result_bytes
        cur.ops.append(OpInfo(name, opcode, result_bytes, type_text,
                              operands, s))
    return comps, entry


def _called_computations(op: OpInfo) -> List[str]:
    return _CALLED_RE.findall(op.line)


def analyze(text: str) -> "ModuleCost":
    comps, entry = parse_module(text)

    # computations reached via fusion/reducer calls: excluded from traffic
    fusion_called: set = set()
    while_bodies: Dict[str, Tuple[str, str]] = {}
    for c in comps.values():
        for op in c.ops:
            called = _called_computations(op)
            if op.opcode == "while":
                m_body = re.search(r"body=%?([\w.\-]+)", op.line)
                m_cond = re.search(r"condition=%?([\w.\-]+)", op.line)
                if m_body and m_cond:
                    while_bodies[op.name] = (m_body.group(1),
                                             m_cond.group(1))
            elif op.opcode in ("fusion", "reduce", "map", "scatter",
                               "select-and-scatter", "reduce-window",
                               "sort", "custom-call"):
                fusion_called.update(called)

    def trip_count(while_op: OpInfo, cond_name: str) -> int:
        # exact count from the scheduler's backend config when present
        m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', while_op.line)
        if m:
            return int(m.group(1))
        cond = comps.get(cond_name)
        if cond is None:
            return 1
        consts = []
        for op in cond.ops:
            consts += [int(v) for v in _CONST_RE.findall(op.line)]
        return max(consts) if consts else 1

    # per-computation own costs
    for c in comps.values():
        for op in c.ops:
            if op.opcode == "dot":
                k = _dot_contracted(op, c)
                c.flops += 2.0 * (op.result_bytes /
                                  max(_result_elem_size(op), 1)) * k
            if op.opcode in _NO_TRAFFIC or not op.opcode:
                pass
            elif ("dynamic-update-slice" in op.name
                  or op.opcode == "dynamic-update-slice"):
                # in-place update: only the slice moves (read + write);
                # the big aliased buffer is NOT traffic
                sizes = sorted(c.symbols.get(o, 0) for o in op.operands)
                c.traffic += 2 * sum(sizes[:-1])
            elif "dynamic-slice" in op.name or op.opcode == "dynamic-slice":
                # reads only result-sized slice from the big operand
                sizes = sorted(c.symbols.get(o, 0) for o in op.operands)
                c.traffic += 2 * op.result_bytes + sum(sizes[:-1])
            else:
                opnd = sum(c.symbols.get(o, 0) for o in op.operands)
                c.traffic += op.result_bytes + opnd
            kind = _collective_kind(op.opcode)
            if kind:
                opnd_b = [c.symbols.get(o, 0) for o in op.operands]
                elems = _shape_bytes_list(op.result_type_text) or [0]
                moved = max([max(elems)] + opnd_b)
                c.collectives[kind] = c.collectives.get(kind, 0.0) + moved

    # roll up with trip multiplication (memoized, cycle-safe)
    memo: Dict[str, Tuple[float, float, Dict[str, float]]] = {}
    visiting: set = set()

    def total(name: str) -> Tuple[float, float, Dict[str, float]]:
        if name in memo:
            return memo[name]
        if name in visiting or name not in comps:
            return (0.0, 0.0, {})
        visiting.add(name)
        c = comps[name]
        fl, tr = c.flops, c.traffic
        coll = dict(c.collectives)
        for op in c.ops:
            if op.opcode == "while" and op.name in while_bodies:
                body, cond = while_bodies[op.name]
                t = trip_count(op, cond)
                bfl, btr, bcoll = total(body)
                fl += t * bfl
                tr += t * btr
                for k, v in bcoll.items():
                    coll[k] = coll.get(k, 0.0) + t * v
            elif op.opcode == "call":
                # XLA:CPU wraps parallelized fusions (and whole entries) in
                # plain calls; their bodies hold the real traffic-bearing
                # ops.  Resolve callees from the op line itself — op names
                # are only unique per computation, so indexing by name
                # would collide across computations.
                for callee in _called_computations(op):
                    bfl, btr, bcoll = total(callee)
                    fl += bfl
                    tr += btr
                    for k, v in bcoll.items():
                        coll[k] = coll.get(k, 0.0) + v
            elif op.opcode == "conditional":
                # hardware instantiates all branches; one executes per call
                branches = [total(callee)
                            for callee in _called_computations(op)]
                if branches:
                    bfl, btr, bcoll = max(
                        branches, key=lambda b: b[0] + b[1])
                    fl += bfl
                    tr += btr
                    for k, v in bcoll.items():
                        coll[k] = coll.get(k, 0.0) + v
        visiting.discard(name)
        memo[name] = (fl, tr, coll)
        return memo[name]

    # fusion internals: zero them (their boundary traffic counted by caller)
    loop_comps = {n for pair in while_bodies.values() for n in pair}
    for fc in fusion_called:
        if fc in comps and fc not in loop_comps:
            memo[fc] = (comps[fc].flops, 0.0, {})  # dots in fusions count

    fl, tr, coll = total(entry) if entry else (0.0, 0.0, {})
    return ModuleCost(flops=fl, traffic_bytes=tr, collective_bytes=coll)


def _result_elem_size(op: OpInfo) -> int:
    m = _SHAPE_RE.search(op.result_type_text)
    if not m:
        return 4
    return _DTYPE_BYTES.get(m.group(1), 4)


def _dot_contracted(op: OpInfo, c: Computation) -> float:
    """Contracted-dimension size product from lhs shape + printed dims."""
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
    if not m:
        return 1.0
    contracting = [int(x) for x in m.group(1).split(",") if x]
    # lhs operand shape: find its definition text
    if not op.operands:
        return 1.0
    lhs_name = op.operands[0]
    # recover dims from the op line itself: dot(%a, %b) — we need a's shape,
    # which we stored only as bytes.  Parse from the line: XLA prints
    # operand types inline in newer versions; fall back to searching the
    # computation's defining line.
    dims = _find_dims(c, lhs_name)
    if dims is None:
        return 1.0
    k = 1.0
    for d in contracting:
        if d < len(dims):
            k *= dims[d]
    return k


def _find_dims(c: Computation, name: str) -> Optional[List[int]]:
    for op in c.ops:
        if op.name == name:
            m = _SHAPE_RE.search(op.result_type_text or op.line)
            if m:
                return [int(x) for x in m.group(2).split(",") if x]
    return None


def _collective_kind(opcode: str) -> Optional[str]:
    for k in _COLLECTIVE_KINDS:
        if opcode == k or opcode == k + "-start":
            return k
    return None


@dataclasses.dataclass
class ModuleCost:
    flops: float
    traffic_bytes: float
    collective_bytes: Dict[str, float]

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())

    def as_dict(self):
        return {"flops": self.flops, "traffic_bytes": self.traffic_bytes,
                "collective_bytes": dict(self.collective_bytes),
                "total_collective_bytes": self.total_collective_bytes}
