"""Production training launcher.

    # CPU-scale run (reduced config, real runtime):
    PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --reduced \
        --steps 50

    # Production lowering check for the full config on the target mesh:
    PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --dry-run

On a real TPU cluster this module is invoked per-host under the standard
JAX distributed bootstrap; the mesh/sharding config is identical to what
the dry-run validates.
"""
import argparse
import tempfile

from repro.data.pipeline import DataConfig
from repro.models import get_config
from repro.optim import adamw
from repro.runtime.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-scale)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=20)
    ap.add_argument("--dry-run", action="store_true",
                    help="lower+compile the full config on the production "
                         "mesh instead of training")
    args = ap.parse_args()

    if args.dry_run:
        # delegate to the dry-run module (must own process startup for the
        # 512-device host platform flag)
        import subprocess
        import sys
        raise SystemExit(subprocess.call(
            [sys.executable, "-m", "repro.launch.dryrun",
             "--arch", args.arch, "--shape", "train_4k", "--both",
             "--force"]))

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    ckpt = args.ckpt_dir or tempfile.mkdtemp(prefix=f"train_{args.arch}_")
    tcfg = TrainerConfig(total_steps=args.steps,
                         checkpoint_every=args.checkpoint_every,
                         checkpoint_dir=ckpt)
    opt = adamw.AdamWConfig(lr=args.lr, warmup_steps=max(2, args.steps // 10),
                            total_steps=args.steps)
    tr = Trainer(cfg, tcfg, opt_cfg=opt,
                 data_cfg=DataConfig(vocab_size=cfg.vocab_size,
                                     seq_len=args.seq,
                                     global_batch=args.global_batch))
    tr.run_with_restarts()
    losses = [h["loss"] for h in tr.history if "loss" in h]
    print(f"[train] {cfg.name}: {len(losses)} steps, "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}, ckpt={ckpt}")


if __name__ == "__main__":
    main()
