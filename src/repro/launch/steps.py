"""Step functions (train / prefill / decode) with sharding applied."""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..models import decode as D
from ..models import transformer as TF
from ..models.common import set_sharding_rules
from ..models.config import ModelConfig
from ..optim import adamw
from ..sharding.rules import ShardingStrategy, logical_rules


def install_rules(cfg: ModelConfig, mesh, st: ShardingStrategy,
                  shard_heads: bool = False) -> None:
    set_sharding_rules(mesh, logical_rules(st, shard_heads=shard_heads))


def make_train_step(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig,
                    microbatches: int = 1):
    """Train step with optional gradient accumulation (perf iteration 7):
    the global batch is split into ``microbatches`` sequential slices, so
    live activations shrink by that factor while weight re-reads stay
    negligible against activation traffic."""

    def grad_of(params, batch):
        def lf(p):
            return TF.loss_fn(cfg, p, batch)
        return jax.value_and_grad(lf, has_aux=True)(params)

    def train_step(state: Dict, batch: Dict) -> Tuple[Dict, Dict]:
        params = state["params"]
        if microbatches <= 1:
            (loss, metrics), grads = grad_of(params, batch)
        else:
            mb = {k: v.reshape((microbatches, -1) + v.shape[1:])
                  for k, v in batch.items()}

            def body(acc, mbatch):
                (l, m), g = grad_of(params, mbatch)
                acc_g, acc_l = acc
                acc_g = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), acc_g, g)
                return (acc_g, acc_l + l), m

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (grads, loss_sum), ms = jax.lax.scan(
                body, (g0, jnp.zeros((), jnp.float32)), mb)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss_sum / microbatches
            metrics = jax.tree.map(lambda m: m[-1], ms)
        new_p, new_opt, om = adamw.apply_updates(opt_cfg, params,
                                                 grads, state["opt"])
        return {"params": new_p, "opt": new_opt}, {**metrics, **om}

    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch: Dict) -> jax.Array:
        logits, _ = TF.forward(cfg, params, batch["tokens"],
                               modality=batch.get("modality"))
        return logits

    return prefill_step


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, cache, tokens, pos):
        return D.serve_step(cfg, params, cache, tokens, pos)

    return serve_step


def step_and_args(cfg: ModelConfig, shape_kind: str,
                  specs: Dict[str, Any],
                  opt_cfg: adamw.AdamWConfig = None,
                  microbatches: int = 1):
    """(callable, ordered example args) for lowering a given cell."""
    opt_cfg = opt_cfg or adamw.AdamWConfig()
    if shape_kind == "train":
        return (make_train_step(cfg, opt_cfg, microbatches=microbatches),
                (specs["state"], specs["batch"]))
    if shape_kind == "prefill":
        return make_prefill_step(cfg), (specs["params"], specs["batch"])
    return make_serve_step(cfg), (specs["params"], specs["cache"],
                                  specs["tokens"], specs["pos"])
