"""The assigned input-shape set, per-cell input specs, and skip logic.

Four canonical shapes per architecture (40 cells):
  train_4k    : seq 4096,   global_batch 256   -> train_step
  prefill_32k : seq 32768,  global_batch 32    -> prefill (forward)
  decode_32k  : cache 32768, global_batch 128  -> serve_step
  long_500k   : cache 524288, global_batch 1   -> serve_step (SSM/hybrid only)

``long_500k`` is skipped for pure full-attention architectures (see
DESIGN.md §4) — quadratic attention at 512k would misrepresent them.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models import decode
from ..models import params as MP
from ..models.config import ModelConfig
from ..sharding.rules import (ShardingStrategy, param_pspecs,
                              sanitize_spec)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # 'train' | 'prefill' | 'decode'


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def cell_supported(cfg: ModelConfig, shape: str) -> Tuple[bool, str]:
    if shape == "long_500k" and not cfg.supports_long_context:
        return False, ("full-attention architecture: 512k decode is "
                       "quadratic; skipped per assignment (DESIGN.md §4)")
    return True, ""


def _sh(mesh: Mesh, *axes) -> NamedSharding:
    return NamedSharding(mesh, P(*axes))


def _modality_spec(cfg: ModelConfig, batch: int, mesh: Mesh,
                   st: ShardingStrategy) -> Optional[jax.ShapeDtypeStruct]:
    dt = jnp.dtype(cfg.dtype)
    if cfg.family == "vlm":
        return jax.ShapeDtypeStruct((batch, cfg.num_patches, cfg.d_model), dt,
                                    sharding=_sh(mesh, st.batch, None, None))
    if cfg.family == "audio":
        return jax.ShapeDtypeStruct((batch, cfg.encoder_seq, cfg.d_model), dt,
                                    sharding=_sh(mesh, st.batch, None, None))
    return None


def batch_specs(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
                st: ShardingStrategy) -> Dict[str, Any]:
    """Training/prefill batch ShapeDtypeStructs (tokens + optional modality)."""
    b, s = shape.global_batch, shape.seq_len
    specs: Dict[str, Any] = {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32,
                                       sharding=_sh(mesh, st.batch, None)),
    }
    mod = _modality_spec(cfg, b, mesh, st)
    if mod is not None:
        specs["modality"] = mod
    return specs


def _cache_axis_for(cfg: ModelConfig, mesh: Mesh, st: ShardingStrategy,
                    batch: int):
    """(batch_axes, head_axis): shard heads over TP only when divisible;
    tiny-batch cells (long_500k) rely on head sharding."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    tp = sizes.get(st.tp, 1)
    head_ok = cfg.num_kv_heads % tp == 0 and cfg.num_kv_heads >= tp
    baxes = st.batch if batch >= _axis_prod(mesh, st.batch) else None
    return baxes, (st.tp if head_ok else None)


def _axis_prod(mesh: Mesh, axes) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if axes is None:
        return 1
    out = 1
    for a in (axes if isinstance(axes, (tuple, list)) else (axes,)):
        out *= sizes[a]
    return out


def cache_pspecs(cfg: ModelConfig, batch: int, mesh: Mesh,
                 st: ShardingStrategy) -> Any:
    """PartitionSpecs for the decode cache tree (path-keyed)."""
    baxes, hax = _cache_axis_for(cfg, mesh, st, batch)
    shapes = decode.cache_shapes(cfg, batch, 8)   # structure only

    def spec_for(path: Tuple[str, ...], shape: tuple) -> P:
        name = path[-1]
        stacked_inner = (("self" in path and cfg.family == "vlm")
                         or ("mamba" in path and cfg.family == "hybrid"))
        n_lead = 1 + (1 if stacked_inner else 0)
        lead = [None] * n_lead
        if name in ("k", "v", "k_scale", "v_scale"):
            return P(*lead, baxes, hax, None, None)
        if name == "h":                     # mamba state (…,B,nh,st,hd)
            return P(*lead, baxes, hax, None, None)
        if name == "conv":                  # (…,B,K-1,CH)
            return P(*lead, baxes, None, st.tp)
        if name == "wkv":                   # (…,B,H,dk,dv)
            return P(*lead, baxes, hax, None, None)
        if name.startswith("shift"):        # (…,B,1,D)
            return P(*lead, baxes, None, None)
        raise KeyError(path)

    def walk(tree, path=()):
        if isinstance(tree, tuple):
            return spec_for(path, tree)
        return {k: walk(v, path + (k,)) for k, v in tree.items()}

    return walk(shapes)


def cache_specs_sharded(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
                        st: ShardingStrategy) -> Any:
    specs = decode.cache_specs(cfg, shape.global_batch, shape.seq_len)
    pspecs = cache_pspecs(cfg, shape.global_batch, mesh, st)
    return jax.tree.map(
        lambda sd, p: jax.ShapeDtypeStruct(
            sd.shape, sd.dtype,
            sharding=NamedSharding(mesh, sanitize_spec(p, sd.shape, mesh))),
        specs, pspecs)


def decode_input_specs(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
                       st: ShardingStrategy) -> Dict[str, Any]:
    b = shape.global_batch
    baxes, _ = _cache_axis_for(cfg, mesh, st, b)
    return {
        "tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32,
                                       sharding=_sh(mesh, baxes, None)),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
        "cache": cache_specs_sharded(cfg, shape, mesh, st),
    }


def param_specs_sharded(cfg: ModelConfig, mesh: Mesh,
                        st: ShardingStrategy) -> Any:
    dt = jnp.dtype(cfg.dtype)
    shapes = MP.param_shapes(cfg)
    pspecs = param_pspecs(cfg, st, mesh=mesh)

    def mk(lf, spec):
        return jax.ShapeDtypeStruct(lf[0], dt,
                                    sharding=NamedSharding(mesh, spec))

    return jax.tree.map(mk, shapes, pspecs, is_leaf=MP._is_leaf)


def opt_state_specs_sharded(cfg: ModelConfig, mesh: Mesh,
                            st: ShardingStrategy) -> Any:
    """AdamW m/v mirror params (fp32) + scalar step."""
    shapes = MP.param_shapes(cfg)
    pspecs = param_pspecs(cfg, st, mesh=mesh)

    def mk(lf, spec):
        return jax.ShapeDtypeStruct(lf[0], jnp.float32,
                                    sharding=NamedSharding(mesh, spec))

    mirror = jax.tree.map(mk, shapes, pspecs, is_leaf=MP._is_leaf)
    from ..optim.adamw import OptState
    return OptState(step=jax.ShapeDtypeStruct((), jnp.int32),
                    m=mirror, v=jax.tree.map(lambda x: x, mirror))


def input_specs(cfg: ModelConfig, shape_name: str, mesh: Mesh,
                st: ShardingStrategy) -> Dict[str, Any]:
    """Everything the step function needs, as sharded ShapeDtypeStructs."""
    shape = SHAPES[shape_name]
    params = param_specs_sharded(cfg, mesh, st)
    if shape.kind == "train":
        return {"state": {"params": params,
                          "opt": opt_state_specs_sharded(cfg, mesh, st)},
                "batch": batch_specs(cfg, shape, mesh, st)}
    if shape.kind == "prefill":
        return {"params": params, "batch": batch_specs(cfg, shape, mesh, st)}
    return {"params": params, **decode_input_specs(cfg, shape, mesh, st)}
