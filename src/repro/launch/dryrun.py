import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is the proof that the distribution config is coherent: every cell must
``.lower().compile()`` against the production meshes (16x16 single pod,
2x16x16 multi-pod) with real shardings, and the compiled artifact yields
the memory/cost/collective numbers for EXPERIMENTS.md §Dry-run / §Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod|--both] [--force]

Artifacts: artifacts/dryrun/<arch>__<shape>__<mesh>.json (resumable).
"""
import argparse
import json
import pathlib
import time
import traceback
from typing import Any, Dict

import jax

from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import SHAPES, cell_supported, input_specs
from repro.launch.steps import install_rules, step_and_args
from repro.launch import hlo_analysis, hlo_stats
from repro.models import all_names, get_config
from repro.models.common import clear_sharding_rules

ARTIFACTS = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def _attn_layer_spans(cfg, s: int):
    """[(n_layers, kv_span)]: how many layers attend over which span."""
    if cfg.family == "ssm":
        return []
    if cfg.family == "hybrid":
        return [(cfg.num_groups, s)]          # shared attn once per group
    if cfg.local_global:
        half = cfg.num_layers // 2
        return [(half, min(s, cfg.sliding_window)), (half, s)]
    return [(cfg.num_layers, s)]


def model_flops(cfg, shape) -> float:
    """Useful-work FLOPs for the cell (6ND train / 2ND prefill / 2N decode),
    plus attention score FLOPs over each layer's true kv span (sliding
    windows and hybrid shared-attention counted exactly)."""
    n_active = cfg.active_param_count()
    n_embed = cfg.vocab_size * cfg.d_model
    n_eff = n_active - (0 if cfg.tie_embeddings else n_embed)
    b, s = shape.global_batch, shape.seq_len
    h_dh = cfg.num_heads * cfg.head_dim
    if shape.kind in ("train", "prefill"):
        # causal: each query sees ~span/2 keys on average (full span) or
        # ~span keys (window smaller than the sequence)
        attn = 0.0
        for layers, span in _attn_layer_spans(cfg, s):
            avg_kv = span / 2 if span == s else span
            attn += 4.0 * layers * b * s * avg_kv * h_dh  # QK^T + PV
        if shape.kind == "train":
            return 6.0 * n_eff * b * s + 3.0 * attn
        return 2.0 * n_eff * b * s + attn
    # decode: one token per sequence reads each layer's kv span once
    attn_dec = sum(4.0 * layers * min(span, s) * h_dh * b
                   for layers, span in _attn_layer_spans(cfg, s))
    return 2.0 * n_eff * b + attn_dec


def _spec_bytes_per_device(tree, n_dev: int) -> float:
    total = 0.0
    for leaf in jax.tree.leaves(tree):
        n = 1
        for d in leaf.shape:
            n *= d
        nbytes = n * leaf.dtype.itemsize
        sh = getattr(leaf, "sharding", None)
        if sh is not None and hasattr(sh, "num_devices"):
            shards = sh.num_devices
            try:
                shard_shape = sh.shard_shape(leaf.shape)
                shard_n = 1
                for d in shard_shape:
                    shard_n *= d
                total += shard_n * leaf.dtype.itemsize
                continue
            except Exception:
                pass
        total += nbytes / n_dev
    return total


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             outdir: pathlib.Path, force: bool = False) -> Dict[str, Any]:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    out = outdir / f"{arch}__{shape_name}__{mesh_name}.json"
    if out.exists() and not force:
        return json.loads(out.read_text())
    outdir.mkdir(parents=True, exist_ok=True)

    cfg = get_config(arch)
    kv_dt = os.environ.get("REPRO_KV_DTYPE", "")
    if kv_dt:
        import dataclasses as _dc
        cfg = _dc.replace(cfg, kv_cache_dtype=kv_dt)
    shape = SHAPES[shape_name]
    record: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "kind": shape.kind,
        "params": cfg.param_count(), "active_params": cfg.active_param_count(),
    }
    ok, why = cell_supported(cfg, shape_name)
    if not ok:
        record.update(status="skipped", reason=why)
        out.write_text(json.dumps(record, indent=1))
        return record

    t0 = time.time()
    try:
        from repro.sharding.rules import (ShardingStrategy,
                                          validate_divisibility)
        mesh = make_production_mesh(multi_pod=multi_pod)
        st = ShardingStrategy()
        if multi_pod:
            st = st.with_pod()
        install_rules(cfg, mesh, st)
        specs = input_specs(cfg, shape_name, mesh, st)
        mb = int(os.environ.get("REPRO_MICROBATCH", "1"))
        fn, args = step_and_args(cfg, shape.kind, specs, microbatches=mb)
        record["microbatches"] = mb
        chips = mesh.devices.size
        with mesh:
            lowered = jax.jit(fn).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        mem = None
        try:
            ma = compiled.memory_analysis()
            if ma is not None:
                mem = {k: int(getattr(ma, k)) for k in
                       ("argument_size_in_bytes", "output_size_in_bytes",
                        "temp_size_in_bytes", "generated_code_size_in_bytes")
                       if hasattr(ma, k)}
        except Exception:
            pass
        cost = {}
        try:
            ca = compiled.cost_analysis()
            if isinstance(ca, (list, tuple)):
                ca = ca[0]
            cost = {k: float(v) for k, v in ca.items()
                    if isinstance(v, (int, float))}
        except Exception:
            pass
        text = compiled.as_text()
        # trip-count-aware analysis (XLA cost_analysis counts loop bodies
        # once; see hlo_analysis docstring) — this is the §Roofline source.
        mcost = hlo_analysis.analyze(text)
        coll = hlo_stats.CollectiveStats(
            bytes_by_kind={k: int(v)
                           for k, v in mcost.collective_bytes.items()},
            count_by_kind={})
        mf = model_flops(cfg, shape)
        roof = hlo_stats.roofline_terms(
            {"flops": mcost.flops, "bytes accessed": mcost.traffic_bytes},
            coll, chips, mf)
        record.update(
            status="ok",
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            chips=chips,
            arg_bytes_per_device=_spec_bytes_per_device(args, chips),
            memory_analysis=mem,
            xla_cost={k: cost.get(k) for k in ("flops", "bytes accessed")
                      if k in cost},
            hlo_cost=mcost.as_dict(),
            collectives=coll.as_dict(),
            model_flops=mf,
            roofline=roof.as_dict(),
            uneven_sharding=validate_divisibility(cfg, mesh, st),
            hlo_bytes=len(text),
        )
    except Exception as e:  # failures here are bugs in the system
        record.update(status="error", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-4000:])
    finally:
        clear_sharding_rules()
    record["wall_s"] = round(time.time() - t0, 1)
    out.write_text(json.dumps(record, indent=1))
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both", action="store_true",
                    help="run single-pod AND multi-pod meshes")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=str(ARTIFACTS))
    args = ap.parse_args()
    outdir = pathlib.Path(args.out)

    archs = all_names() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both else [args.multi_pod]

    n_ok = n_skip = n_err = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape, mp, outdir, force=args.force)
                tag = rec["status"]
                n_ok += tag == "ok"
                n_skip += tag == "skipped"
                n_err += tag == "error"
                extra = ""
                if tag == "ok":
                    r = rec["roofline"]
                    extra = (f"dom={r['dominant']} "
                             f"roofline={r['roofline_frac']:.3f} "
                             f"compile={rec['compile_s']}s")
                elif tag == "error":
                    extra = rec["error"][:120]
                print(f"[{tag:7s}] {arch:22s} {shape:12s} "
                      f"{'2x16x16' if mp else '16x16':8s} {extra}",
                      flush=True)
    print(f"done: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
