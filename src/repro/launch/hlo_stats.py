"""HLO text analysis: collective bytes, op census, roofline terms.

``cost_analysis`` has no collective figures, so we parse the (post-SPMD,
per-device) HLO text and sum result-shape bytes of every collective op.
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %all-gather.5 = bf16[16,2048]{1,0} all-gather(...)
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([\d,]*)\][^=]*?\s("
    + "|".join(_COLLECTIVES) + r")(?:-start|-done)?\(")
_TUPLE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int]
    count_by_kind: Dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_kind.values())

    def as_dict(self):
        return {"bytes_by_kind": self.bytes_by_kind,
                "count_by_kind": self.count_by_kind,
                "total_bytes": self.total_bytes,
                "total_count": self.total_count}


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Sum result bytes per collective kind.  Handles tuple results
    ((f32[..], f32[..]) all-gather(...)) and async -start/-done pairs
    (only -start lines are counted)."""
    bytes_by: Dict[str, int] = {}
    count_by: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if "-done(" in stripped:
            continue  # counted at -start
        kind = None
        for c in _COLLECTIVES:
            if f" {c}(" in stripped or f" {c}-start(" in stripped:
                kind = c
                break
        if kind is None or "=" not in stripped:
            continue
        rhs = stripped.split("=", 1)[1]
        type_part = rhs.split(kind)[0]
        total = 0
        for m in _TUPLE_RE.finditer(type_part):
            total += _shape_bytes(m.group(1), m.group(2))
        if total == 0:
            continue
        bytes_by[kind] = bytes_by.get(kind, 0) + total
        count_by[kind] = count_by.get(kind, 0) + 1
    return CollectiveStats(bytes_by, count_by)


def count_op(hlo_text: str, opname: str) -> int:
    return len(re.findall(rf"\s{re.escape(opname)}\(", hlo_text))


# ---------------------------------------------------------------------------
# Roofline terms (TPU v5e constants per assignment)
# ---------------------------------------------------------------------------

PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float                # per-device HLO flops
    bytes_accessed: float       # per-device HLO bytes
    collective_bytes: float     # per-device collective bytes
    model_flops: float = 0.0    # 6*N*D (useful work, global)
    chips: int = 1

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_frac(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs * chips) — remat/redundancy waste."""
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_frac(self) -> float:
        """Fraction of the chips' peak that USEFUL work achieves at the
        modeled step time (an MFU bound)."""
        t = self.step_time_s
        if not t or not self.model_flops:
            return 0.0
        return self.model_flops / (t * self.chips * PEAK_FLOPS_BF16)

    def as_dict(self):
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "flops_per_device": self.flops,
            "bytes_per_device": self.bytes_accessed,
            "collective_bytes_per_device": self.collective_bytes,
            "model_flops": self.model_flops,
            "useful_flops_frac": self.useful_flops_frac,
            "roofline_frac": self.roofline_frac,
            "step_time_s": self.step_time_s,
            "chips": self.chips,
        }


def roofline_terms(cost: Dict, coll: CollectiveStats, chips: int,
                   model_flops: float, ici_links: int = 4) -> Roofline:
    """cost: compiled.cost_analysis() (per-device, post-SPMD)."""
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    cbytes = float(coll.total_bytes)
    return Roofline(
        compute_s=flops / PEAK_FLOPS_BF16,
        memory_s=byts / HBM_BW,
        collective_s=cbytes / (ICI_BW * ici_links),
        flops=flops, bytes_accessed=byts, collective_bytes=cbytes,
        model_flops=model_flops, chips=chips,
    )
