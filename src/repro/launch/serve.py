"""Production serving launcher: continuous batched decode loop.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b \
        --slots 4 --requests 12 --gen 16

Implements slot-based continuous batching over the family-appropriate
cache: finished sequences release their slot, queued requests claim it, and
every engine step decodes the whole batch.  (Per-slot cache reset uses a
position mask, so one jitted serve_step serves the whole run — the same
step the decode_32k / long_500k dry-run cells lower at production shape.)
"""
import argparse
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode, get_config
from repro.models import params as MP


class Request:
    def __init__(self, rid: int, prompt: np.ndarray, gen: int):
        self.rid = rid
        self.prompt = prompt
        self.gen = gen
        self.out: List[int] = []
        self.fed = 0          # prompt tokens consumed


class Engine:
    """Slot-based continuous batching on top of serve_step."""

    def __init__(self, cfg, params, slots: int, max_len: int):
        self.cfg = cfg
        self.params = params
        self.slots: List[Optional[Request]] = [None] * slots
        self.pos = 0
        self.cache = decode.init_cache(cfg, params, slots, max_len)
        self.max_len = max_len
        self._step = jax.jit(
            lambda p, c, t, pos: decode.serve_step(cfg, p, c, t, pos))
        self.steps = 0

    def admit(self, queue: List[Request]) -> None:
        for i, slot in enumerate(self.slots):
            if slot is None and queue:
                self.slots[i] = queue.pop(0)

    def step(self) -> None:
        toks = np.zeros((len(self.slots), 1), np.int32)
        for i, r in enumerate(self.slots):
            if r is None:
                continue
            if r.fed < len(r.prompt):
                toks[i, 0] = r.prompt[r.fed]
                r.fed += 1
            elif r.out:
                toks[i, 0] = r.out[-1]
        logits, self.cache = self._step(self.params, self.cache,
                                        jnp.asarray(toks),
                                        jnp.asarray(self.pos, jnp.int32))
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1), np.int32)
        for i, r in enumerate(self.slots):
            if r is None:
                continue
            if r.fed >= len(r.prompt):
                r.out.append(int(nxt[i]))
                if len(r.out) >= r.gen:
                    self.slots[i] = None    # slot released
        self.pos += 1
        self.steps += 1


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    rng = np.random.default_rng(args.seed)
    params = MP.init_params(cfg, seed=args.seed)
    max_len = (args.prompt_len + args.gen) * (
        1 + args.requests // args.slots) + 8

    queue = [Request(i, rng.integers(1, cfg.vocab_size,
                                     size=args.prompt_len).astype(np.int32),
                     args.gen)
             for i in range(args.requests)]
    done: List[Request] = []
    eng = Engine(cfg, params, args.slots, max_len)

    t0 = time.time()
    inflight = lambda: sum(s is not None for s in eng.slots)
    while queue or inflight():
        eng.admit(queue)
        before = [s for s in eng.slots]
        eng.step()
        for prev, cur in zip(before, eng.slots):
            if prev is not None and cur is None:
                done.append(prev)
        if eng.pos >= max_len - 1:
            break
    dt = time.time() - t0
    total_tokens = sum(len(r.out) for r in done)
    print(f"[serve] {cfg.name}: {len(done)}/{args.requests} requests, "
          f"{total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens / max(dt, 1e-9):.1f} tok/s, "
          f"{eng.steps} engine steps)")
    assert len(done) == args.requests, "not all requests completed"
    print("OK")


if __name__ == "__main__":
    main()
