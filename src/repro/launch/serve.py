"""Production serving launcher: continuous batched decode loop.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b \
        --slots 4 --requests 12 --gen 16 \
        --metrics-out /tmp/serve.prom --spans-out /tmp/serve_spans.jsonl

Implements slot-based continuous batching over the family-appropriate
cache: finished sequences release their slot, queued requests claim it, and
every engine step decodes the whole batch.  (Per-slot cache reset uses a
position mask, so one jitted serve_step serves the whole run — the same
step the decode_32k / long_500k dry-run cells lower at production shape.)

Observability (``repro.obs``): the engine accepts an optional
``MetricsRegistry`` and ``SpanTracer``.  Every instrumentation site is
guarded by ``if ... is not None`` — the uninstrumented engine pays nothing
beyond the ``jax.block_until_ready`` it always performs (the step's argmax
is transferred to the host each step regardless, so the sync is inherent to
the serving loop, and making it explicit means *every* wall-clock stamp is
taken after device work finished — async-dispatch timing lies are
structurally impossible).  One span per request tracks the
enqueue -> admit -> prefill -> first_token -> complete phase chain; one
event per engine step carries slot occupancy, queue depth, and tokens
emitted.  Under a fixed ``--seed`` the span stream is byte-identical across
runs in the exporter's ``--stable`` mode (wall-clock fields normalized).

Resilience (``repro.launch.resilience`` + ``repro.launch.faults``): the
engine optionally takes a :class:`~repro.launch.faults.FaultPlan` (seeded,
replayable step-level fault injection) and a
:class:`~repro.launch.resilience.ResilienceConfig` (detection + recovery
policy), each defaulting to ``None`` under the same zero-cost-when-off
contract as the observability hooks.  With resilience on: sampled logits
pass a per-step finite-guard; a non-finite slot is quarantined (cache
positions zeroed, slot released) and its request requeued with capped
exponential backoff + deterministic jitter, up to ``max_attempts``;
injected step exceptions abort the step without mutating any request;
per-request TTFT/completion deadlines and a bounded queue with pluggable
shedding run admission control; engine health walks
healthy -> degraded -> draining.  Deadlines and backoff are measured on a
virtual *tick* clock (engine steps + latency-spike penalties), never wall
time, so the whole failure/recovery schedule is deterministic under a seed
and the chaos span streams stay byte-identical in ``--stable`` mode.
"""
import argparse
import functools
import time
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import faults as FLT
from repro.launch import resilience as RES
from repro.models import decode, get_config
from repro.models import params as MP
from repro.obs import MetricsRegistry, SpanTracer, spans as SP, traffic
from repro.obs.modelprof import LayerProfiler
from repro.obs import modelprof as MPF


class Request:
    def __init__(self, rid: int, prompt: np.ndarray, gen: int,
                 deadline_ticks: int = 0, ttft_deadline_ticks: int = 0):
        self.rid = rid
        self.prompt = prompt
        self.gen = gen
        self.out: List[int] = []
        self.fed = 0              # prompt tokens consumed
        self.reason = ""          # set on completion
        self.enqueue_us = -1      # engine-epoch stamps (observability only)
        self.first_token_us = -1
        # resilience state (all deterministic; ticks, not wall time)
        self.attempt = 1
        self.enqueue_tick = -1    # first-submit tick (-1 = never offered)
        self.deadline_ticks = deadline_ticks        # per-request override
        self.ttft_deadline_ticks = ttft_deadline_ticks
        self.deadline_end = -1    # absolute tick bounds (-1 = none)
        self.ttft_end = -1
        self.ttft_seen = False    # first token emitted (any attempt)
        self.ttft_observed = False  # TTFT recorded once (metrics only)

    @property
    def est_tokens(self) -> int:
        """Footprint estimate for token-budget admission control."""
        return len(self.prompt) + self.gen


def serve_metrics(reg: MetricsRegistry, cfg, slots: int, cache) -> dict:
    """Create (get-or-create) the serving instrument set on ``reg``.

    Shared by the engine and the batch driver so every serving surface
    exports the same metric names (see the README metric table).
    """
    st = decode.step_stats(cfg, cache)
    reg.gauge("serve_slots_total", "configured engine slots").set(slots)
    reg.gauge("serve_cache_bytes",
              "bytes held by the decode cache").set(st["cache_bytes"])
    reg.gauge("serve_cache_max_len",
              "cache positions available").set(st["cache_max_len"])
    reg.gauge("serve_approx_flops_per_token",
              "2 x active params").set(st["approx_flops_per_token"])
    m = {
        "enq": reg.counter("serve_requests_enqueued_total",
                           "requests submitted to the queue"),
        "adm": reg.counter("serve_requests_admitted_total",
                           "requests that claimed a slot"),
        "fin": reg.counter("serve_requests_completed_total",
                           "requests finished normally"),
        "trunc": reg.counter("serve_requests_truncated_total",
                             "requests truncated before finishing"),
        "steps": reg.counter("serve_engine_steps_total",
                             "engine steps executed"),
        "gen": reg.counter("serve_tokens_generated_total",
                           "tokens decoded across all requests"),
        "pre": reg.counter("serve_tokens_prefill_total",
                           "prompt tokens fed through the decode path"),
        "occ": reg.gauge("serve_slots_occupied",
                         "slots occupied after the last admit/step"),
        "qd": reg.gauge("serve_queue_depth", "requests waiting for a slot"),
        "step_h": reg.histogram("serve_step_latency_us",
                                "engine step wall time (post-sync)"),
        "ttft": reg.histogram("serve_ttft_us",
                              "enqueue to first generated token"),
        "dtok": reg.histogram("serve_decode_token_us",
                              "steady-state per-token decode latency"),
        "retry": reg.counter("serve_retries_total",
                             "slot quarantines that requeued the victim"),
        "finj": reg.counter("serve_faults_injected_total",
                            "faults injected by the active FaultPlan"),
        "fdet": reg.counter("serve_faults_detected_total",
                            "faults caught by the finite-guard or step "
                            "exception handler"),
        "rej": reg.counter("serve_queue_rejections_total",
                           "submissions bounced by admission control "
                           "(retryable by the client)"),
        "health": reg.gauge("serve_engine_health",
                            "0 healthy / 1 degraded / 2 draining"),
    }
    for reason in RES.REASONS:
        m["trunc_" + reason] = reg.counter(
            f"serve_requests_truncated_{reason}_total",
            f"requests truncated with reason {reason!r}")
    return m


@functools.lru_cache(maxsize=None)
def _guarded_argmax():
    """Fused sample + finite-screen: one dispatch returns the argmax row
    per slot and whether every logit in that row is finite."""
    return jax.jit(lambda last: (
        jnp.argmax(last, axis=-1).astype(jnp.int32),
        jnp.all(jnp.isfinite(last), axis=-1)))


class Engine:
    """Slot-based continuous batching on top of serve_step."""

    def __init__(self, cfg, params, slots: int, max_len: int,
                 metrics: Optional[MetricsRegistry] = None,
                 spans: Optional[SpanTracer] = None,
                 layers: Optional["LayerProfiler"] = None,
                 faults: Optional[FLT.FaultPlan] = None,
                 resilience: Optional[RES.ResilienceConfig] = None):
        self.cfg = cfg
        self.params = params
        self.slots: List[Optional[Request]] = [None] * slots
        self.pos = 0
        self.max_len = max_len
        # attaching a layer profiler switches the engine to the sliced
        # per-operator step (same math, bit-identical logits — asserted by
        # tests) whose cache travels in per-group list form; the fused
        # engine pays nothing for the feature existing
        self.layers = layers
        if layers is not None:
            self._prof = decode.make_profiled_serve_step(cfg)
            self.cache = decode.ProfiledServeStep.init_cache(
                cfg, params, slots, max_len)
        else:
            self._prof = None
            self.cache = decode.init_cache(cfg, params, slots, max_len)
        self._step = decode.make_serve_step(cfg)
        self.steps = 0
        self.queue: List[Request] = []
        self.done: List[Request] = []
        self.spans = spans
        # resilience state — all structural (tick clock, not wall time)
        self.faults = faults
        self.res = resilience
        self._tick = 0            # steps + latency-spike penalty ticks
        self.delayed: List[Tuple[int, Request]] = []  # (due_tick, victim)
        self.health = RES.HEALTHY
        self.health_ticks = {RES.HEALTHY: 0, RES.DEGRADED: 0,
                             RES.DRAINING: 0}
        self._clean = 0           # consecutive fault-free steps
        self._fault_ticks: List[int] = []
        self.faults_injected = 0
        self.faults_detected = 0
        self.retries = 0
        if resilience is not None and resilience.token_budget > 0:
            self._token_budget = resilience.token_budget
        else:
            self._token_budget = slots * max_len
        # one clock for every stamp: when a tracer is attached its epoch is
        # the authoritative one (span events default to tracer time), so the
        # metrics-side stamps must read the same clock or phase timestamps
        # drift apart by the construction-time offset
        self._t0 = time.perf_counter()
        self._now_us = spans.now_us if spans is not None \
            else self._own_now_us
        self._m = serve_metrics(metrics, cfg, slots, self.cache) \
            if metrics is not None else None

    # -- observability helpers ----------------------------------------------

    def _own_now_us(self) -> int:
        return int((time.perf_counter() - self._t0) * 1e6)

    @property
    def inflight(self) -> int:
        return sum(s is not None for s in self.slots)

    # -- health state machine ------------------------------------------------

    def _set_health(self, state: str) -> None:
        if state == self.health:
            return
        self.health = state
        if self.spans is not None:
            self.spans.emit(SP.HEALTH, prov=("engine",), step=self.steps,
                            detail=state, data=(RES.HEALTH_CODE[state],))
        if self._m is not None:
            self._m["health"].set(RES.HEALTH_CODE[state])

    def _record_fault(self) -> None:
        """A fault was *detected* this step: degrade, maybe drain."""
        self._clean = 0
        res = self.res
        if res.drain_faults > 0:
            self._fault_ticks.append(self._tick)
            self._fault_ticks = [t for t in self._fault_ticks
                                 if t > self._tick - res.drain_window]
            if len(self._fault_ticks) >= res.drain_faults:
                self._set_health(RES.DRAINING)
                return
        if self.health == RES.HEALTHY:
            self._set_health(RES.DEGRADED)

    def _health_step(self, detected: bool) -> None:
        if self.res is None:
            return
        if not detected:
            self._clean += 1
            if self.health == RES.DEGRADED \
                    and self._clean >= self.res.recovery_ticks:
                self._set_health(RES.HEALTHY)
        self.health_ticks[self.health] += 1

    # -- queue lifecycle -----------------------------------------------------

    def submit(self, req: Request) -> str:
        """Offer a request.  Returns ``"queued"``, ``"rejected"``
        (admission control bounced it — the client may retry),
        ``"shed"`` (terminally dropped), or ``"deadline"``."""
        if req.enqueue_tick < 0:
            # first offer: stamp the span + absolute deadline bounds once,
            # before any admission decision — rejected requests were still
            # *offered* and must carry an enqueue event
            req.enqueue_tick = self._tick
            res = self.res
            dl = req.deadline_ticks or (res.deadline_ticks if res else 0)
            req.deadline_end = req.enqueue_tick + dl if dl > 0 else -1
            tdl = req.ttft_deadline_ticks or \
                (res.ttft_deadline_ticks if res else 0)
            req.ttft_end = req.enqueue_tick + tdl if tdl > 0 else -1
            if self.spans is not None or self._m is not None:
                now = self._now_us()
                req.enqueue_us = now
                if self.spans is not None:
                    self.spans.emit(SP.REQ_ENQUEUE, ts_us=now,
                                    prov=SP.req_prov(req.rid),
                                    step=self.steps, rid=req.rid)
                if self._m is not None:
                    self._m["enq"].inc()
        res = self.res
        if res is None:
            self.queue.append(req)
            if self._m is not None:
                self._m["qd"].set(len(self.queue))
            return "queued"
        if self.health == RES.DRAINING:
            self._finish(req, SP.TRUNCATED_PREFIX + RES.REASON_SHED)
            return "shed"
        if req.deadline_end >= 0 and self._tick >= req.deadline_end:
            # a client retry arrived after the request's own deadline
            self._finish(req, SP.TRUNCATED_PREFIX + RES.REASON_DEADLINE)
            return "deadline"
        if res.queue_cap and len(self.queue) >= res.queue_cap:
            if res.shed_policy == RES.POLICY_SHED_OLDEST:
                self._finish(self.queue.pop(0),
                             SP.TRUNCATED_PREFIX + RES.REASON_SHED)
            else:
                if self._m is not None:
                    self._m["rej"].inc()
                return "rejected"
        if res.shed_policy == RES.POLICY_TOKEN_BUDGET:
            est = req.est_tokens + sum(q.est_tokens for q in self.queue)
            if est > self._token_budget:
                if self._m is not None:
                    self._m["rej"].inc()
                return "rejected"
        self.queue.append(req)
        if self._m is not None:
            self._m["qd"].set(len(self.queue))
        return "queued"

    def shed(self, req: Request) -> None:
        """Terminally drop an offered-but-unqueued request (e.g. the
        client gave up retrying a rejection)."""
        self._finish(req, SP.TRUNCATED_PREFIX + RES.REASON_SHED)

    def _release_delayed(self) -> None:
        """Move due backed-off victims to the queue front (retries jump
        the line — they have already waited).  When the engine is
        otherwise idle, fast-forward the tick clock to the earliest due
        retry instead of spinning empty steps."""
        if not self.delayed:
            return
        if not self.inflight and not self.queue:
            earliest = min(t for t, _ in self.delayed)
            if earliest > self._tick:
                self._tick = earliest
        due = sorted(((t, r.rid, r) for t, r in self.delayed
                      if t <= self._tick))
        if not due:
            return
        self.delayed = [(t, r) for t, r in self.delayed if t > self._tick]
        self.queue[:0] = [r for _, _, r in due]
        if self._m is not None:
            self._m["qd"].set(len(self.queue))

    def _sweep_queue_deadlines(self) -> None:
        """Expire queued requests that can no longer meet their deadline
        (even if admitted right now, completion lands past the bound)."""
        if self.res is None:
            return
        keep: List[Request] = []
        for r in self.queue:
            if (r.deadline_end >= 0 and self._tick >= r.deadline_end) or \
                    (r.ttft_end >= 0 and not r.ttft_seen
                     and self._tick >= r.ttft_end):
                self._finish(r, SP.TRUNCATED_PREFIX + RES.REASON_DEADLINE)
            else:
                keep.append(r)
        if len(keep) != len(self.queue):
            self.queue[:] = keep
            if self._m is not None:
                self._m["qd"].set(len(self.queue))

    def admit(self, queue: Optional[List[Request]] = None) -> None:
        """Fill free slots from ``queue`` (default: the engine's own)."""
        q = self.queue if queue is None else queue
        if queue is None:
            self._release_delayed()
            self._sweep_queue_deadlines()
        for i, slot in enumerate(self.slots):
            if slot is None and q:
                r = q.pop(0)
                self.slots[i] = r
                if self.spans is not None:
                    self.spans.emit(SP.REQ_ADMIT, prov=SP.req_prov(r.rid),
                                    step=self.steps, rid=r.rid, slot=i)
                if self._m is not None:
                    self._m["adm"].inc()
                    self._m["qd"].set(len(self.queue))
                    self._m["occ"].set(self.inflight)

    def _finish(self, r: Request, detail: str, slot: int = -1) -> None:
        """Shared terminal bookkeeping: span, per-reason counters, dtok."""
        r.reason = detail
        self.done.append(r)
        if self.spans is not None:
            self.spans.emit(SP.REQ_COMPLETE, prov=SP.req_prov(r.rid),
                            step=self.steps, rid=r.rid, slot=slot,
                            detail=detail, data=(len(r.out),))
        if self._m is not None:
            m = self._m
            if detail == SP.FINISHED:
                m["fin"].inc()
            else:
                m["trunc"].inc()
                key = "trunc_" + detail[len(SP.TRUNCATED_PREFIX):]
                if key in m:
                    m[key].inc()
            m["occ"].set(self.inflight)
            m["qd"].set(len(self.queue))
            if slot >= 0 and len(r.out) >= 2 and r.first_token_us >= 0:
                m["dtok"].observe((self._now_us() - r.first_token_us)
                                  / (len(r.out) - 1))

    def _complete(self, i: int, reason: str) -> None:
        r = self.slots[i]
        assert r is not None
        self.slots[i] = None
        self._finish(r, reason, slot=i)

    def truncate_all(self, reason: str) -> None:
        """Release every in-flight, queued, and backed-off request."""
        detail = SP.TRUNCATED_PREFIX + reason
        for i, r in enumerate(self.slots):
            if r is not None:
                self._complete(i, detail)
        while self.queue:
            self._finish(self.queue.pop(0), detail)
        for _, _, r in sorted((t, r.rid, r) for t, r in self.delayed):
            self._finish(r, detail)
        self.delayed = []

    def _enforce_deadlines(self) -> None:
        """End-of-step deadline pass over in-flight requests (end of step
        so the release never contradicts the step's occupancy snapshot)."""
        if self.res is None:
            return
        for i, r in enumerate(self.slots):
            if r is None:
                continue
            if (r.deadline_end >= 0 and self._tick > r.deadline_end) or \
                    (r.ttft_end >= 0 and not r.ttft_seen
                     and self._tick > r.ttft_end):
                self._complete(i, SP.TRUNCATED_PREFIX + RES.REASON_DEADLINE)

    # -- the engine step -----------------------------------------------------

    def _abort_step(self, observing: bool, t0: float, spike_ticks: int,
                    spike_us: int) -> None:
        """An injected (or caught) step exception: the whole lockstep batch
        loses the step — no tokens, no cache advance, ``pos`` frozen — but
        the step still counts, ticks, and carries a span."""
        self._record_fault()
        if spike_us:
            time.sleep(spike_us / 1e6)
        occupied = self.inflight
        if observing:
            now = self._now_us()
            wall_us = int((time.perf_counter() - t0) * 1e6)
        if self.spans is not None:
            self.spans.emit(SP.STEP, prov=SP.step_prov(self.steps),
                            step=self.steps, detail="fault:exception",
                            dur_us=wall_us,
                            data=(occupied, len(self.queue), 0, 0))
        if self._m is not None:
            self._m["steps"].inc()
            self._m["step_h"].observe(wall_us)
        self._health_step(detected=True)
        self._tick += 1 + spike_ticks
        self._enforce_deadlines()
        self.steps += 1

    def step(self) -> None:
        pending = self.faults.at(self.steps) if self.faults is not None \
            else ()
        observing = self.spans is not None or self._m is not None
        t0 = time.perf_counter() if observing else 0.0
        spike_ticks = 0
        spike_us = 0
        injected = 0
        n_exc = 0
        for f in pending:
            if f.kind == FLT.LATENCY_SPIKE:
                injected += 1
                spike_ticks += f.spike_ticks
                spike_us += f.spike_us
            elif f.kind == FLT.EXCEPTION:
                injected += 1
                n_exc += 1
        if n_exc:
            # injected before any request mutation, so the aborted step
            # needs no rollback
            self.faults_injected += injected
            if self._m is not None:
                self._m["finj"].inc(injected)
            if self.res is None:
                raise FLT.InjectedFault(
                    f"injected step exception at step {self.steps}")
            self.faults_detected += n_exc
            if self._m is not None:
                self._m["fdet"].inc(n_exc)
            self._abort_step(observing, t0, spike_ticks, spike_us)
            return
        toks = np.zeros((len(self.slots), 1), np.int32)
        prefill_started: List[int] = []
        fed_slots: List[int] = []
        prefill_fed = 0
        for i, r in enumerate(self.slots):
            if r is None:
                continue
            if r.fed < len(r.prompt):
                if r.fed == 0:
                    prefill_started.append(r.rid)
                toks[i, 0] = r.prompt[r.fed]
                r.fed += 1
                fed_slots.append(i)
                prefill_fed += 1
            elif r.out:
                toks[i, 0] = r.out[-1]
        if self.spans is not None:
            for rid in prefill_started:
                self.spans.emit(SP.REQ_PREFILL, prov=SP.req_prov(rid),
                                step=self.steps, rid=rid)
        occupied = self.inflight
        seg_walls: Optional[List[float]] = None
        try:
            if self._prof is not None:
                logits, self.cache, seg_walls = self._prof(
                    self.params, self.cache, jnp.asarray(toks),
                    jnp.asarray(self.pos, jnp.int32))
            else:
                logits, self.cache = self._step(
                    self.params, self.cache, jnp.asarray(toks),
                    jnp.asarray(self.pos, jnp.int32))
        except Exception:
            if self.res is None:
                raise
            # genuine runtime failure: roll back this step's prompt feeds
            # (no cache was written) and degrade instead of crashing
            for i in fed_slots:
                r = self.slots[i]
                if r is not None:
                    r.fed -= 1
            self.faults_detected += 1
            if self._m is not None:
                self._m["fdet"].inc()
            self._abort_step(observing, t0, spike_ticks, spike_us)
            return
        last = logits[:, -1]
        for f in pending:
            if f.kind in (FLT.NAN_LOGITS, FLT.INF_LOGITS):
                injected += 1
                bad_val = jnp.nan if f.kind == FLT.NAN_LOGITS else jnp.inf
                last = last.at[f.slot].set(bad_val)
        if self.res is not None and self.res.finite_guard:
            nxt_d, fin_d = _guarded_argmax()(last)
            nxt = np.asarray(nxt_d, np.int32)
            finite = np.asarray(fin_d)
        else:
            nxt = np.asarray(jnp.argmax(last, axis=-1), np.int32)
            finite = None
        for f in pending:
            if f.kind == FLT.CACHE_CORRUPT:
                # applied after the step's cache write: silent until the
                # poison reaches the slot's logits on a later step
                injected += 1
                self.cache = decode.corrupt_cache_slot(self.cfg, self.cache,
                                                       f.slot)
        if injected:
            self.faults_injected += injected
            if self._m is not None:
                self._m["finj"].inc(injected)
        # the argmax transfer above already forced the logits; block on the
        # cache too so every wall-clock stamp below is post-device-sync
        jax.block_until_ready(self.cache)
        if spike_us:
            time.sleep(spike_us / 1e6)
        bad: List[int] = []
        if finite is not None:
            bad = [i for i, r in enumerate(self.slots)
                   if r is not None and not bool(finite[i])]
        new_tokens = 0
        first_token: List[int] = []
        completed: List[int] = []
        for i, r in enumerate(self.slots):
            if r is None or i in bad:
                continue
            if r.fed >= len(r.prompt):
                r.out.append(int(nxt[i]))
                new_tokens += 1
                if len(r.out) == 1:
                    r.ttft_seen = True
                    first_token.append(i)
                if len(r.out) >= r.gen:
                    completed.append(i)
        if observing:
            now = self._now_us()
            wall_us = int((time.perf_counter() - t0) * 1e6)
            for i in first_token:
                r = self.slots[i]
                assert r is not None
                r.first_token_us = now
                if self.spans is not None:
                    self.spans.emit(SP.REQ_FIRST_TOKEN, ts_us=now,
                                    prov=SP.req_prov(r.rid), step=self.steps,
                                    rid=r.rid, slot=i)
                if self._m is not None and r.enqueue_us >= 0 \
                        and not r.ttft_observed:
                    # once per request: a retried victim keeps its original
                    # TTFT; the -1 sentinel can never reach the histogram
                    # because observation happens only at emission time
                    self._m["ttft"].observe(now - r.enqueue_us)
                    r.ttft_observed = True
        for i in completed:
            self._complete(i, SP.FINISHED)
        for i in bad:
            self._quarantine(i)
        if self.spans is not None:
            self.spans.emit(SP.STEP, prov=SP.step_prov(self.steps),
                            step=self.steps, dur_us=wall_us,
                            data=(occupied, len(self.queue), new_tokens,
                                  prefill_fed))
        if self._m is not None:
            m = self._m
            m["steps"].inc()
            m["gen"].inc(new_tokens)
            m["pre"].inc(prefill_fed)
            m["occ"].set(self.inflight)
            m["step_h"].observe(wall_us)
        if self.layers is not None and seg_walls is not None:
            # one-clock rule: when a span tracer is attached its epoch is
            # authoritative, so the layer records stamp with the same
            # post-step `now` as the step span they join to
            self.layers.on_step(
                self.steps, self._prof.ops, seg_walls,
                ts_us=now if self.spans is not None else None)
        self._health_step(detected=bool(bad))
        self._tick += 1 + spike_ticks
        self._enforce_deadlines()
        self.pos += 1
        self.steps += 1

    def _quarantine(self, i: int) -> None:
        """Non-finite logits on slot ``i``: zero the slot's cache
        positions, release the slot, and either requeue the victim with
        backoff or terminate it when attempts are exhausted."""
        r = self.slots[i]
        assert r is not None
        self.faults_detected += 1
        if self._m is not None:
            self._m["fdet"].inc()
        self._record_fault()
        self.cache = decode.reset_cache_slot(self.cfg, self.cache, i)
        res = self.res
        if r.attempt >= res.max_attempts:
            reason = RES.REASON_FAULT if res.max_attempts == 1 \
                else RES.REASON_RETRY_EXHAUSTED
            self._complete(i, SP.TRUNCATED_PREFIX + reason)
            return
        self.slots[i] = None
        failed = r.attempt
        r.attempt += 1
        r.out = []
        r.fed = 0
        r.first_token_us = -1
        delay = RES.backoff_ticks(res, r.rid, failed)
        self.delayed.append((self._tick + 1 + delay, r))
        self.retries += 1
        if self.spans is not None:
            self.spans.emit(SP.REQ_RETRY, prov=SP.req_prov(r.rid),
                            step=self.steps, rid=r.rid, slot=i,
                            detail=SP.QUARANTINE_PREFIX + "nonfinite",
                            data=(failed, delay))
        if self._m is not None:
            self._m["retry"].inc()
            self._m["occ"].set(self.inflight)

    # -- drivers -------------------------------------------------------------

    def run(self) -> None:
        """Drain the queue, backed-off retries, and all in-flight work."""
        while self.queue or self.inflight or self.delayed:
            if self.pos >= self.max_len - 1:
                self.truncate_all("max_len")
                break
            self.admit()
            self.step()


class ReplayDriver:
    """Incremental replay of an arrival schedule: each request joins the
    queue once the engine has executed its ``arrival_step`` steps (when
    the engine goes idle the clock fast-forwards to the next arrival).

    One :meth:`tick` is one scheduler round (submit due arrivals, admit,
    step).  Exposing the replay one tick at a time lets the serve
    benchmark drive an instrumented and an uninstrumented engine through
    the identical schedule *interleaved tick-for-tick*, so its overhead
    comparison pairs wall-clock samples taken milliseconds apart —
    back-to-back full runs would be seconds apart and CPU load drift
    swamps the signal.

    Admission-control rejections are retryable: the driver plays the
    client, resubmitting a bounced request with doubling step backoff up
    to ``client_retries`` times before giving up and shedding it — so
    every offered request still terminates with an explicit reason.
    """

    def __init__(self, eng: Engine,
                 arrivals: Sequence[Tuple[int, Request]],
                 client_retries: int = 4) -> None:
        self.eng = eng
        self.arrivals = arrivals
        self._order = sorted(range(len(arrivals)),
                             key=lambda j: (arrivals[j][0],
                                            arrivals[j][1].rid))
        self._i = 0
        self.client_retries = client_retries
        self._pending: List[Tuple[int, int, Request]] = []  # (due, tries, r)

    @property
    def active(self) -> bool:
        return (self._i < len(self.arrivals) or bool(self._pending)
                or bool(self.eng.queue) or bool(self.eng.delayed)
                or bool(self.eng.inflight))

    def _offer(self, req: Request, tries: int = 0) -> None:
        if self.eng.submit(req) == "rejected":
            if tries >= self.client_retries:
                self.eng.shed(req)
            else:
                self._pending.append((self.eng.steps + (2 << tries),
                                      tries + 1, req))

    def _submit_due(self, all_remaining: bool = False) -> None:
        eng = self.eng
        if self._pending:
            due = [(d, t, r) for d, t, r in self._pending
                   if all_remaining or d <= eng.steps]
            if due:
                self._pending = [p for p in self._pending if p not in due]
                for d, t, r in sorted(due, key=lambda p: (p[0], p[2].rid)):
                    self._offer(r, t)
        while self._i < len(self.arrivals) and (
                all_remaining
                or self.arrivals[self._order[self._i]][0] <= eng.steps
                or (not eng.inflight and not eng.queue and not eng.delayed
                    and not self._pending)):
            self._offer(self.arrivals[self._order[self._i]][1])
            self._i += 1

    def _flush(self) -> None:
        """Force every not-yet-offered request into the engine and shed
        anything still bouncing, so ``truncate_all`` accounts for all."""
        self._submit_due(all_remaining=True)
        while self._pending:
            _, _, r = self._pending.pop(0)
            self.eng.shed(r)

    def tick(self) -> bool:
        """One scheduler round; returns True if an engine step ran."""
        if not self.active:
            return False
        eng = self.eng
        self._submit_due()
        if eng.pos >= eng.max_len - 1:
            self._flush()
            eng.truncate_all("max_len")
            return False
        eng.admit()
        eng.step()
        return True


def replay(eng: Engine, arrivals: Sequence[Tuple[int, Request]]) -> None:
    """Drive ``eng`` through an arrival schedule to completion."""
    drv = ReplayDriver(eng, arrivals)
    while drv.active:
        drv.tick()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--arrival-mean", type=float, default=0.0,
                    help="Poisson mean inter-arrival gap in engine steps "
                         "(0 = whole queue arrives up front)")
    ap.add_argument("--metrics-out", default="",
                    help="write the metrics registry here on exit "
                         "(.json -> JSON, anything else -> Prometheus text)")
    ap.add_argument("--spans-out", default="",
                    help="write the span event stream here as JSONL")
    ap.add_argument("--profile-layers", default="",
                    help="run the sliced per-operator step and write one "
                         "layer record per operator per engine step here "
                         "as JSONL (repro.obs.modelprof)")
    ap.add_argument("--stable", action="store_true",
                    help="normalize wall-clock fields in the span/layer "
                         "exports (byte-identical across same-seed runs)")
    ap.add_argument("--fault-plan", default="",
                    help="replay a FaultPlan JSON (repro.launch.faults); "
                         "auto-enables resilience")
    ap.add_argument("--deadline-steps", type=int, default=0,
                    help="per-request completion deadline in engine ticks "
                         "(0 = none); auto-enables resilience")
    ap.add_argument("--ttft-deadline-steps", type=int, default=0,
                    help="per-request TTFT deadline in engine ticks")
    ap.add_argument("--queue-cap", type=int, default=0,
                    help="bound the queue (0 = unbounded)")
    ap.add_argument("--shed-policy", default=RES.POLICY_REJECT_NEWEST,
                    choices=RES.SHED_POLICIES)
    ap.add_argument("--max-attempts", type=int, default=3,
                    help="total tries per request incl. the first")
    ap.add_argument("--resilience", action="store_true",
                    help="enable the resilience layer even with no faults "
                         "or deadlines configured")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    rng = np.random.default_rng(args.seed)
    params = MP.init_params(cfg, seed=args.seed)
    per_req = args.prompt_len + args.gen
    if args.arrival_mean > 0:
        # spread arrivals stretch the schedule; budget for a serial tail
        max_len = per_req * args.requests + 8
    else:
        max_len = per_req * (1 + args.requests // args.slots) + 8

    resilient = (args.resilience or args.fault_plan or args.deadline_steps
                 or args.ttft_deadline_steps or args.queue_cap)
    res = RES.ResilienceConfig(
        max_attempts=args.max_attempts, queue_cap=args.queue_cap,
        shed_policy=args.shed_policy, deadline_ticks=args.deadline_steps,
        ttft_deadline_ticks=args.ttft_deadline_steps,
        seed=args.seed) if resilient else None
    plan = FLT.FaultPlan.load(args.fault_plan) if args.fault_plan else None
    if plan is not None or res is not None:
        # retries replay whole requests and exception faults freeze pos:
        # give the step budget headroom so chaos runs end by draining, not
        # by tripping the max_len guard
        max_len = max_len * 2 + 64

    trace = traffic.synth_trace(args.seed, args.requests, args.arrival_mean,
                                [args.prompt_len], [args.gen])
    arrivals = [(t.arrival_step,
                 Request(t.rid,
                         rng.integers(1, cfg.vocab_size,
                                      size=t.prompt_len).astype(np.int32),
                         t.gen_len))
                for t in trace]

    metrics = MetricsRegistry() if args.metrics_out else None
    spans_tr = SpanTracer() if args.spans_out else None
    layers = LayerProfiler() if args.profile_layers else None
    eng = Engine(cfg, params, args.slots, max_len,
                 metrics=metrics, spans=spans_tr, layers=layers,
                 faults=plan, resilience=res)

    t0 = time.perf_counter()
    replay(eng, arrivals)
    # Engine.step syncs on the step outputs before returning (explicit
    # block_until_ready), so this delta is a true post-device wall clock.
    dt = time.perf_counter() - t0
    finished = [r for r in eng.done if r.reason == SP.FINISHED]
    truncated = [r for r in eng.done if r.reason != SP.FINISHED]
    total_tokens = sum(len(r.out) for r in eng.done)
    print(f"[serve] {cfg.name}: {len(finished)}/{args.requests} requests, "
          f"{total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens / max(dt, 1e-9):.1f} tok/s, "
          f"{eng.steps} engine steps)")
    if truncated:
        print(f"[serve] {len(truncated)} truncated: "
              f"{sorted(set(r.reason for r in truncated))}")
    if plan is not None or res is not None:
        print(f"[serve] resilience: {eng.faults_injected} faults injected, "
              f"{eng.faults_detected} detected, {eng.retries} retries, "
              f"goodput {len(finished) / max(args.requests, 1):.3f}, "
              f"health={eng.health}")
    if metrics is not None:
        ttft = metrics.get("serve_ttft_us")
        print(f"[serve] ttft p50={ttft.quantile(0.5):.0f}us "
              f"p99={ttft.quantile(0.99):.0f}us "
              f"({ttft.count} first tokens)")
        with open(args.metrics_out, "w") as f:
            f.write(metrics.dump_json()
                    if args.metrics_out.endswith(".json")
                    else metrics.to_prometheus())
        print(f"[serve] metrics -> {args.metrics_out}")
    if spans_tr is not None:
        problems = SP.validate(spans_tr.events, slots=args.slots,
                               engine_steps=eng.steps)
        assert not problems, problems
        with open(args.spans_out, "w") as f:
            f.write(SP.to_jsonl(spans_tr.events, stable=args.stable))
        print(f"[serve] {len(spans_tr.events)} span events -> "
              f"{args.spans_out}{' (stable)' if args.stable else ''}")
    if layers is not None:
        problems = MPF.validate(layers.records, cfg=cfg,
                                engine_steps=eng.steps)
        if spans_tr is not None:
            problems += MPF.join_mismatches(layers.records,
                                            spans_tr.events, cfg=cfg)
        assert not problems, problems
        with open(args.profile_layers, "w") as f:
            f.write(MPF.to_jsonl(layers.records, stable=args.stable))
        print(f"[serve] {len(layers.records)} layer records -> "
              f"{args.profile_layers}{' (stable)' if args.stable else ''}")
    assert len(eng.done) == args.requests, "requests lost by the engine"
    if plan is None and res is None:
        assert len(finished) == args.requests, "not all requests completed"
    print("OK")


if __name__ == "__main__":
    main()
