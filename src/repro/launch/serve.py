"""Production serving launcher: continuous batched decode loop.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b \
        --slots 4 --requests 12 --gen 16 \
        --metrics-out /tmp/serve.prom --spans-out /tmp/serve_spans.jsonl

Implements slot-based continuous batching over the family-appropriate
cache: finished sequences release their slot, queued requests claim it, and
every engine step decodes the whole batch.  (Per-slot cache reset uses a
position mask, so one jitted serve_step serves the whole run — the same
step the decode_32k / long_500k dry-run cells lower at production shape.)

Observability (``repro.obs``): the engine accepts an optional
``MetricsRegistry`` and ``SpanTracer``.  Every instrumentation site is
guarded by ``if ... is not None`` — the uninstrumented engine pays nothing
beyond the ``jax.block_until_ready`` it always performs (the step's argmax
is transferred to the host each step regardless, so the sync is inherent to
the serving loop, and making it explicit means *every* wall-clock stamp is
taken after device work finished — async-dispatch timing lies are
structurally impossible).  One span per request tracks the
enqueue -> admit -> prefill -> first_token -> complete phase chain; one
event per engine step carries slot occupancy, queue depth, and tokens
emitted.  Under a fixed ``--seed`` the span stream is byte-identical across
runs in the exporter's ``--stable`` mode (wall-clock fields normalized).
"""
import argparse
import time
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode, get_config
from repro.models import params as MP
from repro.obs import MetricsRegistry, SpanTracer, spans as SP, traffic
from repro.obs.modelprof import LayerProfiler
from repro.obs import modelprof as MPF


class Request:
    def __init__(self, rid: int, prompt: np.ndarray, gen: int):
        self.rid = rid
        self.prompt = prompt
        self.gen = gen
        self.out: List[int] = []
        self.fed = 0              # prompt tokens consumed
        self.reason = ""          # set on completion
        self.enqueue_us = -1      # engine-epoch stamps (observability only)
        self.first_token_us = -1


def serve_metrics(reg: MetricsRegistry, cfg, slots: int, cache) -> dict:
    """Create (get-or-create) the serving instrument set on ``reg``.

    Shared by the engine and the batch driver so every serving surface
    exports the same metric names (see the README metric table).
    """
    st = decode.step_stats(cfg, cache)
    reg.gauge("serve_slots_total", "configured engine slots").set(slots)
    reg.gauge("serve_cache_bytes",
              "bytes held by the decode cache").set(st["cache_bytes"])
    reg.gauge("serve_cache_max_len",
              "cache positions available").set(st["cache_max_len"])
    reg.gauge("serve_approx_flops_per_token",
              "2 x active params").set(st["approx_flops_per_token"])
    return {
        "enq": reg.counter("serve_requests_enqueued_total",
                           "requests submitted to the queue"),
        "adm": reg.counter("serve_requests_admitted_total",
                           "requests that claimed a slot"),
        "fin": reg.counter("serve_requests_completed_total",
                           "requests finished normally"),
        "trunc": reg.counter("serve_requests_truncated_total",
                             "requests truncated before finishing"),
        "steps": reg.counter("serve_engine_steps_total",
                             "engine steps executed"),
        "gen": reg.counter("serve_tokens_generated_total",
                           "tokens decoded across all requests"),
        "pre": reg.counter("serve_tokens_prefill_total",
                           "prompt tokens fed through the decode path"),
        "occ": reg.gauge("serve_slots_occupied",
                         "slots occupied after the last admit/step"),
        "qd": reg.gauge("serve_queue_depth", "requests waiting for a slot"),
        "step_h": reg.histogram("serve_step_latency_us",
                                "engine step wall time (post-sync)"),
        "ttft": reg.histogram("serve_ttft_us",
                              "enqueue to first generated token"),
        "dtok": reg.histogram("serve_decode_token_us",
                              "steady-state per-token decode latency"),
    }


class Engine:
    """Slot-based continuous batching on top of serve_step."""

    def __init__(self, cfg, params, slots: int, max_len: int,
                 metrics: Optional[MetricsRegistry] = None,
                 spans: Optional[SpanTracer] = None,
                 layers: Optional["LayerProfiler"] = None):
        self.cfg = cfg
        self.params = params
        self.slots: List[Optional[Request]] = [None] * slots
        self.pos = 0
        self.max_len = max_len
        # attaching a layer profiler switches the engine to the sliced
        # per-operator step (same math, bit-identical logits — asserted by
        # tests) whose cache travels in per-group list form; the fused
        # engine pays nothing for the feature existing
        self.layers = layers
        if layers is not None:
            self._prof = decode.make_profiled_serve_step(cfg)
            self.cache = decode.ProfiledServeStep.init_cache(
                cfg, params, slots, max_len)
        else:
            self._prof = None
            self.cache = decode.init_cache(cfg, params, slots, max_len)
        self._step = decode.make_serve_step(cfg)
        self.steps = 0
        self.queue: List[Request] = []
        self.done: List[Request] = []
        self.spans = spans
        # one clock for every stamp: when a tracer is attached its epoch is
        # the authoritative one (span events default to tracer time), so the
        # metrics-side stamps must read the same clock or phase timestamps
        # drift apart by the construction-time offset
        self._t0 = time.perf_counter()
        self._now_us = spans.now_us if spans is not None \
            else self._own_now_us
        self._m = serve_metrics(metrics, cfg, slots, self.cache) \
            if metrics is not None else None

    # -- observability helpers ----------------------------------------------

    def _own_now_us(self) -> int:
        return int((time.perf_counter() - self._t0) * 1e6)

    @property
    def inflight(self) -> int:
        return sum(s is not None for s in self.slots)

    # -- queue lifecycle -----------------------------------------------------

    def submit(self, req: Request) -> None:
        self.queue.append(req)
        if self.spans is not None or self._m is not None:
            now = self._now_us()
            req.enqueue_us = now
            if self.spans is not None:
                self.spans.emit(SP.REQ_ENQUEUE, ts_us=now,
                                prov=SP.req_prov(req.rid), step=self.steps,
                                rid=req.rid)
            if self._m is not None:
                self._m["enq"].inc()
                self._m["qd"].set(len(self.queue))

    def admit(self, queue: Optional[List[Request]] = None) -> None:
        """Fill free slots from ``queue`` (default: the engine's own)."""
        q = self.queue if queue is None else queue
        for i, slot in enumerate(self.slots):
            if slot is None and q:
                r = q.pop(0)
                self.slots[i] = r
                if self.spans is not None:
                    self.spans.emit(SP.REQ_ADMIT, prov=SP.req_prov(r.rid),
                                    step=self.steps, rid=r.rid, slot=i)
                if self._m is not None:
                    self._m["adm"].inc()
                    self._m["qd"].set(len(self.queue))
                    self._m["occ"].set(self.inflight)

    def _complete(self, i: int, reason: str) -> None:
        r = self.slots[i]
        assert r is not None
        self.slots[i] = None
        r.reason = reason
        self.done.append(r)
        if self.spans is not None:
            self.spans.emit(SP.REQ_COMPLETE, prov=SP.req_prov(r.rid),
                            step=self.steps, rid=r.rid, slot=i,
                            detail=reason, data=(len(r.out),))
        if self._m is not None:
            m = self._m
            (m["fin"] if reason == SP.FINISHED else m["trunc"]).inc()
            m["occ"].set(self.inflight)
            if len(r.out) >= 2 and r.first_token_us >= 0:
                m["dtok"].observe((self._now_us() - r.first_token_us)
                                  / (len(r.out) - 1))

    def truncate_all(self, reason: str) -> None:
        """Release every in-flight and queued request as truncated."""
        detail = SP.TRUNCATED_PREFIX + reason
        for i, r in enumerate(self.slots):
            if r is not None:
                self._complete(i, detail)
        while self.queue:
            r = self.queue.pop(0)
            r.reason = detail
            self.done.append(r)
            if self.spans is not None:
                self.spans.emit(SP.REQ_COMPLETE, prov=SP.req_prov(r.rid),
                                step=self.steps, rid=r.rid, detail=detail,
                                data=(len(r.out),))
            if self._m is not None:
                self._m["trunc"].inc()
                self._m["qd"].set(len(self.queue))

    # -- the engine step -----------------------------------------------------

    def step(self) -> None:
        observing = self.spans is not None or self._m is not None
        t0 = time.perf_counter() if observing else 0.0
        toks = np.zeros((len(self.slots), 1), np.int32)
        prefill_started: List[int] = []
        prefill_fed = 0
        for i, r in enumerate(self.slots):
            if r is None:
                continue
            if r.fed < len(r.prompt):
                if r.fed == 0:
                    prefill_started.append(r.rid)
                toks[i, 0] = r.prompt[r.fed]
                r.fed += 1
                prefill_fed += 1
            elif r.out:
                toks[i, 0] = r.out[-1]
        if self.spans is not None:
            for rid in prefill_started:
                self.spans.emit(SP.REQ_PREFILL, prov=SP.req_prov(rid),
                                step=self.steps, rid=rid)
        occupied = self.inflight
        seg_walls: Optional[List[float]] = None
        if self._prof is not None:
            logits, self.cache, seg_walls = self._prof(
                self.params, self.cache, jnp.asarray(toks),
                jnp.asarray(self.pos, jnp.int32))
        else:
            logits, self.cache = self._step(self.params, self.cache,
                                            jnp.asarray(toks),
                                            jnp.asarray(self.pos, jnp.int32))
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1), np.int32)
        # the argmax transfer above already forced the logits; block on the
        # cache too so every wall-clock stamp below is post-device-sync
        jax.block_until_ready(self.cache)
        new_tokens = 0
        first_token: List[int] = []
        completed: List[int] = []
        for i, r in enumerate(self.slots):
            if r is None:
                continue
            if r.fed >= len(r.prompt):
                r.out.append(int(nxt[i]))
                new_tokens += 1
                if len(r.out) == 1:
                    first_token.append(i)
                if len(r.out) >= r.gen:
                    completed.append(i)
        if observing:
            now = self._now_us()
            wall_us = int((time.perf_counter() - t0) * 1e6)
            for i in first_token:
                r = self.slots[i]
                assert r is not None
                r.first_token_us = now
                if self.spans is not None:
                    self.spans.emit(SP.REQ_FIRST_TOKEN, ts_us=now,
                                    prov=SP.req_prov(r.rid), step=self.steps,
                                    rid=r.rid, slot=i)
                if self._m is not None and r.enqueue_us >= 0:
                    self._m["ttft"].observe(now - r.enqueue_us)
        for i in completed:
            self._complete(i, SP.FINISHED)
        if self.spans is not None:
            self.spans.emit(SP.STEP, prov=SP.step_prov(self.steps),
                            step=self.steps, dur_us=wall_us,
                            data=(occupied, len(self.queue), new_tokens,
                                  prefill_fed))
        if self._m is not None:
            m = self._m
            m["steps"].inc()
            m["gen"].inc(new_tokens)
            m["pre"].inc(prefill_fed)
            m["occ"].set(self.inflight)
            m["step_h"].observe(wall_us)
        if self.layers is not None and seg_walls is not None:
            # one-clock rule: when a span tracer is attached its epoch is
            # authoritative, so the layer records stamp with the same
            # post-step `now` as the step span they join to
            self.layers.on_step(
                self.steps, self._prof.ops, seg_walls,
                ts_us=now if self.spans is not None else None)
        self.pos += 1
        self.steps += 1

    # -- drivers -------------------------------------------------------------

    def run(self) -> None:
        """Drain the queue and all in-flight work."""
        while self.queue or self.inflight:
            if self.pos >= self.max_len - 1:
                self.truncate_all("max_len")
                break
            self.admit()
            self.step()


class ReplayDriver:
    """Incremental replay of an arrival schedule: each request joins the
    queue once the engine has executed its ``arrival_step`` steps (when
    the engine goes idle the clock fast-forwards to the next arrival).

    One :meth:`tick` is one scheduler round (submit due arrivals, admit,
    step).  Exposing the replay one tick at a time lets the serve
    benchmark drive an instrumented and an uninstrumented engine through
    the identical schedule *interleaved tick-for-tick*, so its overhead
    comparison pairs wall-clock samples taken milliseconds apart —
    back-to-back full runs would be seconds apart and CPU load drift
    swamps the signal.
    """

    def __init__(self, eng: Engine,
                 arrivals: Sequence[Tuple[int, Request]]) -> None:
        self.eng = eng
        self.arrivals = arrivals
        self._order = sorted(range(len(arrivals)),
                             key=lambda j: (arrivals[j][0],
                                            arrivals[j][1].rid))
        self._i = 0

    @property
    def active(self) -> bool:
        return (self._i < len(self.arrivals) or bool(self.eng.queue)
                or bool(self.eng.inflight))

    def _submit_due(self, all_remaining: bool = False) -> None:
        eng = self.eng
        while self._i < len(self.arrivals) and (
                all_remaining
                or self.arrivals[self._order[self._i]][0] <= eng.steps
                or (not eng.inflight and not eng.queue)):
            eng.submit(self.arrivals[self._order[self._i]][1])
            self._i += 1

    def tick(self) -> bool:
        """One scheduler round; returns True if an engine step ran."""
        if not self.active:
            return False
        eng = self.eng
        self._submit_due()
        if eng.pos >= eng.max_len - 1:
            self._submit_due(all_remaining=True)
            eng.truncate_all("max_len")
            return False
        eng.admit()
        eng.step()
        return True


def replay(eng: Engine, arrivals: Sequence[Tuple[int, Request]]) -> None:
    """Drive ``eng`` through an arrival schedule to completion."""
    drv = ReplayDriver(eng, arrivals)
    while drv.active:
        drv.tick()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--arrival-mean", type=float, default=0.0,
                    help="Poisson mean inter-arrival gap in engine steps "
                         "(0 = whole queue arrives up front)")
    ap.add_argument("--metrics-out", default="",
                    help="write the metrics registry here on exit "
                         "(.json -> JSON, anything else -> Prometheus text)")
    ap.add_argument("--spans-out", default="",
                    help="write the span event stream here as JSONL")
    ap.add_argument("--profile-layers", default="",
                    help="run the sliced per-operator step and write one "
                         "layer record per operator per engine step here "
                         "as JSONL (repro.obs.modelprof)")
    ap.add_argument("--stable", action="store_true",
                    help="normalize wall-clock fields in the span/layer "
                         "exports (byte-identical across same-seed runs)")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    rng = np.random.default_rng(args.seed)
    params = MP.init_params(cfg, seed=args.seed)
    per_req = args.prompt_len + args.gen
    if args.arrival_mean > 0:
        # spread arrivals stretch the schedule; budget for a serial tail
        max_len = per_req * args.requests + 8
    else:
        max_len = per_req * (1 + args.requests // args.slots) + 8

    trace = traffic.synth_trace(args.seed, args.requests, args.arrival_mean,
                                [args.prompt_len], [args.gen])
    arrivals = [(t.arrival_step,
                 Request(t.rid,
                         rng.integers(1, cfg.vocab_size,
                                      size=t.prompt_len).astype(np.int32),
                         t.gen_len))
                for t in trace]

    metrics = MetricsRegistry() if args.metrics_out else None
    spans_tr = SpanTracer() if args.spans_out else None
    layers = LayerProfiler() if args.profile_layers else None
    eng = Engine(cfg, params, args.slots, max_len,
                 metrics=metrics, spans=spans_tr, layers=layers)

    t0 = time.perf_counter()
    replay(eng, arrivals)
    # Engine.step syncs on the step outputs before returning (explicit
    # block_until_ready), so this delta is a true post-device wall clock.
    dt = time.perf_counter() - t0
    finished = [r for r in eng.done if r.reason == SP.FINISHED]
    truncated = [r for r in eng.done if r.reason != SP.FINISHED]
    total_tokens = sum(len(r.out) for r in eng.done)
    print(f"[serve] {cfg.name}: {len(finished)}/{args.requests} requests, "
          f"{total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens / max(dt, 1e-9):.1f} tok/s, "
          f"{eng.steps} engine steps)")
    if truncated:
        print(f"[serve] {len(truncated)} truncated: "
              f"{sorted(set(r.reason for r in truncated))}")
    if metrics is not None:
        ttft = metrics.get("serve_ttft_us")
        print(f"[serve] ttft p50={ttft.quantile(0.5):.0f}us "
              f"p99={ttft.quantile(0.99):.0f}us "
              f"({ttft.count} first tokens)")
        with open(args.metrics_out, "w") as f:
            f.write(metrics.dump_json()
                    if args.metrics_out.endswith(".json")
                    else metrics.to_prometheus())
        print(f"[serve] metrics -> {args.metrics_out}")
    if spans_tr is not None:
        problems = SP.validate(spans_tr.events, slots=args.slots,
                               engine_steps=eng.steps)
        assert not problems, problems
        with open(args.spans_out, "w") as f:
            f.write(SP.to_jsonl(spans_tr.events, stable=args.stable))
        print(f"[serve] {len(spans_tr.events)} span events -> "
              f"{args.spans_out}{' (stable)' if args.stable else ''}")
    if layers is not None:
        problems = MPF.validate(layers.records, cfg=cfg,
                                engine_steps=eng.steps)
        if spans_tr is not None:
            problems += MPF.join_mismatches(layers.records,
                                            spans_tr.events, cfg=cfg)
        assert not problems, problems
        with open(args.profile_layers, "w") as f:
            f.write(MPF.to_jsonl(layers.records, stable=args.stable))
        print(f"[serve] {len(layers.records)} layer records -> "
              f"{args.profile_layers}{' (stable)' if args.stable else ''}")
    assert len(eng.done) == args.requests, "requests lost by the engine"
    assert len(finished) == args.requests, "not all requests completed"
    print("OK")


if __name__ == "__main__":
    main()
