"""Seeded, replayable fault injection for the continuous-batching engine.

    PYTHONPATH=src python -m repro.launch.faults --seed 7 --steps 64 \
        --rate 0.05 --slots 4 --out /tmp/plan.json

A :class:`FaultPlan` is a deterministic schedule of step-level faults the
engine (``repro.launch.serve.Engine``) applies while it runs.  Plans are a
pure function of their generation arguments (``generate`` draws from one
``numpy`` PRNG stream), serialize to JSON (``save``/``load``), and replay
byte-identically: two engines driven by the same seed, trace, and plan
produce the same span stream in the exporter's ``--stable`` mode — every
chaos run is reproducible, which is what makes the resilience benchmark
(``benchmarks/resilience_bench.py``) gateable in CI.

Fault taxonomy
--------------

==================  ========================================================
kind                effect on the engine step it fires at
==================  ========================================================
``nan_logits``      the target slot's sampled logits row becomes NaN —
                    models an overflowed accumulation / bad kernel output
``inf_logits``      same, with +Inf — a saturated activation
``exception``       the step computation raises :class:`InjectedFault` —
                    models a device/runtime error for the whole lockstep
                    batch (no tokens, no cache advance)
``latency_spike``   the step stalls (``spike_us`` of real sleep) and the
                    deadline clock jumps ``spike_ticks`` — models GC /
                    preemption / a slow collective
``cache_corrupt``   the target slot's cache entries are silently set to
                    NaN *after* the step — undetectable until the poison
                    reaches the logits on a later step
==================  ========================================================

Slot-targeted faults (``nan_logits``/``inf_logits``/``cache_corrupt``) hit
whatever request occupies the slot when they fire — including none; a
corruption planted in a free slot ambushes the next request admitted there,
which is exactly the nastiest real-world variant.

The engine injects faults regardless of whether resilience is enabled:
injection without ``ResilienceConfig`` is the negative control showing the
finite-guard is load-bearing (``tests/test_serve_faults.py``).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
from typing import Dict, List, Sequence, Tuple

import numpy as np

NAN_LOGITS = "nan_logits"
INF_LOGITS = "inf_logits"
EXCEPTION = "exception"
LATENCY_SPIKE = "latency_spike"
CACHE_CORRUPT = "cache_corrupt"

KINDS = (NAN_LOGITS, INF_LOGITS, EXCEPTION, LATENCY_SPIKE, CACHE_CORRUPT)
SLOT_KINDS = (NAN_LOGITS, INF_LOGITS, CACHE_CORRUPT)


class InjectedFault(RuntimeError):
    """Raised by an ``exception`` fault inside the engine step."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One fault firing at one engine step."""
    step: int                  # engine step index the fault fires at
    kind: str                  # one of KINDS
    slot: int = -1             # target slot for SLOT_KINDS (-1 otherwise)
    spike_ticks: int = 0       # latency_spike: deadline-clock penalty
    spike_us: int = 0          # latency_spike: real wall-clock stall

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(known: {KINDS})")
        if self.kind in SLOT_KINDS and self.slot < 0:
            raise ValueError(f"{self.kind} fault needs a slot >= 0")

    def to_json(self) -> Dict[str, int]:
        return {"step": self.step, "kind": self.kind, "slot": self.slot,
                "spike_ticks": self.spike_ticks, "spike_us": self.spike_us}

    @staticmethod
    def from_json(o: Dict) -> "FaultSpec":
        return FaultSpec(int(o["step"]), str(o["kind"]),
                         int(o.get("slot", -1)),
                         int(o.get("spike_ticks", 0)),
                         int(o.get("spike_us", 0)))


class FaultPlan:
    """An ordered, replayable schedule of :class:`FaultSpec`."""

    __slots__ = ("specs", "meta", "_by_step")

    def __init__(self, specs: Sequence[FaultSpec],
                 meta: Dict[str, object] | None = None) -> None:
        self.specs: Tuple[FaultSpec, ...] = tuple(
            sorted(specs, key=lambda s: (s.step, s.kind, s.slot)))
        self.meta: Dict[str, object] = dict(meta or {})
        by_step: Dict[int, List[FaultSpec]] = {}
        for s in self.specs:
            by_step.setdefault(s.step, []).append(s)
        self._by_step = {k: tuple(v) for k, v in by_step.items()}

    def __len__(self) -> int:
        return len(self.specs)

    def at(self, step: int) -> Tuple[FaultSpec, ...]:
        """Faults firing at engine step ``step`` (deterministic order)."""
        return self._by_step.get(step, ())

    # -- generation ----------------------------------------------------------

    @classmethod
    def generate(cls, seed: int, steps: int, rate: float,
                 slots: int, kinds: Sequence[str] = KINDS,
                 spike_ticks: int = 4, spike_us: int = 2000) -> "FaultPlan":
        """Draw a seeded campaign: each of ``steps`` engine steps faults
        independently with probability ``rate``; the kind is uniform over
        ``kinds`` and slot-targeted kinds pick a uniform slot.  One PRNG
        stream, consumed in step order — the plan is a pure function of
        its arguments."""
        for k in kinds:
            if k not in KINDS:
                raise ValueError(f"unknown fault kind {k!r}")
        rng = np.random.default_rng(seed)
        specs: List[FaultSpec] = []
        for step in range(steps):
            if rng.random() >= rate:
                continue
            kind = kinds[int(rng.integers(len(kinds)))]
            slot = int(rng.integers(slots)) if kind in SLOT_KINDS else -1
            specs.append(FaultSpec(
                step, kind, slot,
                spike_ticks=spike_ticks if kind == LATENCY_SPIKE else 0,
                spike_us=spike_us if kind == LATENCY_SPIKE else 0))
        return cls(specs, meta={"seed": seed, "steps": steps, "rate": rate,
                                "slots": slots, "kinds": list(kinds)})

    # -- serialization -------------------------------------------------------

    def to_json(self) -> str:
        return json.dumps({"schema": 1, "meta": self.meta,
                           "faults": [s.to_json() for s in self.specs]},
                          indent=2, sort_keys=True) + "\n"

    @classmethod
    def from_json(cls, text: str) -> "FaultPlan":
        o = json.loads(text)
        return cls([FaultSpec.from_json(f) for f in o.get("faults", [])],
                   meta=o.get("meta", {}))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())

    @classmethod
    def load(cls, path: str) -> "FaultPlan":
        with open(path) as f:
            return cls.from_json(f.read())

    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for s in self.specs:
            out[s.kind] = out.get(s.kind, 0) + 1
        return out


def main() -> None:
    ap = argparse.ArgumentParser(
        description="Generate a seeded, replayable fault plan")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--steps", type=int, default=64,
                    help="engine-step horizon the plan covers")
    ap.add_argument("--rate", type=float, default=0.05,
                    help="per-step fault probability")
    ap.add_argument("--slots", type=int, default=4,
                    help="engine slot count (targets of slot faults)")
    ap.add_argument("--kinds", default=",".join(KINDS),
                    help="comma-separated fault kinds to draw from")
    ap.add_argument("--spike-ticks", type=int, default=4)
    ap.add_argument("--spike-us", type=int, default=2000)
    ap.add_argument("--out", required=True, help="write the plan JSON here")
    args = ap.parse_args()
    kinds = tuple(k.strip() for k in args.kinds.split(",") if k.strip())
    plan = FaultPlan.generate(args.seed, args.steps, args.rate, args.slots,
                              kinds=kinds, spike_ticks=args.spike_ticks,
                              spike_us=args.spike_us)
    plan.save(args.out)
    print(f"[faults] {len(plan)} faults over {args.steps} steps "
          f"({plan.counts()}) -> {args.out}")


if __name__ == "__main__":
    main()
