"""Resilience policy for the continuous-batching engine.

Everything here is pure configuration + deterministic arithmetic; the
mechanisms live in ``repro.launch.serve.Engine`` and are zero-cost when no
:class:`ResilienceConfig` is passed (same ``if x is not None`` hook
convention as ``repro.obs``).

Three policy surfaces:

* **Detection / degradation** — ``finite_guard`` screens sampled logits
  every step; a non-finite row quarantines the slot (cache reset, slot
  released) and requeues the victim with capped exponential backoff +
  deterministic jitter (:func:`backoff_ticks`).  Engine health walks
  ``healthy -> degraded -> draining``: degraded while faults are recent,
  back to healthy after ``recovery_ticks`` clean ticks, draining (stop
  admitting, shed new work) when ``drain_faults`` faults land within a
  ``drain_window``-tick sliding window.

* **Deadlines** — per-request TTFT and completion deadlines measured on
  the engine's *tick* clock (steps + latency-spike penalties), so
  enforcement is structurally deterministic; wall-clock variants exist as
  per-request fields for interactive callers.  Expired requests release
  their slot with the distinct ``deadline`` reason.

* **Admission control** — ``queue_cap`` bounds the queue; on overflow one
  of three shedding policies runs: ``reject_newest`` (bounce the
  arrival — retryable), ``shed_oldest`` (evict the stalest queued request
  to admit the new one), ``token_budget`` (reject arrivals whose
  estimated token footprint exceeds a per-queue budget derived from
  ``decode.step_stats``).

All knobs are frozen-dataclass fields so a config hashes/compares cleanly
and campaign grids in ``benchmarks/resilience_bench.py`` can sweep it.
"""
from __future__ import annotations

import dataclasses

HEALTHY = "healthy"
DEGRADED = "degraded"
DRAINING = "draining"
HEALTH_CODE = {HEALTHY: 0, DEGRADED: 1, DRAINING: 2}

POLICY_REJECT_NEWEST = "reject_newest"
POLICY_SHED_OLDEST = "shed_oldest"
POLICY_TOKEN_BUDGET = "token_budget"
SHED_POLICIES = (POLICY_REJECT_NEWEST, POLICY_SHED_OLDEST,
                 POLICY_TOKEN_BUDGET)

# Termination reasons carried in ``truncated:<reason>`` span details and
# the per-reason serve_requests_truncated_* counters.
REASON_MAX_LEN = "max_len"
REASON_DEADLINE = "deadline"
REASON_SHED = "shed"
REASON_FAULT = "fault"
REASON_RETRY_EXHAUSTED = "quarantine_retry_exhausted"
REASONS = (REASON_MAX_LEN, REASON_DEADLINE, REASON_SHED, REASON_FAULT,
           REASON_RETRY_EXHAUSTED)


@dataclasses.dataclass(frozen=True)
class ResilienceConfig:
    """Knobs for detection, retry, deadlines, and admission control.

    A zero value disables the corresponding limit (``queue_cap=0`` means
    unbounded, ``deadline_ticks=0`` means no deadline, ``drain_faults=0``
    means the engine never drains).
    """
    # detection + quarantine
    finite_guard: bool = True       # screen sampled logits for NaN/Inf
    max_attempts: int = 3           # total tries incl. the first
    backoff_base: int = 2           # ticks before retry, attempt 1
    backoff_cap: int = 32           # ceiling on the exponential term
    backoff_jitter: int = 2         # jitter span in ticks (deterministic)
    seed: int = 0                   # jitter hash seed
    # admission control
    queue_cap: int = 0              # max queued requests (0 = unbounded)
    shed_policy: str = POLICY_REJECT_NEWEST
    token_budget: int = 0           # token_budget policy: max estimated
    #                                 queued tokens (0 = derive 4x cap)
    # deadlines (engine ticks; 0 disables)
    ttft_deadline_ticks: int = 0    # enqueue -> first token
    deadline_ticks: int = 0         # enqueue -> completion
    # health state machine
    recovery_ticks: int = 8         # clean ticks: degraded -> healthy
    drain_faults: int = 0           # faults in window -> draining (0=off)
    drain_window: int = 16          # sliding window, ticks

    def __post_init__(self):
        if self.shed_policy not in SHED_POLICIES:
            raise ValueError(f"unknown shed policy {self.shed_policy!r} "
                             f"(known: {SHED_POLICIES})")
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")


def _mix(h: int, v: int) -> int:
    # splitmix64-style integer hash step: deterministic, platform-stable.
    h = (h + 0x9E3779B97F4A7C15 + v) & 0xFFFFFFFFFFFFFFFF
    h = ((h ^ (h >> 30)) * 0xBF58476D1CE4E5B9) & 0xFFFFFFFFFFFFFFFF
    h = ((h ^ (h >> 27)) * 0x94D049BB133111EB) & 0xFFFFFFFFFFFFFFFF
    return h ^ (h >> 31)


def backoff_ticks(cfg: ResilienceConfig, rid: int, attempt: int) -> int:
    """Retry delay (engine ticks) before attempt ``attempt+1`` of ``rid``:
    capped exponential plus a deterministic per-(seed, rid, attempt)
    jitter, so two runs of the same campaign back off identically."""
    base = min(cfg.backoff_cap, cfg.backoff_base * (2 ** (attempt - 1)))
    if cfg.backoff_jitter <= 0:
        return base
    jitter = _mix(_mix(cfg.seed, rid), attempt) % (cfg.backoff_jitter + 1)
    return base + jitter
