"""Mamba2 (SSD) block — zamba2's backbone layer.

Structure per layer: norm -> in_proj -> causal depthwise conv over
(x, B, C) -> SSD recurrence (scalar per-head decay via chunked decay scan)
-> gate -> out_proj.  State size 64, head dim 64, d_inner = 2 * d_model.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import constrain, norm
from .config import ModelConfig
from .ssm_ops import chunked_decay_scan, decay_scan_step


def _dims(cfg: ModelConfig):
    d_inner = 2 * cfg.d_model
    nh = d_inner // cfg.ssm_head_dim
    return d_inner, nh, cfg.ssm_state


def _split_proj(cfg: ModelConfig, proj: jax.Array):
    d_inner, nh, st = _dims(cfg)
    z, xs, bmat, cmat, dt = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + st, 2 * d_inner + 2 * st],
        axis=-1)
    return z, xs, bmat, cmat, dt


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over (B, S, CH), width K (shift-and-add form —
    lowers to cheap adds; K is small)."""
    k = w.shape[0]
    out = jnp.zeros_like(x)
    for i in range(k):
        shift = k - 1 - i
        xi = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, :x.shape[1]]
        out = out + xi * w[i]
    return jax.nn.silu(out + b)


def mamba_block(cfg: ModelConfig, p, x: jax.Array) -> jax.Array:
    """x: (B, S, D) -> (B, S, D)."""
    b, s, d = x.shape
    d_inner, nh, st = _dims(cfg)
    hx = norm(cfg, p["ln"], x)
    proj = jnp.einsum("bsd,de->bse", hx, p["in_proj"])
    z, xs, bmat, cmat, dt = _split_proj(cfg, proj)
    conv_in = jnp.concatenate([xs, bmat, cmat], axis=-1)
    conv_out = _causal_conv(conv_in, p["conv_w"], p["conv_b"])
    xs, bmat, cmat = jnp.split(conv_out, [d_inner, d_inner + st], axis=-1)

    dt_full = jax.nn.softplus(dt.astype(jnp.float32)
                              + p["dt_bias"].astype(jnp.float32))  # (B,S,nh)
    a = jnp.exp(p["A_log"].astype(jnp.float32))                    # (nh,)
    w_log = (-dt_full * a).transpose(0, 2, 1)                      # (B,nh,S)

    xh = xs.reshape(b, s, nh, cfg.ssm_head_dim).transpose(0, 2, 1, 3)
    q = jnp.broadcast_to(cmat[:, None], (b, nh, s, st))
    k = jnp.broadcast_to(bmat[:, None], (b, nh, s, st))
    k = k * dt_full.transpose(0, 2, 1)[..., None]                  # dt * B
    y = chunked_decay_scan(q, k, xh.astype(q.dtype), w_log,
                           chunk=64, diag_mode="inclusive")        # (B,nh,S,hd)
    y = y + p["D"].astype(y.dtype)[None, :, None, None] * xh.astype(y.dtype)
    y = y.transpose(0, 2, 1, 3).reshape(b, s, d_inner)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y.astype(x.dtype), p["out_proj"])
    return constrain(out, "batch", "seq", "embed")


# ---------------------------------------------------------------------------
# Decode (O(1) state per layer)
# ---------------------------------------------------------------------------


def mamba_cache_shape(cfg: ModelConfig, batch: int):
    d_inner, nh, st = _dims(cfg)
    conv_ch = d_inner + 2 * st
    return {
        "h": (batch, nh, st, cfg.ssm_head_dim),
        "conv": (batch, cfg.ssm_conv_width - 1, conv_ch),
    }


def mamba_decode_step(cfg: ModelConfig, p, x1: jax.Array, cache: Dict
                      ) -> Tuple[jax.Array, Dict]:
    """x1: (B, 1, D); cache: {'h','conv'} per mamba_cache_shape."""
    b = x1.shape[0]
    d_inner, nh, st = _dims(cfg)
    hx = norm(cfg, p["ln"], x1)
    proj = jnp.einsum("bsd,de->bse", hx, p["in_proj"])
    z, xs, bmat, cmat, dt = _split_proj(cfg, proj)
    conv_in = jnp.concatenate([xs, bmat, cmat], axis=-1)   # (B,1,CH)
    window = jnp.concatenate([cache["conv"], conv_in], axis=1)  # (B,K,CH)
    conv_out = jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"]
    conv_out = jax.nn.silu(conv_out)[:, None]              # (B,1,CH)
    xs, bmat, cmat = jnp.split(conv_out, [d_inner, d_inner + st], axis=-1)

    dt_full = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                              + p["dt_bias"].astype(jnp.float32))  # (B,nh)
    a = jnp.exp(p["A_log"].astype(jnp.float32))
    w1 = -dt_full * a                                      # (B,nh) log decay

    xh = xs[:, 0].reshape(b, nh, cfg.ssm_head_dim)
    q1 = jnp.broadcast_to(cmat[:, 0, None], (b, nh, st))
    k1 = jnp.broadcast_to(bmat[:, 0, None], (b, nh, st)) * dt_full[..., None]
    o, h_new = decay_scan_step(cache["h"], q1, k1, xh, w1)
    o = o + p["D"].astype(o.dtype)[None, :, None] * xh.astype(o.dtype)
    y = o.reshape(b, 1, d_inner) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y.astype(x1.dtype), p["out_proj"])
    return out, {"h": h_new, "conv": window[:, 1:]}
