"""Dense MLP (optionally gated) with activation-sharded intermediates."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import activation, constrain
from .config import ModelConfig
from .params import gated_mlp


def mlp_block(cfg: ModelConfig, p, x: jax.Array) -> jax.Array:
    h = jnp.einsum("bsd,df->bsf", x, p["wi"])
    if gated_mlp(cfg):
        g = jnp.einsum("bsd,df->bsf", x, p["wg"])
        h = activation(cfg, g) * h
    else:
        h = activation(cfg, h)
    h = constrain(h, "batch", "seq", "mlp")
    out = jnp.einsum("bsf,fd->bsd", h, p["wo"])
    return constrain(out, "batch", "seq", "embed")
