"""Model zoo: ten architectures over one family-dispatched substrate."""
from .config import ModelConfig, get_config, all_names, register  # noqa: F401
