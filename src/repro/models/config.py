"""Model configuration for every assigned architecture.

One frozen dataclass covers the ten families; per-arch constructor modules
live in ``repro.configs.<id>`` and must reproduce the public-literature
numbers exactly.  ``reduced()`` derives the CPU-smoke-test variant of any
config (same family/topology, tiny widths).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | vlm | hybrid | audio | ssm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // num_heads

    # attention details
    qkv_bias: bool = False
    rope_theta: float = 1e4
    logit_softcap: float = 0.0          # gemma2 final-logit softcap
    attn_softcap: float = 0.0           # gemma2 attention softcap
    sliding_window: int = 0             # local-attention window
    local_global: bool = False          # gemma2 alternating pattern

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    moe_dispatch: str = "banked"        # banked (paper-style) | gather

    # SSM / hybrid
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    hybrid_attn_every: int = 0          # zamba2: group = (n-1) mamba + 1 attn

    # enc-dec / modality frontends (stubs provide embeddings)
    encoder_layers: int = 0             # whisper encoder depth
    encoder_seq: int = 1500             # whisper frame count (stub)
    frontend: str = "none"              # none | audio_stub | patch_stub
    cross_attn_every: int = 0           # vlm: group = (n-1) self + 1 cross
    num_patches: int = 1601             # vlm stub patch count

    act: str = "silu"
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    # runtime knobs
    remat: bool = True
    scan_layers: bool = True
    use_flash_kernel: bool = False      # Pallas path (TPU); jnp ref on CPU
    kv_cache_dtype: str = ""            # "" = model dtype; "int8" quantized

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim",
                               self.d_model // max(self.num_heads, 1))

    # ---- derived ----------------------------------------------------------
    @property
    def group_size(self) -> int:
        """Layers per scanned group (heterogeneous stacks scan over groups)."""
        if self.family == "hybrid":
            return self.hybrid_attn_every
        if self.family == "vlm" and self.cross_attn_every:
            return self.cross_attn_every
        if self.local_global:
            return 2
        return 1

    @property
    def num_groups(self) -> int:
        assert self.num_layers % self.group_size == 0, (
            self.name, self.num_layers, self.group_size)
        return self.num_layers // self.group_size

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic sequence mixing (SSM/hybrid) -> long_500k runs."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True   # all assigned archs decode (whisper via its decoder)

    def param_count(self) -> int:
        """Approximate total parameters (embedding included)."""
        from . import params as P
        return P.count_params(self)

    def active_param_count(self) -> int:
        from . import params as P
        return P.count_params(self, active_only=True)

    def reduced(self) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        changes: Dict = dict(
            num_layers=self.group_size * 2,
            d_model=64,
            num_heads=4,
            num_kv_heads=min(4, max(1, self.num_kv_heads * 4
                                    // max(self.num_heads, 1))),
            d_ff=128,
            vocab_size=256,
            head_dim=16,
            dtype="float32",
            scan_layers=True,
            remat=False,
        )
        if self.num_experts:
            changes.update(num_experts=4, experts_per_token=2)
        if self.ssm_state:
            changes.update(ssm_state=16, ssm_head_dim=16)
        if self.sliding_window:
            changes.update(sliding_window=16)
        if self.encoder_layers:
            changes.update(encoder_layers=2, encoder_seq=12)
        if self.frontend == "patch_stub":
            changes.update(num_patches=9)
        return dataclasses.replace(self, **changes)


_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if not _REGISTRY:
        _load_all()
    return _REGISTRY[name]


def all_names():
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)


def _load_all():
    import importlib
    for mod in ("olmoe_1b_7b", "granite_moe_1b_a400m", "llama32_vision_11b",
                "gemma2_27b", "qwen2_0_5b", "starcoder2_7b", "qwen2_7b",
                "zamba2_7b", "whisper_large_v3", "rwkv6_7b"):
        importlib.import_module(f"repro.configs.{mod}")
