"""GQA attention: chunked (flash-style) training path, cached decode path.

The training path is a pure-jnp double-chunked online-softmax attention —
the same math as ``kernels/flash_attention.py`` (which serves as the TPU
kernel) but expressed with lax.scan so it compiles compactly inside the
layer scan and never materializes (S, S) score matrices.  GQA is an einsum
over a folded group dimension — never a materialized head repeat.

Supports: causal masking, sliding windows (gemma2 local layers), attention
softcapping, cross attention (whisper / llama-vision), QKV bias (qwen2).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .common import apply_rope, constrain, rope_freqs, softcap
from .config import ModelConfig

_NEG = -1e30


def qkv_proj(cfg: ModelConfig, p, x: jax.Array,
             kv_x: Optional[jax.Array] = None
             ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: (B,S,D) -> q (B,H,S,dh), k/v (B,Hkv,Sk,dh)."""
    b, s, _ = x.shape
    h, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    src = x if kv_x is None else kv_x
    sk = src.shape[1]
    q = jnp.einsum("bsd,de->bse", x, p["wq"])
    k = jnp.einsum("bsd,de->bse", src, p["wk"])
    v = jnp.einsum("bsd,de->bse", src, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    k = k.reshape(b, sk, hkv, dh).transpose(0, 2, 1, 3)
    v = v.reshape(b, sk, hkv, dh).transpose(0, 2, 1, 3)
    return constrain(q, "batch", "heads", None, None), \
        constrain(k, "batch", "kv_heads", None, None), \
        constrain(v, "batch", "kv_heads", None, None)


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, window: int = 0,
                      attn_softcap: float = 0.0, scale: float,
                      q_chunk: int = 512, kv_chunk: int = 512,
                      q_offset: int = 0) -> jax.Array:
    """Online-softmax attention with a STATIC flash schedule.

    The q-chunk loop is unrolled in python; each q chunk scans exactly its
    live kv range (causal frontier / sliding window), with the mask applied
    only to boundary chunks — interior chunks run mask-free.  Static chunk
    indices are the compile-time "bank selection" of the paper's layout
    discipline: no runtime conditionals, dead chunks never lowered.

    ``q_offset``: absolute position of q[0] (decode/prefill continuation).
    q: (B,H,Sq,dh), k/v: (B,Hkv,Sk,dh).
    """
    b, h, sq, dh = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    g = h // hkv
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, sk)
    nq = -(-sq // q_chunk)
    nk = -(-sk // kv_chunk)
    sq_p, sk_p = nq * q_chunk, nk * kv_chunk
    if sq_p != sq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, sq_p - sq), (0, 0)))
    if sk_p != sk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, sk_p - sk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, sk_p - sk), (0, 0)))

    qg = q.reshape(b, hkv, g, sq_p, dh)
    k_blocks = k.reshape(b, hkv, nk, kv_chunk, dh).transpose(2, 0, 1, 3, 4)
    v_blocks = v.reshape(b, hkv, nk, kv_chunk, dh).transpose(2, 0, 1, 3, 4)
    full_chunks = sk // kv_chunk       # chunks with no padding

    def make_step(q_blk, q_pos, masked: bool):
        def kv_step(carry, kj_blk):
            m, l, acc = carry
            kj, k_blk, v_blk = kj_blk
            s = jnp.einsum("bhgqd,bhkd->bhgqk", q_blk, k_blk,
                           preferred_element_type=jnp.float32) * scale
            if attn_softcap:
                s = softcap(s, attn_softcap)
            if masked:
                k_pos = kj * kv_chunk + jnp.arange(kv_chunk)
                mask = jnp.ones((q_chunk, kv_chunk), bool)
                if causal:
                    mask &= q_pos[:, None] >= k_pos[None, :]
                if window:
                    mask &= (q_pos[:, None] - k_pos[None, :]) < window
                mask &= (k_pos < sk)[None, :]
                s = jnp.where(mask[None, None, None], s, _NEG)
            m_new = jnp.maximum(m, s.max(axis=-1, keepdims=True))
            p = jnp.exp(s - m_new)
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1, keepdims=True)
            acc_new = acc * alpha + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p.astype(v_blk.dtype), v_blk,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None
        return kv_step

    def q_chunk_attend(q_blk, qi):
        a_pos = q_offset + qi * q_chunk             # first q position
        b_pos = a_pos + q_chunk - 1                 # last q position
        q_pos = a_pos + jnp.arange(q_chunk)
        # live kv chunk range [lo, hi)
        hi = min(nk, b_pos // kv_chunk + 1) if causal else nk
        lo = 0
        if window:
            # first key any query in the chunk needs: a_pos - window + 1
            lo = max(0, -(-(a_pos - window + 2 - kv_chunk) // kv_chunk))
        # fully-unmasked interior [lo_full, hi_full)
        hi_full = hi
        if causal:
            hi_full = max(lo, min(hi, (a_pos - kv_chunk + 1) // kv_chunk + 1
                                  if a_pos - kv_chunk + 1 >= 0 else 0))
        lo_full = lo
        if window:
            # chunk is unmasked only if the LAST query (b_pos) sees all keys
            lo_full = min(hi_full, max(lo, -(-(b_pos - window + 1)
                                             // kv_chunk)))
        hi_full = min(hi_full, full_chunks)          # padding needs masking
        lo_full = min(lo_full, hi_full)

        m0 = jnp.full((b, hkv, g, q_chunk, 1), _NEG, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, q_chunk, 1), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, q_chunk, dh), jnp.float32)
        carry = (m0, l0, a0)

        def run(carry, lo_i, hi_i, masked):
            if hi_i <= lo_i:
                return carry
            step = jax.checkpoint(make_step(q_blk, q_pos, masked))
            idx = jnp.arange(lo_i, hi_i)
            carry, _ = jax.lax.scan(
                step, carry,
                (idx, k_blocks[lo_i:hi_i], v_blocks[lo_i:hi_i]))
            return carry

        carry = run(carry, lo, lo_full, True)        # window boundary
        carry = run(carry, lo_full, hi_full, False)  # interior, mask-free
        carry = run(carry, hi_full, hi, True)        # causal/pad boundary
        m, l, acc = carry
        return (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)

    outs = []
    for qi in range(nq):
        q_blk = qg[:, :, :, qi * q_chunk:(qi + 1) * q_chunk]
        fn = jax.checkpoint(q_chunk_attend, static_argnums=(1,))
        outs.append(fn(q_blk, qi))
    out = jnp.concatenate(outs, axis=3) if len(outs) > 1 else outs[0]
    out = out.reshape(b, h, sq_p, dh)
    return out[:, :, :sq]


def attn_block(cfg: ModelConfig, p, x: jax.Array, *,
               rope: Optional[Tuple[jax.Array, jax.Array]] = None,
               causal: bool = True, window: int = 0,
               kv_x: Optional[jax.Array] = None,
               attn_softcap: float = 0.0) -> jax.Array:
    """Full attention sub-block (projections + mixing + output proj)."""
    b, s, d = x.shape
    q, k, v = qkv_proj(cfg, p, x, kv_x=kv_x)
    if rope is not None and kv_x is None:
        cos, sin = rope
        q = apply_rope(q, cos[:s], sin[:s])
        k = apply_rope(k, cos[:s], sin[:s])
    scale = 1.0 / (cfg.head_dim ** 0.5)
    out = chunked_attention(q, k, v, causal=causal and kv_x is None,
                            window=window, attn_softcap=attn_softcap,
                            scale=scale)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, -1)
    out = jnp.einsum("bse,ed->bsd", out, p["wo"])
    return constrain(out, "batch", "seq", "embed")


# ---------------------------------------------------------------------------
# Decode path (single new token against a static KV cache)
# ---------------------------------------------------------------------------


def attn_decode(cfg: ModelConfig, p, x1: jax.Array, cache: dict, pos,
                *, window: int = 0, attn_softcap: float = 0.0,
                ring: bool = False) -> Tuple[jax.Array, dict]:
    """x1: (B, 1, D); cache: {'k','v'} (B, Hkv, S_max, dh); pos: scalar.

    ``ring=True`` treats the cache as a circular window buffer (sliding-
    window layers): slot i holds absolute position pos - ((pos - i) mod L).

    Returns (attn output (B,1,D), updated cache).
    """
    b, _, d = x1.shape
    h, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    g = h // hkv
    q, k1, v1 = qkv_proj(cfg, p, x1)
    if cfg.rope_theta:
        posv = jnp.full((b, 1), pos, jnp.int32)
        cos, sin = rope_freqs(dh, cfg.rope_theta, posv)
        q = apply_rope(q, cos, sin)
        k1 = apply_rope(k1, cos, sin)
    smax = cache["k"].shape[2]
    # floor-mod (jnp.mod), NOT lax.rem: C-style rem goes negative for
    # pos - k_pos < 0 and would mark empty ring slots as valid
    slot = jnp.mod(pos, smax) if ring else pos
    quantized = "k_scale" in cache
    new_cache = {}
    if quantized:
        # int8 KV cache: per-token absmax scales (beyond-paper feature)
        def _quant(x):
            amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                           keepdims=True) + 1e-6
            scale = amax / 127.0
            qx = jnp.clip(jnp.round(x.astype(jnp.float32) / scale),
                          -127, 127).astype(jnp.int8)
            return qx, scale
        k_q, k_s = _quant(k1)
        v_q, v_s = _quant(v1)
        kc_q = jax.lax.dynamic_update_slice(cache["k"], k_q, (0, 0, slot, 0))
        vc_q = jax.lax.dynamic_update_slice(cache["v"], v_q, (0, 0, slot, 0))
        ks = jax.lax.dynamic_update_slice(cache["k_scale"], k_s,
                                          (0, 0, slot, 0))
        vs = jax.lax.dynamic_update_slice(cache["v_scale"], v_s,
                                          (0, 0, slot, 0))
        kc = kc_q.astype(jnp.float32) * ks
        vc = vc_q.astype(jnp.float32) * vs
        new_cache = {"k": kc_q, "v": vc_q, "k_scale": ks, "v_scale": vs}
    else:
        kc = jax.lax.dynamic_update_slice(
            cache["k"], k1.astype(cache["k"].dtype), (0, 0, slot, 0))
        vc = jax.lax.dynamic_update_slice(
            cache["v"], v1.astype(cache["v"].dtype), (0, 0, slot, 0))
    k_pos = jnp.arange(smax)
    if ring:
        abs_pos = pos - jnp.mod(pos - k_pos, smax)
        mask = abs_pos >= 0
        if window:
            mask &= (pos - abs_pos) < window
    else:
        mask = k_pos <= pos
        if window:
            mask &= (pos - k_pos) < window
    qg = q.reshape(b, hkv, g, 1, dh)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(jnp.float32),
                   kc.astype(jnp.float32)) / (dh ** 0.5)
    if attn_softcap:
        s = softcap(s, attn_softcap)
    s = jnp.where(mask[None, None, None, None], s, _NEG)
    pgs = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", pgs, vc.astype(jnp.float32))
    out = out.reshape(b, h, 1, dh).transpose(0, 2, 1, 3).reshape(b, 1, -1)
    out = jnp.einsum("bse,ed->bsd", out.astype(x1.dtype), p["wo"])
    if quantized:
        return out, new_cache
    return out, {"k": kc, "v": vc}
