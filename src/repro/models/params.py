"""Parameter trees: shapes, ShapeDtypeStruct specs, initialization, counting.

Shapes are the single source of truth: ``param_shapes`` builds a pytree whose
leaves are (shape tuple, init kind); ``param_specs`` wraps them into
ShapeDtypeStructs (dry-run — never allocates); ``init_params`` materializes
(smoke tests / small training only).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig

Leaf = Tuple[tuple, str]          # (shape, init_kind)


def _leaf(shape, kind="normal") -> Leaf:
    return (tuple(int(s) for s in shape), kind)


def _is_leaf(x) -> bool:
    return (isinstance(x, tuple) and len(x) == 2 and isinstance(x[0], tuple)
            and isinstance(x[1], str))


# ---------------------------------------------------------------------------
# Per-layer shape builders
# ---------------------------------------------------------------------------


def norm_shapes(cfg: ModelConfig) -> Dict[str, Leaf]:
    d = cfg.d_model
    if cfg.family in ("audio", "ssm"):
        return {"w": _leaf((d,), "ones"), "b": _leaf((d,), "zeros")}
    return {"w": _leaf((d,), "zeros" if cfg.name.startswith("gemma")
                       else "ones")}


def attn_shapes(cfg: ModelConfig, cross: bool = False) -> Dict[str, Any]:
    d, h, hkv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    s: Dict[str, Any] = {
        "wq": _leaf((d, h * dh)),
        "wk": _leaf((d, hkv * dh)),
        "wv": _leaf((d, hkv * dh)),
        "wo": _leaf((h * dh, d)),
    }
    if cfg.qkv_bias:
        s["bq"] = _leaf((h * dh,), "zeros")
        s["bk"] = _leaf((hkv * dh,), "zeros")
        s["bv"] = _leaf((hkv * dh,), "zeros")
    return s


def gated_mlp(cfg: ModelConfig) -> bool:
    return cfg.act == "silu" or cfg.name.startswith("gemma")


def mlp_shapes(cfg: ModelConfig, d_ff: int = 0) -> Dict[str, Leaf]:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    s = {"wi": _leaf((d, f)), "wo": _leaf((f, d))}
    if gated_mlp(cfg):
        s["wg"] = _leaf((d, f))
    return s


def moe_shapes(cfg: ModelConfig) -> Dict[str, Leaf]:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    s = {"router": _leaf((d, e)),
         "w1": _leaf((e, d, f)), "w2": _leaf((e, f, d))}
    if gated_mlp(cfg):
        s["wg"] = _leaf((e, d, f))
    return s


def dense_layer_shapes(cfg: ModelConfig) -> Dict[str, Any]:
    s = {"ln1": norm_shapes(cfg), "attn": attn_shapes(cfg),
         "ln2": norm_shapes(cfg), "mlp": mlp_shapes(cfg)}
    if cfg.name.startswith("gemma"):
        s["ln1_post"] = norm_shapes(cfg)
        s["ln2_post"] = norm_shapes(cfg)
    return s


def moe_layer_shapes(cfg: ModelConfig) -> Dict[str, Any]:
    return {"ln1": norm_shapes(cfg), "attn": attn_shapes(cfg),
            "ln2": norm_shapes(cfg), "moe": moe_shapes(cfg)}


def cross_layer_shapes(cfg: ModelConfig) -> Dict[str, Any]:
    s = {"ln1": norm_shapes(cfg), "attn": attn_shapes(cfg),
         "ln2": norm_shapes(cfg), "mlp": mlp_shapes(cfg),
         "gate_attn": _leaf((), "zeros"), "gate_mlp": _leaf((), "zeros")}
    return s


def mamba_layer_shapes(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    d_inner = 2 * d
    nh = d_inner // cfg.ssm_head_dim
    st = cfg.ssm_state
    conv_ch = d_inner + 2 * st
    return {
        "ln": norm_shapes(cfg),
        "in_proj": _leaf((d, 2 * d_inner + 2 * st + nh)),
        "conv_w": _leaf((cfg.ssm_conv_width, conv_ch)),
        "conv_b": _leaf((conv_ch,), "zeros"),
        "A_log": _leaf((nh,), "a_log"),
        "D": _leaf((nh,), "ones"),
        "dt_bias": _leaf((nh,), "dt_bias"),
        "out_proj": _leaf((d_inner, d)),
    }


def rwkv_layer_shapes(cfg: ModelConfig) -> Dict[str, Any]:
    d, f = cfg.d_model, cfg.d_ff
    h, dh = cfg.num_heads, cfg.head_dim
    lora_w, lora_mix = 64, 32
    return {
        "ln1": {"w": _leaf((d,), "ones"), "b": _leaf((d,), "zeros")},
        "ln2": {"w": _leaf((d,), "ones"), "b": _leaf((d,), "zeros")},
        "tm": {
            "mu": _leaf((5, d), "half"),            # ddlerp bases (r,k,v,w,g)
            "mix_A": _leaf((d, 5 * lora_mix)),
            "mix_B": _leaf((5, lora_mix, d), "zeros"),
            "wr": _leaf((d, h * dh)), "wk": _leaf((d, h * dh)),
            "wv": _leaf((d, h * dh)), "wg": _leaf((d, h * dh)),
            "wo": _leaf((h * dh, d)),
            "w0": _leaf((d,), "decay_base"),
            "wlora_A": _leaf((d, lora_w)),
            "wlora_B": _leaf((lora_w, d), "zeros"),
            "u": _leaf((h, dh), "half"),
            "gn_w": _leaf((d,), "ones"), "gn_b": _leaf((d,), "zeros"),
        },
        "cm": {
            "mu_k": _leaf((d,), "half"), "mu_r": _leaf((d,), "half"),
            "wk": _leaf((d, f)), "wv": _leaf((f, d)), "wr": _leaf((d, d)),
        },
    }


def enc_layer_shapes(cfg: ModelConfig) -> Dict[str, Any]:
    return {"ln1": norm_shapes(cfg), "attn": attn_shapes(cfg),
            "ln2": norm_shapes(cfg), "mlp": mlp_shapes(cfg)}


def dec_layer_shapes(cfg: ModelConfig) -> Dict[str, Any]:
    return {"ln1": norm_shapes(cfg), "attn": attn_shapes(cfg),
            "ln2": norm_shapes(cfg), "cross": attn_shapes(cfg, cross=True),
            "ln3": norm_shapes(cfg), "mlp": mlp_shapes(cfg)}


# ---------------------------------------------------------------------------
# Group / model assembly
# ---------------------------------------------------------------------------


def _stack(n: int, tree):
    return jax.tree.map(lambda lf: ((n,) + lf[0], lf[1]), tree,
                        is_leaf=_is_leaf)


def group_shapes(cfg: ModelConfig) -> Dict[str, Any]:
    fam = cfg.family
    if fam in ("dense",):
        if cfg.local_global:
            return {"local": dense_layer_shapes(cfg),
                    "global": dense_layer_shapes(cfg)}
        return {"lyr": dense_layer_shapes(cfg)}
    if fam == "moe":
        return {"lyr": moe_layer_shapes(cfg)}
    if fam == "vlm":
        n_self = cfg.cross_attn_every - 1
        return {"self": _stack(n_self, dense_layer_shapes(cfg)),
                "cross": cross_layer_shapes(cfg)}
    if fam == "hybrid":
        n_mamba = cfg.hybrid_attn_every - 1
        return {"mamba": _stack(n_mamba, mamba_layer_shapes(cfg))}
    if fam == "ssm":
        return {"lyr": rwkv_layer_shapes(cfg)}
    if fam == "audio":
        return {"lyr": dec_layer_shapes(cfg)}
    raise ValueError(fam)


def param_shapes(cfg: ModelConfig) -> Dict[str, Any]:
    d, v = cfg.d_model, cfg.vocab_size
    tree: Dict[str, Any] = {
        "embed": _leaf((v, d), "embed"),
        "blocks": _stack(cfg.num_groups, group_shapes(cfg)),
        "final_norm": norm_shapes(cfg),
    }
    if not cfg.tie_embeddings:
        tree["lm_head"] = _leaf((d, v))
    if cfg.family == "hybrid":
        tree["shared_block"] = dense_layer_shapes(cfg)
    if cfg.family == "audio":
        tree["encoder"] = _stack(cfg.encoder_layers, enc_layer_shapes(cfg))
        tree["enc_norm"] = norm_shapes(cfg)
    return tree


# ---------------------------------------------------------------------------
# Specs / init / counting
# ---------------------------------------------------------------------------


def param_specs(cfg: ModelConfig) -> Any:
    dt = jnp.dtype(cfg.dtype)
    return jax.tree.map(lambda lf: jax.ShapeDtypeStruct(lf[0], dt),
                        param_shapes(cfg), is_leaf=_is_leaf)


def _init_leaf(rng: np.random.Generator, lf: Leaf, dtype, d_model: int):
    shape, kind = lf
    if kind == "zeros":
        return jnp.zeros(shape, dtype)
    if kind == "ones":
        return jnp.ones(shape, dtype)
    if kind == "half":
        return jnp.full(shape, 0.5, dtype)
    if kind == "a_log":
        return jnp.asarray(np.log(rng.uniform(1, 16, shape)), dtype)
    if kind == "dt_bias":
        return jnp.asarray(np.log(np.expm1(rng.uniform(1e-3, 0.1, shape))),
                           dtype)
    if kind == "decay_base":
        return jnp.asarray(rng.uniform(-7.0, -5.0, shape), dtype)
    scale = 0.02 if kind == "embed" else 1.0 / math.sqrt(max(shape[0] if
                                                             shape else 1, 1))
    arr = rng.normal(0.0, scale, shape).astype(np.float32)
    return jnp.asarray(arr, dtype)


def init_params(cfg: ModelConfig, seed: int = 0) -> Any:
    rng = np.random.default_rng(seed)
    dt = jnp.dtype(cfg.dtype)
    return jax.tree.map(lambda lf: _init_leaf(rng, lf, dt, cfg.d_model),
                        param_shapes(cfg), is_leaf=_is_leaf)


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    total = 0
    expert_frac = (cfg.experts_per_token / cfg.num_experts
                   if cfg.num_experts else 1.0)

    def visit(tree, path=""):
        nonlocal total
        if _is_leaf(tree):
            n = 1
            for s in tree[0]:
                n *= s
            if active_only and "/moe/w" in path:
                n = int(n * expert_frac)
            total += n
            return
        for k, v in tree.items():
            visit(v, f"{path}/{k}")

    visit(param_shapes(cfg))
    return total
