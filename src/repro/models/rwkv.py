"""RWKV6 "Finch" block: data-dependent-decay time mix + channel mix.

Time mix: data-dependent token-shift interpolation (ddlerp with a shared
low-rank adapter), per-channel decay ``w_t = -exp(w0 + lora(x))``, WKV
recurrence with bonus ``u`` (strictly-past state + current-token bonus —
``diag_mode='bonus'`` of the decay scan), per-head group norm, output gate.

Channel mix: token-shift lerp, squared-ReLU FFN with a receptance gate.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .common import constrain, layer_norm
from .config import ModelConfig
from .ssm_ops import chunked_decay_scan, decay_scan_step


def _group_norm(x: jax.Array, w: jax.Array, b: jax.Array, heads: int,
                eps: float = 1e-5) -> jax.Array:
    """Per-head group norm over (B, S, H*dh)."""
    bsz, s, d = x.shape
    xh = x.reshape(bsz, s, heads, d // heads).astype(jnp.float32)
    mu = xh.mean(-1, keepdims=True)
    var = ((xh - mu) ** 2).mean(-1, keepdims=True)
    xh = (xh - mu) * jax.lax.rsqrt(var + eps)
    out = xh.reshape(bsz, s, d) * w.astype(jnp.float32) + b.astype(jnp.float32)
    return out.astype(x.dtype)


def _shift(x: jax.Array, prev: jax.Array = None) -> jax.Array:
    """Token shift: x_{t-1} (zeros/``prev`` for t=0)."""
    pad = jnp.zeros_like(x[:, :1]) if prev is None else prev
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def _ddlerp(p, x: jax.Array, xx: jax.Array):
    """Finch data-dependent lerp producing the 5 mixed inputs (r,k,v,w,g)."""
    delta = xx - x
    # shared low-rank adapter: (B,S,D) -> 5 x (B,S,D)
    mixed = x + delta * 0.5
    low = jnp.tanh(jnp.einsum("bsd,dr->bsr", mixed, p["mix_A"]))
    low = low.reshape(*low.shape[:-1], 5, -1)                 # (B,S,5,r)
    adj = jnp.einsum("bsir,ird->bsid", low, p["mix_B"])       # (B,S,5,D)
    mu = p["mu"][None, None]                                  # (1,1,5,D)
    out = x[:, :, None] + delta[:, :, None] * (mu + adj)
    return [out[:, :, i] for i in range(5)]


def _time_mix_core(cfg: ModelConfig, p, xr, xk, xv, xw, xg):
    h, dh = cfg.num_heads, cfg.head_dim
    b, s, _ = xr.shape
    r = jnp.einsum("bsd,de->bse", xr, p["wr"]).reshape(b, s, h, dh)
    k = jnp.einsum("bsd,de->bse", xk, p["wk"]).reshape(b, s, h, dh)
    v = jnp.einsum("bsd,de->bse", xv, p["wv"]).reshape(b, s, h, dh)
    g = jnp.einsum("bsd,de->bse", xg, p["wg"])
    w_log = -jnp.exp(p["w0"].astype(jnp.float32)
                     + jnp.einsum("bsd,dr->bsr", xw.astype(jnp.float32),
                                  p["wlora_A"].astype(jnp.float32))
                     @ p["wlora_B"].astype(jnp.float32))      # (B,S,D) <= 0
    w_log = w_log.reshape(b, s, h, dh)
    return r, k, v, g, w_log


def time_mix(cfg: ModelConfig, p, x: jax.Array) -> jax.Array:
    b, s, d = x.shape
    h, dh = cfg.num_heads, cfg.head_dim
    xx = _shift(x)
    xr, xk, xv, xw, xg = _ddlerp(p, x, xx)
    r, k, v, g, w_log = _time_mix_core(cfg, p, xr, xk, xv, xw, xg)
    # (B,S,H,dh) -> (B,H,S,dh)
    tr = lambda t: t.transpose(0, 2, 1, 3)
    o = chunked_decay_scan(tr(r), tr(k), tr(v), tr(w_log), u=p["u"],
                           chunk=64, diag_mode="bonus")       # (B,H,S,dh)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, d)
    o = _group_norm(o, p["gn_w"], p["gn_b"], heads=h)
    o = o * jax.nn.silu(g)
    out = jnp.einsum("bse,ed->bsd", o.astype(x.dtype), p["wo"])
    return constrain(out, "batch", "seq", "embed")


def channel_mix(cfg: ModelConfig, p, x: jax.Array) -> jax.Array:
    xx = _shift(x)
    xk = x + (xx - x) * p["mu_k"]
    xr = x + (xx - x) * p["mu_r"]
    k = jnp.einsum("bsd,df->bsf", xk, p["wk"])
    k = jnp.square(jnp.maximum(k, 0.0))
    kv = jnp.einsum("bsf,fd->bsd", k, p["wv"])
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["wr"]))
    return r * kv


def rwkv_block(cfg: ModelConfig, p, x: jax.Array) -> jax.Array:
    x = x + time_mix(cfg, p["tm"],
                     layer_norm(x, p["ln1"]["w"], p["ln1"]["b"]))
    x = x + channel_mix(cfg, p["cm"],
                        layer_norm(x, p["ln2"]["w"], p["ln2"]["b"]))
    return x


# ---------------------------------------------------------------------------
# Decode (O(1): wkv state + two shift states)
# ---------------------------------------------------------------------------


def rwkv_cache_shape(cfg: ModelConfig, batch: int):
    return {
        "wkv": (batch, cfg.num_heads, cfg.head_dim, cfg.head_dim),
        "shift_tm": (batch, 1, cfg.d_model),
        "shift_cm": (batch, 1, cfg.d_model),
    }


def rwkv_time_mix_step(cfg: ModelConfig, p, x1: jax.Array, cache: Dict
                       ) -> Tuple[jax.Array, Dict]:
    """Time-mix half of the decode step (ln1 + WKV recurrence + gate).

    Consumes ``cache['wkv']``/``cache['shift_tm']``; returns the residual
    stream after the time-mix and the updated halves of the cache.  Split
    out of :func:`rwkv_decode_step` so the layer profiler can time the
    sequence-mixing and channel-mixing operators separately.
    """
    b, _, d = x1.shape
    h, dh = cfg.num_heads, cfg.head_dim
    xn = layer_norm(x1, p["ln1"]["w"], p["ln1"]["b"])
    xx = cache["shift_tm"]
    xr, xk, xv, xw, xg = _ddlerp(p["tm"], xn, xx)
    r, k, v, g, w_log = _time_mix_core(cfg, p["tm"], xr, xk, xv, xw, xg)
    sq = lambda t: t[:, 0].reshape(b, h, dh)
    o, wkv_new = decay_scan_step(cache["wkv"], sq(r), sq(k), sq(v),
                                 sq(w_log), u=p["tm"]["u"], diag_mode="bonus")
    o = o.reshape(b, 1, d)
    o = _group_norm(o, p["tm"]["gn_w"], p["tm"]["gn_b"], heads=h)
    o = o * jax.nn.silu(g)
    x1 = x1 + jnp.einsum("bse,ed->bsd", o.astype(x1.dtype), p["tm"]["wo"])
    return x1, {"wkv": wkv_new, "shift_tm": xn}


def rwkv_channel_mix_step(cfg: ModelConfig, p, x1: jax.Array, cache: Dict
                          ) -> Tuple[jax.Array, Dict]:
    """Channel-mix half of the decode step (ln2 + gated squared-ReLU FFN)."""
    xn2 = layer_norm(x1, p["ln2"]["w"], p["ln2"]["b"])
    xxc = cache["shift_cm"]
    xk2 = xn2 + (xxc - xn2) * p["cm"]["mu_k"]
    xr2 = xn2 + (xxc - xn2) * p["cm"]["mu_r"]
    kk = jnp.square(jnp.maximum(
        jnp.einsum("bsd,df->bsf", xk2, p["cm"]["wk"]), 0.0))
    kv = jnp.einsum("bsf,fd->bsd", kk, p["cm"]["wv"])
    rr = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr2, p["cm"]["wr"]))
    return x1 + rr * kv, {"shift_cm": xn2}


def rwkv_decode_step(cfg: ModelConfig, p, x1: jax.Array, cache: Dict
                     ) -> Tuple[jax.Array, Dict]:
    x1, c_tm = rwkv_time_mix_step(cfg, p, x1, cache)
    x1, c_cm = rwkv_channel_mix_step(cfg, p, x1, cache)
    return x1, {**c_tm, **c_cm}
