"""Family-dispatched LM forward + loss for all ten architectures.

The layer stack is scanned over *groups* (heterogeneous stacks — gemma2's
local/global pair, vlm's self*4+cross, zamba2's mamba*2+shared-attn — scan
over their repeating unit) with optional remat, so the lowered HLO contains
one group body regardless of depth: essential for the 512-device dry-run.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as A
from . import mamba as M
from . import moe as MOE
from . import rwkv as R
from .common import (constrain, embed, lm_logits, norm, rope_freqs,
                     sinusoid_pos)
from .config import ModelConfig
from .mlp import mlp_block


# ---------------------------------------------------------------------------
# Layer bodies
# ---------------------------------------------------------------------------


def dense_layer(cfg: ModelConfig, p, x, rope, *, window: int = 0,
                kv_x=None, gated: bool = False):
    h = norm(cfg, p["ln1"], x)
    a = A.attn_block(cfg, p["attn"], h, rope=rope, causal=True,
                     window=window, kv_x=kv_x,
                     attn_softcap=cfg.attn_softcap)
    if gated:
        a = a * jnp.tanh(p["gate_attn"]).astype(a.dtype)
    if "ln1_post" in p:
        a = norm(cfg, p["ln1_post"], a)
    x = x + a
    h = norm(cfg, p["ln2"], x)
    m = mlp_block(cfg, p["mlp"], h)
    if gated:
        m = m * jnp.tanh(p["gate_mlp"]).astype(m.dtype)
    if "ln2_post" in p:
        m = norm(cfg, p["ln2_post"], m)
    return x + m


def moe_layer(cfg: ModelConfig, p, x, rope, aux_acc: Dict):
    h = norm(cfg, p["ln1"], x)
    x = x + A.attn_block(cfg, p["attn"], h, rope=rope, causal=True)
    h = norm(cfg, p["ln2"], x)
    y, aux = MOE.moe_block(cfg, p["moe"], h)
    for k, v in aux.items():
        aux_acc[k] = aux_acc.get(k, 0.0) + v
    return x + y


def whisper_dec_layer(cfg: ModelConfig, p, x, enc_out):
    h = norm(cfg, p["ln1"], x)
    x = x + A.attn_block(cfg, p["attn"], h, rope=None, causal=True)
    h = norm(cfg, p["ln2"], x)
    x = x + A.attn_block(cfg, p["cross"], h, rope=None, kv_x=enc_out)
    h = norm(cfg, p["ln3"], x)
    return x + mlp_block(cfg, p["mlp"], h)


def whisper_enc_layer(cfg: ModelConfig, p, x):
    h = norm(cfg, p["ln1"], x)
    x = x + A.attn_block(cfg, p["attn"], h, rope=None, causal=False)
    h = norm(cfg, p["ln2"], x)
    return x + mlp_block(cfg, p["mlp"], h)


# ---------------------------------------------------------------------------
# Group step functions (one scanned unit)
# ---------------------------------------------------------------------------


def _group_fn(cfg: ModelConfig, params, rope, modality):
    fam = cfg.family

    if fam == "dense" and cfg.local_global:
        def step(x, gp, aux):
            x = dense_layer(cfg, gp["local"], x, rope,
                            window=cfg.sliding_window)
            x = dense_layer(cfg, gp["global"], x, rope)
            return x, aux
    elif fam == "dense":
        def step(x, gp, aux):
            return dense_layer(cfg, gp["lyr"], x, rope), aux
    elif fam == "moe":
        def step(x, gp, aux):
            return moe_layer(cfg, gp["lyr"], x, rope, aux), aux
    elif fam == "vlm":
        def step(x, gp, aux):
            def self_body(carry, lp):
                return dense_layer(cfg, lp, carry, rope), None
            x, _ = jax.lax.scan(self_body, x, gp["self"])
            x = dense_layer(cfg, gp["cross"], x, rope, kv_x=modality,
                            gated=True)
            return x, aux
    elif fam == "hybrid":
        shared = params["shared_block"]

        def step(x, gp, aux):
            def mamba_body(carry, lp):
                return carry + M.mamba_block(cfg, lp, carry), None
            x, _ = jax.lax.scan(mamba_body, x, gp["mamba"])
            x = dense_layer(cfg, shared, x, rope)
            return x, aux
    elif fam == "ssm":
        def step(x, gp, aux):
            return R.rwkv_block(cfg, gp["lyr"], x), aux
    elif fam == "audio":
        def step(x, gp, aux):
            return whisper_dec_layer(cfg, gp["lyr"], x, modality), aux
    else:
        raise ValueError(fam)
    return step


def _scan_groups(cfg: ModelConfig, params, x, step):
    aux: Dict[str, Any] = {}
    if cfg.scan_layers:
        def body(carry, gp):
            xx, ax = carry
            xx, ax = step(xx, gp, ax)
            return (xx, ax), None
        if cfg.remat:
            body = jax.checkpoint(body)
        aux0 = ({"moe_aux": jnp.zeros((), jnp.float32),
                 "moe_zloss": jnp.zeros((), jnp.float32)}
                if cfg.family == "moe" else {})
        (x, aux), _ = jax.lax.scan(body, (x, aux0), params["blocks"])
    else:
        for i in range(cfg.num_groups):
            gp = jax.tree.map(lambda a: a[i], params["blocks"])
            x, aux = step(x, gp, aux)
    return x, aux


# ---------------------------------------------------------------------------
# Public forward / loss
# ---------------------------------------------------------------------------


def encode_audio(cfg: ModelConfig, params, frames: jax.Array) -> jax.Array:
    """Whisper encoder over stub frame embeddings (B, enc_seq, D)."""
    x = frames + sinusoid_pos(frames.shape[1], cfg.d_model).astype(frames.dtype)

    def body(carry, lp):
        return whisper_enc_layer(cfg, lp, carry), None
    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["encoder"])
    return norm(cfg, params["enc_norm"], x)


def forward_hidden(cfg: ModelConfig, params, tokens: jax.Array,
                   modality: Optional[jax.Array] = None
                   ) -> Tuple[jax.Array, Dict]:
    """tokens: (B, S) -> final-norm hidden states (B, S, D) + aux."""
    b, s = tokens.shape
    x = embed(cfg, params, tokens)
    rope = None
    if cfg.rope_theta:
        rope = rope_freqs(cfg.head_dim, cfg.rope_theta, jnp.arange(s))
    if cfg.family == "audio":
        assert modality is not None, "whisper needs frame embeddings"
        modality = encode_audio(cfg, params, modality)
        x = x + sinusoid_pos(s, cfg.d_model).astype(x.dtype)
    if cfg.family == "vlm":
        assert modality is not None, "vlm needs patch embeddings"
    step = _group_fn(cfg, params, rope, modality)
    x, aux = _scan_groups(cfg, params, x, step)
    return norm(cfg, params["final_norm"], x), aux


def forward(cfg: ModelConfig, params, tokens: jax.Array,
            modality: Optional[jax.Array] = None
            ) -> Tuple[jax.Array, Dict]:
    """Full-logit forward (prefill/serving path)."""
    x, aux = forward_hidden(cfg, params, tokens, modality=modality)
    return lm_logits(cfg, params, x), aux


def chunked_ce(cfg: ModelConfig, params, x: jax.Array, tokens: jax.Array,
               mask: Optional[jax.Array] = None, chunk: int = 512
               ) -> jax.Array:
    """Cross entropy without materializing (B, S, V) logits (perf iter 2).

    Scans sequence chunks; each chunk computes its own logits/log-softmax
    and is rematerialized in the backward pass, so the live logit buffer is
    (B, chunk, V) instead of (B, S, V)."""
    b, s, d = x.shape
    tgt = jnp.concatenate([tokens[:, 1:], jnp.zeros((b, 1), tokens.dtype)],
                          axis=1)
    valid = jnp.ones((b, s), jnp.float32).at[:, -1].set(0.0)
    if mask is not None:
        valid = valid * mask.astype(jnp.float32)
    c = min(chunk, s)
    n = s // c if s % c == 0 else 1
    c = s // n
    xs = x.reshape(b, n, c, d).transpose(1, 0, 2, 3)
    ts = tgt.reshape(b, n, c).transpose(1, 0, 2)
    vs = valid.reshape(b, n, c).transpose(1, 0, 2)

    def body(tot, xtv):
        xc, tc, vc = xtv
        logits = lm_logits(cfg, params, xc)      # (B, c, V)
        lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(lp, tc[..., None], axis=-1)[..., 0]
        return tot + (nll * vc).sum(), None

    total, _ = jax.lax.scan(jax.checkpoint(body),
                            jnp.zeros((), jnp.float32), (xs, ts, vs))
    return total / jnp.maximum(valid.sum(), 1.0)


def loss_fn(cfg: ModelConfig, params, batch: Dict) -> Tuple[jax.Array, Dict]:
    """Next-token cross entropy (+ MoE aux losses), chunked over sequence."""
    tokens = batch["tokens"]
    x, aux = forward_hidden(cfg, params, tokens,
                            modality=batch.get("modality"))
    loss = chunked_ce(cfg, params, x, tokens, mask=batch.get("mask"))
    metrics = {"ce_loss": loss}
    if aux:
        n = cfg.num_groups
        metrics["moe_aux"] = aux["moe_aux"] / n
        metrics["moe_zloss"] = aux["moe_zloss"] / n
        loss = loss + 0.01 * metrics["moe_aux"] + 1e-3 * metrics["moe_zloss"]
    metrics["loss"] = loss
    return loss, metrics
