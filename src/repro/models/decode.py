"""Decode path: cache construction + single-token serve step, all families.

``decode_*`` shapes lower THIS path (one new token against a static
seq_len-sized cache), not the training step.  Caches are stacked over scan
groups so the decode HLO also contains a single group body.

Cache layouts:
  dense/moe : {'k','v'} (G, [layers-per-group,] B, Hkv, L, dh), pos scalar
  vlm       : self caches + precomputed vision cross K/V
  hybrid    : mamba states (O(1)) + shared-attn KV cache
  ssm       : wkv state + shift states (O(1))
  audio     : decoder self cache + precomputed encoder cross K/V
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as A
from . import mamba as M
from . import moe as MOE
from . import rwkv as R
from .common import embed, lm_logits, norm, rope_freqs, sinusoid_pos
from .config import ModelConfig
from .mlp import mlp_block
from .params import param_specs
from .transformer import encode_audio


def _kv_shape(cfg: ModelConfig, batch: int, max_len: int):
    return (batch, cfg.num_kv_heads, max_len, cfg.head_dim)


def _kv_entry(cfg: ModelConfig, batch: int, max_len: int):
    """Self-attention cache entry; int8 mode adds per-token scales."""
    kv = _kv_shape(cfg, batch, max_len)
    entry = {"k": kv, "v": kv}
    if cfg.kv_cache_dtype == "int8":
        entry["k_scale"] = (batch, cfg.num_kv_heads, max_len, 1)
        entry["v_scale"] = (batch, cfg.num_kv_heads, max_len, 1)
    return entry


def _zeros(shape, dtype):
    return jnp.zeros(shape, dtype)


def _stack_shapes(n: int, tree):
    return jax.tree.map(lambda s: (n,) + s if isinstance(s, tuple) else s,
                        tree, is_leaf=lambda x: isinstance(x, tuple))


# ---------------------------------------------------------------------------
# Cache spec (shapes only — used by the dry-run) and init
# ---------------------------------------------------------------------------


def cache_shapes(cfg: ModelConfig, batch: int, max_len: int) -> Any:
    g = cfg.num_groups
    kv = _kv_shape(cfg, batch, max_len)
    fam = cfg.family
    if fam == "dense" and cfg.local_global:
        local_len = min(max_len, cfg.sliding_window)
        per = {"local": _kv_entry(cfg, batch, local_len),
               "global": _kv_entry(cfg, batch, max_len)}
    elif fam in ("dense", "moe"):
        per = {"lyr": _kv_entry(cfg, batch, max_len)}
    elif fam == "vlm":
        n_self = cfg.cross_attn_every - 1
        cross_kv = (batch, cfg.num_kv_heads, cfg.num_patches, cfg.head_dim)
        per = {"self": _stack_shapes(n_self, _kv_entry(cfg, batch, max_len)),
               "cross": {"k": cross_kv, "v": cross_kv}}
    elif fam == "hybrid":
        n_mamba = cfg.hybrid_attn_every - 1
        per = {"mamba": _stack_shapes(n_mamba, M.mamba_cache_shape(cfg, batch)),
               "attn": _kv_entry(cfg, batch, max_len)}
    elif fam == "ssm":
        per = {"lyr": R.rwkv_cache_shape(cfg, batch)}
    elif fam == "audio":
        enc_kv = (batch, cfg.num_kv_heads, cfg.encoder_seq, cfg.head_dim)
        per = {"lyr": {"self": _kv_entry(cfg, batch, max_len),
                       "cross": {"k": enc_kv, "v": enc_kv}}}
    else:
        raise ValueError(fam)
    return _stack_shapes(g, per)


def _cache_leaf_dtype(cfg: ModelConfig, path_key: str, shape, parent):
    """int8 only for self-attn k/v whose sibling scale entry exists
    (cross caches are read raw by _cross_decode and stay full precision)."""
    if cfg.kv_cache_dtype == "int8":
        if path_key in ("k", "v") and f"{path_key}_scale" in parent:
            return jnp.dtype(jnp.int8)
        if path_key.endswith("_scale"):
            return jnp.dtype(jnp.float32)
    return jnp.dtype(cfg.dtype)


def _map_cache(cfg: ModelConfig, tree, fn):
    """Map over cache leaves with their dict-key names + parent dict."""
    def walk(t, key="", parent=None):
        if isinstance(t, tuple):
            return fn(key, t, parent or {})
        return {k: walk(v, k, t) for k, v in t.items()}
    return walk(tree)


def cache_specs(cfg: ModelConfig, batch: int, max_len: int):
    return _map_cache(
        cfg, cache_shapes(cfg, batch, max_len),
        lambda key, s, par: jax.ShapeDtypeStruct(
            s, _cache_leaf_dtype(cfg, key, s, par)))


def init_cache(cfg: ModelConfig, params, batch: int, max_len: int,
               modality: Optional[jax.Array] = None):
    """Materialize an empty cache; precompute cross K/V where applicable."""
    dt = jnp.dtype(cfg.dtype)
    cache = _map_cache(
        cfg, cache_shapes(cfg, batch, max_len),
        lambda key, s, par: _zeros(s, _cache_leaf_dtype(cfg, key, s, par)))
    if cfg.family == "vlm" and modality is not None:
        def fill(gp, c):
            _, kx, vx = A.qkv_proj(cfg, gp["cross"]["attn"], modality,
                                   kv_x=modality)
            c = dict(c)
            c["cross"] = {"k": kx.astype(dt), "v": vx.astype(dt)}
            return c
        groups = [fill(jax.tree.map(lambda a: a[i], params["blocks"]),
                       jax.tree.map(lambda a: a[i], cache))
                  for i in range(cfg.num_groups)]
        cache = jax.tree.map(lambda *xs: jnp.stack(xs), *groups)
    if cfg.family == "audio" and modality is not None:
        enc = encode_audio(cfg, params, modality)
        def fill(gp, c):
            _, kx, vx = A.qkv_proj(cfg, gp["lyr"]["cross"], enc, kv_x=enc)
            c = dict(c)
            c["lyr"] = dict(c["lyr"])
            c["lyr"]["cross"] = {"k": kx.astype(dt), "v": vx.astype(dt)}
            return c
        groups = [fill(jax.tree.map(lambda a: a[i], params["blocks"]),
                       jax.tree.map(lambda a: a[i], cache))
                  for i in range(cfg.num_groups)]
        cache = jax.tree.map(lambda *xs: jnp.stack(xs), *groups)
    return cache


# ---------------------------------------------------------------------------
# Cross-attention against a precomputed cache (no causal mask)
# ---------------------------------------------------------------------------


def _cross_decode(cfg: ModelConfig, p, x1, kc, vc):
    b = x1.shape[0]
    h, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    g = h // hkv
    q = jnp.einsum("bsd,de->bse", x1, p["wq"])
    if cfg.qkv_bias:
        q = q + p["bq"]
    q = q.reshape(b, 1, h, dh).transpose(0, 2, 1, 3)
    qg = q.reshape(b, hkv, g, 1, dh)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(jnp.float32),
                   kc.astype(jnp.float32)) / (dh ** 0.5)
    pgs = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", pgs, vc.astype(jnp.float32))
    out = out.reshape(b, h, 1, dh).transpose(0, 2, 1, 3).reshape(b, 1, -1)
    return jnp.einsum("bse,ed->bsd", out.astype(x1.dtype), p["wo"])


# ---------------------------------------------------------------------------
# Per-family group decode steps
# ---------------------------------------------------------------------------


def _dense_decode(cfg, p, x1, c, pos, window=0, ring=False):
    h = norm(cfg, p["ln1"], x1)
    a, c_new = A.attn_decode(cfg, p["attn"], h, c, pos, window=window,
                             attn_softcap=cfg.attn_softcap, ring=ring)
    if "ln1_post" in p:
        a = norm(cfg, p["ln1_post"], a)
    x1 = x1 + a
    h = norm(cfg, p["ln2"], x1)
    m = mlp_block(cfg, p["mlp"], h)
    if "ln2_post" in p:
        m = norm(cfg, p["ln2_post"], m)
    return x1 + m, c_new


def _group_decode(cfg: ModelConfig, params, pos):
    fam = cfg.family

    if fam == "dense" and cfg.local_global:
        def step(x1, gp, gc):
            x1, cl = _dense_decode(cfg, gp["local"], x1, gc["local"], pos,
                                   window=cfg.sliding_window, ring=True)
            x1, cg = _dense_decode(cfg, gp["global"], x1, gc["global"], pos)
            return x1, {"local": cl, "global": cg}
    elif fam == "dense":
        def step(x1, gp, gc):
            x1, c = _dense_decode(cfg, gp["lyr"], x1, gc["lyr"], pos)
            return x1, {"lyr": c}
    elif fam == "moe":
        def step(x1, gp, gc):
            p = gp["lyr"]
            h = norm(cfg, p["ln1"], x1)
            a, c = A.attn_decode(cfg, p["attn"], h, gc["lyr"], pos)
            x1 = x1 + a
            h = norm(cfg, p["ln2"], x1)
            y, _ = MOE.moe_block(cfg, p["moe"], h)
            return x1 + y, {"lyr": c}
    elif fam == "vlm":
        def step(x1, gp, gc):
            def body(xx, lpc):
                lp, lc = lpc
                return _dense_decode(cfg, lp, xx, lc, pos)
            x1_, self_new = jax.lax.scan(body, x1, (gp["self"], gc["self"]))
            p = gp["cross"]
            h = norm(cfg, p["ln1"], x1_)
            a = _cross_decode(cfg, p["attn"], h, gc["cross"]["k"],
                              gc["cross"]["v"])
            x1_ = x1_ + a * jnp.tanh(p["gate_attn"]).astype(a.dtype)
            h = norm(cfg, p["ln2"], x1_)
            m = mlp_block(cfg, p["mlp"], h)
            x1_ = x1_ + m * jnp.tanh(p["gate_mlp"]).astype(m.dtype)
            return x1_, {"self": self_new, "cross": gc["cross"]}
    elif fam == "hybrid":
        shared = params["shared_block"]

        def step(x1, gp, gc):
            def body(xx, lpc):
                lp, lc = lpc
                delta, lc_new = M.mamba_decode_step(cfg, lp, xx, lc)
                return xx + delta, lc_new
            x1_, mamba_new = jax.lax.scan(body, x1,
                                          (gp["mamba"], gc["mamba"]))
            x1_, attn_new = _dense_decode(cfg, shared, x1_, gc["attn"], pos)
            return x1_, {"mamba": mamba_new, "attn": attn_new}
    elif fam == "ssm":
        def step(x1, gp, gc):
            x1, c = R.rwkv_decode_step(cfg, gp["lyr"], x1, gc["lyr"])
            return x1, {"lyr": c}
    elif fam == "audio":
        def step(x1, gp, gc):
            p = gp["lyr"]
            h = norm(cfg, p["ln1"], x1)
            a, c_self = A.attn_decode(cfg, p["attn"], h, gc["lyr"]["self"],
                                      pos)
            x1 = x1 + a
            h = norm(cfg, p["ln2"], x1)
            x1 = x1 + _cross_decode(cfg, p["cross"], h,
                                    gc["lyr"]["cross"]["k"],
                                    gc["lyr"]["cross"]["v"])
            h = norm(cfg, p["ln3"], x1)
            x1 = x1 + mlp_block(cfg, p["mlp"], h)
            return x1, {"lyr": {"self": c_self, "cross": gc["lyr"]["cross"]}}
    else:
        raise ValueError(fam)
    return step


def serve_step(cfg: ModelConfig, params, cache, tokens: jax.Array, pos
               ) -> Tuple[jax.Array, Any]:
    """tokens: (B, 1) int32; pos: scalar int32 (next write position).

    Returns (logits (B, 1, V), updated cache).
    """
    x1 = embed(cfg, params, tokens)
    if cfg.family == "audio":
        table = sinusoid_pos(cache_max_len(cfg, cache), cfg.d_model)
        pe = jax.lax.dynamic_slice_in_dim(table, pos, 1)
        x1 = x1 + pe[None].astype(x1.dtype)
    step = _group_decode(cfg, params, pos)

    def body(carry, gpc):
        gp, gc = gpc
        xx = carry
        xx, gc_new = step(xx, gp, gc)
        return xx, gc_new

    if cfg.scan_layers:
        x1, new_cache = jax.lax.scan(body, x1, (params["blocks"], cache))
    else:
        new_groups = []
        for i in range(cfg.num_groups):
            gp = jax.tree.map(lambda a: a[i], params["blocks"])
            gc = jax.tree.map(lambda a: a[i], cache)
            x1, gc_new = step(x1, gp, gc)
            new_groups.append(gc_new)
        new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *new_groups)
    x1 = norm(cfg, params["final_norm"], x1)
    return lm_logits(cfg, params, x1), new_cache


def cache_max_len(cfg: ModelConfig, cache) -> int:
    """Decoder self-attention cache length (the position-table size)."""
    if cfg.family == "audio":
        return cache["lyr"]["self"]["k"].shape[-2]
    leaves = jax.tree.leaves(cache)
    return max((l.shape[-2] for l in leaves if l.ndim >= 4), default=1)


# ---------------------------------------------------------------------------
# Serving plumbing: shared jitted step + per-step stats for observability
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def make_serve_step(cfg: ModelConfig):
    """Jitted :func:`serve_step` closed over ``cfg``, cached per config.

    Every engine/driver built on the same config shares one compilation —
    a fresh ``jax.jit(lambda ...)`` per caller would retrace on each
    instantiation, which both wastes compile time and poisons wall-clock
    comparisons between instrumented and uninstrumented runs of the same
    workload (the serve benchmark measures exactly that differential).
    """
    return jax.jit(functools.partial(serve_step, cfg))


def cache_num_bytes(cache) -> int:
    """Total bytes held by the cache leaves (the serving-memory gauge)."""
    return sum(leaf.size * leaf.dtype.itemsize
               for leaf in jax.tree.leaves(cache))


def step_stats(cfg: ModelConfig, cache) -> Dict[str, int]:
    """Static per-step facts the serving metrics export as gauges: cache
    footprint/length and the approximate FLOPs one decoded token costs
    (2 x active parameters — the standard decode estimate)."""
    from .params import count_params
    return {
        "cache_bytes": cache_num_bytes(cache),
        "cache_max_len": cache_max_len(cfg, cache),
        "approx_flops_per_token": 2 * count_params(cfg, active_only=True),
    }


# ---------------------------------------------------------------------------
# Per-slot cache surgery (quarantine + fault injection — ``repro.launch``)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def cache_batch_axes(cfg: ModelConfig):
    """Tree (matching the fused cache structure) giving each cache leaf's
    batch-axis index, discovered by diffing shape templates at two batch
    sizes — the one differing dim per leaf is the batch axis.  Robust to
    family layout (dense KV at axis 1 behind the group axis, vlm/hybrid
    inner layer stacking at axis 1 pushing batch to 2, ssm state tensors
    with no length dim) without per-family switch statements."""
    s2, s3 = cache_shapes(cfg, 2, 8), cache_shapes(cfg, 3, 8)

    def ax(a, b):
        diff = [i for i, (x, y) in enumerate(zip(a, b)) if x != y]
        if len(diff) != 1:
            raise ValueError(f"ambiguous batch axis for cache leaf {a}")
        return diff[0]

    return jax.tree.map(ax, s2, s3, is_leaf=lambda x: isinstance(x, tuple))


def _map_slot(cfg: ModelConfig, cache, fn):
    """Apply ``fn(leaf, batch_axis)`` across a fused cache tree or the
    per-group list form (``ProfiledServeStep``), where the sliced-off
    group axis shifts every batch axis down by one."""
    axes = cache_batch_axes(cfg)
    if isinstance(cache, list):
        return [jax.tree.map(lambda leaf, ax: fn(leaf, ax - 1), g, axes)
                for g in cache]
    return jax.tree.map(fn, cache, axes)


def reset_cache_slot(cfg: ModelConfig, cache, slot: int):
    """Zero one batch slot across every cache leaf (slot quarantine: the
    replacement request re-prefills from position 0, so stale or corrupted
    state must not survive).  Returns the updated cache."""
    def zero(leaf, ax):
        idx = (slice(None),) * ax + (slot,)
        return leaf.at[idx].set(jnp.zeros((), leaf.dtype))
    return _map_slot(cfg, cache, zero)


def corrupt_cache_slot(cfg: ModelConfig, cache, slot: int):
    """Silently poison one batch slot: NaN into every floating cache leaf
    (int8 KV payloads cannot hold NaN — their float32 scale leaves carry
    the poison instead, which contaminates the dequantized values the same
    way).  Fault-injection only; returns the updated cache."""
    def poison(leaf, ax):
        if not jnp.issubdtype(leaf.dtype, jnp.floating):
            return leaf
        idx = (slice(None),) * ax + (slot,)
        return leaf.at[idx].set(jnp.nan)
    return _map_slot(cfg, cache, poison)


# ---------------------------------------------------------------------------
# Per-operator sliced serve step (layer profiling — ``repro.obs.modelprof``)
# ---------------------------------------------------------------------------

# families with a sliced-segment decomposition; vlm/audio decode steps fold
# modality cross-attention into the group scan and are not sliced yet
PROFILED_FAMILIES = ("dense", "moe", "ssm", "hybrid")


def profile_ops(cfg: ModelConfig) -> Tuple[Tuple[str, int], ...]:
    """Ordered ``(op, group)`` decomposition of one serve_step.

    ``group`` is the scan-group index (``-1`` for the embed/head segments
    outside the block stack).  This is the canonical op list the layer
    profiler, its validator, and the analytic cost model all share — one
    record per entry per engine step.
    """
    if cfg.family not in PROFILED_FAMILIES:
        raise NotImplementedError(
            f"layer profiling not implemented for family {cfg.family!r} "
            f"(supported: {PROFILED_FAMILIES})")
    ops = [("embed", -1)]
    for g in range(cfg.num_groups):
        if cfg.family == "dense" and cfg.local_global:
            ops += [("attn_local", g), ("mlp_local", g),
                    ("attn_global", g), ("mlp_global", g)]
        elif cfg.family == "dense":
            ops += [("attn", g), ("mlp", g)]
        elif cfg.family == "moe":
            ops += [("attn", g), ("moe", g)]
        elif cfg.family == "ssm":
            ops += [("time_mix", g), ("channel_mix", g)]
        else:  # hybrid
            ops += [("scan", g), ("attn", g), ("mlp", g)]
    ops.append(("head", -1))
    return tuple(ops)


class ProfiledServeStep:
    """One decode step as a sequence of independently jitted segments
    (embed / per-group operators / head), each synced with
    ``jax.block_until_ready`` and wall-stamped.

    This is a distinct *execution mode* of the identical math as
    :func:`serve_step` (logits/cache agree with the fused step — asserted
    by tests): slicing the step loses XLA's cross-operator fusion and pays
    one dispatch+sync per segment, so a profiled engine is slower than a
    fused one by a measured, reported factor (``slice_overhead`` in
    BENCH_model.json).  The <5% observability contract covers the
    *recording* layer on top of this mode (see ``obs.modelprof``), exactly
    as PR 8's contract covered the span hooks on top of the engine's
    inherent per-step sync.

    The cache travels as a **list of per-group subtrees** (no per-step
    slice/stack device work — group slicing of the parameters happens once
    per params object and is memoized).  ``init_cache``/``stack_cache``
    convert to and from the fused layout.
    """

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.ops = profile_ops(cfg)
        self._gps = None
        self._params_id = None
        self._aux = None            # head/embed/shared params, sliced once
        self._segs = self._build_segments(cfg)

    # -- cache layout --------------------------------------------------------

    @staticmethod
    def init_cache(cfg: ModelConfig, params, batch: int, max_len: int):
        """Family cache in per-group list form."""
        c = init_cache(cfg, params, batch, max_len)
        return [jax.tree.map(lambda a: a[g], c)
                for g in range(cfg.num_groups)]

    @staticmethod
    def stack_cache(groups):
        """Per-group list form back to the fused (stacked) layout."""
        return jax.tree.map(lambda *xs: jnp.stack(xs), *groups)

    # -- segment builders ----------------------------------------------------

    def _build_segments(self, cfg: ModelConfig):
        fam = cfg.family
        segs: Dict[str, Any] = {}

        def embed_seg(emb, tokens):
            return embed(cfg, {"embed": emb}, tokens)

        def head_seg(final_norm, head_w, x1):
            x1 = norm(cfg, final_norm, x1)
            params = {"embed" if cfg.tie_embeddings else "lm_head": head_w}
            return lm_logits(cfg, params, x1)

        segs["embed"] = jax.jit(embed_seg)
        segs["head"] = jax.jit(head_seg)

        def dense_attn(p, x1, c, pos, window=0, ring=False):
            h = norm(cfg, p["ln1"], x1)
            a, c_new = A.attn_decode(cfg, p["attn"], h, c, pos,
                                     window=window,
                                     attn_softcap=cfg.attn_softcap,
                                     ring=ring)
            if "ln1_post" in p:
                a = norm(cfg, p["ln1_post"], a)
            return x1 + a, c_new

        def dense_mlp(p, x1):
            h = norm(cfg, p["ln2"], x1)
            m = mlp_block(cfg, p["mlp"], h)
            if "ln2_post" in p:
                m = norm(cfg, p["ln2_post"], m)
            return x1 + m

        if fam == "dense" and cfg.local_global:
            segs["attn_local"] = jax.jit(functools.partial(
                dense_attn, window=cfg.sliding_window, ring=True))
            segs["mlp_local"] = jax.jit(dense_mlp)
            segs["attn_global"] = jax.jit(dense_attn)
            segs["mlp_global"] = jax.jit(dense_mlp)
        elif fam == "dense":
            segs["attn"] = jax.jit(dense_attn)
            segs["mlp"] = jax.jit(dense_mlp)
        elif fam == "moe":
            def moe_attn(p, x1, c, pos):
                h = norm(cfg, p["ln1"], x1)
                a, c_new = A.attn_decode(cfg, p["attn"], h, c, pos)
                return x1 + a, c_new

            def moe_ffn(p, x1):
                h = norm(cfg, p["ln2"], x1)
                y, _ = MOE.moe_block(cfg, p["moe"], h)
                return x1 + y

            segs["attn"] = jax.jit(moe_attn)
            segs["moe"] = jax.jit(moe_ffn)
        elif fam == "ssm":
            segs["time_mix"] = jax.jit(
                functools.partial(R.rwkv_time_mix_step, cfg))
            segs["channel_mix"] = jax.jit(
                functools.partial(R.rwkv_channel_mix_step, cfg))
        else:  # hybrid
            def mamba_scan(lps, x1, lcs):
                def body(xx, lpc):
                    lp, lc = lpc
                    delta, lc_new = M.mamba_decode_step(cfg, lp, xx, lc)
                    return xx + delta, lc_new
                return jax.lax.scan(body, x1, (lps, lcs))

            segs["scan"] = jax.jit(mamba_scan)
            segs["attn"] = jax.jit(dense_attn)
            segs["mlp"] = jax.jit(dense_mlp)
        return segs

    # -- params slicing (once per params object) -----------------------------

    def _sliced(self, params):
        if self._params_id != id(params):
            gps = [jax.tree.map(lambda a: a[g], params["blocks"])
                   for g in range(self.cfg.num_groups)]
            head_w = params["embed"] if self.cfg.tie_embeddings \
                else params["lm_head"]
            aux = {"embed": params["embed"], "head_w": head_w,
                   "final_norm": params["final_norm"]}
            if self.cfg.family == "hybrid":
                aux["shared"] = params["shared_block"]
            jax.block_until_ready(gps)
            self._gps, self._aux, self._params_id = gps, aux, id(params)
        return self._gps, self._aux

    # -- one profiled step ---------------------------------------------------

    def __call__(self, params, cache_groups, tokens, pos
                 ) -> Tuple[jax.Array, list, list]:
        """Returns ``(logits, new_cache_groups, walls)`` where ``walls``
        aligns with :func:`profile_ops` — one post-sync wall-clock
        microsecond figure per segment."""
        import time as _time
        cfg = self.cfg
        gps, aux = self._sliced(params)
        segs = self._segs
        walls: list = []

        def timed(fn, *args):
            t0 = _time.perf_counter()
            out = fn(*args)
            jax.block_until_ready(out)
            walls.append((_time.perf_counter() - t0) * 1e6)
            return out

        x1 = timed(segs["embed"], aux["embed"], tokens)
        new_groups = []
        for g in range(cfg.num_groups):
            gp, gc = gps[g], cache_groups[g]
            if cfg.family == "dense" and cfg.local_global:
                (x1, cl) = timed(segs["attn_local"], gp["local"], x1,
                                 gc["local"], pos)
                x1 = timed(segs["mlp_local"], gp["local"], x1)
                (x1, cgl) = timed(segs["attn_global"], gp["global"], x1,
                                  gc["global"], pos)
                x1 = timed(segs["mlp_global"], gp["global"], x1)
                new_groups.append({"local": cl, "global": cgl})
            elif cfg.family in ("dense", "moe"):
                (x1, c_new) = timed(segs["attn"], gp["lyr"], x1,
                                    gc["lyr"], pos)
                x1 = timed(segs["mlp" if cfg.family == "dense" else "moe"],
                           gp["lyr"], x1)
                new_groups.append({"lyr": c_new})
            elif cfg.family == "ssm":
                (x1, c_tm) = timed(segs["time_mix"], gp["lyr"], x1,
                                   gc["lyr"])
                (x1, c_cm) = timed(segs["channel_mix"], gp["lyr"], x1,
                                   gc["lyr"])
                new_groups.append({"lyr": {**c_tm, **c_cm}})
            else:  # hybrid
                (x1, mamba_new) = timed(segs["scan"], gp["mamba"], x1,
                                        gc["mamba"])
                (x1, attn_new) = timed(segs["attn"], aux["shared"], x1,
                                       gc["attn"], pos)
                x1 = timed(segs["mlp"], aux["shared"], x1)
                new_groups.append({"mamba": mamba_new, "attn": attn_new})
        logits = timed(segs["head"], aux["final_norm"], aux["head_w"], x1)
        return logits, new_groups, walls


@functools.lru_cache(maxsize=None)
def make_profiled_serve_step(cfg: ModelConfig) -> ProfiledServeStep:
    """Per-config cached :class:`ProfiledServeStep` (same sharing contract
    as :func:`make_serve_step` — every profiled engine/driver on one config
    shares one set of compiled segments)."""
    return ProfiledServeStep(cfg)
