"""Mixture-of-Experts with the paper's banking discipline applied to experts.

``banked`` dispatch (default — the layout-embedded scheme): experts are
memory banks.  Tokens are moved into a static expert-leading capacity
buffer (E, C, D) — row-wise data movement of O(T*k*D) — and all compute is
dense einsums over the expert dimension, which shards over the model axis
exactly like banks: each device owns E/ep experts selected by the
PartitionSpec (a compile-time index), never a runtime branch.

``gather`` dispatch (the "branchy" analogue, for the ablation): per-token
expert-WEIGHT gathers — O(T*D*F) data movement with data-dependent
indexing, mirroring the cost explosion of the paper's conditional
bank-select chains (moving the bank to the request instead of the request
to the bank).
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .common import activation, constrain
from . import common as _common
from .config import ModelConfig
from .params import gated_mlp


def _router(cfg: ModelConfig, p, x2: jax.Array):
    """x2: (T, D) -> (probs (T,k), idx (T,k), aux metrics)."""
    logits = jnp.einsum("td,de->te", x2.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, cfg.experts_per_token)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    e = cfg.num_experts
    me = jnp.mean(jax.nn.one_hot(top_i, e).sum(1), axis=0)      # load/expert
    pe = probs.mean(axis=0)
    aux = e * jnp.sum(me / cfg.experts_per_token * pe)
    zloss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return top_p, top_i, {"moe_aux": aux, "moe_zloss": zloss}


def capacity(cfg: ModelConfig, tokens: int) -> int:
    c = int(math.ceil(tokens * cfg.experts_per_token
                      * cfg.moe_capacity_factor / cfg.num_experts))
    return max(8, -(-c // 8) * 8)   # pad to lane multiple


def _expert_ffn(cfg: ModelConfig, p, xe: jax.Array) -> jax.Array:
    """xe: (E, C, D) -> (E, C, D): dense over the leading expert 'banks'."""
    xe = constrain(xe, "experts", "capacity", None)
    h = jnp.einsum("ecd,edf->ecf", xe, p["w1"])
    if gated_mlp(cfg):
        g = jnp.einsum("ecd,edf->ecf", xe, p["wg"])
        h = activation(cfg, g) * h
    else:
        h = activation(cfg, h)
    h = constrain(h, "experts", "capacity", None)
    out = jnp.einsum("ecf,efd->ecd", h, p["w2"])
    return constrain(out, "experts", "capacity", None)


def moe_block_banked(cfg: ModelConfig, p, x: jax.Array
                     ) -> Tuple[jax.Array, Dict]:
    """x: (B, S, D).  Static-capacity dispatch: scatter rows into the
    expert-leading buffer, dense expert FFN, gather back."""
    b, s, d = x.shape
    t = b * s
    e, k = cfg.num_experts, cfg.experts_per_token
    cap = capacity(cfg, t)
    x2 = x.reshape(t, d)
    top_p, top_i, aux = _router(cfg, p, x2)

    # flat (T*k,) assignment stream, token-major; position inside each
    # expert's capacity buffer = number of earlier assignments to it.
    eid = top_i.reshape(t * k)
    gate = top_p.reshape(t * k)
    oh = jax.nn.one_hot(eid, e, dtype=jnp.int32)                # (T*k, E)
    pos = (jnp.cumsum(oh, axis=0) - oh)                         # exclusive
    pos = jnp.take_along_axis(pos, eid[:, None], axis=1)[:, 0]  # (T*k,)
    keep = pos < cap
    pos_c = jnp.minimum(pos, cap - 1)

    # perf iteration 4: expert-leading (E, cap, D) buffer with an explicit
    # expert sharding — the scatter target lives on the expert's owner
    # device (bank = device), never replicated.  Dropped tokens scatter
    # zeros onto the last slot (add-safe).
    x_rep = jnp.repeat(x2, k, axis=0)                           # (T*k, D)
    upd = x_rep * keep[:, None].astype(x.dtype)
    buf = constrain(jnp.zeros((e, cap, d), x.dtype),
                    "experts", "capacity", None)
    buf = buf.at[eid, pos_c].add(upd)
    buf = constrain(buf, "experts", "capacity", None)
    ye = _expert_ffn(cfg, p, buf)
    y_rows = ye[eid, pos_c]                                     # (T*k, D)
    y_rows = (y_rows.astype(jnp.float32)
              * (gate * keep.astype(jnp.float32))[:, None])
    y2 = y_rows.reshape(t, k, d).sum(axis=1)
    return y2.astype(x.dtype).reshape(b, s, d), aux


def moe_block_gather(cfg: ModelConfig, p, x: jax.Array
                     ) -> Tuple[jax.Array, Dict]:
    """Ablation path: per-token expert-weight gathers (the 'branchy'
    analogue).  Only sane at small scale — benchmarks contrast its HLO
    (dynamic-gather of O(T*D*F) weights) against the banked path."""
    b, s, d = x.shape
    t = b * s
    x2 = x.reshape(t, d)
    top_p, top_i, aux = _router(cfg, p, x2)
    y2 = jnp.zeros((t, d), jnp.float32)
    for slot in range(cfg.experts_per_token):
        idx = top_i[:, slot]                       # (T,) dynamic
        w1 = p["w1"][idx]                          # (T, D, F) gather!
        w2 = p["w2"][idx]
        h = jnp.einsum("td,tdf->tf", x2, w1)
        if gated_mlp(cfg):
            wg = p["wg"][idx]
            h = activation(cfg, jnp.einsum("td,tdf->tf", x2, wg)) * h
        else:
            h = activation(cfg, h)
        y = jnp.einsum("tf,tfd->td", h, w2)
        y2 = y2 + top_p[:, slot, None] * y.astype(jnp.float32)
    return y2.astype(x.dtype).reshape(b, s, d), aux


def _ep_context():
    """(mesh, model_axis, batch_axes, tp_size) when EP is available."""
    mesh = _common._MESH
    if mesh is None:
        return None
    rules = _common._RULES
    model_axis = rules.get("experts")
    batch_axes = rules.get("batch")
    if not isinstance(model_axis, str):
        return None
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    tp = sizes.get(model_axis, 1)
    if tp <= 1:
        return None
    return mesh, model_axis, batch_axes, tp


def moe_block_banked_ep(cfg: ModelConfig, p, x: jax.Array, mesh, model_axis,
                        batch_axes, tp: int) -> Tuple[jax.Array, Dict]:
    """Expert-parallel dispatch via shard_map (perf iteration 5).

    Tokens are replicated across the model axis after batch sharding, so
    each expert owner selects the rows bound for ITS experts locally —
    the dispatch itself moves no bytes; one psum over the model axis
    combines expert outputs.  The device index is the bank index: the
    paper's layout-embedded banking at mesh scale, now with explicitly
    scheduled communication."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    e, k = cfg.num_experts, cfg.experts_per_token
    e_loc = e // tp
    gated = gated_mlp(cfg)

    def local_fn(xl, router, w1, w2, wg):
        bl, s, d = xl.shape
        tl = bl * s
        x2 = xl.reshape(tl, d)
        top_p, top_i, aux = _router(cfg, {"router": router}, x2)
        cap = capacity(cfg, tl)                       # local capacity
        eid = top_i.reshape(tl * k)
        gate = top_p.reshape(tl * k)
        oh = jax.nn.one_hot(eid, e, dtype=jnp.int32)
        pos = jnp.cumsum(oh, axis=0) - oh
        pos = jnp.take_along_axis(pos, eid[:, None], axis=1)[:, 0]
        keep = pos < cap
        pos_c = jnp.minimum(pos, cap - 1)

        m = jax.lax.axis_index(model_axis)
        mine = (eid >= m * e_loc) & (eid < (m + 1) * e_loc) & keep
        loc_e = jnp.where(mine, eid - m * e_loc, 0)
        x_rep = jnp.repeat(x2, k, axis=0)
        upd = x_rep * mine[:, None].astype(x.dtype)
        buf = jnp.zeros((e_loc, cap, d), x.dtype).at[loc_e, pos_c].add(upd)

        h = jnp.einsum("ecd,edf->ecf", buf, w1)
        if gated:
            g = jnp.einsum("ecd,edf->ecf", buf, wg)
            h = activation(cfg, g) * h
        else:
            h = activation(cfg, h)
        ye = jnp.einsum("ecf,efd->ecd", h, w2)

        y_rows = ye[loc_e, pos_c]
        w_gate = (gate * mine.astype(jnp.float32))[:, None]
        y2 = (y_rows.astype(jnp.float32) * w_gate).reshape(tl, k, d).sum(1)
        y2 = jax.lax.psum(y2, model_axis)             # combine experts
        aux = {kk: jax.lax.pmean(jax.lax.pmean(vv, batch_axes), model_axis)
               for kk, vv in aux.items()}
        return y2.astype(x.dtype).reshape(bl, s, d), aux

    wg_param = p.get("wg", p["w1"])
    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(batch_axes, None, None), P(None, None),
                  P(model_axis, None, None), P(model_axis, None, None),
                  P(model_axis, None, None)),
        out_specs=(P(batch_axes, None, None),
                   {"moe_aux": P(), "moe_zloss": P()}),
        check_rep=False)
    return fn(x, p["router"], p["w1"], p["w2"], wg_param)


def moe_block(cfg: ModelConfig, p, x: jax.Array) -> Tuple[jax.Array, Dict]:
    if cfg.moe_dispatch == "banked":
        ep = _ep_context()
        if ep is not None and cfg.num_experts % ep[3] == 0:
            mesh, model_axis, batch_axes, tp = ep
            return moe_block_banked_ep(cfg, p, x, mesh, model_axis,
                                       batch_axes, tp)
        return moe_block_banked(cfg, p, x)
    return moe_block_gather(cfg, p, x)
