"""Chunked decay-scan in pure jnp — model-side twin of kernels/ssm_scan.py.

Two paths:
  * scalar decay per head (Mamba2 SSD): w (B,H,S); (C,C) relative-decay
    matrices — cheap.
  * per-channel decay (RWKV6): w (B,H,S,dk); (C,C,dk) intermediates inside
    the chunk scan.

Semantics identical to kernels/ref.ssm_scan_ref (tested against it).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def chunked_decay_scan(q: jax.Array, k: jax.Array, v: jax.Array,
                       w: jax.Array, u: Optional[jax.Array] = None,
                       chunk: int = 64, diag_mode: str = "inclusive",
                       h0: Optional[jax.Array] = None,
                       return_state: bool = False):
    """q/k: (B,H,S,dk); v: (B,H,S,dv); w: (B,H,S) scalar or (B,H,S,dk).

    h_t = exp(w_t) (.) h_{t-1} + k_t (x) v_t
    inclusive: o_t = q_t . h_t          (Mamba2)
    bonus:     o_t = q_t . h_{t-1} + (q_t . (u (.) k_t)) v_t   (RWKV6)
    """
    b, h, s, dk = q.shape
    dv = v.shape[-1]
    scalar_decay = (w.ndim == 3)
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    n = s // chunk
    dt32 = jnp.float32

    # q/k/v stay in their native dtype (no whole-sequence f32 copies —
    # perf iteration 1); decays cumsum in f32 for stability.
    qc = q.reshape(b, h, n, chunk, dk)
    kc = k.reshape(b, h, n, chunk, dk)
    vc = v.reshape(b, h, n, chunk, dv)
    wc = (w.astype(dt32).reshape(b, h, n, chunk) if scalar_decay
          else w.astype(dt32).reshape(b, h, n, chunk, dk))
    if u is not None:
        uf = u.astype(dt32)                       # (H, dk)

    mask_incl = jnp.tril(jnp.ones((chunk, chunk), bool))
    mask_strict = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)

    def step(hstate, blk):
        qb, kb, vb, wb = blk                      # (b,h,C,*)
        W = jnp.cumsum(wb, axis=2)                # inclusive
        if scalar_decay:
            Wq = W[..., None]                     # (b,h,C,1) broadcast to dk
        else:
            Wq = W
        # NOTE: relative-decay exponents are masked BEFORE exp — the
        # upper triangle holds positive exponents that overflow, and
        # gradients through where(mask, inf, 0) are NaN otherwise.
        dt = qb.dtype
        if diag_mode == "inclusive":
            qW = qb * jnp.exp(Wq).astype(dt)
            o_inter = jnp.einsum("bhck,bhkv->bhcv", qW,
                                 hstate.astype(dt),
                                 preferred_element_type=dt32)
            if scalar_decay:
                diff = W[..., :, None] - W[..., None, :]           # (b,h,C,C)
                rel = jnp.exp(jnp.where(mask_incl, diff, -1e30))
                scores = jnp.einsum("bhtd,bhsd->bhts", qb, kb,
                                    preferred_element_type=dt32) * rel
            else:
                diff = W[..., :, None, :] - W[..., None, :, :]
                rel = jnp.exp(jnp.where(mask_incl[..., None], diff, -1e30))
                scores = jnp.einsum("bhtd,bhtsd,bhsd->bhts",
                                    qb.astype(dt32), rel, kb.astype(dt32))
            o = o_inter + jnp.einsum("bhts,bhsv->bhtv",
                                     scores.astype(dt), vb,
                                     preferred_element_type=dt32)
        else:
            Wprev = W - wb
            Wq_prev = Wprev[..., None] if scalar_decay else Wprev
            qW = qb * jnp.exp(Wq_prev).astype(dt)
            o_inter = jnp.einsum("bhck,bhkv->bhcv", qW,
                                 hstate.astype(dt),
                                 preferred_element_type=dt32)
            if scalar_decay:
                diff = Wprev[..., :, None] - W[..., None, :]
                rel = jnp.exp(jnp.where(mask_strict, diff, -1e30))
                scores = jnp.einsum("bhtd,bhsd->bhts", qb, kb,
                                    preferred_element_type=dt32) * rel
            else:
                diff = Wprev[..., :, None, :] - W[..., None, :, :]
                rel = jnp.exp(jnp.where(mask_strict[..., None], diff, -1e30))
                scores = jnp.einsum("bhtd,bhtsd,bhsd->bhts",
                                    qb.astype(dt32), rel, kb.astype(dt32))
            o = o_inter + jnp.einsum("bhts,bhsv->bhtv",
                                     scores.astype(dt), vb,
                                     preferred_element_type=dt32)
            bonus = jnp.einsum("bhtd,hd,bhtd->bht", qb.astype(dt32),
                               uf, kb.astype(dt32))
            o = o + bonus[..., None] * vb.astype(dt32)
        w_last = (W[..., -1][..., None] if scalar_decay else W[..., -1, :])
        # (b,h,dk)
        k_dec = kb * jnp.exp(w_last[..., None, :] - Wq).astype(dt)
        h_new = (jnp.exp(w_last)[..., None] * hstate
                 + jnp.einsum("bhck,bhcv->bhkv", k_dec, vb,
                              preferred_element_type=dt32))
        return h_new, o

    if h0 is None:
        h0 = jnp.zeros((b, h, dk, dv), dt32)
    blks = (jnp.moveaxis(qc, 2, 0), jnp.moveaxis(kc, 2, 0),
            jnp.moveaxis(vc, 2, 0), jnp.moveaxis(wc, 2, 0))
    # checkpoint: the (C,C[,dk]) relative-decay intermediates are
    # recomputed in backward rather than stacked across chunks
    h_final, outs = jax.lax.scan(jax.checkpoint(step), h0, blks)
    o = jnp.moveaxis(outs, 0, 2).reshape(b, h, s, dv).astype(q.dtype)
    if return_state:
        return o, h_final
    return o


def decay_scan_step(hstate: jax.Array, q1, k1, v1, w1,
                    u: Optional[jax.Array] = None,
                    diag_mode: str = "inclusive"):
    """Single-token recurrence step for decode.

    hstate: (B,H,dk,dv); q1/k1/w1: (B,H,dk) (w scalar -> (B,H)); v1: (B,H,dv).
    Returns (o (B,H,dv), new state).
    """
    dt32 = jnp.float32
    q1, k1, v1 = q1.astype(dt32), k1.astype(dt32), v1.astype(dt32)
    if w1.ndim == 2:
        decay = jnp.exp(w1.astype(dt32))[..., None, None]
    else:
        decay = jnp.exp(w1.astype(dt32))[..., :, None]
    h_new = decay * hstate + k1[..., :, None] * v1[..., None, :]
    if diag_mode == "inclusive":
        o = jnp.einsum("bhk,bhkv->bhv", q1, h_new)
    else:
        o = jnp.einsum("bhk,bhkv->bhv", q1, hstate)
        bonus = jnp.einsum("bhk,hk,bhk->bh", q1, u.astype(dt32), k1)
        o = o + bonus[..., None] * v1
    return o, h_new
