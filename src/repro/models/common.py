"""Shared layers: norms, RoPE, activations, embedding, sharding constraints."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig


# ---------------------------------------------------------------------------
# Logical-axis sharding constraints.  The launcher installs rules; model code
# annotates activations with logical axes and stays mesh-agnostic.
# ---------------------------------------------------------------------------

_RULES: dict = {}
_MESH = None


def set_sharding_rules(mesh, rules: dict) -> None:
    global _RULES, _MESH
    _RULES, _MESH = dict(rules), mesh


def clear_sharding_rules() -> None:
    global _RULES, _MESH
    _RULES, _MESH = {}, None


def constrain(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint via logical axis names (no-op without rules).
    Axes whose mesh-shard count does not divide the dimension are dropped
    (e.g. vocab 51866 over 16-way TP) — GSPMD padding is legal but we keep
    input/constraint shardings even."""
    if _MESH is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    sizes = dict(zip(_MESH.axis_names, _MESH.devices.shape))

    def nshards(ax):
        if ax is None:
            return 1
        axes = ax if isinstance(ax, (tuple, list)) else (ax,)
        out = 1
        for a in axes:
            out *= sizes[a]
        return out

    entries = []
    for dim, a in zip(x.shape, logical_axes):
        ax = _RULES.get(a) if a else None
        entries.append(ax if (ax and dim % nshards(ax) == 0) else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_MESH, P(*entries)))


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6,
             plus_one: bool = False) -> jax.Array:
    """Statistics in f32; the (B,S,D) data path stays in the model dtype
    (perf iteration 6 — no materialized f32 activation copies)."""
    dt = x.dtype
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(dt)
    scale = (1.0 + w.astype(jnp.float32)).astype(dt) if plus_one \
        else w.astype(dt)
    return x * inv * scale


def layer_norm(x: jax.Array, w: jax.Array, b: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    return ((x - mu.astype(dt)) * inv.astype(dt) * w.astype(dt)
            + b.astype(dt))


def norm(cfg: ModelConfig, p, x: jax.Array) -> jax.Array:
    """Family-appropriate normalization.  p is dict with 'w' (+ 'b' for LN)."""
    if cfg.family in ("audio",) or cfg.family == "ssm":
        return layer_norm(x, p["w"], p["b"], eps=cfg.norm_eps)
    plus_one = cfg.name.startswith("gemma")
    return rms_norm(x, p["w"], eps=cfg.norm_eps, plus_one=plus_one)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------


def activation(cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if cfg.act == "silu":
        return jax.nn.silu(x)
    if cfg.act == "gelu":
        return jax.nn.gelu(x, approximate=True)
    if cfg.act == "relu":
        return jnp.maximum(x, 0.0)
    raise ValueError(cfg.act)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    if not cap:
        return x
    return jnp.tanh(x / cap) * cap


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float, positions: jax.Array) -> Tuple:
    """positions: (..., S) int32 -> (cos, sin) of shape (..., S, head_dim//2)."""
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                           / head_dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, H, S, D); cos/sin: (B, S, D/2) or (S, D/2).

    Rotations applied in the model dtype — cos/sin tables are cast once
    (tiny) instead of promoting the whole q/k tensors to f32."""
    if cos.ndim == 2:
        cos = cos[None]
        sin = sin[None]
    cos = cos[:, None].astype(x.dtype)    # (B, 1, S, D/2)
    sin = sin[:, None].astype(x.dtype)
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                           axis=-1)


def sinusoid_pos(seq: int, dim: int, offset: int = 0) -> jax.Array:
    """Whisper-style fixed sinusoidal embeddings (S, D)."""
    pos = jnp.arange(offset, offset + seq, dtype=jnp.float32)[:, None]
    div = jnp.exp(-jnp.log(10000.0)
                  * jnp.arange(0, dim, 2, dtype=jnp.float32) / dim)
    pe = jnp.zeros((seq, dim), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------


def embed(cfg: ModelConfig, params, tokens: jax.Array) -> jax.Array:
    x = params["embed"][tokens]            # (B, S, D)
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return constrain(x, "batch", "seq", "embed")


def lm_logits(cfg: ModelConfig, params, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    logits = softcap(logits.astype(jnp.float32), cfg.logit_softcap)
    return constrain(logits, "batch", "seq", "vocab")
