"""Qwen2-0.5B — GQA with QKV bias [arXiv:2407.10671]."""
from repro.models.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-0.5b", family="dense",
    num_layers=24, d_model=896, num_heads=14, num_kv_heads=2,
    d_ff=4864, vocab_size=151936, head_dim=64,
    qkv_bias=True, rope_theta=1e6, act="silu", tie_embeddings=True,
))
