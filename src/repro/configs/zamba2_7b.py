"""Zamba2-7B — Mamba2 backbone + shared attention blocks
[arXiv:2411.15242].  81 layers = 27 groups of (2 Mamba2 + 1 shared-attn);
the attention weights are SHARED across all application points."""
from repro.models.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="zamba2-7b", family="hybrid",
    num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32,
    d_ff=14336, vocab_size=32000, head_dim=112,
    ssm_state=64, ssm_head_dim=64, hybrid_attn_every=3,
    rope_theta=1e4, act="silu",
))
