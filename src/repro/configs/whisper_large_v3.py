"""Whisper-large-v3 — encoder-decoder, conv frontend STUB
[arXiv:2212.04356].  32 encoder + 32 decoder layers; the stub provides
precomputed (B, 1500, d_model) frame embeddings."""
from repro.models.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-large-v3", family="audio",
    num_layers=32, d_model=1280, num_heads=20, num_kv_heads=20,
    d_ff=5120, vocab_size=51866, head_dim=64,
    encoder_layers=32, encoder_seq=1500, frontend="audio_stub",
    rope_theta=0.0, act="gelu", tie_embeddings=True,
))
