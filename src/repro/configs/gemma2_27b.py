"""Gemma2-27B — local/global alternating attention, logit softcap
[arXiv:2408.00118]."""
from repro.models.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma2-27b", family="dense",
    num_layers=46, d_model=4608, num_heads=32, num_kv_heads=16,
    d_ff=36864, vocab_size=256000, head_dim=128,
    local_global=True, sliding_window=4096,
    attn_softcap=50.0, logit_softcap=30.0,
    rope_theta=1e4, act="gelu", tie_embeddings=True,
))
