"""Llama-3.2-11B-Vision — cross-attn image layers every 5th layer
(32 self-attn + 8 cross-attn = 40L) [hf:meta-llama/Llama-3.2-11B-Vision].
Vision frontend is a patch-embedding STUB per the assignment."""
from repro.models.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="llama-3.2-vision-11b", family="vlm",
    num_layers=40, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=128256, head_dim=128,
    cross_attn_every=5, frontend="patch_stub", num_patches=1601,
    rope_theta=5e5, act="silu",
))
