"""RWKV6-7B "Finch" — attention-free, data-dependent decay
[arXiv:2404.05892].  64 WKV heads of size 64."""
from repro.models.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="rwkv6-7b", family="ssm",
    num_layers=32, d_model=4096, num_heads=64, num_kv_heads=64,
    d_ff=14336, vocab_size=65536, head_dim=64,
    rope_theta=0.0, act="relu",
))
