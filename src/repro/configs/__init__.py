"""Per-architecture configs (exact public-literature numbers)."""
