"""Int8 error-feedback gradient compression for the DP all-reduce.

Each worker quantizes its local gradient shard to int8 with a per-tensor
scale, keeps the quantization residual as error feedback (added back before
the next step's quantization — EF-SGD), and the all-reduce moves 1/4 of the
f32 bytes.  Exposed two ways:

  * ``ef_compress``/``ef_decompress``: pure functions over pytrees;
  * ``compressed_psum``: a shard_map-based gradient sync whose lowered HLO
    contains an s8 all-reduce — the dry-run benchmark shows the 4x
    collective-byte reduction directly.
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def _quantize(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    amax = jnp.max(jnp.abs(g)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_compress(grads: Any, errors: Any) -> Tuple[Any, Any, Any]:
    """(grads, errors) -> (q_tree, scales, new_errors)."""
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = _quantize(corrected)
        new_e = corrected - _dequantize(q, s)
        return q, s, new_e

    flat, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(errors)
    out = [one(g, e) for g, e in zip(flat, flat_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]),
            treedef.unflatten([o[2] for o in out]))


def ef_decompress(q_tree: Any, scales: Any) -> Any:
    return jax.tree.map(_dequantize, q_tree, scales)


def init_errors(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(grads: Any, errors: Any, mesh: Mesh, axis: str = "data"):
    """shard_map gradient sync: int8 quantize -> psum(int32) -> dequantize.

    Input grads are per-device (replicated view of local grads); returns
    (synced_grads, new_errors).  The all-reduce payload is int8-accumulated
    in int32 (exact for <= 2^23 workers)."""
    from jax.experimental.shard_map import shard_map

    def sync(g_local, e_local):
        q, s, new_e = ef_compress(g_local, e_local)
        q32 = jax.tree.map(lambda x: x.astype(jnp.int32), q)
        summed = jax.tree.map(
            lambda x: jax.lax.psum(x, axis_name=axis), q32)
        s_sum = jax.tree.map(
            lambda x: jax.lax.psum(x, axis_name=axis), s)
        n = jax.lax.psum(1, axis_name=axis)
        avg_scale = jax.tree.map(lambda x: x / n, s_sum)
        out = jax.tree.map(
            lambda qs, sc: qs.astype(jnp.float32) * sc / n, summed, avg_scale)
        return out, new_e

    spec = P()  # grads replicated per data shard in this sync stage
    fn = shard_map(sync, mesh=mesh,
                   in_specs=(spec, spec), out_specs=(spec, spec),
                   check_rep=False)
    return fn(grads, errors)
