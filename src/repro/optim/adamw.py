"""AdamW with global-norm clipping and schedules — pure-pytree, shardable.

The optimizer state mirrors the parameter tree leaf-for-leaf (m, v), so the
parameter PartitionSpecs apply verbatim to the state (ZeRO: optimizer state
is sharded exactly as far as the params are).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jax.Array          # ()
    m: Any                   # like params
    v: Any                   # like params


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def init_state(params: Any) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), m=zeros,
                    v=jax.tree.map(jnp.copy, zeros))


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                        for g in jax.tree.leaves(tree)))


def apply_updates(cfg: AdamWConfig, params: Any, grads: Any,
                  state: OptState) -> Tuple[Any, OptState, Dict]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * g * g
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new, v_new

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, OptState(step, new_m, new_v), metrics
