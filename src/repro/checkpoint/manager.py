"""Fault-tolerant checkpointing: atomic, async, mesh-shape-agnostic.

Layout:  <dir>/step_{k:08d}/{manifest.json, arrays.npz}
A checkpoint is valid iff its manifest exists and carries a matching
``complete: true`` marker — the manifest is written LAST, after arrays are
flushed, and the step directory is renamed from a temp name, so a host
dying mid-save can never corrupt the latest checkpoint.

Restore is *elastic*: arrays are saved unsharded (gathered), and re-placed
with whatever NamedShardings the current mesh prescribes — restoring a
512-chip checkpoint onto a 256-chip mesh (or a CPU test mesh) is the same
code path.
"""
from __future__ import annotations

import concurrent.futures
import json
import os
import pathlib
import shutil
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any) -> Tuple[List[Tuple[str, Any]], Any]:
    # jax.tree gained flatten_with_path only after 0.4.37; tree_util has
    # carried it for much longer, so use the stable spelling.
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        items.append((key, leaf))
    return items, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep_n: int = 3,
                 async_save: bool = True):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_n = keep_n
        self._pool = (concurrent.futures.ThreadPoolExecutor(max_workers=1)
                      if async_save else None)
        self._pending: Optional[concurrent.futures.Future] = None

    # -- save -----------------------------------------------------------------
    def save(self, step: int, state: Any, blocking: bool = False) -> None:
        items, _ = _flatten(state)
        host_arrays = [(k, np.asarray(jax.device_get(v))) for k, v in items]
        if self._pool is None or blocking:
            self._write(step, host_arrays)
        else:
            self.wait()
            self._pending = self._pool.submit(self._write, step, host_arrays)

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def _write(self, step: int, items: List[Tuple[str, np.ndarray]]) -> None:
        final = self.dir / f"step_{step:08d}"
        tmp = self.dir / f".tmp_step_{step:08d}_{os.getpid()}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        np.savez(tmp / "arrays.npz", **{k: v for k, v in items})
        manifest = {
            "step": step, "complete": True, "time": time.time(),
            "keys": [k for k, _ in items],
            "shapes": {k: list(v.shape) for k, v in items},
            "dtypes": {k: str(v.dtype) for k, v in items},
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)                       # atomic publish
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep_n]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # -- restore ----------------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for p in sorted(self.dir.glob("step_*")):
            man = p / "manifest.json"
            if not man.exists():
                continue
            try:
                if json.loads(man.read_text()).get("complete"):
                    out.append(int(p.name.split("_")[1]))
            except (json.JSONDecodeError, ValueError):
                continue
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like: Any, step: Optional[int] = None) -> Tuple[Any, int]:
        """Restore into the structure/shardings of ``like`` (a pytree of
        arrays or ShapeDtypeStructs with .sharding)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        path = self.dir / f"step_{step:08d}"
        data = np.load(path / "arrays.npz")
        items, treedef = _flatten(like)
        leaves = []
        for key, proto in items:
            arr = data[key]
            shard = getattr(proto, "sharding", None)
            if shard is not None:
                leaves.append(jax.device_put(
                    arr.astype(proto.dtype), shard))
            else:
                leaves.append(jax.numpy.asarray(arr, dtype=proto.dtype))
        return jax.tree.unflatten(treedef, leaves), step
