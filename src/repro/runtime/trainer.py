"""Fault-tolerant training runtime.

Production posture for 1000+-node runs, exercised here at CPU scale:

  * checkpoint/restart — ``run_with_restarts`` supervises the train loop;
    any ``WorkerFailure`` (injected in tests, real preemptions in prod)
    triggers restore-from-latest and continuation.  The data pipeline is
    counter-based, so recovered trajectories are bitwise-identical.
  * straggler mitigation — per-step wall times feed an EMA outlier
    detector; flagged hosts are reported (prod: triggers hot-spare swap).
  * elastic rescale — checkpoints are mesh-agnostic; ``rescale`` restores
    the same state onto a different mesh/data-axis size.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from ..checkpoint.manager import CheckpointManager
from ..data.pipeline import DataConfig, SyntheticLM
from ..models import transformer
from ..models.config import ModelConfig
from ..models import params as MP
from ..optim import adamw


class WorkerFailure(RuntimeError):
    """A (simulated) node failure."""


@dataclasses.dataclass
class StragglerDetector:
    """EMA-based per-host step-time outlier detection."""
    alpha: float = 0.2
    threshold: float = 2.0          # x median-of-hosts
    _ema: Dict[int, float] = dataclasses.field(default_factory=dict)

    def record(self, host: int, step_time: float) -> None:
        prev = self._ema.get(host, step_time)
        self._ema[host] = (1 - self.alpha) * prev + self.alpha * step_time

    def stragglers(self) -> List[int]:
        if len(self._ema) < 2:
            return []
        med = float(np.median(list(self._ema.values())))
        return [h for h, t in self._ema.items()
                if t > self.threshold * med]


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 20
    log_every: int = 10
    checkpoint_dir: str = "/tmp/repro_ckpt"
    max_restarts: int = 3
    seed: int = 0


class Trainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainerConfig,
                 opt_cfg: Optional[adamw.AdamWConfig] = None,
                 data_cfg: Optional[DataConfig] = None,
                 failure_hook: Optional[Callable[[int], None]] = None):
        self.cfg = cfg
        self.tcfg = tcfg
        self.opt_cfg = opt_cfg or adamw.AdamWConfig(
            total_steps=tcfg.total_steps)
        self.data_cfg = data_cfg or DataConfig(
            vocab_size=cfg.vocab_size, seq_len=64, global_batch=8,
            seed=tcfg.seed)
        self.data = SyntheticLM(self.data_cfg, model_cfg=cfg)
        self.ckpt = CheckpointManager(tcfg.checkpoint_dir)
        self.failure_hook = failure_hook
        self.detector = StragglerDetector()
        self.history: List[Dict] = []
        self._step_fn = None

    # -- state ------------------------------------------------------------------
    def init_state(self) -> Dict:
        params = MP.init_params(self.cfg, seed=self.tcfg.seed)
        return {"params": params, "opt": adamw.init_state(params)}

    def _compiled_step(self):
        if self._step_fn is None:
            cfg, opt_cfg = self.cfg, self.opt_cfg

            def step(state, batch):
                def lf(p):
                    return transformer.loss_fn(cfg, p, batch)
                (loss, metrics), grads = jax.value_and_grad(
                    lf, has_aux=True)(state["params"])
                new_p, new_opt, om = adamw.apply_updates(
                    opt_cfg, state["params"], grads, state["opt"])
                return ({"params": new_p, "opt": new_opt},
                        {**metrics, **om})

            self._step_fn = jax.jit(step, donate_argnums=0)
        return self._step_fn

    # -- training ---------------------------------------------------------------
    def _loop(self, state: Dict, start_step: int) -> Dict:
        step_fn = self._compiled_step()
        for step in range(start_step, self.tcfg.total_steps):
            if self.failure_hook is not None:
                self.failure_hook(step)     # may raise WorkerFailure
            t0 = time.time()
            batch = {k: jax.numpy.asarray(v)
                     for k, v in self.data.batch(step).items()}
            state, metrics = step_fn(state, batch)
            dt = time.time() - t0
            self.detector.record(0, dt)
            rec = {"step": step, "loss": float(metrics["loss"]),
                   "grad_norm": float(metrics["grad_norm"]),
                   "step_time_s": dt}
            self.history.append(rec)
            if (step + 1) % self.tcfg.checkpoint_every == 0 \
                    or step + 1 == self.tcfg.total_steps:
                self.ckpt.save(step + 1, state)
        self.ckpt.wait()
        return state

    def run_with_restarts(self) -> Dict:
        """Supervised loop: restore-from-latest on failure, bounded retries."""
        restarts = 0
        state = self.init_state()
        start = 0
        latest = self.ckpt.latest_step()
        if latest is not None:
            state, start = self.ckpt.restore(state)
        while True:
            try:
                return self._loop(state, start)
            except WorkerFailure as e:
                restarts += 1
                if restarts > self.tcfg.max_restarts:
                    raise RuntimeError(
                        f"exceeded max_restarts={self.tcfg.max_restarts}") \
                        from e
                self.ckpt.wait()
                state = self.init_state()
                latest = self.ckpt.latest_step()
                start = 0
                if latest is not None:
                    state, start = self.ckpt.restore(state)
                self.history.append({"restart": restarts,
                                     "resume_step": start})

    # -- elasticity ---------------------------------------------------------------
    def rescale(self, like_state: Any) -> Any:
        """Restore the latest checkpoint into a differently-sharded state
        skeleton (new mesh size / data-axis) — elastic scaling."""
        state, step = self.ckpt.restore(like_state)
        return state, step
