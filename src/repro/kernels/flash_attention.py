"""Flash attention (online softmax) with GQA and causal masking.

TPU-native tiling: grid (batch*q_heads, q_blocks, kv_blocks); the kv-block
dimension is innermost/sequential and carries running max / denominator /
accumulator in VMEM scratch.  The GQA mapping (q head -> kv head) happens in
the BlockSpec ``index_map`` — again a compile-time bank selection, never a
runtime gather (the paper's layout-embedded banking discipline).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  nk: int, bq: int, bk: int, scale: float, causal: bool):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (bq,bk)

    if causal:
        i = pl.program_id(1)
        q_ids = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_ids = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(q_ids >= k_ids, s, _NEG_INF)

    m_prev = m_ref[...]                       # (bq, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)                    # (bq, bk)
    alpha = jnp.exp(m_prev - m_new)           # (bq, 1)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
    acc_ref[...] = (acc_ref[...] * alpha
                    + jnp.dot(p, v_ref[0].astype(jnp.float32),
                              preferred_element_type=jnp.float32))
    m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True) -> jax.Array:
    """q: (B, Hq, S, D); k/v: (B, Hkv, S, D) with Hq % Hkv == 0."""
    b, hq, s, d = q.shape
    _, hkv, sk, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    bq = min(block_q, s)
    bk = min(block_k, sk)
    assert s % bq == 0 and sk % bk == 0, "seq lens must divide block sizes"
    nq, nk = s // bq, sk // bk

    qf = q.reshape(b * hq, s, d)
    kf = k.reshape(b * hkv, sk, d)
    vf = v.reshape(b * hkv, sk, d)

    def kv_map(h, i, j):
        return (h // group, j, 0)

    kernel = functools.partial(_flash_kernel, nk=nk, bq=bq, bk=bk,
                               scale=scale, causal=causal)
    out = pl.pallas_call(
        kernel,
        grid=(b * hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, d), kv_map),
            pl.BlockSpec((1, bk, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, hq, s, d)
