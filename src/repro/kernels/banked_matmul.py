"""Banked tiled matmul — the paper's FFNN hot loop as a TPU Pallas kernel.

TPU adaptation of the paper's layout-embedded banking: the cyclic banking
factor becomes the grid partition, and the BlockSpec ``index_map`` plays the
role of the compile-time-constant bank index — each grid step addresses a
statically-determined VMEM tile, with no runtime selection logic (the
hardware analogue of the paper's folded ``(c*ii + a) % c``).

Grid is (M/bm, N/bn, K/bk) with the K dimension innermost (sequential,
"arbitrary") carrying an f32 VMEM accumulator — MXU-aligned tiles, f32
accumulation regardless of input dtype.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _pad2(x: jax.Array, r: int, c: int) -> jax.Array:
    pr, pc = r - x.shape[0], c - x.shape[1]
    if pr or pc:
        x = jnp.pad(x, ((0, pr), (0, pc)))
    return x


def derive_block(m: int, n: int, k: int,
                 banks: Tuple[int, int, int]) -> Tuple[int, int, int]:
    """Bank counts -> MXU-aligned VMEM tile sizes (the BlockSpec analogue of
    the paper's per-dimension cyclic factors)."""
    bm = _round_up(max(1, -(-m // banks[0])), 8)
    bn = _round_up(max(1, -(-n // banks[1])), 128 if n >= 128 else 8)
    bk = _round_up(max(1, -(-k // banks[2])), 128 if k >= 128 else 8)
    return (min(bm, _round_up(m, 8)),
            min(bn, _round_up(n, 128 if n >= 128 else 8)),
            min(bk, _round_up(k, 128 if k >= 128 else 8)))


def banked_matmul(a: jax.Array, b: jax.Array,
                  banks: Tuple[int, int, int] = (1, 1, 1),
                  block: Optional[Tuple[int, int, int]] = None,
                  out_dtype=None, interpret: bool = True) -> jax.Array:
    """C[M,N] = A[M,K] @ B[K,N] with bank-derived tiling.

    ``banks`` follows the paper's per-dimension cyclic factors (c_m,c_n,c_k).
    Inputs are zero-padded up to tile multiples (zeros are matmul-neutral);
    the result is sliced back to (M, N).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    out_dtype = out_dtype or a.dtype
    bm, bn, bk = block or derive_block(m, n, k, banks)
    mp, np_, kp = _round_up(m, bm), _round_up(n, bn), _round_up(k, bk)
    a = _pad2(a, mp, kp)
    b = _pad2(b, kp, np_)
    gm, gn, gk = mp // bm, np_ // bn, kp // bk

    kernel = functools.partial(_matmul_kernel, nk=gk)
    out = pl.pallas_call(
        kernel,
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
    return out[:m, :n]
