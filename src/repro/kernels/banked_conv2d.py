"""Banked 2-D convolution — the paper's CNN hot loop as a TPU Pallas kernel.

The paper's CNN suffers on Calyx because flattened multi-dim indexing costs
address arithmetic per access.  The TPU adaptation sidesteps exactly that:
the (cin, kh, kw) reduction is unrolled inside the kernel with compile-time
offsets (the fold of ``(c*i + a) % c`` one more time), and the banking
factors become the (output-channel x row-block) grid.

Layout: x (Cin, H, W); w (Cout, Cin, kh, kw); out (Cout, H', W') with
H' = H-kh+1, W' = W-kw+1 (valid, unit stride).  The input feature map stays
resident (it is small for conv workloads); each grid step slices its
overlapping row window with a dynamic slice whose only traced component is
the row-block index.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _conv_kernel(x_ref, w_ref, o_ref, *, kh: int, kw: int, bh: int,
                 wout: int):
    r = pl.program_id(1)
    x = x_ref[...].astype(jnp.float32)          # (Cin, Hp, W)
    w = w_ref[...].astype(jnp.float32)          # (bc, Cin, kh, kw)
    cin = x.shape[0]
    acc = jnp.zeros(o_ref.shape, jnp.float32)   # (bc, bh, wout)
    for dy in range(kh):                        # static reduction offsets
        for dx in range(kw):
            patch = jax.lax.dynamic_slice(
                x, (0, r * bh + dy, dx), (cin, bh, wout))
            tap = w[:, :, dy, dx]               # (bc, Cin)
            acc = acc + jnp.einsum("oc,chw->ohw", tap, patch,
                                   preferred_element_type=jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


def banked_conv2d(x: jax.Array, w: jax.Array,
                  banks: Tuple[int, int] = (1, 1),
                  interpret: bool = True) -> jax.Array:
    """x: (Cin, H, W); w: (Cout, Cin, kh, kw) -> (Cout, H-kh+1, W-kw+1).

    ``banks`` = (cout_banks, row_banks): the cyclic partition of the output
    channel and output-row dimensions, realized as the Pallas grid.
    """
    cin, h, width = x.shape
    cout, cin2, kh, kw = w.shape
    assert cin == cin2, (x.shape, w.shape)
    hout, wout = h - kh + 1, width - kw + 1
    bc = max(1, -(-cout // banks[0]))
    bh = max(1, -(-hout // banks[1]))
    gc, gh = -(-cout // bc), -(-hout // bh)
    cout_p, hout_p = gc * bc, gh * bh
    if cout_p != cout:
        w = jnp.pad(w, ((0, cout_p - cout), (0, 0), (0, 0), (0, 0)))
    hp = hout_p + kh - 1
    if hp != h:
        x = jnp.pad(x, ((0, 0), (0, hp - h), (0, 0)))

    kernel = functools.partial(_conv_kernel, kh=kh, kw=kw, bh=bh, wout=wout)
    out = pl.pallas_call(
        kernel,
        grid=(gc, gh),
        in_specs=[
            pl.BlockSpec((cin, hp, width), lambda c, r: (0, 0, 0)),
            pl.BlockSpec((bc, cin, kh, kw), lambda c, r: (c, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bc, bh, wout), lambda c, r: (c, r, 0)),
        out_shape=jax.ShapeDtypeStruct((cout_p, hout_p, wout), x.dtype),
        interpret=interpret,
    )(x, w)
    return out[:cout, :hout]
