"""Pure-jnp oracles for every Pallas kernel.  The kernels must match these
bit-for-bit up to dtype tolerance on all swept shapes."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def matmul_ref(a: jax.Array, b: jax.Array, out_dtype=None) -> jax.Array:
    out_dtype = out_dtype or a.dtype
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                   preferred_element_type=jnp.float32).astype(out_dtype)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                  causal: bool = True,
                  scale: Optional[float] = None) -> jax.Array:
    """q: (B,Hq,S,D); k/v: (B,Hkv,S,D); GQA by head repetition."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    kr = jnp.repeat(k, group, axis=1)
    vr = jnp.repeat(v, group, axis=1)
    s_mat = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                       kr.astype(jnp.float32)) * scale
    if causal:
        sk = k.shape[2]
        mask = jnp.tril(jnp.ones((s, sk), bool), k=sk - s)
        s_mat = jnp.where(mask, s_mat, -1e30)
    p = jax.nn.softmax(s_mat, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vr.astype(jnp.float32))
    return out.astype(q.dtype)


def ssm_scan_ref(q: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
                 u: Optional[jax.Array] = None,
                 diag_mode: str = "inclusive") -> jax.Array:
    """Step-by-step recurrence (jax.lax.scan over time) — the ground truth.

        h_t = exp(w_t) (.) h_{t-1} + k_t (x) v_t
        inclusive: o_t = q_t . h_t
        bonus:     o_t = q_t . h_{t-1} + (q_t . (u (.) k_t)) v_t
    """
    b, h, s, dk = q.shape
    dv = v.shape[-1]
    if u is None:
        u = jnp.zeros((h, dk), q.dtype)
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    wf = w.astype(jnp.float32)
    uf = jnp.broadcast_to(u.astype(jnp.float32)[None], (b, h, dk))

    def step(hstate, inp):
        qt, kt, vt, wt = inp                      # (b,h,dk),(b,h,dk),(b,h,dv)
        decayed = jnp.exp(wt)[..., None] * hstate  # (b,h,dk,dv)
        h_new = decayed + kt[..., None] * vt[..., None, :]
        if diag_mode == "inclusive":
            o = jnp.einsum("bhk,bhkv->bhv", qt, h_new)
        else:
            o = jnp.einsum("bhk,bhkv->bhv", qt, hstate)
            bonus = jnp.einsum("bhk,bhk->bh", qt, uf * kt)
            o = o + bonus[..., None] * vt
        return h_new, o

    h0 = jnp.zeros((b, h, dk, dv), jnp.float32)
    inputs = (jnp.moveaxis(qf, 2, 0), jnp.moveaxis(kf, 2, 0),
              jnp.moveaxis(vf, 2, 0), jnp.moveaxis(wf, 2, 0))
    _, outs = jax.lax.scan(step, h0, inputs)
    return jnp.moveaxis(outs, 0, 2).astype(q.dtype)


def conv2d_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: (Cin,H,W); w: (Cout,Cin,kh,kw) -> valid unit-stride conv."""
    out = jax.lax.conv_general_dilated(
        x[None].astype(jnp.float32), w.astype(jnp.float32),
        window_strides=(1, 1), padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"))
    return out[0].astype(x.dtype)
