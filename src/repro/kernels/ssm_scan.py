"""Chunked decay-scan kernel (SSD / linear attention) for Mamba2 and RWKV6.

Recurrence per head, with per-channel log-decay ``w_t <= 0`` over the key
dimension (RWKV6 "Finch" data-dependent decay; Mamba2 broadcasts a scalar):

    h_t = exp(w_t) (.) h_{t-1}  +  k_t (x) v_t            h in R^{dk x dv}
    o_t = q_t . h_{t-1 or t}                               (see ``diag_mode``)

``diag_mode``:
  * ``"inclusive"`` (Mamba2/SSD): o_t reads h_t (current token included via
    the decay path).
  * ``"bonus"`` (RWKV6): o_t reads h_{t-1} plus a bonus term
    ``(q_t . (u (.) k_t)) v_t`` for the current token.

TPU chunking: grid (B*H, n_chunks), sequential chunk axis carrying the f32
state in VMEM scratch.  Within a chunk the recurrence is materialized in
parallel form: cumulative decays fold the paper's bank-index trick one more
time — positions inside the chunk address the state with compile-time
offsets, never a serial python loop.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _window_sums(w: jax.Array, chunk: int) -> jax.Array:
    """Pairwise decay sums ``out[t, s, d] = sum_{s < i <= t} w[i, d]``.

    Computed directly as per-window running sums (a fresh cumsum restarted
    after every ``s``) rather than as the cumsum difference ``W_t - W_s``:
    subtracting two long accumulations cancels catastrophically once |W|
    grows with the chunk length, which is exactly what made large-chunk
    runs drift from small-chunk runs.  Here the rounding error of each
    entry is proportional to the *window* magnitude — large windows have
    vanishing ``exp`` anyway, so the error lands where it cannot matter.
    """
    i_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)  # (s, i)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    gated = jnp.where((i_idx > s_idx)[:, :, None], w[None, :, :], 0.0)
    win = jnp.cumsum(gated, axis=1)       # win[s, t, d] = sum_{s < i <= t}
    return jnp.transpose(win, (1, 0, 2))  # (t, s, d)


def _scan_kernel(q_ref, k_ref, v_ref, w_ref, u_ref, o_ref, h_ref, *,
                 chunk: int, diag_mode: str):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    q = q_ref[0].astype(jnp.float32)      # (C, dk)
    k = k_ref[0].astype(jnp.float32)      # (C, dk)
    v = v_ref[0].astype(jnp.float32)      # (C, dv)
    w = w_ref[0].astype(jnp.float32)      # (C, dk), log-decays (<= 0)

    W = jnp.cumsum(w, axis=0)             # (C, dk) inclusive cumulative decay
    h0 = h_ref[...]                       # (dk, dv) state before this chunk
    win = _window_sums(w, chunk)          # (C, C, dk) exact window decays
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)

    if diag_mode == "inclusive":
        # o_t = q_t . h_t ; h_t includes token t
        qW = q * jnp.exp(W)               # decay from chunk start to t
        o_inter = jnp.dot(qW, h0, preferred_element_type=jnp.float32)
        # intra: sum_{s<=t} exp(sum_{s<i<=t} w_i) (q_t.k_s) v_s
        # (exponent masked BEFORE exp: upper triangle overflows otherwise)
        diff = jnp.where((s_idx <= t_idx)[:, :, None], win, -1e30)
        rel = jnp.exp(diff)                               # (C, C, dk)
        scores = jnp.einsum("td,tsd,sd->ts", q, rel, k)
        o = o_inter + jnp.dot(scores, v, preferred_element_type=jnp.float32)
    else:  # bonus (RWKV6): o_t reads h_{t-1}, diag via u
        # exclusive cumulative decay (chunk start .. t-1) as a shift of the
        # inclusive one — W - w would reintroduce the cancellation
        Wprev = jnp.concatenate([jnp.zeros((1,) + W.shape[1:], W.dtype),
                                 W[:-1]], axis=0)
        qW = q * jnp.exp(Wprev)
        o_inter = jnp.dot(qW, h0, preferred_element_type=jnp.float32)
        # exponent sum_{s<i<=t-1} w_i = win[t-1, s]: shift win along t
        shifted = jnp.concatenate(
            [jnp.zeros((1, chunk, win.shape[2]), win.dtype), win[:-1]],
            axis=0)
        diff = jnp.where((s_idx < t_idx)[:, :, None], shifted, -1e30)
        rel = jnp.exp(diff)                               # s <= t-1
        scores = jnp.einsum("td,tsd,sd->ts", q, rel, k)
        o = o_inter + jnp.dot(scores, v, preferred_element_type=jnp.float32)
        u = u_ref[...].astype(jnp.float32)                # (1, dk)
        bonus = jnp.sum(q * u * k, axis=1, keepdims=True) # (C, 1)
        o = o + bonus * v

    o_ref[0] = o.astype(o_ref.dtype)

    # state update: h' = exp(W_last) h0 + sum_s exp(sum_{s<i} w_i) k_s v_s.
    # The per-position suffix decays are the last row of the window table
    # (again direct sums, never W_last - W_s), and the full-chunk decay is a
    # plain reduction — both keep the f32 carry consistent across chunkings.
    w_total = jnp.sum(w, axis=0)                           # (dk,)
    k_dec = k * jnp.exp(win[-1])                           # (C, dk)
    h_ref[...] = (jnp.exp(w_total)[:, None] * h0
                  + jnp.dot(k_dec.T, v, preferred_element_type=jnp.float32))


def ssm_scan(q: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
             u: Optional[jax.Array] = None, chunk: int = 32,
             diag_mode: str = "inclusive", interpret: bool = True
             ) -> jax.Array:
    """q/k/w: (B, H, S, dk); v: (B, H, S, dv); u: (H, dk) for RWKV bonus.

    Returns o: (B, H, S, dv).  S must be divisible by ``chunk``.
    """
    b, h, s, dk = q.shape
    dv = v.shape[-1]
    assert s % chunk == 0, (s, chunk)
    assert diag_mode in ("inclusive", "bonus")
    nchunks = s // chunk
    if u is None:
        u = jnp.zeros((h, dk), q.dtype)

    qf = q.reshape(b * h, s, dk)
    kf = k.reshape(b * h, s, dk)
    vf = v.reshape(b * h, s, dv)
    wf = w.reshape(b * h, s, dk)
    uf = jnp.tile(u, (b, 1)).reshape(b * h, dk)

    kernel = functools.partial(_scan_kernel, chunk=chunk, diag_mode=diag_mode)
    out = pl.pallas_call(
        kernel,
        grid=(b * h, nchunks),
        in_specs=[
            pl.BlockSpec((1, chunk, dk), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, chunk, dk), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, chunk, dv), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, chunk, dk), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, dk), lambda bh, c: (bh, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, dv), lambda bh, c: (bh, c, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, dv), q.dtype),
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        interpret=interpret,
    )(qf, kf, vf, wf, uf)
    return out.reshape(b, h, s, dv)
