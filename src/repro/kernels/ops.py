"""Jitted public wrappers for the Pallas kernels.

``interpret`` defaults to True unless a real TPU backend is present —
frameworks flip to compiled kernels transparently on hardware, while CPU
CI exercises the identical kernel bodies through the Pallas interpreter.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import banked_conv2d as _bc
from . import banked_matmul as _bm
from . import flash_attention as _fa
from . import ssm_scan as _ss


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:  # pragma: no cover
        return False


@functools.partial(jax.jit, static_argnames=("banks", "block", "out_dtype"))
def matmul(a: jax.Array, b: jax.Array,
           banks: Tuple[int, int, int] = (1, 1, 1),
           block: Optional[Tuple[int, int, int]] = None,
           out_dtype=None) -> jax.Array:
    return _bm.banked_matmul(a, b, banks=banks, block=block,
                             out_dtype=out_dtype, interpret=not _on_tpu())


@functools.partial(jax.jit,
                   static_argnames=("causal", "scale", "block_q", "block_k"))
def attention(q, k, v, causal: bool = True, scale: Optional[float] = None,
              block_q: int = 128, block_k: int = 128) -> jax.Array:
    return _fa.flash_attention(q, k, v, causal=causal, scale=scale,
                               block_q=block_q, block_k=block_k,
                               interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("chunk", "diag_mode"))
def decay_scan(q, k, v, w, u=None, chunk: int = 32,
               diag_mode: str = "inclusive") -> jax.Array:
    return _ss.ssm_scan(q, k, v, w, u=u, chunk=chunk, diag_mode=diag_mode,
                        interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("banks",))
def conv2d(x, w, banks: Tuple[int, int] = (1, 1)) -> jax.Array:
    return _bc.banked_conv2d(x, w, banks=banks, interpret=not _on_tpu())
