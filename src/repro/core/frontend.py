"""Torch-like module frontend — the "PyTorch → Allo" stage of the pipeline.

Users define models with ``nn``-style modules; ``trace`` runs the module
symbolically against an input spec and records a ``tensor_ir.Graph``.  This is
deliberately a small, faithful analogue of what Allo does for PyTorch: it
preserves tensor semantics, parameter identity, and module structure (each
module becomes a named region; function calls become graph sub-regions, the
analogue of the paper's "functions become Calyx components").
"""
from __future__ import annotations

import math
from typing import List, Optional, Sequence, Tuple

import numpy as np

from . import tensor_ir as T


class Value:
    """Symbolic tensor value flowing through the tracer."""

    def __init__(self, graph: T.Graph, name: str):
        self.graph = graph
        self.name = name

    @property
    def shape(self) -> Tuple[int, ...]:
        return self.graph.shape(self.name)

    def __matmul__(self, other: "Value") -> "Value":
        return Value(self.graph, T.matmul(self.graph, self.name, other.name))

    def __add__(self, other: "Value") -> "Value":
        return Value(self.graph, T.add(self.graph, self.name, other.name))

    def __mul__(self, other) -> "Value":
        if isinstance(other, (int, float)):
            return Value(self.graph, T.scale(self.graph, self.name, other))
        return Value(self.graph, T.mul(self.graph, self.name, other.name))

    def t(self) -> "Value":
        return Value(self.graph, T.transpose(self.graph, self.name))


class Module:
    """Base class.  Subclasses define ``forward`` over ``Value``s."""

    def __call__(self, *args):
        return self.forward(*args)

    def forward(self, *args):  # pragma: no cover - abstract
        raise NotImplementedError

    def named_parameters(self, prefix: str = ""):
        for k, v in vars(self).items():
            path = f"{prefix}.{k}" if prefix else k
            if isinstance(v, np.ndarray):
                yield path, v
            elif isinstance(v, Module):
                yield from v.named_parameters(path)
            elif isinstance(v, (list, tuple)):
                for i, item in enumerate(v):
                    if isinstance(item, Module):
                        yield from item.named_parameters(f"{path}.{i}")


def _kaiming(rng: np.random.Generator, fan_in: int, shape) -> np.ndarray:
    bound = math.sqrt(1.0 / max(fan_in, 1))
    return rng.uniform(-bound, bound, size=shape).astype(np.float32)


class Linear(Module):
    def __init__(self, in_features: int, out_features: int, bias: bool = True,
                 rng: Optional[np.random.Generator] = None):
        rng = rng or np.random.default_rng(0)
        self.weight = _kaiming(rng, in_features, (in_features, out_features))
        self.bias = _kaiming(rng, in_features, (out_features,)) if bias else None

    def forward(self, x: Value) -> Value:
        g = x.graph
        w = Value(g, g.add_param(g._fresh("w"), self.weight))
        out = x @ w
        if self.bias is not None:
            b = Value(g, g.add_param(g._fresh("b"), self.bias))
            out = out + b
        return out


class ReLU(Module):
    def forward(self, x: Value) -> Value:
        return Value(x.graph, T.relu(x.graph, x.name))


class Conv2d(Module):
    """Unit-stride valid conv over (Cin,H,W) inputs."""

    def __init__(self, cin: int, cout: int, kh: int, kw: int,
                 rng: Optional[np.random.Generator] = None):
        rng = rng or np.random.default_rng(0)
        self.weight = _kaiming(rng, cin * kh * kw, (cout, cin, kh, kw))

    def forward(self, x: Value) -> Value:
        g = x.graph
        w = Value(g, g.add_param(g._fresh("convw"), self.weight))
        return Value(g, T.conv2d(g, x.name, w.name))


class MaxPool2d(Module):
    def __init__(self, ph: int, pw: int):
        self.ph, self.pw = ph, pw

    def forward(self, x: Value) -> Value:
        return Value(x.graph, T.maxpool2d(x.graph, x.name, self.ph, self.pw))


class Flatten(Module):
    def forward(self, x: Value) -> Value:
        return Value(x.graph, T.flatten(x.graph, x.name))


class Softmax(Module):
    def forward(self, x: Value) -> Value:
        return Value(x.graph, T.softmax(x.graph, x.name))


class Sequential(Module):
    def __init__(self, *mods: Module):
        self.mods = list(mods)

    def forward(self, x: Value) -> Value:
        for m in self.mods:
            x = m(x)
        return x


class MultiheadAttention(Module):
    """Causal MHA over a (S, D) sequence — the paper's MHA benchmark shape.

    ``heads`` heads each over a D/heads subspace, with causal masking.
    """

    def __init__(self, embed_dim: int, num_heads: int,
                 rng: Optional[np.random.Generator] = None):
        assert embed_dim % num_heads == 0
        rng = rng or np.random.default_rng(0)
        self.embed_dim, self.num_heads = embed_dim, num_heads
        self.head_dim = embed_dim // num_heads
        self.wq = Linear(embed_dim, embed_dim, bias=False, rng=rng)
        self.wk = Linear(embed_dim, embed_dim, bias=False, rng=rng)
        self.wv = Linear(embed_dim, embed_dim, bias=False, rng=rng)
        self.wo = Linear(embed_dim, embed_dim, bias=False, rng=rng)

    def forward(self, x: Value) -> Value:
        g = x.graph
        s, d = x.shape
        q, k, v = self.wq(x), self.wk(x), self.wv(x)
        head_outs: List[Value] = []
        hd = self.head_dim
        for h in range(self.num_heads):
            # slice head h: implemented as matmul with a selector param so the
            # whole program stays inside the closed op set (as Allo would
            # materialize a view).
            sel = np.zeros((d, hd), dtype=np.float32)
            sel[h * hd:(h + 1) * hd, :] = np.eye(hd, dtype=np.float32)
            selv = Value(g, g.add_param(g._fresh(f"sel{h}"), sel))
            qh, kh, vh = q @ selv, k @ selv, v @ selv
            scores = qh @ kh.t()
            scores = scores * (1.0 / math.sqrt(hd))
            masked = Value(g, T.causal_mask(g, scores.name))
            probs = Value(g, T.softmax(g, masked.name))
            head_outs.append(probs @ vh)
        # concat heads via selector transposes: out = sum_h head_h @ sel_h^T
        acc = None
        for h, ho in enumerate(head_outs):
            sel = np.zeros((hd, d), dtype=np.float32)
            sel[:, h * hd:(h + 1) * hd] = np.eye(hd, dtype=np.float32)
            selv = Value(g, g.add_param(g._fresh(f"cat{h}"), sel))
            part = ho @ selv
            acc = part if acc is None else acc + part
        return self.wo(acc)


def trace(module: Module, input_shapes: Sequence[Tuple[int, ...]],
          name: str = "main") -> T.Graph:
    """Run ``module`` symbolically and return the recorded Graph."""
    g = T.Graph(name=name)
    vals = []
    for i, shp in enumerate(input_shapes):
        nm = g.add_input(f"arg{i}", shp)
        vals.append(Value(g, nm))
    out = module(*vals)
    outs = out if isinstance(out, (list, tuple)) else [out]
    g.outputs = [o.name for o in outs]
    g.topo_check()
    return g


# ---------------------------------------------------------------------------
# The paper's three benchmark models (§4.1), exactly as specified.
# ---------------------------------------------------------------------------

def paper_ffnn(rng_seed: int = 0) -> Module:
    """64 features -> FC 64x48 -> ReLU -> FC 48x4."""
    rng = np.random.default_rng(rng_seed)
    return Sequential(Linear(64, 48, rng=rng), ReLU(), Linear(48, 4, rng=rng))


def paper_cnn(rng_seed: int = 0) -> Module:
    """80x60x3 image -> conv 5x5 (3->8) -> ReLU -> maxpool 2x3 -> FC -> 2."""
    rng = np.random.default_rng(rng_seed)
    h, w = 80 - 5 + 1, 60 - 5 + 1          # 76 x 56 valid conv
    flat = 8 * (h // 2) * (w // 3)         # pool 2x3
    return Sequential(Conv2d(3, 8, 5, 5, rng=rng), ReLU(), MaxPool2d(2, 3),
                      Flatten(), _RowVec(), Linear(flat, 2, rng=rng))


class _RowVec(Module):
    """(N,) -> (1, N) so flattened features can feed a Linear."""

    def forward(self, x: Value) -> Value:
        n = x.shape[0]
        return Value(x.graph, T.reshape(x.graph, x.name, (1, n)))


def paper_mha(rng_seed: int = 0, seq_len: int = 8) -> Module:
    """2 heads over 21-dim subspaces of a 42-dim embedding, causal."""
    rng = np.random.default_rng(rng_seed)
    return MultiheadAttention(42, 2, rng=rng)
