"""Cycle-accurate FSM simulator for the Calyx-like IR.

Where ``affine.interpret`` executes the *source* of the lowering and
``estimator.cycles`` predicts the schedule of its *result* from a closed
form, this module executes the lowered component itself: it walks the
control tree as an FSM scheduler, fires each group's recorded micro-ops
(``Group.uops``, see ``core.dataflow``) against real register/memory
state, and advances a cycle clock — so both the output tensors *and* the
cycle count are measured, not modeled.

Scheduling semantics (the constructive twin of the estimator's model):

* ``seq``     — children run back to back.
* ``repeat``  — loop setup, then each iteration runs the body plus the
                per-iteration overhead; the body's iteration variable is
                bound in the environment the micro-ops evaluate addresses
                against.
* ``if``      — the condition is evaluated and only the taken arm
                *executes*, but the control FSM is statically timed: the
                state reserves the worst-case arm latency (the non-taken
                arm's static cycles), matching real Calyx static control
                and the estimator's ``max(arms)`` term.
* ``par``     — arms are partitioned with the estimator's own
                :func:`estimator.par_conflict_components`: arms that hit a
                common single-ported (memory, bank) serialize inside their
                component, components run concurrently, and the join
                handshake closes the block.  The simulator additionally
                enforces the constraint the partition is meant to uphold —
                every memory access is stamped into a per-(memory, bank,
                cycle) port table, and two same-cycle accesses raise
                :class:`SimError` unless they are identical-address loads
                (a broadcast from one read port).

Shared functional units (``Cell.users > 1``, produced by
``sharing.share_cells``) are arbitrated for single ownership: concurrent
``par`` components must not both invoke the same pool cell, otherwise the
design would need to serialize — exactly the invariant the binding pass
promises.  Violations raise :class:`SimError` rather than silently
mis-simulating.

Because every control construct's duration is input-independent (see the
``if`` rule), measured cycles structurally equal ``estimator.cycles``; the
differential tests assert the equality exactly, making every compiled
design an end-to-end hardware-semantics test.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, List, Optional, Set, Tuple

import numpy as np

from . import dataflow as D
from . import estimator
from . import float_lib as F
from . import trace as T
from .affine import Program, pack_banked
from .calyx import CIf, CNode, CPar, CRepeat, CSeq, Component, GEnable


class SimError(RuntimeError):
    """A dynamic hardware-semantics violation (port clash, FU contention)."""


@dataclasses.dataclass
class SimStats:
    """Measured facts about one simulation run."""
    cycles: int = 0                  # measured end-to-end latency
    group_activations: int = 0
    uops: int = 0
    mem_reads: int = 0
    mem_writes: int = 0
    broadcast_reads: int = 0         # same-cycle identical-address loads
    par_blocks: int = 0              # par nodes executed (dynamic count)
    serialized_arms: int = 0         # arms forced behind a sibling by ports
    fu_grants: Dict[str, int] = dataclasses.field(default_factory=dict)
    # cycle-attribution counters — same fields as rtl_sim.RtlStats and
    # the synthesized perf-counter bank; the observability differential
    # asserts all of them equal across levels
    group_cycles: Dict[str, int] = dataclasses.field(default_factory=dict)
    stall_port_cycles: int = 0       # par arms serialized behind siblings
    stall_pool_cycles: int = 0       # shared-pool waits (0 by construction)
    stall_ii_cycles: int = 0         # initiation-interval recurrence loss
    fsm_overhead_cycles: int = 0     # setup/iter/cond/pad/join states
    pipe_launches: int = 0

    def as_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


class _Sim:
    def __init__(self, comp: Component, prog: Program,
                 tracer: Optional[T.Tracer] = None):
        self.comp = comp
        self.prog = prog
        self.stats = SimStats()
        self.regs: Dict[str, float] = {}
        self.mems: Dict[str, np.ndarray] = {}
        self._env: Dict[str, int] = {}
        self._gstart = 0                       # active group's start cycle
        self._par_depth = 0                    # live par nesting depth
        self._pipe_depth = 0                   # live pipelined-loop depth
        # trace hook — None unless tracing; every emission site is guarded
        # so the off path allocates no events and no provenance tuples
        self._tr = tracer
        self._gprov: Tuple[str, ...] = ()      # active group's provenance
        self._ggroup = ""                      # active group's name
        self._pooled: Dict[str, List[str]] = {}
        # (mem, bank, cycle) -> (is_store, address-tuple).  Clashes can only
        # happen between accesses whose windows overlap — i.e. inside one
        # group or under a live par — so the table is cleared whenever the
        # schedule is provably past all stamped cycles (see run/_run_par),
        # bounding it to the widest concurrent window instead of the run.
        self._ports: Dict[Tuple[str, int, int], Tuple[bool, tuple]] = {}
        # memoization keyed by control-node identity (the tree is static)
        self._static: Dict[int, int] = {}
        self._components: Dict[int, List[List[int]]] = {}
        self._shared: Dict[int, FrozenSet[str]] = {}
        self._par_checked: Set[int] = set()

    # -- memory state ---------------------------------------------------------
    def init_mems(self, inputs: Dict[str, np.ndarray],
                  params: Dict[str, np.ndarray]) -> None:
        orig_shapes = self.prog.meta.get("orig_shapes", {})
        for name, decl in self.prog.mems.items():
            if decl.role in ("input", "param"):
                src = inputs[name] if decl.role == "input" else params[name]
                arr = np.asarray(src, dtype=np.float64)
                if decl.banks:
                    arr = pack_banked(arr.reshape(orig_shapes[name]),
                                      decl.banks)
                else:
                    arr = arr.reshape(decl.shape)
            else:
                arr = np.zeros(decl.shape, dtype=np.float64)
            self.mems[name] = arr.copy()

    def _locate(self, mem: str, idxs) -> Tuple[tuple, int]:
        vals = tuple(ix.evaluate(self._env) for ix in idxs)
        if self.prog.mems[mem].banks:
            return vals, int(vals[0])
        return vals, 0

    def _claim_port(self, mem: str, bank: int, cycle: int,
                    is_store: bool, addr: tuple) -> None:
        key = (mem, bank, cycle)
        prev = self._ports.get(key)
        if prev is None:
            self._ports[key] = (is_store, addr)
            return
        pstore, paddr = prev
        if not is_store and not pstore and paddr == addr:
            self.stats.broadcast_reads += 1   # one read port feeds both
            return
        # same stable codes the static verifier reports: RV022 when the
        # clash comes from overlapped pipelined iterations (an unsound II),
        # RV020 for a plain same-cycle port conflict
        code = "RV022" if self._pipe_depth > 0 else "RV020"
        raise SimError(
            f"[{code}] memory port violation on {mem} bank {bank} at cycle "
            f"{cycle}: {'write' if is_store else 'read'}@{addr} clashes with "
            f"{'write' if pstore else 'read'}@{paddr} — Calyx memories "
            f"accept one access per cycle")

    def _read_mem(self, u: D.UMemRead) -> float:
        vals, bank = self._locate(u.mem, u.idxs)
        self._claim_port(u.mem, bank, self._gstart + u.off, False, vals)
        self.stats.mem_reads += 1
        if self._tr is not None:
            self._tr.emit(self._gstart + u.off, T.PORT_GRANT, self._gprov,
                          self._ggroup, f"R:{u.mem}:b{bank}", data=vals)
        return float(self.mems[u.mem][vals])

    def _write_mem(self, u: D.UMemWrite, value: float) -> None:
        vals, bank = self._locate(u.mem, u.idxs)
        self._claim_port(u.mem, bank, self._gstart + u.off, True, vals)
        self.stats.mem_writes += 1
        if self._tr is not None:
            self._tr.emit(self._gstart + u.off, T.PORT_GRANT, self._gprov,
                          self._ggroup, f"W:{u.mem}:b{bank}", data=vals)
        self.mems[u.mem][vals] = value

    def _on_alu(self, u: D.UAlu) -> None:
        cell = self.comp.cells.get(u.cell)
        if cell is not None and cell.users > 1:
            self.stats.fu_grants[u.cell] = \
                self.stats.fu_grants.get(u.cell, 0) + 1

    def _on_uop(self, u: D.UOp) -> None:
        # trace hook (installed only when tracing): one event per issue
        self._tr.emit(self._gstart + D.uop_off(u), T.UOP, self._gprov,
                      self._ggroup, D.uop_detail(u))

    def _pooled_units(self, g) -> List[str]:
        """Shared pool cells the group invokes, in micro-op first-use
        order — the same order ``rtl.lower_component`` records in
        ``DpBlock.pooled_units``, so both simulators' ``pool:grant``
        events line up."""
        got = self._pooled.get(g.name)
        if got is None:
            got = []
            for u in g.uops:
                if isinstance(u, D.UAlu) and u.cell not in got:
                    cell = self.comp.cells.get(u.cell)
                    if cell is not None and cell.users > 1:
                        got.append(u.cell)
            self._pooled[g.name] = got
        return got

    # -- FSM scheduler --------------------------------------------------------
    def run(self, node: CNode, start: int,
            path: Tuple[str, ...] = ()) -> int:
        """Execute ``node`` beginning at absolute cycle ``start``; return
        the cycle at which its done signal rises.  ``path`` is the
        control-tree provenance chain (see ``core.trace``); it is only
        extended while tracing, so the off path allocates nothing."""
        tr = self._tr
        if isinstance(node, GEnable):
            g = self.comp.groups[node.group]
            if not g.uops:
                raise SimError(
                    f"[RV007] group {g.name} carries no micro-ops — the "
                    f"component was built without datapath semantics "
                    f"(re-lower with calyx.lower_program)")
            self.stats.group_activations += 1
            if self._par_depth == 0 and self._pipe_depth == 0:
                # sequential flow: earlier windows are strictly in the past
                self._ports.clear()
            self._gstart = start
            if self._pipe_depth == 0:
                # pipelined launches overlap; the loop accounts the union
                self.stats.group_cycles[g.name] = \
                    self.stats.group_cycles.get(g.name, 0) + g.latency
            on_uop = None
            if tr is not None:
                self._gprov = path + (g.name,)
                self._ggroup = g.name
                tr.emit(start, T.GROUP_START, self._gprov, g.name,
                        dur=g.latency)
                tr.emit(start + g.latency, T.GROUP_STOP, self._gprov,
                        g.name)
                for unit in self._pooled_units(g):
                    tr.emit(start, T.POOL_GRANT, self._gprov, g.name,
                            detail=unit, dur=g.latency)
                on_uop = self._on_uop
            self.stats.uops += D.execute(g.uops, self._env, self.regs,
                                         self._read_mem, self._write_mem,
                                         self._on_alu, on_uop)
            return start + g.latency
        if isinstance(node, CSeq):
            t = start
            if tr is None:
                for ch in node.children:
                    t = self.run(ch, t, path)
            else:
                for k, ch in enumerate(node.children):
                    t = self.run(ch, t, path + (T.seq_label(k),))
            return t
        if isinstance(node, CRepeat):
            lpath = path if tr is None else path + (T.loop_label(node.var),)
            if node.ii and node.extent > 0:
                # pipelined loop: iteration i launches at setup + i*ii and
                # its port claims are stamped at those absolute cycles —
                # overlapped windows coexist in the port table, so an
                # unsound initiation interval raises SimError instead of
                # silently mis-simulating the hardware
                g = self.comp.groups[node.body.group]  # body is one group
                self.stats.fsm_overhead_cycles += F.LOOP_SETUP_CYCLES
                self.stats.group_cycles[g.name] = \
                    self.stats.group_cycles.get(g.name, 0) \
                    + (node.extent - 1) * node.ii + g.latency
                self.stats.stall_ii_cycles += \
                    (node.extent - 1) * (node.ii - 1)
                self.stats.pipe_launches += node.extent
                if tr is not None:
                    tr.emit(start, T.STALL_FSM, lpath, detail="setup",
                            dur=F.LOOP_SETUP_CYCLES)
                t = start + F.LOOP_SETUP_CYCLES
                end = t
                self._pipe_depth += 1
                for i in range(node.extent):
                    if node.var:
                        self._env[node.var] = i
                    if tr is not None:
                        tr.emit(t, T.PIPE_LAUNCH, lpath, data=(i,))
                        if i and node.ii > 1:
                            tr.emit(t, T.STALL_II, lpath, dur=node.ii - 1,
                                    data=(i,))
                    end = max(end, self.run(node.body, t, lpath))
                    t += node.ii
                self._pipe_depth -= 1
                if self._par_depth == 0 and self._pipe_depth == 0:
                    self._ports.clear()    # drained: windows are past
                return end
            self.stats.fsm_overhead_cycles += \
                F.LOOP_SETUP_CYCLES + node.extent * F.LOOP_ITER_OVERHEAD
            if tr is not None:
                tr.emit(start, T.STALL_FSM, lpath, detail="setup",
                        dur=F.LOOP_SETUP_CYCLES)
            t = start + F.LOOP_SETUP_CYCLES
            for i in range(node.extent):
                if node.var:
                    self._env[node.var] = i
                t = self.run(node.body, t, lpath)
                if tr is not None:
                    tr.emit(t, T.STALL_FSM, lpath, detail="iter",
                            dur=F.LOOP_ITER_OVERHEAD)
                t += F.LOOP_ITER_OVERHEAD
            return t
        if isinstance(node, CIf):
            if node.cond is None:
                raise SimError("[RV005] if-node carries no condition — "
                           "component predates the executable lowering")
            body_start = start + node.cond_latency + F.IF_SELECT_CYCLES
            self.stats.fsm_overhead_cycles += \
                node.cond_latency + F.IF_SELECT_CYCLES
            taken_then = bool(node.cond.evaluate(self._env))
            taken = node.then if taken_then else node.els
            other = node.els if taken is node.then else node.then
            apath = path
            if tr is not None:
                ipath = path + (T.IF_LABEL,)
                tr.emit(start, T.STALL_FSM, ipath, detail="cond",
                        dur=node.cond_latency + F.IF_SELECT_CYCLES)
                apath = ipath + \
                    (T.THEN_LABEL if taken_then else T.ELSE_LABEL,)
            end = self.run(taken, body_start, apath)
            # statically-timed if: the FSM reserves the worst-case arm;
            # a shorter taken arm pads out the difference
            pad = body_start + self._static_cycles(other) - end
            if pad > 0:
                self.stats.fsm_overhead_cycles += pad
                if tr is not None:
                    tr.emit(end, T.STALL_FSM, apath, detail="pad", dur=pad)
                end += pad
            return end
        if isinstance(node, CPar):
            return self._run_par(node, start, path)
        raise TypeError(node)

    def _static_cycles(self, node: CNode) -> int:
        key = id(node)
        if key not in self._static:
            self._static[key] = estimator.cycles(self.comp, node)
        return self._static[key]

    def _run_par(self, node: CPar, start: int,
                 path: Tuple[str, ...] = ()) -> int:
        arms = node.children
        if not arms:
            return start
        self.stats.par_blocks += 1
        comps = self._components.get(id(node))
        if comps is None:
            comps = estimator.par_conflict_components(self.comp, node)
            self._components[id(node)] = comps
        self._check_fu_arbitration(node, comps)
        self._par_depth += 1
        tr = self._tr
        ppath = path if tr is None else path + (T.PAR_LABEL,)
        ends = []
        for members in comps:
            t = start                      # components start concurrently
            for p, i in enumerate(members):  # conflicting arms serialize
                apath = ppath if tr is None \
                    else ppath + (T.arm_label(i),)
                if p:
                    # this arm waited behind its port-conflicting siblings
                    wait = t - start
                    self.stats.stall_port_cycles += wait
                    if tr is not None and wait > 0:
                        tr.emit(start, T.STALL_PORT, apath, dur=wait,
                                data=(p,))
                t = self.run(arms[i], t, apath)
            self.stats.serialized_arms += len(members) - 1
            ends.append(t)
        self._par_depth -= 1
        if self._par_depth == 0 and self._pipe_depth == 0:
            self._ports.clear()            # everything stamped is now past
        join = estimator.par_join_cycles(len(arms))
        self.stats.fsm_overhead_cycles += join
        if tr is not None:
            tr.emit(max(ends), T.STALL_FSM, ppath, detail="join", dur=join)
        return max(ends) + join

    # -- shared-FU arbitration ------------------------------------------------
    def _subtree_shared_cells(self, node: CNode) -> FrozenSet[str]:
        key = id(node)
        got = self._shared.get(key)
        if got is not None:
            return got
        if isinstance(node, GEnable):
            out = frozenset(
                c for c in self.comp.groups[node.group].cells
                if c in self.comp.cells and self.comp.cells[c].users > 1)
        elif isinstance(node, (CSeq, CPar)):
            out = frozenset().union(
                *[self._subtree_shared_cells(ch) for ch in node.children]) \
                if node.children else frozenset()
        elif isinstance(node, CRepeat):
            out = self._subtree_shared_cells(node.body)
        elif isinstance(node, CIf):
            out = (self._subtree_shared_cells(node.then)
                   | self._subtree_shared_cells(node.els))
        else:
            raise TypeError(node)
        self._shared[key] = out
        return out

    def _check_fu_arbitration(self, node: CPar,
                              comps: List[List[int]]) -> None:
        """Concurrent components must not both own a shared pool cell.

        Arms inside one conflict component serialize, so they may reuse a
        pool cell across their (disjoint) windows; arms in *different*
        components overlap in time, and a pool cell reachable from two of
        them would need a second owner in the same cycle.  The structure
        is static, so each par node is checked once per run.
        """
        if id(node) in self._par_checked or len(comps) <= 1:
            self._par_checked.add(id(node))
            return
        self._par_checked.add(id(node))
        sets = [frozenset().union(
            *[self._subtree_shared_cells(node.children[i]) for i in members])
            for members in comps]
        for i in range(len(sets)):
            for j in range(i + 1, len(sets)):
                both = sets[i] & sets[j]
                if both:
                    raise SimError(
                        f"[RV021] shared cell(s) {sorted(both)} invoked "
                        f"from two concurrent par components — single-owner "
                        f"arbitration of shared functional units failed")


def simulate(comp: Component, prog: Program,
             inputs: Dict[str, np.ndarray],
             params: Dict[str, np.ndarray],
             tracer: Optional[T.Tracer] = None
             ) -> Tuple[Dict[str, np.ndarray], SimStats]:
    """Cycle-accurately execute ``comp`` (lowered from ``prog``).

    Returns the final memory state (banked layout, as declared by the
    program) and the measured :class:`SimStats`.  ``prog`` supplies the
    memory declarations/roles and the banked packing of inputs and params —
    the same staging a host performs before launching the accelerator.
    Pass a :class:`trace.Tracer` to record the canonical event trace
    (``core.trace``) at micro-op granularity; the default leaves every
    trace hook cold.
    """
    sim = _Sim(comp, prog, tracer)
    sim.init_mems(inputs, params)
    end = sim.run(comp.control, 0)
    sim.stats.cycles = end
    return sim.mems, sim.stats
