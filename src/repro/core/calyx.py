"""Calyx-like structural hardware IR and the Affine -> Calyx lowering.

Mirrors Calyx's split between *structure* (cells: registers, single-ported
memories, HardFloat units, address arithmetic) and *control* (seq / par /
if / repeat trees over group enables).  The lowering itself instantiates a
fresh cell per static operation — the paper's choice, and what makes
par-unrolled designs grow superlinearly; the downstream binding stage
(``sharing.share_cells``) then rebinds expensive units used by mutually
exclusive groups onto shared pools, so the emitted design pays for peak
concurrency rather than statement count.  ``Cell.users`` records how many
group-level uses a pooled cell serves (1 = private), which the estimator
turns into operand-mux overhead.

The lowering records, per group, the memory *port accesses* it performs;
the estimator uses those to model Calyx's one-access-per-cycle memory
constraint (conflicting parallel arms serialize — the behaviour that makes
unbanked parallelism worthless and banked parallelism near-linear).

Beyond the latency/cells/ports summary, every group now also carries its
executable datapath semantics as a micro-op list (``Group.uops``, see
``core.dataflow``): cell invocations with explicit operand routing,
register reads/writes, and memory accesses with concrete address
expressions and their in-group cycle offsets.  ``CIf`` keeps the lowered
affine condition.  Together these make the component *runnable* — the
cycle-accurate simulator (``core.sim``) executes exactly what was lowered
instead of re-interpreting the affine program.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

from . import dataflow as D
from . import float_lib as F
from .affine import (AExpr, Bin, Cond, ConstF, DivAtom, If, Load, Loop,
                     MemDecl, ModAtom, Par, Program, ReadReg, SelectC, SetReg,
                     Stmt, Store, Un, VExpr)

# ---------------------------------------------------------------------------
# Structure
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Cell:
    name: str
    kind: str                 # fp_add, fp_mul, ..., int_mul, int_divmod,
    words: int = 0            # mem_bank: capacity
    const: int = 0            # int_mul / int_divmod constant operand
    users: int = 1            # group-level uses bound to this cell (sharing)


@dataclasses.dataclass
class PortAccess:
    mem: str
    bank: Optional[int]       # None = runtime-selected bank
    key: Optional[tuple]      # structural address key; None = never shareable
    free_vars: frozenset      # loop vars the address depends on
    is_store: bool
    # Symbolic bank index for runtime-selected banks (layout mode where the
    # cyclic fold did not reach a constant).  The conflict model proves two
    # such accesses land on distinct physical banks when the per-digit
    # difference is a nonzero constant modulo the banking factor — the
    # "bank-affine" par analysis.  None when the bank is constant or the
    # expression depends on loop vars bound inside the subtree under test.
    bank_expr: Optional[AExpr] = None


@dataclasses.dataclass
class Group:
    name: str
    latency: int
    cells: List[str]
    ports: List[PortAccess]
    uops: List[D.UOp] = dataclasses.field(default_factory=list)


# ---------------------------------------------------------------------------
# Control
# ---------------------------------------------------------------------------


class CNode:
    pass


@dataclasses.dataclass
class GEnable(CNode):
    group: str


@dataclasses.dataclass
class CSeq(CNode):
    children: List[CNode]


@dataclasses.dataclass
class CPar(CNode):
    children: List[CNode]


@dataclasses.dataclass
class CRepeat(CNode):
    extent: int
    body: CNode
    var: str = ""
    # Initiation interval set by the pipelining pass (core.pipelining).
    # 0 = not pipelined (iterations run back to back with the per-iteration
    # overhead); ii > 0 = a new iteration launches every ``ii`` cycles and
    # iterations overlap:  cycles = setup + (extent-1)*ii + body_latency.
    # The estimator, the Calyx simulator, the RTL lowering, and the RTL
    # simulator all price/execute exactly this overlapped schedule.
    ii: int = 0


@dataclasses.dataclass
class CIf(CNode):
    cond_latency: int
    then: CNode
    els: CNode
    cond_cells: List[str] = dataclasses.field(default_factory=list)
    cond: Optional[Cond] = None   # lowered affine condition (simulation)


@dataclasses.dataclass
class Component:
    name: str
    cells: Dict[str, Cell]
    groups: Dict[str, Group]
    control: CNode
    meta: Dict[str, object] = dataclasses.field(default_factory=dict)


# ---------------------------------------------------------------------------
# Lowering
# ---------------------------------------------------------------------------


class _Lower:
    def __init__(self, prog: Program):
        self.prog = prog
        self.cells: Dict[str, Cell] = {}
        self.groups: Dict[str, Group] = {}
        self._n = 0
        self._tmp = 0            # per-group micro-op temporary counter

    def fresh(self, stem: str) -> str:
        self._n += 1
        return f"{stem}{self._n}"

    def tmp(self) -> int:
        t = self._tmp
        self._tmp += 1
        return t

    def add_cell(self, kind: str, words: int = 0, const: int = 0,
                 name: Optional[str] = None) -> str:
        name = name or self.fresh(kind)
        if name not in self.cells:
            self.cells[name] = Cell(name, kind, words, const)
        return name

    # -- address arithmetic ---------------------------------------------------
    def addr_cells_cycles(self, e: AExpr, cells: List[str]) -> int:
        """Instantiate const-mul / divmod units for one index expression.
        Returns extra cycles (iterative divmod only)."""
        cycles = 0
        nterms = len(e.coeffs)
        for atom, coeff in e.coeffs.items():
            mc = F.int_mul_cost(coeff)
            if mc.lut or mc.dsp:
                cells.append(self.add_cell("int_mul", const=coeff))
            if isinstance(atom, (DivAtom, ModAtom)):
                dc = F.int_divmod_cost(atom.c)
                if dc.cycles or dc.lut:
                    cells.append(self.add_cell("int_divmod", const=atom.c))
                    cycles += dc.cycles
                cycles += self.addr_cells_cycles(atom.inner, cells)
        if nterms > 1:
            for _ in range(nterms - 1):
                cells.append(self.add_cell("int_add"))
        return cycles

    # -- value expressions -----------------------------------------------------
    def vexpr(self, e: VExpr, cells: List[str], ports: List[PortAccess],
              uops: List[D.UOp], off: int) -> Tuple[int, int]:
        """Instantiate cells and record micro-ops; return (latency, temp).

        ``off`` is the cycle offset (within the enclosing group's window)
        at which this expression starts evaluating — memory micro-ops are
        stamped with the offset their port is actually busy, mirroring the
        latency arithmetic below.
        """
        if isinstance(e, ConstF):
            t = self.tmp()
            uops.append(D.UConst(t, e.value))
            return 0, t
        if isinstance(e, ReadReg):
            self.add_cell("reg32", name=f"reg_{e.name}")
            cells.append(f"reg_{e.name}")
            t = self.tmp()
            uops.append(D.URegRead(t, e.name))
            return 0, t
        if isinstance(e, Load):
            addr_cyc = self._access(e.mem, e.idxs, False, cells, ports)
            t = self.tmp()
            uops.append(D.UMemRead(t, e.mem, list(e.idxs), off + addr_cyc))
            return F.MEM_READ_CYCLES + addr_cyc, t
        if isinstance(e, Bin):
            kind = {"add": "fp_add", "sub": "fp_sub", "mul": "fp_mul",
                    "div": "fp_div", "max": "fp_max", "min": "fp_min"}[e.op]
            cname = self.add_cell(kind)
            cells.append(cname)
            a, ta = self.vexpr(e.a, cells, ports, uops, off)
            b, tb = self.vexpr(e.b, cells, ports, uops, off)
            t = self.tmp()
            uops.append(D.UAlu(t, e.op, ta, tb, cell=cname,
                               off=off + max(a, b)))
            return F.FLOAT_COSTS[kind].cycles + max(a, b), t
        if isinstance(e, Un):
            kind = {"exp": "fp_exp", "relu": "fp_relu", "neg": "fp_neg"}[e.op]
            cname = self.add_cell(kind)
            cells.append(cname)
            a, ta = self.vexpr(e.a, cells, ports, uops, off)
            t = self.tmp()
            uops.append(D.UAlu(t, e.op, ta, None, cell=cname, off=off + a))
            return F.FLOAT_COSTS[kind].cycles + a, t
        if isinstance(e, SelectC):
            cells.append(self.add_cell("mux"))
            cells.append(self.add_cell("cmp"))
            cond_cyc = self.addr_cells_cycles(e.cond.expr, cells)
            a, ta = self.vexpr(e.a, cells, ports, uops, off)
            b, tb = self.vexpr(e.b, cells, ports, uops, off)
            t = self.tmp()
            uops.append(D.USelect(t, e.cond, ta, tb,
                                  off=off + cond_cyc + max(a, b)))
            return F.IF_SELECT_CYCLES + cond_cyc + max(a, b), t
        raise TypeError(e)

    def _access(self, mem: str, idxs: Sequence[AExpr], is_store: bool,
                cells: List[str], ports: List[PortAccess]) -> int:
        decl = self.prog.mems[mem]
        cyc = 0
        for ix in idxs:
            cyc += self.addr_cells_cycles(ix, cells)
        if decl.banks:
            bank_e = idxs[0]
            bank = bank_e.const_value() if bank_e.is_const() else None
            key_exprs = idxs[1:]
        else:
            bank = 0
            key_exprs = idxs
        free = set()
        for ke in key_exprs:
            free |= ke.free_vars()
        bank_expr = None
        if decl.banks and not idxs[0].is_const():
            # runtime-selected bank: keep the intra-bank address key *and*
            # the symbolic bank expression so the conflict model can still
            # prove distinct-bank / same-bank facts (bank-affine par)
            bank_expr = idxs[0]
            free |= idxs[0].free_vars()
        key = tuple(ke.key() for ke in key_exprs)
        ports.append(PortAccess(mem, bank, key, frozenset(free), is_store,
                                bank_expr=bank_expr))
        return cyc

    # -- statements -------------------------------------------------------------
    def stmt(self, s: Stmt) -> CNode:
        if isinstance(s, Store):
            cells: List[str] = []
            ports: List[PortAccess] = []
            uops: List[D.UOp] = []
            self._tmp = 0
            lat, t = self.vexpr(s.value, cells, ports, uops, 0)
            waddr = self._access(s.mem, s.idxs, True, cells, ports)
            uops.append(D.UMemWrite(s.mem, list(s.idxs), t, off=lat + waddr))
            lat += waddr + F.MEM_WRITE_CYCLES
            g = self.fresh("st_")
            self.groups[g] = Group(g, lat, cells, ports, uops)
            return GEnable(g)
        if isinstance(s, SetReg):
            cells = []
            ports = []
            uops = []
            self._tmp = 0
            self.add_cell("reg32", name=f"reg_{s.name}")
            cells.append(f"reg_{s.name}")
            vlat, t = self.vexpr(s.value, cells, ports, uops, 0)
            uops.append(D.URegWrite(s.name, t, off=vlat))
            lat = max(1, vlat)
            g = self.fresh("sr_")
            self.groups[g] = Group(g, lat, cells, ports, uops)
            return GEnable(g)
        if isinstance(s, Loop):
            self.add_cell("idx_reg", name=f"idx_{s.var}")
            body = self.block(s.body)
            return CRepeat(s.extent, body, var=s.var)
        if isinstance(s, Par):
            return CPar([self.block(a) for a in s.arms])
        if isinstance(s, If):
            cells = []
            cond_cyc = self.addr_cells_cycles(s.cond.expr, cells)
            cells.append(self.add_cell("cmp"))
            return CIf(cond_cyc, self.block(s.then),
                       self.block(s.els), cond_cells=cells, cond=s.cond)
        raise TypeError(s)

    def block(self, stmts: List[Stmt]) -> CNode:
        nodes = [self.stmt(s) for s in stmts]
        if len(nodes) == 1:
            return nodes[0]
        return CSeq(nodes)

    def run(self) -> Component:
        # memory banks as cells
        for name, decl in self.prog.mems.items():
            if decl.banks:
                nbanks = decl.shape[0]
                words = 1
                for s in decl.shape[1:]:
                    words *= s
                for b in range(nbanks):
                    self.add_cell("mem_bank", words=words,
                                  name=f"mem_{name}_b{b}")
            else:
                self.add_cell("mem_bank", words=decl.size, name=f"mem_{name}")
        control = self.block(self.prog.body)
        meta = dict(self.prog.meta)
        # banking factors per logical memory — the conflict model and the
        # scheduling passes consult these for bank-affinity proofs
        meta["bank_factors"] = {name: tuple(decl.banks)
                                for name, decl in self.prog.mems.items()}
        comp = Component(self.prog.name, self.cells, self.groups, control,
                         meta=meta)
        return comp


def lower_program(prog: Program) -> Component:
    return _Lower(prog).run()


def referenced_groups(node: CNode) -> Set[str]:
    """Names of every group reachable from ``node`` — the liveness set the
    chaining pass filters to and the verifier's dead-group analysis uses."""
    out: Set[str] = set()

    def walk(n: CNode) -> None:
        if isinstance(n, GEnable):
            out.add(n.group)
        elif isinstance(n, (CSeq, CPar)):
            for ch in n.children:
                walk(ch)
        elif isinstance(n, CRepeat):
            walk(n.body)
        elif isinstance(n, CIf):
            walk(n.then)
            walk(n.els)

    walk(node)
    return out


# ---------------------------------------------------------------------------
# Text emission (futil-like) for debuggability
# ---------------------------------------------------------------------------


def emit_text(comp: Component) -> str:
    out: List[str] = [f"component {comp.name}() -> () {{", "  cells {"]
    for c in comp.cells.values():
        extra = f", words={c.words}" if c.kind == "mem_bank" else (
            f", const={c.const}" if c.const else "")
        shared = f"  // shared x{c.users}" if c.users > 1 else ""
        out.append(f"    {c.name} = {c.kind}(){extra};{shared}")
    out.append("  }")
    out.append("  groups {")
    for g in comp.groups.values():
        ports = " ".join(
            f"{'W' if p.is_store else 'R'}:{p.mem}[b={p.bank}]" for p in g.ports)
        bound = [c for c in g.cells
                 if c in comp.cells and comp.cells[c].users > 1]
        uses = f" uses {', '.join(bound)}" if bound else ""
        out.append(f"    group {g.name}<{g.latency}>{uses} {{ {ports} }}")
    out.append("  }")
    out.append("  control {")

    def emit(node: CNode, ind: int):
        pad = "  " * ind
        if isinstance(node, GEnable):
            out.append(f"{pad}{node.group};")
        elif isinstance(node, CSeq):
            out.append(f"{pad}seq {{")
            for ch in node.children:
                emit(ch, ind + 1)
            out.append(f"{pad}}}")
        elif isinstance(node, CPar):
            out.append(f"{pad}par {{")
            for ch in node.children:
                emit(ch, ind + 1)
            out.append(f"{pad}}}")
        elif isinstance(node, CRepeat):
            pipe = f" pipeline ii={node.ii}" if node.ii else ""
            out.append(f"{pad}repeat {node.extent}{pipe} "
                       f"/* {node.var} */ {{")
            emit(node.body, ind + 1)
            out.append(f"{pad}}}")
        elif isinstance(node, CIf):
            cond_cells = (f" with [{', '.join(node.cond_cells)}]"
                          if node.cond_cells else "")
            out.append(f"{pad}if <cond:{node.cond_latency}>{cond_cells} {{")
            emit(node.then, ind + 1)
            out.append(f"{pad}}} else {{")
            emit(node.els, ind + 1)
            out.append(f"{pad}}}")

    emit(comp.control, 2)
    out.append("  }")
    out.append("}")
    return "\n".join(out)
