"""Linalg-like tensor operation graph — the entry IR of the compiler.

This mirrors the role of the MLIR Linalg dialect in the paper's pipeline
(PyTorch --Allo--> Linalg).  A ``Graph`` is a list of ``TensorOp`` nodes in
topological order over named values.  Every op has a pure-jnp reference
semantics (see ``jax_backend.execute_graph``) and an affine lowering
(see ``affine.lower_graph``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

Shape = Tuple[int, ...]

# Op kinds understood by the whole pipeline.  Keep this list closed: each
# kind must have (a) a jnp reference, (b) an affine lowering.
OP_KINDS = (
    "input",      # graph input placeholder
    "param",      # trained parameter (weights/bias)
    "matmul",     # (M,K) @ (K,N) -> (M,N)
    "add",        # elementwise / broadcast-last-dim bias add
    "mul",        # elementwise multiply
    "scale",      # multiply by scalar constant
    "relu",
    "conv2d",     # (Cin,H,W) * (Cout,Cin,kh,kw) -> (Cout,H',W') unit stride
    "maxpool2d",  # (C,H,W) -> (C,H//ph,W//pw) window (ph,pw)
    "flatten",    # (…) -> (prod,)
    "reshape",
    "transpose",  # 2-D transpose
    "softmax",    # row-wise softmax over last dim of 2-D operand
    "causal_mask",# (S,S) scores -> masked scores (j<=i kept, else -inf)
)


@dataclasses.dataclass
class TensorOp:
    name: str                   # SSA value name this op defines
    kind: str
    inputs: List[str]           # names of operand values
    shape: Shape
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    dtype: str = "float32"

    def __post_init__(self):
        if self.kind not in OP_KINDS:
            raise ValueError(f"unknown op kind {self.kind!r}")


@dataclasses.dataclass
class Graph:
    """A straight-line tensor program."""

    ops: List[TensorOp] = dataclasses.field(default_factory=list)
    inputs: List[str] = dataclasses.field(default_factory=list)
    params: Dict[str, Any] = dataclasses.field(default_factory=dict)  # name -> np.ndarray
    outputs: List[str] = dataclasses.field(default_factory=list)
    name: str = "main"

    # ---- construction helpers -------------------------------------------------
    _counter: int = 0

    def _fresh(self, stem: str) -> str:
        self._counter += 1
        return f"{stem}_{self._counter}"

    def add_op(self, kind: str, inputs: Sequence[str], shape: Shape,
               attrs: Optional[Dict[str, Any]] = None, name: Optional[str] = None) -> str:
        name = name or self._fresh(kind)
        self.ops.append(TensorOp(name=name, kind=kind, inputs=list(inputs),
                                 shape=tuple(shape), attrs=dict(attrs or {})))
        return name

    def add_input(self, name: str, shape: Shape) -> str:
        self.ops.append(TensorOp(name=name, kind="input", inputs=[], shape=tuple(shape)))
        self.inputs.append(name)
        return name

    def add_param(self, name: str, value) -> str:
        self.ops.append(TensorOp(name=name, kind="param", inputs=[], shape=tuple(value.shape)))
        self.params[name] = value
        return name

    # ---- queries ---------------------------------------------------------------
    def op(self, name: str) -> TensorOp:
        for o in self.ops:
            if o.name == name:
                return o
        raise KeyError(name)

    def shape(self, name: str) -> Shape:
        return self.op(name).shape

    def topo_check(self) -> None:
        defined = set()
        for o in self.ops:
            for i in o.inputs:
                if i not in defined:
                    raise ValueError(f"op {o.name} uses {i} before definition")
            defined.add(o.name)

    def flops(self) -> int:
        """Useful-work FLOP count (the MODEL_FLOPS analogue for §Roofline)."""
        total = 0
        for o in self.ops:
            if o.kind == "matmul":
                m, k = self.shape(o.inputs[0])
                _, n = self.shape(o.inputs[1])
                total += 2 * m * k * n
            elif o.kind == "conv2d":
                cout, h, w = o.shape
                cin, kh, kw = o.attrs["cin"], o.attrs["kh"], o.attrs["kw"]
                total += 2 * cout * h * w * cin * kh * kw
            elif o.kind in ("add", "mul", "relu", "scale"):
                total += int(prod(o.shape))
            elif o.kind == "softmax":
                total += 4 * int(prod(o.shape))
            elif o.kind == "maxpool2d":
                total += int(prod(self.shape(o.inputs[0])))
        return total


def prod(xs: Sequence[int]) -> int:
    out = 1
    for x in xs:
        out *= int(x)
    return out


# ---------------------------------------------------------------------------
# Graph-building API used by the frontend tracer and by tests directly.
# ---------------------------------------------------------------------------

def matmul(g: Graph, a: str, b: str, name: Optional[str] = None) -> str:
    (m, k), (k2, n) = g.shape(a), g.shape(b)
    assert k == k2, f"matmul shape mismatch {g.shape(a)} @ {g.shape(b)}"
    return g.add_op("matmul", [a, b], (m, n), name=name)


def add(g: Graph, a: str, b: str) -> str:
    sa, sb = g.shape(a), g.shape(b)
    # broadcast bias over leading dims
    assert sa[-len(sb):] == sb or sa == sb, (sa, sb)
    return g.add_op("add", [a, b], sa)


def mul(g: Graph, a: str, b: str) -> str:
    assert g.shape(a) == g.shape(b)
    return g.add_op("mul", [a, b], g.shape(a))


def scale(g: Graph, a: str, c: float) -> str:
    return g.add_op("scale", [a], g.shape(a), attrs={"value": float(c)})


def relu(g: Graph, a: str) -> str:
    return g.add_op("relu", [a], g.shape(a))


def conv2d(g: Graph, x: str, w: str) -> str:
    cin, h, wd = g.shape(x)
    cout, cin2, kh, kw = g.shape(w)
    assert cin == cin2
    out = (cout, h - kh + 1, wd - kw + 1)
    return g.add_op("conv2d", [x, w], out, attrs={"cin": cin, "kh": kh, "kw": kw})


def maxpool2d(g: Graph, x: str, ph: int, pw: int) -> str:
    c, h, w = g.shape(x)
    return g.add_op("maxpool2d", [x], (c, h // ph, w // pw), attrs={"ph": ph, "pw": pw})


def flatten(g: Graph, x: str) -> str:
    return g.add_op("flatten", [x], (prod(g.shape(x)),))


def reshape(g: Graph, x: str, shape: Shape) -> str:
    assert prod(shape) == prod(g.shape(x))
    return g.add_op("reshape", [x], tuple(shape))


def transpose(g: Graph, x: str) -> str:
    m, n = g.shape(x)
    return g.add_op("transpose", [x], (n, m))


def softmax(g: Graph, x: str) -> str:
    assert len(g.shape(x)) == 2
    return g.add_op("softmax", [x], g.shape(x))


def causal_mask(g: Graph, x: str) -> str:
    s1, s2 = g.shape(x)
    assert s1 == s2
    return g.add_op("causal_mask", [x], g.shape(x))
