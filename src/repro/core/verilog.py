"""SystemVerilog emission from the structural RTL netlist.

``emit`` prints a :class:`rtl.Netlist` as one top-level module per
component plus the parameterized primitive modules it instantiates.  The
output is the contract the paper's toolchain ends on ("targets
synthesizable SystemVerilog"): the netlist level — controllers, muxes,
banks, handshakes — is structurally synthesizable, while the FP
primitive *cores* compute through simulation-only ``real`` arithmetic
(``$bitstoreal``/``$realtobits``/``$exp``) behind synthesizable pipeline
registers; synthesis requires dropping in HardFloat cores at the marked
point, exactly as the paper integrates them.  Everything obeys a strict
structural discipline enforced by :func:`lint` (and by the golden
tests):

* **deterministic** — byte-identical across runs for the same netlist
  (no timestamps, no id()s, insertion-order iteration only);
* **no behavioral shortcuts** — no ``#delay``, no ``initial`` blocks
  outside the memory-bank primitive's zero-init, no multi-ported
  arrays: every memory is a single-ported ``repro_mem_bank`` whose one
  port is arbitrated by a single ``always_comb`` per bank;
* **single-driver nets** — every signal is driven by exactly one
  ``assign``, one ``always`` block, or one instance output.

Structure of the emitted top module:

* a **go/done handshake** — the root FSM leaves idle when ``go`` rises
  and holds ``done`` until ``go`` falls;
* a **host port** — while idle, a word-wide host bus is muxed onto the
  memory banks so the harness can stage inputs/parameters and read
  results back (the staging ``rtl_sim.load``/``unload`` model);
* one ``always_ff`` **controller per FSM** (the root plus one child per
  ``par`` conflict component) with explicit state localparams, a shared
  down-counter, loop index counters, and condition branches;
* per-group **datapath blocks**: constant wires (IEEE-754 bit
  patterns), pipelined primitive instances with per-operand steering
  muxes (the ``rtl.OperandMux`` hardware of shared pool cells),
  synchronous read-capture registers, and write-port scheduling off the
  controller's cycle counter.

The floating-point primitive cores compute through SystemVerilog
``real`` arithmetic behind a pipeline of ``LATENCY`` register stages
mirroring ``float_lib`` exactly — bit-faithful to the f64 datapath the
simulators execute, but not themselves synthesizable; swapping the
cores for HardFloat (as the paper integrates) changes only the
primitive bodies, not the netlist or the controllers.

Pipelined loops (``FsmState.kind == "pipe"``, produced by
``core.pipelining``) emit a single controller state with a modulo-II
launch counter: the loop index increments every II cycles and every
datapath event guard matches ``(elapsed - offset) % II == 0`` within the
event's live window, with loop-index references in address expressions
rewound by ``offset // II`` stages (the index has advanced while the
access's iteration is still in flight).  Like the FP cores, the
*cross-stage value forwarding registers* a fully overlapped datapath
needs (per-stage copies of captured operands when II < body latency) are
part of the HardFloat-style drop-in: the emitted netlist carries the
schedule contract — launch cadence, port cadence, index rewind — that
``rtl_sim`` executes and verifies cycle-exactly.
"""
from __future__ import annotations

import math
import re
import struct
from typing import Dict, List, Optional, Tuple

from . import diagnostics
from .affine import AExpr, Cond, DivAtom, ModAtom, Var
from .rtl import (PROFILE_HOST_BANK, DpBlock, DpConst, DpMemRead,
                  DpMemWrite, DpRegRead, DpRegWrite, DpSelect, DpUnit,
                  Netlist)

DATA_W = 64


# ---------------------------------------------------------------------------
# Small emission helpers
# ---------------------------------------------------------------------------


def _f64_bits(value: float) -> str:
    """IEEE-754 bit pattern of a double as a SV literal."""
    bits = struct.unpack(">Q", struct.pack(">d", float(value)))[0]
    return f"64'h{bits:016x}"


def _sint(v: int) -> str:
    return f"-32'sd{-v}" if v < 0 else f"32'sd{v}"


def _sv_aexpr(e: AExpr, resolve) -> str:
    """Affine expression -> signed SV expression over index counters."""
    terms: List[str] = []
    for atom, co in e.coeffs.items():
        if isinstance(atom, Var):
            base = resolve(atom.name)
        elif isinstance(atom, DivAtom):
            base = f"({_sv_aexpr(atom.inner, resolve)} / {_sint(atom.c)})"
        elif isinstance(atom, ModAtom):
            base = f"({_sv_aexpr(atom.inner, resolve)} % {_sint(atom.c)})"
        else:                                 # pragma: no cover
            raise TypeError(atom)
        terms.append(base if co == 1 else f"({_sint(co)} * {base})")
    if e.const or not terms:
        terms.append(_sint(e.const))
    return "(" + " + ".join(terms) + ")"


_COND_OPS = {"le": "<=", "lt": "<", "eq": "==", "ge": ">=", "gt": ">"}


def _sv_cond(c: Cond, resolve) -> str:
    return f"({_sv_aexpr(c.expr, resolve)} {_COND_OPS[c.op]} 32'sd0)"


def _addr_width(words: int) -> int:
    return max(1, math.ceil(math.log2(max(words, 2))))


# ---------------------------------------------------------------------------
# Primitive modules
# ---------------------------------------------------------------------------

_BIN_CORE = {
    "fp_add": "ra + rb", "fp_sub": "ra - rb", "fp_mul": "ra * rb",
    "fp_div": "ra / rb",
    "fp_max": "(ra > rb) ? ra : rb", "fp_min": "(ra < rb) ? ra : rb",
}
_UN_CORE = {
    "fp_relu": "(ra > 0.0) ? ra : 0.0",
    "fp_neg": "-ra",
    "fp_exp": "$exp((ra > 700.0) ? 700.0 : ra)",
}


def _emit_fp_primitive(kind: str) -> List[str]:
    binary = kind in _BIN_CORE
    core = _BIN_CORE.get(kind) or _UN_CORE[kind]
    ports = ["  input  logic clk,",
             f"  input  logic [{DATA_W - 1}:0] a,"]
    if binary:
        ports.append(f"  input  logic [{DATA_W - 1}:0] b,")
    ports.append(f"  output logic [{DATA_W - 1}:0] y")
    out = [
        f"// {kind}: LATENCY-stage pipeline around a real-arithmetic core",
        f"// (HardFloat drop-in point: replace the core, keep the pipeline).",
        f"module repro_{kind} #(",
        "  parameter int LATENCY = 1",
        ") (",
        *ports,
        ");",
        "  real ra;",
    ]
    if binary:
        out.append("  real rb;")
    out.append(f"  logic [{DATA_W - 1}:0] pipe [0:LATENCY-1];")
    out.append("  always_comb begin")
    out.append("    ra = $bitstoreal(a);")
    if binary:
        out.append("    rb = $bitstoreal(b);")
    out.append("  end")
    out.append("  always_ff @(posedge clk) begin")
    out.append(f"    pipe[0] <= $realtobits({core});")
    out.append("    for (int i = 1; i < LATENCY; i++) begin")
    out.append("      pipe[i] <= pipe[i-1];")
    out.append("    end")
    out.append("  end")
    out.append("  assign y = pipe[LATENCY-1];")
    out.append("endmodule")
    out.append("")
    return out


def _emit_mem_bank() -> List[str]:
    return [
        "// Single-ported memory bank: one access per cycle, sync read.",
        "// The initial block below is memory init — the one behavioral",
        "// construct the lint allows (BRAM init is synthesizable).",
        "module repro_mem_bank #(",
        "  parameter int WORDS = 2,",
        "  parameter int AW = 1",
        ") (",
        "  input  logic clk,",
        "  input  logic en,",
        "  input  logic we,",
        "  input  logic [AW-1:0] addr,",
        f"  input  logic [{DATA_W - 1}:0] wdata,",
        f"  output logic [{DATA_W - 1}:0] rdata",
        ");",
        f"  logic [{DATA_W - 1}:0] store [0:WORDS-1];",
        "  initial begin",
        "    for (int i = 0; i < WORDS; i++) begin",
        f"      store[i] = {DATA_W}'d0;",
        "    end",
        "  end",
        "  always_ff @(posedge clk) begin",
        "    if (en) begin",
        "      if (we) begin",
        "        store[addr] <= wdata;",
        "      end",
        "      rdata <= store[addr];",
        "    end",
        "  end",
        "endmodule",
        "",
    ]


# ---------------------------------------------------------------------------
# Top-level emission
# ---------------------------------------------------------------------------


class _Emitter:
    def __init__(self, net: Netlist):
        self.net = net
        self.lines: List[str] = []
        # group -> owning fsm fid (for index resolution / counters)
        self.group_fid: Dict[str, int] = net.group_fids()
        # pipelined groups: group -> its `pipe` FsmState (launch cadence)
        self.pipe_of: Dict[str, object] = {}
        self.fsm_has_pipe: set = set()
        for f in net.fsms:
            for st in f.states:
                if st.kind == "pipe":
                    self.pipe_of[st.group] = st
                    self.fsm_has_pipe.add(f.fid)
        # unit -> users in grant order: (group, a_wire, b_wire)
        self.unit_users: Dict[str, List[Tuple[str, int, Optional[int]]]] = {}
        for blk in net.blocks.values():
            for op in blk.ops:
                if isinstance(op, DpUnit):
                    self.unit_users.setdefault(op.unit, []).append(
                        (blk.group, op.a, op.b))

    def w(self, line: str = "") -> None:
        self.lines.append(line)

    # -- naming ----------------------------------------------------------------
    def resolver(self, group_or_fid) -> "callable":
        fid = group_or_fid if isinstance(group_or_fid, int) \
            else self.group_fid[group_or_fid]

        def resolve(var: str) -> str:
            return self.net.resolve_index(fid, var).name
        return resolve

    def resolver_at(self, group: str, off: int) -> "callable":
        """Index resolver for a datapath event at in-body offset ``off``.

        For a pipelined group the loop index register free-runs (one
        increment per II) while iterations are still in flight, so an
        event belonging to iteration j observes the register at value
        ``j + off // II`` — references to the pipelined loop var are
        rewound by that stage count.  Other variables (and every
        non-pipelined group) resolve unchanged.
        """
        base = self.resolver(group)
        st = self.pipe_of.get(group)
        if st is None:
            return base
        var, _extent, ii, _lat = st.pipe
        rewind = off // ii
        if rewind == 0:
            return base

        def resolve(v: str) -> str:
            name = base(v)
            if v == var:
                return f"({name} - 32'sd{rewind})"
            return name
        return resolve

    def wire(self, group: str, n: int) -> str:
        return f"w_{group}_{n}"

    def state_lp(self, fid: int, idx: int) -> str:
        return f"F{fid}_S{idx}"

    def idle_lp(self, fid: int) -> str:
        return f"F{fid}_IDLE"

    def go_sig(self, fid: int) -> str:
        return "go" if self.net.fsms[fid].parent is None else f"fsm{fid}_go"

    # -- address plumbing -------------------------------------------------------
    def flat_addr(self, mem: str, idxs: List[AExpr]) -> AExpr:
        spec = self.net.mems[mem]
        addr_idxs = idxs[1:] if spec.banks else idxs
        flat = AExpr.const_(0)
        for ix, s in zip(addr_idxs, spec.row_strides()):
            flat = flat + ix * s
        return flat

    # -- sections ---------------------------------------------------------------
    def emit(self) -> str:
        net = self.net
        self.w("// Generated by repro.core.verilog — structural RTL for the")
        self.w(f"// component '{net.name}' lowered from the Calyx-like IR.")
        self.w("// Address arithmetic (const-multiply / divmod chains) is")
        self.w("// folded into index expressions; datapath FP units are")
        self.w("// pipelined primitives with float_lib latencies.")
        self.w("`default_nettype none")
        self.w()
        kinds = sorted({u.kind for u in net.units.values()
                        if u.kind in _BIN_CORE or u.kind in _UN_CORE})
        for kind in kinds:
            self.lines += _emit_fp_primitive(kind)
        self.lines += _emit_mem_bank()
        self._emit_top(kinds)
        self.w("`default_nettype wire")
        return "\n".join(self.lines) + "\n"

    def _emit_top(self, kinds: List[str]) -> None:
        net = self.net
        self.w(f"module {net.name} (")
        self.w("  input  logic clk,")
        self.w("  input  logic reset,")
        self.w("  input  logic go,")
        self.w("  output logic done,")
        self.w("  // host bus: stages tensors into the banks while idle")
        self.w("  input  logic host_we,")
        self.w("  input  logic [15:0] host_bank,")
        self.w("  input  logic [31:0] host_addr,")
        self.w(f"  input  logic [{DATA_W - 1}:0] host_wdata,")
        self.w(f"  output logic [{DATA_W - 1}:0] host_rdata")
        self.w(");")
        self._emit_state_localparams()
        self._emit_fsm_decls()
        self._emit_index_regs()
        self._emit_group_go()
        self._emit_regs_decl()
        if net.profile:
            self._emit_perf_counters()
        self._emit_units()
        self._emit_banks()
        self._emit_datapath()
        self._emit_reg_writes()
        self._emit_bank_port_mux()
        self._emit_host_rdata()
        self._emit_fsm_processes()
        self.w("endmodule")
        self.w()

    # .. controllers ............................................................
    def _emit_state_localparams(self) -> None:
        self.w()
        self.w("  // controller states (one FSM per par conflict component)")
        for f in self.net.fsms:
            parts = [f"{self.state_lp(f.fid, s.index)} = {s.index}"
                     for s in f.states]
            parts.append(f"{self.idle_lp(f.fid)} = {len(f.states)}")
            self.w(f"  localparam int {', '.join(parts)};")

    def _emit_fsm_decls(self) -> None:
        self.w()
        for f in self.net.fsms:
            self.w(f"  logic [31:0] fsm{f.fid}_state;")
            self.w(f"  logic [31:0] fsm{f.fid}_cnt;")
            if f.fid in self.fsm_has_pipe:
                self.w(f"  logic [31:0] fsm{f.fid}_pipe_cd;")
        for f in self.net.fsms:
            done_idx = next(s.index for s in f.states if s.kind == "done")
            self.w(f"  wire fsm{f.fid}_done = "
                   f"(fsm{f.fid}_state == {self.state_lp(f.fid, done_idx)});")
        # child go: asserted while the parent sits in the forking par state
        for f in self.net.fsms:
            for st in f.states:
                if st.kind == "par":
                    for cid in st.children:
                        self.w(f"  wire fsm{cid}_go = (fsm{f.fid}_state == "
                               f"{self.state_lp(f.fid, st.index)});")
        self.w("  assign done = fsm0_done;")
        self.w(f"  wire busy = (fsm0_state != {self.idle_lp(0)});")

    def _emit_index_regs(self) -> None:
        self.w()
        self.w("  // loop index counters (per controller — par arms that")
        self.w("  // reuse a source loop var own physically distinct regs)")
        for reg in self.net.index_regs.values():
            self.w(f"  logic signed [31:0] {reg.name};")

    def _emit_group_go(self) -> None:
        self.w()
        for f in self.net.fsms:
            for st in f.states:
                if st.kind in ("group", "pipe"):
                    self.w(f"  wire g_{st.group}_go = (fsm{f.fid}_state == "
                           f"{self.state_lp(f.fid, st.index)});")

    def _emit_regs_decl(self) -> None:
        if not self.net.regs:
            return
        self.w()
        self.w("  // data registers")
        for r in self.net.regs.values():
            self.w(f"  logic [{DATA_W - 1}:0] {r.name};")

    # .. perf-counter bank (profile builds) .....................................
    def _emit_perf_counters(self) -> None:
        """Synthesize the cycle-attribution counter bank (``net.profile``).

        One 64-bit counter per :class:`rtl.PerfCounter`, cleared on the
        go edge (idle -> run) and read over the existing host bus at bank
        ``PROFILE_HOST_BANK`` (see ``_emit_host_rdata``).  Every increment
        condition samples exactly the pre-edge state the netlist
        simulator's counter model (``rtl_sim._count_cycle``) evaluates,
        so hardware readings equal trace aggregates cycle-for-cycle:

        * ``total``        — ``busy && !done``;
        * ``group``        — the group's existing ``g_<g>_go`` enable;
        * ``stall_port``   — per-controller stall-weight mux over the
          serialized par-chain states, summed across controllers;
        * ``stall_pool``   — pairwise both-granted indicators over each
          shared pool's user groups (never fires when the binding
          invariant holds — the counter exists so silicon can falsify);
        * ``stall_ii``     — pipe state with launches outstanding while
          the modulo-II countdown is above one;
        * ``fsm_overhead`` — delay/cond-state residence plus par join
          reduction (par state with all child dones high).
        """
        net = self.net
        self.w()
        self.w("  // perf-counter bank (profile build): 64-bit counters,")
        self.w("  // cleared at go, read back at host_bank == "
               f"16'h{PROFILE_HOST_BANK:04x}")
        for c in net.counters:
            self.w(f"  logic [63:0] {c.name};")
        ovh_terms: List[str] = []      # control-state residence indicators
        stallw_terms: List[str] = []   # 32-bit per-controller stall weights
        iis_terms: List[str] = []      # pipe inter-launch wait indicators
        for f in net.fsms:
            def eq(st) -> str:
                return (f"(fsm{f.fid}_state == "
                        f"{self.state_lp(f.fid, st.index)})")
            delay = [eq(st) for st in f.states
                     if st.kind in ("delay", "cond")]
            if delay:
                wn = f"perf_fsm{f.fid}_ovh"
                self.w(f"  wire {wn} = {' || '.join(delay)};")
                ovh_terms.append(f"32'({wn})")
            for st in f.states:
                if st.kind == "par":
                    alldone = " && ".join(f"fsm{c}_done"
                                          for c in st.children)
                    wn = f"perf_fsm{f.fid}_join{st.index}"
                    self.w(f"  wire {wn} = {eq(st)} && {alldone};")
                    ovh_terms.append(f"32'({wn})")
                elif st.kind == "pipe" and st.pipe[2] > 1:
                    var, extent, _ii, _lat = st.pipe
                    reg = net.index_regs[(f.fid, var)]
                    wn = f"perf_fsm{f.fid}_iis{st.index}"
                    self.w(f"  wire {wn} = {eq(st)} && "
                           f"({reg.name} < 32'sd{extent - 1}) && "
                           f"(fsm{f.fid}_pipe_cd > 32'd1);")
                    iis_terms.append(f"32'({wn})")
            weighted = [st for st in f.states if st.stall_weight]
            if weighted:
                expr = "32'd0"
                for st in reversed(weighted):
                    expr = f"{eq(st)} ? 32'd{st.stall_weight} : {expr}"
                wn = f"perf_fsm{f.fid}_stallw"
                self.w(f"  wire [31:0] {wn} = {expr};")
                stallw_terms.append(wn)
        pool_terms: List[str] = []
        for unit, users in self.unit_users.items():
            groups: List[str] = []
            for g, _a, _b in users:
                if g not in groups:
                    groups.append(g)
            for i in range(len(groups)):
                for j in range(i + 1, len(groups)):
                    pool_terms.append(
                        f"32'(g_{groups[i]}_go && g_{groups[j]}_go)")
        for name, terms in (("perf_ovh_inc", ovh_terms),
                            ("perf_stallw_inc", stallw_terms),
                            ("perf_iis_inc", iis_terms),
                            ("perf_pool_inc", pool_terms)):
            if terms:
                self.w(f"  wire [31:0] {name} = {' + '.join(terms)};")
        steps = {"total": ("busy && !done", "64'd1"),
                 "stall_port": ("busy && !done" if stallw_terms else None,
                                "64'(perf_stallw_inc)"),
                 "stall_pool": ("busy && !done" if pool_terms else None,
                                "64'(perf_pool_inc)"),
                 "stall_ii": ("busy && !done" if iis_terms else None,
                              "64'(perf_iis_inc)"),
                 "fsm_overhead": ("busy && !done" if ovh_terms else None,
                                  "64'(perf_ovh_inc)")}
        clear = f"(fsm0_state == {self.idle_lp(0)}) && go"
        for c in net.counters:
            if c.kind == "group":
                cond, step = f"g_{c.group}_go", "64'd1"
            else:
                cond, step = steps[c.kind]
            self.w("  always_ff @(posedge clk) begin")
            self.w("    if (reset) begin")
            self.w(f"      {c.name} <= 64'd0;")
            self.w("    end")
            self.w(f"    else if ({clear}) begin")
            self.w(f"      {c.name} <= 64'd0;")
            self.w("    end")
            if cond is not None:
                self.w(f"    else if ({cond}) begin")
                self.w(f"      {c.name} <= {c.name} + {step};")
                self.w("    end")
            self.w("  end")

    # .. datapath units .........................................................
    def _emit_units(self) -> None:
        net = self.net
        fp_units = [u for u in net.units.values()
                    if u.kind in _BIN_CORE or u.kind in _UN_CORE]
        if not fp_units:
            return
        self.w()
        self.w("  // datapath units (shared pool cells carry operand muxes)")
        for u in fp_units:
            users = self.unit_users.get(u.name, [])
            binary = u.kind in _BIN_CORE
            self.w(f"  logic [{DATA_W - 1}:0] {u.name}_a;")
            if binary:
                self.w(f"  logic [{DATA_W - 1}:0] {u.name}_b;")
            self.w(f"  logic [{DATA_W - 1}:0] {u.name}_y;")
            conns = [f".clk(clk)", f".a({u.name}_a)"]
            if binary:
                conns.append(f".b({u.name}_b)")
            conns.append(f".y({u.name}_y)")
            self.w(f"  repro_{u.kind} #(.LATENCY({max(1, u.latency)})) "
                   f"u_{u.name} ({', '.join(conns)});")
            if not users:
                self.w(f"  always_comb begin")
                self.w(f"    {u.name}_a = {DATA_W}'d0;")
                if binary:
                    self.w(f"    {u.name}_b = {DATA_W}'d0;")
                self.w("  end")
                continue
            # operand steering: priority mux over the granted groups
            self.w("  always_comb begin")
            self.w(f"    {u.name}_a = {DATA_W}'d0;")
            if binary:
                self.w(f"    {u.name}_b = {DATA_W}'d0;")
            kw = "if"
            for group, aw, bw in users:
                self.w(f"    {kw} (g_{group}_go) begin")
                self.w(f"      {u.name}_a = {self.wire(group, aw)};")
                if binary and bw is not None:
                    self.w(f"      {u.name}_b = {self.wire(group, bw)};")
                self.w("    end")
                kw = "else if"
            self.w("  end")

    # .. memory banks ...........................................................
    def _emit_banks(self) -> None:
        self.w()
        self.w("  // single-ported memory banks")
        for bank in self.net.banks.values():
            aw = _addr_width(bank.words)
            for sig, width in (("en", None), ("we", None),
                               ("addr", aw), ("wdata", DATA_W),
                               ("rdata", DATA_W)):
                decl = "logic" if width is None else f"logic [{width - 1}:0]"
                self.w(f"  {decl} {bank.name}_{sig};")
            self.w(f"  repro_mem_bank #(.WORDS({bank.words}), .AW({aw})) "
                   f"u_{bank.name} (.clk(clk), .en({bank.name}_en), "
                   f".we({bank.name}_we), .addr({bank.name}_addr), "
                   f".wdata({bank.name}_wdata), .rdata({bank.name}_rdata));")

    # .. per-group datapath ......................................................
    def _cnt_cond(self, group: str, off: int) -> str:
        """Counter match for the cycle `off` of the group's window.

        Plain groups match one counter value.  Pipelined groups (enabled
        from a ``pipe`` state) fire the event once per launched
        iteration: every II cycles inside the event's live window
        ``[latency - off, residence - off]`` of the down-counter.
        """
        fid = self.group_fid[group]
        blk = self.net.blocks[group]
        off = min(off, blk.latency - 1)
        st = self.pipe_of.get(group)
        if st is None:
            k = max(1, blk.latency - off)
            return f"g_{group}_go && (fsm{fid}_cnt == 32'd{k})"
        _var, _extent, ii, lat = st.pipe
        hi = st.cycles - off              # iteration 0's event
        lo = max(1, lat - off)            # iteration extent-1's event
        return (f"g_{group}_go && (fsm{fid}_cnt <= 32'd{hi})"
                f" && (fsm{fid}_cnt >= 32'd{lo})"
                f" && (((32'd{hi} - fsm{fid}_cnt) % 32'd{ii}) == 32'd0)")

    def _rdata_mux(self, mem: str, idxs: List[AExpr], resolve) -> str:
        spec = self.net.mems[mem]
        if not spec.banks:
            return f"{spec.bank_names[0]}_rdata"
        bank_e = idxs[0]
        if bank_e.is_const():
            return f"{spec.bank_names[bank_e.const_value()]}_rdata"
        sel = _sv_aexpr(bank_e, resolve)
        expr = f"{spec.bank_names[-1]}_rdata"
        for b in range(len(spec.bank_names) - 2, -1, -1):
            expr = (f"(({sel} == {_sint(b)}) ? "
                    f"{spec.bank_names[b]}_rdata : {expr})")
        return expr

    def _emit_datapath(self) -> None:
        self.w()
        self.w("  // group datapath blocks (SSA wires per activation)")
        for blk in self.net.blocks.values():
            resolve = self.resolver(blk.group)
            for op in blk.ops:
                if isinstance(op, DpConst):
                    self.w(f"  wire [{DATA_W - 1}:0] "
                           f"{self.wire(blk.group, op.dst)} = "
                           f"{_f64_bits(op.value)};  // {op.value!r}")
                elif isinstance(op, DpRegRead):
                    self.w(f"  wire [{DATA_W - 1}:0] "
                           f"{self.wire(blk.group, op.dst)} = "
                           f"reg_{op.reg};")
                elif isinstance(op, DpMemRead):
                    # the bank is a sync-read RAM: the address goes out at
                    # in-group cycle `off` (counter == latency - off) and
                    # rdata holds the word one cycle later — capture then,
                    # not at the address edge (which would latch the
                    # previous read).  A read completing at the group's
                    # last cycle has no later edge inside the window, so
                    # it aliases rdata combinationally instead.  Pipelined
                    # groups re-capture once per launched iteration (the
                    # modulo-II guard in _cnt_cond).
                    wn = self.wire(blk.group, op.dst)
                    k = blk.latency - op.off - 1
                    if k >= 1:
                        # the select is evaluated at the *capture* cycle
                        # (off+1), when a pipelined loop's free-running
                        # index has possibly advanced a stage past the
                        # address cycle — rewind for off+1, not off
                        at = self.resolver_at(blk.group, op.off + 1)
                        rdata = self._rdata_mux(op.mem, op.idxs, at)
                        capture = self._cnt_cond(blk.group, op.off + 1)
                        self.w(f"  logic [{DATA_W - 1}:0] {wn};")
                        self.w(f"  always_ff @(posedge clk) begin")
                        self.w(f"    if ({capture}) begin")
                        self.w(f"      {wn} <= {rdata};")
                        self.w("    end")
                        self.w("  end")
                    else:
                        at = self.resolver_at(blk.group, op.off)
                        rdata = self._rdata_mux(op.mem, op.idxs, at)
                        self.w(f"  wire [{DATA_W - 1}:0] {wn} = {rdata};")
                elif isinstance(op, DpUnit):
                    self.w(f"  wire [{DATA_W - 1}:0] "
                           f"{self.wire(blk.group, op.dst)} = "
                           f"{op.unit}_y;")
                elif isinstance(op, DpSelect):
                    at = self.resolver_at(blk.group, op.off)
                    self.w(f"  wire [{DATA_W - 1}:0] "
                           f"{self.wire(blk.group, op.dst)} = "
                           f"{_sv_cond(op.cond, at)} ? "
                           f"{self.wire(blk.group, op.a)} : "
                           f"{self.wire(blk.group, op.b)};")
                # reg/mem writes are emitted by the dedicated muxes below

    def _emit_reg_writes(self) -> None:
        # collect writers per register, in block order
        writers: Dict[str, List[Tuple[str, int, int]]] = {}
        for blk in self.net.blocks.values():
            for op in blk.ops:
                if isinstance(op, DpRegWrite):
                    writers.setdefault(op.reg, []).append(
                        (blk.group, op.src, op.off))
        if not writers:
            return
        self.w()
        self.w("  // register write-back (one driver block per register;")
        self.w("  // each write latches at its scheduled in-group offset)")
        for reg, uses in writers.items():
            self.w("  always_ff @(posedge clk) begin")
            kw = "if"
            # reversed: when clamping lands two same-group writes on one
            # cycle, the priority chain resolves to the later micro-op
            for group, src, off in reversed(uses):
                self.w(f"    {kw} ({self._cnt_cond(group, off)}) begin")
                self.w(f"      reg_{reg} <= {self.wire(group, src)};")
                self.w("    end")
                kw = "else if"
            self.w("  end")

    def _emit_bank_port_mux(self) -> None:
        net = self.net
        # bank -> ordered accesses: (guard, we, addr expr, wdata or None)
        accesses: Dict[str, List[Tuple[str, bool, str, Optional[str]]]] = \
            {bn: [] for bn in net.banks}
        for blk in net.blocks.values():
            for op in blk.ops:
                if not isinstance(op, (DpMemRead, DpMemWrite)):
                    continue
                resolve = self.resolver_at(blk.group, op.off)
                spec = net.mems[op.mem]
                flat = _sv_aexpr(self.flat_addr(op.mem, op.idxs), resolve)
                base_guard = f"({self._cnt_cond(blk.group, op.off)})"
                is_store = isinstance(op, DpMemWrite)
                wdata = self.wire(blk.group, op.src) if is_store else None
                if not spec.banks:
                    targets = [(spec.bank_names[0], base_guard)]
                elif op.idxs[0].is_const():
                    bn = spec.bank_names[op.idxs[0].const_value()]
                    targets = [(bn, base_guard)]
                else:
                    sel = _sv_aexpr(op.idxs[0], resolve)
                    targets = [
                        (bn, f"{base_guard} && ({sel} == {_sint(b)})")
                        for b, bn in enumerate(spec.bank_names)]
                for bn, guard in targets:
                    accesses[bn].append((guard, is_store, flat, wdata))
        self.w()
        self.w("  // bank port arbitration: host while idle, then the one")
        self.w("  // scheduled access per cycle (port discipline)")
        flat_banks = list(net.banks.values())
        for k, bank in enumerate(flat_banks):
            aw = _addr_width(bank.words)
            self.w("  always_comb begin")
            self.w(f"    {bank.name}_en = 1'b0;")
            self.w(f"    {bank.name}_we = 1'b0;")
            self.w(f"    {bank.name}_addr = {aw}'d0;")
            self.w(f"    {bank.name}_wdata = {DATA_W}'d0;")
            self.w(f"    if (!busy && (host_bank == 16'd{k})) begin")
            self.w(f"      {bank.name}_en = 1'b1;")
            self.w(f"      {bank.name}_we = host_we;")
            self.w(f"      {bank.name}_addr = host_addr[{aw - 1}:0];")
            self.w(f"      {bank.name}_wdata = host_wdata;")
            self.w("    end")
            for guard, is_store, addr, wdata in accesses[bank.name]:
                self.w(f"    else if ({guard}) begin")
                self.w(f"      {bank.name}_en = 1'b1;")
                if is_store:
                    self.w(f"      {bank.name}_we = 1'b1;")
                    self.w(f"      {bank.name}_wdata = {wdata};")
                self.w(f"      {bank.name}_addr = {aw}'({addr});")
                self.w("    end")
            self.w("  end")

    def _emit_host_rdata(self) -> None:
        self.w()
        self.w("  always_comb begin")
        self.w(f"    host_rdata = {DATA_W}'d0;")
        kw = "if"
        if self.net.profile:
            # the perf-counter bank answers on a reserved bank id; unlike
            # the memory banks it reads plain registers, so the host may
            # read it at any time (including while busy)
            self.w(f"    if (host_bank == 16'h{PROFILE_HOST_BANK:04x}) "
                   "begin")
            ikw = "if"
            for c in self.net.counters:
                self.w(f"      {ikw} (host_addr == 32'd{c.index}) begin")
                self.w(f"        host_rdata = {c.name};")
                self.w("      end")
                ikw = "else if"
            self.w("    end")
            kw = "else if"
        for k, bank in enumerate(self.net.banks.values()):
            self.w(f"    {kw} (host_bank == 16'd{k}) begin")
            self.w(f"      host_rdata = {bank.name}_rdata;")
            self.w("    end")
            kw = "else if"
        self.w("  end")

    # .. FSM processes ..........................................................
    def _enter(self, f, target: int, pad: str) -> List[str]:
        """Statements entering state ``target`` of fsm ``f``."""
        st = f.states[target]
        out = [f"{pad}fsm{f.fid}_state <= {self.state_lp(f.fid, target)};"]
        if st.kind == "par":
            out.append(f"{pad}fsm{f.fid}_cnt <= 32'd{st.join_cycles};")
        elif st.kind != "done":
            out.append(f"{pad}fsm{f.fid}_cnt <= 32'd{st.cycles};")
        if st.kind == "pipe":
            out.append(f"{pad}fsm{f.fid}_pipe_cd <= 32'd{st.pipe[2]};")
        if st.set_idx is not None:
            reg = self.net.index_regs[(f.fid, st.set_idx)]
            out.append(f"{pad}{reg.name} <= 32'sd0;")
        return out

    def _emit_fsm_processes(self) -> None:
        for f in self.net.fsms:
            go = self.go_sig(f.fid)
            resolve = self.resolver(f.fid)
            self.w()
            self.w(f"  // controller fsm{f.fid}"
                   + (" (root)" if f.parent is None
                      else f" (forked by fsm{f.parent})"))
            self.w("  always_ff @(posedge clk) begin")
            self.w("    if (reset) begin")
            self.w(f"      fsm{f.fid}_state <= {self.idle_lp(f.fid)};")
            self.w(f"      fsm{f.fid}_cnt <= 32'd0;")
            self.w("    end")
            self.w("    else begin")
            self.w(f"      case (fsm{f.fid}_state)")
            self.w(f"        {self.idle_lp(f.fid)}: begin")
            self.w(f"          if ({go}) begin")
            for ln in self._enter(f, f.start, "            "):
                self.w(ln)
            self.w("          end")
            self.w("        end")
            for st in f.states:
                lp = self.state_lp(f.fid, st.index)
                if st.kind == "done":
                    self.w(f"        {lp}: begin")
                    self.w(f"          if (!{go}) begin")
                    self.w(f"            fsm{f.fid}_state <= "
                           f"{self.idle_lp(f.fid)};")
                    self.w("          end")
                    self.w("        end")
                    continue
                if st.kind == "pipe":
                    # pipelined loop: the down-counter spans the whole
                    # residence; a modulo-II launch counter advances the
                    # (free-running) loop index once per initiation
                    # interval — datapath guards rewind it per stage
                    var, _extent, ii, _lat = st.pipe
                    reg = self.net.index_regs[(f.fid, var)]
                    self.w(f"        {lp}: begin")
                    self.w(f"          if (fsm{f.fid}_cnt <= 32'd1) begin")
                    for ln in self._enter(f, st.next, "            "):
                        self.w(ln)
                    self.w("          end")
                    self.w("          else begin")
                    self.w(f"            fsm{f.fid}_cnt <= "
                           f"fsm{f.fid}_cnt - 32'd1;")
                    self.w(f"            if (fsm{f.fid}_pipe_cd <= 32'd1) "
                           f"begin")
                    self.w(f"              {reg.name} <= "
                           f"{reg.name} + 32'sd1;")
                    self.w(f"              fsm{f.fid}_pipe_cd <= 32'd{ii};")
                    self.w("            end")
                    self.w("            else begin")
                    self.w(f"              fsm{f.fid}_pipe_cd <= "
                           f"fsm{f.fid}_pipe_cd - 32'd1;")
                    self.w("            end")
                    self.w("          end")
                    self.w("        end")
                    continue
                self.w(f"        {lp}: begin")
                if st.kind == "par":
                    alldone = " && ".join(f"fsm{c}_done" for c in st.children)
                    self.w(f"          if ({alldone}) begin")
                    self.w(f"            if (fsm{f.fid}_cnt <= 32'd1) begin")
                    for ln in self._enter(f, st.next, "              "):
                        self.w(ln)
                    self.w("            end")
                    self.w("            else begin")
                    self.w(f"              fsm{f.fid}_cnt <= "
                           f"fsm{f.fid}_cnt - 32'd1;")
                    self.w("            end")
                    self.w("          end")
                    self.w("        end")
                    continue
                self.w(f"          if (fsm{f.fid}_cnt <= 32'd1) begin")
                pad = "            "
                if st.inc_idx is not None:
                    reg = self.net.index_regs[(f.fid, st.inc_idx)]
                    self.w(f"{pad}{reg.name} <= {reg.name} + 32'sd1;")
                if st.kind == "cond":
                    self.w(f"{pad}if ({_sv_cond(st.cond, resolve)}) begin")
                    for ln in self._enter(f, st.then_state, pad + "  "):
                        self.w(ln)
                    self.w(f"{pad}end")
                    self.w(f"{pad}else begin")
                    for ln in self._enter(f, st.else_state, pad + "  "):
                        self.w(ln)
                    self.w(f"{pad}end")
                elif st.loop is not None:
                    var, extent, head = st.loop
                    reg = self.net.index_regs[(f.fid, var)]
                    self.w(f"{pad}if ({reg.name} + 32'sd1 < "
                           f"32'sd{extent}) begin")
                    for ln in self._enter(f, head, pad + "  "):
                        self.w(ln)
                    self.w(f"{pad}end")
                    self.w(f"{pad}else begin")
                    for ln in self._enter(f, st.next, pad + "  "):
                        self.w(ln)
                    self.w(f"{pad}end")
                else:
                    for ln in self._enter(f, st.next, pad):
                        self.w(ln)
                self.w("          end")
                self.w("          else begin")
                self.w(f"            fsm{f.fid}_cnt <= "
                       f"fsm{f.fid}_cnt - 32'd1;")
                self.w("          end")
                self.w("        end")
            self.w("        default: begin")
            self.w(f"          fsm{f.fid}_state <= {self.idle_lp(f.fid)};")
            self.w("        end")
            self.w("      endcase")
            self.w("    end")
            self.w("  end")


def emit(net: Netlist) -> str:
    """Emit the netlist as deterministic, synthesizable SystemVerilog."""
    return _Emitter(net).emit()


# ---------------------------------------------------------------------------
# Structural lint — the no-behavioral-shortcuts contract, enforced
# ---------------------------------------------------------------------------

_DELAY_RE = re.compile(r"#\s*\d")
_MODULE_RE = re.compile(r"^\s*module\s+(\w+)")
_ASSIGN_RE = re.compile(r"^\s*assign\s+(\w+)")
_WIRE_ASSIGN_RE = re.compile(r"^\s*wire\s+(?:\[[^\]]*\]\s*)?(\w+)\s*=")
_LHS_RE = re.compile(r"^\s*(\w+)(?:\[[^\]]*\])?\s*(<=|=)\s")
_KEYWORDS = frozenset({
    "if", "else", "case", "endcase", "begin", "end", "for", "always_ff",
    "always_comb", "module", "endmodule", "input", "output", "inout",
    "logic", "wire", "real", "localparam", "parameter", "assign",
    "initial", "default", "int", "typedef",
})

MEM_INIT_MODULE = "repro_mem_bank"


def lint_diagnostics(text: str) -> List["diagnostics.Diagnostic"]:
    """Check the emitted SystemVerilog for behavioral constructs.

    Returns structured :class:`~.diagnostics.Diagnostic` findings (empty =
    clean) with the verifier's stable codes:

    * ``RV040`` — ``#<n>`` delay controls anywhere;
    * ``RV041`` — ``initial`` blocks outside the memory-bank primitive
      (memory init is the one allowed use);
    * ``RV042`` — multi-driver nets: a signal assigned from more than one
      ``assign`` / ``always`` block within a module.

    :func:`lint` is the original plain-string face of the same checks.
    """
    errors: List[diagnostics.Diagnostic] = []

    def err(code: str, message: str, *prov: str) -> None:
        errors.append(diagnostics.diag(code, message, stage="verilog-lint",
                                       provenance=prov))
    module = ""
    always_depth = 0           # begin/end nesting inside an always block
    in_always = False
    in_initial = False         # memory init writes are not drivers
    block_id = 0
    drivers: Dict[Tuple[str, str], set] = {}

    def note(sig: str, driver: str) -> None:
        drivers.setdefault((module, sig), set()).add(driver)

    for ln, raw in enumerate(text.splitlines(), 1):
        line = raw.split("//", 1)[0]
        if not line.strip():
            continue
        m = _MODULE_RE.match(line)
        if m:
            module = m.group(1)
            in_always = False
            always_depth = 0
        if _DELAY_RE.search(line):
            err("RV040", f"line {ln}: delay control in {module}: "
                f"{raw.strip()}", f"module:{module}", f"line:{ln}")
        if re.search(r"\binitial\b", line) and module != MEM_INIT_MODULE:
            err("RV041", f"line {ln}: initial block outside memory init "
                f"({module}): {raw.strip()}", f"module:{module}",
                f"line:{ln}")
        stripped = line.strip()
        if stripped.startswith(("always_ff", "always_comb", "initial")):
            in_always = True
            in_initial = stripped.startswith("initial")
            always_depth = 0
            block_id += 1
        if in_always:
            always_depth += len(re.findall(r"\bbegin\b", line))
            always_depth -= len(re.findall(r"\bend\b", line))
            lm = _LHS_RE.match(line)
            if lm and lm.group(1) not in _KEYWORDS and not in_initial:
                note(lm.group(1), f"always#{block_id}")
            if always_depth <= 0 and re.search(r"\bend\b", line):
                in_always = False
                in_initial = False
            continue
        am = _ASSIGN_RE.match(line)
        if am:
            note(am.group(1), f"assign@{ln}")
            continue
        wm = _WIRE_ASSIGN_RE.match(line)
        if wm:
            note(wm.group(1), f"wire@{ln}")
    for (mod, sig), drvs in drivers.items():
        if len(drvs) > 1:
            err("RV042", f"multi-driver net {sig} in {mod}: "
                f"{sorted(drvs)}", f"module:{mod}", f"net:{sig}")
    return errors


def lint(text: str) -> List[str]:
    """Plain-string shim over :func:`lint_diagnostics` (kept for existing
    callers/tests): one message per finding, empty = clean."""
    return [d.message for d in lint_diagnostics(text)]
