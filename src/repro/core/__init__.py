"""Core compiler: the paper's PyTorch -> Calyx pipeline, plus binding.

  frontend  : torch-like tracing        (PyTorch -> Allo)
  tensor_ir : Linalg-like tensor graph  (Allo -> Linalg)
  affine    : loop-nest IR + interpreter(Linalg -> Affine/SCF)
  schedule  : par materialization + par/seq restructuring
  banking   : cyclic memory partitioning (layout-embedded vs branchy)
  calyx     : structural hardware IR    (CIRCT -> Calyx)
  chaining  : operation chaining / group fusion      (opt_level >= 1)
  pipelining: loop pipelining with static IIs        (opt_level >= 2)
  sharing   : resource binding onto shared functional-unit pools
  estimator : cycles / resources / timing
  rtl       : Calyx -> FSM + datapath netlist (structural RTL)
  verilog   : netlist -> synthesizable SystemVerilog
  rtl_sim   : cycle-driven two-state execution of the netlist
"""
from .pipeline import CompiledDesign, compile_graph, compile_model  # noqa: F401
from .banking import BankingSpec, BankConflictError  # noqa: F401
from .sharing import SharingReport, share_cells  # noqa: F401
from .rtl import Netlist, lower_component  # noqa: F401
