"""Loop scheduling passes: strip-mine + unroll `par`, and the paper's
par(seq) -> seq(par) restructuring.

The paper (§3.3) makes two scheduling contributions we reproduce exactly:

1. Parallelization is materialized by strip-mining a loop by the banking
   factor and *fully unrolling* the inner strip into `par` arms, so every
   arm sees statically-known indices (``i = c*ii + a`` with constant ``a``).

2. ``par(j){ seq(i){...} }`` duplicates one sequential controller per arm;
   the pass rewrites it to ``seq(i){ par(j){...} }`` which shares a single
   controller — semantically equal in software, much cheaper in hardware.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from .affine import (AExpr, Bin, Cond, ConstF, If, Load, Loop, Par, Program,
                     ReadReg, SelectC, SetReg, Stmt, Store, Un, VExpr)

# ---------------------------------------------------------------------------
# Cloning with substitution (loop-var -> expr, reg renaming)
# ---------------------------------------------------------------------------


def clone_vexpr(e: VExpr, env: Dict[str, AExpr], regmap: Dict[str, str]) -> VExpr:
    if isinstance(e, ConstF):
        return ConstF(e.value)
    if isinstance(e, Load):
        return Load(e.mem, [ix.substitute(env) for ix in e.idxs])
    if isinstance(e, ReadReg):
        return ReadReg(regmap.get(e.name, e.name))
    if isinstance(e, Bin):
        return Bin(e.op, clone_vexpr(e.a, env, regmap), clone_vexpr(e.b, env, regmap))
    if isinstance(e, Un):
        return Un(e.op, clone_vexpr(e.a, env, regmap))
    if isinstance(e, SelectC):
        return SelectC(e.cond.substitute(env),
                       clone_vexpr(e.a, env, regmap),
                       clone_vexpr(e.b, env, regmap))
    raise TypeError(e)


def clone_stmts(stmts: List[Stmt], env: Dict[str, AExpr],
                regmap: Dict[str, str]) -> List[Stmt]:
    out: List[Stmt] = []
    for s in stmts:
        if isinstance(s, Store):
            out.append(Store(s.mem, [ix.substitute(env) for ix in s.idxs],
                             clone_vexpr(s.value, env, regmap)))
        elif isinstance(s, SetReg):
            out.append(SetReg(regmap.get(s.name, s.name),
                              clone_vexpr(s.value, env, regmap)))
        elif isinstance(s, Loop):
            out.append(Loop(s.var, s.extent, clone_stmts(s.body, env, regmap),
                            kind=s.kind))
        elif isinstance(s, Par):
            out.append(Par([clone_stmts(a, env, regmap) for a in s.arms]))
        elif isinstance(s, If):
            cond = s.cond.substitute(env)
            const = cond.try_const()
            if const is True:
                out.extend(clone_stmts(s.then, env, regmap))
            elif const is False:
                out.extend(clone_stmts(s.els, env, regmap))
            else:
                out.append(If(cond, clone_stmts(s.then, env, regmap),
                              clone_stmts(s.els, env, regmap)))
        else:
            raise TypeError(s)
    return out


def assigned_regs(stmts: List[Stmt]) -> List[str]:
    regs: List[str] = []
    for s in stmts:
        if isinstance(s, SetReg) and s.name not in regs:
            regs.append(s.name)
        elif isinstance(s, Loop):
            regs += [r for r in assigned_regs(s.body) if r not in regs]
        elif isinstance(s, Par):
            for a in s.arms:
                regs += [r for r in assigned_regs(a) if r not in regs]
        elif isinstance(s, If):
            regs += [r for r in assigned_regs(s.then) + assigned_regs(s.els)
                     if r not in regs]
    return regs


# ---------------------------------------------------------------------------
# Strip-mine + unroll
# ---------------------------------------------------------------------------


def strip_count(extent: int, factor: int) -> int:
    """Bank-affine strip factor: how many arms to unroll a loop into.

    The arm count must divide ``extent`` (arms stay balanced) and must not
    exceed ``factor`` (the banking factor — more arms than banks can never
    all hit distinct banks).  Among the candidates we prefer divisors of
    ``factor``: with ``c | factor`` the unroll offsets ``0..c-1`` keep the
    *combined* offset span of nested strip-mines within one bank period,
    so every arm's accesses provably land on distinct banks (either the
    cyclic fold reaches a constant digit, or the bank-affine difference
    proof in ``estimator.banks_provably_distinct`` closes it).  When no
    nontrivial divisor of ``factor`` divides ``extent`` (e.g. extent 3,
    factor 4) we fall back to the largest divisor of ``extent`` itself —
    arms then span ``c <= factor`` consecutive offsets, still pairwise
    distinct modulo the banking factor.

    ``gcd(extent, factor)`` — the previous policy — is always a
    candidate, but it is not always the best one: gcd(6, 4) = 2 wastes
    half the banks where 3 arms are provably conflict-free.
    """
    best = 1
    for c in range(2, min(extent, factor) + 1):
        if extent % c:
            continue
        if factor % c == 0:
            best = max(best, c)
    if best == 1 and extent >= factor:
        # No divisor of the factor divides the extent: fall back to the
        # largest divisor of the extent itself (e.g. extent 6, factor 4
        # -> 3 arms at offsets {0,1,2}, pairwise distinct mod 4).  Only
        # when the extent covers the factor — stripping a short loop
        # (extent < factor) adds arms without adding distinct banks and
        # its offsets stack onto sibling strips until they wrap the bank
        # period, conflict-serializing the combined par.
        for c in range(2, min(extent, factor) + 1):
            if extent % c == 0:
                best = max(best, c)
    return best


def _is_simple_reduce(loop: Loop) -> bool:
    """Reduction loops of the form ``acc = acc (+|max|min) f(k)``."""
    if loop.kind != "reduce" or len(loop.body) != 1:
        return False
    s = loop.body[0]
    return (isinstance(s, SetReg) and isinstance(s.value, Bin)
            and s.value.op in ("add", "max", "min")
            and isinstance(s.value.a, ReadReg) and s.value.a.name == s.name)


def strip_mine_par(loop: Loop, factor: int) -> List[Stmt]:
    """Loop(j,N) -> Loop(j_o, N/c){ Par[ body[j := c*j_o + a] ] }.

    ``c`` is the bank-affine :func:`strip_count` — chosen so the unroll
    arms' address strides provably land on distinct banks of a
    factor-``factor`` cyclic partitioning (``banking.BankingSpec``).
    """
    c = strip_count(loop.extent, factor)
    if c <= 1:
        return [loop]
    outer = loop.var + "_o"
    arms: List[List[Stmt]] = []
    regs = assigned_regs(loop.body)
    for a in range(c):
        env = {loop.var: AExpr.var(outer) * c + a}
        regmap = {r: f"{r}__{loop.var}{a}" for r in regs}
        arms.append(clone_stmts(loop.body, env, regmap))
    return [Loop(outer, loop.extent // c, [Par(arms)], kind="seq")]


def strip_mine_reduce(loop: Loop, factor: int) -> List[Stmt]:
    """Cyclic reduction split with per-arm accumulators and a combine tail.

    ``for k: acc = acc + f(k)``  becomes::

        par { acc_a = 0  for each arm }
        for k_o: par { acc_a = acc_a + f(c*k_o + a) }
        acc = acc + acc_0 + ... + acc_{c-1}     (sequential combine)
    """
    c = strip_count(loop.extent, factor)
    if c <= 1 or not _is_simple_reduce(loop):
        return [loop]
    s: SetReg = loop.body[0]  # type: ignore[assignment]
    op = s.value.op  # type: ignore[union-attr]
    acc = s.name
    outer = loop.var + "_o"
    init = ConstF(0.0) if op == "add" else ConstF(-1e30 if op == "max" else 1e30)
    inits: List[List[Stmt]] = []
    arms: List[List[Stmt]] = []
    combines: List[Stmt] = []
    for a in range(c):
        arm_acc = f"{acc}__{loop.var}{a}"
        env = {loop.var: AExpr.var(outer) * c + a}
        regmap = {acc: arm_acc}
        inits.append([SetReg(arm_acc, init)])
        arms.append(clone_stmts(loop.body, env, regmap))
        combines.append(SetReg(acc, Bin(op, ReadReg(acc), ReadReg(arm_acc))))
    return [Par(inits),
            Loop(outer, loop.extent // c, [Par(arms)], kind="seq"),
            *combines]


def parallelize(prog: Program, factor: int) -> Program:
    """Strip-mine the deepest data-parallel loop and the deepest simple
    reduction loop of every nest by ``factor`` (bottom-up, so a matmul nest
    yields c^2 MAC arms after restructuring)."""
    if factor <= 1:
        return prog

    def rewrite(stmts: List[Stmt], par_budget: int) -> List[Stmt]:
        out: List[Stmt] = []
        for s in stmts:
            if isinstance(s, Loop):
                inner_has_par = any(isinstance(x, Loop) and x.kind == "par_data"
                                    for x in _descend(s.body))
                body = rewrite(s.body, par_budget)
                s = Loop(s.var, s.extent, body, kind=s.kind)
                if _is_simple_reduce_shape(s):
                    out.extend(strip_mine_reduce(s, factor))
                elif s.kind == "par_data" and not inner_has_par and par_budget > 0:
                    out.extend(strip_mine_par(s, factor))
                else:
                    out.append(s)
            elif isinstance(s, If):
                out.append(If(s.cond, rewrite(s.then, par_budget),
                              rewrite(s.els, par_budget)))
            elif isinstance(s, Par):
                out.append(Par([rewrite(a, par_budget) for a in s.arms]))
            else:
                out.append(s)
        return out

    prog = dataclasses.replace(prog, body=rewrite(prog.body, 1))
    prog.meta["parallel_factor"] = factor
    return prog


def _descend(stmts: List[Stmt]):
    for s in stmts:
        yield s
        if isinstance(s, Loop):
            yield from _descend(s.body)
        elif isinstance(s, If):
            yield from _descend(s.then)
            yield from _descend(s.els)
        elif isinstance(s, Par):
            for a in s.arms:
                yield from _descend(a)


def _is_simple_reduce_shape(loop: Loop) -> bool:
    return _is_simple_reduce(loop)


# ---------------------------------------------------------------------------
# par(seq) -> seq(par) restructuring  (paper §3.3, second transformation)
# ---------------------------------------------------------------------------


def restructure_par(par: Par,
                    _counter: Optional[List[int]] = None) -> List[Stmt]:
    """Hoist shared sequential structure out of parallel arms.

    If every arm has the same statement count and position-wise compatible
    structure (equal-extent loops at matching positions), rewrite stepwise:
    ``Par[A1;A2 | B1;B2]`` -> ``Par[A1|B1]; Par[A2|B2]`` and
    ``Par[Loop(e){a} | Loop(e){b}]`` -> ``Loop(e){ Par[a|b] }``.

    ``_counter`` numbers the fused loop variables.  It is *per invocation*
    (a fresh one is allocated when omitted, and :func:`restructure`
    threads a single counter through one whole program rewrite): a
    module-global counter would make repeated compiles in one process
    emit different ``_fuseN`` names, i.e. non-reproducible text.
    """
    counter = [0] if _counter is None else _counter
    arms = par.arms
    if len(arms) <= 1:
        return [par]
    n = len(arms[0])
    if any(len(a) != n for a in arms):
        return [par]
    out: List[Stmt] = []
    for pos in range(n):
        col = [a[pos] for a in arms]
        if all(isinstance(s, Loop) for s in col):
            loops: List[Loop] = col  # type: ignore[assignment]
            if len({(l.extent,) for l in loops}) == 1:
                counter[0] += 1
                var = f"_fuse{counter[0]}"
                bodies = []
                for l in loops:
                    env = {l.var: AExpr.var(var)}
                    bodies.append(clone_stmts(l.body, env, {}))
                inner = restructure_par(Par(bodies), counter)
                out.append(Loop(var, loops[0].extent, inner, kind="seq"))
                continue
        out.append(Par([[s] for s in col]) if len(col) > 1 else col[0])
    return out


def restructure(prog: Program, enable: bool = True) -> Program:
    """Apply the par/seq rewrite everywhere (ablatable via ``enable``)."""
    if not enable:
        return prog
    counter = [0]                 # per-invocation: reproducible _fuseN names

    def rewrite(stmts: List[Stmt]) -> List[Stmt]:
        out: List[Stmt] = []
        for s in stmts:
            if isinstance(s, Loop):
                out.append(Loop(s.var, s.extent, rewrite(s.body), kind=s.kind))
            elif isinstance(s, Par):
                arms = [rewrite(a) for a in s.arms]
                out.extend(restructure_par(Par(arms), counter))
            elif isinstance(s, If):
                out.append(If(s.cond, rewrite(s.then), rewrite(s.els)))
            else:
                out.append(s)
        return out

    return dataclasses.replace(prog, body=rewrite(prog.body))
