"""Loop pipelining over the Calyx-like IR (opt_level 2).

The baseline schedule runs a ``repeat`` body to completion — plus a
per-iteration overhead cycle — before the next iteration starts, so a
loop costs ``setup + extent * (body + overhead)``.  Real HLS control
(HIR's explicitly-scheduled pipelined loops; Vitis' II-based pipelining)
overlaps iterations instead: a new iteration launches every *initiation
interval* (II) cycles and the loop costs

    setup + (extent - 1) * II + body_latency

This pass computes a safe II for every innermost ``repeat`` whose body is
a single group (the form the chaining pass produces) and annotates the
node (``CRepeat.ii``).  Every downstream stage models the same overlapped
schedule: the estimator prices the closed form above, the Calyx simulator
launches iteration *i* at ``setup + i*II`` and stamps its memory-port
claims at real absolute cycles (so an unsound II would be *caught*, not
mis-simulated), and the RTL backend compiles the loop into a pipelined
controller state whose launch counter fires the body every II cycles.

The II is the maximum of three constraint families, mirroring classic
modulo scheduling:

* **loop-carried register recurrences** — for a register both written
  (at stamped offset ``w``) and consumed (at offset ``c``) in the body:
  ``II >= max(W) - min(C)`` (the next iteration may not consume before
  this one produced — e.g. a reduction accumulator whose adder starts at
  cycle 4 and latches at 6 gives II = 2, the adder's depth) and
  ``II >= max(C) - min(W)`` (the next iteration may not overwrite a
  value this one still reads; there is no register renaming).

* **memory-port reservation** — each single-ported bank serves one
  access per cycle, so the body's access offsets into one bank must stay
  pairwise distinct modulo II (the classic modulo reservation table).
  Banks are resolved from constant bank indices; accesses with
  runtime-selected banks conservatively share one reservation row per
  logical memory.  Bodies that both read and write one memory are not
  pipelined at all (a loop-carried memory dependence we do not analyze).

* **non-pipelined units** — iterative units (fp_div, fp_exp,
  int_divmod) accept a new operation only every ``latency`` cycles;
  pipelined HardFloat-style add/mul accept one per cycle and impose
  nothing.

II search starts at the recurrence/unit floor and stops at the body
latency — beyond that, pipelining cannot beat the sequential schedule.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set, Tuple

from . import dataflow as D
from . import float_lib as F
from .calyx import (CIf, CNode, CPar, CRepeat, CSeq, Component, GEnable,
                    Group)

# Iterative (non-pipelined) unit kinds: a new op may only issue every
# `latency` cycles.  Everything else is a pipelined primitive (II=1).
NONPIPELINED_KINDS = frozenset({"fp_div", "fp_exp", "int_divmod"})


def _unit_latency(comp: Component, cell_name: str) -> int:
    cell = comp.cells.get(cell_name)
    if cell is None:
        return 0
    if cell.kind in F.FLOAT_COSTS:
        return F.FLOAT_COSTS[cell.kind].cycles
    if cell.kind == "int_divmod":
        return F.int_divmod_cost(cell.const).cycles
    return 0


def _register_floor(g: Group) -> int:
    """Loop-carried register recurrence floor for II."""
    writes: Dict[str, List[int]] = {}
    reads: Dict[int, str] = {}            # temp -> register it carries
    consumes: Dict[str, List[int]] = {}
    for u in g.uops:
        if isinstance(u, D.URegWrite):
            writes.setdefault(u.reg, []).append(u.off)
        elif isinstance(u, D.URegRead):
            reads[u.dst] = u.reg

    def consume(temp: Optional[int], off: int) -> None:
        if temp is not None and temp in reads:
            consumes.setdefault(reads[temp], []).append(off)

    for u in g.uops:
        if isinstance(u, D.UAlu):
            consume(u.a, u.off)
            consume(u.b, u.off)
        elif isinstance(u, D.USelect):
            consume(u.a, u.off)
            consume(u.b, u.off)
        elif isinstance(u, D.URegWrite):
            consume(u.src, u.off)
        elif isinstance(u, D.UMemWrite):
            consume(u.src, u.off)
    floor = 1
    for reg, w_offs in writes.items():
        c_offs = consumes.get(reg)
        if not c_offs:
            continue
        floor = max(floor,
                    max(w_offs) - min(c_offs),    # produce before next use
                    max(c_offs) - min(w_offs))    # read before overwrite
    return floor


def _unit_floor(comp: Component, g: Group) -> int:
    """Non-pipelined (iterative) units must finish before re-issue."""
    per_cell: Dict[str, int] = {}
    for u in g.uops:
        if isinstance(u, D.UAlu):
            cell = comp.cells.get(u.cell)
            if cell is not None and cell.kind in NONPIPELINED_KINDS:
                per_cell[u.cell] = per_cell.get(u.cell, 0) + 1
    floor = 1
    for cell_name, uses in per_cell.items():
        floor = max(floor, uses * _unit_latency(comp, cell_name))
    return floor


def _port_offsets(comp: Component, g: Group
                  ) -> Optional[Dict[Tuple, Set[int]]]:
    """Per-bank reservation rows: bank key -> set of busy offsets.

    Returns None when the body both reads and writes one memory — a
    potential loop-carried memory dependence this pass does not analyze,
    so the loop is left unpipelined.
    """
    factors: Dict[str, tuple] = comp.meta.get("bank_factors", {})
    rw: Dict[str, Set[bool]] = {}
    runtime_bank: Set[str] = set()
    rows: Dict[Tuple, Set[int]] = {}
    accesses: List[Tuple[str, Optional[int], int]] = []
    for u in g.uops:
        if isinstance(u, D.UMemRead):
            is_store = False
        elif isinstance(u, D.UMemWrite):
            is_store = True
        else:
            continue
        rw.setdefault(u.mem, set()).add(is_store)
        bank: Optional[int] = 0
        if factors.get(u.mem):
            bank = (u.idxs[0].const_value() if u.idxs[0].is_const()
                    else None)
        if bank is None:
            runtime_bank.add(u.mem)
        accesses.append((u.mem, bank, u.off))
    if any(len(v) > 1 for v in rw.values()):
        return None
    for mem, bank, off in accesses:
        key: Tuple = (mem,) if mem in runtime_bank else (mem, bank)
        rows.setdefault(key, set()).add(off)
    return rows


def _rows_admit(rows: Dict[Tuple, Set[int]], ii: int) -> bool:
    """True iff every reservation row's offsets stay distinct modulo ii."""
    for offs in rows.values():
        if len({o % ii for o in offs}) != len(offs):
            return False
    return True


# Public faces of the II constraint families — the stage-boundary verifier
# (core.verify) re-proves every annotated II through these same functions,
# so an unsound annotation is caught statically with the exact model the
# pass used to compute it.
register_floor = _register_floor
unit_floor = _unit_floor
port_offsets = _port_offsets
rows_admit = _rows_admit


def compute_ii(comp: Component, g: Group) -> int:
    """Smallest admissible initiation interval for ``g`` as a loop body,
    or 0 when the loop should stay unpipelined."""
    if not g.uops:
        return 0
    rows = _port_offsets(comp, g)
    if rows is None:
        return 0
    floor = max(_register_floor(g), _unit_floor(comp, g))
    for ii in range(max(1, floor), g.latency + 1):
        if _rows_admit(rows, ii):
            return ii
    return 0


def pipeline_loops(comp: Component) -> Component:
    """Annotate innermost single-group repeats with their II.

    Only loops whose body is one group qualify (run chaining first —
    that is what collapses multi-statement bodies); a loop is pipelined
    only when the computed II actually beats the sequential
    ``body + overhead`` per-iteration cost.
    """
    pipelined: List[Dict[str, int]] = []

    def rewrite(node: CNode) -> CNode:
        if isinstance(node, GEnable):
            return node
        if isinstance(node, CSeq):
            return CSeq([rewrite(ch) for ch in node.children])
        if isinstance(node, CPar):
            return CPar([rewrite(ch) for ch in node.children])
        if isinstance(node, CIf):
            return dataclasses.replace(node, then=rewrite(node.then),
                                       els=rewrite(node.els))
        if isinstance(node, CRepeat):
            body = rewrite(node.body)
            node = dataclasses.replace(node, body=body)
            if (node.ii == 0 and node.extent >= 2
                    and isinstance(body, GEnable)):
                g = comp.groups[body.group]
                ii = compute_ii(comp, g)
                if ii and ii < g.latency + F.LOOP_ITER_OVERHEAD:
                    node = dataclasses.replace(node, ii=ii)
                    pipelined.append({"var": node.var,
                                      "extent": node.extent,
                                      "ii": ii,
                                      "body_latency": g.latency})
            return node
        raise TypeError(node)

    control = rewrite(comp.control)
    out = Component(comp.name, comp.cells, comp.groups, control,
                    meta=dict(comp.meta))
    out.meta["pipelined"] = pipelined
    return out
