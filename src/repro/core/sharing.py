"""Resource-sharing (binding) pass over the Calyx-like IR.

The paper's toolchain instantiates a fresh functional unit for every static
operation and defers resource sharing to future work; this pass supplies the
missing binding stage, in the spirit of LegUp/HIR-style HLS binding: expensive
units (HardFloat adders/multipliers/dividers/exp, constant integer
multiply/divmod) used by *mutually exclusive* groups are rebound onto a shared
pool, so a design pays for its peak concurrency instead of its statement count.

The pass has three parts:

1. **Mutual-exclusion analysis** (:func:`concurrent_pairs`) over the control
   tree.  Children of ``seq`` execute one after another and a ``repeat`` body
   only ever races itself across iterations — both are exclusive.  The two
   arms of an ``if`` are exclusive by definition.  Only the children of a
   ``par`` may be active in the same cycle window, so group pairs drawn from
   *different* par arms are the (only) concurrent pairs.

2. **Binding** (:func:`share_cells`): every use of a shareable cell is
   greedily colored onto the lowest-indexed pool slot whose current users are
   all exclusive with it — a clique-per-``par``-arm lower bound that the
   greedy order achieves on these series-parallel control trees.  Pool slots
   are per ``(kind, const)`` class: a multiply-by-12 unit is different
   hardware from a multiply-by-48 unit and is never merged with it.

3. **Rewrite + verification**: ``Component.cells`` shrinks to the pool (plus
   untouched unshareable cells), every ``Group.cells`` list is rewritten to
   the bound names — and so is every group's micro-op list, where each
   rebound ``UAlu`` keeps its own operand temporaries plus its pre-binding
   cell as provenance, so per-user operand routing through the pool stays
   explicit and the simulator can arbitrate single ownership — and
   :func:`verify_sharing` re-checks that no pool cell is
   referenced from two concurrent groups — sharing must never serialize
   ``par`` arms, and because group latencies, ports, and the control tree are
   untouched, ``estimator.cycles`` is provably unchanged (the pipeline
   asserts it anyway).

The cost model charges each pool cell a steering overhead (operand muxes plus
a grant register) per extra user via ``float_lib.sharing_mux_cost`` — sharing
is therefore not free, and stops paying once a unit is cheaper than its mux.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Set, Tuple

from . import dataflow as D
from . import float_lib as F
from .calyx import (Cell, CIf, CNode, CPar, CRepeat, CSeq, Component, GEnable,
                    Group)

# Cells worth pooling: everything whose unit cost dwarfs a 32-bit mux.
# Cheap fabric (relu/neg/min/max, address adders, cmp, mux, registers) is
# excluded — a shared copy plus steering would cost *more* than duplicates,
# and registers carry state so they are never shareable at all.
SHAREABLE_KINDS = frozenset({
    "fp_add", "fp_sub", "fp_mul", "fp_div", "fp_exp",
    "int_mul", "int_divmod",
})


# ---------------------------------------------------------------------------
# Mutual-exclusion analysis
# ---------------------------------------------------------------------------


def concurrent_pairs(control: CNode) -> Set[frozenset]:
    """Unordered pairs of groups that may be active in the same cycle.

    Exactly the pairs that sit in *different* arms of some ``par`` node;
    every other pair (seq siblings, repeat iterations, if arms) is mutually
    exclusive under Calyx's one-subtree-at-a-time semantics.
    """
    pairs: Set[frozenset] = set()

    def walk(node: CNode) -> Set[str]:
        if isinstance(node, GEnable):
            return {node.group}
        if isinstance(node, (CSeq, CPar)):
            child_sets = [walk(ch) for ch in node.children]
            if isinstance(node, CPar):
                for i in range(len(child_sets)):
                    for j in range(i + 1, len(child_sets)):
                        for a in child_sets[i]:
                            for b in child_sets[j]:
                                pairs.add(frozenset((a, b)))
            out: Set[str] = set()
            for s in child_sets:
                out |= s
            return out
        if isinstance(node, CRepeat):
            return walk(node.body)
        if isinstance(node, CIf):
            return walk(node.then) | walk(node.els)
        raise TypeError(node)

    walk(control)
    return pairs


def mutually_exclusive(control: CNode, g1: str, g2: str) -> bool:
    """True iff groups ``g1`` and ``g2`` can never be active together."""
    if g1 == g2:
        return False
    return frozenset((g1, g2)) not in concurrent_pairs(control)


# ---------------------------------------------------------------------------
# Binding
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SharingReport:
    cells_before: int                       # shareable-kind cells pre-binding
    cells_after: int                        # pool cells post-binding
    pools: Dict[str, List[str]]             # pool cell -> original cell names
    by_kind: Dict[str, Tuple[int, int]]     # kind -> (before, after)

    @property
    def removed(self) -> int:
        return self.cells_before - self.cells_after

    def summary(self) -> str:
        per_kind = " ".join(f"{k}:{b}->{a}"
                            for k, (b, a) in sorted(self.by_kind.items()))
        return (f"shared {self.cells_before}->{self.cells_after} cells "
                f"({per_kind})")


def _pool_name(kind: str, const: int, idx: int) -> str:
    tag = f"_c{const}" if const else ""
    return f"shared_{kind}{tag}_{idx}"


def _pinned_cells(comp: Component) -> Set[str]:
    """Cells that must keep their identity: referenced from if-condition
    logic (active outside any group's window) or from more than one group
    (already structurally shared by construction, e.g. named registers)."""
    pinned: Set[str] = set()

    def walk(node: CNode) -> None:
        if isinstance(node, CIf):
            pinned.update(node.cond_cells)
            walk(node.then)
            walk(node.els)
        elif isinstance(node, (CSeq, CPar)):
            for ch in node.children:
                walk(ch)
        elif isinstance(node, CRepeat):
            walk(node.body)

    walk(comp.control)
    seen_in: Dict[str, str] = {}
    for g in comp.groups.values():
        for c in g.cells:
            if seen_in.setdefault(c, g.name) != g.name:
                pinned.add(c)
    return pinned


def share_cells(comp: Component) -> Tuple[Component, SharingReport]:
    """Bind shareable cells of mutually-exclusive groups onto shared pools.

    Returns a new :class:`Component` (control tree, group latencies, and
    port lists are reused untouched) plus a :class:`SharingReport`.
    """
    pairs = concurrent_pairs(comp.control)
    pinned = _pinned_cells(comp)

    def conflicts(g1: str, g2: str) -> bool:
        # Same group: both uses live in one activation window.  Different
        # groups: conflict iff some par makes them co-active.
        return g1 == g2 or frozenset((g1, g2)) in pairs

    # (kind, const) -> pool slots; each slot is the list of (group, orig).
    slots: Dict[Tuple[str, int], List[List[Tuple[str, str]]]] = {}
    bound: Dict[str, str] = {}              # original cell name -> pool name

    for g in comp.groups.values():          # deterministic lowering order
        for orig in g.cells:
            cell = comp.cells.get(orig)
            if (cell is None or cell.kind not in SHAREABLE_KINDS
                    or orig in pinned):
                continue
            key = (cell.kind, cell.const)
            pool = slots.setdefault(key, [])
            for idx, users in enumerate(pool):
                if all(not conflicts(g.name, ug) for ug, _ in users):
                    users.append((g.name, orig))
                    bound[orig] = _pool_name(*key, idx)
                    break
            else:
                bound[orig] = _pool_name(*key, len(pool))
                pool.append([(g.name, orig)])

    # Rebuild the cell table: pool cells appear at the position of their
    # first original, annotated with their user count for the mux model.
    pool_users: Dict[str, List[str]] = {}
    pool_cell: Dict[str, Cell] = {}
    for (kind, const), pool in slots.items():
        for idx, users in enumerate(pool):
            name = _pool_name(kind, const, idx)
            pool_users[name] = [orig for _, orig in users]
            pool_cell[name] = Cell(name, kind, const=const, users=len(users))

    new_cells: Dict[str, Cell] = {}
    for name, cell in comp.cells.items():
        if name in bound:
            pname = bound[name]
            if pname not in new_cells:
                new_cells[pname] = pool_cell[pname]
        else:
            new_cells[name] = cell

    def _route(u: D.UOp) -> D.UOp:
        # Rebind the FU invocation onto its pool cell while keeping the
        # use's own operand temporaries and pre-binding identity — the
        # per-user operand routing the simulator arbitrates against.
        if isinstance(u, D.UAlu) and u.cell in bound:
            return dataclasses.replace(u, cell=bound[u.cell],
                                       orig_cell=u.orig_cell or u.cell)
        return u

    # Rebuild only groups that actually drive a pooled cell; untouched
    # groups keep their identity, so the stage-boundary verifier's
    # already-checked cache stays valid across the sharing boundary.
    new_groups = {
        g.name: (Group(g.name, g.latency,
                       [bound.get(c, c) for c in g.cells], g.ports,
                       [_route(u) for u in g.uops])
                 if any(c in bound for c in g.cells)
                 or any(isinstance(u, D.UAlu) and u.cell in bound
                        for u in g.uops) else g)
        for g in comp.groups.values()
    }

    by_kind: Dict[str, Tuple[int, int]] = {}
    for (kind, _), pool in slots.items():
        b, a = by_kind.get(kind, (0, 0))
        by_kind[kind] = (b + sum(len(u) for u in pool), a + len(pool))
    report = SharingReport(
        cells_before=len(bound),
        cells_after=len(pool_cell),
        pools=pool_users,
        by_kind=by_kind,
    )
    shared = Component(comp.name, new_cells, new_groups, comp.control,
                       meta=dict(comp.meta))
    shared.meta["sharing"] = report.summary()
    verify_sharing(shared, pairs=pairs)
    return shared, report


# ---------------------------------------------------------------------------
# Verification — sharing must never serialize par arms
# ---------------------------------------------------------------------------


def pool_cells_by_group(comp: Component) -> Dict[str, Set[str]]:
    """group name -> shared pool cells (``users > 1``) it drives.  Shared
    by :func:`verify_sharing` and the static single-owner proof in
    ``core.verify`` (RV021)."""
    return {
        g.name: {c for c in g.cells
                 if comp.cells.get(c) is not None
                 and comp.cells[c].users > 1}
        for g in comp.groups.values()
    }


def verify_sharing(comp: Component,
                   pairs: "Set[frozenset] | None" = None) -> None:
    """Check no two concurrent groups reference the same shared pool cell.

    A pool cell reachable from two arms of one ``par`` would force those
    arms to serialize on the real hardware — exactly what the binding's
    exclusivity constraint forbids.  O(pairs x cells); cheap on the static
    group counts this IR produces.  Raises (not asserts: the invariant must
    survive ``python -O``).  ``pairs`` lets callers reuse an
    already-computed concurrency relation.
    """
    shared_by_group = pool_cells_by_group(comp)
    if pairs is None:
        pairs = concurrent_pairs(comp.control)
    for pair in pairs:
        tup = tuple(pair)
        # a singleton means a group enabled in two arms of one par — it
        # races itself, so any pooled cell it drives is a conflict
        g1, g2 = tup if len(tup) == 2 else (tup[0], tup[0])
        overlap = shared_by_group.get(g1, set()) & shared_by_group.get(g2, set())
        if overlap:
            raise ValueError(
                f"shared cell(s) {sorted(overlap)} bound into concurrent "
                f"groups {g1!r} and {g2!r}: sharing would serialize a par")


def mux_overhead(comp: Component) -> F.OpCost:
    """Total steering overhead the shared pools add (for reports)."""
    lut = ff = 0
    for cell in comp.cells.values():
        c = F.sharing_mux_cost(cell.kind, cell.users)
        lut += c.lut
        ff += c.ff
    return F.OpCost(0, lut, ff, 0)
