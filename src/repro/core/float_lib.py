"""Hardware cost tables — the analogue of the paper's floating-point library.

The paper integrates Berkeley HardFloat into Calyx/CIRCT; synthesizing real
RTL is out of scope here, so each primitive carries a *calibrated* cost tuple
(cycles, LUT, FF, DSP) in the regime of HardFloat units on a Xilinx 7-series
part at ~250 MHz.  Absolute resource numbers are first-order models; the
benchmarks validate *ratios and regimes* against the paper's tables, which is
what the cycle model is calibrated for.

All constants live here so the whole estimator is tunable from one place.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict


@dataclasses.dataclass(frozen=True)
class OpCost:
    cycles: int
    lut: int
    ff: int
    dsp: int


# HardFloat-style IEEE-754 single-precision units.
FLOAT_COSTS: Dict[str, OpCost] = {
    "fp_add": OpCost(2, 460, 70, 0),     # addFN (LUT carry chains)
    "fp_sub": OpCost(2, 460, 70, 0),
    "fp_mul": OpCost(3, 140, 55, 2),     # mulFN maps mantissa mul to DSP48
    "fp_div": OpCost(26, 760, 150, 0),    # iterative divSqrtFN
    "fp_max": OpCost(1, 70, 16, 0),
    "fp_min": OpCost(1, 70, 16, 0),
    "fp_relu": OpCost(1, 40, 4, 0),
    "fp_neg": OpCost(1, 6, 0, 0),
    "fp_exp": OpCost(16, 950, 130, 4),    # range-reduced polynomial
    "fp_cmp": OpCost(1, 60, 8, 0),
}

# Integer / address-path units.  Address arithmetic is combinational within a
# group (0 cycles) but costs fabric; div/mod by a non-power-of-2 constant is
# an iterative unit — the "expensive multiplication and modulo" the paper
# blames for flattened-memory indexing cost.
INT_COSTS: Dict[str, OpCost] = {
    "int_mul": OpCost(0, 90, 0, 1),       # const multiply, non-trivial
    "int_divmod": OpCost(6, 260, 70, 0),  # non-power-of-2 divide/modulo
    "int_add": OpCost(0, 18, 0, 0),
    "cmp": OpCost(0, 20, 0, 0),
    "mux": OpCost(0, 18, 0, 0),
    "reg32": OpCost(0, 4, 22, 0),         # 32-bit data register
    "idx_reg": OpCost(0, 3, 10, 0),       # loop index register + incr adder
}


def is_pow2(v: int) -> bool:
    return v > 0 and (v & (v - 1)) == 0


def int_mul_cost(const: int) -> OpCost:
    """Multiply-by-constant: powers of two are wiring; popcount<=2 constants
    become shift-adds; anything else takes a DSP slice."""
    c = abs(int(const))
    if c in (0, 1) or is_pow2(c):
        return OpCost(0, 0, 0, 0)
    if bin(c).count("1") <= 2:
        return OpCost(0, 40, 0, 0)
    return INT_COSTS["int_mul"]


def int_divmod_cost(const: int) -> OpCost:
    if is_pow2(abs(int(const))):
        return OpCost(0, 0, 0, 0)  # shift / mask
    return INT_COSTS["int_divmod"]


# Memory model: Calyx memories are single-ported (1 access/cycle) — the
# constraint that motivates banking.  Small banks become LUTRAM.
BRAM_BITS = 18 * 1024
LUTRAM_MAX_WORDS = 48
WORD_BITS = 32
MEM_READ_CYCLES = 1
MEM_WRITE_CYCLES = 1


def memory_cost(words: int) -> OpCost:
    """Fabric cost of one bank (BRAM count reported via memory_brams)."""
    if words <= LUTRAM_MAX_WORDS:
        # distributed RAM: ~1 LUT per 2 words of 32b + addressing
        return OpCost(0, max(4, words // 2 + 8), 8, 0)
    return OpCost(0, 24, 12, 0)


def memory_brams(words: int) -> int:
    if words <= LUTRAM_MAX_WORDS:
        return 0
    return math.ceil(words * WORD_BITS / BRAM_BITS)


# Resource sharing (binding) steering.  A pooled unit needs a 2:1 32-bit mux
# per operand for every user beyond the first, plus a grant/select register
# bit — so sharing pays off only for units well above mux cost, which is why
# sharing.SHAREABLE_KINDS excludes the cheap fabric.
SHARING_MUX_LUT_PER_EXTRA_USER: Dict[str, int] = {
    "fp_add": 34, "fp_sub": 34, "fp_mul": 34, "fp_div": 34,  # two operands
    "fp_exp": 18,                                             # one operand
    "int_mul": 18, "int_divmod": 34,
}
SHARING_MUX_FF_PER_EXTRA_USER = 2


def sharing_mux_cost(kind: str, users: int) -> OpCost:
    """Steering overhead of one shared cell serving ``users`` groups."""
    extra = max(0, users - 1)
    if not extra:
        return OpCost(0, 0, 0, 0)
    lut = SHARING_MUX_LUT_PER_EXTRA_USER.get(kind, 18) * extra
    return OpCost(0, lut, SHARING_MUX_FF_PER_EXTRA_USER * extra, 0)


# Control / FSM model.
FSM_LUT_PER_STATE = 14
FSM_FF_PER_STATE_BIT = 8
GROUP_FABRIC_LUT = 22          # go/done handshake + assignment fabric
LOOP_ITER_OVERHEAD = 1         # condition check folded with increment
LOOP_SETUP_CYCLES = 2
PAR_JOIN_CYCLES = 1
IF_SELECT_CYCLES = 1

# Per-design constant overhead (top-level interface / AXI-ish shim).
TOP_OVERHEAD = {"lut": 520, "ff": 90, "dsp": 2, "bram": 1}

# Timing model for wall-clock: base period plus pressure terms.
BASE_PERIOD_NS = 4.0
PERIOD_PER_LOG2_STATE_NS = 0.16
PERIOD_PER_SELECT_DEPTH_NS = 0.12


def achievable_period_ns(fsm_states: int, max_select_depth: int) -> float:
    return (BASE_PERIOD_NS
            + PERIOD_PER_LOG2_STATE_NS * math.log2(max(fsm_states, 2))
            + PERIOD_PER_SELECT_DEPTH_NS * max_select_depth)
