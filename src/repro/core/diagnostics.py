"""Structured compiler diagnostics for the Calyx path.

Every static check in the verifier (``core.verify``), the Verilog text
lint (``verilog.lint_diagnostics``), and the simulators' runtime raises
speak one vocabulary: a :class:`Diagnostic` with a stable ``RV0xx`` error
code, a severity, a human message, the pipeline *stage* that produced it,
and a *provenance chain* — outermost-to-innermost locations (control-tree
path -> group -> micro-op -> netlist state/wire) so a finding at any
layer can be traced back to the construct that lowered it.

The code space is grouped by family:

* ``RV00x`` — IR well-formedness (dangling references, unreachable
  groups, malformed control nodes).
* ``RV01x`` — dataflow over the stamped micro-op schedules (SSA temp
  discipline, register def-use/liveness, write races).
* ``RV02x`` — static re-proofs of the hardware disciplines the
  simulators enforce dynamically (one-access-per-cycle memory ports,
  single-owner shared pools, modulo-II reservation soundness).
* ``RV03x`` — netlist-level structure (multi-driven nets, combinational
  loops, FSM reachability, index-register resolution).
* ``RV04x`` — emitted-SystemVerilog text lint.

Severities: ``error`` findings are miscompiles — the pipeline refuses to
hand the artifact to the next stage (:class:`VerificationError`);
``warning`` findings are suspicious but sound (dead cells/groups — the
elimination pass in ``core.verify`` consumes exactly these); ``info`` is
reporting only.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Iterable, Iterator, List, Optional, Tuple

ERROR = "error"
WARNING = "warning"
INFO = "info"

#: code -> (one-line title, default severity).  The single source of the
#: error-code table in the README; ``tests/test_core_verify.py`` checks
#: every code here fires on its negative-corpus fixture.
CODES: Dict[str, Tuple[str, str]] = {
    # -- RV00x: IR well-formedness -------------------------------------
    "RV001": ("dangling cell reference", ERROR),
    "RV002": ("unused cell", WARNING),
    "RV003": ("control references undefined group", ERROR),
    "RV004": ("group unreachable from the control tree", WARNING),
    "RV005": ("if-node missing its lowered condition", ERROR),
    "RV006": ("malformed repeat node", ERROR),
    "RV007": ("group carries no micro-ops", ERROR),
    "RV008": ("access to undeclared memory", ERROR),
    "RV009": ("unbound loop variable in address/condition", ERROR),
    # -- RV01x: micro-op dataflow --------------------------------------
    "RV010": ("temp read before definition", ERROR),
    "RV011": ("register read before any write", ERROR),
    "RV012": ("dead register write", WARNING),
    "RV013": ("register write-write race", ERROR),
    "RV014": ("temp defined more than once", ERROR),
    # -- RV02x: static hardware-discipline proofs ----------------------
    "RV020": ("memory port conflict (one access per cycle)", ERROR),
    "RV021": ("shared pool cell owned by concurrent arms", ERROR),
    "RV022": ("unsound initiation interval", ERROR),
    "RV023": ("pipelined loop with loop-carried memory dependence", ERROR),
    # -- RV03x: netlist structure --------------------------------------
    "RV030": ("multi-driven net", ERROR),
    "RV031": ("combinational loop", ERROR),
    "RV032": ("unreachable FSM state", WARNING),
    "RV033": ("dangling FSM transition", ERROR),
    "RV034": ("loop variable unresolvable on the controller chain", ERROR),
    # -- RV04x: SystemVerilog text lint --------------------------------
    "RV040": ("delay control in emitted Verilog", ERROR),
    "RV041": ("initial block outside memory init", ERROR),
    "RV042": ("multi-driver net in emitted Verilog", ERROR),
    # -- RV05x: observability (perf-counter bank) ----------------------
    "RV050": ("perf counter references unknown group or unit", ERROR),
    "RV051": ("perf counter address map malformed", ERROR),
    "RV052": ("profiled netlist counter bank incomplete", ERROR),
}


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One finding, traceable through the lowering layers."""
    code: str                       # stable RV0xx identifier
    message: str
    severity: str = ""              # defaults to the code's registry entry
    stage: str = ""                 # pipeline boundary that produced it
    provenance: Tuple[str, ...] = ()  # outermost -> innermost location

    def __post_init__(self):
        if self.code not in CODES:
            raise ValueError(f"unknown diagnostic code {self.code!r}")
        if not self.severity:
            object.__setattr__(self, "severity", CODES[self.code][1])
        elif self.severity not in (ERROR, WARNING, INFO):
            raise ValueError(f"unknown severity {self.severity!r}")

    @property
    def title(self) -> str:
        return CODES[self.code][0]

    @property
    def is_error(self) -> bool:
        return self.severity == ERROR

    def where(self) -> str:
        return " > ".join(self.provenance)

    def format(self) -> str:
        loc = f" [{self.where()}]" if self.provenance else ""
        stage = f" ({self.stage})" if self.stage else ""
        return f"{self.code} {self.severity}{stage}: {self.message}{loc}"


def diag(code: str, message: str, *, stage: str = "",
         provenance: Iterable[str] = (),
         severity: str = "") -> Diagnostic:
    """Build a :class:`Diagnostic` with the registry's default severity."""
    return Diagnostic(code=code, message=message, severity=severity,
                      stage=stage, provenance=tuple(provenance))


class VerificationError(RuntimeError):
    """A stage boundary rejected its artifact (error-severity findings).

    Carries the full :class:`DiagnosticReport` so callers (the lint CLI,
    tests) can render the structured findings, not just the message.
    """

    def __init__(self, report: "DiagnosticReport"):
        self.report = report
        errs = report.errors()
        head = "; ".join(d.format() for d in errs[:3])
        more = f" (+{len(errs) - 3} more)" if len(errs) > 3 else ""
        super().__init__(
            f"stage {report.stage!r}: {len(errs)} error-severity "
            f"diagnostic(s): {head}{more}")


@dataclasses.dataclass
class DiagnosticReport:
    """All findings of one verification pass at one stage boundary."""
    stage: str
    diagnostics: List[Diagnostic] = dataclasses.field(default_factory=list)
    wall_us: float = 0.0            # verifier wall-clock for this pass

    def add(self, d: Diagnostic) -> None:
        if not d.stage:
            d = dataclasses.replace(d, stage=self.stage)
        self.diagnostics.append(d)

    def extend(self, ds: Iterable[Diagnostic]) -> None:
        for d in ds:
            self.add(d)

    def __iter__(self) -> Iterator[Diagnostic]:
        return iter(self.diagnostics)

    def __len__(self) -> int:
        return len(self.diagnostics)

    def errors(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == ERROR]

    def warnings(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == WARNING]

    def by_code(self) -> Dict[str, List[Diagnostic]]:
        out: Dict[str, List[Diagnostic]] = {}
        for d in self.diagnostics:
            out.setdefault(d.code, []).append(d)
        return out

    @property
    def ok(self) -> bool:
        """True when no error-severity finding was recorded."""
        return not self.errors()

    def raise_if_errors(self) -> None:
        if not self.ok:
            raise VerificationError(self)

    def summary(self) -> str:
        ne, nw = len(self.errors()), len(self.warnings())
        return (f"{self.stage}: {ne} error(s), {nw} warning(s), "
                f"{len(self.diagnostics)} finding(s) "
                f"in {self.wall_us:.0f}us")

    def table(self) -> str:
        """Render the findings as a fixed-width diagnostic table."""
        return render_table([self])


def render_table(reports: Iterable["DiagnosticReport"]) -> str:
    """One table over several stage reports (the ``--verify`` CLI view)."""
    rows: List[Tuple[str, str, str, str, str]] = []
    for rep in reports:
        for d in rep:
            rows.append((d.code, d.severity, d.stage or rep.stage,
                         d.message, d.where()))
    if not rows:
        stages = ", ".join(r.stage for r in reports) or "-"
        return f"no findings (stages: {stages})"
    headers = ("code", "severity", "stage", "message", "provenance")
    widths = [len(h) for h in headers]
    for row in rows:
        for i, cell in enumerate(row):
            widths[i] = min(max(widths[i], len(cell)), 56)

    def fmt(row: Tuple[str, ...]) -> str:
        return "  ".join(
            (c[:53] + "..." if len(c) > 56 else c).ljust(widths[i])
            for i, c in enumerate(row)).rstrip()

    lines = [fmt(headers), fmt(tuple("-" * w for w in widths))]
    lines += [fmt(row) for row in rows]
    return "\n".join(lines)


class _Timer:
    """Context manager stamping ``wall_us`` onto a report."""

    def __init__(self, report: DiagnosticReport):
        self.report = report

    def __enter__(self) -> DiagnosticReport:
        self._t0 = time.perf_counter()
        return self.report

    def __exit__(self, *exc) -> None:
        self.report.wall_us = (time.perf_counter() - self._t0) * 1e6


def timed_report(stage: str) -> _Timer:
    """``with timed_report("post-lower") as rep: ...`` — stamps wall_us."""
    return _Timer(DiagnosticReport(stage))
