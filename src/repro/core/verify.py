"""Stage-boundary static verifier for the Calyx path.

The compile pipeline used to enforce its invariants *dynamically*: an
unsound initiation interval or a bank-port conflict surfaced as a runtime
raise deep inside ``core.sim``/``core.rtl_sim``, and the only static check
was the text-level ``verilog.lint``.  This module re-proves those
properties *statically* on every lowered artifact, at every stage boundary
of ``pipeline.compile_graph`` (post-lower, post-chaining, post-pipelining,
post-sharing, post-RTL), reporting structured :class:`~.diagnostics.
Diagnostic` findings with stable ``RV0xx`` codes and provenance chains
(control path -> group -> micro-op, or fsm -> state / block -> wire).

Check families (see ``diagnostics.CODES`` for the full table):

* **IR well-formedness** — dangling cell/group references, groups never
  reached from the control tree, ``CIf``/``CRepeat`` structural
  invariants, groups without micro-ops, unknown memories, loop variables
  used outside any binding ``repeat``.

* **Micro-op dataflow** — SSA temp discipline (use-before-def,
  redefinition), register def-use over the control tree (a read on some
  path with no prior write on that path), dead register writes, and
  write-write races (same register latched twice in one cycle).

* **Static hardware-discipline proofs** — the properties the simulators
  enforce per-cycle, proven over the stamped schedules instead: one
  access per cycle on every single-ported bank (within a group's
  activation window, under the same bank-affine proof the estimator's
  ``par`` conflict model uses), single-owner arbitration of shared pools
  across ``par`` arms, and modulo-II reservation soundness of every
  pipelined loop (recomputed from the body's offsets, not trusted from
  the annotation).

* **Netlist structure** — multi-driven wires and registers (including
  registers written from two provably-concurrent controllers),
  combinational loops in a block's dataflow order, unreachable FSM
  states, dangling FSM transitions, and loop-variable resolution along
  the controller parent chain.

The liveness side of the analysis is load-bearing, not advisory:
:func:`eliminate_dead` consumes exactly the unreachable-group/unused-cell
findings (``RV004``/``RV002``) to strip dead structure, and is provably
cycle-neutral — it never touches the control tree or any live group, and
``estimator.cycles`` only consults groups reachable from control.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Set, Tuple

from . import dataflow as D
from . import estimator, pipelining
from .affine import Program
from .calyx import (CIf, CNode, CPar, CRepeat, CSeq, Component, GEnable,
                    Group, PortAccess, referenced_groups)
from .diagnostics import DiagnosticReport, diag, timed_report
from .rtl import (DpBlock, DpMemRead, DpMemWrite, DpRegRead, DpRegWrite,
                  DpSelect, DpUnit, Netlist)

_EMPTY_SET: frozenset = frozenset()


class GroupCache:
    """Identity-keyed per-group summaries reused across stage boundaries.

    Successive boundaries of one compile mostly re-see the same
    :class:`Group` objects — chaining keeps unfused groups, pipelining
    rewrites only control nodes, and sharing rebuilds only groups that
    drive a pool — so their uop-level summaries (referenced cells, free
    loop vars, register reads/writes) and a clean :func:`_check_group`
    verdict carry over verbatim.  Entries hold a strong reference to
    the group, so a recycled ``id()`` can never produce a false hit.
    Scope a cache to ONE ``compile_graph`` run: summaries are
    environment-independent, but the clean verdict bakes in that run's
    program/banking context.
    """

    def __init__(self) -> None:
        self._entries: Dict[int, list] = {}
        # (component, live groups, used cells) of the last verified
        # boundary — lets eliminate_dead reuse the liveness the verifier
        # just computed instead of re-walking the same component
        self.liveness: Optional[tuple] = None
        # carry-over state of the last CLEAN boundary's control-tree
        # analyses: (control, summaries, live, pipe_nodes, cond_cells,
        # bound_vars, used, cells).  A later boundary whose control tree
        # is the same object and whose summaries' control-relevant
        # components are the same objects (pass-through groups, or
        # sharing's verified rebind) re-proves only the cell-table-
        # dependent checks.  Never stored after a dirty boundary, so
        # findings are always re-derived where they fired.
        self.flow_state: Optional[tuple] = None

    def _entry(self, g: Group) -> list:
        e = self._entries.get(id(g))
        if e is None or e[0] is not g:
            # group, summary, clean-refs, clean pipelined-IIs
            e = [g, None, None, set()]
            self._entries[id(g)] = e
        return e

    def summary(self, g: Group) -> tuple:
        """(used_cells, free_vars, first_uncovered_read, writes, reads)
        for one group, computed on first sight and reused afterwards.
        The sets are plain (not frozen) to keep the per-group cost at
        the allocation floor; consumers treat them as read-only."""
        e = self._entries.get(id(g))
        if e is not None and e[0] is g:
            s = e[1]
            if s is not None:
                return s
        e = self._entry(g)
        if e[1] is None:
            used: Set[str] = set(g.cells)
            free: Set[str] = set()
            first_read: Dict[str, int] = {}
            writes: Set[str] = set()
            reads: Set[str] = set()
            for i, u in enumerate(g.uops):
                if isinstance(u, D.UAlu):
                    if u.cell:
                        used.add(u.cell)
                elif isinstance(u, D.URegRead):
                    used.add(f"reg_{u.reg}")
                    reads.add(u.reg)
                    if u.reg not in writes and u.reg not in first_read:
                        first_read[u.reg] = i
                elif isinstance(u, D.URegWrite):
                    used.add(f"reg_{u.reg}")
                    writes.add(u.reg)
                elif isinstance(u, (D.UMemRead, D.UMemWrite)):
                    for ix in u.idxs:
                        free |= ix.free_vars()
                elif isinstance(u, D.USelect):
                    free |= u.cond.expr.free_vars()
            e[1] = (used, free, first_read, writes, reads)
        return e[1]

    def clean_refs(self, g: Group) -> Optional[tuple]:
        """The (cell_refs, alu_refs) a previously-clean group must still
        resolve against the current cell table, or None if unchecked."""
        return self._entry(g)[2]

    def mark_clean(self, g: Group, refs: tuple) -> None:
        self._entry(g)[2] = refs

    def pipe_clean(self, g: Group, ii: int) -> bool:
        """Whether this group body already passed the modulo-II re-proof
        at this initiation interval."""
        return ii in self._entry(g)[3]

    def mark_pipe_clean(self, g: Group, ii: int) -> None:
        self._entry(g)[3].add(ii)

    def transfer_rebound(self, old_groups: Dict[str, Group],
                         new_groups: Dict[str, Group],
                         bound: Dict[str, str]) -> None:
        """Carry summaries and clean verdicts across the sharing rebind.

        ``share_cells`` rebuilds exactly the groups that drive a pool,
        changing nothing but cell bindings (``Group.cells`` entries and
        ``UAlu.cell``, both through ``bound``).  This *verifies* that
        claim micro-op by micro-op — identical objects for non-ALU uops,
        field-equal-modulo-``bound`` for ALU uops — and only then
        transfers the old group's summary (with its used-cell set
        rebound) and clean verdict (with its ALU refs rebound; the
        post-sharing boundary still re-resolves them against the pooled
        cell table).  A group that fails the equivalence check simply
        stays uncached and pays the full re-check — never unsound, just
        slower.  Pipelined-II verdicts do not transfer: pooling changes
        the unit population the modulo reservation argues about."""
        for name, ng in new_groups.items():
            og = old_groups.get(name)
            if og is None or ng is og:
                continue
            e_old = self._entries.get(id(og))
            if e_old is None or e_old[0] is not og:
                continue
            s, refs = e_old[1], e_old[2]
            if s is None and refs is None:
                continue
            if (len(ng.uops) != len(og.uops)
                    or len(ng.cells) != len(og.cells)
                    or any(nc != bound.get(oc, oc)
                           for nc, oc in zip(ng.cells, og.cells))):
                continue
            ok = True
            for nu, ou in zip(ng.uops, og.uops):
                if nu is ou:
                    continue
                if not (type(nu) is D.UAlu and type(ou) is D.UAlu
                        and nu.cell == bound.get(ou.cell, ou.cell)
                        and nu.dst == ou.dst and nu.op == ou.op
                        and nu.a == ou.a and nu.b == ou.b
                        and nu.off == ou.off):
                    ok = False
                    break
            if not ok:
                continue
            e = self._entry(ng)
            if s is not None and e[1] is None:
                e[1] = ({bound.get(c, c) for c in s[0]},
                        s[1], s[2], s[3], s[4])
            if refs is not None and e[2] is None:
                alu_s = {bound.get(c, c) for c in refs[1]}
                union_s = set(ng.cells)
                union_s.update(alu_s)
                e[2] = (ng.cells, alu_s, union_s)


# ---------------------------------------------------------------------------
# Control-tree walking with provenance paths
# ---------------------------------------------------------------------------


def _walk(node: CNode, path: Tuple[str, ...]):
    """Yield every control node with its provenance path, depth-first in
    document order.  Iterative: nested ``yield from`` chains would make
    deep control trees quadratic in yield count."""
    stack = [(node, path)]
    while stack:
        node, path = stack.pop()
        yield node, path
        if isinstance(node, (CSeq, CPar)):
            tag = "seq" if isinstance(node, CSeq) else "par"
            for i in range(len(node.children) - 1, -1, -1):
                stack.append((node.children[i], path + (f"{tag}[{i}]",)))
        elif isinstance(node, CRepeat):
            stack.append((node.body,
                          path + (f"repeat({node.var or '_'})",)))
        elif isinstance(node, CIf):
            stack.append((node.els, path + ("if.else",)))
            stack.append((node.then, path + ("if.then",)))


def _walk_nodes(node: CNode):
    """Yield every control node depth-first in document order, without
    materializing provenance paths — the clean-path walker.  Checks that
    need a path for a finding collect the offending nodes and rebuild
    their paths afterwards with one :func:`_walk` (findings are rare;
    per-node path tuples on every boundary of every compile are not)."""
    stack = [node]
    while stack:
        node = stack.pop()
        yield node
        t = type(node)
        if t is CSeq or t is CPar:
            stack.extend(reversed(node.children))
        elif t is CRepeat:
            stack.append(node.body)
        elif t is CIf:
            stack.append(node.els)
            stack.append(node.then)


def _paths_of(control: CNode, nodes) -> Dict[int, Tuple[str, ...]]:
    """id(node) -> provenance path of its first occurrence, for exactly
    the nodes a deferred finding needs."""
    want = {id(n) for n in nodes}
    out: Dict[int, Tuple[str, ...]] = {}
    for node, path in _walk(control, ()):
        i = id(node)
        if i in want and i not in out:
            out[i] = path
            if len(out) == len(want):
                break
    return out


def _groups_under(node: CNode) -> Set[str]:
    return referenced_groups(node)


# ---------------------------------------------------------------------------
# Liveness: reachable groups and the cells they keep alive
# ---------------------------------------------------------------------------


def _cond_cells(control: CNode) -> Set[str]:
    out: Set[str] = set()
    for node, _ in _walk(control, ()):
        if isinstance(node, CIf):
            out.update(node.cond_cells)
    return out


def _bound_vars(control: CNode) -> Set[str]:
    out: Set[str] = set()
    for node, _ in _walk(control, ()):
        if isinstance(node, CRepeat) and node.var:
            out.add(node.var)
    return out


def _used_cells(comp: Component, live: Set[str],
                summaries: Dict[str, tuple],
                cond_cells: Optional[Set[str]] = None,
                bound_vars: Optional[Set[str]] = None) -> Set[str]:
    """Cells a live design actually needs: everything referenced from a
    reachable group (cell lists, FU invocations, registers), if-condition
    hardware, index counters of bound loop vars, and every memory bank
    (part of the host interface regardless of reachability).  Callers
    that already walked the control tree pass the condition cells and
    bound loop vars they collected; others pay for the two walks here."""
    used: Set[str] = set()
    for name in live:
        s = summaries.get(name)
        if s is None:
            continue
        used |= s[0]
    used |= (_cond_cells(comp.control) if cond_cells is None
             else cond_cells)
    for var in (_bound_vars(comp.control) if bound_vars is None
                else bound_vars):
        used.add(f"idx_{var}")
    for cell in comp.cells.values():
        if cell.kind == "mem_bank":
            used.add(cell.name)
    return used


# ---------------------------------------------------------------------------
# IR well-formedness (RV00x)
# ---------------------------------------------------------------------------


def _check_structure(comp: Component, rep: DiagnosticReport,
                     summaries: Dict[str, tuple]) -> tuple:
    """Control-tree invariants; returns ``(live, pipe_nodes, used,
    cond_cells, bound_vars)`` — the reachable group set, every pipelined
    ``repeat`` node (for :func:`_check_pipelined`, which then needs no
    walk of its own), the used-cell set (for :func:`eliminate_dead` to
    reuse), and the walk's condition-cell / bound-loop-var collections
    (for the carry-over skip at a later boundary).  One path-free walk collects
    everything the liveness computation needs (reached groups, condition
    cells, bound loop vars) alongside the checks; provenance paths are
    rebuilt only for the nodes findings actually landed on."""
    live: Set[str] = set()
    cond_cells: Set[str] = set()
    bound: Set[str] = set()
    pipe_nodes: List[CRepeat] = []
    deferred: List[tuple] = []      # (code, message, node, path suffix)
    for node in _walk_nodes(comp.control):
        t = type(node)
        if t is GEnable:
            if node.group not in comp.groups:
                deferred.append(("RV003",
                                 f"control enables undefined group "
                                 f"{node.group!r}", node, ()))
            else:
                live.add(node.group)
        elif t is CIf:
            if node.cond is None:
                deferred.append(("RV005",
                                 "if-node carries no lowered condition",
                                 node, ()))
            cond_cells.update(node.cond_cells)
            for c in node.cond_cells:
                if c not in comp.cells:
                    deferred.append(("RV001",
                                     f"if condition references undefined "
                                     f"cell {c!r}", node, (f"cell:{c}",)))
        elif t is CRepeat:
            if node.var:
                bound.add(node.var)
            if node.extent < 0 or node.ii < 0:
                deferred.append(("RV006",
                                 f"repeat has negative extent/ii "
                                 f"({node.extent}/{node.ii})", node, ()))
            elif node.ii > 0 and not isinstance(node.body, GEnable):
                deferred.append(("RV006",
                                 f"pipelined repeat (ii={node.ii}) body "
                                 f"must be a single group", node, ()))
            if node.ii > 0 and isinstance(node.body, GEnable):
                pipe_nodes.append(node)
    if deferred:
        paths = _paths_of(comp.control, [n for _, _, n, _ in deferred])
        for code, msg, node, suffix in deferred:
            rep.add(diag(code, msg,
                         provenance=paths.get(id(node), ()) + suffix))
    for name in comp.groups:
        if name not in live:
            rep.add(diag("RV004",
                         f"group {name!r} is never enabled from the "
                         f"control tree", provenance=(f"group:{name}",)))
    used = _used_cells(comp, live, summaries, cond_cells, bound)
    for name, cell in comp.cells.items():
        if name not in used:
            rep.add(diag("RV002",
                         f"cell {name!r} ({cell.kind}) is referenced by no "
                         f"reachable group or condition",
                         provenance=(f"cell:{name}",)))
    return live, pipe_nodes, used, cond_cells, bound


def _check_bound_vars(comp: Component, rep: DiagnosticReport,
                      summaries: Dict[str, tuple]) -> None:
    """Every loop var a group's addresses/conditions read must be bound by
    an enclosing ``repeat`` (RV009).  Path-free recursion; provenance is
    rebuilt only for nodes with findings."""
    findings: List[tuple] = []      # (node, group name or None, var)

    def walk(node: CNode, bound: Set[str]) -> None:
        t = type(node)
        if t is GEnable:
            s = summaries.get(node.group)
            if s is None:
                return
            free = s[1]
            if free <= bound:
                return
            for var in sorted(free - bound):
                findings.append((node, node.group, var))
        elif t is CSeq or t is CPar:
            for ch in node.children:
                walk(ch, bound)
        elif t is CRepeat:
            inner = bound | {node.var} if node.var else bound
            walk(node.body, inner)
        elif t is CIf:
            if node.cond is not None:
                fv = node.cond.expr.free_vars()
                if not fv <= bound:
                    for var in sorted(fv - bound):
                        findings.append((node, None, var))
            walk(node.then, bound)
            walk(node.els, bound)

    walk(comp.control, set())
    if findings:
        paths = _paths_of(comp.control, [n for n, _, _ in findings])
        for node, gname, var in findings:
            path = paths.get(id(node), ())
            if gname is not None:
                rep.add(diag("RV009",
                             f"group {gname!r} addresses loop var {var!r} "
                             f"but no enclosing repeat binds it",
                             provenance=path + (f"group:{gname}",
                                                f"var:{var}")))
            else:
                rep.add(diag("RV009",
                             f"if condition reads loop var {var!r} "
                             f"outside any binding repeat",
                             provenance=path + (f"var:{var}",)))


# ---------------------------------------------------------------------------
# Per-group micro-op dataflow + port discipline (RV01x, RV020)
# ---------------------------------------------------------------------------


def _uop_port(mem: str, idxs, is_store: bool,
              factors: Dict[str, tuple]) -> PortAccess:
    """Rebuild the PortAccess of one memory micro-op — the same bank/key
    split ``calyx._Lower._access`` records, so the static conflict test
    below matches the estimator's bank-affine model exactly."""
    if factors.get(mem):
        bank_e = idxs[0]
        bank = bank_e.const_value() if bank_e.is_const() else None
        key_exprs = idxs[1:]
        bank_expr = None if bank is not None else bank_e
    else:
        bank, key_exprs, bank_expr = 0, list(idxs), None
    free: Set[str] = set()
    for ke in key_exprs:
        free |= ke.free_vars()
    if bank_expr is not None:
        free |= bank_expr.free_vars()
    return PortAccess(mem, bank, tuple(ke.key() for ke in key_exprs),
                      frozenset(free), is_store, bank_expr=bank_expr)


def _use_before_def(rep, g, i, u, t):
    rep.add(diag("RV010", f"temp t{t} read before definition",
                 provenance=(f"group:{g.name}",
                             f"uop[{i}]:{type(u).__name__}")))


def _redefined(rep, g, i, u, t):
    rep.add(diag("RV014", f"temp t{t} defined more than once",
                 provenance=(f"group:{g.name}",
                             f"uop[{i}]:{type(u).__name__}")))


def _check_mem_bounds(rep, prog, factors, g, i, u):
    """RV008 on one memory micro-op; returns False when the memory is
    undeclared (the access then never enters the port-conflict table)."""
    if u.mem not in prog.mems:
        rep.add(diag("RV008",
                     f"access to undeclared memory {u.mem!r}",
                     provenance=(f"group:{g.name}",
                                 f"uop[{i}]:{type(u).__name__}")))
        return False
    if factors.get(u.mem):
        be = u.idxs[0]
        if be.is_const():
            bank = be.const_value()
            nbanks = prog.mems[u.mem].shape[0]
            if not 0 <= bank < nbanks:
                rep.add(diag(
                    "RV008",
                    f"bank index {bank} out of range for "
                    f"memory {u.mem!r} ({nbanks} banks)",
                    provenance=(f"group:{g.name}",
                                f"uop[{i}]:{type(u).__name__}")))
    return True


def _check_group(comp: Component, prog: Optional[Program], g: Group,
                 rep: DiagnosticReport,
                 distinct_cache: Optional[dict] = None,
                 cache: Optional[GroupCache] = None,
                 entry: Optional[list] = None) -> tuple:
    """Check one group; returns ``(cell_refs, alu_refs, all_refs)`` — the
    names a clean verdict assumed present in ``comp.cells`` (what a cache
    hit at a later boundary must re-resolve against the then-current
    table; ``cell_refs`` is the group's own cell list, ``all_refs`` the
    precomputed union a hit tests with one subset comparison).  The same micro-op pass also computes the group's
    :meth:`GroupCache.summary` and stores it on ``cache`` — the summary
    is purely descriptive, so it is valid even when findings fire."""
    if distinct_cache is None:
        distinct_cache = {}
    alu_refs: Set[str] = set()
    free: Set[str] = set()
    first_read: Dict[str, int] = {}
    writes: Set[str] = set()
    reads: Set[str] = set()
    if not g.uops:
        rep.add(diag("RV007",
                     f"group {g.name!r} carries no micro-ops — the "
                     f"component has no executable datapath semantics",
                     provenance=(f"group:{g.name}",)))
    cells = comp.cells
    for c in g.cells:
        if c not in cells:
            rep.add(diag("RV001",
                         f"group {g.name!r} references undefined cell "
                         f"{c!r}", provenance=(f"group:{g.name}",
                                               f"cell:{c}")))
    factors: Dict[str, tuple] = comp.meta.get("bank_factors", {})
    defined: Set[int] = set()
    reg_write_offs: Dict[Tuple[str, int], int] = {}
    busy: Dict[Tuple[int, str], list] = {}
    # direct type dispatch ordered by measured frequency (reg reads and
    # writes dominate lowered groups; selects are rare), provenance built
    # only on a finding — this loop runs over every micro-op of every
    # group at every boundary and must stay cheap on the (overwhelmingly
    # common) clean path
    for i, u in enumerate(g.uops):
        tu = type(u)
        if tu is D.URegRead:
            if u.dst in defined:
                _redefined(rep, g, i, u, u.dst)
            defined.add(u.dst)
            reads.add(u.reg)
            if u.reg not in writes and u.reg not in first_read:
                first_read[u.reg] = i
        elif tu is D.URegWrite:
            if u.src not in defined:
                _use_before_def(rep, g, i, u, u.src)
            writes.add(u.reg)
            key = (u.reg, u.off)
            if key in reg_write_offs:
                rep.add(diag(
                    "RV013",
                    f"register {u.reg!r} latched twice at cycle offset "
                    f"{u.off} (also uop[{reg_write_offs[key]}])",
                    provenance=(f"group:{g.name}", f"uop[{i}]:URegWrite")))
            reg_write_offs[key] = i
        elif tu is D.UAlu:
            if u.a not in defined:
                _use_before_def(rep, g, i, u, u.a)
            if u.b is not None and u.b not in defined:
                _use_before_def(rep, g, i, u, u.b)
            if u.dst in defined:
                _redefined(rep, g, i, u, u.dst)
            defined.add(u.dst)
            if u.cell:
                alu_refs.add(u.cell)
                if u.cell not in cells:
                    rep.add(diag(
                        "RV001",
                        f"micro-op invokes undefined unit {u.cell!r}",
                        provenance=(f"group:{g.name}", f"uop[{i}]:UAlu")))
        elif tu is D.UMemRead:
            if u.dst in defined:
                _redefined(rep, g, i, u, u.dst)
            defined.add(u.dst)
            for ix in u.idxs:
                free.update(ix.free_vars())
            if (prog is None
                    or _check_mem_bounds(rep, prog, factors, g, i, u)):
                busy.setdefault((u.off, u.mem), []).append((i, u, False))
        elif tu is D.UConst:
            if u.dst in defined:
                _redefined(rep, g, i, u, u.dst)
            defined.add(u.dst)
        elif tu is D.UMemWrite:
            if u.src not in defined:
                _use_before_def(rep, g, i, u, u.src)
            for ix in u.idxs:
                free.update(ix.free_vars())
            if (prog is None
                    or _check_mem_bounds(rep, prog, factors, g, i, u)):
                busy.setdefault((u.off, u.mem), []).append((i, u, True))
        elif tu is D.USelect:
            if u.a not in defined:
                _use_before_def(rep, g, i, u, u.a)
            if u.b not in defined:
                _use_before_def(rep, g, i, u, u.b)
            if u.dst in defined:
                _redefined(rep, g, i, u, u.dst)
            defined.add(u.dst)
            free.update(u.cond.expr.free_vars())
    # one access per (memory, bank) per cycle within the activation window;
    # all accesses in one group share an environment, so structural key
    # equality means address equality and the estimator's pairwise test
    # (distinct banks / broadcast loads) applies verbatim.  Structurally
    # equal bank signatures short-circuit to "same bank"; only genuinely
    # different runtime bank expressions pay for the mod-residue
    # distinctness proof, memoized across the component's groups.  Port
    # accesses (and their structural keys) are materialized only for the
    # rare (cycle, memory) buckets holding more than one access.
    multi = ([kv for kv in busy.items() if len(kv[1]) > 1]
             if busy else None)
    if multi:
        multi.sort()
    for (off, _mem), raw in multi or ():
        accs = []
        for i, u, is_store in raw:
            pa = _uop_port(u.mem, u.idxs, is_store, factors)
            sig = pa.bank if pa.bank is not None else (
                pa.bank_expr.key() if pa.bank_expr is not None else None)
            accs.append((pa, i, sig))
        for x in range(len(accs)):
            pa, i, sa = accs[x]
            for y in range(x + 1, len(accs)):
                pb, j, sb = accs[y]
                if sa == sb and sa is not None:
                    distinct = False       # same bank under the shared env
                elif pa.bank is not None and pb.bank is not None:
                    distinct = True        # two different constant banks
                else:
                    ck = (sa, sb)
                    distinct = distinct_cache.get(ck)
                    if distinct is None:
                        distinct = estimator.banks_provably_distinct(pa, pb)
                        distinct_cache[ck] = distinct
                if distinct:
                    continue
                if (not pa.is_store and not pb.is_store
                        and pa.key is not None and pa.key == pb.key):
                    continue               # broadcast-equal loads
                rep.add(diag(
                    "RV020",
                    f"memory {pa.mem!r} port contended at cycle offset "
                    f"{off}: uop[{i}] vs uop[{j}] (one access per "
                    f"cycle)", provenance=(f"group:{g.name}",
                                           f"uop[{i}]+uop[{j}]")))
    refs_u = set(g.cells)
    if alu_refs:
        refs_u.update(alu_refs)
    e = entry if entry is not None else (
        cache._entry(g) if cache is not None else None)
    if e is not None and e[1] is None:
        # the used-cell set is the ref union plus the register cells the
        # group touches — assembled once here, not per micro-op
        used = set(refs_u)
        for r in writes:
            used.add(f"reg_{r}")
        for r in reads:
            used.add(f"reg_{r}")
        e[1] = (used, free, first_read, writes, reads)
    return (g.cells, alu_refs, refs_u)


# ---------------------------------------------------------------------------
# Register def-use over the control tree (RV011 / RV012)
# ---------------------------------------------------------------------------


def _check_reg_flow(comp: Component, rep: DiagnosticReport,
                    summaries: Dict[str, tuple]) -> None:
    """Forward must-write analysis: a register read is clean only when a
    write dominates it on every path.  ``par`` arms see only writes from
    before the fork (arms are concurrent); ``if`` joins intersect; a
    ``repeat`` body is flowed once — iteration 0 is the binding case."""
    reported: Set[Tuple[str, str]] = set()
    findings: List[Tuple[str, str, int]] = []

    # ``flow(node, layers)`` returns the set of registers node must-writes
    # (its delta); ``layers`` is the read-only chain of ancestor write
    # sets a read resolves against.  Deltas stay small and layers are
    # shared, never copied — forking a ``par``/``if`` arm costs nothing,
    # and a linear ``seq`` chain appends one accumulator layer instead of
    # rebuilding a growing union per group (quadratic in chain length).
    # Provenance paths are reconstructed only if something actually fired.
    def flow(node: CNode, layers: tuple) -> Set[str]:
        t = type(node)
        if t is GEnable:
            s = summaries.get(node.group)
            if s is None:
                return _EMPTY_SET
            first_read, writes = s[2], s[3]
            if first_read:
                for reg in first_read:
                    for have in layers:
                        if reg in have:
                            break
                    else:
                        if (node.group, reg) not in reported:
                            reported.add((node.group, reg))
                            findings.append(
                                (node.group, reg, first_read[reg]))
            # callers only union the returned delta, never mutate it,
            # so the summary's own write set is safe to hand back
            return writes
        if t is CSeq:
            acc: Set[str] = set()
            inner = layers + (acc,)
            for ch in node.children:
                acc |= flow(ch, inner)
            return acc
        if t is CPar:
            # arms are concurrent: each sees only pre-fork writes
            out: Set[str] = set()
            for ch in node.children:
                out |= flow(ch, layers)
            return out
        if t is CIf:
            return flow(node.then, layers) & flow(node.els, layers)
        if t is CRepeat:
            if node.extent <= 0:
                return _EMPTY_SET
            return flow(node.body, layers)
        return _EMPTY_SET

    flow(comp.control, ())
    if findings:
        first_path: Dict[str, Tuple[str, ...]] = {}
        for node, path in _walk(comp.control, ()):
            if isinstance(node, GEnable) and node.group not in first_path:
                first_path[node.group] = path
        for gname, reg, i in findings:
            rep.add(diag(
                "RV011",
                f"register {reg!r} read with no prior write on this path",
                provenance=first_path.get(gname, ())
                + (f"group:{gname}", f"uop[{i}]:URegRead")))


def _check_dead_writes(comp: Component, live: Set[str],
                       rep: DiagnosticReport,
                       summaries: Dict[str, tuple]) -> None:
    """A register no reachable group ever reads makes every write to it
    dead (RV012, warning) — the liveness input to dead-cell elimination."""
    read: Set[str] = set()
    for name in live:
        s = summaries.get(name)
        if s is not None:
            read |= s[4]
    for name in sorted(live):
        g = comp.groups.get(name)
        s = summaries.get(name)
        if g is None or s is None or s[3] <= read:
            continue                       # every written reg is read
        for i, u in enumerate(g.uops):
            if isinstance(u, D.URegWrite) and u.reg not in read:
                rep.add(diag(
                    "RV012",
                    f"register {u.reg!r} is written but never read by any "
                    f"reachable group",
                    provenance=(f"group:{g.name}",
                                f"uop[{i}]:URegWrite")))


# ---------------------------------------------------------------------------
# Static hardware-discipline proofs (RV021 / RV022 / RV023)
# ---------------------------------------------------------------------------


def _check_pools(comp: Component, rep: DiagnosticReport,
                 summaries: Dict[str, tuple]) -> None:
    """Static twin of the simulators' single-owner arbitration: no shared
    pool cell may be reachable from two arms of one ``par``.  Skipped
    entirely pre-binding (no pooled cells exist).  One bottom-up pass:
    each subtree reports the pool cells reachable under it (a group's
    used-cell summary intersected with the pool names — uop-level FU
    invocations count, matching what the simulators arbitrate), and
    every ``par`` node checks its arms' sets pairwise on the way up."""
    pooled_names = {n for n, c in comp.cells.items() if c.users > 1}
    if not pooled_names:
        return
    empty: frozenset = frozenset()
    findings: List[tuple] = []      # (par node, arm i, arm j, overlap)

    def gather(node: CNode) -> Set[str]:
        t = type(node)
        if t is GEnable:
            s = summaries.get(node.group)
            return s[0] & pooled_names if s is not None else empty
        if t is CSeq:
            out: Set[str] = set()
            for ch in node.children:
                out |= gather(ch)
            return out
        if t is CPar:
            arm_pools = [gather(ch) for ch in node.children]
            busy = [a for a in arm_pools if a]
            if len(busy) > 1:
                for i in range(len(arm_pools)):
                    for j in range(i + 1, len(arm_pools)):
                        both = arm_pools[i] & arm_pools[j]
                        if both:
                            findings.append((node, i, j, both))
            out = set()
            for a in arm_pools:
                out |= a
            return out
        if t is CRepeat:
            return gather(node.body)
        if t is CIf:
            return gather(node.then) | gather(node.els)
        return empty

    gather(comp.control)
    if findings:
        paths = _paths_of(comp.control, [n for n, _, _, _ in findings])
        for node, i, j, both in findings:
            rep.add(diag(
                "RV021",
                f"shared cell(s) {sorted(both)} reachable from par arms "
                f"{i} and {j} — single-owner arbitration cannot hold",
                provenance=paths.get(id(node), ())
                + (f"par[{i}]+par[{j}]",)))


def _check_pipelined(comp: Component, rep: DiagnosticReport,
                     cache: GroupCache,
                     pipe_nodes: List[CRepeat]) -> None:
    """Re-prove every annotated II from the body's stamped offsets — the
    modulo reservation table, register recurrence floor, and iterative-
    unit floor the pipelining pass claims to have honored.  A (group,
    ii) pair that already passed at an earlier boundary is not re-proven
    (the proof reads only the group's own stamped schedule).  Works off
    the pipelined-repeat list :func:`_check_structure` collected, so no
    extra control walk on the clean path."""
    findings: List[tuple] = []      # (node, group, code, message)
    for node in pipe_nodes:
        g = comp.groups.get(node.body.group)
        if g is None or not g.uops:
            continue
        if cache.pipe_clean(g, node.ii):
            continue
        rows = pipelining.port_offsets(comp, g)
        if rows is None:
            findings.append((node, g, "RV023",
                             f"loop over {node.var or '_'!r} is pipelined "
                             f"(ii={node.ii}) but its body both reads and "
                             f"writes one memory — a loop-carried "
                             f"dependence pipelining does not analyze"))
            continue
        floor = max(pipelining.register_floor(g),
                    pipelining.unit_floor(comp, g))
        if node.ii < floor:
            findings.append((node, g, "RV022",
                             f"ii={node.ii} is below the loop-carried "
                             f"recurrence / iterative-unit floor {floor}"))
        elif not pipelining.rows_admit(rows, node.ii):
            findings.append((node, g, "RV022",
                             f"ii={node.ii} violates the body's modulo "
                             f"port reservation (same-bank offsets collide "
                             f"mod ii)"))
        else:
            cache.mark_pipe_clean(g, node.ii)
    if findings:
        paths = _paths_of(comp.control, [n for n, _, _, _ in findings])
        for node, g, code, msg in findings:
            rep.add(diag(code, msg,
                         provenance=paths.get(id(node), ())
                         + (f"group:{g.name}",)))


# ---------------------------------------------------------------------------
# Component entry point
# ---------------------------------------------------------------------------


def _flow_identical(old: Dict[str, tuple], new: Dict[str, tuple]) -> bool:
    """Whether two boundaries' summaries agree on every control-relevant
    component (free vars, first reads, writes, reads) — by object
    identity, so pass-through groups and sharing's verified rebind (which
    reuses those components) hit, while any recomputed summary
    conservatively misses.  ``s[0]`` (used cells) is deliberately not
    compared: the checks that read it re-run at every boundary."""
    if len(old) != len(new):
        return False
    for name, a in new.items():
        b = old.get(name)
        if b is None:
            return False
        if a is b:
            continue
        if not (a[1] is b[1] and a[2] is b[2]
                and a[3] is b[3] and a[4] is b[4]):
            return False
    return True


def verify_component(comp: Component, prog: Optional[Program] = None, *,
                     stage: str = "post-lower",
                     cache: Optional[GroupCache] = None
                     ) -> DiagnosticReport:
    """Statically verify one lowered component; never raises — callers
    decide via :meth:`DiagnosticReport.raise_if_errors`.

    Pass one :class:`GroupCache` across the successive boundaries of a
    single compile: group objects a pass carried over unchanged skip
    straight to re-resolving their cell references against the current
    cell table instead of re-proving the whole per-group check suite.
    """
    if cache is None:
        cache = GroupCache()
    with timed_report(stage) as rep:
        # group-local checks first: a cache hit is one set-subset test
        # against the current cell table; a miss runs the full check and
        # computes the group's summary in the same micro-op pass.  The
        # summaries dict the control walkers below consume is filled here
        # too — one cache access per group, not two.
        ckeys = set(comp.cells)
        distinct_cache: dict = {}
        summaries: Dict[str, tuple] = {}
        for name, g in comp.groups.items():
            e = cache._entry(g)
            refs = e[2]
            if refs is None:
                before = len(rep)
                refs = _check_group(comp, prog, g, rep,
                                    distinct_cache, cache, e)
                if len(rep) == before:
                    e[2] = refs
            elif not refs[2] <= ckeys:
                for c in sorted(set(refs[0]) - ckeys):
                    rep.add(diag("RV001",
                                 f"group {g.name!r} references undefined "
                                 f"cell {c!r}",
                                 provenance=(f"group:{g.name}",
                                             f"cell:{c}")))
                for c in sorted(refs[1] - ckeys):
                    rep.add(diag("RV001",
                                 f"micro-op invokes undefined unit {c!r}",
                                 provenance=(f"group:{g.name}",)))
            s = e[1]
            summaries[name] = s if s is not None else cache.summary(g)
        # control-tree analyses: skipped when this boundary's control is
        # the same object the last clean boundary walked and the
        # summaries' control-relevant parts carried over — then only the
        # cell-table-dependent checks (RV001 above, RV002 here, pools,
        # pipelined floors) can change verdicts
        fs = cache.flow_state
        carried = False
        if (fs is not None and fs[0] is comp.control
                and _flow_identical(fs[1], summaries)):
            live, pipe_nodes, cond_cells, bvars = fs[2], fs[3], fs[4], fs[5]
            if fs[7] is comp.cells:
                used = fs[6]          # same cell table too: nothing to redo
                carried = True
            elif fs[4] <= ckeys:
                used = _used_cells(comp, live, summaries, cond_cells, bvars)
                for name, cell in comp.cells.items():
                    if name not in used:
                        rep.add(diag(
                            "RV002",
                            f"cell {name!r} ({cell.kind}) is referenced "
                            f"by no reachable group or condition",
                            provenance=(f"cell:{name}",)))
                carried = True
        if not carried:
            (live, pipe_nodes, used,
             cond_cells, bvars) = _check_structure(comp, rep, summaries)
            _check_bound_vars(comp, rep, summaries)
            _check_reg_flow(comp, rep, summaries)
            _check_dead_writes(comp, live, rep, summaries)
        _check_pools(comp, rep, summaries)
        _check_pipelined(comp, rep, cache, pipe_nodes)
    cache.liveness = (comp, live, used)
    cache.flow_state = ((comp.control, summaries, live, pipe_nodes,
                         cond_cells, bvars, used, comp.cells)
                        if not rep else None)
    return rep


# ---------------------------------------------------------------------------
# Dead-cell / dead-group elimination (consumes RV002/RV004 liveness)
# ---------------------------------------------------------------------------


def eliminate_dead(comp: Component, cache: Optional[GroupCache] = None
                   ) -> Tuple[Component, Dict[str, List[str]]]:
    """Strip groups unreachable from control and cells nothing live
    references.  Cycle-neutral by construction: the control tree and every
    live group are reused untouched, and ``estimator.cycles`` only ever
    consults groups reachable from control.  Memory banks and the index
    counters of bound loop vars always survive (host interface /
    controller state).  Returns ``(component, removed)`` where ``removed``
    maps ``"groups"``/``"cells"`` to the stripped names (both empty on a
    clean design — the pass then returns the input component unchanged).
    """
    cache = cache or GroupCache()
    lv = cache.liveness
    if lv is not None and lv[0] is comp:
        # the verifier just walked this exact component: reuse its
        # liveness instead of recomputing the same reachability + use set
        live, used = lv[1], lv[2]
    else:
        live = referenced_groups(comp.control)
        used = _used_cells(comp, live, {name: cache.summary(g)
                                        for name, g in comp.groups.items()})
    dead_groups = sorted(set(comp.groups) - live)
    dead_cells = sorted(c for c in comp.cells if c not in used)
    removed = {"groups": dead_groups, "cells": dead_cells}
    if not dead_groups and not dead_cells:
        return comp, removed
    out = Component(
        comp.name,
        {n: c for n, c in comp.cells.items() if n in used},
        {n: g for n, g in comp.groups.items() if n in live},
        comp.control, meta=dict(comp.meta))
    out.meta["dead_eliminated"] = removed
    return out, removed


# ---------------------------------------------------------------------------
# Netlist checks (RV03x)
# ---------------------------------------------------------------------------


def _fsm_paths(net: Netlist) -> Dict[int, List[Tuple[int, int, int]]]:
    """fid -> fork-edge path from the root: [(parent_fid, par_state, fid)].

    Two controllers are concurrent iff their paths first diverge at the
    *same* par state into *different* children; diverging at different
    states of one FSM means they run at different times, and an
    ancestor/descendant pair never overlaps on group states (the parent
    sits in its par state while the child runs).
    """
    edge: Dict[int, Tuple[int, int]] = {}
    for f in net.fsms:
        for st in f.states:
            if st.kind == "par":
                for ch in st.children:
                    if 0 <= ch < len(net.fsms):
                        edge[ch] = (f.fid, st.index)
    paths: Dict[int, List[Tuple[int, int, int]]] = {}
    for f in net.fsms:
        p: List[Tuple[int, int, int]] = []
        cur, seen = f.fid, set()
        while cur in edge and cur not in seen:
            seen.add(cur)
            pf, si = edge[cur]
            p.append((pf, si, cur))
            cur = pf
        paths[f.fid] = list(reversed(p))
    return paths


def _fsms_concurrent(pa: List[Tuple[int, int, int]],
                     pb: List[Tuple[int, int, int]]) -> bool:
    for ea, eb in zip(pa, pb):
        if ea == eb:
            continue
        return ea[0] == eb[0] and ea[1] == eb[1]
    return False


def _state_prov(f, st) -> Tuple[str, str]:
    """Provenance of one FSM state — built only next to a finding; the
    state loop visits every controller state of every design."""
    return (f"fsm{f.fid}", f"state[{st.index}]:{st.kind}")


def _check_fsms(net: Netlist, rep: DiagnosticReport) -> None:
    nfsms = len(net.fsms)
    for f in net.fsms:
        nstates = len(f.states)
        succ: Dict[int, List[int]] = {}
        for st in f.states:
            nexts: List[int] = []
            v = st.next
            if v is not None:
                if 0 <= v < nstates:
                    nexts.append(v)
                else:
                    rep.add(diag("RV033",
                                 f"next -> state {v} out of range "
                                 f"(fsm has {nstates} states)",
                                 provenance=_state_prov(f, st)))
            v = st.then_state
            if v is not None:
                if 0 <= v < nstates:
                    nexts.append(v)
                else:
                    rep.add(diag("RV033",
                                 f"then_state -> state {v} out of range "
                                 f"(fsm has {nstates} states)",
                                 provenance=_state_prov(f, st)))
            v = st.else_state
            if v is not None:
                if 0 <= v < nstates:
                    nexts.append(v)
                else:
                    rep.add(diag("RV033",
                                 f"else_state -> state {v} out of range "
                                 f"(fsm has {nstates} states)",
                                 provenance=_state_prov(f, st)))
            if st.loop is not None:
                var, _extent, head = st.loop
                if not 0 <= head < nstates:
                    rep.add(diag("RV033",
                                 f"loop back-edge -> state {head} out of "
                                 f"range", provenance=_state_prov(f, st)))
                else:
                    nexts.append(head)
                if var not in f.binds:
                    rep.add(diag("RV033",
                                 f"loop back-edge counts unbound index "
                                 f"{var!r}",
                                 provenance=_state_prov(f, st)))
            for ch in st.children:
                if not 0 <= ch < nfsms:
                    rep.add(diag("RV033",
                                 f"par child fsm{ch} does not exist",
                                 provenance=_state_prov(f, st)))
                elif net.fsms[ch].parent != f.fid:
                    rep.add(diag("RV033",
                                 f"par child fsm{ch} names fsm"
                                 f"{net.fsms[ch].parent} as its parent",
                                 provenance=_state_prov(f, st)))
            kind = st.kind
            if kind == "group" or kind == "pipe":
                if st.group not in net.blocks:
                    rep.add(diag("RV033",
                                 f"state enables unknown datapath block "
                                 f"{st.group!r}",
                                 provenance=_state_prov(f, st)))
            elif kind == "cond":
                if st.cond is None:
                    rep.add(diag("RV005",
                                 "cond state carries no condition",
                                 provenance=_state_prov(f, st)))
                else:
                    for var in st.cond.expr.free_vars():
                        try:
                            net.resolve_index(f.fid, var)
                        except KeyError:
                            rep.add(diag(
                                "RV034",
                                f"condition reads loop var {var!r} not "
                                f"bound on the controller chain",
                                provenance=_state_prov(f, st)
                                + (f"var:{var}",)))
            succ[st.index] = nexts
        # reachability over intra-fsm transitions
        if not 0 <= f.start < nstates:
            rep.add(diag("RV033", f"start state {f.start} out of range",
                         provenance=(f"fsm{f.fid}",)))
            continue
        seen = {f.start}
        stack = [f.start]
        while stack:
            s = stack.pop()
            for nxt in succ.get(s, ()):
                if nxt not in seen:
                    seen.add(nxt)
                    stack.append(nxt)
        for st in f.states:
            if st.index not in seen:
                rep.add(diag("RV032",
                             f"state never reached from the start state",
                             provenance=(f"fsm{f.fid}",
                                         f"state[{st.index}]:{st.kind}")))


def _check_block(net: Netlist, b: DpBlock, fid: Optional[int],
                 rep: DiagnosticReport,
                 resolved: Optional[dict] = None) -> None:
    """One datapath block.  Direct type dispatch, provenance tuples built
    only next to a finding, and loop-var resolution memoized per
    ``(fid, var)`` across the netlist's blocks (``resolved``) — this
    runs over every op of every block on every compile."""
    defined: Set[int] = set()
    reg_write_offs: Set[Tuple[str, int]] = set()
    if resolved is None:
        resolved = {}
    units, regs, mems = net.units, net.regs, net.mems

    def prov_at(i, op):
        return (f"block:{b.group}", f"op[{i}]:{type(op).__name__}")

    def resolvable(var: str, i, op) -> None:
        # memo-hit fast path is inlined at the call sites; this body only
        # runs on a first sighting of (fid, var) or a known-bad one
        key = (fid, var)
        ok = resolved.get(key)
        if ok is None:
            try:
                net.resolve_index(fid, var)
                ok = True
            except KeyError:
                ok = False
            resolved[key] = ok
        if not ok:
            rep.add(diag("RV034",
                         f"loop var {var!r} not bound on the controller "
                         f"chain of fsm{fid}",
                         provenance=prov_at(i, op) + (f"var:{var}",)))

    def undriven(t, i, op, dst) -> None:
        kind = ("self-reference" if t == dst
                else "forward reference")
        rep.add(diag("RV031",
                     f"wire w{t} read before it is driven "
                     f"({kind} in the block's dataflow order)",
                     provenance=prov_at(i, op) + (f"wire:w{t}",)))

    def multi_driven(dst, i, op) -> None:
        rep.add(diag("RV030",
                     f"wire w{dst} driven by more than one "
                     f"datapath op",
                     provenance=prov_at(i, op) + (f"wire:w{dst}",)))

    for i, op in enumerate(b.ops):
        t = type(op)
        if t is DpUnit:
            dst = op.dst
            if op.a not in defined:
                undriven(op.a, i, op, dst)
            b2 = op.b
            if b2 is not None and b2 not in defined:
                undriven(b2, i, op, dst)
            if op.unit not in units:
                rep.add(diag("RV001",
                             f"block drives undefined unit {op.unit!r}",
                             provenance=prov_at(i, op)))
            if dst in defined:
                multi_driven(dst, i, op)
            defined.add(dst)
        elif t is DpSelect:
            dst = op.dst
            if op.a not in defined:
                undriven(op.a, i, op, dst)
            if op.b not in defined:
                undriven(op.b, i, op, dst)
            if fid is not None:
                for var in op.cond.expr.free_vars():
                    if not resolved.get((fid, var), False):
                        resolvable(var, i, op)
            if dst in defined:
                multi_driven(dst, i, op)
            defined.add(dst)
        elif t is DpRegWrite:
            if op.src not in defined:
                undriven(op.src, i, op, None)
            if op.reg not in regs:
                rep.add(diag("RV001",
                             f"block writes undefined register {op.reg!r}",
                             provenance=prov_at(i, op)))
            key = (op.reg, op.off)
            if key in reg_write_offs:
                rep.add(diag("RV030",
                             f"register {op.reg!r} driven twice at cycle "
                             f"offset {op.off}", provenance=prov_at(i, op)))
            reg_write_offs.add(key)
        elif t is DpMemWrite:
            if op.src not in defined:
                undriven(op.src, i, op, None)
            if op.mem not in mems:
                rep.add(diag("RV008",
                             f"access to undeclared memory {op.mem!r}",
                             provenance=prov_at(i, op)))
            if fid is not None:
                for ix in op.idxs:
                    for var in ix.free_vars():
                        if not resolved.get((fid, var), False):
                            resolvable(var, i, op)
        elif t is DpMemRead:
            if op.mem not in mems:
                rep.add(diag("RV008",
                             f"access to undeclared memory {op.mem!r}",
                             provenance=prov_at(i, op)))
            if fid is not None:
                for ix in op.idxs:
                    for var in ix.free_vars():
                        if not resolved.get((fid, var), False):
                            resolvable(var, i, op)
            dst = op.dst
            if dst in defined:
                multi_driven(dst, i, op)
            defined.add(dst)
        elif t is DpRegRead:
            if op.reg not in regs:
                rep.add(diag("RV001",
                             f"block reads undefined register {op.reg!r}",
                             provenance=prov_at(i, op)))
            dst = op.dst
            if dst in defined:
                multi_driven(dst, i, op)
            defined.add(dst)
        else:
            dst = getattr(op, "dst", None)
            if dst is not None:
                if dst in defined:
                    multi_driven(dst, i, op)
                defined.add(dst)


def _check_reg_drivers(net: Netlist, rep: DiagnosticReport) -> None:
    """Registers written from two provably-concurrent controllers would be
    multi-driven in hardware (RV030) — the netlist twin of the IR-level
    write-race check."""
    gfids = net.group_fids()
    writes_by_fid: Dict[int, Dict[str, str]] = {}
    for name, b in net.blocks.items():
        fid = gfids.get(name)
        if fid is None:
            continue
        table = writes_by_fid.setdefault(fid, {})
        for op in b.ops:
            if isinstance(op, DpRegWrite):
                table.setdefault(op.reg, name)
    fids = sorted(writes_by_fid)
    if len(fids) < 2:
        return
    paths = _fsm_paths(net)
    for x in range(len(fids)):
        for y in range(x + 1, len(fids)):
            fa, fb = fids[x], fids[y]
            both = set(writes_by_fid[fa]) & set(writes_by_fid[fb])
            if not both:
                continue
            if _fsms_concurrent(paths[fa], paths[fb]):
                for reg in sorted(both):
                    rep.add(diag(
                        "RV030",
                        f"register {reg!r} written from concurrent "
                        f"controllers fsm{fa} (block "
                        f"{writes_by_fid[fa][reg]!r}) and fsm{fb} (block "
                        f"{writes_by_fid[fb][reg]!r})",
                        provenance=(f"fsm{fa}+fsm{fb}", f"reg:{reg}")))


_COUNTER_KINDS = ("total", "stall_port", "stall_pool", "stall_ii",
                  "fsm_overhead")


def _check_counters(net: Netlist, rep: DiagnosticReport) -> None:
    """Verify the profiled netlist's perf-counter bank (RV05x).

    The host derives the counter address map from the design alone
    (``rtl.perf_counter_bank``), so the bank must be structurally exact:
    indices dense from zero, every group counter naming a real datapath
    block, one ``total``, one counter per group, and each stall family
    present exactly once.
    """
    counters = net.counters
    idxs = [c.index for c in counters]
    if idxs != list(range(len(counters))):
        rep.add(diag("RV051",
                     f"counter indices must be dense from 0 "
                     f"(got {idxs})", provenance=("counters",)))
    names = [c.name for c in counters]
    if len(set(names)) != len(names):
        dup = sorted({n for n in names if names.count(n) > 1})
        rep.add(diag("RV051", f"duplicate counter names {dup}",
                     provenance=("counters",)))
    by_kind: Dict[str, int] = {}
    for c in counters:
        if c.kind not in _COUNTER_KINDS + ("group",):
            rep.add(diag("RV051",
                         f"counter {c.name!r} has unknown kind "
                         f"{c.kind!r}", provenance=(f"counter:{c.name}",)))
            continue
        by_kind[c.kind] = by_kind.get(c.kind, 0) + 1
        if c.kind == "group" and c.group not in net.blocks:
            rep.add(diag("RV050",
                         f"counter {c.name!r} references unknown group "
                         f"{c.group!r}", provenance=(f"counter:{c.name}",)))
    counted = {c.group for c in counters if c.kind == "group"}
    missing = [g for g in net.blocks if g not in counted]
    if missing:
        rep.add(diag("RV052",
                     f"groups without a counter: {missing}",
                     provenance=("counters",)))
    for kind in _COUNTER_KINDS:
        if by_kind.get(kind, 0) != 1:
            rep.add(diag("RV052",
                         f"expected exactly one {kind!r} counter "
                         f"(got {by_kind.get(kind, 0)})",
                         provenance=("counters",)))


def verify_netlist(net: Netlist, *,
                   stage: str = "post-rtl") -> DiagnosticReport:
    """Statically verify the FSM + datapath netlist (``core.rtl``) — the
    graph, not the emitted text (``verilog.lint_diagnostics`` covers
    that).  Profiled netlists additionally get their perf-counter bank
    checked against the canonical address map (RV05x)."""
    with timed_report(stage) as rep:
        _check_fsms(net, rep)
        if net.profile:
            _check_counters(net, rep)
        gfids = net.group_fids()
        resolved: dict = {}
        # net.blocks is insertion-ordered by construction, so iteration
        # (and therefore finding order) is already deterministic
        for name, b in net.blocks.items():
            fid = gfids.get(name)
            if fid is None:
                rep.add(diag("RV004",
                             f"datapath block {name!r} is enabled by no "
                             f"controller state",
                             provenance=(f"block:{name}",)))
            _check_block(net, b, fid, rep, resolved)
    return rep


# ---------------------------------------------------------------------------
# Whole-design convenience (CLI, benchmarks)
# ---------------------------------------------------------------------------


def verify_design(design) -> List[DiagnosticReport]:
    """Verify a ``pipeline.CompiledDesign`` end to end: the final
    component and its RTL netlist.  Pure re-analysis — compiles nothing,
    simulates nothing; used by ``scripts/lint_design.py`` and the
    benchmark's verifier-overhead timing."""
    return [verify_component(design.component, design.program,
                             stage="post-sharing"),
            verify_netlist(design.to_rtl())]
