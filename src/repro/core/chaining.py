"""Operation chaining / group fusion over the Calyx-like IR (opt_level 1).

The lowering emits one group per source statement, and the paper's control
compilation pays for that granularity twice: every group owns a go/done
handshake and an FSM state (attention at factor 4 burns >1300 states and
drops fmax to ~130 MHz), and every ``par`` of tiny groups pays a join
handshake per loop iteration.  This pass fuses groups at the IR level so
downstream stages — estimator, Calyx simulator, RTL lowering, RTL
simulator — all price and execute the *same* coarser schedule:

* **seq fusion** — a run of consecutive group enables inside a ``seq``
  becomes one group: micro-ops are concatenated with their cycle offsets
  shifted by the running latency, so the dependent chain
  (address compute -> load -> ALU -> store -> next statement) executes in
  one activation window.  Cycle-neutral by construction (the fused
  latency is the sum the ``seq`` already paid) but it collapses FSM
  states and go/done fabric — and it is what turns a multi-statement
  loop body into the single-group form the pipelining pass needs.

* **par fusion** — arms of a ``par`` that are single groups and provably
  port-compatible (pairwise non-conflicting under the estimator's
  bank-affine test: distinct banks, or broadcast-equal load addresses)
  fuse into one group of latency ``max(arms)``.  The arms' memory
  accesses keep their per-arm cycle offsets — the simulators still stamp
  and police every port claim — but the fork/join handshake and the
  per-arm FSM controllers disappear.  Arms that do conflict are left
  behind as separate arms (greedy bucketing), so fusion never serializes
  anything the conflict partition would have run concurrently.

A ``par`` whose arms all fuse into one group loses the par node entirely
(no join reduction); a ``seq`` left with one child collapses to that
child.  Fused groups are renamed deterministically in traversal order, so
emitted text stays byte-reproducible.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from . import dataflow as D
from . import estimator
from .calyx import (CIf, CNode, CPar, CRepeat, CSeq, Component, GEnable,
                    Group, referenced_groups)


def _max_temp(uops: List[D.UOp]) -> int:
    """Highest SSA temp id used in a micro-op list (-1 if none)."""
    hi = -1
    for u in uops:
        for field in D.TEMP_FIELDS:
            v = getattr(u, field, None)
            if isinstance(v, int):
                hi = max(hi, v)
    return hi


def _shift_uop(u: D.UOp, tmp_base: int, cyc_base: int) -> D.UOp:
    """Renumber one micro-op's temps by ``tmp_base`` and shift its cycle
    offset by ``cyc_base`` (the fused group's running latency)."""
    kw: Dict[str, int] = {}
    for field in D.TEMP_FIELDS:
        v = getattr(u, field, None)
        if isinstance(v, int):
            kw[field] = v + tmp_base
    if hasattr(u, "off"):
        kw["off"] = u.off + cyc_base
    return dataclasses.replace(u, **kw)


def _fuse(groups: List[Group], name: str, sequential: bool) -> Group:
    """Concatenate ``groups`` into one.

    ``sequential=True`` chains them back to back (offsets shifted by the
    running latency, total = sum) — the seq-fusion shape; ``False`` runs
    them concurrently from cycle 0 (total = max) — the par-fusion shape.
    Temps are renumbered into one dense SSA space either way.
    """
    uops: List[D.UOp] = []
    cells: List[str] = []
    ports = []
    tmp_base = 0
    cyc_base = 0
    latency = 0
    for g in groups:
        base = cyc_base if sequential else 0
        uops += [_shift_uop(u, tmp_base, base) for u in g.uops]
        cells += g.cells
        ports += list(g.ports)
        tmp_base += _max_temp(g.uops) + 1
        if sequential:
            cyc_base += g.latency
            latency = cyc_base
        else:
            latency = max(latency, g.latency)
    return Group(name, latency, cells, ports, uops)


class _Chainer:
    def __init__(self, comp: Component):
        self.comp = comp
        self.groups: Dict[str, Group] = dict(comp.groups)
        self._n = 0
        self.seq_fused = 0
        self.par_fused = 0

    def _name(self) -> str:
        self._n += 1
        return f"fused{self._n}"

    def _install(self, parts: List[Group], sequential: bool) -> GEnable:
        fused = _fuse(parts, self._name(), sequential)
        for g in parts:
            del self.groups[g.name]
        self.groups[fused.name] = fused
        if sequential:
            self.seq_fused += len(parts)
        else:
            self.par_fused += len(parts)
        return GEnable(fused.name)

    # -- seq: fuse maximal runs of group enables ------------------------------
    def _rewrite_seq(self, node: CSeq) -> CNode:
        children = [self.rewrite(ch) for ch in node.children]
        out: List[CNode] = []
        run: List[Group] = []

        def flush() -> None:
            if len(run) == 1:
                out.append(GEnable(run[0].name))
            elif run:
                out.append(self._install(list(run), sequential=True))
            run.clear()

        for ch in children:
            if isinstance(ch, GEnable):
                run.append(self.groups[ch.group])
            else:
                flush()
                out.append(ch)
        flush()
        if len(out) == 1:
            return out[0]
        return CSeq(out)

    # -- par: fuse compatible single-group arms -------------------------------
    def _rewrite_par(self, node: CPar) -> CNode:
        children = [self.rewrite(ch) for ch in node.children]
        if len(children) <= 1:
            return children[0] if children else CPar([])
        # Only arms that conflict with *no* sibling fuse (their singleton
        # conflict components).  Fusing across components could chain two
        # previously-independent serializations through the union of the
        # fused arm's ports — restricting to conflict-free arms makes par
        # fusion a guaranteed improvement (max of latencies, no join for
        # whatever collapses), never a regression.  A pair of accesses
        # conflicts for the union iff it conflicts for some member, so
        # conflict-free arms stay conflict-free after fusing.
        tmp = Component(self.comp.name, self.comp.cells, self.groups,
                        node)
        ports = [estimator._collect_ports(tmp, ch, set())
                 for ch in children]
        conflicted = [False] * len(children)
        for i in range(len(children)):
            for j in range(i + 1, len(children)):
                if estimator.ports_conflict(ports[i], ports[j]):
                    conflicted[i] = conflicted[j] = True
        # greedy bucketing of the conflict-free single-group arms; arm
        # order is preserved (each bucket lands at its first member's
        # position) so the interpreter's value order survives fusion
        buckets: List[List[Group]] = []
        bucket_of: Dict[int, int] = {}          # child index -> bucket index
        for i, ch in enumerate(children):
            if conflicted[i] or not isinstance(ch, GEnable):
                continue
            g = self.groups[ch.group]
            for bi, bucket in enumerate(buckets):
                if not self._shares_pool_cell(g, bucket):
                    bucket.append(g)
                    bucket_of[i] = bi
                    break
            else:
                bucket_of[i] = len(buckets)
                buckets.append([g])
        emitted: set = set()
        arms: List[CNode] = []
        for i, ch in enumerate(children):
            if i not in bucket_of:
                arms.append(ch)
                continue
            bi = bucket_of[i]
            if bi in emitted:
                continue
            emitted.add(bi)
            bucket = buckets[bi]
            if len(bucket) == 1:
                arms.append(GEnable(bucket[0].name))
            else:
                arms.append(self._install(bucket, sequential=False))
        if not arms:
            return CPar([])
        if len(arms) == 1:
            return arms[0]          # the join handshake disappears with it
        return CPar(arms)

    def _shares_pool_cell(self, g: Group, bucket: List[Group]) -> bool:
        """Refuse to fuse two arms driving one shared pool cell — their
        activation windows would overlap on a single-owner unit.  (Only
        reachable when chaining runs after binding; the standard pipeline
        chains first, where every cell is still private.)"""
        pooled = {c for c in g.cells
                  if self.comp.cells.get(c) is not None
                  and self.comp.cells[c].users > 1}
        if not pooled:
            return False
        for other in bucket:
            if pooled & {c for c in other.cells
                         if self.comp.cells.get(c) is not None
                         and self.comp.cells[c].users > 1}:
                return True
        return False

    # -- dispatch -------------------------------------------------------------
    def rewrite(self, node: CNode) -> CNode:
        if isinstance(node, GEnable):
            return node
        if isinstance(node, CSeq):
            return self._rewrite_seq(node)
        if isinstance(node, CPar):
            return self._rewrite_par(node)
        if isinstance(node, CRepeat):
            return dataclasses.replace(node, body=self.rewrite(node.body))
        if isinstance(node, CIf):
            return dataclasses.replace(node, then=self.rewrite(node.then),
                                       els=self.rewrite(node.els))
        raise TypeError(node)


def chain_component(comp: Component) -> Component:
    """Fuse groups along ``seq`` runs and across compatible ``par`` arms.

    Returns a new component over the same cells; group count, FSM states,
    and par-join handshakes shrink, while every memory port claim keeps a
    definite cycle offset the simulators still verify.  Seq fusion is
    cycle-neutral; par fusion removes join/fork cycles the coarser
    schedule genuinely no longer pays.
    """
    chainer = _Chainer(comp)
    control = chainer.rewrite(comp.control)
    live = referenced_groups(control)
    groups = {name: g for name, g in chainer.groups.items() if name in live}
    out = Component(comp.name, comp.cells, groups, control,
                    meta=dict(comp.meta))
    out.meta["chained"] = {"seq_fused": chainer.seq_fused,
                          "par_fused": chainer.par_fused,
                          "groups": len(groups)}
    return out
