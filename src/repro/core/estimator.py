"""Cycle-count, resource, and timing estimation over the Calyx-like IR.

Latency model:
  * ``seq``     — sum of children.
  * ``repeat``  — setup + extent * (body + per-iteration overhead).
  * ``if``      — cond + max(arms) + select overhead (both arms exist in
                  hardware; only one executes).  The control FSM is
                  *statically timed*: the ``if`` state always reserves the
                  worst-case arm latency, so every subtree's latency is
                  input-independent.  This is why the cycle-accurate
                  simulator (``core.sim``), which executes only the taken
                  arm but charges the worst case, measures *exactly* this
                  closed-form count — the differential tests in
                  ``tests/test_core_sim.py`` assert equality with no
                  tolerance, and there is no intentional divergence.
  * ``par``     — memory-port conflict model: arms that touch the same
                  (memory, bank) with non-shareable addresses must serialize
                  (Calyx memories accept one access per cycle).  We build a
                  conflict graph over the arms; each connected component runs
                  sequentially, components run concurrently:
                  ``latency = max over components(sum of arm latencies)``.
                  Identical-address *loads* broadcast from one port and do
                  not conflict.  This is what makes unbanked `par` worthless
                  and layout-banked `par` near-linear — the paper's story.

Resource model: sum of cell costs (float_lib) + BRAM/LUTRAM per bank +
FSM fabric per control state + a constant top-level overhead.

Timing: first-order achievable period grows with FSM state count and bank
select-chain depth; wall-clock latency = cycles * period.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Set, Tuple

from . import float_lib as F
from .calyx import (CIf, CNode, CPar, CRepeat, CSeq, Component, GEnable,
                    PortAccess)


# ---------------------------------------------------------------------------
# Port collection (for the par conflict model)
# ---------------------------------------------------------------------------


def _collect_ports(comp: Component, node: CNode,
                   bound: Set[str]) -> List[PortAccess]:
    """All port accesses under ``node``; addresses depending on loop vars
    bound *inside* this subtree are marked unshareable (key -> None)."""
    out: List[PortAccess] = []
    if isinstance(node, GEnable):
        for p in comp.groups[node.group].ports:
            if p.key is not None and p.free_vars & bound:
                out.append(dataclasses.replace(p, key=None))
            else:
                out.append(p)
    elif isinstance(node, CSeq) or isinstance(node, CPar):
        for ch in node.children:
            out += _collect_ports(comp, ch, bound)
    elif isinstance(node, CRepeat):
        out += _collect_ports(comp, node.body, bound | {node.var})
    elif isinstance(node, CIf):
        out += _collect_ports(comp, node.then, bound)
        out += _collect_ports(comp, node.els, bound)
    return out


def _arms_conflict(pa: List[PortAccess], pb: List[PortAccess]) -> bool:
    for a in pa:
        for b in pb:
            if a.mem != b.mem:
                continue
            if a.bank is not None and b.bank is not None and a.bank != b.bank:
                continue  # provably different physical banks
            if (not a.is_store and not b.is_store
                    and a.key is not None and a.key == b.key):
                continue  # identical-address loads: broadcast one read
            return True
    return False


def par_conflict_components(comp: Component, node: CPar) -> List[List[int]]:
    """Partition a ``par``'s arm indices into port-conflict components.

    Arms in one component must serialize (they touch the same single-ported
    (memory, bank) with non-broadcastable addresses); distinct components
    run concurrently.  Shared by the closed-form latency model below and by
    the cycle-accurate scheduler (``core.sim``) — the two agreeing on this
    partition is what makes measured and estimated cycles identical.
    """
    arms = node.children
    n = len(arms)
    ports = [_collect_ports(comp, a, set()) for a in arms]
    # union-find over conflict graph
    parent = list(range(n))

    def find(x: int) -> int:
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for i in range(n):
        for j in range(i + 1, n):
            if _arms_conflict(ports[i], ports[j]):
                parent[find(i)] = find(j)
    comps: Dict[int, List[int]] = {}
    for i in range(n):
        comps.setdefault(find(i), []).append(i)
    return list(comps.values())


def par_join_cycles(n_arms: int) -> int:
    """Join handshake: a done-signal reduction tree over the arms."""
    return F.PAR_JOIN_CYCLES + max(0, math.ceil(math.log2(max(n_arms, 1))))


# ---------------------------------------------------------------------------
# Cycles
# ---------------------------------------------------------------------------


def cycles(comp: Component, node: Optional[CNode] = None) -> int:
    node = comp.control if node is None else node
    if isinstance(node, GEnable):
        return comp.groups[node.group].latency
    if isinstance(node, CSeq):
        return sum(cycles(comp, ch) for ch in node.children)
    if isinstance(node, CRepeat):
        body = cycles(comp, node.body)
        return F.LOOP_SETUP_CYCLES + node.extent * (body + F.LOOP_ITER_OVERHEAD)
    if isinstance(node, CIf):
        t = cycles(comp, node.then)
        e = cycles(comp, node.els)
        return node.cond_latency + F.IF_SELECT_CYCLES + max(t, e)
    if isinstance(node, CPar):
        arms = node.children
        if not arms:
            return 0
        lats = [cycles(comp, a) for a in arms]
        comps = par_conflict_components(comp, node)
        return (max(sum(lats[i] for i in c) for c in comps)
                + par_join_cycles(len(arms)))
    raise TypeError(node)


# ---------------------------------------------------------------------------
# Resources
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Resources:
    lut: int = 0
    ff: int = 0
    bram: int = 0
    dsp: int = 0

    def add(self, c: F.OpCost, n: int = 1):
        self.lut += c.lut * n
        self.ff += c.ff * n
        self.dsp += c.dsp * n

    def as_dict(self) -> Dict[str, int]:
        return {"LUT": self.lut, "FF": self.ff, "BRAM": self.bram,
                "DSP": self.dsp}


def fsm_states(node: CNode) -> int:
    if isinstance(node, GEnable):
        return 1
    if isinstance(node, CSeq):
        return sum(fsm_states(ch) for ch in node.children)
    if isinstance(node, CPar):
        return 1 + sum(fsm_states(ch) for ch in node.children)
    if isinstance(node, CRepeat):
        return 1 + fsm_states(node.body)
    if isinstance(node, CIf):
        return 1 + fsm_states(node.then) + fsm_states(node.els)
    raise TypeError(node)


def max_select_depth(comp: Component, node: Optional[CNode] = None) -> int:
    """Depth of the deepest bank-selection chain (branchy mode blow-up)."""
    node = comp.control if node is None else node
    if isinstance(node, GEnable):
        return 0
    if isinstance(node, (CSeq, CPar)):
        return max((max_select_depth(comp, ch) for ch in node.children),
                   default=0)
    if isinstance(node, CRepeat):
        return max_select_depth(comp, node.body)
    if isinstance(node, CIf):
        inner = max(max_select_depth(comp, node.then),
                    max_select_depth(comp, node.els))
        return 1 + inner
    raise TypeError(node)


def resources(comp: Component) -> Resources:
    res = Resources()
    for cell in comp.cells.values():
        if cell.kind == "mem_bank":
            res.add(F.memory_cost(cell.words))
            res.bram += F.memory_brams(cell.words)
        elif cell.kind in F.FLOAT_COSTS:
            res.add(F.FLOAT_COSTS[cell.kind])
        elif cell.kind == "int_mul":
            res.add(F.int_mul_cost(cell.const))
        elif cell.kind == "int_divmod":
            res.add(F.int_divmod_cost(cell.const))
        elif cell.kind in F.INT_COSTS:
            res.add(F.INT_COSTS[cell.kind])
        else:
            raise KeyError(cell.kind)
        if cell.users > 1:   # pooled by the sharing pass: operand steering
            res.add(F.sharing_mux_cost(cell.kind, cell.users))
    states = fsm_states(comp.control)
    res.lut += F.FSM_LUT_PER_STATE * states
    res.lut += F.GROUP_FABRIC_LUT * len(comp.groups)
    res.ff += F.FSM_FF_PER_STATE_BIT * max(1, math.ceil(math.log2(states + 1)))
    res.ff += states
    res.lut += F.TOP_OVERHEAD["lut"]
    res.ff += F.TOP_OVERHEAD["ff"]
    res.dsp += F.TOP_OVERHEAD["dsp"]
    res.bram += F.TOP_OVERHEAD["bram"]
    return res


# ---------------------------------------------------------------------------
# Report
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Estimate:
    cycles: int
    resources: Dict[str, int]
    fsm_states: int
    period_ns: float
    fmax_mhz: float
    wall_us: float

    def as_dict(self):
        return dataclasses.asdict(self)


def estimate(comp: Component) -> Estimate:
    cyc = cycles(comp)
    res = resources(comp)
    states = fsm_states(comp.control)
    depth = max_select_depth(comp)
    period = F.achievable_period_ns(states, depth)
    return Estimate(
        cycles=cyc,
        resources=res.as_dict(),
        fsm_states=states,
        period_ns=round(period, 3),
        fmax_mhz=round(1000.0 / period, 1),
        wall_us=round(cyc * period / 1000.0, 3),
    )
