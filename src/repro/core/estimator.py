"""Cycle-count, resource, and timing estimation over the Calyx-like IR.

Latency model:
  * ``seq``     — sum of children.
  * ``repeat``  — setup + extent * (body + per-iteration overhead).
  * ``if``      — cond + max(arms) + select overhead (both arms exist in
                  hardware; only one executes).  The control FSM is
                  *statically timed*: the ``if`` state always reserves the
                  worst-case arm latency, so every subtree's latency is
                  input-independent.  This is why the cycle-accurate
                  simulator (``core.sim``), which executes only the taken
                  arm but charges the worst case, measures *exactly* this
                  closed-form count — the differential tests in
                  ``tests/test_core_sim.py`` assert equality with no
                  tolerance, and there is no intentional divergence.
  * ``par``     — memory-port conflict model: arms that touch the same
                  (memory, bank) with non-shareable addresses must serialize
                  (Calyx memories accept one access per cycle).  We build a
                  conflict graph over the arms; each connected component runs
                  sequentially, components run concurrently:
                  ``latency = max over components(sum of arm latencies)``.
                  Identical-address *loads* broadcast from one port and do
                  not conflict.  This is what makes unbanked `par` worthless
                  and layout-banked `par` near-linear — the paper's story.

Resource model: sum of cell costs (float_lib) + BRAM/LUTRAM per bank +
FSM fabric per control state + a constant top-level overhead.

Timing: first-order achievable period grows with FSM state count and bank
select-chain depth; wall-clock latency = cycles * period.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Set, Tuple

from . import float_lib as F
from .affine import AExpr, ModAtom
from .calyx import (CIf, CNode, CPar, CRepeat, CSeq, Component, GEnable,
                    PortAccess)


class BankingEfficiencyWarning(UserWarning):
    """A ``par`` block's arms conflict-serialize on memory banks — the
    banking factor bought hardware but not cycles (e.g. the conv2d
    banks=4 regression this warning was introduced to surface)."""


# ---------------------------------------------------------------------------
# Port collection (for the par conflict model)
# ---------------------------------------------------------------------------


def _collect_ports(comp: Component, node: CNode,
                   bound: Set[str]) -> List[PortAccess]:
    """All port accesses under ``node``; addresses depending on loop vars
    bound *inside* this subtree are marked unshareable (key -> None)."""
    out: List[PortAccess] = []
    if isinstance(node, GEnable):
        for p in comp.groups[node.group].ports:
            if p.free_vars & bound:
                # the address depends on a loop var bound inside this
                # subtree: neither the broadcast key nor the bank-affinity
                # proof may assume a common environment
                out.append(dataclasses.replace(p, key=None, bank_expr=None))
            else:
                out.append(p)
    elif isinstance(node, CSeq) or isinstance(node, CPar):
        for ch in node.children:
            out += _collect_ports(comp, ch, bound)
    elif isinstance(node, CRepeat):
        out += _collect_ports(comp, node.body, bound | {node.var})
    elif isinstance(node, CIf):
        out += _collect_ports(comp, node.then, bound)
        out += _collect_ports(comp, node.els, bound)
    return out


def banks_provably_distinct(a: PortAccess, b: PortAccess) -> bool:
    """True iff the two accesses provably hit different physical banks.

    Constant banks compare directly.  Runtime-selected banks (layout mode
    where the cyclic fold did not reach a constant, e.g. ``(2*i + a) % 4``
    after strip-mining by a factor that does not divide the banking
    factor) are compared *digit-wise*: the bank index is a mixed-radix
    sum of ``(expr_d mod f_d) * stride_d`` digits, and two digit vectors
    provably differ when

    * the whole bank-expression difference folds to a nonzero constant
      (e.g. one digit folded to distinct constants in both arms), or
    * some matched digit pair ``(e1 mod f)``/``(e2 mod f)`` on the same
      stride has ``e1 - e2`` a constant not divisible by ``f`` — residues
      of values a fixed non-multiple-of-``f`` apart always differ.

    This is what lets strip-mined arms whose strides are bank-affine (the
    unroll offset lands each arm on its own bank) run concurrently even
    when no digit is a compile-time constant.
    """
    if a.bank is not None and b.bank is not None:
        return a.bank != b.bank
    ea, eb = a.bank_expr, b.bank_expr
    if ea is None or eb is None:
        return False              # one side constant/invalidated: unknown
    diff = ea - eb
    if diff.is_const():
        return diff.const_value() != 0
    by_coeff = {}
    for atom, co in eb.coeffs.items():
        if isinstance(atom, ModAtom):
            by_coeff.setdefault(co, []).append(atom)
    for atom, co in ea.coeffs.items():
        if not isinstance(atom, ModAtom):
            continue
        for other in by_coeff.get(co, ()):
            if other.c != atom.c:
                continue
            d = atom.inner - other.inner
            if d.is_const() and d.const_value() % atom.c != 0:
                return True       # this digit always differs
    return False


def _arms_conflict(pa: List[PortAccess], pb: List[PortAccess]) -> bool:
    for a in pa:
        for b in pb:
            if a.mem != b.mem:
                continue
            if banks_provably_distinct(a, b):
                continue
            if (not a.is_store and not b.is_store
                    and a.key is not None and a.key == b.key):
                # identical intra-bank address: either the banks coincide
                # (one read port broadcasts to both) or they differ (no
                # port is contended) — never a conflict for loads
                continue
            return True
    return False


def ports_conflict(pa: List[PortAccess], pb: List[PortAccess]) -> bool:
    """Public face of the pairwise port-conflict test — used by the
    chaining pass to decide which ``par`` arms may fuse into one group."""
    return _arms_conflict(pa, pb)


def par_conflict_components(comp: Component, node: CPar) -> List[List[int]]:
    """Partition a ``par``'s arm indices into port-conflict components.

    Arms in one component must serialize (they touch the same single-ported
    (memory, bank) with non-broadcastable addresses); distinct components
    run concurrently.  Shared by the closed-form latency model below and by
    the cycle-accurate scheduler (``core.sim``) — the two agreeing on this
    partition is what makes measured and estimated cycles identical.
    """
    arms = node.children
    n = len(arms)
    ports = [_collect_ports(comp, a, set()) for a in arms]
    # union-find over conflict graph
    parent = list(range(n))

    def find(x: int) -> int:
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for i in range(n):
        for j in range(i + 1, n):
            if _arms_conflict(ports[i], ports[j]):
                parent[find(i)] = find(j)
    comps: Dict[int, List[int]] = {}
    for i in range(n):
        comps.setdefault(find(i), []).append(i)
    return list(comps.values())


def par_join_cycles(n_arms: int) -> int:
    """Join handshake: a done-signal reduction tree over the arms."""
    return F.PAR_JOIN_CYCLES + max(0, math.ceil(math.log2(max(n_arms, 1))))


def par_serializations(comp: Component) -> List[Tuple[CPar, int, int]]:
    """Every ``par`` whose conflict partition collapses arms.

    Returns ``(node, n_arms, n_components)`` for each multi-arm par where
    ``n_components < n_arms`` — i.e. some arms the schedule *placed* in
    parallel will run sequentially on the hardware because they contend
    for a single-ported bank.  Compile-time visibility for regressions
    like conv2d banks=4, without running a benchmark.
    """
    out: List[Tuple[CPar, int, int]] = []

    def walk(node: CNode) -> None:
        if isinstance(node, (CSeq, CPar)):
            if isinstance(node, CPar) and len(node.children) > 1:
                comps = par_conflict_components(comp, node)
                if len(comps) < len(node.children):
                    out.append((node, len(node.children), len(comps)))
            for ch in node.children:
                walk(ch)
        elif isinstance(node, CRepeat):
            walk(node.body)
        elif isinstance(node, CIf):
            walk(node.then)
            walk(node.els)

    walk(comp.control)
    return out


def banking_efficiency(comp: Component) -> float:
    """Worst-case concurrency retention across all ``par`` blocks.

    1.0 = every par's arms run fully concurrently; ``k/n`` = the worst
    par keeps only ``k`` of its ``n`` arms concurrent (its conflict
    partition has ``k`` components).  Exposed on ``Estimate`` and warned
    about at compile time so banked-but-serialized designs are visible.
    """
    worst = 1.0
    for _, n_arms, n_comps in par_serializations(comp):
        worst = min(worst, n_comps / n_arms)
    return worst


# ---------------------------------------------------------------------------
# Cycles
# ---------------------------------------------------------------------------


def cycles(comp: Component, node: Optional[CNode] = None) -> int:
    node = comp.control if node is None else node
    if isinstance(node, GEnable):
        return comp.groups[node.group].latency
    if isinstance(node, CSeq):
        return sum(cycles(comp, ch) for ch in node.children)
    if isinstance(node, CRepeat):
        body = cycles(comp, node.body)
        if node.ii and node.extent > 0:
            # pipelined loop: a new iteration launches every ii cycles and
            # the last one drains its full body latency (core.pipelining)
            return F.LOOP_SETUP_CYCLES + (node.extent - 1) * node.ii + body
        return F.LOOP_SETUP_CYCLES + node.extent * (body + F.LOOP_ITER_OVERHEAD)
    if isinstance(node, CIf):
        t = cycles(comp, node.then)
        e = cycles(comp, node.els)
        return node.cond_latency + F.IF_SELECT_CYCLES + max(t, e)
    if isinstance(node, CPar):
        arms = node.children
        if not arms:
            return 0
        lats = [cycles(comp, a) for a in arms]
        comps = par_conflict_components(comp, node)
        return (max(sum(lats[i] for i in c) for c in comps)
                + par_join_cycles(len(arms)))
    raise TypeError(node)


# ---------------------------------------------------------------------------
# Cycle attribution (the analytic level of the observability differential)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CycleAttribution:
    """Closed-form counterpart of the perf-counter bank.

    Predicts, without executing anything, the exact values the
    synthesized hardware counters / both simulators' stats will measure:
    per-group busy cycles, cycles lost to each stall cause, and control
    overhead.  ``exact`` is False when the control tree contains an
    ``if`` — the analysis charges the worst-case arm (the statically
    timed FSM always *reserves* it, so ``total`` stays exact), but which
    groups actually fire is input-dependent, so the per-group split is a
    bound rather than an identity there.
    """
    total: int = 0
    group_cycles: Dict[str, int] = dataclasses.field(default_factory=dict)
    stall_port_cycles: int = 0       # par arms serialized behind siblings
    stall_pool_cycles: int = 0       # waits on shared-unit pools (always 0:
    #                                  binding keeps pools in one component)
    stall_ii_cycles: int = 0         # (extent-1)*(ii-1) per pipelined loop
    fsm_overhead_cycles: int = 0     # setup/iter/cond/pad/join states
    pipe_launches: int = 0
    exact: bool = True

    def as_dict(self):
        return dataclasses.asdict(self)

    def counters(self) -> Dict[str, object]:
        """Same shape as ``trace.aggregate`` / ``trace.counters_of_stats``
        so the four-way differential compares dicts directly."""
        return {
            "total": self.total,
            "group_cycles": dict(sorted(self.group_cycles.items())),
            "stall_port_cycles": self.stall_port_cycles,
            "stall_pool_cycles": self.stall_pool_cycles,
            "stall_ii_cycles": self.stall_ii_cycles,
            "fsm_overhead_cycles": self.fsm_overhead_cycles,
            "pipe_launches": self.pipe_launches,
        }


def attribute(comp: Component,
              node: Optional[CNode] = None) -> CycleAttribution:
    """Attribute every cycle of :func:`cycles`'s total to a cause.

    The invariant (asserted across the benchmark matrix): for if-free
    designs the returned counters equal the Calyx-level ``SimStats``,
    the netlist-level ``RtlStats``, and the synthesized counter bank,
    field for field.
    """
    att = CycleAttribution()
    att.total = cycles(comp, node)

    def walk(n: CNode, mult: int) -> None:
        if isinstance(n, GEnable):
            g = comp.groups[n.group]
            att.group_cycles[g.name] = \
                att.group_cycles.get(g.name, 0) + g.latency * mult
            return
        if isinstance(n, CSeq):
            for ch in n.children:
                walk(ch, mult)
            return
        if isinstance(n, CRepeat):
            if n.ii and n.extent > 0:
                # pipelined loop (body is a single group, see
                # pipelining.pipeline_loops): overlapped launch windows
                # keep the group busy (extent-1)*ii + latency cycles
                g = comp.groups[n.body.group]   # type: ignore[union-attr]
                busy = (n.extent - 1) * n.ii + g.latency
                att.group_cycles[g.name] = \
                    att.group_cycles.get(g.name, 0) + busy * mult
                att.fsm_overhead_cycles += F.LOOP_SETUP_CYCLES * mult
                att.stall_ii_cycles += (n.extent - 1) * (n.ii - 1) * mult
                att.pipe_launches += n.extent * mult
                return
            att.fsm_overhead_cycles += (
                F.LOOP_SETUP_CYCLES
                + n.extent * F.LOOP_ITER_OVERHEAD) * mult
            walk(n.body, mult * n.extent)
            return
        if isinstance(n, CIf):
            # statically timed: the FSM reserves max(arms), so charge the
            # worst arm's groups — but which arm *fires* is runtime data
            att.exact = False
            att.fsm_overhead_cycles += \
                (n.cond_latency + F.IF_SELECT_CYCLES) * mult
            t, e = cycles(comp, n.then), cycles(comp, n.els)
            walk(n.then if t >= e else n.els, mult)
            return
        if isinstance(n, CPar):
            arms = n.children
            if not arms:
                return
            att.fsm_overhead_cycles += par_join_cycles(len(arms)) * mult
            for members in par_conflict_components(comp, n):
                wait = 0
                for i in members:
                    att.stall_port_cycles += wait * mult
                    wait += cycles(comp, arms[i])
            for ch in arms:
                walk(ch, mult)
            return
        raise TypeError(n)

    walk(comp.control if node is None else node, 1)
    return att


# ---------------------------------------------------------------------------
# Resources
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Resources:
    lut: int = 0
    ff: int = 0
    bram: int = 0
    dsp: int = 0

    def add(self, c: F.OpCost, n: int = 1):
        self.lut += c.lut * n
        self.ff += c.ff * n
        self.dsp += c.dsp * n

    def as_dict(self) -> Dict[str, int]:
        return {"LUT": self.lut, "FF": self.ff, "BRAM": self.bram,
                "DSP": self.dsp}


def fsm_states(node: CNode) -> int:
    if isinstance(node, GEnable):
        return 1
    if isinstance(node, CSeq):
        return sum(fsm_states(ch) for ch in node.children)
    if isinstance(node, CPar):
        return 1 + sum(fsm_states(ch) for ch in node.children)
    if isinstance(node, CRepeat):
        return 1 + fsm_states(node.body)
    if isinstance(node, CIf):
        return 1 + fsm_states(node.then) + fsm_states(node.els)
    raise TypeError(node)


def max_select_depth(comp: Component, node: Optional[CNode] = None) -> int:
    """Depth of the deepest bank-selection chain (branchy mode blow-up)."""
    node = comp.control if node is None else node
    if isinstance(node, GEnable):
        return 0
    if isinstance(node, (CSeq, CPar)):
        return max((max_select_depth(comp, ch) for ch in node.children),
                   default=0)
    if isinstance(node, CRepeat):
        return max_select_depth(comp, node.body)
    if isinstance(node, CIf):
        inner = max(max_select_depth(comp, node.then),
                    max_select_depth(comp, node.els))
        return 1 + inner
    raise TypeError(node)


def resources(comp: Component) -> Resources:
    res = Resources()
    for cell in comp.cells.values():
        if cell.kind == "mem_bank":
            res.add(F.memory_cost(cell.words))
            res.bram += F.memory_brams(cell.words)
        elif cell.kind in F.FLOAT_COSTS:
            res.add(F.FLOAT_COSTS[cell.kind])
        elif cell.kind == "int_mul":
            res.add(F.int_mul_cost(cell.const))
        elif cell.kind == "int_divmod":
            res.add(F.int_divmod_cost(cell.const))
        elif cell.kind in F.INT_COSTS:
            res.add(F.INT_COSTS[cell.kind])
        else:
            raise KeyError(cell.kind)
        if cell.users > 1:   # pooled by the sharing pass: operand steering
            res.add(F.sharing_mux_cost(cell.kind, cell.users))
    states = fsm_states(comp.control)
    res.lut += F.FSM_LUT_PER_STATE * states
    res.lut += F.GROUP_FABRIC_LUT * len(comp.groups)
    res.ff += F.FSM_FF_PER_STATE_BIT * max(1, math.ceil(math.log2(states + 1)))
    res.ff += states
    res.lut += F.TOP_OVERHEAD["lut"]
    res.ff += F.TOP_OVERHEAD["ff"]
    res.dsp += F.TOP_OVERHEAD["dsp"]
    res.bram += F.TOP_OVERHEAD["bram"]
    return res


# ---------------------------------------------------------------------------
# Report
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Estimate:
    cycles: int
    resources: Dict[str, int]
    fsm_states: int
    period_ns: float
    fmax_mhz: float
    wall_us: float
    banking_efficiency: float = 1.0   # worst par concurrency retention

    def as_dict(self):
        return dataclasses.asdict(self)


def estimate(comp: Component) -> Estimate:
    cyc = cycles(comp)
    res = resources(comp)
    states = fsm_states(comp.control)
    depth = max_select_depth(comp)
    period = F.achievable_period_ns(states, depth)
    return Estimate(
        cycles=cyc,
        resources=res.as_dict(),
        fsm_states=states,
        period_ns=round(period, 3),
        fmax_mhz=round(1000.0 / period, 1),
        wall_us=round(cyc * period / 1000.0, 3),
        banking_efficiency=round(banking_efficiency(comp), 4),
    )
