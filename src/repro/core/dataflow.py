"""Executable datapath semantics (micro-ops) for Calyx groups.

The lowering in ``calyx._Lower`` used to record only a *summary* of each
group (latency, cells, port accesses); the computation itself was lost at
lowering time, so the emitted component could be estimated but never
executed.  This module defines the micro-op vocabulary the lowering now
records per group — cell invocations, register reads/writes, and memory
port accesses with concrete address expressions — plus the evaluator the
cycle-accurate simulator (``core.sim``) drives.

A micro-op list is a small SSA program over per-activation temporaries:
temps are dense integers local to one group activation, so re-executing a
group across ``repeat`` iterations never aliases stale state.  Micro-ops
that occupy a memory port carry the cycle *offset* (within the group's
activation window) at which the port is busy, consistent with the latency
arithmetic of the lowering — the hook the simulator uses to enforce
Calyx's one-access-per-cycle memory constraint at per-cycle granularity.
ALU, select, and register-write micro-ops likewise carry the offset at
which they fire: the scheduling layer (``core.pipelining``) reads those
stamps to derive loop-carried recurrence constraints when computing a
pipelined loop's initiation interval.

``UAlu.cell`` names the functional unit that performs the operation.  When
the binding pass (``sharing.share_cells``) rebinds units onto shared pools
the name is rewritten to the pool cell while ``orig_cell`` keeps the
pre-binding identity: every use keeps its own operand temporaries and its
provenance, i.e. the per-user operand routing stays explicit, which is
what lets the simulator arbitrate single ownership of shared units.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence

from .affine import AExpr, Cond


class UOp:
    """Base class for group micro-operations."""


@dataclasses.dataclass
class UConst(UOp):
    dst: int
    value: float


@dataclasses.dataclass
class URegRead(UOp):
    dst: int
    reg: str


@dataclasses.dataclass
class UMemRead(UOp):
    dst: int
    mem: str
    idxs: List[AExpr]
    off: int                  # cycle offset of the port access in the group


@dataclasses.dataclass
class UAlu(UOp):
    dst: int
    op: str                   # add sub mul div max min | exp relu neg
    a: int
    b: Optional[int]          # None for unary ops
    cell: str                 # functional unit (pool name after binding)
    orig_cell: str = ""       # pre-binding cell name (set by sharing)
    off: int = 0              # cycle offset at which the unit starts


@dataclasses.dataclass
class USelect(UOp):
    dst: int
    cond: Cond
    a: int
    b: int
    off: int = 0              # cycle offset at which the mux selects


@dataclasses.dataclass
class URegWrite(UOp):
    reg: str
    src: int
    off: int = 0              # cycle offset at which the register latches


@dataclasses.dataclass
class UMemWrite(UOp):
    mem: str
    idxs: List[AExpr]
    src: int
    off: int                  # cycle offset of the write-port access


# Integer-temp fields a micro-op may carry — the single source for passes
# that renumber or analyze the SSA space (chaining, verify).
TEMP_FIELDS = ("dst", "a", "b", "src")


def temp_def(u: UOp) -> Optional[int]:
    """The temp a micro-op defines, or None (writes define no temp)."""
    if isinstance(u, (UConst, URegRead, UMemRead, UAlu, USelect)):
        return u.dst
    return None


def temp_uses(u: UOp) -> List[int]:
    """The temps a micro-op reads, in operand order."""
    if isinstance(u, UAlu):
        return [u.a] if u.b is None else [u.a, u.b]
    if isinstance(u, USelect):
        return [u.a, u.b]
    if isinstance(u, (URegWrite, UMemWrite)):
        return [u.src]
    return []


_BIN = {
    "add": lambda a, b: a + b,
    "sub": lambda a, b: a - b,
    "mul": lambda a, b: a * b,
    "div": lambda a, b: a / b,
    "max": max,
    "min": min,
}


def alu(op: str, a: float, b: Optional[float] = None) -> float:
    """Reference FU semantics — must agree with ``affine.interpret``."""
    fn = _BIN.get(op)
    if fn is not None:
        return fn(a, b)
    if op == "exp":
        return math.exp(min(a, 700.0))
    if op == "relu":
        return max(a, 0.0)
    if op == "neg":
        return -a
    raise KeyError(op)


def uop_detail(u: UOp) -> str:
    """The trace descriptor of a micro-op (``trace.UOP`` event detail).

    Chosen so the Calyx-level and netlist-level simulators produce equal
    strings for the same operation: ``UAlu.cell`` equals the lowered
    ``DpUnit.unit`` (post-sharing pool name), register and memory names
    survive lowering unchanged.
    """
    if isinstance(u, UAlu):
        return f"alu:{u.op}:{u.cell}"
    if isinstance(u, UConst):
        return "const"
    if isinstance(u, URegRead):
        return f"regrd:{u.reg}"
    if isinstance(u, USelect):
        return "select"
    if isinstance(u, URegWrite):
        return f"regwr:{u.reg}"
    if isinstance(u, UMemRead):
        return f"memrd:{u.mem}"
    if isinstance(u, UMemWrite):
        return f"memwr:{u.mem}"
    raise TypeError(u)


def uop_off(u: UOp) -> int:
    """Cycle offset of a micro-op within its group's activation window
    (0 for ops that carry no stamp: constants and register reads)."""
    return getattr(u, "off", 0)


def execute(uops: Sequence[UOp], env: Dict[str, int], regs: Dict[str, float],
            read_mem: Callable[[UMemRead], float],
            write_mem: Callable[[UMemWrite, float], None],
            on_alu: Optional[Callable[[UAlu], None]] = None,
            on_uop: Optional[Callable[[UOp], None]] = None) -> int:
    """Run one group activation; returns the micro-op count executed.

    ``read_mem`` / ``write_mem`` receive the micro-op itself so the caller
    can evaluate addresses against ``env``, track port occupancy, and touch
    its backing store.  Register state persists across activations through
    ``regs``; temporaries do not.  ``on_uop`` (the trace hook) sees every
    micro-op as it issues; it is None unless tracing is on.
    """
    tmp: Dict[int, float] = {}
    n = 0
    for u in uops:
        n += 1
        if on_uop is not None:
            on_uop(u)
        if isinstance(u, UConst):
            tmp[u.dst] = u.value
        elif isinstance(u, URegRead):
            tmp[u.dst] = regs[u.reg]
        elif isinstance(u, UMemRead):
            tmp[u.dst] = read_mem(u)
        elif isinstance(u, UAlu):
            if on_alu is not None:
                on_alu(u)
            tmp[u.dst] = alu(u.op, tmp[u.a],
                             None if u.b is None else tmp[u.b])
        elif isinstance(u, USelect):
            tmp[u.dst] = tmp[u.a] if u.cond.evaluate(env) else tmp[u.b]
        elif isinstance(u, URegWrite):
            regs[u.reg] = tmp[u.src]
        elif isinstance(u, UMemWrite):
            write_mem(u, tmp[u.src])
        else:
            raise TypeError(u)
    return n
