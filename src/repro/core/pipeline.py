"""End-to-end compile driver: PyTorch-like module -> synthesizable RTL.

``compile_model`` mirrors the paper's full flow plus the binding stage the
paper leaves to future work:

    frontend.trace      (PyTorch -> Allo -> Linalg)
    affine.lower_graph  (Linalg -> Affine/SCF/Memref)
    schedule.parallelize + restructure   (par materialization, FSM sharing)
    banking.apply_banking                (cyclic partitioning)
    banking.check_par_hazards            (static safety analysis)
    calyx.lower_program                  (CIRCT -> Calyx)
    chaining.chain_component             (opt_level>=1: group fusion)
    pipelining.pipeline_loops            (opt_level>=2: loop pipelining, II)
    sharing.share_cells                  (resource binding; ``share=True``)
    estimator.estimate                   (Calyx -> cost report)
    rtl.lower_component                  (Calyx -> FSM+datapath netlist)
    verilog.emit                         (netlist -> SystemVerilog)

The scheduling layer (``opt_level=0/1/2``) sits between lowering and
binding: level 1 fuses seq runs and port-compatible par arms into
multi-op groups (cycle-neutral along seq; removes fork/join handshakes
and most FSM states), level 2 additionally pipelines innermost
single-group repeats with a statically computed initiation interval, so
``cycles = setup + (extent-1)*II + body`` replaces
``setup + extent*(body+overhead)``.  Designs whose par arms still
conflict-serialize get a ``BankingEfficiencyWarning`` and report
``estimate.banking_efficiency < 1``.

The sharing stage rebinds expensive functional units of mutually exclusive
groups onto shared pools; it provably cannot change ``estimate.cycles``
(group latencies, ports, and control are untouched — asserted below) and it
never merges cells across ``par`` arms, so parallel speedups survive intact.
Pass ``share=False`` to reproduce the paper's every-statement-owns-its-unit
resource numbers (Table 2).

The returned ``CompiledDesign`` executes at three levels, forming the
**four-way differential harness** against the jnp oracle:

* ``run`` interprets the *banked affine program* on numpy — proving the
  transformed hardware schedule computes the same function as the oracle;
* ``simulate`` cycle-accurately executes the *lowered Calyx component*
  (``core.sim``), measuring a cycle count that must equal
  ``estimate.cycles`` exactly;
* ``simulate_rtl`` executes the *RTL netlist itself* (``core.rtl_sim``) —
  the same artifact ``emit_verilog`` prints — cycle by cycle through its
  explicit FSM controllers, again measuring ``estimate.cycles`` exactly.

RTL-simulated ≡ Calyx-simulated ≡ affine-interpreted outputs bit-for-bit,
all ≡ oracle within float tolerance, and both measured cycle counts ≡ the
closed-form estimate with zero tolerance — asserted by the differential
matrix in ``tests/test_core_rtl.py`` / ``tests/test_core_sim.py``.

Observability hook points (``core.trace`` / ``core.profiler``):

* ``simulate(inputs, tracer=Tracer())`` — canonical event trace at
  micro-op granularity (group windows, uop issues, port grants, stalls);
* ``simulate_rtl(inputs, tracer=Tracer(), profile=True)`` — the same
  schema at netlist granularity (plus ``fsm:state`` events), join-able
  event-for-event against the Calyx-level trace, with
  ``RtlStats.counters`` modeling the synthesized counter bank per cycle;
* ``to_rtl(profile=True)`` / ``emit_verilog(profile=True)`` — the
  netlist/SystemVerilog with the hardware perf-counter bank, read over
  the host bus at bank ``rtl.PROFILE_HOST_BANK``;
* ``profile(inputs)`` — runs everything above plus
  ``estimator.attribute`` and returns the joined ``profiler.Profile``
  (flame table, occupancy, stall breakdown, four-way counter check).

All hooks default off; the untraced paths allocate no event objects and
build no provenance tuples (the <2% overhead contract the perf gate
checks).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, List, Optional, Tuple

import numpy as np

from . import affine, banking, calyx, chaining, estimator, frontend
from . import pipelining, schedule, sharing
from . import profiler
from . import rtl as rtl_ir
from . import rtl_sim
from . import sim as calyx_sim
from . import trace
from . import tensor_ir as T
from . import jax_backend
from . import verify as verify_mod
from . import verilog
from .diagnostics import DiagnosticReport


@dataclasses.dataclass
class CompiledDesign:
    graph: T.Graph
    program: affine.Program          # final (scheduled + banked) program
    component: calyx.Component
    estimate: estimator.Estimate
    hazards: List[str]
    spec: banking.BankingSpec
    sharing: Optional[sharing.SharingReport] = None
    opt_level: int = 0               # scheduling level the design was built at
    # stage-boundary verification (core.verify): one DiagnosticReport per
    # boundary the compile crossed; empty when the design was compiled with
    # verify=False.  to_rtl() appends the post-RTL report lazily.
    verify_reports: List[DiagnosticReport] = dataclasses.field(
        default_factory=list, repr=False, compare=False)
    verify_enabled: bool = True
    # netlist cache, keyed by the profile flag (a profiled netlist adds
    # the perf-counter bank; both variants are deterministic)
    _netlists: Dict[bool, rtl_ir.Netlist] = dataclasses.field(
        default_factory=dict, repr=False, compare=False)

    def _validate_inputs(self, inputs: Dict[str, np.ndarray]) -> None:
        """Check input names and shapes up front with a clear error.

        Without this, a missing or misshaped input surfaces as a deep
        ``KeyError``/``ValueError`` inside the micro-op evaluator, far
        from the call site.
        """
        expected = {op.name: tuple(op.shape) for op in self.graph.ops
                    if op.kind == "input"}
        missing = sorted(set(expected) - set(inputs))
        extra = sorted(set(inputs) - set(expected))
        if missing or extra:
            want = ", ".join(f"{n}{expected[n]}" for n in sorted(expected))
            raise ValueError(
                f"design {self.graph.name!r} takes inputs [{want}]; "
                + (f"missing {missing}" if missing else "")
                + ("; " if missing and extra else "")
                + (f"unexpected {extra}" if extra else ""))
        for name, shape in expected.items():
            got = tuple(np.asarray(inputs[name]).shape)
            if got != shape:
                raise ValueError(
                    f"input {name!r} of design {self.graph.name!r} has "
                    f"shape {got}, expected {shape}")

    def run(self, inputs: Dict[str, np.ndarray]) -> List[np.ndarray]:
        """Execute the banked hardware schedule (numpy interpreter)."""
        self._validate_inputs(inputs)
        mems = affine.interpret(self.program, inputs, self.graph.params)
        return self._extract_outputs(mems)

    def simulate(self, inputs: Dict[str, np.ndarray],
                 tracer: Optional[trace.Tracer] = None
                 ) -> Tuple[List[np.ndarray], "calyx_sim.SimStats"]:
        """Cycle-accurately execute the lowered Calyx component.

        Runs the FSM scheduler over the component's control tree, firing
        each group's micro-ops against real memory/register state, and
        returns ``(outputs, SimStats)`` where ``SimStats.cycles`` is the
        *measured* latency (equal to ``estimate.cycles`` by construction —
        asserted by the differential tests).

        Trace hook: pass a ``trace.Tracer`` to record the canonical event
        trace (group windows, micro-op issues, port grants, stalls) at
        micro-op granularity; ``None`` (the default) keeps the simulator
        on its zero-instrumentation path.
        """
        self._validate_inputs(inputs)
        mems, stats = calyx_sim.simulate(self.component, self.program,
                                         inputs, self.graph.params,
                                         tracer=tracer)
        return self._extract_outputs(mems), stats

    # -- RTL backend ----------------------------------------------------------
    def to_rtl(self, profile: bool = False) -> rtl_ir.Netlist:
        """Lower the Calyx component to the FSM + datapath netlist
        (cached per ``profile`` flag — both variants are deterministic
        for a compiled design).  ``profile=True`` additionally
        synthesizes the hardware perf-counter bank (``rtl.PerfCounter``)
        read over the host bus.  When the design was compiled with
        ``verify=True`` the netlist is statically checked at this
        boundary too (post-RTL: multi-driven nets, combinational loops,
        FSM reachability, and — profiled — the counter address map)."""
        if profile not in self._netlists:
            net = rtl_ir.lower_component(self.component, self.program,
                                         profile=profile)
            if self.verify_enabled:
                rep = verify_mod.verify_netlist(net)
                self.verify_reports.append(rep)
                rep.raise_if_errors()
            self._netlists[profile] = net
        return self._netlists[profile]

    def emit_verilog(self, path: Optional[str] = None,
                     profile: bool = False) -> str:
        """Emit the netlist as SystemVerilog (structurally synthesizable;
        simulation-level FP cores with a HardFloat drop-in point);
        optionally write it to ``path``.  Deterministic byte-for-byte.
        ``profile=True`` includes the synthesized perf-counter bank."""
        text = verilog.emit(self.to_rtl(profile=profile))
        if path is not None:
            with open(path, "w") as f:
                f.write(text)
        return text

    def simulate_rtl(self, inputs: Dict[str, np.ndarray],
                     tracer: Optional[trace.Tracer] = None,
                     profile: bool = False
                     ) -> Tuple[List[np.ndarray], "rtl_sim.RtlStats"]:
        """Execute the RTL netlist cycle-by-cycle (``core.rtl_sim``).

        This drives the *netlist* — explicit FSM controllers, physical
        memory banks, operand-muxed units — not the Calyx IR; outputs are
        bit-equal to ``simulate``/``run`` and ``RtlStats.cycles`` equals
        ``estimate.cycles`` exactly (the four-way differential contract).

        Trace hook: a ``trace.Tracer`` records the same canonical event
        schema ``simulate`` emits (plus netlist-only ``fsm:state``
        events), with provenance keys that join event-for-event against
        the Calyx-level trace.  ``profile=True`` runs the netlist that
        carries the synthesized counter bank and fills
        ``RtlStats.counters`` with the per-cycle hardware counter model.
        """
        self._validate_inputs(inputs)
        mems, stats = rtl_sim.simulate(self.to_rtl(profile=profile),
                                       inputs, self.graph.params,
                                       tracer=tracer)
        return self._extract_outputs(mems), stats

    def profile(self, inputs: Dict[str, np.ndarray]) -> "profiler.Profile":
        """Run both simulators with tracing plus the analytic attribution
        and return the joined :class:`profiler.Profile` (flame table,
        occupancy, stall breakdown, counter cross-check)."""
        return profiler.profile_design(self, inputs)

    def _extract_outputs(self, mems: Dict[str, np.ndarray]
                         ) -> List[np.ndarray]:
        outs = []
        orig_shapes = self.program.meta.get("orig_shapes", {})
        for name in self.graph.outputs:
            decl = self.program.mems[name]
            arr = mems[name]
            if decl.banks:
                arr = affine.unpack_banked(arr, orig_shapes[name], decl.banks)
            outs.append(arr.astype(np.float32))
        return outs

    def run_oracle(self, inputs: Dict[str, np.ndarray]) -> List[np.ndarray]:
        return jax_backend.execute_graph(self.graph, inputs)

    def calyx_text(self) -> str:
        return calyx.emit_text(self.component)


def compile_graph(graph: T.Graph, factor: int = 1, mode: str = "layout",
                  restructure: bool = True,
                  check_hazards: bool = True,
                  share: bool = True,
                  opt_level: int = 0,
                  verify: bool = True) -> CompiledDesign:
    """Compile a tensor graph to a Calyx component + estimate.

    ``opt_level`` selects the static scheduling layer between lowering
    and binding/estimation:

    * ``0`` — the paper's schedule: one group per statement, loops pay a
      per-iteration overhead, ``par`` pays a fork/join per activation.
    * ``1`` — operation chaining / group fusion (``core.chaining``):
      seq runs and port-compatible par arms fuse into multi-op groups;
      FSM states, go/done fabric, and join handshakes collapse.
    * ``2`` — level 1 plus loop pipelining (``core.pipelining``):
      innermost single-group repeats get an initiation interval from
      memory-port, non-pipelined-unit, and loop-carried register
      constraints, and iterations overlap.

    ``verify`` (default on) runs the stage-boundary static verifier
    (``core.verify``) on every lowered artifact — post-lower,
    post-chaining, post-pipelining, post-sharing, and (lazily, in
    ``to_rtl``) post-RTL — raising
    :class:`~.diagnostics.VerificationError` on any error-severity
    finding, and strips dead groups/cells the liveness analysis proves
    unreachable (cycle-neutral).  The per-stage reports are kept on
    ``CompiledDesign.verify_reports``.

    Every level preserves the end-to-end invariant: estimator cycles ==
    Calyx-sim cycles == RTL-sim cycles exactly, and outputs bit-equal to
    the affine interpreter.
    """
    if opt_level not in (0, 1, 2):
        raise ValueError(f"opt_level must be 0, 1, or 2 (got {opt_level})")
    prog = affine.lower_graph(graph)
    if factor > 1:
        prog = schedule.parallelize(prog, factor)
        if check_hazards and mode == "layout":
            banking.check_par_hazards(prog)
        prog = schedule.restructure(prog, enable=restructure)
    spec = banking.BankingSpec(factor=factor, mode=mode)
    prog = banking.apply_banking(prog, spec)
    hazards = []
    if factor > 1:
        hazards = banking.check_par_hazards(
            prog, raise_on_conflict=(check_hazards and mode == "layout"))
    reports: List[DiagnosticReport] = []
    # one cache across all of this compile's boundaries: groups a pass
    # carries over unchanged skip re-proving their per-group checks
    vcache = verify_mod.GroupCache()

    def checkpoint(stage: str, component: calyx.Component) -> None:
        if not verify:
            return
        rep = verify_mod.verify_component(component, prog, stage=stage,
                                          cache=vcache)
        reports.append(rep)
        rep.raise_if_errors()

    comp = calyx.lower_program(prog)
    checkpoint("post-lower", comp)
    if opt_level >= 1:
        comp = chaining.chain_component(comp)
        checkpoint("post-chaining", comp)
    if opt_level >= 2:
        comp = pipelining.pipeline_loops(comp)
        checkpoint("post-pipelining", comp)
    if verify:
        # liveness-fed cleanup: provably cycle-neutral (control untouched)
        comp, _removed = verify_mod.eliminate_dead(comp, vcache)
    report = None
    pre_cycles = None
    if share:
        pre_cycles = estimator.cycles(comp)
        pre_groups = comp.groups
        comp, report = sharing.share_cells(comp)
        if verify:
            # carry clean verdicts across the rebind after re-proving,
            # uop by uop, that binding changed nothing but cell names
            bound = {orig: pool for pool, origs in report.pools.items()
                     for orig in origs}
            vcache.transfer_rebound(pre_groups, comp.groups, bound)
    checkpoint("post-sharing", comp)
    est = estimator.estimate(comp)
    if pre_cycles is not None and est.cycles != pre_cycles:
        # load-bearing invariant: survives python -O
        raise RuntimeError(
            f"resource sharing changed the schedule "
            f"({pre_cycles} -> {est.cycles} cycles) — binding must "
            f"be latency-neutral")
    if est.banking_efficiency < 1.0:
        serial = estimator.par_serializations(comp)
        detail = "; ".join(f"{n} arms -> {k} concurrent"
                           for _, n, k in serial[:4])
        warnings.warn(
            f"design {graph.name!r} (factor={factor}, mode={mode}, "
            f"opt_level={opt_level}): {len(serial)} par block(s) "
            f"conflict-serialize on memory banks ({detail}) — banking "
            f"efficiency {est.banking_efficiency}",
            estimator.BankingEfficiencyWarning, stacklevel=2)
    return CompiledDesign(graph, prog, comp, est, hazards, spec,
                          sharing=report, opt_level=opt_level,
                          verify_reports=reports, verify_enabled=verify)


def compile_model(module: frontend.Module, input_shapes,
                  factor: int = 1, mode: str = "layout",
                  restructure: bool = True, name: str = "main",
                  check_hazards: bool = True,
                  share: bool = True,
                  opt_level: int = 0,
                  verify: bool = True) -> CompiledDesign:
    graph = frontend.trace(module, input_shapes, name=name)
    return compile_graph(graph, factor=factor, mode=mode,
                         restructure=restructure, check_hazards=check_hazards,
                         share=share, opt_level=opt_level, verify=verify)
