"""Affine loop-nest IR — the "Linalg → Affine/SCF/Memref" stage.

The IR models what the paper lowers through MLIR: perfect/imperfect loop
nests over multi-dimensional memories with affine accesses, scalar registers
for reductions, structured `if` (the paper's added SCF support), and explicit
`par` blocks (Calyx's first-class parallel control).

The affine-expression engine is the heart of the banking pass: expressions
are kept in a canonical linear form over *atoms* (loop variables or opaque
``div``/``mod`` terms) so that after par-unrolling substitutes constants,
``(c*ii + j) % c`` folds to ``j`` and ``(c*ii + j) // c`` folds to ``ii`` —
exactly the compile-time-constant bank index the paper relies on.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

# ---------------------------------------------------------------------------
# Affine expressions (integer domain)
# ---------------------------------------------------------------------------


class Atom:
    """Base for linear-combination atoms."""

    def key(self) -> tuple:  # pragma: no cover - abstract
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class Var(Atom):
    name: str

    def key(self):
        return ("var", self.name)

    def __repr__(self):
        return self.name


@dataclasses.dataclass(frozen=True)
class DivAtom(Atom):
    """floor(inner / c) that did not fold."""
    inner: "AExpr"
    c: int

    def key(self):
        return ("div", self.inner.key(), self.c)

    def __repr__(self):
        return f"({self.inner} // {self.c})"


@dataclasses.dataclass(frozen=True)
class ModAtom(Atom):
    """inner mod c that did not fold."""
    inner: "AExpr"
    c: int

    def key(self):
        return ("mod", self.inner.key(), self.c)

    def __repr__(self):
        return f"({self.inner} % {self.c})"


class AExpr:
    """Canonical affine expression: sum(coeff * atom) + const.

    Structurally hashable so that identical div/mod atoms built in different
    par-arm clones merge during algebra (required for the disjointness proof:
    ``(bank+1) - bank`` must fold to the constant 1).
    """

    __slots__ = ("coeffs", "const", "_key", "_free")

    def __init__(self, coeffs: Optional[Dict[Atom, int]] = None, const: int = 0):
        self.coeffs = {a: c for a, c in (coeffs or {}).items() if c != 0}
        self.const = int(const)
        self._key = None
        self._free = None

    def __eq__(self, other):
        return isinstance(other, AExpr) and self.key() == other.key()

    def __hash__(self):
        return hash(self.key())

    # -- constructors --------------------------------------------------------
    @staticmethod
    def const_(v: int) -> "AExpr":
        return AExpr({}, v)

    @staticmethod
    def var(name: str) -> "AExpr":
        return AExpr({Var(name): 1}, 0)

    # -- algebra ---------------------------------------------------------------
    def __add__(self, other: Union["AExpr", int]) -> "AExpr":
        other = _as_aexpr(other)
        coeffs = dict(self.coeffs)
        for a, c in other.coeffs.items():
            coeffs[a] = coeffs.get(a, 0) + c
        return AExpr(coeffs, self.const + other.const)

    def __sub__(self, other: Union["AExpr", int]) -> "AExpr":
        return self + (_as_aexpr(other) * -1)

    def __mul__(self, k: int) -> "AExpr":
        return AExpr({a: c * k for a, c in self.coeffs.items()}, self.const * k)

    def floordiv(self, c: int) -> "AExpr":
        assert c > 0
        if c == 1:
            return self
        if not self.coeffs:
            return AExpr.const_(self.const // c)
        if all(co % c == 0 for co in self.coeffs.values()):
            # c*L + k  -->  L + k//c   (exact because c*L is divisible)
            return AExpr({a: co // c for a, co in self.coeffs.items()},
                         self.const // c)
        return AExpr({DivAtom(self, c): 1}, 0)

    def mod(self, c: int) -> "AExpr":
        assert c > 0
        if c == 1:
            return AExpr.const_(0)
        if not self.coeffs:
            return AExpr.const_(self.const % c)
        if all(co % c == 0 for co in self.coeffs.values()):
            return AExpr.const_(self.const % c)
        return AExpr({ModAtom(self, c): 1}, 0)

    # -- queries ---------------------------------------------------------------
    def is_const(self) -> bool:
        return not self.coeffs

    def const_value(self) -> int:
        assert self.is_const(), f"{self} is not constant"
        return self.const

    def atoms(self) -> List[Atom]:
        return list(self.coeffs)

    def has_divmod(self) -> bool:
        """True if any non-folded div/mod survives anywhere inside."""
        for a in self.coeffs:
            if isinstance(a, (DivAtom, ModAtom)):
                return True
            # Vars are leaves.
        return False

    def free_vars(self) -> frozenset:
        if self._free is None:
            out = set()
            for a in self.coeffs:
                if isinstance(a, Var):
                    out.add(a.name)
                else:
                    out |= a.inner.free_vars()
            self._free = frozenset(out)
        return self._free

    def key(self) -> tuple:
        if self._key is None:
            self._key = (tuple(sorted((a.key(), c)
                                      for a, c in self.coeffs.items())),
                         self.const)
        return self._key

    def substitute(self, env: Dict[str, "AExpr"]) -> "AExpr":
        """Substitute vars and re-canonicalize (refolds div/mod)."""
        out = AExpr.const_(self.const)
        for a, co in self.coeffs.items():
            if isinstance(a, Var):
                repl = env.get(a.name)
                term = (repl if repl is not None else AExpr({a: 1})) * co
            elif isinstance(a, DivAtom):
                term = a.inner.substitute(env).floordiv(a.c) * co
            else:
                term = a.inner.substitute(env).mod(a.c) * co
            out = out + term
        return out

    def evaluate(self, env: Dict[str, int]) -> int:
        total = self.const
        for a, co in self.coeffs.items():
            if isinstance(a, Var):
                total += co * env[a.name]
            elif isinstance(a, DivAtom):
                total += co * (a.inner.evaluate(env) // a.c)
            else:
                total += co * (a.inner.evaluate(env) % a.c)
        return total

    def divmod_count(self) -> int:
        """Number of surviving div/mod operations (each costs hardware)."""
        n = 0
        for a in self.coeffs:
            if isinstance(a, (DivAtom, ModAtom)):
                n += 1 + a.inner.divmod_count()
        return n

    def mul_count(self) -> int:
        """Number of non-trivial integer multiplies to materialize this."""
        n = sum(1 for a, co in self.coeffs.items() if co not in (1, -1))
        for a in self.coeffs:
            if isinstance(a, (DivAtom, ModAtom)):
                n += a.inner.mul_count()
        return n

    def __repr__(self):
        parts = [f"{c}*{a}" if c != 1 else f"{a}" for a, c in self.coeffs.items()]
        if self.const or not parts:
            parts.append(str(self.const))
        return " + ".join(parts)


def _as_aexpr(v: Union[AExpr, int]) -> AExpr:
    return v if isinstance(v, AExpr) else AExpr.const_(v)


@dataclasses.dataclass
class Cond:
    """Affine condition  lhs <op> 0  (canonicalized)."""
    op: str            # 'le', 'lt', 'eq', 'ge', 'gt'
    expr: AExpr        # compare expr against 0

    @staticmethod
    def cmp(lhs: AExpr, op: str, rhs: Union[AExpr, int]) -> "Cond":
        return Cond(op, lhs - _as_aexpr(rhs))

    def evaluate(self, env: Dict[str, int]) -> bool:
        v = self.expr.evaluate(env)
        return {"le": v <= 0, "lt": v < 0, "eq": v == 0,
                "ge": v >= 0, "gt": v > 0}[self.op]

    def substitute(self, env: Dict[str, AExpr]) -> "Cond":
        return Cond(self.op, self.expr.substitute(env))

    def try_const(self) -> Optional[bool]:
        if self.expr.is_const():
            return self.evaluate({})
        return None

    def __repr__(self):
        sym = {"le": "<=", "lt": "<", "eq": "==", "ge": ">=", "gt": ">"}[self.op]
        return f"({self.expr} {sym} 0)"


# ---------------------------------------------------------------------------
# Value (float-domain) expressions
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class VExpr:
    pass


@dataclasses.dataclass
class ConstF(VExpr):
    value: float


@dataclasses.dataclass
class Load(VExpr):
    mem: str
    idxs: List[AExpr]


@dataclasses.dataclass
class ReadReg(VExpr):
    name: str


@dataclasses.dataclass
class Bin(VExpr):
    op: str   # add sub mul div max min
    a: VExpr
    b: VExpr


@dataclasses.dataclass
class Un(VExpr):
    op: str   # exp relu neg
    a: VExpr


@dataclasses.dataclass
class SelectC(VExpr):
    """cond ? a : b — hardware instantiates both sides plus a mux."""
    cond: Cond
    a: VExpr
    b: VExpr


# ---------------------------------------------------------------------------
# Statements
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Stmt:
    pass


@dataclasses.dataclass
class Store(Stmt):
    mem: str
    idxs: List[AExpr]
    value: VExpr


@dataclasses.dataclass
class SetReg(Stmt):
    name: str
    value: VExpr


@dataclasses.dataclass
class Loop(Stmt):
    var: str
    extent: int
    body: List[Stmt]
    kind: str = "seq"    # 'seq' | 'par_data' | 'reduce'


@dataclasses.dataclass
class Par(Stmt):
    """Explicit parallel arms (Calyx `par`). Arms must be hazard-free."""
    arms: List[List[Stmt]]


@dataclasses.dataclass
class If(Stmt):
    cond: Cond
    then: List[Stmt]
    els: List[Stmt] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class MemDecl:
    name: str
    shape: Tuple[int, ...]
    role: str = "temp"         # input | param | temp | output
    banks: Tuple[int, ...] = ()  # set by the banking pass; () = unbanked

    @property
    def size(self) -> int:
        out = 1
        for s in self.shape:
            out *= s
        return out


@dataclasses.dataclass
class Program:
    name: str
    mems: Dict[str, MemDecl]
    body: List[Stmt]
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def mem(self, name: str) -> MemDecl:
        return self.mems[name]


# ---------------------------------------------------------------------------
# Lowering: tensor Graph -> affine Program
# ---------------------------------------------------------------------------

from . import tensor_ir as T  # noqa: E402  (cycle-free: tensor_ir has no deps)


class _Lowerer:
    def __init__(self, graph: T.Graph):
        self.g = graph
        self.prog = Program(graph.name, {}, [])
        self._reg = 0

    def fresh_reg(self, stem="r") -> str:
        self._reg += 1
        return f"{stem}{self._reg}"

    def declare(self, name: str, shape, role: str):
        self.prog.mems[name] = MemDecl(name, tuple(shape), role)

    def run(self) -> Program:
        out_set = set(self.g.outputs)
        for op in self.g.ops:
            role = ("input" if op.kind == "input" else
                    "param" if op.kind == "param" else
                    "output" if op.name in out_set else "temp")
            self.declare(op.name, op.shape, role)
            fn = getattr(self, f"lower_{op.kind}", None)
            if fn is None:
                raise NotImplementedError(op.kind)
            fn(op)
        self.prog.meta["useful_flops"] = self.g.flops()
        return self.prog

    # -- per-op lowerings ------------------------------------------------------
    def lower_input(self, op):
        pass

    def lower_param(self, op):
        pass

    def _loopvars(self, op, dims: int) -> List[str]:
        return [f"{op.name}_i{d}" for d in range(dims)]

    def lower_matmul(self, op):
        a, b = op.inputs
        m, k = self.g.shape(a)
        _, n = self.g.shape(b)
        i, j, kk = self._loopvars(op, 3)
        acc = self.fresh_reg("acc")
        iv, jv, kv = AExpr.var(i), AExpr.var(j), AExpr.var(kk)
        inner = [SetReg(acc, Bin("add", ReadReg(acc),
                                 Bin("mul", Load(a, [iv, kv]), Load(b, [kv, jv]))))]
        body_j = [SetReg(acc, ConstF(0.0)),
                  Loop(kk, k, inner, kind="reduce"),
                  Store(op.name, [iv, jv], ReadReg(acc))]
        self.prog.body.append(
            Loop(i, m, [Loop(j, n, body_j, kind="par_data")], kind="par_data"))

    def lower_add(self, op):
        a, b = op.inputs
        sa, sb = self.g.shape(a), self.g.shape(b)
        vs = self._loopvars(op, len(sa))
        idx = [AExpr.var(v) for v in vs]
        bidx = idx[len(sa) - len(sb):] if sa != sb else idx
        body = [Store(op.name, idx, Bin("add", Load(a, idx), Load(b, bidx)))]
        self.prog.body.append(_nest(vs, sa, body, inner_par=True))

    def lower_mul(self, op):
        a, b = op.inputs
        sa = self.g.shape(a)
        vs = self._loopvars(op, len(sa))
        idx = [AExpr.var(v) for v in vs]
        body = [Store(op.name, idx, Bin("mul", Load(a, idx), Load(b, idx)))]
        self.prog.body.append(_nest(vs, sa, body, inner_par=True))

    def lower_scale(self, op):
        a = op.inputs[0]
        sa = self.g.shape(a)
        vs = self._loopvars(op, len(sa))
        idx = [AExpr.var(v) for v in vs]
        body = [Store(op.name, idx,
                      Bin("mul", Load(a, idx), ConstF(op.attrs["value"])))]
        self.prog.body.append(_nest(vs, sa, body, inner_par=True))

    def lower_relu(self, op):
        a = op.inputs[0]
        sa = self.g.shape(a)
        vs = self._loopvars(op, len(sa))
        idx = [AExpr.var(v) for v in vs]
        body = [Store(op.name, idx, Un("relu", Load(a, idx)))]
        self.prog.body.append(_nest(vs, sa, body, inner_par=True))

    def lower_conv2d(self, op):
        x, w = op.inputs
        cout, oh, ow = op.shape
        cin, kh, kw = op.attrs["cin"], op.attrs["kh"], op.attrs["kw"]
        co, oy, ox, ci, ky, kx = self._loopvars(op, 6)
        acc = self.fresh_reg("cacc")
        cov, oyv, oxv = AExpr.var(co), AExpr.var(oy), AExpr.var(ox)
        civ, kyv, kxv = AExpr.var(ci), AExpr.var(ky), AExpr.var(kx)
        mac = [SetReg(acc, Bin("add", ReadReg(acc),
                               Bin("mul",
                                   Load(x, [civ, oyv + kyv, oxv + kxv]),
                                   Load(w, [cov, civ, kyv, kxv]))))]
        red = Loop(ci, cin, [Loop(ky, kh, [Loop(kx, kw, mac, kind="reduce")])])
        body = [SetReg(acc, ConstF(0.0)), red,
                Store(op.name, [cov, oyv, oxv], ReadReg(acc))]
        self.prog.body.append(
            Loop(co, cout,
                 [Loop(oy, oh, [Loop(ox, ow, body, kind="par_data")])],
                 kind="par_data"))

    def lower_maxpool2d(self, op):
        x = op.inputs[0]
        c, oh, ow = op.shape
        ph, pw = op.attrs["ph"], op.attrs["pw"]
        cv_, yv_, xv_, py_, px_ = self._loopvars(op, 5)
        m = self.fresh_reg("mx")
        cv, yv, xv = AExpr.var(cv_), AExpr.var(yv_), AExpr.var(xv_)
        pyv, pxv = AExpr.var(py_), AExpr.var(px_)
        mac = [SetReg(m, Bin("max", ReadReg(m),
                             Load(x, [cv, yv * ph + pyv, xv * pw + pxv])))]
        red = Loop(py_, ph, [Loop(px_, pw, mac, kind="reduce")])
        body = [SetReg(m, ConstF(-1e30)), red,
                Store(op.name, [cv, yv, xv], ReadReg(m))]
        self.prog.body.append(
            Loop(cv_, c, [Loop(yv_, oh, [Loop(xv_, ow, body, kind="par_data")])],
                 kind="par_data"))

    def lower_flatten(self, op):
        x = op.inputs[0]
        sx = self.g.shape(x)
        vs = self._loopvars(op, len(sx))
        idx = [AExpr.var(v) for v in vs]
        # linearize: exercises the address arithmetic the paper highlights
        lin = AExpr.const_(0)
        stride = 1
        for d in reversed(range(len(sx))):
            lin = lin + idx[d] * stride
            stride *= sx[d]
        body = [Store(op.name, [lin], Load(x, idx))]
        self.prog.body.append(_nest(vs, sx, body, inner_par=True))

    def lower_reshape(self, op):
        x = op.inputs[0]
        sx, so = self.g.shape(x), op.shape
        vs = self._loopvars(op, len(sx))
        idx = [AExpr.var(v) for v in vs]
        lin = AExpr.const_(0)
        stride = 1
        for d in reversed(range(len(sx))):
            lin = lin + idx[d] * stride
            stride *= sx[d]
        oidx = []
        rem = lin
        strides_o = []
        s = 1
        for d in reversed(range(len(so))):
            strides_o.insert(0, s)
            s *= so[d]
        for d in range(len(so)):
            oidx.append(rem.floordiv(strides_o[d]).mod(so[d]) if d > 0
                        else rem.floordiv(strides_o[d]))
        body = [Store(op.name, oidx, Load(x, idx))]
        self.prog.body.append(_nest(vs, sx, body, inner_par=True))

    def lower_transpose(self, op):
        x = op.inputs[0]
        m, n = self.g.shape(x)
        i, j = self._loopvars(op, 2)
        iv, jv = AExpr.var(i), AExpr.var(j)
        body = [Store(op.name, [jv, iv], Load(x, [iv, jv]))]
        self.prog.body.append(
            Loop(i, m, [Loop(j, n, body, kind="par_data")], kind="par_data"))

    def lower_softmax(self, op):
        x = op.inputs[0]
        m, n = self.g.shape(x)
        etmp = op.name + "_e"
        self.declare(etmp, (m, n), "temp")
        i, j1, j2, j3 = self._loopvars(op, 4)
        iv = AExpr.var(i)
        mx, s, e = self.fresh_reg("smax"), self.fresh_reg("ssum"), self.fresh_reg("se")
        body_i = [
            SetReg(mx, ConstF(-1e30)),
            Loop(j1, n, [SetReg(mx, Bin("max", ReadReg(mx),
                                        Load(x, [iv, AExpr.var(j1)])))],
                 kind="reduce"),
            SetReg(s, ConstF(0.0)),
            Loop(j2, n, [SetReg(e, Un("exp", Bin("sub", Load(x, [iv, AExpr.var(j2)]),
                                                 ReadReg(mx)))),
                         Store(etmp, [iv, AExpr.var(j2)], ReadReg(e)),
                         SetReg(s, Bin("add", ReadReg(s), ReadReg(e)))],
                 kind="reduce"),
            Loop(j3, n, [Store(op.name, [iv, AExpr.var(j3)],
                               Bin("div", Load(etmp, [iv, AExpr.var(j3)]),
                                   ReadReg(s)))],
                 kind="par_data"),
        ]
        self.prog.body.append(Loop(i, m, body_i, kind="par_data"))

    def lower_causal_mask(self, op):
        x = op.inputs[0]
        s1, _ = self.g.shape(x)
        i, j = self._loopvars(op, 2)
        iv, jv = AExpr.var(i), AExpr.var(j)
        # if j <= i: y = x else: y = -1e30   (exercises the SCF `if` support)
        body = [If(Cond.cmp(jv, "le", iv),
                   [Store(op.name, [iv, jv], Load(x, [iv, jv]))],
                   [Store(op.name, [iv, jv], ConstF(-1e30))])]
        self.prog.body.append(
            Loop(i, s1, [Loop(j, s1, body, kind="par_data")], kind="par_data"))


def _nest(vars_: Sequence[str], extents: Sequence[int], body: List[Stmt],
          inner_par: bool = False) -> Stmt:
    """Build a loop nest; innermost loop optionally data-parallel."""
    stmt: List[Stmt] = body
    out: Optional[Loop] = None
    for d in reversed(range(len(vars_))):
        kind = "par_data" if (inner_par and d == len(vars_) - 1) else "par_data"
        out = Loop(vars_[d], int(extents[d]), stmt, kind=kind)
        stmt = [out]
    return out if out is not None else Loop("_z", 1, body)


def lower_graph(graph: T.Graph) -> Program:
    return _Lowerer(graph).run()


# ---------------------------------------------------------------------------
# Cyclic-banked layout pack/unpack (numpy) — the data movement a host would
# perform when staging tensors into banked accelerator memories.
# ---------------------------------------------------------------------------


def pack_banked(arr: np.ndarray, factors: Sequence[int]) -> np.ndarray:
    """(s0,…) -> (prod(f), ceil(s0/f0),…) with cyclic interleave per dim."""
    shape = arr.shape
    intra = tuple(-(-s // f) for s, f in zip(shape, factors))
    nbanks = 1
    for f in factors:
        nbanks *= f
    out = np.zeros((nbanks,) + intra, dtype=arr.dtype)
    strides = []
    s = 1
    for f in reversed(factors):
        strides.insert(0, s)
        s *= f
    import itertools
    for combo in itertools.product(*[range(f) for f in factors]):
        bank = sum(b * st for b, st in zip(combo, strides))
        sl = tuple(slice(b, None, f) for b, f in zip(combo, factors))
        piece = arr[sl]
        dst = tuple(slice(0, piece.shape[d]) for d in range(len(shape)))
        out[(bank,) + dst] = piece
    return out


def unpack_banked(banked: np.ndarray, orig_shape: Sequence[int],
                  factors: Sequence[int]) -> np.ndarray:
    out = np.zeros(tuple(orig_shape), dtype=banked.dtype)
    strides = []
    s = 1
    for f in reversed(factors):
        strides.insert(0, s)
        s *= f
    import itertools
    for combo in itertools.product(*[range(f) for f in factors]):
        bank = sum(b * st for b, st in zip(combo, strides))
        sl = tuple(slice(b, None, f) for b, f in zip(combo, factors))
        out[sl] = banked[(bank,) + tuple(
            slice(0, out[sl].shape[d]) for d in range(len(orig_shape)))]
    return out


# ---------------------------------------------------------------------------
# Reference interpreter (numpy) — the oracle for every downstream pass.
# ---------------------------------------------------------------------------


def interpret(prog: Program, inputs: Dict[str, np.ndarray],
              params: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
    orig_shapes = prog.meta.get("orig_shapes", {})
    mems: Dict[str, np.ndarray] = {}
    for name, decl in prog.mems.items():
        if decl.role in ("input", "param"):
            src = inputs[name] if decl.role == "input" else params[name]
            arr = np.asarray(src, dtype=np.float64)
            if decl.banks:
                arr = pack_banked(arr.reshape(orig_shapes[name]), decl.banks)
            else:
                arr = arr.reshape(decl.shape)
        else:
            arr = np.zeros(decl.shape, dtype=np.float64)
        mems[name] = arr.copy()
    regs: Dict[str, float] = {}

    def veval(e: VExpr, env: Dict[str, int]) -> float:
        if isinstance(e, ConstF):
            return e.value
        if isinstance(e, Load):
            idx = tuple(ix.evaluate(env) for ix in e.idxs)
            return float(mems[e.mem][idx])
        if isinstance(e, ReadReg):
            return regs[e.name]
        if isinstance(e, Bin):
            a, b = veval(e.a, env), veval(e.b, env)
            if e.op == "add":
                return a + b
            if e.op == "sub":
                return a - b
            if e.op == "mul":
                return a * b
            if e.op == "div":
                return a / b
            if e.op == "max":
                return max(a, b)
            return min(a, b)
        if isinstance(e, Un):
            a = veval(e.a, env)
            return {"exp": math.exp(min(a, 700.0)), "relu": max(a, 0.0),
                    "neg": -a}[e.op]
        if isinstance(e, SelectC):
            return veval(e.a, env) if e.cond.evaluate(env) else veval(e.b, env)
        raise TypeError(e)

    def run(stmts: List[Stmt], env: Dict[str, int]):
        for s in stmts:
            if isinstance(s, Store):
                idx = tuple(ix.evaluate(env) for ix in s.idxs)
                mems[s.mem][idx] = veval(s.value, env)
            elif isinstance(s, SetReg):
                regs[s.name] = veval(s.value, env)
            elif isinstance(s, Loop):
                for v in range(s.extent):
                    env2 = dict(env)
                    env2[s.var] = v
                    run(s.body, env2)
            elif isinstance(s, Par):
                for arm in s.arms:   # sequential emulation of par is safe
                    run(arm, env)    # iff arms are hazard-free (checked by pass)
            elif isinstance(s, If):
                run(s.then if s.cond.evaluate(env) else s.els, env)
            else:
                raise TypeError(s)

    run(prog.body, {})
    return mems


# ---------------------------------------------------------------------------
# Structural helpers shared by the passes
# ---------------------------------------------------------------------------


def walk_statements(stmts: List[Stmt]):
    for s in stmts:
        yield s
        if isinstance(s, Loop):
            yield from walk_statements(s.body)
        elif isinstance(s, Par):
            for arm in s.arms:
                yield from walk_statements(arm)
        elif isinstance(s, If):
            yield from walk_statements(s.then)
            yield from walk_statements(s.els)


def value_loads(e: VExpr):
    if isinstance(e, Load):
        yield e
    elif isinstance(e, Bin):
        yield from value_loads(e.a)
        yield from value_loads(e.b)
    elif isinstance(e, Un):
        yield from value_loads(e.a)
    elif isinstance(e, SelectC):
        yield from value_loads(e.a)
        yield from value_loads(e.b)


def stmt_accesses(s: Stmt):
    """Yield (mem, idxs, is_store) for a single non-compound statement."""
    if isinstance(s, Store):
        yield (s.mem, s.idxs, True)
        for ld in value_loads(s.value):
            yield (ld.mem, ld.idxs, False)
    elif isinstance(s, SetReg):
        for ld in value_loads(s.value):
            yield (ld.mem, ld.idxs, False)
