"""Cycle-attribution profiler over the canonical event trace.

``core.trace`` defines the event schema both simulators emit; this
module turns a trace into the artifacts a performance engineer reads:

* :func:`flame_table` — per control-tree node self/total cycles, keyed
  by the provenance paths the events carry (``s<k>``, ``loop_<var>``,
  ``par``/``arm<i>``, ``if``/``then``/``else``, group name).  *Self*
  cycles at a node are group-busy cycles (interval union, so a
  pipelined group's overlapping launch windows count once) plus stall
  durations attributed there; *total* adds every descendant.  Totals
  are attribution mass, not wall-clock: concurrent ``par`` arms each
  contribute their own cycles.
* :func:`occupancy` — per memory-bank port and per functional unit:
  how many distinct cycles carried a grant/issue, as a fraction of the
  run.
* :func:`stall_breakdown` — cycles lost per cause: port-conflict
  serialization, shared-pool waits, initiation-interval recurrence, and
  FSM overhead split by control state (setup/iter/cond/pad/join).
* :func:`to_vcd` — a deterministic VCD waveform from the netlist-level
  trace (group enables, FSM state registers, bank-port en/we), openable
  in GTKWave or Surfer.
* :func:`profile_design` / :class:`Profile` — run both simulators with
  tracing plus the analytic attribution (``estimator.attribute``) and
  the synthesized-counter model (``RtlStats.counters``), cross-check
  all levels for exact equality, and render the report.

The cross-check (:func:`counter_mismatches`) is the observability
differential: Calyx-sim stats == RTL-sim stats == both trace aggregates
== hardware counter values, and — for if-free designs — == the
closed-form attribution, field for field with zero tolerance.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Sequence, Tuple

from . import estimator
from . import trace as T

# ---------------------------------------------------------------------------
# Flame table
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FlameRow:
    """One control-tree node of the attribution flame table."""
    path: Tuple[str, ...]
    self_cycles: int
    total_cycles: int


def _nat(label: str) -> tuple:
    """Natural sort key: ``s2`` before ``s10``."""
    return tuple(int(p) if p.isdigit() else p
                 for p in re.split(r"(\d+)", label))


def _path_key(path: Tuple[str, ...]) -> tuple:
    return tuple(_nat(p) for p in path)


def flame_table(events: Sequence[T.TraceEvent]) -> List[FlameRow]:
    """Per-provenance-path cycle attribution, depth-first order.

    Group windows contribute interval-union busy cycles at the group's
    full path (control path + group leaf); stall events contribute their
    durations at the path they were emitted with.  Ancestors absent from
    the trace appear with ``self == 0`` so the tree renders complete.
    """
    self_c: Dict[Tuple[str, ...], int] = {}
    group_iv: Dict[Tuple[str, ...], List[Tuple[int, int]]] = {}
    for ev in events:
        if ev.kind == T.GROUP_START:
            group_iv.setdefault(ev.prov, []).append((ev.cycle, ev.end))
        elif ev.kind in T.STALL_KINDS:
            self_c[ev.prov] = self_c.get(ev.prov, 0) + ev.dur
    for p, iv in group_iv.items():
        self_c[p] = self_c.get(p, 0) + T._union_cycles(iv)
    paths = set(self_c)
    for p in list(paths):
        for i in range(len(p)):
            paths.add(p[:i])
    paths.add(())
    total_c = {p: self_c.get(p, 0) for p in paths}
    for p in sorted(paths, key=len, reverse=True):
        if p:
            total_c[p[:-1]] += total_c[p]
    return [FlameRow(p, self_c.get(p, 0), total_c[p])
            for p in sorted(paths, key=_path_key)]


def render_flame(rows: Sequence[FlameRow]) -> str:
    """The flame table as fixed-width text (indent = tree depth)."""
    lines = [f"{'node':<44} {'self':>8} {'total':>8}"]
    for r in rows:
        name = r.path[-1] if r.path else "(root)"
        label = "  " * max(0, len(r.path) - 1) + name
        lines.append(f"{label:<44} {r.self_cycles:>8} {r.total_cycles:>8}")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# Occupancy and stall breakdown
# ---------------------------------------------------------------------------


def occupancy(events: Sequence[T.TraceEvent],
              total: int) -> Dict[str, Dict[str, Dict[str, object]]]:
    """Port and unit utilization over a run of ``total`` cycles.

    ``ports`` maps ``<mem>:b<k>`` to the number of distinct cycles the
    bank's single port was granted (``busy``), the grant count
    (``events`` — broadcast reads grant several loads in one cycle), and
    the busy percentage.  ``units`` maps each functional unit (the
    post-binding pool cell of shared units) to its issue cycles.
    """
    ports: Dict[str, set] = {}
    port_n: Dict[str, int] = {}
    units: Dict[str, set] = {}
    unit_n: Dict[str, int] = {}
    for ev in events:
        if ev.kind == T.PORT_GRANT:
            _rw, mem, bank = ev.detail.split(":")
            key = f"{mem}:{bank}"
            ports.setdefault(key, set()).add(ev.cycle)
            port_n[key] = port_n.get(key, 0) + 1
        elif ev.kind == T.UOP and ev.detail.startswith("alu:"):
            cell = ev.detail.split(":")[2]
            units.setdefault(cell, set()).add(ev.cycle)
            unit_n[cell] = unit_n.get(cell, 0) + 1

    def row(busy: set, n: int) -> Dict[str, object]:
        pct = round(100.0 * len(busy) / total, 2) if total else 0.0
        return {"busy": len(busy), "events": n, "pct": pct}

    return {"ports": {k: row(ports[k], port_n[k])
                      for k in sorted(ports, key=_nat)},
            "units": {k: row(units[k], unit_n[k])
                      for k in sorted(units, key=_nat)}}


def stall_breakdown(events: Sequence[T.TraceEvent]) -> Dict[str, object]:
    """Cycles lost per stall cause; ``fsm_detail`` splits control
    overhead by state flavor (setup/iter/cond/pad/join)."""
    out: Dict[str, object] = {"port": 0, "pool": 0, "ii": 0, "fsm": 0}
    detail: Dict[str, int] = {}
    for ev in events:
        if ev.kind == T.STALL_PORT:
            out["port"] += ev.dur
        elif ev.kind == T.STALL_POOL:
            out["pool"] += ev.dur
        elif ev.kind == T.STALL_II:
            out["ii"] += ev.dur
        elif ev.kind == T.STALL_FSM:
            out["fsm"] += ev.dur
            key = ev.detail or "other"
            detail[key] = detail.get(key, 0) + ev.dur
    out["fsm_detail"] = dict(sorted(detail.items()))
    out["total"] = out["port"] + out["pool"] + out["ii"] + out["fsm"]
    return out


# ---------------------------------------------------------------------------
# VCD waveforms
# ---------------------------------------------------------------------------


def _vcd_id(i: int) -> str:
    """Unique printable VCD identifier (bijective base-94)."""
    s = ""
    i += 1
    while i:
        i -= 1
        s = chr(33 + (i % 94)) + s
        i //= 94
    return s


def _vcd_val(val: int, width: int, ident: str) -> str:
    if width == 1:
        return f"{val}{ident}"
    return f"b{val:b} {ident}"


def to_vcd(events: Sequence[T.TraceEvent], name: str = "design") -> str:
    """Render a netlist-level trace as a VCD waveform (1 cycle = 1ns).

    Signals: one 1-bit enable per group (high while any activation is in
    flight — overlapped pipeline launches OR together, like the hardware
    ``g_<g>_go``), one 32-bit state value per controller (from
    ``fsm:state`` events, so Calyx-level traces yield no state signals),
    and per bank-port ``en``/``we`` pulses from the grant events.
    Deterministic byte-for-byte: fixed header, no timestamps.
    """
    groups = sorted({ev.group for ev in events
                     if ev.kind == T.GROUP_START}, key=_nat)
    fsm_events = [ev for ev in events if ev.kind == T.FSM_STATE]
    fsms = sorted({ev.detail.split(".", 1)[0] for ev in fsm_events},
                  key=_nat)
    grants = [ev for ev in events if ev.kind == T.PORT_GRANT]
    port_names: List[str] = []
    for ev in grants:
        _rw, mem, bank = ev.detail.split(":")
        p = f"{mem}_{bank}"
        if p not in port_names:
            port_names.append(p)
    port_names.sort(key=_nat)

    vars_: List[Tuple[str, int, str]] = []     # (ident, width, name)

    def add(vname: str, width: int) -> None:
        vars_.append((_vcd_id(len(vars_)), width, vname))

    for g in groups:
        add(f"g_{g}_go", 1)
    for f in fsms:
        add(f"{f}_state", 32)
    for p in port_names:
        add(f"{p}_en", 1)
        add(f"{p}_we", 1)

    delta: Dict[int, Dict[str, int]] = {}

    def set_at(t: int, vname: str, val: int) -> None:
        delta.setdefault(t, {})[vname] = val

    # group enables: active-count edges over the activation intervals
    edges: Dict[str, Dict[int, int]] = {}
    for ev in events:
        if ev.kind == T.GROUP_START:
            em = edges.setdefault(ev.group, {})
            em[ev.cycle] = em.get(ev.cycle, 0) + 1
            em[ev.end] = em.get(ev.end, 0) - 1
    for g, em in edges.items():
        active = 0
        for t in sorted(em):
            prev = active
            active += em[t]
            if prev == 0 and active > 0:
                set_at(t, f"g_{g}_go", 1)
            elif prev > 0 and active == 0:
                set_at(t, f"g_{g}_go", 0)
    # controller state values
    for ev in fsm_events:
        fsm, rest = ev.detail.split(".", 1)
        idx = int(rest.split(":", 1)[0])
        set_at(ev.cycle, f"{fsm}_state", idx)
    # bank-port pulses
    pulses: Dict[str, Dict[int, Tuple[int, int]]] = {}
    for ev in grants:
        rw, mem, bank = ev.detail.split(":")
        p = f"{mem}_{bank}"
        cur = pulses.setdefault(p, {})
        we = 1 if rw == "W" else 0
        old = cur.get(ev.cycle, (0, 0))
        cur[ev.cycle] = (1, max(we, old[1]))
    for p, cyc in pulses.items():
        for t in sorted(cyc):
            _en, we = cyc[t]
            set_at(t, f"{p}_en", 1)
            set_at(t, f"{p}_we", we)
            if t + 1 not in cyc:
                set_at(t + 1, f"{p}_en", 0)
                set_at(t + 1, f"{p}_we", 0)

    out = ["$comment repro.core.profiler cycle trace $end",
           "$timescale 1ns $end",
           f"$scope module {name} $end"]
    for ident, width, vname in vars_:
        kind = "wire" if width == 1 else "reg"
        out.append(f"$var {kind} {width} {ident} {vname} $end")
    out.append("$upscope $end")
    out.append("$enddefinitions $end")
    out.append("#0")
    out.append("$dumpvars")
    init = delta.pop(0, {})
    for ident, width, vname in vars_:
        out.append(_vcd_val(init.get(vname, 0), width, ident))
    out.append("$end")
    for t in sorted(delta):
        ch = delta[t]
        out.append(f"#{t}")
        for ident, width, vname in vars_:
            if vname in ch:
                out.append(_vcd_val(ch[vname], width, ident))
    return "\n".join(out) + "\n"


# ---------------------------------------------------------------------------
# The four-way counter cross-check
# ---------------------------------------------------------------------------


def _diff_keys(a: Dict[str, object], b: Dict[str, object]) -> str:
    bad = [k for k in a if a[k] != b.get(k)]
    return ", ".join(f"{k}: {a[k]!r} vs {b.get(k)!r}" for k in bad)


def hw_counter_mismatches(hw: Dict[str, int],
                          counters: Dict[str, object]) -> List[str]:
    """Compare the synthesized counter-bank values (``RtlStats.counters``,
    keys ``total``/``group:<g>``/``stall_*``/``fsm_overhead``) against an
    aggregate-shaped counter dict.  A group counter of a never-fired
    group (an untaken ``if`` arm) must read zero."""
    out: List[str] = []
    for key in sorted(hw):
        val = hw[key]
        if key.startswith("group:"):
            want = counters["group_cycles"].get(key[len("group:"):], 0)
        elif key == "total":
            want = counters["total"]
        else:
            want = counters[f"{key}_cycles"]
        if val != want:
            out.append(f"hw counter {key} = {val}, trace/stats say {want}")
    return out


def counter_mismatches(sim_stats, rtl_stats,
                       sim_events: Sequence[T.TraceEvent],
                       rtl_events: Sequence[T.TraceEvent],
                       attribution:
                       Optional[estimator.CycleAttribution] = None,
                       hw_counters: Optional[Dict[str, int]] = None,
                       limit: int = 8) -> List[str]:
    """The full observability differential; empty list = all levels agree.

    Checks, all exact: Calyx-sim counter fields == RTL-sim counter
    fields; each trace aggregates back to its own simulator's stats; the
    two traces join event-for-event; the hardware counter bank reads the
    same values; and the analytic attribution matches (fully for if-free
    designs, ``total`` always).
    """
    out: List[str] = []
    cs = T.counters_of_stats(sim_stats)
    cr = T.counters_of_stats(rtl_stats)
    if cs != cr:
        out.append(f"sim stats != rtl stats: {_diff_keys(cs, cr)}")
    agg_s = T.aggregate(sim_events)
    if agg_s != cs:
        out.append(f"sim trace aggregate != sim stats: "
                   f"{_diff_keys(agg_s, cs)}")
    agg_r = T.aggregate(rtl_events)
    if agg_r != cr:
        out.append(f"rtl trace aggregate != rtl stats: "
                   f"{_diff_keys(agg_r, cr)}")
    out.extend(T.join_mismatches(sim_events, rtl_events, limit))
    if hw_counters is not None:
        out.extend(hw_counter_mismatches(hw_counters, cr))
    if attribution is not None:
        ac = attribution.counters()
        if attribution.exact:
            if ac != cs:
                out.append(f"analytic attribution != measured: "
                           f"{_diff_keys(ac, cs)}")
        elif ac["total"] != cs["total"]:
            out.append(f"analytic total != measured total: "
                       f"{ac['total']} vs {cs['total']}")
    return out


# ---------------------------------------------------------------------------
# Whole-design profiling
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Profile:
    """Everything one profiling run produced, pre-joined."""
    name: str
    sim_stats: object
    rtl_stats: object
    sim_events: List[T.TraceEvent]
    rtl_events: List[T.TraceEvent]
    attribution: estimator.CycleAttribution
    flame: List[FlameRow]
    occupancy: Dict[str, Dict[str, Dict[str, object]]]
    stalls: Dict[str, object]
    hw_counters: Optional[Dict[str, int]]
    mismatches: List[str]

    @property
    def cycles(self) -> int:
        return self.sim_stats.cycles

    def to_vcd(self) -> str:
        return to_vcd(self.rtl_events, name=self.name)

    def report(self) -> str:
        """The attribution report as text (the ``--profile`` output)."""
        lines = [f"design {self.name}: {self.cycles} cycles "
                 f"(attribution {'exact' if self.attribution.exact else 'bounds an input-dependent if'})"]
        if self.mismatches:
            lines.append(f"COUNTER MISMATCHES ({len(self.mismatches)}):")
            lines.extend(f"  {m}" for m in self.mismatches)
        else:
            lines.append("counters agree across sim / rtl_sim / traces / "
                         "hardware bank"
                         + ("" if self.attribution.exact
                            else " (analytic: total only)"))
        lines.append("")
        lines.append(render_flame(self.flame))
        lines.append("")
        lines.append("stalls: " + ", ".join(
            f"{k}={self.stalls[k]}"
            for k in ("port", "pool", "ii", "fsm", "total")))
        fd = self.stalls["fsm_detail"]
        if fd:
            lines.append("  fsm: " + ", ".join(f"{k}={v}"
                                               for k, v in fd.items()))
        lines.append("occupancy:")
        for section in ("ports", "units"):
            for key, row in self.occupancy[section].items():
                lines.append(f"  {section[:-1]} {key}: {row['pct']}% busy "
                             f"({row['busy']} cycles, "
                             f"{row['events']} events)")
        return "\n".join(lines)


def profile_design(design, inputs) -> Profile:
    """Profile a ``pipeline.CompiledDesign``: both simulators traced, the
    profiled netlist's counter bank, the analytic attribution, and the
    cross-check of all of them (``Profile.mismatches`` empty on a
    healthy toolchain — asserted by the benchmark matrix)."""
    tr_sim = T.Tracer()
    _, sim_stats = design.simulate(inputs, tracer=tr_sim)
    tr_rtl = T.Tracer()
    _, rtl_stats = design.simulate_rtl(inputs, tracer=tr_rtl, profile=True)
    att = estimator.attribute(design.component)
    mism = counter_mismatches(sim_stats, rtl_stats, tr_sim.events,
                              tr_rtl.events, attribution=att,
                              hw_counters=rtl_stats.counters)
    return Profile(
        name=design.component.name,
        sim_stats=sim_stats,
        rtl_stats=rtl_stats,
        sim_events=tr_sim.events,
        rtl_events=tr_rtl.events,
        attribution=att,
        flame=flame_table(tr_rtl.events),
        occupancy=occupancy(tr_rtl.events, rtl_stats.cycles),
        stalls=stall_breakdown(tr_rtl.events),
        hw_counters=rtl_stats.counters,
        mismatches=mism,
    )
