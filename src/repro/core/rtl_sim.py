"""Cycle-driven two-state simulator for the structural RTL netlist.

Where ``sim.simulate`` walks the *Calyx control tree* as a scheduler,
this module executes the :class:`rtl.Netlist` itself — the artifact that
``verilog.emit`` prints — one clock cycle at a time: every FSM instance
(root controller plus the child controllers ``par`` states fork) owns a
state register and a down-counter; on each rising edge every live FSM
ticks once, counters decrement, expiring states perform their exit
actions (index increments, loop back-edges, condition branches) and the
successor state's entry actions fire.  Signals are two-state (every wire
carries a definite value — no X/Z propagation), which is the level real
four-state RTL settles to after reset on this design (all state-holding
elements are reset or host-loaded before ``go``).

When a ``group`` state is entered, its datapath block (``rtl.DpBlock``)
executes against the *physical* state: per-bank flat word arrays (never
the logical tensors), the 64-bit data registers, and the controller's
index counters.  Hardware discipline is enforced at netlist granularity:

* every memory access claims its bank's single port at the absolute
  cycle ``group_start + offset``; two same-cycle accesses raise
  :class:`RtlSimError` unless they are identical-address loads (one read
  port broadcasting);
* a group holding a *grant* on a shared unit claims that unit for its
  whole activation window — an overlapping claim by another group means
  the operand muxes would need two selects at once, the single-owner
  invariant ``sharing.share_cells`` promises.

Because the controller's schedule is static (see ``rtl.py``), the
measured cycle count structurally equals ``estimator.cycles`` — the
four-way differential tests assert the equality exactly, alongside
bit-equality of the outputs against ``sim.simulate`` and
``affine.interpret``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from . import dataflow as D
from . import trace as T
from .affine import pack_banked
from .rtl import (DpBlock, DpConst, DpMemRead, DpMemWrite, DpRegRead,
                  DpRegWrite, DpSelect, DpUnit, Fsm, FsmState, Netlist)


class RtlSimError(RuntimeError):
    """A hardware-discipline violation observed at the netlist level."""


@dataclasses.dataclass
class RtlStats:
    """Measured facts about one netlist execution."""
    cycles: int = 0
    fsm_transitions: int = 0          # state-register updates across FSMs
    group_fires: int = 0
    dp_ops: int = 0
    mem_reads: int = 0
    mem_writes: int = 0
    broadcast_reads: int = 0
    par_forks: int = 0                # par states entered (dynamic)
    child_activations: int = 0        # child FSMs launched
    unit_grants: Dict[str, int] = dataclasses.field(default_factory=dict)
    # cycle-attribution counters — same fields as sim.SimStats; the
    # observability differential asserts them equal level-for-level
    group_cycles: Dict[str, int] = dataclasses.field(default_factory=dict)
    stall_port_cycles: int = 0
    stall_pool_cycles: int = 0
    stall_ii_cycles: int = 0
    fsm_overhead_cycles: int = 0
    pipe_launches: int = 0
    # profiled netlists only (net.profile): the per-cycle counter model
    # that mirrors the synthesized Verilog counter conditions exactly —
    # keys "total", "group:<g>", "stall_port", "stall_pool", "stall_ii",
    # "fsm_overhead"
    counters: Optional[Dict[str, int]] = None

    def as_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


class _Scope:
    """Chained index-register file: each live controller owns its loop
    counters; lookups for outer loop variables walk up to the forking
    controller — mirroring ``rtl.Netlist.resolve_index``.  Two concurrent
    par arms looping over the same source-level variable therefore count
    on physically distinct registers, exactly as the emitted RTL does.
    """

    __slots__ = ("vars", "parent")

    def __init__(self, parent: Optional["_Scope"]):
        self.vars: Dict[str, int] = {}
        self.parent = parent

    def __getitem__(self, key: str) -> int:
        s: Optional[_Scope] = self
        while s is not None:
            if key in s.vars:
                return s.vars[key]
            s = s.parent
        raise KeyError(key)

    def get(self, key: str, default=None):
        try:
            return self[key]
        except KeyError:
            return default


class _FsmExec:
    """One live controller instance: state register + down-counter."""

    __slots__ = ("sim", "fsm", "scope", "state", "counter", "done", "phase",
                 "children", "pipe_launched", "pipe_cd", "t0")

    def __init__(self, sim: "_RtlSim", fsm: Fsm, parent: Optional[_Scope]):
        self.sim = sim
        self.fsm = fsm
        self.scope = _Scope(parent)
        self.state: Optional[FsmState] = None
        self.counter = 0
        self.done = False
        self.phase = 0                      # par: 0 = run, 1 = join
        self.children: List["_FsmExec"] = []
        self.pipe_launched = 0              # pipe: iterations launched
        self.pipe_cd = 0                    # pipe: cycles to next launch
        self.t0 = 0                         # activation cycle (stall base)

    # -- state entry ---------------------------------------------------------
    def activate(self, at_cycle: int) -> None:
        self.t0 = at_cycle
        self._enter(self.fsm.states[self.fsm.start], at_cycle)

    def _enter(self, st: FsmState, at_cycle: int) -> None:
        stats = self.sim.stats
        stats.fsm_transitions += 1
        self.state = st
        tr = self.sim._tr
        if tr is not None:
            tr.emit(at_cycle, T.FSM_STATE, st.prov, st.group or "",
                    f"{self.fsm.name}.{st.index}:{st.kind}", dur=st.cycles)
        if st.stall_arm is not None:
            # entry of a serialized par-chain member: everything since
            # this controller's activation was waiting behind its
            # port-conflicting siblings
            wait = at_cycle - self.t0
            stats.stall_port_cycles += wait
            if tr is not None and wait > 0:
                tr.emit(self.t0, T.STALL_PORT, st.stall_arm[0], dur=wait,
                        data=(st.stall_arm[1],))
        if st.kind == "done":
            self.done = True
            return
        if st.set_idx is not None:
            self.scope.vars[st.set_idx] = 0
        if st.kind == "par":
            self.phase = 0
            self.children = [
                _FsmExec(self.sim, self.sim.net.fsms[fid], self.scope)
                for fid in st.children]
            stats.par_forks += 1
            stats.child_activations += len(self.children)
            self.sim.par_depth += 1
            for ch in self.children:
                ch.activate(at_cycle)
            if all(ch.done for ch in self.children):   # all-empty fork
                self.sim.par_exit()
                self.phase = 1
                self.counter = st.join_cycles
                stats.fsm_overhead_cycles += st.join_cycles
                if tr is not None:
                    tr.emit(at_cycle, T.STALL_FSM, st.prov, detail="join",
                            dur=st.join_cycles)
            return
        if st.kind == "pipe":
            # pipelined repeat: launch iteration 0 now (the setup state
            # zeroed the index), then one more every ii cycles in tick()
            var, extent, ii, _lat = st.pipe
            stats.group_cycles[st.group] = \
                stats.group_cycles.get(st.group, 0) + st.cycles
            stats.stall_ii_cycles += (extent - 1) * (ii - 1)
            stats.pipe_launches += 1
            if tr is not None:
                tr.emit(at_cycle, T.PIPE_LAUNCH, st.prov, data=(0,))
            self.sim.pipe_depth += 1
            self.sim.fire_group(st.group, at_cycle, self.scope, st.prov)
            self.pipe_launched = 1
            self.pipe_cd = ii
            self.counter = st.cycles
            return
        if st.kind == "group":
            stats.group_cycles[st.group] = \
                stats.group_cycles.get(st.group, 0) + st.cycles
            self.sim.fire_group(st.group, at_cycle, self.scope, st.prov)
        elif st.kind in ("delay", "cond"):
            # control overhead: loop setup/iterate, if cond/pad
            stats.fsm_overhead_cycles += st.cycles
            if tr is not None:
                tr.emit(at_cycle, T.STALL_FSM, st.prov, detail=st.label,
                        dur=st.cycles)
        self.counter = st.cycles

    # -- one clock edge ------------------------------------------------------
    def tick(self, cycle: int) -> None:
        st = self.state
        if self.done or st is None:
            return
        if st.kind == "par":
            if self.phase == 0:
                for ch in self.children:
                    ch.tick(cycle)
                if all(ch.done for ch in self.children):
                    self.sim.par_exit()
                    self.phase = 1
                    self.counter = st.join_cycles
                    self.sim.stats.fsm_overhead_cycles += st.join_cycles
                    if self.sim._tr is not None:
                        self.sim._tr.emit(cycle + 1, T.STALL_FSM, st.prov,
                                          detail="join", dur=st.join_cycles)
                return
            self.counter -= 1
            if self.counter <= 0:
                self._enter(self.fsm.states[st.next], cycle + 1)
            return
        if st.kind == "pipe":
            var, extent, ii, _lat = st.pipe
            self.counter -= 1
            if self.pipe_launched < extent:
                self.pipe_cd -= 1
                if self.pipe_cd <= 0:
                    i = self.pipe_launched
                    self.scope.vars[var] = i
                    self.sim.stats.pipe_launches += 1
                    if self.sim._tr is not None:
                        self.sim._tr.emit(cycle + 1, T.PIPE_LAUNCH, st.prov,
                                          data=(i,))
                        if ii > 1:
                            self.sim._tr.emit(cycle + 1, T.STALL_II,
                                              st.prov, dur=ii - 1, data=(i,))
                    self.sim.fire_group(st.group, cycle + 1, self.scope,
                                        st.prov)
                    self.pipe_launched += 1
                    self.pipe_cd = ii
            if self.counter <= 0:
                self.sim.pipe_exit()
                self._enter(self.fsm.states[st.next], cycle + 1)
            return
        self.counter -= 1
        if self.counter > 0:
            return
        # state expiry: exit actions decide the successor
        target = st.next
        if st.inc_idx is not None:
            self.scope.vars[st.inc_idx] = \
                self.scope.vars.get(st.inc_idx, 0) + 1
        if st.loop is not None:
            var, extent, head = st.loop
            if self.scope.vars.get(var, 0) < extent:
                target = head
        if st.kind == "cond":
            taken = st.cond.evaluate(self.scope)
            target = st.then_state if taken else st.else_state
        self._enter(self.fsm.states[target], cycle + 1)


class _RtlSim:
    def __init__(self, net: Netlist, tracer: Optional[T.Tracer] = None):
        self.net = net
        self.stats = RtlStats()
        self._tr = tracer                          # trace hook (None = off)
        self.banks: Dict[str, np.ndarray] = {}     # flat f64 word arrays
        self.regs: Dict[str, float] = {}
        self.par_depth = 0
        self.pipe_depth = 0                        # live pipelined loops
        # (bank, cycle) -> (is_store, full address tuple)
        self._ports: Dict[Tuple[str, int], Tuple[bool, tuple]] = {}
        # (unit, cycle) -> owning group
        self._unit_owner: Dict[Tuple[str, int], str] = {}
        # in-bank row strides, precomputed per logical memory
        self._strides: Dict[str, Tuple[int, ...]] = {
            spec.name: spec.row_strides() for spec in net.mems.values()}

    # -- host loading ---------------------------------------------------------
    def load(self, inputs: Dict[str, np.ndarray],
             params: Dict[str, np.ndarray]) -> None:
        """Stage tensors into the physical banks — the writes a host would
        push through the module's host port while the FSM is idle."""
        for spec in self.net.mems.values():
            if spec.role in ("input", "param"):
                src = inputs[spec.name] if spec.role == "input" \
                    else params[spec.name]
                arr = np.asarray(src, dtype=np.float64)
                if spec.banks:
                    arr = pack_banked(arr.reshape(spec.orig_shape),
                                      spec.banks)
                else:
                    arr = arr.reshape(spec.shape)
            else:
                arr = np.zeros(spec.shape, dtype=np.float64)
            if spec.banks:
                for b, bn in enumerate(spec.bank_names):
                    self.banks[bn] = arr[b].reshape(-1).copy()
            else:
                self.banks[spec.bank_names[0]] = arr.reshape(-1).copy()

    def unload(self) -> Dict[str, np.ndarray]:
        """Reassemble every logical memory from its banks (banked layout,
        as declared — identical to what ``sim.simulate`` returns)."""
        out: Dict[str, np.ndarray] = {}
        for spec in self.net.mems.values():
            parts = [self.banks[bn].reshape(spec.intra)
                     for bn in spec.bank_names]
            if spec.banks:
                out[spec.name] = np.stack(parts)
            else:
                out[spec.name] = parts[0].reshape(spec.shape)
        return out

    # -- memory port discipline -----------------------------------------------
    def _locate(self, mem: str, idxs, env: _Scope) -> Tuple[str, int, tuple]:
        spec = self.net.mems[mem]
        vals = tuple(ix.evaluate(env) for ix in idxs)
        if spec.banks:
            bank, addr_dims = int(vals[0]), vals[1:]
        else:
            bank, addr_dims = 0, vals
        flat = sum(int(v) * s for v, s in zip(addr_dims, self._strides[mem]))
        return spec.bank_names[bank], flat, vals

    def _claim_port(self, bank: str, cycle: int, is_store: bool,
                    addr: tuple) -> None:
        key = (bank, cycle)
        prev = self._ports.get(key)
        if prev is None:
            self._ports[key] = (is_store, addr)
            return
        pstore, paddr = prev
        if not is_store and not pstore and paddr == addr:
            self.stats.broadcast_reads += 1
            return
        raise RtlSimError(
            f"[RV020] bank {bank} port double-driven at cycle {cycle}: "
            f"{'write' if is_store else 'read'}@{addr} vs "
            f"{'write' if pstore else 'read'}@{paddr} — the bank has one "
            f"port, one access per cycle")

    def _claim_unit(self, unit: str, group: str, start: int,
                    latency: int) -> None:
        for c in range(start, start + latency):
            owner = self._unit_owner.setdefault((unit, c), group)
            if owner != group:
                raise RtlSimError(
                    f"[RV021] shared unit {unit} granted to {group} while "
                    f"owned by "
                    f"{owner} at cycle {c} — operand muxes need two selects "
                    f"in one cycle")

    def par_exit(self) -> None:
        """A fork completed; once no par is live every stamped window is
        strictly in the past — drop the claim tables so they stay bounded
        by the widest concurrent window, not the whole run (mirrors the
        Calyx simulator's post-par port-table clear)."""
        self.par_depth -= 1
        if self.par_depth == 0 and self.pipe_depth == 0:
            self._ports.clear()
            self._unit_owner.clear()

    def pipe_exit(self) -> None:
        """A pipelined loop drained its last iteration — same bounding
        rule as :meth:`par_exit`."""
        self.pipe_depth -= 1
        if self.par_depth == 0 and self.pipe_depth == 0:
            self._ports.clear()
            self._unit_owner.clear()

    # -- datapath execution ----------------------------------------------------
    def fire_group(self, gname: str, start: int, env: _Scope,
                   prov: Tuple[str, ...] = ()) -> None:
        if self.par_depth == 0 and self.pipe_depth == 0:
            # sequential flow: all stamped windows are strictly past
            self._ports.clear()
            self._unit_owner.clear()
        self.stats.group_fires += 1
        blk: DpBlock = self.net.blocks[gname]
        tr = self._tr
        gprov: Tuple[str, ...] = ()
        if tr is not None:
            gprov = prov + (gname,)
            tr.emit(start, T.GROUP_START, gprov, gname, dur=blk.latency)
            tr.emit(start + blk.latency, T.GROUP_STOP, gprov, gname)
        for uname in blk.pooled_units:
            self._claim_unit(uname, gname, start, blk.latency)
            self.stats.unit_grants[uname] = \
                self.stats.unit_grants.get(uname, 0) + 1
            if tr is not None:
                tr.emit(start, T.POOL_GRANT, gprov, gname, detail=uname,
                        dur=blk.latency)
        wires: Dict[int, float] = {}
        for op in blk.ops:
            self.stats.dp_ops += 1
            if isinstance(op, DpConst):
                if tr is not None:
                    tr.emit(start, T.UOP, gprov, gname, "const")
                wires[op.dst] = op.value
            elif isinstance(op, DpRegRead):
                if tr is not None:
                    tr.emit(start, T.UOP, gprov, gname, f"regrd:{op.reg}")
                wires[op.dst] = self.regs[op.reg]
            elif isinstance(op, DpMemRead):
                bank, flat, vals = self._locate(op.mem, op.idxs, env)
                self._claim_port(bank, start + op.off, False, vals)
                self.stats.mem_reads += 1
                if tr is not None:
                    tr.emit(start + op.off, T.UOP, gprov, gname,
                            f"memrd:{op.mem}")
                    tr.emit(start + op.off, T.PORT_GRANT, gprov, gname,
                            f"R:{op.mem}:b{self.net.banks[bank].index}",
                            data=vals)
                wires[op.dst] = float(self.banks[bank][flat])
            elif isinstance(op, DpUnit):
                if tr is not None:
                    tr.emit(start + op.off, T.UOP, gprov, gname,
                            f"alu:{op.op}:{op.unit}")
                b = None if op.b is None else wires[op.b]
                wires[op.dst] = D.alu(op.op, wires[op.a], b)
            elif isinstance(op, DpSelect):
                if tr is not None:
                    tr.emit(start + op.off, T.UOP, gprov, gname, "select")
                wires[op.dst] = wires[op.a] if op.cond.evaluate(env) \
                    else wires[op.b]
            elif isinstance(op, DpRegWrite):
                if tr is not None:
                    tr.emit(start + op.off, T.UOP, gprov, gname,
                            f"regwr:{op.reg}")
                self.regs[op.reg] = wires[op.src]
            elif isinstance(op, DpMemWrite):
                bank, flat, vals = self._locate(op.mem, op.idxs, env)
                self._claim_port(bank, start + op.off, True, vals)
                self.stats.mem_writes += 1
                if tr is not None:
                    tr.emit(start + op.off, T.UOP, gprov, gname,
                            f"memwr:{op.mem}")
                    tr.emit(start + op.off, T.PORT_GRANT, gprov, gname,
                            f"W:{op.mem}:b{self.net.banks[bank].index}",
                            data=vals)
                self.banks[bank][flat] = wires[op.src]
            else:
                raise TypeError(op)

    # -- clock loop ------------------------------------------------------------
    def run(self) -> int:
        root = _FsmExec(self, self.net.fsms[0], None)
        counters: Optional[Dict[str, int]] = None
        if self.net.profile:
            counters = {_counter_key(c): 0 for c in self.net.counters}
        root.activate(0)                     # go handshake: launch at cycle 0
        cycle = 0
        while not root.done:
            if counters is not None:
                # evaluate the hardware counter-increment conditions on
                # the settled pre-edge state — exactly what each
                # synthesized always_ff samples at this rising edge
                self._count_cycle(root, counters)
            root.tick(cycle)
            cycle += 1
        if counters is not None:
            self.stats.counters = counters
        return cycle                         # done rose after `cycle` cycles

    # -- per-cycle counter model (mirrors verilog._emit_perf_counters) ---------
    def _count_cycle(self, root: "_FsmExec", counters: Dict[str, int]) -> None:
        counters["total"] += 1               # busy && !done: every run cycle
        stack = [root]
        while stack:
            ex = stack.pop()
            st = ex.state
            if ex.done or st is None:
                continue
            if st.kind in ("group", "pipe"):
                counters[f"group:{st.group}"] += 1      # g_<g>_go high
                if st.kind == "pipe":
                    var, extent, ii, _lat = st.pipe
                    if ex.pipe_launched < extent and ex.pipe_cd > 1:
                        counters["stall_ii"] += 1        # inter-launch wait
            elif st.kind in ("delay", "cond"):
                counters["fsm_overhead"] += 1
            elif st.kind == "par":
                if ex.phase == 1:
                    counters["fsm_overhead"] += 1        # join reduction
                else:
                    stack.extend(ex.children)
            if st.stall_weight:
                # each resident cycle of this chain member delays
                # stall_weight later siblings by one cycle
                counters["stall_port"] += st.stall_weight
        # stall_pool stays 0: binding keeps each shared pool inside one
        # serialized chain, so the two-owners condition never fires


def _counter_key(c) -> str:
    return f"group:{c.group}" if c.kind == "group" else c.kind


def simulate(net: Netlist, inputs: Dict[str, np.ndarray],
             params: Dict[str, np.ndarray],
             tracer: Optional[T.Tracer] = None
             ) -> Tuple[Dict[str, np.ndarray], RtlStats]:
    """Execute the netlist cycle-by-cycle; return (logical memories in
    their declared banked layout, measured :class:`RtlStats`)."""
    sim = _RtlSim(net, tracer)
    sim.load(inputs, params)
    sim.stats.cycles = sim.run()
    return sim.unload(), sim.stats
