"""Canonical cycle-attribution event trace shared by both simulators.

The Calyx-level simulator (``core.sim``) and the netlist-level simulator
(``core.rtl_sim``) execute the *same static schedule* at two different
granularities.  This module defines the one event schema both emit so
their traces are join-able event-for-event, the provenance discipline
that makes the join keys line up, and the aggregation that turns a trace
back into the counter values the synthesized perf-counter bank measures
(``rtl.lower_component(profile=True)``).

Event kinds
-----------

======================  =====================================================
kind                    meaning (dur = duration in cycles)
======================  =====================================================
``group:start``         a group's go rises; ``dur`` = group latency
``group:stop``          the matching done (``dur`` = 0)
``fsm:state``           controller state entry — netlist granularity only
``uop``                 one micro-op issues (``detail`` = op descriptor)
``port:grant``          a memory bank port is granted for one cycle
``pool:grant``          a shared-unit grant for a group's whole window
``pipe:launch``         a pipelined loop launches iteration ``data``
``stall:port``          a par arm serialized behind port-conflicting siblings
``stall:pool``          a grant wait on a shared pool (never occurs: binding
                        keeps pools inside one serialized component)
``stall:ii``            cycles lost to an initiation interval > 1
``stall:fsm``           control overhead (setup/iter/cond/pad/join states)
======================  =====================================================

Every kind except ``fsm:state`` is emitted by *both* simulators with
identical (cycle, prov, detail, dur, data) tuples — asserted by
:func:`join_mismatches`.  ``fsm:state`` exists only at netlist
granularity (one event per controller state entry) and is excluded from
the join.

Provenance
----------

``prov`` is the control-tree path of the event as a tuple of labels:
``s<k>`` for the k-th child of a ``seq``, ``loop_<var>`` for a repeat,
``if``/``then``/``else`` for conditionals, ``par``/``arm<i>`` for a
fork's i-th arm, and the group name as the leaf of group-level events.
``core.sim`` builds the path while walking the control tree;
``core.rtl`` stamps the identical path onto every ``FsmState`` at
lowering time (``FsmState.prov``) so ``core.rtl_sim`` replays it — the
two simulators never exchange information, yet their events carry equal
keys.  The path doubles as the flame-graph axis of
``profiler.flame_table``.

Determinism: events carry only ints, strings, and int tuples (never
floats), so a serialized trace is byte-stable across runs and machines —
the golden-trace tests commit one and diff it.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

# -- event kinds -------------------------------------------------------------
GROUP_START = "group:start"
GROUP_STOP = "group:stop"
FSM_STATE = "fsm:state"
UOP = "uop"
PORT_GRANT = "port:grant"
POOL_GRANT = "pool:grant"
PIPE_LAUNCH = "pipe:launch"
STALL_PORT = "stall:port"
STALL_POOL = "stall:pool"
STALL_II = "stall:ii"
STALL_FSM = "stall:fsm"

STALL_KINDS = (STALL_PORT, STALL_POOL, STALL_II, STALL_FSM)

# kinds both simulators must emit identically (fsm:state is netlist-only)
JOIN_KINDS = frozenset({GROUP_START, GROUP_STOP, UOP, PORT_GRANT,
                        POOL_GRANT, PIPE_LAUNCH, *STALL_KINDS})


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One schedule event.  All fields are ints/strings/int-tuples so a
    trace serializes deterministically."""
    cycle: int                      # absolute cycle the event begins
    kind: str
    prov: Tuple[str, ...] = ()      # control-tree provenance chain
    group: str = ""                 # group the event belongs to ("" = none)
    detail: str = ""                # kind-specific descriptor
    dur: int = 0                    # duration in cycles (0 = instantaneous)
    data: Tuple[int, ...] = ()      # kind-specific ints (address, iteration)

    @property
    def end(self) -> int:
        return self.cycle + self.dur

    def sort_key(self) -> tuple:
        return (self.cycle, self.kind, self.prov, self.group, self.detail,
                self.dur, self.data)

    def to_json(self) -> str:
        # explicit key order -> byte-stable serialization
        return json.dumps({"c": self.cycle, "k": self.kind,
                           "p": list(self.prov), "g": self.group,
                           "d": self.detail, "n": self.dur,
                           "a": list(self.data)}, separators=(",", ":"))

    @staticmethod
    def from_json(line: str) -> "TraceEvent":
        o = json.loads(line)
        return TraceEvent(o["c"], o["k"], tuple(o["p"]), o["g"], o["d"],
                          o["n"], tuple(int(v) for v in o["a"]))


class Tracer:
    """Event sink.  Both simulators accept ``tracer=None`` (the default)
    and guard every emission site with ``if tracer is not None`` — the
    zero-cost-when-off hook contract (no event objects, no path tuples,
    no callbacks are ever built when tracing is off)."""

    __slots__ = ("events",)

    def __init__(self) -> None:
        self.events: List[TraceEvent] = []

    def emit(self, cycle: int, kind: str, prov: Tuple[str, ...] = (),
             group: str = "", detail: str = "", dur: int = 0,
             data: Tuple[int, ...] = ()) -> None:
        self.events.append(TraceEvent(cycle, kind, prov, group, detail,
                                      dur, data))

    def sorted_events(self) -> List[TraceEvent]:
        return sorted(self.events, key=TraceEvent.sort_key)


# -- provenance helpers (the single source of the path discipline) -----------


def seq_label(k: int) -> str:
    return f"s{k}"


def loop_label(var: str) -> str:
    return f"loop_{var}" if var else "loop"


def arm_label(i: int) -> str:
    return f"arm{i}"


IF_LABEL = "if"
THEN_LABEL = "then"
ELSE_LABEL = "else"
PAR_LABEL = "par"


# -- serialization -----------------------------------------------------------


def to_jsonl(events: Iterable[TraceEvent]) -> str:
    """One event per line, in emission order; byte-stable."""
    return "".join(ev.to_json() + "\n" for ev in events)


def from_jsonl(text: str) -> List[TraceEvent]:
    return [TraceEvent.from_json(line)
            for line in text.splitlines() if line.strip()]


# -- join --------------------------------------------------------------------


def join_mismatches(a: Sequence[TraceEvent], b: Sequence[TraceEvent],
                    limit: int = 8) -> List[str]:
    """Compare the join-able projection of two traces event-for-event.

    Both traces are filtered to :data:`JOIN_KINDS` and sorted by the full
    event key; any difference is a divergence between the Calyx-level and
    netlist-level execution of the same schedule.  Returns human-readable
    mismatch descriptions (empty = the traces join exactly).
    """
    sa = sorted((ev for ev in a if ev.kind in JOIN_KINDS),
                key=TraceEvent.sort_key)
    sb = sorted((ev for ev in b if ev.kind in JOIN_KINDS),
                key=TraceEvent.sort_key)
    out: List[str] = []
    if len(sa) != len(sb):
        out.append(f"event count differs: {len(sa)} vs {len(sb)}")
    for ea, eb in zip(sa, sb):
        if ea != eb:
            out.append(f"{ea} != {eb}")
            if len(out) >= limit:
                out.append("... (truncated)")
                break
    return out


# -- aggregation (trace -> counter values) -----------------------------------


def _union_cycles(intervals: List[Tuple[int, int]]) -> int:
    """Total length of the union of [start, end) intervals — a pipelined
    group's overlapping launch windows count each busy cycle once, which
    is exactly what the hardware ``g_<group>_go`` active-cycle counter
    measures."""
    total = 0
    hi = None
    for s, e in sorted(intervals):
        if hi is None or s > hi:
            total += e - s
            hi = e
        elif e > hi:
            total += e - hi
            hi = e
    return total


def aggregate(events: Sequence[TraceEvent]) -> Dict[str, object]:
    """Reduce a trace to the counter values the perf-counter bank holds.

    The returned dict carries the same keys/values as the counter fields
    on ``sim.SimStats`` / ``rtl_sim.RtlStats`` and (modulo the
    software-only ``pipe_launches``) the synthesized counter bank — the
    four-way observability differential compares them for exact equality.
    """
    groups: Dict[str, List[Tuple[int, int]]] = {}
    stalls = {k: 0 for k in STALL_KINDS}
    launches = 0
    total = 0
    for ev in events:
        total = max(total, ev.end)
        if ev.kind == GROUP_START:
            groups.setdefault(ev.group, []).append((ev.cycle, ev.end))
        elif ev.kind in stalls:
            stalls[ev.kind] += ev.dur
        elif ev.kind == PIPE_LAUNCH:
            launches += 1
    return {
        "total": total,
        "group_cycles": {g: _union_cycles(iv)
                         for g, iv in sorted(groups.items())},
        "stall_port_cycles": stalls[STALL_PORT],
        "stall_pool_cycles": stalls[STALL_POOL],
        "stall_ii_cycles": stalls[STALL_II],
        "fsm_overhead_cycles": stalls[STALL_FSM],
        "pipe_launches": launches,
    }


def counters_of_stats(stats) -> Dict[str, object]:
    """The counter view of a ``SimStats``/``RtlStats`` object — the same
    shape :func:`aggregate` produces from a trace."""
    return {
        "total": stats.cycles,
        "group_cycles": dict(sorted(stats.group_cycles.items())),
        "stall_port_cycles": stats.stall_port_cycles,
        "stall_pool_cycles": stats.stall_pool_cycles,
        "stall_ii_cycles": stats.stall_ii_cycles,
        "fsm_overhead_cycles": stats.fsm_overhead_cycles,
        "pipe_launches": stats.pipe_launches,
    }
