"""Pure-jnp reference executor for tensor graphs — the correctness oracle.

Every downstream stage (affine lowering, banking, scheduling) must agree
with this executor bit-for-bit (up to float tolerance).  Also usable as a
fast functional form of a traced model for integration with the training
substrate.
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from . import tensor_ir as T


def _op_fn(op: T.TensorOp, env: Dict[str, jnp.ndarray],
           graph: T.Graph) -> jnp.ndarray:
    ins = [env[i] for i in op.inputs]
    k = op.kind
    if k == "matmul":
        return ins[0] @ ins[1]
    if k == "add":
        return ins[0] + ins[1]
    if k == "mul":
        return ins[0] * ins[1]
    if k == "scale":
        return ins[0] * op.attrs["value"]
    if k == "relu":
        return jnp.maximum(ins[0], 0.0)
    if k == "conv2d":
        x, w = ins  # (Cin,H,W), (Cout,Cin,kh,kw)
        out = jax.lax.conv_general_dilated(
            x[None], w, window_strides=(1, 1), padding="VALID",
            dimension_numbers=("NCHW", "OIHW", "NCHW"))
        return out[0]
    if k == "maxpool2d":
        ph, pw = op.attrs["ph"], op.attrs["pw"]
        x = ins[0]
        c, h, w = x.shape
        x = x[:, : (h // ph) * ph, : (w // pw) * pw]
        x = x.reshape(c, h // ph, ph, w // pw, pw)
        return x.max(axis=(2, 4))
    if k == "flatten":
        return ins[0].reshape(-1)
    if k == "reshape":
        return ins[0].reshape(op.shape)
    if k == "transpose":
        return ins[0].T
    if k == "softmax":
        return jax.nn.softmax(ins[0], axis=-1)
    if k == "causal_mask":
        s = ins[0].shape[0]
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        return jnp.where(mask, ins[0], -1e30)
    raise NotImplementedError(k)


def execute_graph(graph: T.Graph, inputs: Dict[str, np.ndarray]
                  ) -> List[np.ndarray]:
    env: Dict[str, jnp.ndarray] = {}
    for op in graph.ops:
        if op.kind == "input":
            env[op.name] = jnp.asarray(inputs[op.name], dtype=jnp.float32)
        elif op.kind == "param":
            env[op.name] = jnp.asarray(graph.params[op.name],
                                       dtype=jnp.float32)
        else:
            env[op.name] = _op_fn(op, env, graph)
    return [np.asarray(env[o]) for o in graph.outputs]


def as_jax_fn(graph: T.Graph):
    """Return a jit-able fn(inputs_dict) -> list of outputs."""

    def fn(inputs):
        env: Dict[str, jnp.ndarray] = {}
        for op in graph.ops:
            if op.kind == "input":
                env[op.name] = jnp.asarray(inputs[op.name], jnp.float32)
            elif op.kind == "param":
                env[op.name] = jnp.asarray(graph.params[op.name], jnp.float32)
            else:
                env[op.name] = _op_fn(op, env, graph)
        return [env[o] for o in graph.outputs]

    return fn
