"""Banking -> Pallas bridge: execute a tensor graph with banked kernels.

The same ``BankingSpec`` that drives the Calyx flow selects the Pallas grid
partition: factor c on each matmul dimension becomes (c, c, c) banks, i.e.
the BlockSpec index_map plays the bank-index role (compile-time constant per
grid step).  Non-matmul ops run through the jnp oracle — on TPU they fuse
into surrounding XLA computations anyway.
"""
from __future__ import annotations

from typing import Dict, List

import jax.numpy as jnp
import numpy as np

from . import tensor_ir as T
from . import jax_backend
from .banking import BankingSpec
from ..kernels import ops as kops


def execute_graph_pallas(graph: T.Graph, inputs: Dict[str, np.ndarray],
                         spec: BankingSpec) -> List[np.ndarray]:
    banks = (spec.factor, spec.factor, spec.factor)
    env: Dict[str, jnp.ndarray] = {}
    for op in graph.ops:
        if op.kind == "input":
            env[op.name] = jnp.asarray(inputs[op.name], jnp.float32)
        elif op.kind == "param":
            env[op.name] = jnp.asarray(graph.params[op.name], jnp.float32)
        elif op.kind == "matmul":
            a, b = env[op.inputs[0]], env[op.inputs[1]]
            env[op.name] = kops.matmul(a, b, banks=banks)
        else:
            env[op.name] = jax_backend._op_fn(op, env, graph)
    return [np.asarray(env[o]) for o in graph.outputs]
