"""Memory banking & partitioning — the paper's core contribution (§3.3).

Two modes, matching the paper's narrative exactly:

* ``layout``  — the paper's technique: raise each banked memory's
  dimensionality and bake the bank index into the leading dimension.  After
  par-unrolling, ``(c*ii + a) % c`` folds to the constant ``a``: every
  parallel arm addresses a statically-known bank, accesses are provably
  disjoint, and no selection hardware is emitted.

* ``branchy`` — the naive scheme the paper argues against: every access is
  guarded by a bank-selection chain (`if`/select over all banks).  The bank
  expression is deliberately kept symbolic (ModAtom/DivAtom), modeling a
  compiler that cannot fold the predicate; all ``prod(factors)`` arms are
  instantiated in hardware, giving the c^d control blow-up.

``check_par_hazards`` implements the static safety analysis: store/store and
store/load pairs across par arms must be *provably disjoint* (some index
dimension differs by a nonzero constant).  In layout mode this proof succeeds
by construction; in branchy mode it cannot, which is the paper's motivation.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .affine import (AExpr, Bin, Cond, ConstF, DivAtom, If, Load, Loop,
                     MemDecl, ModAtom, Par, Program, ReadReg, SelectC, SetReg,
                     Stmt, Store, Un, VExpr, stmt_accesses, walk_statements)


@dataclasses.dataclass
class BankingSpec:
    factor: int = 1                 # cyclic partition factor per dimension
    mode: str = "layout"            # 'layout' | 'branchy'
    mems: Optional[Set[str]] = None  # None = every non-scalar memory

    def factors_for(self, decl: MemDecl) -> Tuple[int, ...]:
        return tuple(min(self.factor, s) for s in decl.shape)


class BankConflictError(RuntimeError):
    pass


# ---------------------------------------------------------------------------
# Access rewriting
# ---------------------------------------------------------------------------


def _bank_and_intra(idxs: Sequence[AExpr], factors: Sequence[int],
                    fold: bool) -> Tuple[AExpr, List[AExpr]]:
    """bank = mixed-radix of (idx_d mod f_d); intra_d = idx_d // f_d."""
    bank = AExpr.const_(0)
    intra: List[AExpr] = []
    strides = []
    s = 1
    for f in reversed(factors):
        strides.insert(0, s)
        s *= f
    for d, (e, f) in enumerate(zip(idxs, factors)):
        if f == 1:
            intra.append(e)
            continue
        if fold:
            m = e.mod(f)
            q = e.floordiv(f)
        else:  # branchy: keep symbolic even when foldable
            m = AExpr({ModAtom(e, f): 1})
            q = AExpr({DivAtom(e, f): 1})
        bank = bank + m * strides[d]
        intra.append(q)
    return bank, intra


def _rewrite_vexpr(e: VExpr, spec: BankingSpec, decls: Dict[str, MemDecl]) -> VExpr:
    if isinstance(e, Load):
        decl = decls.get(e.mem)
        if decl is None or not decl.banks:
            return Load(e.mem, list(e.idxs))
        factors = decl.banks
        nbanks = _prod(factors)
        bank, intra = _bank_and_intra(e.idxs, factors, fold=spec.mode == "layout")
        if spec.mode == "layout":
            return Load(e.mem, [bank] + intra)
        # branchy: select-chain across all banks (all sides instantiated)
        out: VExpr = Load(e.mem, [AExpr.const_(nbanks - 1)] + intra)
        for b in reversed(range(nbanks - 1)):
            out = SelectC(Cond.cmp(bank, "eq", b),
                          Load(e.mem, [AExpr.const_(b)] + intra), out)
        return out
    if isinstance(e, Bin):
        return Bin(e.op, _rewrite_vexpr(e.a, spec, decls),
                   _rewrite_vexpr(e.b, spec, decls))
    if isinstance(e, Un):
        return Un(e.op, _rewrite_vexpr(e.a, spec, decls))
    if isinstance(e, SelectC):
        return SelectC(e.cond, _rewrite_vexpr(e.a, spec, decls),
                       _rewrite_vexpr(e.b, spec, decls))
    return e


def _rewrite_stmts(stmts: List[Stmt], spec: BankingSpec,
                   decls: Dict[str, MemDecl]) -> List[Stmt]:
    out: List[Stmt] = []
    for s in stmts:
        if isinstance(s, Store):
            decl = decls.get(s.mem)
            value = _rewrite_vexpr(s.value, spec, decls)
            if decl is None or not decl.banks:
                out.append(Store(s.mem, list(s.idxs), value))
                continue
            factors = decl.banks
            nbanks = _prod(factors)
            bank, intra = _bank_and_intra(s.idxs, factors,
                                          fold=spec.mode == "layout")
            if spec.mode == "layout":
                out.append(Store(s.mem, [bank] + intra, value))
            else:
                chain: Stmt = Store(s.mem, [AExpr.const_(nbanks - 1)] + intra,
                                    value)
                stmt_chain: List[Stmt] = [chain]
                for b in reversed(range(nbanks - 1)):
                    stmt_chain = [If(Cond.cmp(bank, "eq", b),
                                     [Store(s.mem, [AExpr.const_(b)] + intra,
                                            value)],
                                     stmt_chain)]
                out.extend(stmt_chain)
        elif isinstance(s, SetReg):
            out.append(SetReg(s.name, _rewrite_vexpr(s.value, spec, decls)))
        elif isinstance(s, Loop):
            out.append(Loop(s.var, s.extent, _rewrite_stmts(s.body, spec, decls),
                            kind=s.kind))
        elif isinstance(s, Par):
            out.append(Par([_rewrite_stmts(a, spec, decls) for a in s.arms]))
        elif isinstance(s, If):
            out.append(If(s.cond, _rewrite_stmts(s.then, spec, decls),
                          _rewrite_stmts(s.els, spec, decls)))
        else:
            raise TypeError(s)
    return out


def _prod(xs) -> int:
    out = 1
    for x in xs:
        out *= int(x)
    return out


def _ceildiv(a: int, b: int) -> int:
    return -(-a // b)


def apply_banking(prog: Program, spec: BankingSpec) -> Program:
    """Rewrite memory declarations and every access for the chosen scheme."""
    if spec.factor <= 1:
        return prog
    decls: Dict[str, MemDecl] = {}
    for name, d in prog.mems.items():
        if spec.mems is not None and name not in spec.mems:
            decls[name] = dataclasses.replace(d, banks=())
            continue
        factors = spec.factors_for(d)
        if _prod(factors) <= 1 or d.size <= 1:
            decls[name] = dataclasses.replace(d, banks=())
            continue
        banked_shape = (_prod(factors),) + tuple(
            _ceildiv(s, f) for s, f in zip(d.shape, factors))
        decls[name] = MemDecl(name, banked_shape, d.role, banks=factors)
    body = _rewrite_stmts(prog.body, spec, decls)
    meta = dict(prog.meta)
    meta["banking"] = {"factor": spec.factor, "mode": spec.mode}
    meta["orig_shapes"] = {n: d.shape for n, d in prog.mems.items()}
    return Program(prog.name, decls, body, meta)


# ---------------------------------------------------------------------------
# Static safety analysis (hazards across par arms)
# ---------------------------------------------------------------------------


def _arm_accesses(stmts: List[Stmt]):
    """All (mem, idxs, is_store) pairs reachable in an arm (incl. nested)."""
    for s in walk_statements(stmts):
        yield from stmt_accesses(s)


def _arm_regs(stmts: List[Stmt]) -> Tuple[Set[str], Set[str]]:
    writes: Set[str] = set()
    reads: Set[str] = set()

    def scan_v(e: VExpr):
        if isinstance(e, ReadReg):
            reads.add(e.name)
        elif isinstance(e, Bin):
            scan_v(e.a)
            scan_v(e.b)
        elif isinstance(e, Un):
            scan_v(e.a)
        elif isinstance(e, SelectC):
            scan_v(e.a)
            scan_v(e.b)

    for s in walk_statements(stmts):
        if isinstance(s, SetReg):
            writes.add(s.name)
            scan_v(s.value)
        elif isinstance(s, Store):
            scan_v(s.value)
    return writes, reads


def provably_disjoint(idxs_a: Sequence[AExpr], idxs_b: Sequence[AExpr]) -> bool:
    """True if for some dimension the difference is a nonzero constant."""
    for ea, eb in zip(idxs_a, idxs_b):
        diff = ea - eb
        if diff.is_const() and diff.const_value() != 0:
            return True
    return False


def structurally_equal(idxs_a: Sequence[AExpr], idxs_b: Sequence[AExpr]) -> bool:
    return (len(idxs_a) == len(idxs_b)
            and all(a.key() == b.key() for a, b in zip(idxs_a, idxs_b)))


def check_par_hazards(prog: Program, raise_on_conflict: bool = True) -> List[str]:
    """Pairwise may-alias analysis over every Par block's arms."""
    conflicts: List[str] = []

    def visit(stmts: List[Stmt]):
        for s in stmts:
            if isinstance(s, Par):
                arms = s.arms
                infos = [(list(_arm_accesses(a)), _arm_regs(a)) for a in arms]
                for i in range(len(arms)):
                    for j in range(i + 1, len(arms)):
                        acc_i, (w_i, r_i) = infos[i]
                        acc_j, (w_j, r_j) = infos[j]
                        if w_i & w_j:
                            conflicts.append(
                                f"reg write/write {sorted(w_i & w_j)}")
                        if (w_i & r_j) or (w_j & r_i):
                            conflicts.append(
                                f"reg cross-read {sorted((w_i & r_j) | (w_j & r_i))}")
                        for (m1, x1, st1) in acc_i:
                            for (m2, x2, st2) in acc_j:
                                if m1 != m2 or not (st1 or st2):
                                    continue
                                if provably_disjoint(x1, x2):
                                    continue
                                conflicts.append(
                                    f"mem {m1}: {x1} vs {x2} "
                                    f"({'WW' if st1 and st2 else 'RW'})")
                for a in arms:
                    visit(a)
            elif isinstance(s, Loop):
                visit(s.body)
            elif isinstance(s, If):
                visit(s.then)
                visit(s.els)

    visit(prog.body)
    # dedupe, keep order
    seen = set()
    uniq = [c for c in conflicts if not (c in seen or seen.add(c))]
    if uniq and raise_on_conflict:
        raise BankConflictError("; ".join(uniq[:8]))
    return uniq


# ---------------------------------------------------------------------------
# Metrics for the ablation study
# ---------------------------------------------------------------------------


def count_branch_arms(prog: Program) -> int:
    """Instantiated bank-selection branches (the paper's c^d blow-up)."""
    n = 0

    def scan_v(e: VExpr):
        nonlocal n
        if isinstance(e, SelectC):
            n += 2
            scan_v(e.a)
            scan_v(e.b)
        elif isinstance(e, Bin):
            scan_v(e.a)
            scan_v(e.b)
        elif isinstance(e, Un):
            scan_v(e.a)

    for s in walk_statements(prog.body):
        if isinstance(s, If) and any(isinstance(a, (ModAtom, DivAtom))
                                     for a in s.cond.expr.coeffs):
            n += 2
        if isinstance(s, Store):
            scan_v(s.value)
        elif isinstance(s, SetReg):
            scan_v(s.value)
    return n


def count_divmod_hardware(prog: Program) -> int:
    """Surviving div/mod units (folded away entirely in layout mode)."""
    n = 0
    for s in walk_statements(prog.body):
        for (_, idxs, _) in stmt_accesses(s):
            for e in idxs:
                n += e.divmod_count()
        if isinstance(s, If):
            n += s.cond.expr.divmod_count()
    return n
